"""Public model facade: init / loss / prefill / decode for every family.

The language-model head + cross-entropy is computed in sequence chunks
(lax.scan) so the (B, S, vocab) logits tensor never materializes — at
vocab 256 206 and 1M tokens per step the full tensor is ~0.5 TB; chunking
caps it at (B, loss_chunk, V) per scan step.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from ..runtime.axes import hint
from . import transformer as tf
from .transformer import cast_params_for_compute

__all__ = ["Model", "chunked_ce_loss"]


def chunked_ce_loss(
    h: jax.Array,        # (B, S, D) final hidden states
    w_head: jax.Array,   # (D, V)
    labels: jax.Array,   # (B, S) int32 targets (next token at each position)
    chunk: int,
) -> jax.Array:
    """Mean token cross-entropy, computed chunk-by-chunk over S.

    The body is rematted: without jax.checkpoint the scan SAVES every
    chunk's logits for the backward pass — 12.9 GB/device on the granite
    train_4k dry-run — which defeats the chunking entirely.  Remat
    recomputes each chunk's logits from (hc, w_head) during backprop, so
    peak logits memory is ONE chunk in both passes.
    """
    b, s, d = h.shape
    chunk = min(chunk, s)
    assert s % chunk == 0, (s, chunk)
    n = s // chunk
    hs = jnp.moveaxis(h.reshape(b, n, chunk, d), 1, 0)       # (n, B, c, D)
    ys = jnp.moveaxis(labels.reshape(b, n, chunk), 1, 0)     # (n, B, c)

    @jax.checkpoint
    def body(total, inp):
        hc, yc = inp
        logits = jnp.dot(hc, w_head, preferred_element_type=jnp.float32)
        logits = hint(logits, "batch", None, "model")
        lse = jax.nn.logsumexp(logits, axis=-1)              # (B, c)
        gold = jnp.take_along_axis(logits, yc[..., None], axis=-1)[..., 0]
        return total + jnp.sum(lse - gold), None

    total, _ = jax.lax.scan(body, jnp.zeros((), jnp.float32), (hs, ys))
    return total / (b * s)


@dataclasses.dataclass(frozen=True)
class Model:
    """Family-dispatched model API over an ArchConfig."""

    cfg: Any  # configs.base.ArchConfig

    # -- parameters -------------------------------------------------------

    def init(self, key) -> dict:
        return tf.init_params(self.cfg, key)

    def abstract_params(self) -> Any:
        """Shape/dtype pytree without allocating (dry-run path)."""
        return jax.eval_shape(self.init, jax.random.PRNGKey(0))

    # -- training ---------------------------------------------------------

    def loss_fn(self, params: dict, batch: dict) -> tuple[jax.Array, dict]:
        """batch: tokens|embeds (+enc_embeds, positions3) and labels."""
        cfg = self.cfg
        params = cast_params_for_compute(params, cfg)
        h, aux = tf.forward_train(params, cfg, batch)
        w_head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
        ce = chunked_ce_loss(h, w_head, batch["labels"], cfg.loss_chunk)
        loss = ce
        if cfg.moe is not None:
            loss = loss + cfg.moe.aux_loss_weight * aux
        return loss, {"ce": ce, "aux": aux}

    # -- serving ----------------------------------------------------------

    def init_cache(self, batch_size: int, max_len: int, enc_len: int = 0) -> dict:
        return tf.init_cache(self.cfg, batch_size, max_len, enc_len)

    def prefill(self, params: dict, batch: dict, max_len: int) -> tuple[jax.Array, dict]:
        """Full-context forward; returns (last-token logits (B,V), cache)."""
        cfg = self.cfg
        params = cast_params_for_compute(params, cfg)
        h_last, cache = tf.forward_prefill(params, cfg, batch, max_len)
        w_head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
        logits = jnp.dot(h_last, w_head, preferred_element_type=jnp.float32)
        return logits, cache

    def decode_step(self, params: dict, cache: dict, batch: dict, pos) -> tuple[jax.Array, dict]:
        """One-token step; returns (logits (B,V), updated cache)."""
        params = cast_params_for_compute(params, self.cfg)
        return tf.forward_decode(params, self.cfg, cache, batch, pos)

"""Pure-JAX model zoo for the assigned architectures.

GQA/RoPE/M-RoPE/qk_norm transformers, SwiGLU, MoE (top-k, shared experts,
scatter dispatch), Mamba2 SSD, hybrid (jamba) period stacks, enc-dec
(seamless) — all built with lax.scan over stacked layer params so the
dry-run HLO stays one-layer-sized.
"""
from .model import Model, chunked_ce_loss
from .transformer import forward_decode, forward_prefill, forward_train, init_cache, init_params

__all__ = [
    "Model",
    "chunked_ce_loss",
    "forward_decode",
    "forward_prefill",
    "forward_train",
    "init_cache",
    "init_params",
]

"""Mamba2 (SSD — state-space duality) mixer, pure JAX (arXiv:2405.21060).

The chunked SSD algorithm: quadratic attention-like compute *within* chunks
of length L (MXU-friendly batched matmuls) and a linear recurrence *across*
chunks (lax.scan over the chunk axis).  Heads are sharded over 'model' by
the runtime; the chunk scan is sequential in the HLO but its body is one
small matmul bundle, so programs stay compact for the dry-run.

Shapes (group-broadcast GQA-style: G state groups, Hg = H//G heads/group):
  x   (B, S, H, P)     inputs per head (P = head_dim)
  dt  (B, S, H)        softplus-discretized step sizes
  A   (H,)             negative decay rates
  Bm  (B, S, G, N)     input projections (N = d_state)
  Cm  (B, S, G, N)     output projections

Decode is the O(1) recurrent form over a persistent (B, H, P, N) state.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from .layers import init_linear, rms_norm

__all__ = [
    "ssd_chunked",
    "ssd_decode_step",
    "init_mamba_params",
    "mamba_mixer",
    "mamba_decode_step",
    "causal_conv1d",
    "conv_decode_step",
]


def _segsum(a: jax.Array) -> jax.Array:
    """Lower-triangular segment sums: out[..., i, j] = sum_{j < s <= i} a[..., s].

    Entries with j > i are -inf (they exponentiate to 0 in the decay matrix).
    """
    l = a.shape[-1]
    cs = jnp.cumsum(a, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]  # sum_{j < s <= i}
    i = jnp.arange(l)
    mask = i[:, None] >= i[None, :]
    return jnp.where(mask, diff, -jnp.inf)


def ssd_chunked(
    x: jax.Array,
    dt: jax.Array,
    A: jax.Array,
    Bm: jax.Array,
    Cm: jax.Array,
    *,
    chunk: int = 256,
    initial_state: Optional[jax.Array] = None,
) -> tuple[jax.Array, jax.Array]:
    """Chunked SSD scan.  Returns (y (B,S,H,P), final_state (B,H,P,N))."""
    b, s, h, p = x.shape
    g, n = Bm.shape[2], Bm.shape[3]
    hg = h // g
    chunk = min(chunk, s)
    pad = (-s) % chunk
    if pad:
        # dt = 0 padding is state-neutral: exp(0·A) = 1 decay, zero input.
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0), (0, 0)))
    s_orig, s = s, s + pad
    nc = s // chunk

    f32 = jnp.float32
    # Chunked views, grouped heads: (B, nc, L, G, Hg, ...)
    xc = x.reshape(b, nc, chunk, g, hg, p)
    dtc = dt.reshape(b, nc, chunk, g, hg).astype(f32)
    bc = Bm.reshape(b, nc, chunk, g, n).astype(f32)
    cc = Cm.reshape(b, nc, chunk, g, n).astype(f32)

    xdt = (xc.astype(f32) * dtc[..., None])  # discretized input (B,nc,L,G,Hg,P)
    da = dtc * A.reshape(g, hg)  # (B,nc,L,G,Hg), negative
    da = jnp.moveaxis(da, 2, -1)  # (B,nc,G,Hg,L)
    da_cs = jnp.cumsum(da, axis=-1)  # (B,nc,G,Hg,L)

    # 1. Intra-chunk (diagonal blocks): attention-like quadratic form.
    lmat = jnp.exp(_segsum(da))  # (B,nc,G,Hg,L,L) lower-tri decays
    y_diag = jnp.einsum(
        "bclgn,bcsgn,bcgrls,bcsgrp->bclgrp", cc, bc, lmat, xdt,
        preferred_element_type=f32,
    )

    # 2. Per-chunk end states.
    decay_states = jnp.exp(da_cs[..., -1:] - da_cs)  # (B,nc,G,Hg,L)
    states = jnp.einsum(
        "bcsgn,bcgrs,bcsgrp->bcgrpn", bc, decay_states, xdt,
        preferred_element_type=f32,
    )  # (B,nc,G,Hg,P,N)

    # 3. Inter-chunk recurrence (scan over chunks).
    chunk_decay = jnp.exp(da_cs[..., -1])  # (B,nc,G,Hg)
    if initial_state is None:
        s0 = jnp.zeros((b, g, hg, p, n), f32)
    else:
        s0 = initial_state.reshape(b, g, hg, p, n).astype(f32)

    def step(carry, inp):
        st_c, dec_c = inp  # (B,G,Hg,P,N), (B,G,Hg)
        prev = carry
        new = prev * dec_c[..., None, None] + st_c
        return new, prev  # emit the state *entering* the chunk

    final_state, prev_states = jax.lax.scan(
        step, s0,
        (jnp.moveaxis(states, 1, 0), jnp.moveaxis(chunk_decay, 1, 0)),
    )
    prev_states = jnp.moveaxis(prev_states, 0, 1)  # (B,nc,G,Hg,P,N)

    # 4. Inter-chunk (off-diagonal) contribution.
    state_decay_out = jnp.exp(da_cs)  # (B,nc,G,Hg,L)
    y_off = jnp.einsum(
        "bclgn,bcgrpn,bcgrl->bclgrp", cc, prev_states, state_decay_out,
        preferred_element_type=f32,
    )

    y = (y_diag + y_off).reshape(b, s, h, p).astype(x.dtype)
    if pad:
        y = y[:, :s_orig]
    return y, final_state.reshape(b, h, p, n)


def ssd_decode_step(
    x_t: jax.Array,   # (B, H, P)
    dt_t: jax.Array,  # (B, H)
    A: jax.Array,     # (H,)
    B_t: jax.Array,   # (B, G, N)
    C_t: jax.Array,   # (B, G, N)
    state: jax.Array,  # (B, H, P, N) float32
) -> tuple[jax.Array, jax.Array]:
    """Recurrent form: state' = exp(dt·A)·state + dt·x ⊗ B;  y = state'·C."""
    b, h, p = x_t.shape
    g, n = B_t.shape[1], B_t.shape[2]
    hg = h // g
    f32 = jnp.float32
    dt_f = dt_t.astype(f32)
    da = jnp.exp(dt_f * A)  # (B,H)
    bh = jnp.repeat(B_t.astype(f32), hg, axis=1)  # (B,H,N)
    ch = jnp.repeat(C_t.astype(f32), hg, axis=1)
    upd = (dt_f[..., None] * x_t.astype(f32))[..., None] * bh[:, :, None, :]  # (B,H,P,N)
    new_state = state * da[..., None, None] + upd
    y = jnp.einsum("bhpn,bhn->bhp", new_state, ch)
    return y.astype(x_t.dtype), new_state


# ---------------------------------------------------------------------------
# Causal depthwise conv1d (width d_conv, per-channel)
# ---------------------------------------------------------------------------


def causal_conv1d(x: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """x: (B, S, C); w: (W, C); b: (C,).  y[t] = Σ_i w[i]·x[t-W+1+i] + b."""
    width = w.shape[0]
    pad = jnp.pad(x, ((0, 0), (width - 1, 0), (0, 0)))
    s = x.shape[1]
    y = jnp.zeros_like(x, dtype=jnp.float32)
    for i in range(width):  # W=4: four shifted fused multiply-adds
        y = y + pad[:, i : i + s, :].astype(jnp.float32) * w[i].astype(jnp.float32)
    return (y + b.astype(jnp.float32)).astype(x.dtype)


def conv_decode_step(
    x_t: jax.Array, conv_state: jax.Array, w: jax.Array, b: jax.Array
) -> tuple[jax.Array, jax.Array]:
    """x_t: (B, C); conv_state: (B, W-1, C) past inputs. Returns (y_t, state')."""
    window = jnp.concatenate([conv_state, x_t[:, None, :]], axis=1)  # (B,W,C)
    y = jnp.einsum("bwc,wc->bc", window.astype(jnp.float32), w.astype(jnp.float32))
    y = (y + b.astype(jnp.float32)).astype(x_t.dtype)
    return y, window[:, 1:, :]


# ---------------------------------------------------------------------------
# Full Mamba2 mixer (in_proj -> conv -> SSD -> gated norm -> out_proj)
# ---------------------------------------------------------------------------


def init_mamba_params(key, d_model: int, ssm, dtype) -> dict:
    """ssm is a configs.base.SSMSettings."""
    d_inner = ssm.expand * d_model
    h = d_inner // ssm.head_dim
    conv_dim = d_inner + 2 * ssm.n_groups * ssm.d_state
    d_in_proj = 2 * d_inner + 2 * ssm.n_groups * ssm.d_state + h
    keys = jax.random.split(key, 4)
    return {
        "in_proj": init_linear(keys[0], d_model, d_in_proj, dtype),
        "conv_w": (jax.random.normal(keys[1], (ssm.d_conv, conv_dim), jnp.float32) * 0.2).astype(dtype),
        "conv_b": jnp.zeros((conv_dim,), dtype),
        "dt_bias": jnp.zeros((h,), jnp.float32),
        "A_log": jnp.zeros((h,), jnp.float32),  # A = -exp(A_log) = -1
        "D": jnp.ones((h,), jnp.float32),
        "norm_w": jnp.ones((d_inner,), dtype),
        "out_proj": init_linear(keys[2], d_inner, d_model, dtype),
    }


def _split_proj(z_xbc_dt, d_inner, gn2, h):
    z = z_xbc_dt[..., :d_inner]
    xbc = z_xbc_dt[..., d_inner : d_inner + d_inner + gn2]
    dt = z_xbc_dt[..., -h:]
    return z, xbc, dt


def mamba_mixer(
    params: dict, x: jax.Array, ssm, *, chunk: Optional[int] = None,
    initial_state: Optional[jax.Array] = None, return_state: bool = False,
):
    """Training/prefill forward.  x: (B, S, D) -> (B, S, D).

    With ``return_state``, also returns (conv_state, ssm_state) for decode
    handoff (prefill).
    """
    b, s, d = x.shape
    d_inner = ssm.expand * d
    h = d_inner // ssm.head_dim
    g, n = ssm.n_groups, ssm.d_state
    proj = jnp.dot(x, params["in_proj"], preferred_element_type=jnp.float32).astype(x.dtype)
    z, xbc, dt_raw = _split_proj(proj, d_inner, 2 * g * n, h)
    xbc = causal_conv1d(xbc, params["conv_w"], params["conv_b"])
    xbc = jax.nn.silu(xbc.astype(jnp.float32)).astype(x.dtype)
    xs = xbc[..., :d_inner].reshape(b, s, h, ssm.head_dim)
    bm = xbc[..., d_inner : d_inner + g * n].reshape(b, s, g, n)
    cm = xbc[..., d_inner + g * n :].reshape(b, s, g, n)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + params["dt_bias"])
    A = -jnp.exp(params["A_log"])
    y, final_state = ssd_chunked(
        xs, dt, A, bm, cm, chunk=chunk or ssm.chunk, initial_state=initial_state
    )
    y = y + (params["D"].reshape(h, 1) * xs.astype(jnp.float32)).astype(y.dtype)
    y = y.reshape(b, s, d_inner)
    y = rms_norm(y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype), params["norm_w"])
    out = jnp.dot(y, params["out_proj"], preferred_element_type=x.dtype)
    if return_state:
        width = params["conv_w"].shape[0]
        # conv state = last W-1 *pre-conv* xbc inputs (pad if S < W-1).
        _, xbc_raw, _ = _split_proj(proj, d_inner, 2 * g * n, h)
        tail = xbc_raw[:, -(width - 1) :, :]
        if s < width - 1:
            tail = jnp.pad(tail, ((0, 0), (width - 1 - s, 0), (0, 0)))
        return out, (tail, final_state)
    return out


def mamba_decode_step(
    params: dict, x_t: jax.Array, conv_state: jax.Array, ssm_state: jax.Array, ssm
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """One-token recurrent step.  x_t: (B, D) -> (B, D), updated states."""
    b, d = x_t.shape
    d_inner = ssm.expand * d
    h = d_inner // ssm.head_dim
    g, n = ssm.n_groups, ssm.d_state
    proj = jnp.dot(x_t, params["in_proj"], preferred_element_type=jnp.float32).astype(x_t.dtype)
    z, xbc, dt_raw = _split_proj(proj, d_inner, 2 * g * n, h)
    xbc, conv_state = conv_decode_step(xbc, conv_state, params["conv_w"], params["conv_b"])
    xbc = jax.nn.silu(xbc.astype(jnp.float32)).astype(x_t.dtype)
    xs = xbc[..., :d_inner].reshape(b, h, ssm.head_dim)
    bm = xbc[..., d_inner : d_inner + g * n].reshape(b, g, n)
    cm = xbc[..., d_inner + g * n :].reshape(b, g, n)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + params["dt_bias"])
    A = -jnp.exp(params["A_log"])
    y, ssm_state = ssd_decode_step(xs, dt, A, bm, cm, ssm_state)
    y = y + (params["D"].reshape(h, 1) * xs.astype(jnp.float32)).astype(y.dtype)
    y = y.reshape(b, d_inner)
    y = rms_norm(y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype), params["norm_w"])
    out = jnp.dot(y, params["out_proj"], preferred_element_type=x_t.dtype)
    return out, conv_state, ssm_state

"""Mixture-of-Experts layer with scatter-based dispatch (pure JAX).

Dispatch strategy: scatter/gather with explicit capacity slabs rather than
the one-hot (T, E, C) dispatch einsum — the latter's dispatch tensor is
O(T·E·C) and cannot fit any memory at qwen3-moe scale (1M tokens × 128
experts).  Scatter-add keeps everything O(T·k + E·C·D):

  1. router logits -> softmax -> top-k (weights, ids);
  2. position-in-expert via a one-hot cumsum over the flattened (T·k) routed
     pairs (associative scan — GSPMD partitions it);
  3. tokens scatter-added into per-expert capacity slabs (E, C, D);
  4. grouped expert SwiGLU over the slabs (einsum over the E axis —
     sharded along 'model' for expert parallelism);
  5. outputs gathered back per routed pair and combined with router weights.

Overflowed tokens (beyond capacity) are dropped from that expert (standard
capacity-factor semantics); their combine weight contributes nothing.

The EP model's scheduling hook (core/moe_schedule.py) reorders tokens and
places experts *offline* so that step 3/5's all-to-all crosses as few shard
boundaries as possible; the layer itself is schedule-agnostic (it consumes
an optional ``expert_perm`` giving the EP-chosen expert placement).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from .layers import init_linear

__all__ = ["init_moe_params", "moe_ffn", "router_load_balancing_loss"]


def init_moe_params(key, d_model: int, cfg, dtype) -> dict:
    """cfg is a configs.base.MoESettings."""
    keys = jax.random.split(key, 6)
    p = {
        "router": init_linear(keys[0], d_model, cfg.n_experts, jnp.float32),
        "w_gate": _init_experts(keys[1], cfg.n_experts, d_model, cfg.d_ff_expert, dtype),
        "w_up": _init_experts(keys[2], cfg.n_experts, d_model, cfg.d_ff_expert, dtype),
        "w_down": _init_experts(keys[3], cfg.n_experts, cfg.d_ff_expert, d_model, dtype),
    }
    if cfg.n_shared_experts:
        f_shared = cfg.n_shared_experts * cfg.d_ff_expert
        p["shared"] = {
            "w_gate": init_linear(keys[4], d_model, f_shared, dtype),
            "w_up": init_linear(keys[5], d_model, f_shared, dtype),
            "w_down": init_linear(keys[4], f_shared, d_model, dtype),
            "gate": init_linear(keys[5], d_model, 1, jnp.float32),
        }
    return p


def _init_experts(key, e, d_in, d_out, dtype):
    scale = 1.0 / jnp.sqrt(d_in)
    return (jax.random.normal(key, (e, d_in, d_out), jnp.float32) * scale).astype(dtype)


def router_load_balancing_loss(router_probs: jax.Array, expert_ids: jax.Array, n_experts: int) -> jax.Array:
    """Switch-Transformer aux loss: E * sum_e f_e * p_e (1.0 at uniform)."""
    t = router_probs.shape[0]
    counts = jnp.zeros(n_experts, jnp.float32).at[expert_ids.reshape(-1)].add(1.0)
    frac_tokens = counts / jnp.maximum(counts.sum(), 1.0)
    frac_probs = router_probs.mean(axis=0)
    return n_experts * jnp.sum(frac_tokens * frac_probs)


def _dispatch_shard_map():
    """(shard_map fn, dp axes, tp axis, mesh) if a profile is active.

    GSPMD cannot see that the dispatch scatter is shard-local — each source
    row writes only the slab slice of its own data shard, but the compiler
    partial-sums the full slab across shards anyway (measured: 2 x 0.97 TB
    all-reduce per step on qwen3-moe train).  shard_map expresses the
    locality manually: per-shard scatter/gather with ZERO collectives, and
    (when E divides the 'model' axis) each device builds only ITS expert
    slice — activations are replicated across 'model', so this costs no
    communication either; the combine is one bf16 psum of token outputs
    (the minimal possible all-to-all volume).
    """
    from ..runtime.axes import get_activation_sharding

    prof = get_activation_sharding()
    if prof is None:
        return None
    dp = tuple(prof.logical.get("batch", ()))
    dp = tuple(a for a in dp if a in prof.mesh.shape)
    if not dp:
        return None
    tp = tuple(prof.logical.get("model", ()))
    tp = tuple(a for a in tp if a in prof.mesh.shape)
    try:
        from jax import shard_map as _sm
    except ImportError:  # pragma: no cover - older jax
        from jax.experimental.shard_map import shard_map as _sm
    return _sm, dp, (tp[0] if tp else None), prof.mesh


def _dispatch_scatter(ids_s, pos_c, x_rep, n_experts: int, cap_l: int):
    """(ns, Tl*k) indices + (ns, Tl*k, D) rows -> slab (E, ns, cap_l, D).

    Shard-local when a mesh profile is active (see _dispatch_shard_map);
    falls back to a plain batched scatter otherwise (identical semantics).
    """
    ns, tl, d = x_rep.shape
    sm = _dispatch_shard_map() if ns > 1 else None
    if sm is not None:
        shard_map, dp, tp, mesh = sm
        from jax.sharding import PartitionSpec as P

        tp_size = mesh.shape.get(tp, 1) if tp else 1
        if tp and n_experts % tp_size == 0 and tp_size > 1:
            e_per = n_experts // tp_size

            def local2d(ids_l, pos_l, x_l):
                # Expert-sharded: this device builds only its E-slice.
                e0 = jax.lax.axis_index(tp) * e_per
                rel = ids_l - e0
                ok = (rel >= 0) & (rel < e_per)
                x_m = jnp.where(ok[..., None], x_l, 0)
                rel_c = jnp.clip(rel, 0, e_per - 1)
                sidx = jnp.broadcast_to(
                    jnp.arange(ids_l.shape[0])[:, None], ids_l.shape
                )
                slab_l = jnp.zeros((e_per, ids_l.shape[0], cap_l, d), x_l.dtype)
                return slab_l.at[rel_c, sidx, pos_l].add(x_m, mode="drop")

            return shard_map(
                local2d, mesh=mesh,
                in_specs=(P(dp, None), P(dp, None), P(dp, None, None)),
                out_specs=P(tp, dp, None, None),
                check_vma=False,
            )(ids_s, pos_c, x_rep)

        def local(ids_l, pos_l, x_l):
            # ids_l/pos_l: (ns_local, tl); x_l: (ns_local, tl, d)
            slab_l = jnp.zeros((n_experts, ids_l.shape[0], cap_l, d), x_l.dtype)
            sidx = jnp.broadcast_to(
                jnp.arange(ids_l.shape[0])[:, None], ids_l.shape
            )
            return slab_l.at[ids_l, sidx, pos_l].add(x_l, mode="drop")

        return shard_map(
            local, mesh=mesh,
            in_specs=(P(dp, None), P(dp, None), P(dp, None, None)),
            out_specs=P(None, dp, None, None),
            check_vma=False,
        )(ids_s, pos_c, x_rep)
    sidx = jnp.broadcast_to(jnp.arange(ns)[:, None], ids_s.shape)
    slab = jnp.zeros((n_experts, ns, cap_l, d), x_rep.dtype)
    return slab.at[ids_s, sidx, pos_c].add(x_rep, mode="drop")


def _dispatch_gather(out_slab, ids_s, pos_c):
    """Inverse of _dispatch_scatter: (E, ns, cap_l, D) -> (ns, Tl*k, D)."""
    ns = ids_s.shape[0]
    n_experts = out_slab.shape[0]
    sm = _dispatch_shard_map() if ns > 1 else None
    if sm is not None:
        shard_map, dp, tp, mesh = sm
        from jax.sharding import PartitionSpec as P

        tp_size = mesh.shape.get(tp, 1) if tp else 1
        if tp and n_experts % tp_size == 0 and tp_size > 1:
            e_per = n_experts // tp_size

            def local2d(slab_l, ids_l, pos_l):
                # Each expert shard contributes its tokens' rows; the psum
                # over 'model' is the combine — one bf16 token-activation
                # volume, the minimal cross-shard traffic of MoE.
                e0 = jax.lax.axis_index(tp) * e_per
                rel = ids_l - e0
                ok = (rel >= 0) & (rel < e_per)
                rel_c = jnp.clip(rel, 0, e_per - 1)
                sidx = jnp.broadcast_to(
                    jnp.arange(ids_l.shape[0])[:, None], ids_l.shape
                )
                y = slab_l[rel_c, sidx, pos_l]
                y = jnp.where(ok[..., None], y, 0)
                return jax.lax.psum(y, tp)

            return shard_map(
                local2d, mesh=mesh,
                in_specs=(P(tp, dp, None, None), P(dp, None), P(dp, None)),
                out_specs=P(dp, None, None),
                check_vma=False,
            )(out_slab, ids_s, pos_c)

        def local(slab_l, ids_l, pos_l):
            sidx = jnp.broadcast_to(
                jnp.arange(ids_l.shape[0])[:, None], ids_l.shape
            )
            return slab_l[ids_l, sidx, pos_l]

        return shard_map(
            local, mesh=mesh,
            in_specs=(P(None, dp, None, None), P(dp, None), P(dp, None)),
            out_specs=P(dp, None, None),
            check_vma=False,
        )(out_slab, ids_s, pos_c)
    sidx = jnp.broadcast_to(jnp.arange(ns)[:, None], ids_s.shape)
    return out_slab[ids_s, sidx, pos_c]


def moe_ffn(
    x: jax.Array,  # (T, D) flattened tokens
    params: dict,
    n_experts: int,
    top_k: int,
    capacity: int,
    *,
    norm_topk: bool = True,
    expert_perm: Optional[jax.Array] = None,
    n_dispatch_shards: int = 1,
) -> tuple[jax.Array, jax.Array]:
    """Returns (output (T, D), aux load-balancing loss).

    ``n_dispatch_shards`` (= the data-parallel degree) splits the capacity
    slab into PER-SHARD slices: slab (E, ns, cap/ns, D) where each data
    shard scatters only into its own slice.  Without this the scatter-add
    partial-sums across data shards and GSPMD emits a full-slab all-reduce
    per layer (measured: 2x 0.97 TB/step on qwen3-moe train — 16x the
    traffic of a true dispatch, since each token belongs to exactly one
    shard).  Per-shard slices make the scatter shard-local; only the small
    expert einsum boundary moves data.  This is the paper's hierarchical
    cache-domain structure applied to dispatch: capacity domains nested
    inside expert domains.  ns=1 reproduces the flat semantics (CPU tests).

    ``expert_perm`` (E,) — optional EP-schedule expert placement: logical
    expert e's weights live at slot expert_perm[e], so co-routed experts
    are physically adjacent (same 'model' shard).
    """
    t, d = x.shape
    logits = jnp.dot(x.astype(jnp.float32), params["router"])  # (T, E) f32
    probs = jax.nn.softmax(logits, axis=-1)
    weights, ids = jax.lax.top_k(probs, top_k)  # (T, k)
    if norm_topk:
        weights = weights / jnp.maximum(weights.sum(-1, keepdims=True), 1e-9)
    aux = router_load_balancing_loss(probs, ids, n_experts)

    if expert_perm is not None:
        ids = expert_perm[ids]  # logical -> physical slot

    ns = n_dispatch_shards
    if ns < 1 or t % ns or capacity % ns or capacity // ns < top_k:
        ns = 1  # decode-sized batches: slices would be thinner than top_k
    tl = (t // ns) * top_k   # routed pairs per shard
    cap_l = capacity // ns   # per-shard capacity slice

    # Position of each routed pair within its (expert, shard): one-hot
    # cumsum along the SHARD-LOCAL pair axis — no cross-shard dependency,
    # so the cumsum never all-gathers the one-hot across 'data'.
    ids_s = ids.reshape(ns, tl)                    # (ns, Tl*k)
    onehot = jax.nn.one_hot(ids_s, n_experts, dtype=jnp.int32)  # (ns, Tl*k, E)
    pos = (jnp.cumsum(onehot, axis=1) * onehot).sum(-1) - 1     # (ns, Tl*k)
    keep = pos < cap_l
    pos_c = jnp.minimum(pos, cap_l - 1)

    x_rep = jnp.repeat(x, top_k, axis=0).reshape(ns, tl, d)
    x_rep = jnp.where(keep[..., None], x_rep, 0)
    slab = _dispatch_scatter(ids_s, pos_c, x_rep, n_experts, cap_l)

    # Grouped expert SwiGLU (E sharded over 'model' => expert parallelism).
    # The row-parallel w_down contraction reduces in the compute dtype —
    # the TPU MXU accumulates fp32 internally either way, and a bf16
    # all-reduce halves that collective.
    gate = jnp.einsum("escd,edf->escf", slab, params["w_gate"], preferred_element_type=jnp.float32)
    up = jnp.einsum("escd,edf->escf", slab, params["w_up"], preferred_element_type=jnp.float32)
    h = (jax.nn.silu(gate) * up).astype(x.dtype)
    out_slab = jnp.einsum("escf,efd->escd", h, params["w_down"], preferred_element_type=x.dtype)

    # Gather each routed pair's output and combine with router weights.
    y_pairs = _dispatch_gather(out_slab, ids_s, pos_c)  # (ns, Tl*k, D)
    y_pairs = jnp.where(keep[..., None], y_pairs, 0.0)
    w_flat = weights.reshape(ns, tl, 1)
    y = (y_pairs.astype(jnp.float32) * w_flat).reshape(t, top_k, d).sum(axis=1)
    y = y.astype(x.dtype)

    if "shared" in params:
        sp = params["shared"]
        g = jnp.dot(x, sp["w_gate"], preferred_element_type=jnp.float32)
        u = jnp.dot(x, sp["w_up"], preferred_element_type=jnp.float32)
        hs = (jax.nn.silu(g) * u).astype(x.dtype)
        ys = jnp.dot(hs, sp["w_down"], preferred_element_type=x.dtype)
        sg = jax.nn.sigmoid(jnp.dot(x.astype(jnp.float32), sp["gate"]))  # (T,1)
        y = y + (ys.astype(jnp.float32) * sg).astype(x.dtype)

    return y, aux

"""Transformer / hybrid / SSM / enc-dec stacks (pure JAX, scan-over-layers).

Layer stacks are *stacked pytrees* (leading axis = layer) consumed by
``jax.lax.scan`` so the HLO contains ONE layer body regardless of depth —
compile time and program size stay constant for 72-layer stacks, which the
512-device dry-run depends on.  Heterogeneous stacks (jamba) scan over
*periods* (the 8-layer attn:mamba repeat unit) with the period body
unrolled, so the HLO holds exactly one period.

Each block is wrapped in ``jax.checkpoint`` (remat) when cfg.remat is set:
activation memory = one layer's working set per microbatch, the standard
large-model recipe.
"""
from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp

from ..runtime.axes import hint
from . import mamba2 as m2
from .layers import (
    apply_mrope,
    apply_rope,
    chunked_attention,
    decode_attention,
    init_embedding,
    init_linear,
    init_rms_norm,
    repeat_kv,
    rms_norm,
    swiglu,
)
from .moe import init_moe_params, moe_ffn

__all__ = [
    "init_params",
    "forward_train",
    "forward_prefill",
    "forward_decode",
    "init_cache",
    "moe_capacity",
]


def _dtype(cfg):
    return jnp.bfloat16 if cfg.param_dtype == "bfloat16" else jnp.float32


def _cdtype(cfg):
    return jnp.bfloat16 if cfg.compute_dtype == "bfloat16" else jnp.float32


#: Param leaves that stay float32 under mixed precision (routing decisions,
#: SSD decay rates — small, numerically sensitive).
_KEEP_F32 = ("router", "gate", "dt_bias", "A_log", "D")


def cast_params_for_compute(params: dict, cfg) -> dict:
    """Mixed precision: bf16 compute copies of the (f32 master) weights."""
    cd = _cdtype(cfg)

    def one(path, p):
        name = str(getattr(path[-1], "key", path[-1]))
        if name in _KEEP_F32 or not jnp.issubdtype(p.dtype, jnp.floating):
            return p
        return p.astype(cd)

    return jax.tree_util.tree_map_with_path(one, params)


def moe_capacity(cfg, n_tokens: int) -> int:
    """Static per-expert capacity for a microbatch of ``n_tokens``.

    Rounded to a multiple of 128 so the slab's capacity dim divides the
    batch mesh axes (sharding) and stays MXU-lane aligned.
    """
    e = cfg.moe
    cap = int(n_tokens * e.top_k / e.n_experts * e.capacity_factor)
    cap = max(cap, e.top_k, 8)
    if cap > 128:
        return ((cap + 127) // 128) * 128
    return ((cap + 7) // 8) * 8


# ---------------------------------------------------------------------------
# Parameter init (works under jax.eval_shape for the dry-run)
# ---------------------------------------------------------------------------


def _init_attn(key, cfg, cross: bool = False) -> dict:
    d, hq, hkv, dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    dt = _dtype(cfg)
    ks = jax.random.split(key, 4)
    p = {
        "norm": init_rms_norm(d, dt),
        "wq": init_linear(ks[0], d, hq * dh, dt),
        "wk": init_linear(ks[1], d, hkv * dh, dt),
        "wv": init_linear(ks[2], d, hkv * dh, dt),
        "wo": init_linear(ks[3], hq * dh, d, dt),
    }
    if cfg.qk_norm:
        p["q_norm"] = init_rms_norm(dh, dt)
        p["k_norm"] = init_rms_norm(dh, dt)
    return p


def _init_mlp(key, cfg) -> dict:
    d, f = cfg.d_model, cfg.d_ff
    dt = _dtype(cfg)
    ks = jax.random.split(key, 3)
    return {
        "norm": init_rms_norm(d, dt),
        "w_gate": init_linear(ks[0], d, f, dt),
        "w_up": init_linear(ks[1], d, f, dt),
        "w_down": init_linear(ks[2], f, d, dt),
    }


def _init_moe(key, cfg) -> dict:
    return {
        "norm": init_rms_norm(cfg.d_model, _dtype(cfg)),
        "moe": init_moe_params(key, cfg.d_model, cfg.moe, _dtype(cfg)),
    }


def _init_mamba(key, cfg) -> dict:
    return {
        "norm": init_rms_norm(cfg.d_model, _dtype(cfg)),
        "mamba": m2.init_mamba_params(key, cfg.d_model, cfg.ssm, _dtype(cfg)),
    }


def _stack(init_fn, key, n: int):
    """Stack n independently-initialized param trees along a new axis 0."""
    return jax.vmap(init_fn)(jax.random.split(key, n))


def init_params(cfg, key) -> dict:
    """Full parameter pytree for any family."""
    dt = _dtype(cfg)
    k_embed, k_head, k_stack, k_enc, k_final = jax.random.split(key, 5)
    params: dict[str, Any] = {
        "embed": init_embedding(k_embed, cfg.vocab_size, cfg.d_model, dt),
        "final_norm": init_rms_norm(cfg.d_model, dt),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = init_linear(k_head, cfg.d_model, cfg.vocab_size, dt)

    if cfg.family in ("dense", "moe"):
        ffn_kind = cfg.ffn_kinds()[0]
        if ffn_kind == "moe":
            block = lambda k: {
                "attn": _init_attn(jax.random.fold_in(k, 0), cfg),
                "ffn": _init_moe(jax.random.fold_in(k, 1), cfg),
            }
        else:
            block = lambda k: {
                "attn": _init_attn(jax.random.fold_in(k, 0), cfg),
                "ffn": _init_mlp(jax.random.fold_in(k, 1), cfg),
            }
        params["blocks"] = _stack(block, k_stack, cfg.n_layers)

    elif cfg.family == "ssm":
        params["blocks"] = _stack(lambda k: _init_mamba(k, cfg), k_stack, cfg.n_layers)

    elif cfg.family == "hybrid":
        period = cfg.attn_every
        n_periods = cfg.n_layers // period
        kinds = cfg.layer_kinds()[:period]
        ffns = cfg.ffn_kinds()[:period]
        n_mamba = kinds.count("mamba")
        n_moe = ffns.count("moe")
        n_mlp = ffns.count("mlp")

        def one_period(k):
            p = {
                "attn": _init_attn(jax.random.fold_in(k, 0), cfg),
                "mamba": _stack(
                    lambda kk: _init_mamba(kk, cfg), jax.random.fold_in(k, 1), n_mamba
                ),
            }
            if n_moe:
                p["moe"] = _stack(
                    lambda kk: _init_moe(kk, cfg), jax.random.fold_in(k, 2), n_moe
                )
            if n_mlp:
                p["mlp"] = _stack(
                    lambda kk: _init_mlp(kk, cfg), jax.random.fold_in(k, 3), n_mlp
                )
            return p

        params["periods"] = _stack(one_period, k_stack, n_periods)

    elif cfg.family == "encdec":
        enc_block = lambda k: {
            "attn": _init_attn(jax.random.fold_in(k, 0), cfg),
            "ffn": _init_mlp(jax.random.fold_in(k, 1), cfg),
        }
        dec_block = lambda k: {
            "attn": _init_attn(jax.random.fold_in(k, 0), cfg),
            "cross": _init_attn(jax.random.fold_in(k, 1), cfg, cross=True),
            "ffn": _init_mlp(jax.random.fold_in(k, 2), cfg),
        }
        params["encoder"] = _stack(enc_block, k_enc, cfg.n_encoder_layers)
        params["blocks"] = _stack(dec_block, k_stack, cfg.n_layers)
        params["enc_final_norm"] = init_rms_norm(cfg.d_model, dt)
    else:
        raise ValueError(f"unknown family {cfg.family!r}")
    return params


# ---------------------------------------------------------------------------
# Attention block
# ---------------------------------------------------------------------------


def _project_qkv(p, cfg, x, kv_x):
    """q in flat-head layout (B, H, S, Dh); k/v in cache layout (B, T, Hkv, Dh)."""
    b, s = x.shape[0], x.shape[1]
    t = kv_x.shape[1]
    hq, hkv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    q = jnp.dot(x, p["wq"], preferred_element_type=jnp.float32).astype(x.dtype)
    k = jnp.dot(kv_x, p["wk"], preferred_element_type=jnp.float32).astype(x.dtype)
    v = jnp.dot(kv_x, p["wv"], preferred_element_type=jnp.float32).astype(x.dtype)
    q = q.reshape(b, s, hq, dh).transpose(0, 2, 1, 3)  # (B, H, S, Dh)
    k = k.reshape(b, t, hkv, dh)
    v = v.reshape(b, t, hkv, dh)
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    return q, k, v


def _rope_q(cfg, q, positions, positions3):
    if cfg.mrope and positions3 is not None:
        return apply_mrope(q, positions3, cfg.mrope_sections, cfg.rope_theta)
    return apply_rope(q, positions, cfg.rope_theta)


def _rope_k(cfg, k, positions, positions3):
    # k: (B, T, Hkv, Dh) -> rotate over T with head axis at -2.
    km = k.transpose(0, 2, 1, 3)  # (B,Hkv,T,Dh)
    if cfg.mrope and positions3 is not None:
        km = apply_mrope(km, positions3, cfg.mrope_sections, cfg.rope_theta)
    else:
        km = apply_rope(km, positions, cfg.rope_theta)
    return km.transpose(0, 2, 1, 3)


def attn_block(
    p: dict,
    cfg,
    h: jax.Array,
    positions: jax.Array,
    *,
    causal: bool = True,
    memory: Optional[jax.Array] = None,
    memory_positions: Optional[jax.Array] = None,
    positions3: Optional[jax.Array] = None,
) -> jax.Array:
    """Full-sequence attention (train/prefill).  Residual included."""
    x = rms_norm(h, p["norm"], cfg.norm_eps)
    kv_src = x if memory is None else memory
    q, k, v = _project_qkv(p, cfg, x, kv_src)
    q = _rope_q(cfg, q, positions, positions3)
    kpos = positions if memory is None else memory_positions
    k = _rope_k(cfg, k, kpos, positions3 if memory is None else None)
    out = chunked_attention(
        q, repeat_kv(k, cfg.n_heads), repeat_kv(v, cfg.n_heads),
        causal=causal and memory is None, kv_chunk=cfg.attn_chunk_kv,
    )  # (B, H, S, Dh)
    b, hq, s, dh = out.shape
    out = out.transpose(0, 2, 1, 3).reshape(b, s, hq * dh)
    return h + jnp.dot(out, p["wo"], preferred_element_type=h.dtype)


def attn_block_prefill(p, cfg, h, positions, positions3=None):
    """Like attn_block but also returns the (B,S,Hkv,Dh) k/v for the cache."""
    x = rms_norm(h, p["norm"], cfg.norm_eps)
    q, k, v = _project_qkv(p, cfg, x, x)
    q = _rope_q(cfg, q, positions, positions3)
    k = _rope_k(cfg, k, positions, positions3)
    out = chunked_attention(
        q, repeat_kv(k, cfg.n_heads), repeat_kv(v, cfg.n_heads),
        causal=True, kv_chunk=cfg.attn_chunk_kv,
    )
    b, hq, s, dh = out.shape
    out = out.transpose(0, 2, 1, 3).reshape(b, s, hq * dh)
    h = h + jnp.dot(out, p["wo"], preferred_element_type=h.dtype)
    return h, k, v


def attn_block_decode(
    p, cfg, h, k_cache, v_cache, pos, *, positions3=None, update_cache: bool = True,
):
    """One-token attention.  h: (B, 1, D); caches (B, T, Hkv, Dh); pos scalar.

    With ``update_cache=False`` the caches are used read-only (cross-attn).
    """
    x = rms_norm(h, p["norm"], cfg.norm_eps)
    q, k_new, v_new = _project_qkv(p, cfg, x, x)
    b = h.shape[0]
    positions = jnp.full((b, 1), pos, jnp.int32)
    p3 = None
    if cfg.mrope and positions3 is None:
        p3 = jnp.full((3, b, 1), pos, jnp.int32)
    elif positions3 is not None:
        p3 = positions3
    q = _rope_q(cfg, q, positions, p3)
    if update_cache:
        k_new = _rope_k(cfg, k_new, positions, p3)
        k_cache = jax.lax.dynamic_update_slice_in_dim(k_cache, k_new.astype(k_cache.dtype), pos, axis=1)
        v_cache = jax.lax.dynamic_update_slice_in_dim(v_cache, v_new.astype(v_cache.dtype), pos, axis=1)
        valid_len = pos + 1
    else:
        valid_len = k_cache.shape[1]
    out = decode_attention(q, k_cache, v_cache, valid_len)  # (B, H, 1, Dh)
    b, hq, s, dh = out.shape
    out = out.transpose(0, 2, 1, 3).reshape(b, 1, hq * dh)
    h = h + jnp.dot(out, p["wo"], preferred_element_type=h.dtype)
    return h, k_cache, v_cache


# ---------------------------------------------------------------------------
# FFN blocks
# ---------------------------------------------------------------------------


def mlp_block(p, cfg, h):
    x = rms_norm(h, p["norm"], cfg.norm_eps)
    return h + swiglu(x, p["w_gate"], p["w_up"], p["w_down"])


def moe_block(p, cfg, h, capacity):
    from ..runtime.axes import get_activation_sharding

    b, s, d = h.shape
    x = rms_norm(h, p["norm"], cfg.norm_eps).reshape(b * s, d)
    # Dispatch-shard count = the data-parallel degree (see moe_ffn): the
    # per-shard capacity slices keep the scatter shard-local.
    ns = 1
    prof = get_activation_sharding()
    if prof is not None:
        ns = prof.axis_size(prof.logical.get("batch", ()))
        if b % ns or (b * s) % ns:
            ns = 1
    y, aux = moe_ffn(
        x, p["moe"], cfg.moe.n_experts, cfg.moe.top_k, capacity,
        n_dispatch_shards=ns,
    )
    return h + y.reshape(b, s, d), aux


def mamba_block(p, cfg, h):
    x = rms_norm(h, p["norm"], cfg.norm_eps)
    return h + m2.mamba_mixer(p["mamba"], x, cfg.ssm)


# ---------------------------------------------------------------------------
# Full-sequence forward (training) per family
# ---------------------------------------------------------------------------


def _maybe_remat(fn, cfg):
    return jax.checkpoint(fn) if cfg.remat else fn


def _embed_in(params, cfg, batch) -> tuple[jax.Array, jax.Array, Optional[jax.Array]]:
    """Returns (h, positions, positions3)."""
    if "embeds" in batch:
        h = batch["embeds"]
        b, s = h.shape[0], h.shape[1]
    else:
        tokens = batch["tokens"]
        b, s = tokens.shape
        h = params["embed"][tokens]
    h = hint(h.astype(_cdtype(cfg)), "batch", None, None)
    positions = batch.get("positions")
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    return h, positions, batch.get("positions3")


def forward_train(params, cfg, batch) -> tuple[jax.Array, jax.Array]:
    """Returns (final hidden states (B,S,D), aux loss scalar)."""
    h, positions, positions3 = _embed_in(params, cfg, batch)
    b, s, _ = h.shape

    if cfg.family in ("dense", "moe"):
        ffn_kind = cfg.ffn_kinds()[0]
        cap = moe_capacity(cfg, b * s) if ffn_kind == "moe" else 0

        def body_fn(lp, h):
            h = attn_block(lp["attn"], cfg, h, positions, positions3=positions3)
            if ffn_kind == "moe":
                h, aux = moe_block(lp["ffn"], cfg, h, cap)
            else:
                h, aux = mlp_block(lp["ffn"], cfg, h), jnp.zeros((), jnp.float32)
            return hint(h, "batch", None, None), aux

        body_fn = _maybe_remat(body_fn, cfg)

        def scan_body(carry, lp):
            h, aux_sum = carry
            h, aux = body_fn(lp, h)
            return (h, aux_sum + aux), None

        (h, aux), _ = jax.lax.scan(
            scan_body, (h, jnp.zeros((), jnp.float32)), params["blocks"]
        )

    elif cfg.family == "ssm":
        def body_fn(lp, h):
            return hint(mamba_block(lp, cfg, h), "batch", None, None)

        body_fn = _maybe_remat(body_fn, cfg)

        def scan_body(h, lp):
            return body_fn(lp, h), None

        h, _ = jax.lax.scan(scan_body, h, params["blocks"])
        aux = 0.0

    elif cfg.family == "hybrid":
        period = cfg.attn_every
        kinds = cfg.layer_kinds()[:period]
        ffns = cfg.ffn_kinds()[:period]
        cap = moe_capacity(cfg, b * s)

        def period_fn(pp, h):
            aux = jnp.zeros((), jnp.float32)
            mi = mo = ml = 0
            for j in range(period):
                if kinds[j] == "attn":
                    h = attn_block(pp["attn"], cfg, h, positions)
                else:
                    h = mamba_block(jax.tree.map(lambda a: a[mi], pp["mamba"]), cfg, h)
                    mi += 1
                if ffns[j] == "moe":
                    h, a = moe_block(jax.tree.map(lambda a: a[mo], pp["moe"]), cfg, h, cap)
                    aux = aux + a
                    mo += 1
                elif ffns[j] == "mlp":
                    h = mlp_block(jax.tree.map(lambda a: a[ml], pp["mlp"]), cfg, h)
                    ml += 1
                h = hint(h, "batch", None, None)
            return h, aux

        period_fn = _maybe_remat(period_fn, cfg)

        def scan_body(carry, pp):
            h, aux_sum = carry
            h, aux = period_fn(pp, h)
            return (h, aux_sum + aux), None

        (h, aux), _ = jax.lax.scan(
            scan_body, (h, jnp.zeros((), jnp.float32)), params["periods"]
        )

    elif cfg.family == "encdec":
        memory, mem_pos = encode(params, cfg, batch["enc_embeds"])

        def body_fn(lp, h):
            h = attn_block(lp["attn"], cfg, h, positions)
            h = attn_block(
                lp["cross"], cfg, h, positions,
                memory=memory, memory_positions=mem_pos, causal=False,
            )
            return hint(mlp_block(lp["ffn"], cfg, h), "batch", None, None)

        body_fn = _maybe_remat(body_fn, cfg)

        def scan_body(h, lp):
            return body_fn(lp, h), None

        h, _ = jax.lax.scan(scan_body, h, params["blocks"])
        aux = 0.0
    else:
        raise ValueError(cfg.family)

    return rms_norm(h, params["final_norm"], cfg.norm_eps), aux


def encode(params, cfg, enc_embeds):
    """Bidirectional encoder stack (encdec family)."""
    b, s, _ = enc_embeds.shape
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))

    def body_fn(lp, h):
        h = attn_block(lp["attn"], cfg, h, positions, causal=False)
        return hint(mlp_block(lp["ffn"], cfg, h), "batch", None, None)

    body_fn = _maybe_remat(body_fn, cfg)

    def scan_body(h, lp):
        return body_fn(lp, h), None

    enc_in = hint(enc_embeds.astype(_cdtype(cfg)), "batch", None, None)
    h, _ = jax.lax.scan(scan_body, enc_in, params["encoder"])
    return rms_norm(h, params["enc_final_norm"], cfg.norm_eps), positions


# ---------------------------------------------------------------------------
# KV / state caches
# ---------------------------------------------------------------------------


def init_cache(cfg, batch_size: int, max_len: int, enc_len: int = 0) -> dict:
    """Decode-time cache pytree (zeros; prefill fills it)."""
    dt = _cdtype(cfg)
    hkv, dh = cfg.n_kv_heads, cfg.d_head
    cache: dict[str, Any] = {}
    if cfg.family in ("dense", "moe"):
        cache["k"] = jnp.zeros((cfg.n_layers, batch_size, max_len, hkv, dh), dt)
        cache["v"] = jnp.zeros((cfg.n_layers, batch_size, max_len, hkv, dh), dt)
    elif cfg.family == "ssm":
        s = cfg.ssm
        d_inner = s.expand * cfg.d_model
        h = d_inner // s.head_dim
        conv_dim = d_inner + 2 * s.n_groups * s.d_state
        cache["conv"] = jnp.zeros((cfg.n_layers, batch_size, s.d_conv - 1, conv_dim), dt)
        cache["ssm"] = jnp.zeros(
            (cfg.n_layers, batch_size, h, s.head_dim, s.d_state), jnp.float32
        )
    elif cfg.family == "hybrid":
        period = cfg.attn_every
        n_periods = cfg.n_layers // period
        n_mamba = cfg.layer_kinds()[:period].count("mamba")
        s = cfg.ssm
        d_inner = s.expand * cfg.d_model
        h = d_inner // s.head_dim
        conv_dim = d_inner + 2 * s.n_groups * s.d_state
        cache["k"] = jnp.zeros((n_periods, batch_size, max_len, hkv, dh), dt)
        cache["v"] = jnp.zeros((n_periods, batch_size, max_len, hkv, dh), dt)
        cache["conv"] = jnp.zeros(
            (n_periods, n_mamba, batch_size, s.d_conv - 1, conv_dim), dt
        )
        cache["ssm"] = jnp.zeros(
            (n_periods, n_mamba, batch_size, h, s.head_dim, s.d_state), jnp.float32
        )
    elif cfg.family == "encdec":
        cache["k"] = jnp.zeros((cfg.n_layers, batch_size, max_len, hkv, dh), dt)
        cache["v"] = jnp.zeros((cfg.n_layers, batch_size, max_len, hkv, dh), dt)
        cache["cross_k"] = jnp.zeros((cfg.n_layers, batch_size, enc_len, hkv, dh), dt)
        cache["cross_v"] = jnp.zeros((cfg.n_layers, batch_size, enc_len, hkv, dh), dt)
    return cache


# ---------------------------------------------------------------------------
# Prefill: full-sequence forward that also fills the cache
# ---------------------------------------------------------------------------


def forward_prefill(params, cfg, batch, max_len: int):
    """Returns (last-position hidden (B,D), cache)."""
    h, positions, positions3 = _embed_in(params, cfg, batch)
    b, s, _ = h.shape
    pad = max_len - s

    if cfg.family in ("dense", "moe"):
        ffn_kind = cfg.ffn_kinds()[0]
        cap = moe_capacity(cfg, b * s) if ffn_kind == "moe" else 0

        def body_fn(lp, h):
            h, k, v = attn_block_prefill(lp["attn"], cfg, h, positions, positions3)
            if ffn_kind == "moe":
                h, _ = moe_block(lp["ffn"], cfg, h, cap)
            else:
                h = mlp_block(lp["ffn"], cfg, h)
            kp = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
            vp = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
            return hint(h, "batch", None, None), (kp, vp)

        body_fn = _maybe_remat(body_fn, cfg)

        def scan_body(h, lp):
            h, kv = body_fn(lp, h)
            return h, kv

        h, (ks, vs) = jax.lax.scan(scan_body, h, params["blocks"])
        cache = {"k": ks, "v": vs}

    elif cfg.family == "ssm":
        def body_fn(lp, h):
            x = rms_norm(h, lp["norm"], cfg.norm_eps)
            y, (conv, ssm) = m2.mamba_mixer(lp["mamba"], x, cfg.ssm, return_state=True)
            return hint(h + y, "batch", None, None), (conv, ssm)

        body_fn = _maybe_remat(body_fn, cfg)
        h, (convs, ssms) = jax.lax.scan(lambda h, lp: body_fn(lp, h), h, params["blocks"])
        cache = {"conv": convs.astype(_dtype(cfg)), "ssm": ssms}

    elif cfg.family == "hybrid":
        period = cfg.attn_every
        kinds = cfg.layer_kinds()[:period]
        ffns = cfg.ffn_kinds()[:period]
        cap = moe_capacity(cfg, b * s)

        def period_fn(pp, h):
            convs, ssms = [], []
            mi = mo = ml = 0
            k = v = None
            for j in range(period):
                if kinds[j] == "attn":
                    h, k, v = attn_block_prefill(pp["attn"], cfg, h, positions)
                else:
                    lp = jax.tree.map(lambda a: a[mi], pp["mamba"])
                    x = rms_norm(h, lp["norm"], cfg.norm_eps)
                    y, (conv, ssm) = m2.mamba_mixer(lp["mamba"], x, cfg.ssm, return_state=True)
                    h = h + y
                    convs.append(conv)
                    ssms.append(ssm)
                    mi += 1
                if ffns[j] == "moe":
                    h, _ = moe_block(jax.tree.map(lambda a: a[mo], pp["moe"]), cfg, h, cap)
                    mo += 1
                elif ffns[j] == "mlp":
                    h = mlp_block(jax.tree.map(lambda a: a[ml], pp["mlp"]), cfg, h)
                    ml += 1
                h = hint(h, "batch", None, None)
            kp = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
            vp = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
            return h, (kp, vp, jnp.stack(convs), jnp.stack(ssms))

        period_fn = _maybe_remat(period_fn, cfg)
        h, (ks, vs, convs, ssms) = jax.lax.scan(
            lambda h, pp: period_fn(pp, h), h, params["periods"]
        )
        cache = {"k": ks, "v": vs, "conv": convs.astype(_dtype(cfg)), "ssm": ssms}

    elif cfg.family == "encdec":
        memory, mem_pos = encode(params, cfg, batch["enc_embeds"])

        def body_fn(lp, h):
            h, k, v = attn_block_prefill(lp["attn"], cfg, h, positions)
            # Cross-attention: compute (and cache) k/v of the memory once.
            x = rms_norm(h, lp["cross"]["norm"], cfg.norm_eps)
            q, ck, cv = _project_qkv(lp["cross"], cfg, x, memory)
            q = apply_rope(q, positions, cfg.rope_theta)
            ckr = _rope_k(cfg, ck, mem_pos, None)
            out = chunked_attention(
                q, repeat_kv(ckr, cfg.n_heads), repeat_kv(cv, cfg.n_heads),
                causal=False, kv_chunk=cfg.attn_chunk_kv,
            )
            bb, hq, ss, dh = out.shape
            out = out.transpose(0, 2, 1, 3).reshape(bb, ss, hq * dh)
            h = h + jnp.dot(out, lp["cross"]["wo"], preferred_element_type=h.dtype)
            h = mlp_block(lp["ffn"], cfg, h)
            kp = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
            vp = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
            return hint(h, "batch", None, None), (kp, vp, ckr, cv)

        body_fn = _maybe_remat(body_fn, cfg)
        h, (ks, vs, cks, cvs) = jax.lax.scan(lambda h, lp: body_fn(lp, h), h, params["blocks"])
        cache = {"k": ks, "v": vs, "cross_k": cks, "cross_v": cvs}
    else:
        raise ValueError(cfg.family)

    h = rms_norm(h, params["final_norm"], cfg.norm_eps)
    return h[:, -1, :], cache


# ---------------------------------------------------------------------------
# Decode: one token, cache update
# ---------------------------------------------------------------------------


def forward_decode(params, cfg, cache: dict, batch: dict, pos) -> tuple[jax.Array, dict]:
    """One decode step.  batch: {'tokens': (B,1)} or {'embeds': (B,1,D)}.

    ``pos`` is the scalar write position (current sequence length).
    Returns (logits (B, vocab), updated cache).
    """
    if "embeds" in batch:
        h = batch["embeds"]
    else:
        h = params["embed"][batch["tokens"]]
    h = hint(h.astype(_cdtype(cfg)), "batch", None, None)
    b = h.shape[0]

    if cfg.family in ("dense", "moe"):
        ffn_kind = cfg.ffn_kinds()[0]
        cap = moe_capacity(cfg, b) if ffn_kind == "moe" else 0

        def scan_body(h, xs):
            lp, kc, vc = xs
            h, kc, vc = attn_block_decode(lp["attn"], cfg, h, kc, vc, pos)
            if ffn_kind == "moe":
                h, _ = moe_block(lp["ffn"], cfg, h, cap)
            else:
                h = mlp_block(lp["ffn"], cfg, h)
            return h, (kc, vc)

        h, (ks, vs) = jax.lax.scan(scan_body, h, (params["blocks"], cache["k"], cache["v"]))
        cache = {"k": ks, "v": vs}

    elif cfg.family == "ssm":
        def scan_body(h, xs):
            lp, conv, ssm = xs
            x = rms_norm(h, lp["norm"], cfg.norm_eps)
            y, conv, ssm = m2.mamba_decode_step(lp["mamba"], x[:, 0, :], conv, ssm, cfg.ssm)
            return h + y[:, None, :], (conv, ssm)

        h, (convs, ssms) = jax.lax.scan(
            scan_body, h, (params["blocks"], cache["conv"], cache["ssm"])
        )
        cache = {"conv": convs, "ssm": ssms}

    elif cfg.family == "hybrid":
        period = cfg.attn_every
        kinds = cfg.layer_kinds()[:period]
        ffns = cfg.ffn_kinds()[:period]
        cap = moe_capacity(cfg, b)

        def scan_body(h, xs):
            pp, kc, vc, convs, ssms = xs
            new_convs, new_ssms = [], []
            mi = mo = ml = 0
            for j in range(period):
                if kinds[j] == "attn":
                    h, kc, vc = attn_block_decode(pp["attn"], cfg, h, kc, vc, pos)
                else:
                    lp = jax.tree.map(lambda a: a[mi], pp["mamba"])
                    x = rms_norm(h, lp["norm"], cfg.norm_eps)
                    y, conv, ssm = m2.mamba_decode_step(
                        lp["mamba"], x[:, 0, :], convs[mi], ssms[mi], cfg.ssm
                    )
                    h = h + y[:, None, :]
                    new_convs.append(conv)
                    new_ssms.append(ssm)
                    mi += 1
                if ffns[j] == "moe":
                    h, _ = moe_block(jax.tree.map(lambda a: a[mo], pp["moe"]), cfg, h, cap)
                    mo += 1
                elif ffns[j] == "mlp":
                    h = mlp_block(jax.tree.map(lambda a: a[ml], pp["mlp"]), cfg, h)
                    ml += 1
            return h, (kc, vc, jnp.stack(new_convs), jnp.stack(new_ssms))

        h, (ks, vs, convs, ssms) = jax.lax.scan(
            scan_body, h,
            (params["periods"], cache["k"], cache["v"], cache["conv"], cache["ssm"]),
        )
        cache = {"k": ks, "v": vs, "conv": convs, "ssm": ssms}

    elif cfg.family == "encdec":
        def scan_body(h, xs):
            lp, kc, vc, ck, cv = xs
            h, kc, vc = attn_block_decode(lp["attn"], cfg, h, kc, vc, pos)
            h, _, _ = attn_block_decode(
                lp["cross"], cfg, h, ck, cv, pos, update_cache=False
            )
            h = mlp_block(lp["ffn"], cfg, h)
            return h, (kc, vc)

        h, (ks, vs) = jax.lax.scan(
            scan_body, h,
            (params["blocks"], cache["k"], cache["v"], cache["cross_k"], cache["cross_v"]),
        )
        cache = {"k": ks, "v": vs, "cross_k": cache["cross_k"], "cross_v": cache["cross_v"]}
    else:
        raise ValueError(cfg.family)

    h = rms_norm(h, params["final_norm"], cfg.norm_eps)
    w_head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = jnp.dot(h[:, 0, :], w_head, preferred_element_type=jnp.float32)
    return logits, cache

"""Shared neural-net primitives for the model zoo (pure JAX).

Everything here is written for pjit/GSPMD: no explicit collectives, shapes
kept scan-friendly, attention chunked (online-softmax) so the O(S^2) score
matrix never materializes — the memory-planning requirement for the 32k
prefill shapes on a 16 GB-HBM chip.

Conventions:
  * activations (B, S, D); attention heads grouped as (B, Hkv, G, S, Dh)
    with G = n_heads // n_kv_heads (GQA without materializing repeated KV);
  * norms/softmax accumulate in float32 regardless of activation dtype;
  * params are plain nested dicts of jnp arrays (stacked across layers by
    the stack builders in transformer.py).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "rms_norm",
    "make_rope_cache",
    "apply_rope",
    "apply_mrope",
    "swiglu",
    "chunked_attention",
    "decode_attention",
    "init_linear",
    "init_rms_norm",
    "init_embedding",
]


# ---------------------------------------------------------------------------
# Init helpers (used under jax.eval_shape for the dry-run's abstract params)
# ---------------------------------------------------------------------------


def init_linear(key, d_in: int, d_out: int, dtype) -> jax.Array:
    scale = 1.0 / np.sqrt(d_in)
    return (jax.random.normal(key, (d_in, d_out), jnp.float32) * scale).astype(dtype)


def init_rms_norm(d: int, dtype) -> jax.Array:
    return jnp.ones((d,), dtype=dtype)


def init_embedding(key, vocab: int, d: int, dtype) -> jax.Array:
    return (jax.random.normal(key, (vocab, d), jnp.float32) * 0.02).astype(dtype)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------


def rms_norm(x: jax.Array, w: jax.Array, eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * w.astype(jnp.float32)).astype(x.dtype)


# ---------------------------------------------------------------------------
# Rotary embeddings (standard + M-RoPE)
# ---------------------------------------------------------------------------


def make_rope_cache(positions: jax.Array, d_head: int, theta: float) -> tuple[jax.Array, jax.Array]:
    """cos/sin tables for given positions.

    positions: (..., S) int/float -> returns cos, sin of shape (..., S, d_head//2).
    """
    half = d_head // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions.astype(jnp.float32)[..., None] * freqs  # (..., S, half)
    return jnp.cos(ang), jnp.sin(ang)


def _rotate(x: jax.Array, cos: jax.Array, sin: jax.Array) -> jax.Array:
    """Rotate pairs (x1, x2) = (x[..., :half], x[..., half:]) by cos/sin."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    xf1, xf2 = x1.astype(jnp.float32), x2.astype(jnp.float32)
    return jnp.concatenate(
        [xf1 * cos - xf2 * sin, xf2 * cos + xf1 * sin], axis=-1
    ).astype(x.dtype)


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (B, H, S, Dh) or (B, Hkv, G, S, Dh); positions: (B, S)."""
    cos, sin = make_rope_cache(positions, x.shape[-1], theta)  # (B, S, half)
    shape = (cos.shape[0],) + (1,) * (x.ndim - 3) + cos.shape[1:]
    return _rotate(x, cos.reshape(shape), sin.reshape(shape))


def apply_mrope(
    x: jax.Array, positions3: jax.Array, sections: tuple[int, ...], theta: float
) -> jax.Array:
    """Multimodal RoPE (qwen2-vl §2): 3 position streams (t, h, w).

    x: (B, ..., S, Dh); positions3: (3, B, S).  ``sections`` gives how many
    of the Dh//2 rotary frequency pairs take their position from each
    stream (sum(sections) == Dh//2).
    """
    half = x.shape[-1] // 2
    assert sum(sections) == half, (sections, half)
    cos3, sin3 = make_rope_cache(positions3, x.shape[-1], theta)  # (3, B, S, half)
    sel = np.repeat(np.arange(len(sections)), sections)  # (half,) stream per freq
    sel = jnp.asarray(sel)
    idx = jnp.arange(half)
    cos = cos3[sel, :, :, idx]  # (half, B, S) - advanced indexing moves axis front
    sin = sin3[sel, :, :, idx]
    cos = jnp.moveaxis(cos, 0, -1)  # (B, S, half)
    sin = jnp.moveaxis(sin, 0, -1)
    shape = (cos.shape[0],) + (1,) * (x.ndim - 3) + cos.shape[1:]
    return _rotate(x, cos.reshape(shape), sin.reshape(shape))


# ---------------------------------------------------------------------------
# SwiGLU MLP
# ---------------------------------------------------------------------------


def swiglu(x: jax.Array, w_gate: jax.Array, w_up: jax.Array, w_down: jax.Array) -> jax.Array:
    """SwiGLU feed-forward.

    Column-parallel projections accumulate f32 (local, no collective); the
    row-parallel w_down contraction emits in the compute dtype so its
    tensor-parallel all-reduce moves bf16, not f32 — the TPU MXU
    accumulates f32 internally either way, only the cross-shard sum is
    rounded (Megatron-standard; halves the dominant train collective).
    """
    gate = jnp.dot(x, w_gate, preferred_element_type=jnp.float32)
    up = jnp.dot(x, w_up, preferred_element_type=jnp.float32)
    h = (jax.nn.silu(gate) * up).astype(x.dtype)
    return jnp.dot(h, w_down, preferred_element_type=x.dtype)


# ---------------------------------------------------------------------------
# Chunked (flash-style) attention — pure JAX online softmax
# ---------------------------------------------------------------------------
#
# Head layout: FULL heads (B, H, S, Dh), with GQA KV repeated to H at
# compute time (q head h reads kv head h // G).  Rationale (measured on the
# dry-run): the grouped (B, Hkv, G, S, Dh) layout cannot be sharded 16-ways
# when Hkv = 8 — GSPMD would need a 2-dim (Hkv x G) tile and falls back to
# involuntary full rematerialization; the flat-H layout shards cleanly
# (64 % 16 == 0) and the KV repeat is a cheap local broadcast.  Only the
# KV loop is chunked (lax.scan, online softmax): the scores transient is
# O(S·kc) per head, and q stays un-chunked so no sharded-axis dynamic
# slicing appears in the HLO.


def repeat_kv(k: jax.Array, n_heads: int) -> jax.Array:
    """(B, T, Hkv, Dh) -> (B, Hq, T, Dh); q head h maps to kv head h // G."""
    b, t, hkv, dh = k.shape
    g = n_heads // hkv
    k = k.transpose(0, 2, 1, 3)  # (B, Hkv, T, Dh)
    if g == 1:
        return k
    return jnp.repeat(k, g, axis=1)


def chunked_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    kv_chunk: int = 1024,
) -> jax.Array:
    """Online-softmax attention over KV chunks; never materializes (S, T).

    q: (B, H, S, Dh); k, v: (B, H, T, Dh) (already head-repeated).
    Returns (B, H, S, Dh).  Causality uses absolute offsets, so
    cross-attention (causal=False) shares the implementation.
    """
    b, h, s, dh = q.shape
    t = k.shape[2]
    kv_chunk = min(kv_chunk, t)
    pad = (-t) % kv_chunk
    if pad:  # ragged T: pad keys; padded positions are masked below
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
    t_pad = t + pad
    nk = t_pad // kv_chunk
    scale = 1.0 / np.sqrt(dh)

    ks = jnp.moveaxis(k.reshape(b, h, nk, kv_chunk, dh), 2, 0)  # (nk,B,H,kc,Dh)
    vs = jnp.moveaxis(v.reshape(b, h, nk, kv_chunk, dh), 2, 0)
    q_pos = jnp.arange(s)
    k_pos_base = jnp.arange(kv_chunk)
    qf = q  # keep input dtype for the MXU; accumulate f32

    # Remat: the scan would otherwise SAVE the (B,H,S,kc) probability block
    # of every kv step for the backward pass (O(S·T) again — 2.1 GB/device
    # on the granite train_4k dry-run); recompute it instead.
    @jax.checkpoint
    def kv_step(carry, inp):
        m_prev, l_prev, acc = carry
        ki, kb, vb = inp
        sblk = jnp.einsum(
            "bhsd,bhkd->bhsk", qf, kb, preferred_element_type=jnp.float32
        ) * scale  # (B,H,S,kc) f32
        kpos = ki * kv_chunk + k_pos_base
        if causal:
            mask = q_pos[:, None] >= kpos[None, :]
            if pad:
                mask = mask & (kpos < t)[None, :]
            sblk = jnp.where(mask, sblk, -jnp.inf)
        elif pad:
            sblk = jnp.where((kpos < t)[None, :], sblk, -jnp.inf)
        m_cur = jnp.max(sblk, axis=-1)
        m_new = jnp.maximum(m_prev, m_cur)
        safe_m = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
        p = jnp.exp(sblk - safe_m[..., None])
        p = jnp.where(jnp.isfinite(sblk), p, 0.0)
        alpha = jnp.where(jnp.isfinite(m_prev), jnp.exp(m_prev - safe_m), 0.0)
        l_new = l_prev * alpha + p.sum(axis=-1)
        pv = jnp.einsum(
            "bhsk,bhkd->bhsd", p.astype(vb.dtype), vb,
            preferred_element_type=jnp.float32,
        )
        acc_new = acc * alpha[..., None] + pv
        return (m_new, l_new, acc_new), None

    # Derive carry inits from q so their sharding matches q's — fresh
    # jnp.zeros would let the partitioner pick a conflicting layout for the
    # scan carry (observed: involuntary full rematerialization per step).
    qz = (q[..., 0] * 0).astype(jnp.float32)  # (B,H,S) with q's sharding
    m0 = qz - jnp.inf
    l0 = qz
    a0 = (q * 0).astype(jnp.float32)
    (m, l, acc), _ = jax.lax.scan(kv_step, (m0, l0, a0), (jnp.arange(nk), ks, vs))
    l = jnp.maximum(l, 1e-20)
    return (acc / l[..., None]).astype(q.dtype)


def decode_attention(
    q: jax.Array,        # (B, H, 1, Dh) — single new token
    k_cache: jax.Array,  # (B, T, Hkv, Dh)
    v_cache: jax.Array,  # (B, T, Hkv, Dh)
    pos: jax.Array,      # scalar or (B,) current length (tokens < pos valid)
) -> jax.Array:
    """One-token attention over a (possibly seq-sharded) KV cache.

    The cache is consumed in its NATIVE (B, T, Hkv, Dh) layout via a grouped
    einsum — no head repeat: repeating a seq-sharded 32k cache forces GSPMD
    to replicate it (GBs of transient per device); resharding the one-token
    q instead is free.  Scores stay seq-sharded; the masked softmax over the
    sharded T is partial reductions + a tiny all-reduce.
    """
    b, t, hkv = k_cache.shape[0], k_cache.shape[1], k_cache.shape[2]
    h, dh = q.shape[1], q.shape[-1]
    g = h // hkv
    scale = 1.0 / np.sqrt(dh)
    qg = q[:, :, 0, :].reshape(b, hkv, g, dh)  # q head h -> kv head h // g
    s = jnp.einsum(
        "bhgd,bthd->bhgt", qg, k_cache, preferred_element_type=jnp.float32
    ) * scale  # (B, Hkv, G, T)
    if jnp.ndim(pos) == 0:
        valid = jnp.arange(t) < pos
        s = jnp.where(valid[None, None, None, :], s, -jnp.inf)
    else:
        valid = jnp.arange(t)[None, :] < pos[:, None]  # (B, T)
        s = jnp.where(valid[:, None, None, :], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum(
        "bhgt,bthd->bhgd", p.astype(v_cache.dtype), v_cache,
        preferred_element_type=jnp.float32,
    )  # (B, Hkv, G, Dh)
    return out.reshape(b, h, 1, dh).astype(q.dtype)

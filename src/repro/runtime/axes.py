"""Logical activation-sharding hints for model code.

Model layers are mesh-agnostic; launchers install an ``ActivationSharding``
profile (mesh + logical→physical axis mapping) and layers call
``hint(x, 'batch', None, None)`` at layer boundaries.  Without a profile
installed (unit tests, single-device runs) hints are no-ops.

Why this exists (measured on the granite train_4k dry-run): GSPMD drops the
batch sharding of the residual stream a few matmuls into the stack — the
per-layer saved activations then hold the FULL batch per device (16x the
bytes) and the partitioner invents conflicting layouts inside scan bodies.
Pinning the residual to (batch, None, None) at block boundaries restores
the canonical Megatron activation layout everywhere.

Divisibility-guarded like the weight rules: a logical axis resolves to its
mesh axes only when the dim divides evenly, so batch=1 decode shapes
silently replicate instead of failing.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

__all__ = ["ActivationSharding", "set_activation_sharding", "get_activation_sharding", "hint"]

_ACTIVE: Optional["ActivationSharding"] = None


@dataclasses.dataclass(frozen=True)
class ActivationSharding:
    mesh: Mesh
    logical: dict  # e.g. {'batch': ('pod','data'), 'model': ('model',)}

    def axis_size(self, names) -> int:
        n = 1
        for a in names:
            n *= self.mesh.shape.get(a, 1)
        return n


def set_activation_sharding(profile: Optional[ActivationSharding]) -> None:
    global _ACTIVE
    _ACTIVE = profile


def get_activation_sharding() -> Optional[ActivationSharding]:
    return _ACTIVE


def hint(x: jax.Array, *logical_spec) -> jax.Array:
    """Constrain ``x`` to the resolved logical spec (no-op without profile).

    Entries are logical axis names ('batch', 'model', ...) or None.
    """
    prof = _ACTIVE
    if prof is None:
        return x
    dims = []
    for i, name in enumerate(logical_spec):
        if name is None:
            dims.append(None)
            continue
        axes = prof.logical.get(name)
        if not axes:
            dims.append(None)
            continue
        axes = tuple(axes)
        # Divisibility guard (with compound-axis prefix fallback).
        size = x.shape[i]
        chosen = None
        for cut in range(len(axes), 0, -1):
            sub = axes[:cut]
            if size % prof.axis_size(sub) == 0:
                chosen = sub if len(sub) > 1 else sub[0]
                break
        dims.append(chosen)
    try:
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(prof.mesh, P(*dims))
        )
    except Exception:
        return x

"""Fault tolerance: checkpoint/restart training loop, straggler + heartbeat
machinery (DESIGN.md §6).

What is *executable* here (and tested on CPU):
  * ``FaultTolerantLoop`` — drives train steps; checkpoints every
    ``ckpt_every`` (async); on a step exception it restores the latest
    complete checkpoint, regenerates the batch from the stateless pipeline
    (data order is a function of step, nothing to rewind), and retries up
    to ``max_restarts`` times.  Tests inject failures and assert bit-exact
    convergence with the uninterrupted run.
  * ``HeartbeatRegistry`` — host liveness bookkeeping with deadlines; a
    missed heartbeat marks the host suspect and fires a callback (the
    hook a real deployment wires to its scheduler for pod replacement).
  * ``StragglerMonitor`` — per-step wall-time EWMA; steps slower than
    ``threshold ×`` the EWMA are recorded as straggler events (the signal
    used for hot-spare promotion at fleet scale — promotion itself needs a
    scheduler, so it ends at the callback boundary here, documented).

What is documented-only (needs >1 real host): coordinated restart across
hosts (jax.distributed barrier) and spare-pod promotion.  The code paths
end at explicit callbacks so a deployment can graft its control plane on.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from typing import Any, Callable, Optional

import jax

from ..ckpt import CheckpointManager

__all__ = [
    "CircuitBreaker",
    "HeartbeatRegistry",
    "OverloadSchedule",
    "StragglerMonitor",
    "FaultTolerantLoop",
]


class CircuitBreaker:
    """Classic closed → open → half-open breaker over a failure signal.

    ``record_failure`` counts consecutive failures; at ``failures_to_trip``
    the breaker *opens* and ``allow()`` answers False for ``cooldown_s``.
    After the cooldown, exactly one caller is admitted as a *half-open
    probe* (``allow()`` True once; concurrent callers keep getting False);
    a ``record_success`` closes the breaker, another failure re-opens it
    for a fresh cooldown.  ``ReplicaGroup`` keys one breaker per
    (replica, tenant) so a flooding tenant's rejections stop its own
    dispatches without blacklisting the replica for everyone else.

    Thread-safe; ``clock`` is injectable for deterministic tests.
    """

    CLOSED, OPEN, HALF_OPEN = "closed", "open", "half_open"

    def __init__(self, failures_to_trip: int = 3, cooldown_s: float = 1.0,
                 clock: Callable[[], float] = time.monotonic) -> None:
        if failures_to_trip < 1:
            raise ValueError("failures_to_trip must be >= 1")
        self.failures_to_trip = failures_to_trip
        self.cooldown_s = cooldown_s
        self._clock = clock
        self._lock = threading.Lock()
        self._state = self.CLOSED
        self._failures = 0
        self._opened_at = 0.0
        self._probing = False
        self.trips = 0  # total open transitions (monotonic)

    @property
    def state(self) -> str:
        with self._lock:
            return self._state

    def allow(self) -> bool:
        """May a call proceed right now?  In half-open, only the single
        probe slot answers True."""
        with self._lock:
            if self._state == self.CLOSED:
                return True
            if self._state == self.OPEN:
                if self._clock() - self._opened_at < self.cooldown_s:
                    return False
                self._state = self.HALF_OPEN
                self._probing = False
            # half-open: hand out the one probe slot.
            if self._probing:
                return False
            self._probing = True
            return True

    def record_success(self) -> None:
        with self._lock:
            self._state = self.CLOSED
            self._failures = 0
            self._probing = False

    def record_failure(self) -> None:
        with self._lock:
            if self._state == self.HALF_OPEN:
                # Failed probe: straight back to open, fresh cooldown.
                self._state = self.OPEN
                self._opened_at = self._clock()
                self._probing = False
                self.trips += 1
                return
            self._failures += 1
            if self._state == self.CLOSED and self._failures >= self.failures_to_trip:
                self._state = self.OPEN
                self._opened_at = self._clock()
                self.trips += 1

    def blocked(self) -> bool:
        """True while calls would be refused (open and still cooling, or
        half-open with the probe slot taken).  Read-only: unlike
        ``allow()``, never consumes the probe slot — but it does surface
        the open→half-open transition so 'every breaker blocked' can't
        deadlock against a probe nobody asks for."""
        with self._lock:
            if self._state == self.CLOSED:
                return False
            if self._state == self.OPEN:
                if self._clock() - self._opened_at < self.cooldown_s:
                    return True
                self._state = self.HALF_OPEN
                self._probing = False
            return self._probing

    def retry_in(self) -> float:
        """Seconds until the cooldown admits a probe (0 when not open)."""
        with self._lock:
            if self._state != self.OPEN:
                return 0.0
            return max(0.0, self.cooldown_s - (self._clock() - self._opened_at))


class OverloadSchedule:
    """Deterministic per-tenant load-factor windows for fault injection.

    ``add(tenant, start_s, duration_s, factor)`` arms a window (relative to
    the schedule's epoch) during which ``factor_at(tenant)`` reports the
    flood multiplier; outside every window it reports 1.0.  Drives the
    ``FaultInjector.flood`` probe: the bench's flooding tenant reads its
    current factor each round instead of wall-clock guessing.
    """

    def __init__(self, clock: Callable[[], float] = time.monotonic) -> None:
        self._clock = clock
        self._epoch = clock()
        self._windows: dict[str, list[tuple[float, float, float]]] = {}

    def add(self, tenant: str, start_s: float, duration_s: float,
            factor: float) -> "OverloadSchedule":
        self._windows.setdefault(tenant, []).append(
            (start_s, start_s + duration_s, factor))
        return self

    def factor_at(self, tenant: str, now: Optional[float] = None) -> float:
        t = (self._clock() if now is None else now) - self._epoch
        for start, end, factor in self._windows.get(tenant, ()):
            if start <= t < end:
                return factor
        return 1.0


class HeartbeatRegistry:
    """Host liveness with deadlines; no threads — callers pump ``check``."""

    def __init__(self, deadline_s: float = 60.0, on_dead: Optional[Callable[[str], None]] = None,
                 clock: Callable[[], float] = time.monotonic):
        self.deadline_s = deadline_s
        self.on_dead = on_dead
        self.clock = clock
        self.last_seen: dict[str, float] = {}
        self.dead: set[str] = set()

    def register(self, host: str) -> None:
        """Seed the deadline clock for *host* without counting a beat.

        ``check`` only scans ``last_seen``, so a host that registered but
        never beat was previously invisible — it could stay silent forever
        without ever being reported dead.  Registration starts the clock: a
        registered host that never beats is declared dead ``deadline_s``
        after this call.  Re-registering a known host is a no-op (it neither
        refreshes the deadline nor resurrects a dead host — only a real
        ``beat`` does that).
        """
        self.last_seen.setdefault(host, self.clock())

    def beat(self, host: str) -> None:
        self.last_seen[host] = self.clock()
        self.dead.discard(host)

    def alive(self, host: str) -> bool:
        """True while *host* is not marked dead — the routing-weight check.

        Works for hearts that beat locally and for beats that arrive over a
        wire (``core/transport.py`` credits a beat only when the remote
        worker answers a ping): the registry never cares how the beat
        traveled, only when it last landed."""
        return host not in self.dead

    def check(self) -> list[str]:
        now = self.clock()
        newly_dead = []
        for host, t in self.last_seen.items():
            if host not in self.dead and now - t > self.deadline_s:
                self.dead.add(host)
                newly_dead.append(host)
                if self.on_dead:
                    self.on_dead(host)
        return newly_dead


class StragglerMonitor:
    """EWMA step-time tracker; flags outlier steps."""

    def __init__(self, threshold: float = 2.0, alpha: float = 0.2,
                 on_straggler: Optional[Callable[[int, float, float], None]] = None):
        self.threshold = threshold
        self.alpha = alpha
        self.ewma: Optional[float] = None
        self.events: list[tuple[int, float, float]] = []
        self.on_straggler = on_straggler

    def record(self, step: int, dt: float) -> bool:
        is_straggler = False
        if self.ewma is not None and dt > self.threshold * self.ewma:
            self.events.append((step, dt, self.ewma))
            is_straggler = True
            if self.on_straggler:
                self.on_straggler(step, dt, self.ewma)
            # Do not fold outliers into the baseline.
        else:
            self.ewma = dt if self.ewma is None else (1 - self.alpha) * self.ewma + self.alpha * dt
        return is_straggler


@dataclasses.dataclass
class FaultTolerantLoop:
    """Checkpoint/restart driver around a (state, batch) -> (state, metrics)
    step function and a stateless batch source ``batch_fn(step)``."""

    step_fn: Callable[[Any, dict], tuple[Any, dict]]
    batch_fn: Callable[[int], dict]
    ckpt: CheckpointManager
    ckpt_every: int = 10
    max_restarts: int = 3
    straggler: Optional[StragglerMonitor] = None

    def run(self, state: Any, start_step: int, num_steps: int) -> tuple[Any, list[dict]]:
        history: list[dict] = []
        step = start_step
        restarts = 0
        abstract = jax.tree.map(lambda x: x, state)  # structure template
        while step < start_step + num_steps:
            try:
                t0 = time.perf_counter()
                batch = self.batch_fn(step)
                state, metrics = self.step_fn(state, batch)
                dt = time.perf_counter() - t0
                if self.straggler is not None:
                    self.straggler.record(step, dt)
                history.append({"step": step, **{k: float(v) for k, v in metrics.items()}})
                step += 1
                if step % self.ckpt_every == 0:
                    self.ckpt.save(step, state)
            except (FileNotFoundError, KeyboardInterrupt):
                raise
            except Exception:
                restarts += 1
                if restarts > self.max_restarts:
                    raise
                latest = self.ckpt.latest()
                if latest is None:
                    # No checkpoint yet: restart from the caller's state.
                    step = start_step
                    continue
                step, state = self.ckpt.restore(abstract, latest)
        self.ckpt.wait()
        return state, history

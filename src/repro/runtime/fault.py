"""Fault tolerance: checkpoint/restart training loop, straggler + heartbeat
machinery (DESIGN.md §6).

What is *executable* here (and tested on CPU):
  * ``FaultTolerantLoop`` — drives train steps; checkpoints every
    ``ckpt_every`` (async); on a step exception it restores the latest
    complete checkpoint, regenerates the batch from the stateless pipeline
    (data order is a function of step, nothing to rewind), and retries up
    to ``max_restarts`` times.  Tests inject failures and assert bit-exact
    convergence with the uninterrupted run.
  * ``HeartbeatRegistry`` — host liveness bookkeeping with deadlines; a
    missed heartbeat marks the host suspect and fires a callback (the
    hook a real deployment wires to its scheduler for pod replacement).
  * ``StragglerMonitor`` — per-step wall-time EWMA; steps slower than
    ``threshold ×`` the EWMA are recorded as straggler events (the signal
    used for hot-spare promotion at fleet scale — promotion itself needs a
    scheduler, so it ends at the callback boundary here, documented).

What is documented-only (needs >1 real host): coordinated restart across
hosts (jax.distributed barrier) and spare-pod promotion.  The code paths
end at explicit callbacks so a deployment can graft its control plane on.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Optional

import jax

from ..ckpt import CheckpointManager

__all__ = ["HeartbeatRegistry", "StragglerMonitor", "FaultTolerantLoop"]


class HeartbeatRegistry:
    """Host liveness with deadlines; no threads — callers pump ``check``."""

    def __init__(self, deadline_s: float = 60.0, on_dead: Optional[Callable[[str], None]] = None,
                 clock: Callable[[], float] = time.monotonic):
        self.deadline_s = deadline_s
        self.on_dead = on_dead
        self.clock = clock
        self.last_seen: dict[str, float] = {}
        self.dead: set[str] = set()

    def register(self, host: str) -> None:
        """Seed the deadline clock for *host* without counting a beat.

        ``check`` only scans ``last_seen``, so a host that registered but
        never beat was previously invisible — it could stay silent forever
        without ever being reported dead.  Registration starts the clock: a
        registered host that never beats is declared dead ``deadline_s``
        after this call.  Re-registering a known host is a no-op (it neither
        refreshes the deadline nor resurrects a dead host — only a real
        ``beat`` does that).
        """
        self.last_seen.setdefault(host, self.clock())

    def beat(self, host: str) -> None:
        self.last_seen[host] = self.clock()
        self.dead.discard(host)

    def alive(self, host: str) -> bool:
        """True while *host* is not marked dead — the routing-weight check.

        Works for hearts that beat locally and for beats that arrive over a
        wire (``core/transport.py`` credits a beat only when the remote
        worker answers a ping): the registry never cares how the beat
        traveled, only when it last landed."""
        return host not in self.dead

    def check(self) -> list[str]:
        now = self.clock()
        newly_dead = []
        for host, t in self.last_seen.items():
            if host not in self.dead and now - t > self.deadline_s:
                self.dead.add(host)
                newly_dead.append(host)
                if self.on_dead:
                    self.on_dead(host)
        return newly_dead


class StragglerMonitor:
    """EWMA step-time tracker; flags outlier steps."""

    def __init__(self, threshold: float = 2.0, alpha: float = 0.2,
                 on_straggler: Optional[Callable[[int, float, float], None]] = None):
        self.threshold = threshold
        self.alpha = alpha
        self.ewma: Optional[float] = None
        self.events: list[tuple[int, float, float]] = []
        self.on_straggler = on_straggler

    def record(self, step: int, dt: float) -> bool:
        is_straggler = False
        if self.ewma is not None and dt > self.threshold * self.ewma:
            self.events.append((step, dt, self.ewma))
            is_straggler = True
            if self.on_straggler:
                self.on_straggler(step, dt, self.ewma)
            # Do not fold outliers into the baseline.
        else:
            self.ewma = dt if self.ewma is None else (1 - self.alpha) * self.ewma + self.alpha * dt
        return is_straggler


@dataclasses.dataclass
class FaultTolerantLoop:
    """Checkpoint/restart driver around a (state, batch) -> (state, metrics)
    step function and a stateless batch source ``batch_fn(step)``."""

    step_fn: Callable[[Any, dict], tuple[Any, dict]]
    batch_fn: Callable[[int], dict]
    ckpt: CheckpointManager
    ckpt_every: int = 10
    max_restarts: int = 3
    straggler: Optional[StragglerMonitor] = None

    def run(self, state: Any, start_step: int, num_steps: int) -> tuple[Any, list[dict]]:
        history: list[dict] = []
        step = start_step
        restarts = 0
        abstract = jax.tree.map(lambda x: x, state)  # structure template
        while step < start_step + num_steps:
            try:
                t0 = time.perf_counter()
                batch = self.batch_fn(step)
                state, metrics = self.step_fn(state, batch)
                dt = time.perf_counter() - t0
                if self.straggler is not None:
                    self.straggler.record(step, dt)
                history.append({"step": step, **{k: float(v) for k, v in metrics.items()}})
                step += 1
                if step % self.ckpt_every == 0:
                    self.ckpt.save(step, state)
            except (FileNotFoundError, KeyboardInterrupt):
                raise
            except Exception:
                restarts += 1
                if restarts > self.max_restarts:
                    raise
                latest = self.ckpt.latest()
                if latest is None:
                    # No checkpoint yet: restart from the caller's state.
                    step = start_step
                    continue
                step, state = self.ckpt.restore(abstract, latest)
        self.ckpt.wait()
        return state, history

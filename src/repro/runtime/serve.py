"""Serving steps: batched prefill, one-token decode, and EP-SpMV requests.

``make_prefill_step`` / ``make_decode_step`` return the exact functions the
dry-run lowers for the prefill_32k / decode_32k / long_500k shapes — decode
is ONE new token against a cache of ``max_len`` (spec: ``decode_*`` lowers
``serve_step``, not ``train_step``).

``make_graph_serve_fn`` is the request path for EP-scheduled sparse compute:
every request carries a matrix + input vector; the plan comes from the async
``PartitionService`` (paper §4.2) so repeated matrices — the common serving
case — hit the fingerprint cache and never re-partition, and the jit'd
kernel is memoized per plan fingerprint.

Greedy sampling inline (argmax) keeps the served token path on-device; a
real frontend would swap in temperature sampling without touching the
lowered graph shape.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["make_prefill_step", "make_decode_step", "make_graph_serve_fn"]


def make_prefill_step(model, max_len: int):
    def prefill_step(params: Any, batch: dict):
        logits, cache = model.prefill(params, batch, max_len)
        next_token = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return next_token, cache

    return prefill_step


def make_decode_step(model):
    def decode_step(params: Any, cache: dict, tokens: jax.Array, pos: jax.Array):
        """tokens: (B, 1) int32; pos: scalar int32 write position."""
        logits, cache = model.decode_step(params, cache, {"tokens": tokens}, pos)
        next_token = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
        return next_token, cache

    return decode_step


def make_graph_serve_fn(
    service,
    k: int,
    pad: int = 128,
    mode: str = "software",
    interpret: bool = True,
    tenant: str = "default",
    priority: int = 0,
):
    """Service-backed EP-SpMV request handler: ``(request) -> (y, info)``.

    ``service`` is a ``core.PartitionService``.  Each request is
    ``(n_rows, n_cols, rows, cols, vals, x)``; the matrix structure is
    fingerprinted and looked up in the service's plan cache — a warm hit
    skips partitioning AND re-jitting.  The compiled kernel is memoized per
    (structure fingerprint, vals digest): the same sparsity with different
    matrix values re-binds the kernel instead of silently serving results
    from the first-seen values.  ``info`` reports the plan source
    ("full" | "incremental") and whether this request hit the plan cache
    (taken from the request's own ticket, so concurrent requests on other
    graphs can't skew it).

    ``tenant``/``priority`` are the handler's defaults for the service's
    multi-tenant scheduler (cache-budget accounting and queue ordering);
    per-request overrides go through ``serve(..., tenant=, priority=)`` —
    one handler can front many tenants.
    """
    import collections
    import hashlib

    from ..core.graph import affinity_graph_from_coo
    from ..kernels.ops import make_ep_spmv_fn  # runtime->kernels, lazy

    compiled: collections.OrderedDict[tuple, Any] = collections.OrderedDict()

    def serve(n_rows, n_cols, rows, cols, vals, x,
              tenant: str | None = None, priority: int | None = None):
        rows = np.asarray(rows, dtype=np.int64)
        cols = np.asarray(cols, dtype=np.int64)
        edges = affinity_graph_from_coo(n_rows, n_cols, rows, cols)
        req_tenant = tenant if tenant is not None else serve.tenant
        req_priority = priority if priority is not None else serve.priority
        ticket = service.submit(
            edges, k, pad=pad, coo=(n_rows, n_cols, rows, cols),
            tenant=req_tenant, priority=req_priority,
        )
        sp = ticket.result()
        vals = np.asarray(vals)
        vals_digest = hashlib.blake2b(
            np.ascontiguousarray(vals).tobytes(), digest_size=16
        ).hexdigest()
        key = (sp.fingerprint, vals_digest)
        fn = compiled.get(key)
        if fn is None:
            fn = make_ep_spmv_fn(sp.plan, vals, mode=mode, interpret=interpret)
            compiled[key] = fn
            while len(compiled) > 64:
                compiled.popitem(last=False)
        else:
            compiled.move_to_end(key)
        y = fn(jnp.asarray(x))
        info = {
            "fingerprint": sp.fingerprint,
            "cache_hit": ticket.cache_hit,
            "source": sp.source,
            "tenant": req_tenant,
            "partition_time_s": sp.compute_time_s,
        }
        return y, info

    serve.tenant = tenant
    serve.priority = priority
    return serve

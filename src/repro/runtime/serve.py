"""Serving steps: batched prefill and one-token decode.

``make_prefill_step`` / ``make_decode_step`` return the exact functions the
dry-run lowers for the prefill_32k / decode_32k / long_500k shapes — decode
is ONE new token against a cache of ``max_len`` (spec: ``decode_*`` lowers
``serve_step``, not ``train_step``).

Greedy sampling inline (argmax) keeps the served token path on-device; a
real frontend would swap in temperature sampling without touching the
lowered graph shape.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

__all__ = ["make_prefill_step", "make_decode_step"]


def make_prefill_step(model, max_len: int):
    def prefill_step(params: Any, batch: dict):
        logits, cache = model.prefill(params, batch, max_len)
        next_token = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return next_token, cache

    return prefill_step


def make_decode_step(model):
    def decode_step(params: Any, cache: dict, tokens: jax.Array, pos: jax.Array):
        """tokens: (B, 1) int32; pos: scalar int32 write position."""
        logits, cache = model.decode_step(params, cache, {"tokens": tokens}, pos)
        next_token = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
        return next_token, cache

    return decode_step

"""Serving steps: batched prefill, one-token decode, and EP-SpMV requests.

``make_prefill_step`` / ``make_decode_step`` return the exact functions the
dry-run lowers for the prefill_32k / decode_32k / long_500k shapes — decode
is ONE new token against a cache of ``max_len`` (spec: ``decode_*`` lowers
``serve_step``, not ``train_step``).

The EP-SpMV request path moved to ``repro.runtime.request``: a typed
``GraphRequest`` -> ``ServeResult`` surface on a ``GraphServer`` that owns
the bucketed compile cache and the micro-batcher.  ``make_graph_serve_fn``
survives here only as a deprecated shim over it (same positional-tuple call
shape, same ``(y, info_dict)`` return).

Greedy sampling inline (argmax) keeps the served token path on-device; a
real frontend would swap in temperature sampling without touching the
lowered graph shape.
"""
from __future__ import annotations

import warnings
from typing import Any

import jax
import jax.numpy as jnp

__all__ = ["make_prefill_step", "make_decode_step", "make_graph_serve_fn"]


def make_prefill_step(model, max_len: int):
    def prefill_step(params: Any, batch: dict):
        logits, cache = model.prefill(params, batch, max_len)
        next_token = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return next_token, cache

    return prefill_step


def make_decode_step(model):
    def decode_step(params: Any, cache: dict, tokens: jax.Array, pos: jax.Array):
        """tokens: (B, 1) int32; pos: scalar int32 write position."""
        logits, cache = model.decode_step(params, cache, {"tokens": tokens}, pos)
        next_token = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
        return next_token, cache

    return decode_step


def make_graph_serve_fn(
    service,
    k: int,
    pad: int = 128,
    mode: str = "software",
    interpret: bool = True,
    tenant: str = "default",
    priority: int = 0,
):
    """Deprecated shim: the positional-tuple serve handler, now a thin
    wrapper over :class:`repro.runtime.request.GraphServer`.

    Returns the old call shape — ``serve(n_rows, n_cols, rows, cols, vals,
    x, tenant=, priority=) -> (y, info_dict)`` — and still honors the
    legacy ``serve.tenant`` / ``serve.priority`` function attributes.  New
    code should construct a ``GraphServer`` and pass ``GraphRequest``s: it
    exposes the typed ``ServeResult``, the bucketed compile cache with
    ``stats()``, and the micro-batched ``submit`` lane, none of which this
    shim surfaces.  The returned handler's compile cache lives on an
    internal ``GraphServer`` (no batcher thread; every call is the
    synchronous lane).
    """
    warnings.warn(
        "make_graph_serve_fn is deprecated; use "
        "repro.runtime.request.GraphServer with GraphRequest",
        DeprecationWarning,
        stacklevel=2,
    )
    from .request import GraphRequest, GraphServer  # lazy: avoid import cycle

    server = GraphServer(
        service,
        k,
        pad=pad,
        mode=mode,
        interpret=interpret,
        tenant=tenant,
        priority=priority,
        start_batcher=False,
    )

    def serve(n_rows, n_cols, rows, cols, vals, x,
              tenant: str | None = None, priority: int | None = None):
        result = server.serve(
            GraphRequest(
                n_rows=n_rows, n_cols=n_cols, rows=rows, cols=cols,
                vals=vals, x=x,
                tenant=tenant if tenant is not None else serve.tenant,
                priority=priority if priority is not None else serve.priority,
            )
        )
        info = result.info.as_dict()
        return result.y, info

    serve.tenant = tenant
    serve.priority = priority
    serve.server = server  # escape hatch for stats()/close() on the shim
    return serve

"""GSPMD sharding rules for every architecture family.

Axis semantics (DESIGN.md §6):
  'pod'   — pure data parallelism across pods (params replicated over pods;
            exactly one gradient all-reduce per step crosses the slow
            inter-pod links);
  'data'  — within-pod data parallelism; in train mode also FSDP/ZeRO-3
            (params, grads, and Adam moments sharded over 'data');
  'model' — tensor parallelism: attention heads, FFN hidden, experts,
            vocab, Mamba inner dim.

Two modes:

  * ``train``: batch over ('pod','data'); weights ('data' x 'model')
    FSDP+TP.  EXCEPTION — MoE *expert* weights are compute-stationary
    (E over 'model', ffn dim over 'data', never gathered): a jamba period
    holds 38B expert params, and an FSDP all-gather of that is 4.8 GB/chip
    of transient — instead the expert einsum computes with the ffn dim
    sharded and all-reduces the (E, C, D) slab, which is ~30x smaller.
    This mirrors the paper's model: the experts are the shared data
    objects; pin them, move the (small) tasks.
  * ``serve``: no optimizer state, latency path.  Weights are wide-TP over
    ('model','data') (398B bf16 / 256 = 3.1 GB/chip, no per-layer weight
    gathers); attention stays heads-over-'model'; KV caches shard batch
    over 'data' and sequence over 'model' (the decode-shape memory
    bottleneck is cache bytes, not weights).

Every rule is divisibility-guarded: a dim is sharded over an axis (or a
prefix of a compound axis) only if evenly divisible, else replicated —
this lets kv=2..16 GQA configs share one rule set on a 16-wide 'model'
axis.  Stacked layer axes (scan leading dims) are never sharded.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

__all__ = [
    "ShardingRules",
    "make_sharding_rules",
    "param_specs",
    "batch_specs",
    "cache_spec_tree",
    "named",
    "tree_named",
]


def _axis_size(mesh: Mesh, name) -> int:
    if name is None:
        return 1
    if isinstance(name, (tuple, list)):
        return int(np.prod([_axis_size(mesh, n) for n in name]))
    return mesh.shape[name] if name in mesh.shape else 1


def _guard(mesh: Mesh, dim_size: int, name):
    """Shard dim over ``name`` only if evenly divisible (else a divisible
    prefix of a compound axis, else replicate)."""
    if name is None:
        return None
    if dim_size % _axis_size(mesh, name) == 0:
        return name if not (isinstance(name, (tuple, list)) and len(name) == 1) else name[0]
    if isinstance(name, (tuple, list)):
        for cut in range(len(name) - 1, 0, -1):
            sub = tuple(name[:cut])
            if dim_size % _axis_size(mesh, sub) == 0:
                return sub if len(sub) > 1 else sub[0]
    return None


@dataclasses.dataclass(frozen=True)
class ShardingRules:
    mesh: Mesh
    mode: str                    # 'train' | 'serve'
    dp: Any                      # batch axis name(s)
    tp: str = "model"            # attention/tensor axis
    fsdp: Optional[Any] = None   # train: ('data',)
    wide: Optional[Any] = None   # serve: ('model', 'data')
    expert_f: Optional[str] = "data"  # stationary-expert ffn-dim axis


def make_sharding_rules(mesh: Mesh, mode: str = "train") -> ShardingRules:
    axes = tuple(mesh.axis_names)
    has_pod = "pod" in axes
    if mode == "train":
        return ShardingRules(
            mesh=mesh, mode=mode,
            dp=("pod", "data") if has_pod else ("data",),
            fsdp=("data",),
        )
    if mode == "serve":
        return ShardingRules(
            mesh=mesh, mode=mode,
            dp=("pod", "data") if has_pod else ("data",),
            wide=("model", "data"),
        )
    raise ValueError(mode)


def named(rules: ShardingRules, spec: P) -> NamedSharding:
    return NamedSharding(rules.mesh, spec)


def tree_named(rules: ShardingRules, spec_tree: Any) -> Any:
    return jax.tree.map(
        lambda s: NamedSharding(rules.mesh, s),
        spec_tree,
        is_leaf=lambda x: isinstance(x, P),
    )


# ---------------------------------------------------------------------------
# Parameter specs (path-pattern matched over the abstract pytree)
# ---------------------------------------------------------------------------


def _n_stack_dims(path: tuple[str, ...]) -> int:
    """Leading scan-stack dims to leave unsharded, from the param path."""
    if not path:
        return 0
    head = path[0]
    if head in ("blocks", "encoder"):
        return 1
    if head == "periods":
        return 1 if len(path) > 1 and path[1] == "attn" else 2
    return 0


def _base_spec(
    path: tuple[str, ...],
    shape: tuple[int, ...],
    rules: ShardingRules,
    leaf_bytes: float = 0.0,
):
    """PartitionSpec entries for the trailing (non-stacked) dims of a leaf.

    ``leaf_bytes`` — total bytes of the WHOLE leaf (stack dims included),
    used for the size-conditional expert ffn-dim sharding.
    """
    mesh = rules.mesh
    name = path[-1]
    tp = rules.tp
    g = lambda size, ax: _guard(mesh, size, ax)
    in_moe = "moe" in path
    in_shared = "shared" in path
    in_mamba = "mamba" in path
    # The "second" weight axis: FSDP shards it in train, wide-TP in serve.
    col = rules.wide if rules.wide is not None else rules.fsdp
    row = rules.fsdp  # row sharding only in train (serve keeps rows whole)

    # --- MoE expert weights: compute-stationary, never gathered ----------
    # The ffn dim additionally shards over 'data' only when the E-sharded
    # per-chip slice is still large (>1 GB/leaf: jamba's 232 GB expert
    # leaves need it; qwen3-moe's would fit, but its f32 master + Adam
    # moments triple the bill, so the same threshold catches it).  Smaller
    # expert sets stay 1D-sharded — the expert einsum then has no
    # sharded-contraction all-reduce at all.
    if in_moe and not in_shared and len(shape) == 3 and name in ("w_gate", "w_up", "w_down"):
        e_ax = g(shape[0], tp)
        e_ways = _axis_size(mesh, e_ax) if e_ax else 1
        big = (leaf_bytes / e_ways) > 1e9
        f_ax = rules.expert_f if big else None
        if name == "w_down":
            return (e_ax, g(shape[1], f_ax), None)  # (E, F, D)
        return (e_ax, None, g(shape[2], f_ax))      # (E, D, F)

    if name == "embed":
        return (g(shape[0], tp), g(shape[1], row))
    if name == "lm_head":
        return (g(shape[0], row), g(shape[1], col if rules.wide else tp))
    if name in ("wq", "wk", "wv"):
        return (g(shape[0], row), g(shape[1], tp))
    if name == "wo":
        return (g(shape[0], tp), g(shape[1], row))
    if name == "router":
        return (g(shape[0], row), None)
    if name in ("w_gate", "w_up"):  # dense MLP / shared experts (D, F)
        return (g(shape[0], row), g(shape[1], col if rules.wide else tp))
    if name == "w_down":            # (F, D)
        return (g(shape[0], col if rules.wide else tp), g(shape[1], row))
    if name == "gate":              # shared-expert sigmoid gate (D, 1)
        return (g(shape[0], row), None)
    if in_mamba:
        wide_or_tp = col if rules.wide else tp
        if name == "in_proj":
            return (g(shape[0], row), g(shape[1], wide_or_tp))
        if name == "out_proj":
            return (g(shape[0], wide_or_tp), g(shape[1], row))
        if name == "conv_w":
            return (None, g(shape[1], wide_or_tp))
        if name in ("conv_b", "norm_w"):
            return (g(shape[0], wide_or_tp),)
        if name in ("dt_bias", "A_log", "D"):
            return (g(shape[0], tp),)
    # norms / q_norm / k_norm / final norms: replicated.
    return tuple(None for _ in shape)


def param_specs(abstract_params: Any, rules: ShardingRules) -> Any:
    """PartitionSpec pytree matching the (abstract) parameter pytree."""

    def one(path, leaf):
        names = tuple(p.key if hasattr(p, "key") else str(p) for p in path)
        n_stack = _n_stack_dims(names)
        trailing = leaf.shape[n_stack:]
        nbytes = float(np.prod(leaf.shape)) * jax.dtypes.canonicalize_dtype(leaf.dtype).itemsize
        base = _base_spec(names, trailing, rules, leaf_bytes=nbytes)
        return P(*((None,) * n_stack + tuple(base)))

    return jax.tree_util.tree_map_with_path(one, abstract_params)


# ---------------------------------------------------------------------------
# Batch / cache specs
# ---------------------------------------------------------------------------


def batch_specs(batch_shapes: dict, rules: ShardingRules) -> dict:
    """Specs for an input batch dict (tokens/labels/embeds/positions...)."""
    mesh = rules.mesh
    dp = rules.dp
    out = {}
    for k, v in batch_shapes.items():
        shape = v.shape if hasattr(v, "shape") else v
        if k == "positions3":  # (3, B, S)
            out[k] = P(None, _guard(mesh, shape[1], dp), None)
        elif len(shape) >= 1:
            rest = (None,) * (len(shape) - 1)
            out[k] = P(_guard(mesh, shape[0], dp), *rest)
        else:
            out[k] = P()
    return out


def cache_spec_tree(cache_shapes: dict, rules: ShardingRules) -> dict:
    """Specs for the decode cache pytree.

    KV caches (..., B, T, Hkv, Dh): batch over 'data', sequence over
    'model' (kv-head counts of 2..16 do not always divide the model axis;
    the sequence always does at 32k+, and seq-sharding spreads the cache
    *bytes* — the decode-shape memory bottleneck).  SSM states
    (..., B, H, P, N): batch over 'data', heads over 'model'.  Conv states:
    channels over 'model'.
    """
    mesh = rules.mesh
    dp, tp = rules.dp, rules.tp
    cache_b = dp  # batch rows of the cache spread over the dp axes
    out = {}
    for k, v in cache_shapes.items():
        shape = v.shape if hasattr(v, "shape") else v
        nd = len(shape)
        if k in ("k", "v", "cross_k", "cross_v"):
            lead = nd - 4  # stack dims before (B, T, Hkv, Dh)
            b, t = shape[lead], shape[lead + 1]
            spec = (None,) * lead + (_guard(mesh, b, cache_b), _guard(mesh, t, tp), None, None)
        elif k == "ssm":
            lead = nd - 4  # (B, H, P, N)
            b, h = shape[lead], shape[lead + 1]
            spec = (None,) * lead + (_guard(mesh, b, cache_b), _guard(mesh, h, tp), None, None)
        elif k == "conv":
            lead = nd - 3  # (B, W-1, C)
            b, c = shape[lead], shape[lead + 2]
            spec = (None,) * lead + (_guard(mesh, b, cache_b), None, _guard(mesh, c, tp))
        else:
            spec = (None,) * nd
        out[k] = P(*spec)
    return out

"""Distributed train step: gradient-accumulation scan + remat + AdamW.

``make_train_step`` builds the jit-able (params, opt_state, batch) -> step
function the launchers/dry-run lower:

  * the global batch is split into ``num_microbatches`` along the batch
    axis and scanned (sequential in HLO — activation memory is ONE
    microbatch's working set; with per-layer remat this is what makes
    1M-token steps fit a 16 GB chip);
  * gradients accumulate in ``accum_dtype`` (fp32 default; bf16 for the
    398B config where the extra 4 bytes/param does not fit);
  * optional int8 error-feedback compression hook before the optimizer
    (the explicit cross-pod variant lives in optim/compress.py).

The loss mean is over the *global* batch, so GSPMD emits exactly one
gradient all-reduce over ('pod','data') per step — crossing pods once
(DESIGN.md §6).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from ..optim import AdamWConfig, adamw_init, adamw_update

__all__ = ["TrainState", "make_train_step", "init_train_state", "split_microbatches"]


@dataclasses.dataclass(frozen=True)
class TrainState:
    params: Any
    opt_state: Any
    step: jax.Array

    def tree_flatten(self):  # pragma: no cover - registered below
        return (self.params, self.opt_state, self.step), None


jax.tree_util.register_pytree_node(
    TrainState,
    lambda s: ((s.params, s.opt_state, s.step), None),
    lambda _, c: TrainState(params=c[0], opt_state=c[1], step=c[2]),
)


def init_train_state(model, opt_cfg: AdamWConfig, key) -> TrainState:
    params = model.init(key)
    return TrainState(
        params=params,
        opt_state=adamw_init(opt_cfg, params),
        step=jnp.zeros((), jnp.int32),
    )


def split_microbatches(batch: dict, n: int) -> dict:
    """(B, ...) -> (n, B/n, ...); positions3 (3, B, S) -> (n, 3, B/n, S)."""

    def split(k, x):
        if k == "positions3":
            b = x.shape[1]
            assert b % n == 0, (k, x.shape, n)
            y = x.reshape(x.shape[0], n, b // n, *x.shape[2:])
            return jnp.moveaxis(y, 1, 0)
        b = x.shape[0]
        assert b % n == 0, (k, x.shape, n)
        return x.reshape(n, b // n, *x.shape[1:])

    return {k: split(k, v) for k, v in batch.items()}


def make_train_step(
    model,
    opt_cfg: AdamWConfig,
    num_microbatches: int = 1,
    accum_dtype: Optional[Any] = jnp.float32,
    grad_transform: Optional[Callable[[Any], Any]] = None,
):
    """Returns train_step(state, batch) -> (state, metrics)."""

    def loss_fn(params, mb):
        loss, metrics = model.loss_fn(params, mb)
        return loss, metrics

    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def train_step(state: TrainState, batch: dict):
        params = state.params

        if num_microbatches == 1:
            (loss, metrics), grads = grad_fn(params, batch)
            if accum_dtype is not None:
                grads = jax.tree.map(lambda g: g.astype(accum_dtype), grads)
        else:
            mbs = split_microbatches(batch, num_microbatches)

            def body(carry, mb):
                gsum, lsum = carry
                (loss, _), g = grad_fn(params, mb)
                gsum = jax.tree.map(
                    lambda a, b: a + (b.astype(a.dtype) if accum_dtype else b), gsum, g
                )
                return (gsum, lsum + loss), None

            gzero = jax.tree.map(
                lambda p: jnp.zeros(p.shape, accum_dtype or p.dtype), params
            )
            (gsum, lsum), _ = jax.lax.scan(
                body, (gzero, jnp.zeros((), jnp.float32)), mbs
            )
            inv = 1.0 / num_microbatches
            grads = jax.tree.map(lambda g: g * jnp.asarray(inv, g.dtype), gsum)
            loss = lsum * inv
            metrics = {}

        if grad_transform is not None:
            grads = grad_transform(grads)

        new_params, new_opt, stats = adamw_update(opt_cfg, grads, state.opt_state, params)
        new_state = TrainState(
            params=new_params, opt_state=new_opt, step=state.step + 1
        )
        out = {"loss": loss, **stats}
        if isinstance(metrics, dict):
            out.update({k: v for k, v in metrics.items() if k != "loss"})
        return new_state, out

    return train_step

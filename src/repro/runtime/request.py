"""Typed request API + bucketed-compilation serve path for EP-SpMV.

The paper's premise — group tasks so irregular sharing becomes cache hits —
already runs twice in this repo: once inside the kernel (cluster-local
x tiles) and once in the plan cache (repeated graphs never re-partition).
This module applies it a third time, to *compiled kernels*: thousands of
small serving graphs collapse onto a handful of padded shape buckets, and
every request in a bucket reuses one compiled executable instead of paying
a fresh trace/compile (ROADMAP open item 3; the "Stacked/scan-layers"
compile-once idiom, and GraphCage's bucket-by-structure segmenting).

Layering: this is the *request layer*.  It owns

* the typed surface — :class:`GraphRequest` in, :class:`ServeResult`
  (y + :class:`ServeInfo`) out;
* plan-kind resolution (:func:`resolve_plan`) — ``kernels.ops`` takes only
  host-side ``PackPlan``s now; unwrapping scheduler handles (ServicePlan /
  PlanTicket and their timeout semantics) happens here;
* the kernel compile cache (:class:`CompileCache`) — bounded, with
  (size, recency) eviction and hit/miss/evict counters surfaced through
  ``GraphServer.stats()`` and ``ServiceMetrics.compile_cache``;
* micro-batching — ``GraphServer.submit`` coalesces same-bucket requests
  within a ``max_batch`` / ``max_wait_ms`` window through one stacked
  kernel launch, de-padding each request's y on the way out.

``GraphServer.serve`` is the synchronous lane: it runs a batch-of-1
through the same bucket executable (no waiting, still no per-shape
compile).  ``GraphServer.submit`` is the queued lane that trades up to
``max_wait_ms`` of latency for batched launches.
"""
from __future__ import annotations

import dataclasses
import hashlib
import math
import threading
import time
from collections import OrderedDict, deque
from typing import Any, Callable, Optional

import jax.numpy as jnp
import numpy as np

from ..core.partition_service import (
    AdmissionRejectedError,
    PartitionService,
    PlanTicket,
    ServicePlan,
    graph_fingerprint,
)
from ..core.reorder import PackPlan
from ..kernels.ops import (
    BucketSpec,
    make_bucketed_spmv_fn,
    make_ep_spmv_fn,
    pad_plan_operands,
)

__all__ = [
    "BucketKey",
    "BucketPolicy",
    "CompileCache",
    "GraphRequest",
    "GraphServer",
    "ServeInfo",
    "ServeResult",
    "resolve_plan",
]


# ---------------------------------------------------------------------------
# Plan-kind resolution (moved here from kernels.ops)
# ---------------------------------------------------------------------------


def resolve_plan(plan, timeout: float | None = None) -> PackPlan:
    """Unwrap any plan-shaped handle to the host-side ``PackPlan``.

    Accepts a ``PackPlan`` (returned as-is), a ``ServicePlan`` (its packed
    plan; raises ``ValueError`` when the service ran without COO metadata
    and has none), or a ``PlanTicket`` (blocks up to ``timeout`` for the
    worker, then recurses on the resulting ServicePlan).  This is the only
    place scheduler handles are unwrapped — the kernel layer below takes
    PackPlans only.
    """
    if isinstance(plan, PackPlan):
        return plan
    if isinstance(plan, ServicePlan):
        if plan.plan is None:
            raise ValueError(
                "ServicePlan has no PackPlan (submitted without coo=); "
                "cannot serve SpMV from it"
            )
        return plan.plan
    if isinstance(plan, PlanTicket):
        return resolve_plan(plan.result(timeout), timeout)
    raise TypeError(
        f"expected PackPlan, ServicePlan, or PlanTicket; got {type(plan).__name__}"
    )


# ---------------------------------------------------------------------------
# Typed request / result surface
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class GraphRequest:
    """One EP-SpMV serving request: matrix structure + values + input.

    Replaces the positional 6-tuple ``(n_rows, n_cols, rows, cols, vals,
    x)``.  ``tenant``/``priority`` feed the partition service's multi-tenant
    scheduler (cache budgets, queue order); ``timeout`` bounds the wait for
    a cold plan.  Arrays are normalized on construction (index arrays to
    int64, ``vals``/``x`` to float32 — the kernels' serving dtype).
    """

    n_rows: int
    n_cols: int
    rows: np.ndarray
    cols: np.ndarray
    vals: np.ndarray
    x: np.ndarray
    tenant: Optional[str] = None  # None -> server default
    priority: Optional[int] = None
    timeout: Optional[float] = None

    def __post_init__(self) -> None:
        self.rows = np.asarray(self.rows, dtype=np.int64)
        self.cols = np.asarray(self.cols, dtype=np.int64)
        self.vals = np.asarray(self.vals, dtype=np.float32)
        self.x = np.asarray(self.x, dtype=np.float32)
        if self.x.shape != (self.n_cols,):
            raise ValueError(f"x must have shape ({self.n_cols},), got {self.x.shape}")
        if not (self.rows.shape == self.cols.shape == self.vals.shape):
            raise ValueError("rows/cols/vals must have identical shapes")

    def vals_digest(self) -> str:
        return hashlib.blake2b(
            np.ascontiguousarray(self.vals).tobytes(), digest_size=16
        ).hexdigest()


@dataclasses.dataclass(frozen=True)
class ServeInfo:
    """Per-request serving metadata (the typed successor of the info dict)."""

    fingerprint: str
    cache_hit: bool  # plan cache (partition service)
    source: str  # "full" | "incremental"
    tenant: str
    partition_time_s: float
    bucket: Optional[str] = None  # bucket label, None = dedicated compile
    kernel_cache_hit: bool = False  # compiled-kernel cache
    batch_size: int = 1  # requests sharing this launch
    # True when a ReplicaGroup served a cached plan because no replica was
    # healthy (graceful degradation): the answer is correct for the plan it
    # was computed from, but optimization against the *current* request may
    # be pending.  Always False for a plain PartitionService.
    stale: bool = False
    # True when the brownout governor answered from cache because the
    # service was shedding load (admission rejections in the recent
    # window): the plan is a genuine warm hit, but no new partitioning
    # work was admitted for this request.
    degraded: bool = False

    def as_dict(self) -> dict:
        """Legacy dict view — superset of the old ``(y, info)`` keys."""
        return dataclasses.asdict(self)


@dataclasses.dataclass(frozen=True)
class ServeResult:
    y: Any  # jax.Array, length n_rows (de-padded)
    info: ServeInfo


# ---------------------------------------------------------------------------
# Shape buckets
# ---------------------------------------------------------------------------


def _ceil_mult(v: int, m: int) -> int:
    return ((v + m - 1) // m) * m if m > 0 else v


@dataclasses.dataclass(frozen=True)
class BucketKey:
    """One compile bucket: geometric ceilings of (rows, cols, nnz) + (k, mode).

    Two plans map to the same key exactly when they can share a compiled
    kernel; ``label`` is the human-readable cache/metrics key.
    """

    n_rows: int
    n_cols: int
    nnz: int
    k: int
    mode: str

    @property
    def label(self) -> str:
        return f"r{self.n_rows}c{self.n_cols}e{self.nnz}k{self.k}-{self.mode}"

    def spec(self, batch: int, pad: int = 128, slack: float = 0.30) -> BucketSpec:
        """Concrete padded-shape contract for this bucket.

        Per-cluster tile ceilings assume the partitioner's balance: each
        cluster holds at most ``ceil(nnz / k) * (1 + slack)`` tasks
        (``slack`` covers the balance eps + pad rounding), and a cluster
        can never touch more unique x/y entries than it has tasks — nor
        more than exist.  The serve path still double-checks
        ``spec.fits(plan)`` per request and falls back to a dedicated
        compile, so a pathologically skewed plan degrades to the old cost
        instead of miscomputing.
        """
        e_cap = int(math.ceil(self.nnz / max(self.k, 1) * (1.0 + slack)))
        e_max = _ceil_mult(max(e_cap, 1), pad)
        x_max = min(_ceil_mult(self.n_cols, pad), e_max)
        y_max = min(_ceil_mult(self.n_rows, pad), e_max)
        return BucketSpec(
            k=self.k,
            n_rows=self.n_rows,
            n_cols=self.n_cols,
            e_max=e_max,
            x_max=x_max,
            y_max=y_max,
            batch=batch,
            mode=self.mode,
        )


@dataclasses.dataclass(frozen=True)
class BucketPolicy:
    """Geometric bucket-ceiling policy: dims round up to ``floor * growth^i``.

    A request's (n_rows, n_cols, nnz) are each rounded up to the next
    geometric ceiling; requests beyond any ``max_*`` cap get no bucket
    (``bucket_for`` returns None) and are served through a dedicated
    per-structure compile — bounded shape blow-up, unbounded request sizes.
    With ``growth=2.0`` the padding waste is at most 2x per dim, and the
    number of distinct buckets grows logarithmically in the served size
    range — that log-sized set is what makes compile caching effective.
    """

    growth: float = 2.0
    min_rows: int = 256
    min_cols: int = 256
    min_nnz: int = 1024
    max_rows: int = 65536
    max_cols: int = 65536
    max_nnz: int = 1 << 20
    balance_slack: float = 0.30

    def _ceil_geom(self, v: int, floor: int, cap: int) -> Optional[int]:
        if v > cap:
            return None
        c = floor
        while c < v:
            c = int(math.ceil(c * self.growth))
        return min(c, cap)

    def bucket_for(self, padding, mode: str) -> Optional[BucketKey]:
        """Map a plan's ``PlanPadding`` to its bucket, or None if oversized."""
        r = self._ceil_geom(padding.n_rows, self.min_rows, self.max_rows)
        c = self._ceil_geom(padding.n_cols, self.min_cols, self.max_cols)
        e = self._ceil_geom(padding.nnz, self.min_nnz, self.max_nnz)
        if r is None or c is None or e is None:
            return None
        return BucketKey(n_rows=r, n_cols=c, nnz=e, k=padding.k, mode=mode)


# ---------------------------------------------------------------------------
# Compile cache: bounded, (size, recency) eviction, build-slot dedup
# ---------------------------------------------------------------------------


class CompileCache:
    """Bounded cache of compiled kernels with (size, recency) eviction.

    The old serve memo was a plain LRU over 64 entries that ignored
    compiled-kernel cost entirely — a giant bucket executable and a tiny
    dedicated one aged identically.  Here each entry carries a size (padded
    operand element count, a faithful proxy for both executable size and
    the retrace cost it shields); when over ``capacity`` the evictor scans
    the *oldest quarter* of entries and drops the largest one — strict LRU
    order among victims, size as the tiebreak within the old cohort, so a
    hot big bucket is never sacrificed for a cold small one.

    ``get_or_build`` is concurrency-safe per key: the first caller installs
    a build slot and compiles outside the lock; latecomers for the same key
    wait on the slot instead of compiling twice (their hits count as hits —
    the compile was shared).
    """

    def __init__(self, capacity: int = 32) -> None:
        if capacity < 1:
            raise ValueError("capacity must be >= 1")
        self.capacity = capacity
        self._entries: OrderedDict[Any, tuple[Any, int]] = OrderedDict()
        self._building: dict[Any, threading.Event] = {}
        self._lock = threading.Lock()
        self.hits = 0
        self.misses = 0
        self.evictions = 0
        self._per_key_hits: dict[Any, int] = {}

    def get_or_build(self, key, size: int, builder: Callable[[], Any]):
        """Return the cached callable for ``key``, building it at most once."""
        while True:
            with self._lock:
                hit = self._entries.get(key)
                if hit is not None:
                    self._entries.move_to_end(key)
                    self.hits += 1
                    self._per_key_hits[key] = self._per_key_hits.get(key, 0) + 1
                    return hit[0]
                slot = self._building.get(key)
                if slot is None:
                    slot = threading.Event()
                    self._building[key] = slot
                    self.misses += 1
                    break
            slot.wait()  # another thread is compiling this key
        try:
            fn = builder()
        except BaseException:
            with self._lock:
                del self._building[key]
            slot.set()
            raise
        with self._lock:
            self._entries[key] = (fn, int(size))
            del self._building[key]
            self._evict_locked()
        slot.set()
        return fn

    def _evict_locked(self) -> None:
        while len(self._entries) > self.capacity:
            keys = list(self._entries.keys())
            cohort = keys[: max(1, math.ceil(len(keys) / 4))]  # oldest quarter
            victim = max(cohort, key=lambda k: self._entries[k][1])
            del self._entries[victim]
            self._per_key_hits.pop(victim, None)
            self.evictions += 1

    def __len__(self) -> int:
        with self._lock:
            return len(self._entries)

    def __contains__(self, key) -> bool:
        with self._lock:
            return key in self._entries

    def hits_for(self, key) -> int:
        with self._lock:
            return self._per_key_hits.get(key, 0)

    def stats(self) -> dict:
        with self._lock:
            return {
                "capacity": self.capacity,
                "entries": len(self._entries),
                "hits": self.hits,
                "misses": self.misses,
                "evictions": self.evictions,
                "size_elems": sum(s for _, s in self._entries.values()),
            }


# ---------------------------------------------------------------------------
# GraphServer
# ---------------------------------------------------------------------------


class _Pending:
    """One queued request inside the micro-batcher."""

    __slots__ = ("request", "sp", "ticket_hit", "stale", "degraded",
                 "operands", "t_enqueue", "event", "result", "error")

    def __init__(self, request, sp, ticket_hit, operands, t_enqueue,
                 stale: bool = False, degraded: bool = False) -> None:
        self.request = request
        self.sp = sp
        self.ticket_hit = ticket_hit
        self.stale = stale
        self.degraded = degraded
        self.operands = operands
        self.t_enqueue = t_enqueue
        self.event = threading.Event()
        self.result: Optional[ServeResult] = None
        self.error: Optional[BaseException] = None

    def wait(self, timeout: float | None = None) -> ServeResult:
        if not self.event.wait(timeout):
            raise TimeoutError("batched serve did not complete in time")
        if self.error is not None:
            raise self.error
        return self.result  # type: ignore[return-value]


class GraphServer:
    """EP-SpMV request server: plan service + bucketed compiles + batching.

    Owns the compiled-kernel cache (what ``make_graph_serve_fn``'s module
    memo used to be) and the default ``tenant``/``priority`` (what the
    ``serve.tenant`` function-attribute hack used to be).  Two lanes:

    * :meth:`serve` — synchronous.  The request's plan picks a shape
      bucket; the batch-of-1 runs through the bucket's shared executable
      immediately (no coalescing delay).  Oversized or skewed plans fall
      back to a dedicated per-structure compile.
    * :meth:`submit` — queued.  Same-bucket requests arriving within
      ``max_wait_ms`` (or until ``max_batch`` fill) run as one stacked
      kernel launch; each caller's handle de-pads its own row.  Plan
      resolution still happens on the submitting thread, so the batcher
      never blocks on a cold partition.

    ``bucketing=None`` disables buckets entirely — every structure gets a
    dedicated compile through the same bounded cache (the measured
    baseline in ``benchmarks/svc_batched.py``).

    ``service`` is any object with the ``PartitionService`` submit surface —
    a single service or a ``core.replica.ReplicaGroup`` (replication with
    failover/hedging behind the same API; its degraded serves surface as
    ``ServeInfo.stale``).
    """

    def __init__(
        self,
        service: "PartitionService | Any",
        k: int,
        pad: int = 128,
        mode: str = "software",
        interpret: bool = True,
        tenant: str = "default",
        priority: int = 0,
        bucketing: Optional[BucketPolicy] = BucketPolicy(),
        max_batch: int = 8,
        max_wait_ms: float = 2.0,
        compile_cache_entries: int = 32,
        start_batcher: bool = True,
        brownout_window_s: float = 1.0,
        brownout_hedge_off: int = 3,
        brownout_stale_only: int = 6,
        brownout_priority_floor: int = 1,
    ) -> None:
        self.service = service
        self.k = k
        self.pad = pad
        self.mode = mode
        self.interpret = interpret
        self.tenant = tenant
        self.priority = priority
        self.bucketing = bucketing
        self.max_batch = max(1, int(max_batch))
        self.max_wait_ms = float(max_wait_ms)
        self.compile_cache = CompileCache(capacity=compile_cache_entries)
        # Padded host operands per (structure, values, bucket): rebuilding
        # them is cheap but not free, and repeated matrices are the common
        # serving case.  Plain LRU — entries are small numpy views.
        self._operands: OrderedDict[tuple, tuple] = OrderedDict()
        self._operands_cap = 256
        self._lock = threading.Lock()
        self._batch_hist: dict[int, int] = {}
        # Brownout governor state: admission rejections observed in the
        # trailing window drive a degradation ladder — level 1 turns
        # hedging off (extra lanes amplify overload), level 2 answers
        # low-priority tenants from cache only; recovery is automatic as
        # rejections age out of the window.
        self.brownout_window_s = float(brownout_window_s)
        self.brownout_hedge_off = int(brownout_hedge_off)
        self.brownout_stale_only = int(brownout_stale_only)
        self.brownout_priority_floor = int(brownout_priority_floor)
        self._rejections: deque[float] = deque(maxlen=1024)
        self._hedge_saved: Optional[bool] = None
        self._degraded_serves = 0
        self._brownout_rejects = 0
        # Micro-batcher state: per-bucket-label deques of _Pending.
        self._queues: dict[Optional[str], list[_Pending]] = {}
        self._specs: dict[str, BucketSpec] = {}
        self._cv = threading.Condition()
        self._closed = False
        self._batcher: Optional[threading.Thread] = None
        if start_batcher:
            self._batcher = threading.Thread(
                target=self._batch_loop, name="graph-server-batcher", daemon=True
            )
            self._batcher.start()

    # -- brownout governor --------------------------------------------------

    def brownout_level(self) -> int:
        """0 = normal, 1 = hedging disabled, 2 = low-priority tenants are
        cache-only.  Derived from admission rejections in the trailing
        ``brownout_window_s`` — recovery is automatic once they age out."""
        now = time.perf_counter()
        with self._lock:
            while self._rejections and now - self._rejections[0] > self.brownout_window_s:
                self._rejections.popleft()
            n = len(self._rejections)
        if n >= self.brownout_stale_only:
            return 2
        if n >= self.brownout_hedge_off:
            return 1
        return 0

    def _note_rejection(self) -> None:
        with self._lock:
            self._rejections.append(time.perf_counter())

    def _apply_brownout(self, level: int) -> None:
        """First rung of the ladder: hedging multiplies submitted load, so
        it is the first thing to go under pressure (and the first thing
        restored on recovery).  No-op for services without a hedge knob."""
        svc = self.service
        if not hasattr(svc, "hedge"):
            return
        with self._lock:
            if level >= 1 and self._hedge_saved is None and svc.hedge:
                self._hedge_saved = svc.hedge
                svc.hedge = False
            elif level == 0 and self._hedge_saved is not None:
                svc.hedge = self._hedge_saved
                self._hedge_saved = None

    def _fingerprint(self, req: GraphRequest, edges) -> str:
        """The fingerprint ``service.submit`` would assign this request —
        computed here so the brownout path can probe caches without
        submitting any work."""
        opts = getattr(self.service, "default_opts", None)
        return graph_fingerprint(edges, self.k, self.pad, opts, "ep", 0,
                                 (req.n_rows, req.n_cols))

    # -- plan + bucket resolution ------------------------------------------

    def _plan_for(self, req: GraphRequest) -> tuple[ServicePlan, bool, bool, bool]:
        from ..core.graph import affinity_graph_from_coo

        edges = affinity_graph_from_coo(req.n_rows, req.n_cols, req.rows, req.cols)
        tenant = req.tenant if req.tenant is not None else self.tenant
        priority = req.priority if req.priority is not None else self.priority
        level = self.brownout_level()
        self._apply_brownout(level)
        fp: Optional[str] = None
        lookup = getattr(self.service, "lookup", None)
        if (level >= 2 and priority < self.brownout_priority_floor
                and lookup is not None):
            # Stale-only rung: answer low-priority tenants from cache
            # without admitting new work.  A cache miss rejects outright —
            # but is NOT counted as fresh rejection pressure, so brownout
            # cannot sustain itself once the real overload has passed.
            fp = self._fingerprint(req, edges)
            cached = lookup(fp, tenant)
            if cached is not None:
                with self._lock:
                    self._degraded_serves += 1
                return cached, True, False, True
            with self._lock:
                self._brownout_rejects += 1
            raise AdmissionRejectedError(
                f"brownout: tenant {tenant!r} is cache-only under overload "
                "and this graph is not cached",
                retry_after_s=self.brownout_window_s, tenant=tenant,
                reason="brownout")
        try:
            ticket = self.service.submit(
                edges,
                self.k,
                pad=self.pad,
                coo=(req.n_rows, req.n_cols, req.rows, req.cols),
                tenant=tenant,
                priority=priority,
                # End-to-end deadline: a ReplicaGroup stops failover retries
                # when it expires (a single PartitionService sheds queued
                # work past it — the result() wait below is the final bound).
                timeout=req.timeout,
            )
            sp = ticket.result(req.timeout)
        except AdmissionRejectedError:
            # The service shed this request.  Note the pressure (it drives
            # the ladder), then degrade to a pure cache answer if we can.
            self._note_rejection()
            self._apply_brownout(self.brownout_level())
            if lookup is not None:
                cached = lookup(fp or self._fingerprint(req, edges), tenant)
                if cached is not None:
                    with self._lock:
                        self._degraded_serves += 1
                    return cached, True, False, True
            raise
        # ``stale`` exists on ReplicaGroup tickets only (degraded serve).
        return sp, ticket.cache_hit, getattr(ticket, "stale", False), False

    def _bucket_for(self, sp: ServicePlan) -> Optional[tuple[str, BucketSpec]]:
        if self.bucketing is None or sp.plan is None or sp.padding is None:
            return None
        key = self.bucketing.bucket_for(sp.padding, self.mode)
        if key is None:
            return None
        spec = self._specs.get(key.label)
        if spec is None:
            spec = key.spec(
                self.max_batch, pad=self.pad, slack=self.bucketing.balance_slack
            )
            self._specs[key.label] = spec
        if not spec.fits(sp.plan):  # skewed plan: ceilings missed — degrade
            return None
        return key.label, spec

    def _bucket_operands(self, req: GraphRequest, sp: ServicePlan, label: str,
                         spec: BucketSpec) -> tuple:
        okey = (sp.fingerprint, req.vals_digest(), label)
        with self._lock:
            ops = self._operands.get(okey)
            if ops is not None:
                self._operands.move_to_end(okey)
                return ops
        ops = pad_plan_operands(sp.plan, req.vals, spec)
        with self._lock:
            self._operands[okey] = ops
            while len(self._operands) > self._operands_cap:
                self._operands.popitem(last=False)
        return ops

    def _bucket_fn(self, label: str, spec: BucketSpec):
        return self.compile_cache.get_or_build(
            ("bucket", label),
            spec.operand_elems(),
            lambda: make_bucketed_spmv_fn(spec, interpret=self.interpret),
        )

    def _dedicated_fn(self, req: GraphRequest, sp: ServicePlan):
        plan = sp.plan
        size = plan.k * (3 * plan.e_max + plan.x_max + plan.y_max) + plan.n_cols
        return self.compile_cache.get_or_build(
            ("dedicated", sp.fingerprint, req.vals_digest()),
            size,
            lambda: make_ep_spmv_fn(plan, req.vals, mode=self.mode,
                                    interpret=self.interpret),
        )

    def _record_batch(self, size: int) -> None:
        with self._lock:
            self._batch_hist[size] = self._batch_hist.get(size, 0) + 1

    # -- batched execution --------------------------------------------------

    def _run_bucket_batch(self, label: str, spec: BucketSpec,
                          group: list[_Pending]) -> None:
        """Execute up to ``spec.batch`` same-bucket requests as one launch."""
        misses_before = self.compile_cache.misses
        fn = self._bucket_fn(label, spec)
        kernel_hit = self.compile_cache.misses == misses_before
        b = spec.batch
        vp = np.zeros((b, spec.k, spec.e_max), dtype=np.float32)
        xl = np.zeros((b, spec.k, spec.e_max), dtype=np.int32)
        yl = np.zeros((b, spec.k, spec.e_max), dtype=np.int32)
        xg = np.zeros((b, spec.k, spec.x_max), dtype=np.int32)
        # Empty batch slots scatter to the sentinel row, like plan tails.
        yg = np.full((b, spec.k, spec.y_max), spec.n_rows, dtype=np.int32)
        xs = np.zeros((b, spec.n_cols), dtype=np.float32)
        for i, p in enumerate(group):
            vp[i], xl[i], yl[i], xg[i], yg[i] = p.operands
            xs[i, : p.request.n_cols] = p.request.x
        ys = np.asarray(
            fn(jnp.asarray(vp), jnp.asarray(xl), jnp.asarray(yl),
               jnp.asarray(xg), jnp.asarray(yg), jnp.asarray(xs))
        )
        self._record_batch(len(group))
        for i, p in enumerate(group):
            info = ServeInfo(
                fingerprint=p.sp.fingerprint,
                cache_hit=p.ticket_hit,
                source=p.sp.source,
                tenant=(p.request.tenant if p.request.tenant is not None
                        else self.tenant),
                partition_time_s=p.sp.compute_time_s,
                bucket=label,
                kernel_cache_hit=kernel_hit,
                batch_size=len(group),
                stale=p.stale,
                degraded=p.degraded,
            )
            p.result = ServeResult(y=jnp.asarray(ys[i, : p.request.n_rows]), info=info)
            p.event.set()

    def _run_dedicated(self, p: _Pending) -> None:
        misses_before = self.compile_cache.misses
        fn = self._dedicated_fn(p.request, p.sp)
        kernel_hit = self.compile_cache.misses == misses_before
        y = fn(jnp.asarray(p.request.x))
        self._record_batch(1)
        info = ServeInfo(
            fingerprint=p.sp.fingerprint,
            cache_hit=p.ticket_hit,
            source=p.sp.source,
            tenant=p.request.tenant if p.request.tenant is not None else self.tenant,
            partition_time_s=p.sp.compute_time_s,
            bucket=None,
            kernel_cache_hit=kernel_hit,
            batch_size=1,
            stale=p.stale,
            degraded=p.degraded,
        )
        p.result = ServeResult(y=y, info=info)
        p.event.set()

    def _batch_loop(self) -> None:
        wait_s = self.max_wait_ms / 1000.0
        while True:
            todo: list[tuple[Optional[str], list[_Pending]]] = []
            with self._cv:
                while True:
                    if self._closed and not any(self._queues.values()):
                        return
                    now = time.perf_counter()
                    deadline = None
                    for label, q in self._queues.items():
                        if not q:
                            continue
                        if (
                            label is None
                            or len(q) >= self.max_batch
                            or self._closed
                            or now - q[0].t_enqueue >= wait_s
                        ):
                            take = q if label is None else q[: self.max_batch]
                            todo.append((label, list(take)))
                            del q[: len(take)]
                        else:
                            d = q[0].t_enqueue + wait_s
                            deadline = d if deadline is None else min(deadline, d)
                    if todo:
                        break
                    self._cv.wait(
                        timeout=None if deadline is None else max(deadline - now, 0.0)
                    )
            for label, group in todo:
                try:
                    if label is None:
                        for p in group:
                            self._run_dedicated(p)
                    else:
                        self._run_bucket_batch(label, self._specs[label], group)
                except BaseException as e:  # resolve waiters, keep serving
                    for p in group:
                        if not p.event.is_set():
                            p.error = e
                            p.event.set()

    # -- public surface -----------------------------------------------------

    def serve(self, request: GraphRequest) -> ServeResult:
        """Synchronous lane: resolve plan, run a batch-of-1 immediately."""
        sp, ticket_hit, stale, degraded = self._plan_for(request)
        bucket = self._bucket_for(sp)
        if bucket is None:
            p = _Pending(request, sp, ticket_hit, None, time.perf_counter(),
                         stale=stale, degraded=degraded)
            self._run_dedicated(p)
            return p.wait()
        label, spec = bucket
        ops = self._bucket_operands(request, sp, label, spec)
        p = _Pending(request, sp, ticket_hit, ops, time.perf_counter(),
                     stale=stale, degraded=degraded)
        self._run_bucket_batch(label, spec, [p])
        return p.wait()

    def submit(self, request: GraphRequest) -> _Pending:
        """Queued lane: coalesce with same-bucket requests, return a handle.

        The handle's ``wait(timeout)`` returns the :class:`ServeResult`.
        Plan resolution (and any cold partition) runs on the calling
        thread; only the kernel launch is deferred to the batch window.
        """
        if self._batcher is None:
            raise RuntimeError("this GraphServer was built with start_batcher=False")
        sp, ticket_hit, stale, degraded = self._plan_for(request)
        bucket = self._bucket_for(sp)
        if bucket is None:
            p = _Pending(request, sp, ticket_hit, None, time.perf_counter(),
                         stale=stale, degraded=degraded)
            label = None
        else:
            label, spec = bucket
            ops = self._bucket_operands(request, sp, label, spec)
            p = _Pending(request, sp, ticket_hit, ops, time.perf_counter(),
                         stale=stale, degraded=degraded)
        with self._cv:
            if self._closed:
                raise RuntimeError("GraphServer is closed")
            self._queues.setdefault(label, []).append(p)
            self._cv.notify()
        return p

    def stats(self) -> dict:
        """Compile-cache counters + batch-size histogram + per-bucket specs."""
        with self._lock:
            hist = dict(sorted(self._batch_hist.items()))
            per_bucket = {
                label: {
                    "batch": spec.batch,
                    "e_max": spec.e_max,
                    "n_rows": spec.n_rows,
                    "n_cols": spec.n_cols,
                    "operand_elems": spec.operand_elems(),
                    "hits": self.compile_cache.hits_for(("bucket", label)),
                    "compiled": ("bucket", label) in self.compile_cache,
                }
                for label, spec in self._specs.items()
            }
        s = self.compile_cache.stats()
        s["batch_hist"] = hist
        s["buckets"] = per_bucket
        with self._lock:
            s["degraded_serves"] = self._degraded_serves
            s["brownout_rejects"] = self._brownout_rejects
        s["brownout_level"] = self.brownout_level()
        return s

    def metrics(self):
        """Partition-service ``ServiceMetrics`` with compile-cache counters
        merged into its ``compile_cache`` field."""
        snap = self.service.metrics()
        snap.compile_cache.update(self.stats())
        return snap

    def close(self) -> None:
        """Flush the queue and stop the batcher thread (idempotent)."""
        with self._cv:
            if self._closed:
                self._cv.notify()
            self._closed = True
            self._cv.notify()
        if self._batcher is not None and self._batcher.is_alive():
            self._batcher.join(timeout=10.0)

    def __enter__(self) -> "GraphServer":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

"""Distributed runtime: sharding rules, train/serve steps, fault tolerance,
and the typed EP-SpMV request layer (GraphServer + bucketed compilation)."""
from .fault import (
    CircuitBreaker,
    FaultTolerantLoop,
    HeartbeatRegistry,
    OverloadSchedule,
    StragglerMonitor,
)
from .request import (
    BucketKey,
    BucketPolicy,
    CompileCache,
    GraphRequest,
    GraphServer,
    ServeInfo,
    ServeResult,
    resolve_plan,
)
from .serve import make_decode_step, make_graph_serve_fn, make_prefill_step
from .sharding import (
    ShardingRules,
    batch_specs,
    cache_spec_tree,
    make_sharding_rules,
    named,
    param_specs,
    tree_named,
)
from .train import TrainState, init_train_state, make_train_step, split_microbatches

__all__ = [
    "BucketKey",
    "BucketPolicy",
    "CompileCache",
    "CircuitBreaker",
    "FaultTolerantLoop",
    "GraphRequest",
    "GraphServer",
    "HeartbeatRegistry",
    "ServeInfo",
    "ServeResult",
    "ShardingRules",
    "OverloadSchedule",
    "StragglerMonitor",
    "TrainState",
    "batch_specs",
    "cache_spec_tree",
    "init_train_state",
    "make_decode_step",
    "make_graph_serve_fn",
    "make_prefill_step",
    "make_sharding_rules",
    "make_train_step",
    "named",
    "param_specs",
    "resolve_plan",
    "split_microbatches",
    "tree_named",
]

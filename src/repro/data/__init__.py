"""Deterministic synthetic data pipeline (stateless-resumable, host-sharded)."""
from .pipeline import EOS, PipelineConfig, SyntheticPipeline, pack_documents

__all__ = ["EOS", "PipelineConfig", "SyntheticPipeline", "pack_documents"]

"""Deterministic, stateless-resumable synthetic data pipeline.

Every batch is a pure function of (seed, step) — after a restart at step s
the pipeline regenerates batch s bit-exactly with no iterator state to
checkpoint (the standard large-run recipe: data order is derived, not
stored).  Per-host sharding takes (host_index, host_count) and yields only
that host's slice of the global batch.

Documents are synthetic Zipf token streams *packed* into fixed-length rows
(sequence packing: multiple short docs per row, separated by EOS, no pad
waste) — irregular document lengths are what make the packing non-trivial,
matching production text pipelines.

Frontend-stub archs (audio/vision) get deterministic embedding tensors +
M-RoPE position streams instead of token ids, per the task spec.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

__all__ = ["PipelineConfig", "SyntheticPipeline", "pack_documents"]

EOS = 1


def pack_documents(doc_lengths: np.ndarray, seq_len: int) -> list[list[int]]:
    """First-fit packing of docs into rows of seq_len; returns doc ids/row."""
    rows: list[list[int]] = []
    space: list[int] = []
    for i, ln in enumerate(doc_lengths):
        ln = int(min(ln, seq_len))
        placed = False
        for r, s in enumerate(space):
            if s >= ln:
                rows[r].append(i)
                space[r] -= ln
                placed = True
                break
        if not placed:
            rows.append([i])
            space.append(seq_len - ln)
    return rows


@dataclasses.dataclass(frozen=True)
class PipelineConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    mean_doc_len: int = 512
    frontend: Optional[str] = None  # 'audio' | 'vision'
    d_model: int = 0                # for frontend embeds
    host_index: int = 0
    host_count: int = 1


class SyntheticPipeline:
    """batch(step) -> dict of numpy arrays (this host's shard)."""

    def __init__(self, cfg: PipelineConfig):
        if cfg.global_batch % cfg.host_count:
            raise ValueError("global_batch must divide by host_count")
        self.cfg = cfg
        self.local_batch = cfg.global_batch // cfg.host_count

    def _rng(self, step: int) -> np.random.Generator:
        # Philox keyed on (seed, step, host): stateless resume + host shard.
        return np.random.default_rng(
            np.random.SeedSequence(
                entropy=self.cfg.seed, spawn_key=(step, self.cfg.host_index)
            )
        )

    def _token_row(self, rng: np.random.Generator) -> np.ndarray:
        cfg = self.cfg
        # Draw doc lengths until the row is full, Zipf-ish token ids.
        toks = np.empty(cfg.seq_len + 1, dtype=np.int32)
        filled = 0
        while filled < cfg.seq_len + 1:
            ln = int(rng.geometric(1.0 / cfg.mean_doc_len))
            ln = max(2, min(ln, cfg.seq_len + 1 - filled))
            # Zipf body in [2, vocab): 0 reserved pad, 1 = EOS.
            body = rng.zipf(1.3, size=ln - 1)
            body = 2 + (body % (cfg.vocab_size - 2))
            toks[filled : filled + ln - 1] = body
            toks[filled + ln - 1] = EOS
            filled += ln
        return toks

    def batch(self, step: int) -> dict:
        cfg = self.cfg
        rng = self._rng(step)
        out: dict[str, np.ndarray] = {}
        rows = np.stack([self._token_row(rng) for _ in range(self.local_batch)])
        tokens = rows[:, : cfg.seq_len]
        labels = rows[:, 1 : cfg.seq_len + 1]
        if cfg.frontend:
            # Stub frontend: precomputed frame/patch embeddings.
            out["embeds"] = rng.standard_normal(
                (self.local_batch, cfg.seq_len, cfg.d_model), dtype=np.float32
            )
            if cfg.frontend == "vision":
                # M-RoPE (t, h, w) streams: a synthetic grid raster.
                side = max(1, int(np.sqrt(cfg.seq_len)))
                idx = np.arange(cfg.seq_len)
                pos3 = np.stack(
                    [idx, (idx // side) % side, idx % side]
                ).astype(np.int32)  # (3, S)
                out["positions3"] = np.broadcast_to(
                    pos3[:, None, :], (3, self.local_batch, cfg.seq_len)
                ).copy()
        else:
            out["tokens"] = tokens
        out["labels"] = labels
        return out

    def enc_dec_batch(self, step: int) -> dict:
        """encdec variant: encoder embeds + decoder tokens."""
        cfg = self.cfg
        base = self.batch(step)
        rng = self._rng(step)
        base["enc_embeds"] = rng.standard_normal(
            (self.local_batch, cfg.seq_len, cfg.d_model), dtype=np.float32
        )
        if "tokens" not in base:
            base["tokens"] = base.pop("embeds") * 0  # pragma: no cover
        return base

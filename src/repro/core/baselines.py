"""Baseline task-partition methods the paper compares against (§3.3, Fig 6).

* ``default_schedule``      — the GPU default: tasks in input order, chunked
                              into equal-size blocks (CUSP-style layout).
* ``random_partition``      — PowerGraph's random edge placement.
* ``greedy_powergraph``     — PowerGraph's greedy heuristic: prefer a
                              partition already holding an endpoint, else
                              the least-loaded partition.
* ``hypergraph_partition``  — hMETIS/PaToH stand-in: tasks are hypergraph
                              vertices, data objects are nets; partitioned
                              via star expansion with the same multilevel
                              engine.  Measures the same (lambda - 1) net
                              cut as the paper's hypergraph model.
"""
from __future__ import annotations

import numpy as np

from .graph import EdgeList, csr_from_edges
from .partition import MultilevelOptions, partition_vertices

__all__ = [
    "default_schedule",
    "random_partition",
    "greedy_powergraph",
    "hypergraph_partition",
]


def default_schedule(edges: EdgeList, k: int) -> np.ndarray:
    """Tasks in input order, split into k equal contiguous chunks."""
    m = edges.m
    chunk = -(-m // k)
    return (np.arange(m, dtype=np.int64) // chunk).astype(np.int32)


def random_partition(edges: EdgeList, k: int, seed: int = 0) -> np.ndarray:
    """PowerGraph random placement (balanced by round-robin of a shuffle)."""
    rng = np.random.default_rng(seed)
    perm = rng.permutation(edges.m)
    labels = np.empty(edges.m, dtype=np.int32)
    labels[perm] = np.arange(edges.m, dtype=np.int64) % k
    return labels


def greedy_powergraph(edges: EdgeList, k: int, seed: int = 0) -> np.ndarray:
    """PowerGraph greedy placement (sequential, endpoint-affinity).

    For each edge in order: if some partition already holds both endpoints,
    pick it; else if some partition holds one endpoint, pick the least
    loaded of those; else pick the globally least-loaded partition.  A
    capacity cap keeps the result balanced, matching PowerGraph's balance
    constraint.
    """
    m = edges.m
    cap = -(-m // k) * 1.05 + 1
    labels = np.empty(m, dtype=np.int32)
    load = np.zeros(k, dtype=np.int64)
    # partition sets per vertex, stored as python sets (host-side; the paper
    # notes these methods are fast but low quality).
    vparts: list[set[int]] = [set() for _ in range(edges.n)]
    u_arr = edges.u
    v_arr = edges.v
    for e in range(m):
        u, v = int(u_arr[e]), int(v_arr[e])
        pu, pv = vparts[u], vparts[v]
        both = pu & pv
        cand: set[int] | None = None
        if both:
            cand = both
        elif pu or pv:
            cand = pu | pv
        if cand:
            best, best_load = -1, None
            for p in cand:
                if load[p] >= cap:
                    continue
                if best_load is None or load[p] < best_load:
                    best, best_load = p, load[p]
            if best >= 0:
                labels[e] = best
                load[best] += 1
                pu.add(best)
                pv.add(best)
                continue
        p = int(np.argmin(load))
        labels[e] = p
        load[p] += 1
        pu.add(p)
        pv.add(p)
    return labels


def hypergraph_partition(
    edges: EdgeList, k: int, opts: MultilevelOptions | None = None
) -> np.ndarray:
    """Hypergraph model via star expansion (hMETIS/PaToH stand-in).

    Hypergraph: vertex per task (weight 1), net per data object covering the
    tasks that touch it.  Star expansion inserts one zero-weight hub node
    per net connected to each of its pins; partitioning the expanded graph
    with the multilevel engine approximates minimizing the (lambda - 1) net
    cut — the same objective the paper's hypergraph baseline optimizes.
    """
    opts = opts or MultilevelOptions()
    m, n = edges.m, edges.n
    # Task nodes: 0..m, hub nodes: m..m+n.
    pin_src = np.concatenate([np.arange(m, dtype=np.int64)] * 2)
    pin_dst = np.concatenate([m + edges.u, m + edges.v])
    vweights = np.concatenate(
        [np.ones(m, dtype=np.int64), np.zeros(n, dtype=np.int64)]
    )
    g = csr_from_edges(m + n, pin_src, pin_dst, None, vweights=vweights)
    labels, _ = partition_vertices(g, k, opts)
    return labels[:m].astype(np.int32)

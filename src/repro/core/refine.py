"""Shared batched-refinement engine (Jostle/parallel-FM style primitives).

Both refinement sweeps in this codebase — the full multilevel refiner
(`partition._refine`, vertex moves against an edge-cut objective) and the
incremental dirty-region sweep (`partition_service.incremental_repartition`,
task moves against the §3.1 vertex-cut objective) — run the same batched
move machinery: collect candidates, order them overweight-escapes-first then
by gain, and admit whole batches per destination part with cumulative-weight
prefix sums against the balance cap.  This module is that machinery, factored
out so the two callers only differ in *what* they score (vertex connectivity
rows vs. a dense task-incidence table) and *which* item subset they sweep
(every boundary vertex vs. the churn-dirty task set).

Primitives:

  * :func:`run_first_mask` / :func:`run_last_mask` — run boundaries of a
    sorted key array; the building block for every segmented reduction here.
  * :func:`segmented_cumsum` — inclusive prefix sums restarting per segment;
    the balance-cap admission test is ``part_weight + segmented_cumsum(w)``.
  * :func:`admit_batched_moves` — one whole refinement pass' admission:
    per-destination prefix-sum capping (phase A) plus rank-packed repair of
    overweight leftovers into the remaining room (phase B).
  * :func:`build_task_connectivity` / :func:`apply_task_moves` — the dense
    ``(n_relevant, k)`` task-incidence table over a compacted vertex index
    (one bincount over packed keys) and its incremental per-pass update,
    used by the dirty-region sweep.
"""
from __future__ import annotations

import numpy as np

__all__ = [
    "admit_batched_moves",
    "apply_task_moves",
    "build_task_connectivity",
    "project_majority_labels",
    "run_first_mask",
    "run_last_mask",
    "segmented_cumsum",
    "segmented_max",
]


def run_last_mask(keys: np.ndarray) -> np.ndarray:
    """Boolean mask marking the last element of each run of equal keys."""
    last = np.empty(keys.shape[0], dtype=bool)
    last[-1] = True
    np.not_equal(keys[:-1], keys[1:], out=last[:-1])
    return last


def run_first_mask(keys: np.ndarray) -> np.ndarray:
    """Boolean mask marking the first element of each run of equal keys."""
    first = np.empty(keys.shape[0], dtype=bool)
    first[0] = True
    np.not_equal(keys[1:], keys[:-1], out=first[1:])
    return first


def segmented_cumsum(values: np.ndarray, seg_first: np.ndarray) -> np.ndarray:
    """Inclusive prefix sum of ``values`` restarting where ``seg_first``."""
    cum = np.cumsum(values)
    seg_id = np.cumsum(seg_first) - 1
    base = (cum - values)[seg_first]
    return cum - base[seg_id]


def segmented_max(values: np.ndarray, seg_first: np.ndarray) -> np.ndarray:
    """Per-segment maximum, broadcast back to every element of the segment.

    One ``maximum.reduceat`` over the run starts — the segmented-argmax
    building block shared by heavy-edge matching (heaviest remaining
    neighbour per vertex) and cluster coarsening (best-affinity proposal
    per vertex): compare ``values == segmented_max(values, first)`` to mask
    each segment's winners.
    """
    starts = np.flatnonzero(seg_first)
    seg_max = np.maximum.reduceat(values, starts)
    return seg_max[np.cumsum(seg_first) - 1]


def project_majority_labels(
    cmap: np.ndarray,
    labels: np.ndarray,
    weights: np.ndarray,
    k: int,
    nc: int,
) -> np.ndarray:
    """Weight-majority label per coarse vertex — seeded re-initialization.

    ``cmap`` maps fine vertices to coarse ids, ``labels`` / ``weights`` are
    the fine labels and vertex weights; each coarse vertex takes the label
    holding the largest member weight (ties to the lowest part id, via the
    row argmax).  One bincount over packed ``coarse * k + label`` keys — the
    local V-cycle uses this instead of region growing to re-initialize each
    coarser level from the labels being repaired.
    """
    hist = np.bincount(
        cmap * np.int64(k) + labels, weights=weights, minlength=nc * k
    ).reshape(nc, k)
    return np.argmax(hist, axis=1)


def admit_batched_moves(
    cand: np.ndarray,
    gain: np.ndarray,
    dest: np.ndarray,
    cur: np.ndarray,
    weights: np.ndarray,
    part_weight: np.ndarray,
    cap: float,
    over_cand: np.ndarray,
) -> tuple[np.ndarray, np.ndarray]:
    """Admit one pass' worth of moves under the balance cap.

    ``cand`` holds item ids already in priority order (overweight escapes
    first, then descending gain); ``gain`` / ``dest`` / ``cur`` / ``weights``
    / ``over_cand`` are aligned with it (desired destination, current part,
    item weight, and whether the item sits in an overweight part).

    Phase A admits each item toward its desired destination, capped by a
    per-destination cumulative-weight prefix sum (stable sort keeps the
    priority order within each destination).  Phase B rank-packs the
    overweight leftovers into whatever room remains across parts
    (conservative: incoming weight from phase A counts, outgoing weight is
    ignored, so the cap can never be breached).

    Returns ``(mv, dst_p)``: the admitted item ids and their destinations.
    """
    k = int(part_weight.shape[0])
    order = np.argsort(dest, kind="stable")
    c2, d2, g2 = cand[order], dest[order], gain[order]
    w2, cur2, ov2 = weights[order], cur[order], over_cand[order]
    local = segmented_cumsum(w2, run_first_mask(d2)) if d2.size else w2
    admit = (part_weight[d2] + local <= cap) & (d2 != cur2)
    mv, dst_p = c2[admit], d2[admit]

    left_mask = ~admit & ov2
    if left_mask.any():
        incoming = np.bincount(dst_p, weights=w2[admit], minlength=k)
        pw_after = part_weight + incoming
        room = cap - pw_after
        targ = np.flatnonzero(room > 0)
        if targ.size:
            left, lw, lcur = c2[left_mask], w2[left_mask], cur2[left_mask]
            o = np.argsort(-g2[left_mask], kind="stable")
            left, lw, lcur = left[o], lw[o], lcur[o]
            torder = targ[np.argsort(pw_after[targ], kind="stable")]
            bounds = np.cumsum(room[torder])
            pos = np.cumsum(lw)
            rank = np.searchsorted(bounds, pos, side="left")
            fits = rank < torder.size
            bdest = np.where(fits, torder[np.minimum(rank, torder.size - 1)], -1)
            # Exact per-part re-check: an item straddling a room boundary
            # could overflow its slot — drop it this pass.
            ok = fits & (bdest != lcur)
            if ok.any():
                lcum = segmented_cumsum(lw, run_first_mask(bdest))
                ok &= pw_after[np.maximum(bdest, 0)] + lcum <= cap
            if ok.any():
                mv = np.concatenate([mv, left[ok]])
                dst_p = np.concatenate([dst_p, bdest[ok]])
    return mv, dst_p


def build_task_connectivity(
    rel_of: np.ndarray,
    u: np.ndarray,
    v: np.ndarray,
    labels: np.ndarray,
    k: int,
    n_rel: int,
) -> np.ndarray:
    """Dense ``(n_rel, k)`` task-incidence table over a compacted vertex index.

    ``table[rel_of[w], p]`` = number of tasks incident to vertex ``w`` that
    are assigned to part ``p`` (self-loops count once — a task contributes
    one incidence per *distinct* endpoint).  Only endpoints with
    ``rel_of >= 0`` (the relevant-vertex compaction) are counted; everything
    is one bincount over packed ``row * k + part`` keys.
    """
    loop = u == v
    ru, rv = rel_of[u], rel_of[v]
    mu, mv_ = ru >= 0, (rv >= 0) & ~loop
    keys = np.concatenate([(ru[mu] * k + labels[mu]), (rv[mv_] * k + labels[mv_])])
    return np.bincount(keys, minlength=n_rel * k).reshape(n_rel, k)


def apply_task_moves(
    table: np.ndarray,
    rel_of: np.ndarray,
    u: np.ndarray,
    v: np.ndarray,
    old_parts: np.ndarray,
    new_parts: np.ndarray,
) -> None:
    """Incrementally update the task-incidence table after a batch of moves.

    Each moved task (endpoints ``u[i]``, ``v[i]``) leaves ``old_parts[i]``
    and joins ``new_parts[i]``; only its (at most two distinct) endpoint rows
    change, so the per-pass cost is O(moved), not a table rebuild.
    """
    k = table.shape[1]
    loop = u == v
    rows = np.concatenate([rel_of[u], rel_of[v][~loop]])
    olds = np.concatenate([old_parts, old_parts[~loop]])
    news = np.concatenate([new_parts, new_parts[~loop]])
    flat = table.reshape(-1)
    np.subtract.at(flat, rows * k + olds, 1)
    np.add.at(flat, rows * k + news, 1)

"""Bounded admission control for the plan scheduler — overload protection.

The paper's §3–4 argument is that admitting the wrong work into a bounded
resource destroys throughput for every sharer; PR 5's multitenant bench
applied that to the *cache* (per-tenant byte budgets), but the scheduler's
priority heap stayed unbounded: a flooding tenant could queue-starve
everyone, and a request whose end-to-end deadline was already unmeetable
still consumed a worker slot.  This module is the admission half of the
fix (``PlanScheduler`` owns the shedding half):

* :class:`AdmissionRejectedError` — typed over-limit rejection carrying a
  ``retry_after_s`` hint derived from the observed drain rate, so a
  well-behaved client can back off for exactly as long as the queue needs
  to make room.  Pickles faithfully (the hint must cross the
  ``core/transport.py`` wire intact).
* :class:`DeadlineShedError` — the scheduler shed a job because its
  p50-predicted service time already exceeded its remaining deadline
  budget; retrying is pointless, which is why this is *not* an
  ``AdmissionRejectedError`` (no retry hint).
* :class:`AdmissionController` — a configurable queue bound split into
  per-tenant weighted-fair token buckets.  Each tenant may hold queue
  slots up to its weight's share of the bound among *currently active*
  tenants (work-conserving: a lone tenant can fill the whole queue; the
  moment a second tenant shows up the shares contract), with a floor of
  one slot so no tenant can be starved outright.  Tokens are taken at
  submit and returned when the job leaves the queue (worker pickup,
  cancel, shed, or close-drain) — replenish-on-drain, not wall-clock
  refill, so admission decisions are deterministic under injected load.

The controller is deliberately lock-free: every method is called under
``PlanScheduler._cv``'s lock (or a test's single thread), mirroring how
the scheduler guards its own counters.
"""
from __future__ import annotations

import time
from collections import deque
from typing import Callable, Optional

__all__ = [
    "AdmissionController",
    "AdmissionRejectedError",
    "DeadlineShedError",
]


class AdmissionRejectedError(RuntimeError):
    """The request was refused at admission: the tenant's weighted-fair
    share of the bounded queue is full.  ``retry_after_s`` estimates when a
    slot will have drained (from the observed completion rate); clients
    that wait that long and resubmit are load-shaping, not retry-storming.
    """

    def __init__(self, message: str = "", retry_after_s: float = 0.0,
                 tenant: str = "default", reason: str = "queue_full") -> None:
        super().__init__(message)
        self.retry_after_s = float(retry_after_s)
        self.tenant = tenant
        self.reason = reason

    def __reduce__(self):
        # Default exception pickling replays ``args`` only; the hint and
        # tenant must survive the wire (transport answers rejections as
        # typed error frames and the client re-raises this object).
        msg = self.args[0] if self.args else ""
        return (type(self), (msg, self.retry_after_s, self.tenant, self.reason))


class DeadlineShedError(RuntimeError):
    """The job was shed because its p50-predicted service time exceeded
    the remaining deadline budget — it could not have finished in time, so
    failing fast returns the worker slot to requests that still can."""


class AdmissionController:
    """Queue-bound admission with per-tenant weighted-fair token buckets.

    ``max_queue_depth`` is the total number of queue slots.  A tenant's
    bucket capacity is ``max(1, floor(bound * w / sum(active weights)))``
    where the active set is every tenant currently holding at least one
    slot plus the requester — shares are recomputed per decision, so the
    bound partitions itself among whoever is actually competing.

    ``retry_after(tenant)`` converts the tenant's excess occupancy into
    seconds via the drain-rate estimator (:meth:`note_drained` timestamps,
    recorded by the scheduler on every job completion).  With no drain
    history the hint is exactly ``retry_floor_s`` — a deterministic
    fallback the transport tests byte-compare across the wire.
    """

    def __init__(
        self,
        max_queue_depth: int,
        tenant_weights: Optional[dict[str, float]] = None,
        default_weight: float = 1.0,
        retry_floor_s: float = 0.05,
        retry_cap_s: float = 5.0,
        clock: Callable[[], float] = time.perf_counter,
    ) -> None:
        if max_queue_depth < 1:
            raise ValueError("max_queue_depth must be >= 1")
        if default_weight <= 0:
            raise ValueError("default_weight must be > 0")
        self.max_queue_depth = int(max_queue_depth)
        self.default_weight = float(default_weight)
        self.retry_floor_s = float(retry_floor_s)
        self.retry_cap_s = float(retry_cap_s)
        self._clock = clock
        self._weights = {t: float(w) for t, w in (tenant_weights or {}).items()}
        for t, w in self._weights.items():
            if w <= 0:
                raise ValueError(f"tenant weight for {t!r} must be > 0")
        self._held: dict[str, int] = {}  # tenant -> queue slots held
        self._drained: deque[float] = deque(maxlen=64)  # completion times

    # -- shares --------------------------------------------------------------

    def weight(self, tenant: str) -> float:
        return self._weights.get(tenant, self.default_weight)

    def share(self, tenant: str) -> int:
        """Slots ``tenant`` may hold right now: its weighted share of the
        bound among active tenants, floored at one slot."""
        active = {t for t, n in self._held.items() if n > 0}
        active.add(tenant)
        total = sum(self.weight(t) for t in active)
        return max(1, int(self.max_queue_depth * self.weight(tenant) / total))

    def held(self, tenant: str) -> int:
        return self._held.get(tenant, 0)

    def occupancy(self) -> dict[str, int]:
        return {t: n for t, n in self._held.items() if n > 0}

    # -- admit / release -----------------------------------------------------

    def try_acquire(self, tenant: str) -> Optional[AdmissionRejectedError]:
        """Take one queue slot for ``tenant``; returns None on success or
        the (unraised) rejection describing why and when to retry."""
        held = self._held.get(tenant, 0)
        share = self.share(tenant)
        if held < share:
            self._held[tenant] = held + 1
            return None
        hint = self.retry_after(tenant)
        return AdmissionRejectedError(
            f"admission rejected for tenant {tenant!r}: holding {held} of "
            f"{share} queue slots (bound {self.max_queue_depth}); "
            f"retry in {hint:.3g}s",
            retry_after_s=hint, tenant=tenant, reason="queue_full")

    def release(self, tenant: str) -> None:
        """Return one slot (job left the queue: pickup/cancel/shed/drain)."""
        held = self._held.get(tenant, 0)
        if held <= 1:
            self._held.pop(tenant, None)
        else:
            self._held[tenant] = held - 1

    # -- drain-rate estimator ------------------------------------------------

    def note_drained(self, now: Optional[float] = None) -> None:
        """Record one job completion — the queue's drain signal."""
        self._drained.append(self._clock() if now is None else now)

    def drain_rate(self) -> float:
        """Completions per second over the recent drain window (0 when
        fewer than two completions have been observed)."""
        if len(self._drained) < 2:
            return 0.0
        span = self._drained[-1] - self._drained[0]
        if span <= 0.0:
            return 0.0
        return (len(self._drained) - 1) / span

    def retry_after(self, tenant: str) -> float:
        """Seconds until the tenant's excess occupancy should have drained,
        clamped to [retry_floor_s, retry_cap_s]."""
        excess = max(1, self._held.get(tenant, 0) - self.share(tenant) + 1)
        rate = self.drain_rate()
        est = excess / rate if rate > 0.0 else self.retry_floor_s
        return min(max(est, self.retry_floor_s), self.retry_cap_s)

    # -- introspection -------------------------------------------------------

    def snapshot(self) -> dict:
        return {
            "max_queue_depth": self.max_queue_depth,
            "occupancy": self.occupancy(),
            "drain_rate": self.drain_rate(),
        }

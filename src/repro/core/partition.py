"""Multilevel balanced k-way vertex partitioning — fully array-native.

The paper solves balanced *edge* partitioning by converting it into balanced
*vertex* partitioning (§3.2) and handing the converted graph to a multilevel
vertex partitioner (METIS).  METIS is not available offline, so this module
implements the same multilevel scheme from scratch, with every stage of the
hot path expressed as NumPy array programs (no Python-scale per-vertex or
per-edge loops — the cold-path cost the paper's §4.2 overlap has to hide is
exactly this code):

  1. **Coarsening** — size-constrained *cluster* coarsening (the
     ``coarsen.ClusterCoarsener`` engine): every still-singleton vertex
     proposes to join its heaviest-affinity neighbour's cluster (jittered
     heavy-edge affinity, capped by a cluster-size bound derived from the
     balance slack), proposals resolve by pointer-jumping
     to cluster roots, and admission is a score-ordered prefix sum per
     cluster — so one level contracts 3-8x instead of the <=2x a pairwise
     matching can, and the V-cycle reaches the coarsening target in ~4
     levels instead of 10+.  Contraction handles arbitrary fine->coarse
     maps, deduping parallel edges via a packed-key bincount when the
     coarse graph is small (no per-level full-nnz argsort).  Randomized
     heavy-edge matching (mutual-proposal rounds, segmented
     ``maximum.reduceat`` over the CSR-grouped edge list) survives as
     ``MultilevelOptions(coarsen_mode="matching")`` — the property-test
     reference the cluster engine is checked against.
  2. **Initial partitioning** — vectorized multi-source region growing on
     the coarsest graph: all k regions grow *simultaneously*, one vertex per
     part per round, chosen by a masked per-part argmax over a dense
     (k, n) connectivity table.  Conflicts (two parts claiming the same
     vertex) are resolved by a segment-max (lexsort + run-first mask) in
     favour of the strongest connection; empty parts are seeded from the
     highest-degree unassigned vertices, and grown parts whose frontier
     goes cold retire (stragglers are rank-packed into remaining room).
  3. **Uncoarsening + refinement** — project labels level by level and run
     *batched* boundary refinement (Jostle/parallel-FM style): per-vertex
     gains to the best external partition come from grouped connectivity
     tables; all candidate moves of a pass are admitted together, sorted by
     gain, with per-destination cumulative-weight prefix sums enforcing the
     balance cap, and applied as one fancy-index write.  The connectivity
     tables are **incremental across passes**: after a batch of moves, only
     the rows of moved vertices and their neighbours are recomputed (their
     tables are the only ones whose inputs changed, so this is exact, not
     approximate).

All stages read the graph's cached COO view (``CSRGraph.coo_src``) instead
of re-expanding ``indptr`` at every call site, and ``partition_vertices``
reports per-stage wall times (coarsen / init / refine) in
:class:`PartitionStats`.

The output satisfies the paper's balance requirement: max part weight is at
most ``(1 + eps) * ceil(total / k)`` (the paper observes balance factors
below 1.03 in practice; the refiner enforces the cap with dedicated batched
repair passes that drain overweight parts into the remaining room, and a
repair stage fixes any overflow introduced by projection).
"""
from __future__ import annotations

import dataclasses
import time

import numpy as np

from .coarsen import ClusterCoarsener, LevelStats
from .graph import CSRGraph
from .refine import (
    admit_batched_moves,
    project_majority_labels,
    run_first_mask,
    run_last_mask,
    segmented_cumsum,
    segmented_max,
)

__all__ = [
    "partition_vertices",
    "local_partition_vertices",
    "PartitionStats",
    "LocalVcycleStats",
    "MultilevelOptions",
]


@dataclasses.dataclass
class MultilevelOptions:
    """Knobs of the multilevel V-cycle.

    Coarsening knobs:

    * ``coarsen_mode`` — ``"cluster"`` (default) runs the size-constrained
      cluster-coarsening engine (3-8x contraction per level);
      ``"matching"`` runs pairwise randomized heavy-edge matching (<=2x per
      level), kept as the property-test reference.
    * ``cluster_rounds`` — proposal/admission rounds per cluster level; the
      first round grows clusters from singletons, later rounds let leftover
      singletons join the clusters formed before them.
    * ``cluster_cap_frac`` — cluster-size cap as a fraction of the part-
      weight cap ``(1+eps)*ceil(total/k)``.  Small enough that refinement
      can still rebalance the projected partition (a coarse vertex is an
      unsplittable move unit), large enough that coarsening reaches
      ``coarsen_until`` before stalling.
    * ``match_rounds`` — mutual-proposal rounds per matching level
      (``coarsen_mode="matching"`` only).
    """

    eps: float = 0.03  # balance slack
    # Stop coarsening below max(coarsen_until, coarsen_k_factor*k).  768
    # rather than the matching-era 512: cluster levels contract ~3x, so the
    # last level overshoots the threshold by that factor — stopping earlier
    # leaves the V-cycle a finer coarsest graph (richer refinement move
    # units) at the cost of one cheap extra init round.
    coarsen_until: int = 768
    coarsen_k_factor: int = 4
    match_rounds: int = 4
    refine_passes: int = 6
    coarsest_refine_passes: int = 10
    seed: int = 0
    max_levels: int = 40
    coarsen_mode: str = "cluster"  # "cluster" | "matching"
    cluster_rounds: int = 2
    cluster_cap_frac: float = 0.25

    def __post_init__(self) -> None:
        # Fail at construction, not three levels into the V-cycle: a
        # non-positive stop threshold loops forever, a cap fraction outside
        # (0, 1] makes every cluster ineligible (or unboundedly greedy), and
        # a negative k-factor silently disables the k-aware stop.
        if self.eps < 0:
            raise ValueError(f"eps must be >= 0, got {self.eps}")
        if self.coarsen_until <= 0:
            raise ValueError(
                f"coarsen_until must be > 0, got {self.coarsen_until}"
            )
        if not 0.0 < self.cluster_cap_frac <= 1.0:
            raise ValueError(
                f"cluster_cap_frac must be in (0, 1], got {self.cluster_cap_frac}"
            )
        if self.coarsen_k_factor < 0:
            raise ValueError(
                f"coarsen_k_factor must be >= 0, got {self.coarsen_k_factor}"
            )
        if self.coarsen_mode not in ("cluster", "matching"):
            raise ValueError(f"unknown coarsen_mode {self.coarsen_mode!r}")


@dataclasses.dataclass
class PartitionStats:
    levels: int
    coarsest_n: int
    edgecut: float
    balance: float
    # Per-stage wall times (seconds) of the cold path, for ServicePlan /
    # benchmark reporting.
    coarsen_s: float = 0.0
    init_s: float = 0.0
    refine_s: float = 0.0
    coarsen_mode: str = "cluster"
    # One LevelStats per V-cycle contraction (n, nnz, contraction ratio,
    # wall time) — the per-level breakdown behind coarsen_s.
    level_stats: list[LevelStats] = dataclasses.field(default_factory=list)


# ---------------------------------------------------------------------------
# Shared helpers
# ---------------------------------------------------------------------------


def _gather_adjacency(g: CSRGraph, vertices: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
    """Flat CSR positions of the adjacency of ``vertices``.

    Returns ``(srcrep, flat)`` where ``flat`` indexes ``g.indices`` /
    ``g.eweights`` and ``srcrep[i]`` is the vertex owning slot ``flat[i]``.
    """
    counts = g.indptr[vertices + 1] - g.indptr[vertices]
    total = int(counts.sum())
    if total == 0:
        return np.empty(0, dtype=np.int64), np.empty(0, dtype=np.int64)
    seg_ends = np.cumsum(counts)
    seg_starts = seg_ends - counts
    flat = (
        np.arange(total, dtype=np.int64)
        - np.repeat(seg_starts, counts)
        + np.repeat(g.indptr[vertices], counts)
    )
    srcrep = np.repeat(vertices, counts)
    return srcrep, flat


# Run-boundary masks and segmented prefix sums live in the shared batched-
# refinement engine (refine.py) now; the old underscore names stay bound for
# the historical call sites below.
_run_last_mask = run_last_mask
_run_first_mask = run_first_mask
_segmented_cumsum = segmented_cumsum


# ---------------------------------------------------------------------------
# Coarsening
# ---------------------------------------------------------------------------


def _heavy_edge_matching(g: CSRGraph, rng: np.random.Generator, rounds: int) -> np.ndarray:
    """Return match[v] = partner vertex (or v itself for singletons).

    No sorting at all: the CSR edge list is already grouped by source, and
    round-robin filtering preserves that grouping, so each mutual-proposal
    round reads the heaviest remaining neighbour with a segmented
    ``maximum.reduceat`` over the (jittered) weights.
    """
    n = g.n
    cur_src = g.coo_src
    cur_dst = g.coo_dst
    # Random tiebreak so repeated weights don't bias matching.
    cur_w = g.eweights + rng.random(g.nnz) * 1e-9
    match = np.arange(n, dtype=np.int64)
    unmatched = np.ones(n, dtype=bool)
    for _ in range(rounds):
        if cur_src.size == 0:
            break
        is_max = cur_w == segmented_max(cur_w, _run_first_mask(cur_src))
        prop = np.full(n, -1, dtype=np.int64)
        prop[cur_src[is_max]] = cur_dst[is_max]
        cand = np.flatnonzero(prop >= 0)
        mutual_cand = cand[(prop[prop[cand]] == cand) & (cand < prop[cand])]
        # (v, prop[v]) with v < prop[v] are accepted pairs.
        v = mutual_cand
        u = prop[mutual_cand]
        match[v] = u
        match[u] = v
        unmatched[v] = False
        unmatched[u] = False
        keep = unmatched[cur_src] & unmatched[cur_dst]
        cur_src, cur_dst, cur_w = cur_src[keep], cur_dst[keep], cur_w[keep]
    return match


def _contract(
    g: CSRGraph, match: np.ndarray, engine: ClusterCoarsener | None = None
) -> tuple[CSRGraph, np.ndarray]:
    """Contract matched pairs; return coarse graph and fine->coarse map.

    A matching is the two-vertex special case of a cluster map (root = the
    smaller endpoint), so this delegates to the engine's generalized
    ``contract_clusters`` — the packed-key dedupe there groups edges in the
    same ascending-key order with weights summed in original edge order, so
    the coarse graph is byte-identical to the historical pairwise version.
    """
    rep = np.minimum(np.arange(g.n, dtype=np.int64), match)
    return (engine or ClusterCoarsener()).contract_clusters(g, rep)


# ---------------------------------------------------------------------------
# Initial partitioning (coarsest level): vectorized multi-source growing
# ---------------------------------------------------------------------------


def _pack_stragglers(
    labels: np.ndarray, part_weight: np.ndarray, vw: np.ndarray, cap: float, k: int
) -> None:
    """Rank-pack unassigned vertices into the lightest parts, in place.

    Heaviest stragglers first, parts filled lightest-first by cumulative
    weight against the cap; anything beyond all remaining room round-robins
    over the lightest parts (the scalar fallback ignored the cap here too).
    """
    rest = np.flatnonzero(labels < 0)
    if rest.size == 0:
        return
    rest = rest[np.argsort(-vw[rest], kind="stable")]
    porder = np.argsort(part_weight, kind="stable")
    room = np.maximum(cap - part_weight[porder], 0.0)
    bounds = np.cumsum(room)
    pos = np.cumsum(vw[rest])
    rank = np.searchsorted(bounds, pos, side="left")
    fits = rank < k
    cand = rest[fits]
    dst = porder[rank[fits]]
    if cand.size:
        # Exact per-part re-check: a vertex straddling a room boundary
        # would overflow its slot — demote it to the spill instead.
        lcum = _segmented_cumsum(vw[cand], _run_first_mask(dst))
        ok = part_weight[dst] + lcum <= cap
        labels[cand[ok]] = dst[ok]
        spill = np.concatenate([cand[~ok], rest[~fits]])
    else:
        spill = rest[~fits]
    if spill.size:
        labels[spill] = porder[np.arange(spill.size) % k]
    np.add.at(part_weight, labels[rest], vw[rest])


#: Coarsest-graph size above which initial partitioning switches from the
#: dense one-vertex-per-part-per-round growth (O(n) rounds over a (k, n)
#: table — quadratic) to whole-frontier wave growth (O(diameter) rounds).
#: Only stalled coarsenings (random/power-law graphs) ever exceed this.
_WAVE_INIT_THRESHOLD = 16384


def _initial_partition_wave(
    g: CSRGraph, k: int, cap: float, rng: np.random.Generator
) -> np.ndarray:
    """Whole-frontier multi-source wave growth for large coarsest graphs.

    Every round, every unassigned vertex adjacent to a region joins the
    (non-full) region it connects to most strongly, admission bounded per
    part by a cumulative-weight prefix sum against the balance cap — so
    regions advance a full frontier ring per round and the round count is
    the graph diameter, not n.  Coarser-grained than the dense growth (used
    below ``_WAVE_INIT_THRESHOLD``) but memory is O(nnz) and runtime is
    rounds*O(boundary log boundary); refinement cleans the boundary after.
    """
    n = g.n
    labels = np.full(n, -1, dtype=np.int64)
    vw = g.vweights.astype(np.float64)
    target = float(vw.sum()) / k
    # Seeds: stride across the degree order spreads the sources.
    order = np.argsort(-g.degree(), kind="stable")
    seeds = order[:: max(1, n // k)][:k]
    labels[seeds] = np.arange(seeds.shape[0], dtype=np.int64)
    part_weight = np.zeros(k, dtype=np.float64)
    np.add.at(part_weight, labels[seeds], vw[seeds])
    while True:
        unas = np.flatnonzero(labels < 0)
        if unas.size == 0:
            break
        srcrep, flat = _gather_adjacency(g, unas)
        if flat.size == 0:
            break
        nb_part = labels[g.indices[flat].astype(np.int64)]
        ok = (nb_part >= 0) & (part_weight[np.maximum(nb_part, 0)] < target)
        if not ok.any():
            break
        s2, p2, w2 = srcrep[ok], nb_part[ok], g.eweights[flat][ok]
        # Strongest part per boundary vertex: group (vertex, part) sums,
        # then a per-vertex segment max.
        key = s2 * k + p2
        o = np.argsort(key, kind="stable")
        key_s = key[o]
        fm = _run_first_mask(key_s)
        conn_w = np.bincount(np.cumsum(fm) - 1, weights=w2[o])
        g_v = s2[o][fm]
        g_p = key_s[fm] % k
        o2 = np.lexsort((conn_w, g_v))
        last = _run_last_mask(g_v[o2])
        best_v = g_v[o2][last]
        best_p = g_p[o2][last]
        best_w = conn_w[o2][last]
        # Admit per part, strongest connections first, prefix-summed
        # against the growth target.
        adm_order = np.lexsort((-best_w, best_p))
        v3, p3 = best_v[adm_order], best_p[adm_order]
        local = _segmented_cumsum(vw[v3], _run_first_mask(p3))
        admit = part_weight[p3] + local <= cap  # cap >= target by construction
        v_ok, p_ok = v3[admit], p3[admit]
        if v_ok.size == 0:
            break
        labels[v_ok] = p_ok
        np.add.at(part_weight, p_ok, vw[v_ok])
    _pack_stragglers(labels, part_weight, vw, cap, k)
    return labels


def _initial_partition(g: CSRGraph, k: int, cap: float, rng: np.random.Generator) -> np.ndarray:
    """Grow all k regions simultaneously, one vertex per part per round.

    A dense (k, n) connectivity table scores every unassigned vertex against
    every growing region; each round every still-hungry part claims its
    argmax.  Conflicting claims go to the strongest connection (segment-max
    via lexsort).  Claims that would overflow the cap are permanently struck
    for that part (mirroring the scalar BFS's pop-without-assign); empty
    parts draw a fresh high-degree seed, and grown parts whose frontier
    went cold retire (the scalar BFS stopped there too).  Stragglers are
    rank-packed into the remaining room by cumulative weight.

    One vertex per part per round makes this quadratic in n, and the dense
    table is k*n floats — fine for a properly coarsened graph, ruinous when
    coarsening stalled early, so large graphs take the wave-growth path.
    """
    n = g.n
    if n > _WAVE_INIT_THRESHOLD or n * k > _DENSE_TABLE_LIMIT:
        return _initial_partition_wave(g, k, cap, rng)
    labels = np.full(n, -1, dtype=np.int64)
    vw = g.vweights.astype(np.float64)
    total = float(vw.sum())
    target = total / k
    part_weight = np.zeros(k, dtype=np.float64)
    # conn[p, v]: connectivity of unassigned v to region p; -inf marks
    # assigned vertices (whole column), cap-struck (p, v) pairs, and
    # finished parts (whole row) — so the per-round claim is one argmax
    # over the full table, no sub-copies.
    conn = np.zeros((k, n), dtype=np.float64)
    active = np.ones(k, dtype=bool)
    seed_order = np.argsort(-g.degree(), kind="stable")
    unassigned = n
    while unassigned > 0 and active.any():
        picks = np.argmax(conn, axis=1)
        vals = conn[np.arange(k), picks]
        vals[~active] = -np.inf
        # Parts with no positive connectivity: empty parts get a fresh
        # distinct high-degree seed; grown parts whose frontier went cold
        # are done (the scalar BFS stopped there too — stragglers are
        # packed at the end).
        cold = active & (vals <= 0.0)
        deactivated = False
        if cold.any():
            seedable = cold & (part_weight == 0.0)
            done = cold & ~seedable
            n_seed = int(seedable.sum())
            if n_seed:
                unas = seed_order[labels[seed_order] < 0]
                take = min(n_seed, unas.size)
                seed_rows = np.flatnonzero(seedable)
                picks[seed_rows[:take]] = unas[:take]
                vals[seed_rows[:take]] = np.inf  # a fresh seed wins its claim
                if take < n_seed:  # no vertices left to seed with
                    done[seed_rows[take:]] = True
            if done.any():
                active &= ~done
                conn[done] = -np.inf
                vals[done] = -np.inf
                deactivated = True
                if not active.any():
                    break
        claimants = np.flatnonzero(vals > 0.0)
        if claimants.size == 0:
            if not deactivated:
                break
            continue
        # Conflict resolution: one winner per claimed vertex, by strength.
        c_vals, c_picks = vals[claimants], picks[claimants]
        order = np.lexsort((-c_vals, c_picks))
        first = _run_first_mask(c_picks[order])
        win = order[first]
        p_win, v_win = claimants[win], c_picks[win]
        # Cap check: a claim that would overflow its part is struck for good.
        wv = vw[v_win]
        rej = (part_weight[p_win] + wv > cap) & (part_weight[p_win] > 0)
        if rej.any():
            conn[p_win[rej], v_win[rej]] = -np.inf
        p_ok, v_ok = p_win[~rej], v_win[~rej]
        if v_ok.size == 0:
            if not rej.any() and not deactivated:
                break  # no claims, no strikes: nothing can make progress
            continue
        labels[v_ok] = p_ok
        part_weight[p_ok] += vw[v_ok]
        unassigned -= int(v_ok.size)
        conn[:, v_ok] = -np.inf
        # Frontier update: credit each winner's adjacency to its region
        # (adding to -inf keeps assigned/struck entries excluded).
        _, flat = _gather_adjacency(g, v_ok)
        if flat.size:
            counts = g.indptr[v_ok + 1] - g.indptr[v_ok]
            prep = np.repeat(p_ok, counts)
            np.add.at(conn, (prep, g.indices[flat]), g.eweights[flat])
        active[part_weight >= target] = False
    _pack_stragglers(labels, part_weight, vw, cap, k)
    return labels


# ---------------------------------------------------------------------------
# Refinement: batched gain moves under a balance cap, incremental tables
# ---------------------------------------------------------------------------

#: Max n*k for the dense-bincount connectivity build (8M float64 = 64 MB).
_DENSE_TABLE_LIMIT = 1 << 23


def _update_connectivity_rows(
    g: CSRGraph,
    labels: np.ndarray,
    k: int,
    vertices: np.ndarray | None,
    own: np.ndarray,
    best_ext: np.ndarray,
    best_part: np.ndarray,
) -> None:
    """(Re)compute connectivity rows for ``vertices`` in place.

    ``own[v]`` = edge weight from v into its own part, ``best_ext[v]`` /
    ``best_part[v]`` = the strongest external part.  ``vertices=None`` means
    all rows (initial build, reading the cached COO view); otherwise only
    the given rows are touched — after a batch of moves only moved vertices
    and their neighbours have stale rows, so the per-pass cost is
    O(deg(dirty) log) instead of a full O(m log m) lexsort.
    """
    if vertices is None:
        n = g.n
        if n * k <= _DENSE_TABLE_LIMIT:
            # Dense path: one bincount over (vertex, part) keys replaces the
            # O(m log m) lexsort entirely; own/best-external fall out of a
            # row gather + row argmax.
            dense = np.bincount(
                g.coo_src * k + labels[g.coo_dst],
                weights=g.eweights,
                minlength=n * k,
            ).reshape(n, k)
            rows = np.arange(n)
            own[:] = dense[rows, labels]
            dense[rows, labels] = -1.0  # exclude own part from the argmax
            bp = np.argmax(dense, axis=1)
            best_part[:] = bp
            best_ext[:] = np.maximum(dense[rows, bp], 0.0)
            return
        srcrep, dst, w = g.coo_src, g.coo_dst, g.eweights
        own[:] = 0.0
        best_ext[:] = 0.0
        best_part[:] = labels
    else:
        srcrep, flat = _gather_adjacency(g, vertices)
        dst = g.indices[flat].astype(np.int64)
        w = g.eweights[flat]
        own[vertices] = 0.0
        best_ext[vertices] = 0.0
        best_part[vertices] = labels[vertices]
    if srcrep.size == 0:
        return
    key = srcrep * k + labels[dst]
    order = np.argsort(key, kind="stable")
    key_s, src_s, w_s = key[order], srcrep[order], w[order]
    uniq_mask = _run_first_mask(key_s)
    seg = np.cumsum(uniq_mask) - 1
    conn_w = np.bincount(seg, weights=w_s)  # (#groups,)
    g_src = src_s[uniq_mask]
    g_part = key_s[uniq_mask] % k
    is_own = g_part == labels[g_src]
    own[g_src[is_own]] = conn_w[is_own]
    ext = ~is_own
    if ext.any():
        es, ew_, ep = g_src[ext], conn_w[ext], g_part[ext]
        order2 = np.lexsort((ew_, es))
        es2 = es[order2]
        last = _run_last_mask(es2)
        best_ext[es2[last]] = ew_[order2][last]
        best_part[es2[last]] = ep[order2][last]


def _refine(
    g: CSRGraph,
    labels: np.ndarray,
    k: int,
    cap: float,
    passes: int,
    movable: np.ndarray | None = None,
) -> np.ndarray:
    """Batched boundary refinement with incremental connectivity tables.

    Each pass collects every candidate (positive gain, or any vertex inside
    an overweight part), orders them overweight-escapes-first then by gain,
    and admits moves per destination part with a cumulative-weight prefix
    sum against the cap — the whole batch lands in one fancy-index write.
    Overweight candidates whose best part has no room are rank-packed into
    whatever room remains across parts.  After ``passes`` gain passes, extra
    repair-only passes run until no part exceeds the cap (or no move can
    help), preserving the ``max <= (1+eps)*ceil(total/k)`` invariant.

    ``movable`` restricts candidacy to the marked vertices (the local
    V-cycle's dirty region: frozen-label anchor super-vertices still anchor
    every gain/connectivity computation but can never themselves move).
    """
    n = g.n
    vw = g.vweights.astype(np.float64)
    labels = labels.astype(np.int64).copy()
    part_weight = np.bincount(labels, weights=vw, minlength=k)
    own = np.zeros(n, dtype=np.float64)
    best_ext = np.zeros(n, dtype=np.float64)
    best_part = labels.copy()
    _update_connectivity_rows(g, labels, k, None, own, best_ext, best_part)
    tol = 1e-12
    max_repair = 2 * k + 8
    pass_i = 0
    while pass_i < passes + max_repair:
        pass_i += 1
        repair_only = pass_i > passes
        over = part_weight > cap
        if repair_only and not over.any():
            break
        gain = best_ext - own
        over_src = over[labels]
        cand_mask = over_src if repair_only else ((gain > tol) | over_src)
        if movable is not None:
            cand_mask = cand_mask & movable
        cand = np.flatnonzero(cand_mask)
        if cand.size == 0:
            break
        # Overweight escapes first (most negative pressure), then best gains;
        # the shared engine admits the pass (per-destination prefix-sum cap,
        # then rank-packed repair of overweight leftovers).
        cand = cand[np.lexsort((-gain[cand], ~over[labels[cand]]))]
        mv, dst_p = admit_batched_moves(
            cand,
            gain[cand],
            best_part[cand],
            labels[cand],
            vw[cand],
            part_weight,
            cap,
            over[labels[cand]],
        )

        if mv.size == 0:
            if repair_only:
                break
            pass_i = passes  # no gain moves left: skip straight to repair
            continue
        old = labels[mv]
        labels[mv] = dst_p
        part_weight += np.bincount(dst_p, weights=vw[mv], minlength=k)
        part_weight -= np.bincount(old, weights=vw[mv], minlength=k)
        # Incremental table update: only moved vertices and their
        # neighbours have stale rows.
        _, flat = _gather_adjacency(g, mv)
        dirty_mask = np.zeros(n, dtype=bool)
        dirty_mask[mv] = True
        dirty_mask[g.indices[flat]] = True
        dirty = np.flatnonzero(dirty_mask)
        _update_connectivity_rows(g, labels, k, dirty, own, best_ext, best_part)
    return labels


# ---------------------------------------------------------------------------
# Driver
# ---------------------------------------------------------------------------


def partition_vertices(
    g: CSRGraph, k: int, opts: MultilevelOptions | None = None
) -> tuple[np.ndarray, PartitionStats]:
    """Balanced k-way vertex partition of ``g``; returns (labels, stats)."""
    opts = opts or MultilevelOptions()
    if opts.coarsen_mode not in ("cluster", "matching"):
        raise ValueError(f"unknown coarsen_mode {opts.coarsen_mode!r}")
    rng = np.random.default_rng(opts.seed)
    n = g.n
    if k <= 1:
        return np.zeros(n, dtype=np.int32), PartitionStats(0, n, 0.0, 1.0)
    total = float(g.vweights.sum())
    cap = (1.0 + opts.eps) * np.ceil(total / k)

    # --- coarsen ---
    t0 = time.perf_counter()
    graphs = [g]
    maps: list[np.ndarray] = []
    level_stats: list[LevelStats] = []
    stop_n = max(opts.coarsen_until, opts.coarsen_k_factor * k)
    engine = ClusterCoarsener()
    # Cluster-size cap: a coarse vertex is an unsplittable refinement move,
    # so bound it by a fraction of the part-weight cap (the balance slack
    # refinement has to work with).
    cluster_cap = max(1.0, opts.cluster_cap_frac * cap)
    while graphs[-1].n > stop_n and len(graphs) <= opts.max_levels:
        cur = graphs[-1]
        lt0 = time.perf_counter()
        if opts.coarsen_mode == "cluster":
            root = engine.cluster_level(cur, rng, cluster_cap, opts.cluster_rounds)
            coarse, cmap = engine.contract_clusters(cur, root)
        else:
            match = _heavy_edge_matching(cur, rng, opts.match_rounds)
            coarse, cmap = _contract(cur, match, engine)
        if coarse.n > 0.9 * cur.n:  # stalled
            break
        level_stats.append(
            LevelStats(
                n=cur.n,
                nnz=cur.nnz,
                coarse_n=coarse.n,
                ratio=cur.n / max(coarse.n, 1),
                time_s=time.perf_counter() - lt0,
            )
        )
        graphs.append(coarse)
        maps.append(cmap)
    t1 = time.perf_counter()

    # --- initial partition on the coarsest graph ---
    coarsest = graphs[-1]
    labels = _initial_partition(coarsest, k, cap, rng)
    t2 = time.perf_counter()

    # --- refine coarsest, then uncoarsen + refine ---
    labels = _refine(coarsest, labels, k, cap, opts.coarsest_refine_passes)
    for level in range(len(maps) - 1, -1, -1):
        labels = labels[maps[level]]
        labels = _refine(graphs[level], labels, k, cap, opts.refine_passes)
    t3 = time.perf_counter()

    labels = labels.astype(np.int32)
    stats = PartitionStats(
        levels=len(graphs),
        coarsest_n=coarsest.n,
        edgecut=edgecut(g, labels),
        balance=balance_factor(g, labels, k),
        coarsen_s=t1 - t0,
        init_s=t2 - t1,
        refine_s=t3 - t2,
        coarsen_mode=opts.coarsen_mode,
        level_stats=level_stats,
    )
    return labels, stats


# ---------------------------------------------------------------------------
# Local V-cycle: re-coarsen only a dirty region, frozen labels pinned
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class LocalVcycleStats:
    """Shape and wall times of one local V-cycle run."""

    n_dirty: int  # movable fine vertices (the dirty region)
    n_anchor: int  # frozen-label anchor super-vertices
    n_local: int  # local graph size: dirty + anchors
    levels: int  # graphs in the local V-cycle (including the local finest)
    moved: int  # dirty vertices whose label changed
    edgecut: float  # full-graph edge cut of the projected labels
    balance: float
    balance_ok: bool
    build_s: float = 0.0  # frozen-region contraction + seeding
    coarsen_s: float = 0.0
    refine_s: float = 0.0
    level_stats: list[LevelStats] = dataclasses.field(default_factory=list)


def _local_vcycle(
    local_g: CSRGraph,
    lab_local: np.ndarray,
    pinned: np.ndarray,
    k: int,
    cap: float,
    opts: MultilevelOptions,
    rng: np.random.Generator,
    engine: ClusterCoarsener | None = None,
) -> tuple[np.ndarray, int, list[LevelStats], float, float]:
    """Coarsen/seed/refine a prebuilt local graph; the V-cycle proper.

    ``local_g`` is the dirty subgraph plus frozen-label anchor vertices
    (``pinned``); ``lab_local`` seeds every vertex with its current part.
    Returns ``(labels, levels, level_stats, coarsen_s, refine_s)`` —
    ``labels`` at ``local_g``'s granularity, anchors unchanged.  Both
    :func:`local_partition_vertices` (which contracts the frozen region of
    a full graph first) and the service's ``local_repartition`` (which
    assembles the local graph directly from the churn batch) call this.
    """
    engine = engine or ClusterCoarsener()
    t0 = time.perf_counter()
    graphs = [local_g]
    maps: list[np.ndarray] = []
    pinneds = [pinned]
    level_stats: list[LevelStats] = []
    stop_n = max(opts.coarsen_until, opts.coarsen_k_factor * k)
    # Cluster cap scaled to the *movable* mass, not the global part cap: a
    # coarse vertex is an unsplittable move unit, and refinement here only
    # redistributes the dirty weight — clusters sized against the global cap
    # would be a large fraction of each part's movable share.
    movable_w = float(local_g.vweights[~pinned].sum())
    cluster_cap = max(
        1.0, opts.cluster_cap_frac * (1.0 + opts.eps) * np.ceil(movable_w / k)
    )
    while graphs[-1].n > stop_n and len(graphs) <= opts.max_levels:
        cur = graphs[-1]
        lt0 = time.perf_counter()
        root_l = engine.cluster_level(
            cur, rng, cluster_cap, opts.cluster_rounds, pinned=pinneds[-1]
        )
        coarse, cmap = engine.contract_clusters(cur, root_l)
        if coarse.n > 0.9 * cur.n:  # stalled
            break
        pc = np.zeros(coarse.n, dtype=bool)
        pc[cmap[np.flatnonzero(pinneds[-1])]] = True
        level_stats.append(
            LevelStats(
                n=cur.n,
                nnz=cur.nnz,
                coarse_n=coarse.n,
                ratio=cur.n / max(coarse.n, 1),
                time_s=time.perf_counter() - lt0,
            )
        )
        graphs.append(coarse)
        maps.append(cmap)
        pinneds.append(pc)
    t1 = time.perf_counter()

    # Seeded re-init at the coarsest, then refine every level up.
    lab = lab_local
    for i, cmap in enumerate(maps):
        lab = project_majority_labels(
            cmap, lab, graphs[i].vweights.astype(np.float64), k, graphs[i + 1].n
        )
    lab = _refine(
        graphs[-1], lab, k, cap, opts.coarsest_refine_passes, movable=~pinneds[-1]
    )
    for level in range(len(maps) - 1, -1, -1):
        lab = lab[maps[level]]
        lab = _refine(
            graphs[level], lab, k, cap, opts.refine_passes, movable=~pinneds[level]
        )
    t2 = time.perf_counter()
    return lab, len(graphs), level_stats, t1 - t0, t2 - t1


def local_partition_vertices(
    g: CSRGraph,
    labels: np.ndarray,
    dirty: np.ndarray,
    k: int,
    opts: MultilevelOptions | None = None,
) -> tuple[np.ndarray, LocalVcycleStats]:
    """Repartition only the ``dirty`` vertices of an already-labeled graph.

    The mid-churn gear between single-level incremental refinement and a
    full rebuild: labels outside the dirty region are *frozen* — the whole
    frozen region is contracted into one anchor super-vertex per part
    (carrying the part's frozen weight, so the global balance cap
    ``(1+eps)*ceil(total/k)`` applies unchanged to the local problem), and
    the dirty subgraph plus anchors runs a normal V-cycle: size-constrained
    cluster coarsening with the anchors pinned (they never merge), a seeded
    re-initialization (weight-majority label per cluster instead of region
    growing), and batched refinement at every level with moves restricted
    to non-anchor vertices.  The refined labels are projected back onto the
    dirty vertices; frozen labels are returned bit-for-bit unchanged.

    ``dirty`` with no set bit is a no-op returning the input labels; dirty
    everywhere degenerates to a full (seeded) V-cycle.  ``balance_ok`` is
    False when the frozen weight alone exceeds the cap somewhere — local
    moves cannot fix that, callers should escalate to a full rebuild.
    """
    opts = opts or MultilevelOptions()
    labels = np.asarray(labels, dtype=np.int64)
    dirty = np.asarray(dirty, dtype=bool)
    n = g.n
    if labels.shape[0] != n or dirty.shape[0] != n:
        raise ValueError("labels and dirty must have one entry per vertex")
    if k <= 1:
        return np.zeros(n, dtype=np.int32), LocalVcycleStats(
            0, 0, 0, 0, 0, 0.0, 1.0, True
        )
    if labels.size and (labels.min() < 0 or labels.max() >= k):
        raise ValueError(f"labels must be part ids in [0, {k})")
    total = float(g.vweights.sum())
    cap = (1.0 + opts.eps) * np.ceil(total / k)
    if not dirty.any():
        pw = np.bincount(labels, weights=g.vweights.astype(np.float64), minlength=k)
        return labels.astype(np.int32), LocalVcycleStats(
            n_dirty=0,
            n_anchor=0,
            n_local=0,
            levels=0,
            moved=0,
            edgecut=edgecut(g, labels),
            balance=balance_factor(g, labels, k),
            balance_ok=bool(pw.max() <= cap),
        )

    # --- build: contract the frozen region to per-part anchors ---
    t0 = time.perf_counter()
    rng = np.random.default_rng(opts.seed)
    engine = ClusterCoarsener()
    frozen_ids = np.flatnonzero(~dirty)
    # rep[p] = one frozen representative of part p (idempotent root: each
    # representative is itself frozen with label p, so root[rep[p]] == rep[p]).
    rep = np.full(k, -1, dtype=np.int64)
    rep[labels[frozen_ids]] = frozen_ids
    root = np.arange(n, dtype=np.int64)
    root[frozen_ids] = rep[labels[frozen_ids]]
    local_g, fmap = engine.contract_clusters(g, root)
    anchor_parts = np.flatnonzero(rep >= 0)
    n_anchor = int(anchor_parts.size)
    pinned = np.zeros(local_g.n, dtype=bool)
    pinned[fmap[rep[anchor_parts]]] = True
    # Every member of a cluster shares its part (frozen clusters are per-part
    # by construction, dirty vertices are singletons): a scatter is exact.
    lab_local = np.empty(local_g.n, dtype=np.int64)
    lab_local[fmap] = labels
    t1 = time.perf_counter()

    lab, levels, level_stats, coarsen_s, refine_s = _local_vcycle(
        local_g, lab_local, pinned, k, cap, opts, rng, engine
    )

    # --- project back; frozen labels stay bit-for-bit unchanged ---
    dirty_ids = np.flatnonzero(dirty)
    out = labels.copy()
    out[dirty_ids] = lab[fmap[dirty_ids]]
    out32 = out.astype(np.int32)
    pw = np.bincount(out, weights=g.vweights.astype(np.float64), minlength=k)
    stats = LocalVcycleStats(
        n_dirty=int(dirty_ids.size),
        n_anchor=n_anchor,
        n_local=int(local_g.n),
        levels=levels,
        moved=int((out[dirty_ids] != labels[dirty_ids]).sum()),
        edgecut=edgecut(g, out32),
        balance=balance_factor(g, out32, k),
        balance_ok=bool(pw.max() <= cap),
        build_s=t1 - t0,
        coarsen_s=coarsen_s,
        refine_s=refine_s,
        level_stats=level_stats,
    )
    return out32, stats


def edgecut(g: CSRGraph, labels: np.ndarray) -> float:
    cut = labels[g.coo_src] != labels[g.coo_dst]
    return float(g.eweights[cut].sum() / 2.0)  # both directions stored


def balance_factor(g: CSRGraph, labels: np.ndarray, k: int) -> float:
    pw = np.bincount(labels, weights=g.vweights.astype(np.float64), minlength=k)
    avg = g.vweights.sum() / k
    return float(pw.max() / avg) if avg > 0 else 1.0

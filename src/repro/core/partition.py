"""Multilevel balanced k-way vertex partitioning.

The paper solves balanced *edge* partitioning by converting it into balanced
*vertex* partitioning (§3.2) and handing the converted graph to a multilevel
vertex partitioner (METIS).  METIS is not available offline, so this module
implements the same multilevel scheme from scratch:

  1. **Coarsening** — randomized heavy-edge matching (mutual-proposal
     rounds, fully vectorized), contracting matched pairs and summing
     vertex/edge weights until the graph is small.
  2. **Initial partitioning** — greedy graph growing (BFS region growth by
     connectivity) on the coarsest graph.
  3. **Uncoarsening + refinement** — project labels back level by level and
     run vectorized boundary refinement (Jostle/parallel-FM style): compute
     per-vertex gains to the best external partition with a sort/reduce, and
     greedily apply positive-gain moves under the balance constraint.

The output satisfies the paper's balance requirement: max part weight is at
most ``(1 + eps) * ceil(total / k)`` (the paper observes balance factors
below 1.03 in practice; the refiner enforces the cap, and a repair stage
fixes any overflow introduced by projection).
"""
from __future__ import annotations

import dataclasses

import numpy as np

from .graph import CSRGraph

__all__ = ["partition_vertices", "PartitionStats", "MultilevelOptions"]


@dataclasses.dataclass
class MultilevelOptions:
    eps: float = 0.03  # balance slack
    coarsen_until: int = 4096  # stop coarsening below max(this, coarsen_k_factor*k)
    coarsen_k_factor: int = 4
    match_rounds: int = 4
    refine_passes: int = 6
    coarsest_refine_passes: int = 10
    seed: int = 0
    max_levels: int = 40


@dataclasses.dataclass
class PartitionStats:
    levels: int
    coarsest_n: int
    edgecut: float
    balance: float


# ---------------------------------------------------------------------------
# Coarsening
# ---------------------------------------------------------------------------


def _row_argmax_neighbor(
    src: np.ndarray, dst: np.ndarray, w: np.ndarray, n: int
) -> np.ndarray:
    """best[v] = neighbour of v via the heaviest incident edge (-1 if none)."""
    best = np.full(n, -1, dtype=np.int64)
    if src.size == 0:
        return best
    order = np.lexsort((w, src))  # sort by src, then weight ascending
    s, d = src[order], dst[order]
    # Last entry of each src run = max weight neighbour.
    last = np.empty(s.shape[0], dtype=bool)
    last[-1] = True
    np.not_equal(s[:-1], s[1:], out=last[:-1])
    best[s[last]] = d[last]
    return best


def _heavy_edge_matching(g: CSRGraph, rng: np.random.Generator, rounds: int) -> np.ndarray:
    """Return match[v] = partner vertex (or v itself for singletons)."""
    n = g.n
    src = np.repeat(np.arange(n, dtype=np.int64), np.diff(g.indptr))
    dst = g.indices.astype(np.int64)
    w = g.eweights
    # Random tiebreak so repeated weights don't bias matching.
    w = w + rng.random(w.shape[0]) * 1e-9
    match = np.arange(n, dtype=np.int64)
    unmatched = np.ones(n, dtype=bool)
    cur_src, cur_dst, cur_w = src, dst, w
    for _ in range(rounds):
        if cur_src.size == 0:
            break
        best = _row_argmax_neighbor(cur_src, cur_dst, cur_w, n)
        prop = best
        ok = prop >= 0
        mutual = np.zeros(n, dtype=bool)
        idx = np.arange(n)
        cand = idx[ok]
        mutual_cand = cand[(prop[prop[cand]] == cand) & (cand < prop[cand])]
        # (v, prop[v]) with v < prop[v] are accepted pairs.
        v = mutual_cand
        u = prop[mutual_cand]
        match[v] = u
        match[u] = v
        unmatched[v] = False
        unmatched[u] = False
        mutual[v] = True
        mutual[u] = True
        keep = unmatched[cur_src] & unmatched[cur_dst]
        cur_src, cur_dst, cur_w = cur_src[keep], cur_dst[keep], cur_w[keep]
    return match


def _contract(g: CSRGraph, match: np.ndarray) -> tuple[CSRGraph, np.ndarray]:
    """Contract matched pairs; return coarse graph and fine->coarse map."""
    n = g.n
    rep = np.minimum(np.arange(n, dtype=np.int64), match)
    # Dense renumber of representatives.
    uniq, cmap = np.unique(rep, return_inverse=True)
    nc = uniq.shape[0]
    src = cmap[np.repeat(np.arange(n, dtype=np.int64), np.diff(g.indptr))]
    dst = cmap[g.indices.astype(np.int64)]
    w = g.eweights
    keep = src != dst
    src, dst, w = src[keep], dst[keep], w[keep]
    # Dedupe parallel coarse edges, summing weights.
    if src.size:
        key = src * nc + dst
        order = np.argsort(key, kind="stable")
        key, src, dst, w = key[order], src[order], dst[order], w[order]
        uniq_mask = np.empty(key.shape[0], dtype=bool)
        uniq_mask[0] = True
        np.not_equal(key[1:], key[:-1], out=uniq_mask[1:])
        seg = np.cumsum(uniq_mask) - 1
        w = np.bincount(seg, weights=w)
        src, dst = src[uniq_mask], dst[uniq_mask]
    indptr = np.zeros(nc + 1, dtype=np.int64)
    np.add.at(indptr, src + 1, 1)
    np.cumsum(indptr, out=indptr)
    vw = np.bincount(cmap, weights=g.vweights.astype(np.float64), minlength=nc)
    coarse = CSRGraph(
        indptr=indptr,
        indices=dst.astype(np.int32),
        eweights=w.astype(np.float64),
        vweights=vw.astype(np.int64),
    )
    return coarse, cmap


# ---------------------------------------------------------------------------
# Initial partitioning (coarsest level): greedy graph growing
# ---------------------------------------------------------------------------


def _initial_partition(g: CSRGraph, k: int, cap: float, rng: np.random.Generator) -> np.ndarray:
    n = g.n
    labels = np.full(n, -1, dtype=np.int32)
    vw = g.vweights.astype(np.float64)
    total = float(vw.sum())
    target = total / k
    indptr, indices, ew = g.indptr, g.indices, g.eweights
    # Seeds: spread by degree so hubs anchor different regions.
    order = np.argsort(-g.degree(), kind="stable")
    seed_ptr = 0
    part_weight = np.zeros(k, dtype=np.float64)
    conn = np.zeros(n, dtype=np.float64)  # connectivity to the growing region
    for p in range(k):
        # Pick an unassigned seed.
        while seed_ptr < n and labels[order[seed_ptr]] >= 0:
            seed_ptr += 1
        if seed_ptr >= n:
            break
        seed = order[seed_ptr]
        frontier: list[int] = [int(seed)]
        conn[seed] = 1.0
        in_frontier = {int(seed)}
        while part_weight[p] < target and frontier:
            # Take the frontier vertex with max connectivity to the region.
            bi = int(np.argmax([conn[f] for f in frontier]))
            v = frontier.pop(bi)
            in_frontier.discard(v)
            if labels[v] >= 0:
                continue
            if part_weight[p] + vw[v] > cap and part_weight[p] > 0:
                continue
            labels[v] = p
            part_weight[p] += vw[v]
            for ei in range(indptr[v], indptr[v + 1]):
                nb = int(indices[ei])
                if labels[nb] < 0:
                    conn[nb] += ew[ei]
                    if nb not in in_frontier:
                        frontier.append(nb)
                        in_frontier.add(nb)
    # Any stragglers go to the lightest parts.
    rest = np.where(labels < 0)[0]
    for v in rest:
        p = int(np.argmin(part_weight))
        labels[v] = p
        part_weight[p] += vw[v]
    return labels


# ---------------------------------------------------------------------------
# Refinement: vectorized gain-based boundary moves under a balance cap
# ---------------------------------------------------------------------------


def _connectivity_tables(
    g: CSRGraph, labels: np.ndarray, k: int
) -> tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray]:
    """Per-vertex connectivity to own part and to the best external part.

    Returns (own_conn, best_ext_conn, best_ext_part, degree_w).
    """
    n = g.n
    src = np.repeat(np.arange(n, dtype=np.int64), np.diff(g.indptr))
    dst = g.indices.astype(np.int64)
    w = g.eweights
    pv = labels[dst].astype(np.int64)
    key = src * k + pv
    order = np.argsort(key, kind="stable")
    key_s, src_s, w_s = key[order], src[order], w[order]
    if key_s.size == 0:
        z = np.zeros(n)
        return z, z.copy(), labels.astype(np.int64).copy(), z.copy()
    uniq_mask = np.empty(key_s.shape[0], dtype=bool)
    uniq_mask[0] = True
    np.not_equal(key_s[1:], key_s[:-1], out=uniq_mask[1:])
    seg = np.cumsum(uniq_mask) - 1
    conn_w = np.bincount(seg, weights=w_s)  # (#groups,)
    g_src = src_s[uniq_mask]
    g_part = (key_s[uniq_mask] % k).astype(np.int64)
    own = np.zeros(n, dtype=np.float64)
    is_own = g_part == labels[g_src]
    own[g_src[is_own]] = conn_w[is_own]
    # Best external part per vertex.
    ext_mask = ~is_own
    best_ext = np.zeros(n, dtype=np.float64)
    best_part = labels.astype(np.int64).copy()
    if ext_mask.any():
        es, ew_, ep = g_src[ext_mask], conn_w[ext_mask], g_part[ext_mask]
        order2 = np.lexsort((ew_, es))
        es2, ew2, ep2 = es[order2], ew_[order2], ep[order2]
        last = np.empty(es2.shape[0], dtype=bool)
        last[-1] = True
        np.not_equal(es2[:-1], es2[1:], out=last[:-1])
        best_ext[es2[last]] = ew2[last]
        best_part[es2[last]] = ep2[last]
    degw = np.zeros(n, dtype=np.float64)
    np.add.at(degw, src, w)
    return own, best_ext, best_part, degw


def _refine(
    g: CSRGraph,
    labels: np.ndarray,
    k: int,
    cap: float,
    passes: int,
) -> np.ndarray:
    n = g.n
    vw = g.vweights.astype(np.float64)
    labels = labels.astype(np.int64).copy()
    for _ in range(passes):
        part_weight = np.bincount(labels, weights=vw, minlength=k)
        own, best_ext, best_part, _ = _connectivity_tables(g, labels, k)
        gain = best_ext - own
        over = part_weight > cap
        # Candidates: positive gain moves, plus any vertex in an overweight
        # part (balance repair, even at zero/negative gain).
        cand = np.where((gain > 1e-12) | over[labels])[0]
        if cand.size == 0:
            break
        # Overweight escapes first (most negative pressure), then best gains.
        cand = cand[np.lexsort((-gain[cand], ~over[labels[cand]]))]
        moved = 0
        for v in cand:
            a = labels[v]
            b = best_part[v]
            if a == b:
                continue
            w_v = vw[v]
            if part_weight[b] + w_v > cap:
                if not over[a]:
                    continue
                # Balance repair: move to lightest part instead.
                b = int(np.argmin(part_weight))
                if b == a or part_weight[b] + w_v > cap:
                    continue
            if over[a] or gain[v] > 1e-12:
                labels[v] = b
                part_weight[a] -= w_v
                part_weight[b] += w_v
                over[a] = part_weight[a] > cap
                moved += 1
        if moved == 0:
            break
    return labels


# ---------------------------------------------------------------------------
# Driver
# ---------------------------------------------------------------------------


def partition_vertices(
    g: CSRGraph, k: int, opts: MultilevelOptions | None = None
) -> tuple[np.ndarray, PartitionStats]:
    """Balanced k-way vertex partition of ``g``; returns (labels, stats)."""
    opts = opts or MultilevelOptions()
    rng = np.random.default_rng(opts.seed)
    n = g.n
    if k <= 1:
        return np.zeros(n, dtype=np.int32), PartitionStats(0, n, 0.0, 1.0)
    total = float(g.vweights.sum())
    cap = (1.0 + opts.eps) * np.ceil(total / k)

    # --- coarsen ---
    graphs = [g]
    maps: list[np.ndarray] = []
    stop_n = max(opts.coarsen_until, opts.coarsen_k_factor * k)
    while graphs[-1].n > stop_n and len(graphs) <= opts.max_levels:
        cur = graphs[-1]
        match = _heavy_edge_matching(cur, rng, opts.match_rounds)
        coarse, cmap = _contract(cur, match)
        if coarse.n > 0.97 * cur.n:  # stalled
            break
        graphs.append(coarse)
        maps.append(cmap)

    # --- initial partition on the coarsest graph ---
    coarsest = graphs[-1]
    labels = _initial_partition(coarsest, k, cap, rng)
    labels = _refine(coarsest, labels, k, cap, opts.coarsest_refine_passes)

    # --- uncoarsen + refine ---
    for level in range(len(maps) - 1, -1, -1):
        labels = labels[maps[level]]
        labels = _refine(graphs[level], labels, k, cap, opts.refine_passes)

    labels = labels.astype(np.int32)
    stats = PartitionStats(
        levels=len(graphs),
        coarsest_n=coarsest.n,
        edgecut=edgecut(g, labels),
        balance=balance_factor(g, labels, k),
    )
    return labels, stats


def edgecut(g: CSRGraph, labels: np.ndarray) -> float:
    src = np.repeat(np.arange(g.n, dtype=np.int64), np.diff(g.indptr))
    cut = labels[src] != labels[g.indices]
    return float(g.eweights[cut].sum() / 2.0)  # both directions stored


def balance_factor(g: CSRGraph, labels: np.ndarray, k: int) -> float:
    pw = np.bincount(labels, weights=g.vweights.astype(np.float64), minlength=k)
    avg = g.vweights.sum() / k
    return float(pw.max() / avg) if avg > 0 else 1.0

"""Tenant-budgeted, cost-aware plan cache with lineage pinning + persistence.

PR 1's plan cache was a tenant-blind LRU: one flood of one-shot graphs from
any client evicted every other client's warm plans.  In a serving fleet the
cache is a shared resource with per-client quotas; this module is that
policy, factored out of ``PartitionService`` so it is independently
testable:

  * **Per-tenant byte budgets** — every entry is owned by the tenant whose
    request computed it; ``put`` enforces the owner's budget by evicting
    *that tenant's* entries only, so one tenant flooding the cache can
    never push out another tenant's warm plans (global ``max_entries`` /
    ``max_bytes`` backstops still apply, cost-scored across tenants).
  * **Cost-aware eviction** — victims are chosen by ascending
    ``score = compute_time_s / nbytes`` (seconds of recompute bought per
    byte held): a plan that is cheap to recompute but holds many bytes goes
    first, an expensive multilevel run on a big graph stays.  Ties (and the
    degenerate all-equal case) fall back to LRU order.
  * **Incremental-lineage pinning** — a churn stream repeatedly derives
    plans from one base plan (``ServicePlan.lineage`` names the base
    fingerprint); evicting the base breaks the stream with a KeyError even
    though every derived plan is cheap.  Bases referenced by cached derived
    plans are refcounted, and ``pin``/``unpin`` let the service mark a
    stream's base explicitly; pinned entries are evicted only when nothing
    unpinned remains (bounded memory still wins over a pin).
  * **Persistence** — ``save``/``load`` snapshot the cache contents (plans
    are plain dataclasses over numpy arrays, pickled with a format-version
    guard) so a restarted service starts warm instead of re-partitioning
    its whole working set.

Thread safety: every public method takes the internal lock; the lock is
reentrant so the ``PartitionService`` facade can compose calls under its
own critical sections without deadlocking.
"""
from __future__ import annotations

import dataclasses
import logging
import os
import pickle
import threading
from collections import OrderedDict
from typing import Iterable, Optional

__all__ = ["CacheEntry", "PlanCache", "TenantCacheStats",
           "PERSIST_MAGIC", "PERSIST_VERSION"]

# Public: the wire transport reuses this payload format for gossip frames.
PERSIST_MAGIC = "repro-plan-cache"
PERSIST_VERSION = 2
# Backward-compatible aliases (pre-transport name).
_PERSIST_MAGIC = PERSIST_MAGIC
_PERSIST_VERSION = PERSIST_VERSION

logger = logging.getLogger(__name__)


@dataclasses.dataclass
class TenantCacheStats:
    """Per-tenant counters exported into the ServiceMetrics snapshot."""

    hits: int = 0
    misses: int = 0
    evictions: int = 0
    entries: int = 0  # current
    bytes: int = 0  # current
    budget_bytes: Optional[int] = None


@dataclasses.dataclass
class CacheEntry:
    plan: object  # ServicePlan (kept untyped: no import cycle with the facade)
    tenant: str
    nbytes: int
    pinned: bool = False


class PlanCache:
    """Fingerprint-keyed plan cache with per-tenant byte budgets."""

    def __init__(
        self,
        max_entries: int = 64,
        max_bytes: int | None = None,
        tenant_budgets: dict[str, int] | None = None,
        default_tenant_budget: int | None = None,
    ) -> None:
        self.max_entries = max_entries
        self.max_bytes = max_bytes
        self.tenant_budgets = dict(tenant_budgets or {})
        self.default_tenant_budget = default_tenant_budget
        self._entries: OrderedDict[str, CacheEntry] = OrderedDict()  # LRU order
        self._lineage_refs: dict[str, int] = {}  # fingerprint -> #derived entries
        self._tenants: dict[str, TenantCacheStats] = {}
        self._evictions_total = 0
        self._total_bytes = 0  # running sum; O(1) per put/drop, not O(n)
        self._lock = threading.RLock()

    # -- bookkeeping helpers ----------------------------------------------

    def _tenant(self, tenant: str) -> TenantCacheStats:
        st = self._tenants.get(tenant)
        if st is None:
            st = TenantCacheStats(budget_bytes=self.budget_for(tenant))
            self._tenants[tenant] = st
        return st

    def budget_for(self, tenant: str) -> Optional[int]:
        return self.tenant_budgets.get(tenant, self.default_tenant_budget)

    def _is_pinned(self, fingerprint: str, entry: CacheEntry) -> bool:
        return entry.pinned or self._lineage_refs.get(fingerprint, 0) > 0

    @staticmethod
    def _score(entry: CacheEntry) -> float:
        # Seconds of recompute bought per byte held: evict the cheapest.
        return float(getattr(entry.plan, "compute_time_s", 0.0)) / max(entry.nbytes, 1)

    def _victim(self, candidates: Iterable[str]) -> Optional[str]:
        """Lowest-score candidate; pinned entries only if nothing else.
        Iteration follows LRU order, and strict ``<`` keeps the oldest of a
        score tie — the LRU fallback when every score is equal."""
        best = best_pinned = None
        best_s = best_pinned_s = float("inf")
        for fp in candidates:
            entry = self._entries[fp]
            s = self._score(entry)
            if self._is_pinned(fp, entry):
                if s < best_pinned_s:
                    best_pinned, best_pinned_s = fp, s
            elif s < best_s:
                best, best_s = fp, s
        return best if best is not None else best_pinned

    def _drop(self, fingerprint: str, *, evicted: bool) -> CacheEntry:
        entry = self._entries.pop(fingerprint)
        lineage = getattr(entry.plan, "lineage", None)
        if lineage is not None:
            refs = self._lineage_refs.get(lineage, 0) - 1
            if refs <= 0:
                self._lineage_refs.pop(lineage, None)
            else:
                self._lineage_refs[lineage] = refs
        st = self._tenant(entry.tenant)
        st.entries -= 1
        st.bytes -= entry.nbytes
        self._total_bytes -= entry.nbytes
        if evicted:
            st.evictions += 1
            self._evictions_total += 1
        return entry

    # -- core API ----------------------------------------------------------

    def get(self, fingerprint: str, tenant: str = "default") -> Optional[object]:
        """Warm probe: counts a hit (for ``tenant``) and refreshes recency.
        A miss is NOT counted here — in-flight coalescing means not every
        failed probe becomes a computation; the service calls
        :meth:`record_miss` when it actually schedules one."""
        with self._lock:
            entry = self._entries.get(fingerprint)
            if entry is None:
                return None
            self._entries.move_to_end(fingerprint)
            self._tenant(tenant).hits += 1
            return entry.plan

    def peek(self, fingerprint: str) -> Optional[object]:
        """Probe without touching recency or counters (for internal reads)."""
        with self._lock:
            entry = self._entries.get(fingerprint)
            return entry.plan if entry is not None else None

    def touch(self, fingerprint: str) -> bool:
        """Refresh recency without counting a hit (e.g. a churn update
        resolving its base plan is bookkeeping, not a request served)."""
        with self._lock:
            if fingerprint not in self._entries:
                return False
            self._entries.move_to_end(fingerprint)
            return True

    def record_miss(self, tenant: str = "default") -> None:
        with self._lock:
            self._tenant(tenant).misses += 1

    def put(self, plan, tenant: str = "default") -> int:
        """Insert ``plan`` owned by ``tenant``; returns the eviction count.

        Enforcement order: the owner's byte budget first (victims drawn from
        the owner's entries only — the isolation guarantee), then the global
        byte cap, then the global entry cap (both cost-scored across all
        tenants).  A plan larger than its owner's whole budget is not cached
        at all (counted as an eviction of itself): admitting it would just
        evict the tenant's entire working set for a plan that cannot stay.
        """
        fingerprint = plan.fingerprint
        nbytes = int(plan.nbytes())
        evictions = 0
        with self._lock:
            old = self._entries.get(fingerprint)
            owner = old.tenant if old is not None else tenant
            budget = self.budget_for(owner)
            if budget is not None and nbytes > budget:
                # Inadmissible replacement: keep an existing (still warm,
                # possibly pinned / lineage-anchoring) copy rather than
                # silently deleting the fingerprint; count the rejection as
                # an eviction only when there was nothing to keep.
                if old is None:
                    self._tenant(owner).evictions += 1
                    self._evictions_total += 1
                    return 1
                return 0
            if old is not None:
                dropped = self._drop(fingerprint, evicted=False)
                entry = CacheEntry(plan, tenant=dropped.tenant, nbytes=nbytes,
                                   pinned=dropped.pinned)
            else:
                entry = CacheEntry(plan, tenant=tenant, nbytes=nbytes)
            self._entries[fingerprint] = entry
            lineage = getattr(plan, "lineage", None)
            if lineage is not None:
                self._lineage_refs[lineage] = self._lineage_refs.get(lineage, 0) + 1
            st = self._tenant(entry.tenant)
            st.entries += 1
            st.bytes += nbytes
            self._total_bytes += nbytes

            if budget is not None:
                while st.bytes > budget and st.entries > 1:
                    own = [fp for fp, e in self._entries.items()
                           if e.tenant == entry.tenant and fp != fingerprint]
                    victim = self._victim(own)
                    if victim is None:
                        break
                    self._drop(victim, evicted=True)
                    evictions += 1
            if self.max_bytes is not None:
                while self._total_bytes > self.max_bytes and len(self._entries) > 1:
                    victim = self._victim(
                        fp for fp in self._entries if fp != fingerprint)
                    if victim is None:
                        break
                    self._drop(victim, evicted=True)
                    evictions += 1
            while len(self._entries) > self.max_entries:
                victim = self._victim(
                    fp for fp in self._entries if fp != fingerprint)
                if victim is None:
                    break
                self._drop(victim, evicted=True)
                evictions += 1
        return evictions

    def remove(self, fingerprint: str) -> bool:
        with self._lock:
            if fingerprint not in self._entries:
                return False
            self._drop(fingerprint, evicted=False)
            return True

    # -- pinning -----------------------------------------------------------

    def pin(self, fingerprint: str) -> bool:
        """Mark a churn stream's base plan: survives eviction while anything
        unpinned remains.  True iff the fingerprint is cached."""
        with self._lock:
            entry = self._entries.get(fingerprint)
            if entry is None:
                return False
            entry.pinned = True
            return True

    def unpin(self, fingerprint: str) -> bool:
        with self._lock:
            entry = self._entries.get(fingerprint)
            if entry is None:
                return False
            entry.pinned = False
            return True

    # -- introspection -----------------------------------------------------

    def __len__(self) -> int:
        with self._lock:
            return len(self._entries)

    def __contains__(self, fingerprint: str) -> bool:
        with self._lock:
            return fingerprint in self._entries

    def total_bytes(self) -> int:
        with self._lock:
            return self._total_bytes

    @property
    def evictions_total(self) -> int:
        return self._evictions_total

    def fingerprints(self) -> list[str]:
        with self._lock:
            return list(self._entries)

    def pinned_fingerprints(self) -> list[str]:
        """Explicitly-pinned entries (LRU order), e.g. for a service that
        must adopt restored pins into its own bounded anchor tracking."""
        with self._lock:
            return [fp for fp, e in self._entries.items() if e.pinned]

    def tenant_stats(self) -> dict[str, TenantCacheStats]:
        """Deep-copied per-tenant counters (budget refreshed on export)."""
        with self._lock:
            out = {}
            for tenant, st in self._tenants.items():
                out[tenant] = dataclasses.replace(
                    st, budget_bytes=self.budget_for(tenant))
            return out

    # -- persistence -------------------------------------------------------

    def snapshot_payload(self, fingerprints: Iterable[str] | None = None) -> dict:
        """The persistence payload — magic + version header over
        ``(fingerprint, tenant, pinned, plan)`` entries.  ``save`` pickles
        exactly this to disk; the replica transport ships the same envelope
        as gossip frames, so both paths are validated by
        :meth:`admit_payload`.  ``fingerprints`` restricts the snapshot to
        a subset (unknown ones are skipped)."""
        with self._lock:
            if fingerprints is None:
                items = list(self._entries.items())
            else:
                items = [(fp, self._entries[fp]) for fp in fingerprints
                         if fp in self._entries]
            return {
                "magic": PERSIST_MAGIC,
                "version": PERSIST_VERSION,
                "entries": [(fp, e.tenant, e.pinned, e.plan)
                            for fp, e in items],
            }

    def admit_payload(self, payload: object, source: str = "payload") -> int:
        """Validate and admit a :meth:`snapshot_payload` envelope; returns
        the number of entries still resident afterwards.  A wrong magic or
        version fails loudly — that is a foreign or incompatible payload,
        not a corrupt one."""
        if (not isinstance(payload, dict)
                or payload.get("magic") != PERSIST_MAGIC):
            raise ValueError(f"{source} is not a plan-cache snapshot")
        if payload.get("version") != PERSIST_VERSION:
            raise ValueError(
                f"plan-cache snapshot version {payload.get('version')!r} "
                f"not supported (expected {PERSIST_VERSION})")
        with self._lock:
            for fp, tenant, pinned, plan in payload["entries"]:
                self.put(plan, tenant=tenant)
                if pinned and fp in self._entries:
                    self._entries[fp].pinned = True
            # Count at the end: a later restore can evict an earlier one
            # when the snapshot came from a bigger cache.
            return sum(
                1 for fp, *_ in payload["entries"] if fp in self._entries
            )

    def save(self, path: str) -> int:
        """Snapshot cache contents to ``path``; returns the entry count.
        Plans are dataclasses over numpy arrays — pickled with a magic +
        version header so a stale or foreign file fails loudly on load.
        The write is atomic (temp file + ``os.replace``): a crash mid-save
        leaves the previous snapshot intact, never a truncated one."""
        payload = self.snapshot_payload()
        tmp = f"{path}.tmp.{os.getpid()}"
        try:
            with open(tmp, "wb") as f:
                pickle.dump(payload, f, protocol=pickle.HIGHEST_PROTOCOL)
                f.flush()
                os.fsync(f.fileno())
            os.replace(tmp, path)
        except BaseException:
            try:
                os.unlink(tmp)
            except OSError:
                pass
            raise
        return len(payload["entries"])

    def load(self, path: str) -> int:
        """Restore a :meth:`save` snapshot; returns the number of entries
        admitted (budgets are enforced on the way in, so a snapshot from a
        bigger cache loads its best-scored suffix).  Restored entries count
        as neither hits nor misses.

        A truncated or corrupt pickle — the signature of a crash while an
        older non-atomic writer was saving, or of disk damage — is treated
        as a cold start: log a warning and return 0.  A readable payload
        with the wrong magic/version still raises ``ValueError`` (that file
        was never ours, or needs a migration; silently ignoring it would
        mask a real configuration error)."""
        try:
            with open(path, "rb") as f:
                payload = pickle.load(f)
        except (pickle.UnpicklingError, EOFError, AttributeError,
                IndexError, MemoryError) as e:
            logger.warning(
                "plan-cache snapshot %r is truncated or corrupt (%r); "
                "starting cold", path, e)
            return 0
        return self.admit_payload(payload, source=repr(path))

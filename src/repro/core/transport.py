"""Cross-process replica transport: socket-backed ``PartitionService``.

``ReplicaGroup`` (core/replica.py) was built so a replica is anything that
duck-types the small service surface its driver loop touches.  This module
provides that surface over a real network boundary:

* **Frame protocol** — length-prefixed (4-byte big-endian) pickled frames
  over local TCP.  The first frame each way is a handshake carrying a
  protocol magic + version (:data:`WIRE_MAGIC` / :data:`WIRE_VERSION`); a
  mismatch fails loudly before any RPC flows.  Plan payloads reuse the
  ``plan_cache`` persistence format — gossip frames carry the exact
  ``{"magic", "version", "entries"}`` envelope :meth:`PlanCache.save`
  writes to disk, validated by the same code on the way in.
* **Per-RPC deadlines** — every call carries a deadline; the socket is
  armed with it on both send and receive, so a stalled (``SIGSTOP``-ed)
  worker surfaces as :class:`DeadlineExceeded` instead of a hang.  A
  deadline miss also drops the connection: the late reply would otherwise
  desync the request/response stream.
* **Connection supervisor** — :class:`ReplicaConnection` reconnects lazily
  with capped exponential backoff.  A severed or reset connection is
  re-established on the next call; while the backoff window is open, calls
  fail fast with :class:`WireError` (which the group treats as failover).
* **Server** — :class:`PlanServer` hosts one ``PartitionService`` behind an
  accept loop (one handler thread per connection; the ticket table is
  server-global, so a reconnecting client can keep polling tickets it
  submitted on a previous connection — a severed socket loses no work).
* **Adapter** — :class:`RemoteReplica` implements the replica surface the
  group uses (``submit`` / ``update_async`` / ``plan_cache`` peek+put /
  ``metrics`` / ``stats`` / ``close``) plus the wire-only extensions:
  rate-limited ``heartbeat()`` pings (the group only credits a beat when
  the worker answers), ``gossip_*`` for pairwise plan-store anti-entropy,
  and process-level fault probes (``sigkill`` / ``sigstop`` / a mid-frame
  socket sever) for the chaos bench.

The subprocess entrypoint that pairs with this lives in
``repro.launch.replica_worker`` (core must not depend on launch).
"""
from __future__ import annotations

import os
import pickle
import signal
import socket
import struct
import threading
import time
from typing import Any, Callable, Optional

from .partition_service import PartitionService, ServiceStats
from .plan_cache import PERSIST_MAGIC, PERSIST_VERSION
from .plan_scheduler import ServiceClosedError, ServiceMetrics, _latency_summary

__all__ = [
    "WIRE_MAGIC",
    "WIRE_VERSION",
    "WireError",
    "ProtocolError",
    "DeadlineExceeded",
    "send_frame",
    "recv_frame",
    "ReplicaConnection",
    "PlanServer",
    "RemoteReplica",
]

WIRE_MAGIC = "repro-plan-wire"
WIRE_VERSION = 1

_LEN = struct.Struct(">I")
MAX_FRAME_BYTES = 1 << 30


class WireError(ConnectionError):
    """Transport-level failure: connect refused, reset, or backoff open."""


class ProtocolError(WireError):
    """Malformed traffic: bad handshake, truncated frame, undecodable body."""


class DeadlineExceeded(TimeoutError):
    """A per-RPC deadline expired before the peer answered."""


# ---------------------------------------------------------------------------
# Frame codec
# ---------------------------------------------------------------------------


def send_frame(sock: socket.socket, obj: Any, deadline_s: float | None = None) -> None:
    """Pickle ``obj`` and write it as one length-prefixed frame."""
    body = pickle.dumps(obj, protocol=pickle.HIGHEST_PROTOCOL)
    if len(body) > MAX_FRAME_BYTES:
        raise ProtocolError(f"frame too large: {len(body)} bytes")
    sock.settimeout(deadline_s)
    try:
        sock.sendall(_LEN.pack(len(body)) + body)
    except socket.timeout as e:
        raise DeadlineExceeded(f"send deadline ({deadline_s}s) expired") from e


def _recv_exact(sock: socket.socket, n: int, what: str,
                deadline_s: float | None) -> bytes:
    buf = bytearray()
    while len(buf) < n:
        try:
            chunk = sock.recv(n - len(buf))
        except socket.timeout as e:
            raise DeadlineExceeded(
                f"recv deadline ({deadline_s}s) expired reading {what}") from e
        if not chunk:
            raise ProtocolError(
                f"connection closed mid-{what} ({len(buf)}/{n} bytes)")
        buf += chunk
    return bytes(buf)


def recv_frame(sock: socket.socket, deadline_s: float | None = None) -> Any:
    """Read one length-prefixed frame and unpickle it.

    A short read (peer died or severed the socket mid-frame) raises
    :class:`ProtocolError`; an expired deadline raises
    :class:`DeadlineExceeded`.
    """
    sock.settimeout(deadline_s)
    (n,) = _LEN.unpack(_recv_exact(sock, _LEN.size, "header", deadline_s))
    if n > MAX_FRAME_BYTES:
        raise ProtocolError(f"frame length {n} exceeds cap {MAX_FRAME_BYTES}")
    body = _recv_exact(sock, n, "frame", deadline_s)
    try:
        return pickle.loads(body)
    except Exception as e:  # corrupt body is a protocol failure, not a crash
        raise ProtocolError(f"undecodable frame body: {e!r}") from e


def _handshake_frame() -> dict:
    return {"magic": WIRE_MAGIC, "version": WIRE_VERSION, "pid": os.getpid()}


def _check_handshake(frame: Any, who: str) -> dict:
    if not isinstance(frame, dict) or frame.get("magic") != WIRE_MAGIC:
        raise ProtocolError(f"{who} did not speak the plan-wire protocol")
    if frame.get("version") != WIRE_VERSION:
        raise ProtocolError(
            f"{who} protocol version {frame.get('version')!r} "
            f"not supported (expected {WIRE_VERSION})")
    return frame


# ---------------------------------------------------------------------------
# Client connection supervisor
# ---------------------------------------------------------------------------


class ReplicaConnection:
    """One client connection to a :class:`PlanServer`, with supervision.

    Calls are serialized under a lock (one in-flight RPC per connection).
    The socket is (re)established lazily: after a failure, reconnect
    attempts are paced by capped exponential backoff — inside the backoff
    window calls raise :class:`WireError` immediately, which the replica
    group treats like any other lane failure.
    """

    def __init__(
        self,
        address: tuple[str, int],
        *,
        connect_timeout_s: float = 5.0,
        default_deadline_s: float = 10.0,
        reconnect_base_s: float = 0.05,
        reconnect_cap_s: float = 2.0,
        clock: Callable[[], float] = time.monotonic,
    ) -> None:
        self.address = (address[0], int(address[1]))
        self.connect_timeout_s = connect_timeout_s
        self.default_deadline_s = default_deadline_s
        self.reconnect_base_s = reconnect_base_s
        self.reconnect_cap_s = reconnect_cap_s
        self._clock = clock
        self._lock = threading.RLock()
        self._sock: Optional[socket.socket] = None
        self._next_id = 1
        self._fails = 0
        self._next_attempt_t = 0.0
        self._ever_connected = False
        self.server_pid: Optional[int] = None
        self.reconnects = 0

    @property
    def connected(self) -> bool:
        return self._sock is not None

    def _drop_locked(self) -> None:
        if self._sock is not None:
            try:
                self._sock.close()
            except OSError:
                pass
            self._sock = None

    def _connect_locked(self) -> None:
        now = self._clock()
        if self._fails > 0 and now < self._next_attempt_t:
            raise WireError(
                f"reconnect to {self.address} backing off another "
                f"{self._next_attempt_t - now:.3f}s (attempt {self._fails})")
        try:
            sock = socket.create_connection(
                self.address, timeout=self.connect_timeout_s)
            sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
            send_frame(sock, _handshake_frame(), self.connect_timeout_s)
            hello = _check_handshake(
                recv_frame(sock, self.connect_timeout_s), "server")
        except (OSError, WireError, DeadlineExceeded) as e:
            self._fails += 1
            delay = min(self.reconnect_cap_s,
                        self.reconnect_base_s * (2.0 ** (self._fails - 1)))
            self._next_attempt_t = now + delay
            raise WireError(f"connect to {self.address} failed: {e}") from e
        if self._ever_connected or self._fails > 0:
            self.reconnects += 1  # re-established, whether severed or refused
        self._ever_connected = True
        self._fails = 0
        self.server_pid = hello.get("pid")
        self._sock = sock

    def call(self, op: str, args: dict | None = None,
             deadline_s: float | None = None) -> Any:
        """One RPC round trip; returns the response value or raises the
        server-side exception (transported pickled)."""
        deadline = deadline_s if deadline_s is not None else self.default_deadline_s
        with self._lock:
            if self._sock is None:
                self._connect_locked()
            rid = self._next_id
            self._next_id += 1
            try:
                send_frame(self._sock, {"id": rid, "op": op,
                                        "args": args or {},
                                        "deadline_s": deadline}, deadline)
                resp = recv_frame(self._sock, deadline)
            except DeadlineExceeded:
                # The reply may still arrive later and would desync the
                # stream; a deadline miss costs the connection.
                self._drop_locked()
                raise
            except (ProtocolError, OSError) as e:
                self._drop_locked()
                raise WireError(f"rpc {op!r} to {self.address} failed: {e}") from e
            if not isinstance(resp, dict) or resp.get("id") != rid:
                self._drop_locked()
                raise ProtocolError(f"rpc id mismatch answering {op!r}")
            if not resp.get("ok"):
                err = resp.get("error")
                if isinstance(err, BaseException):
                    raise err
                raise WireError(f"rpc {op!r} failed remotely: {err}")
            return resp.get("value")

    def sever(self, mid_frame: bool = True) -> None:
        """Fault probe: cut the connection, optionally mid-frame.

        ``mid_frame=True`` writes a length prefix promising bytes that never
        come, so the *server* exercises its truncated-read recovery path too
        (handler drops the connection; the accept loop keeps serving)."""
        with self._lock:
            if self._sock is None:
                return
            if mid_frame:
                try:
                    self._sock.settimeout(0.5)
                    self._sock.sendall(_LEN.pack(1 << 20) + b"severed")
                except OSError:
                    pass
            self._drop_locked()

    def close(self) -> None:
        with self._lock:
            self._drop_locked()


# ---------------------------------------------------------------------------
# Server
# ---------------------------------------------------------------------------


class PlanServer:
    """Hosts one ``PartitionService`` behind the frame protocol.

    One handler thread per accepted connection; the ticket table is shared
    across connections so a client that reconnects (severed socket, process
    restart on the client side) can keep polling tickets it already
    submitted.  A malformed or truncated frame drops that connection only.
    """

    def __init__(self, service: PartitionService, host: str = "127.0.0.1",
                 port: int = 0) -> None:
        self.service = service
        self._listener = socket.create_server((host, port))
        self.address: tuple[str, int] = self._listener.getsockname()[:2]
        self._tickets: dict[int, Any] = {}
        self._next_tid = 1
        self._lock = threading.Lock()
        self._shutdown = threading.Event()
        self._accept_thread: Optional[threading.Thread] = None

    @property
    def port(self) -> int:
        return self.address[1]

    # -- lifecycle ----------------------------------------------------------

    def start(self) -> "PlanServer":
        """Run the accept loop on a daemon thread (in-process use/tests)."""
        self._accept_thread = threading.Thread(
            target=self.serve_forever, name="plan-server-accept", daemon=True)
        self._accept_thread.start()
        return self

    def serve_forever(self) -> None:
        """Blocking accept loop; returns after :meth:`shutdown`."""
        self._listener.settimeout(0.2)
        try:
            while not self._shutdown.is_set():
                try:
                    conn, _addr = self._listener.accept()
                except socket.timeout:
                    continue
                except OSError:
                    break
                threading.Thread(target=self._handle, args=(conn,),
                                 name="plan-server-conn", daemon=True).start()
        finally:
            try:
                self._listener.close()
            except OSError:
                pass

    def shutdown(self) -> None:
        self._shutdown.set()

    # -- per-connection handler --------------------------------------------

    def _handle(self, conn: socket.socket) -> None:
        try:
            conn.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
            _check_handshake(recv_frame(conn, 10.0), "client")
            send_frame(conn, _handshake_frame(), 10.0)
            while not self._shutdown.is_set():
                try:
                    msg = recv_frame(conn, None)
                except (ProtocolError, DeadlineExceeded):
                    return  # truncated/corrupt/idle-severed: drop this conn
                resp: dict = {"id": msg.get("id") if isinstance(msg, dict) else None}
                try:
                    if not isinstance(msg, dict):
                        raise ProtocolError("rpc frame is not a dict")
                    resp["value"] = self._dispatch(msg.get("op"),
                                                   msg.get("args") or {})
                    resp["ok"] = True
                except BaseException as e:
                    resp["ok"] = False
                    resp["error"] = e
                try:
                    send_frame(conn, resp, 10.0)
                except ProtocolError:
                    return
                except Exception:
                    # Unpicklable error/value: still answer, degraded.
                    send_frame(conn, {"id": resp["id"], "ok": False,
                                      "error": WireError(
                                          f"unserializable response for "
                                          f"{msg.get('op')!r}")}, 10.0)
        except (OSError, WireError, DeadlineExceeded):
            pass
        finally:
            try:
                conn.close()
            except OSError:
                pass

    # -- ops ----------------------------------------------------------------

    def _register(self, ticket: Any) -> int:
        with self._lock:
            tid = self._next_tid
            self._next_tid += 1
            self._tickets[tid] = ticket
            return tid

    def _dispatch(self, op: str, args: dict) -> Any:
        svc = self.service
        if op == "ping":
            return {"pid": os.getpid(), "closed": svc.closed}
        if op == "submit":
            # An admission rejection raised here leaves _dispatch as a
            # typed error frame ({"ok": False, "error": e}) — the client
            # re-raises it with retry_after_s intact; the connection stays
            # up (rejection is an answer, not a transport failure).
            ticket = svc.submit(
                args["edges"], args["k"], method=args.get("method", "ep"),
                opts=args.get("opts"), seed=args.get("seed", 0),
                pad=args.get("pad", 128), coo=args.get("coo"),
                tenant=args.get("tenant", "default"),
                priority=args.get("priority", 0),
                timeout=args.get("timeout"))
            return {"ticket": self._register(ticket),
                    "cache_hit": ticket.cache_hit}
        if op == "update":
            ticket = svc.update_async(
                args["base_fingerprint"], args["k"],
                insert_u=args.get("insert_u"), insert_v=args.get("insert_v"),
                delete_ids=args.get("delete_ids"),
                method=args.get("method", "ep"), opts=args.get("opts"),
                seed=args.get("seed", 0), pad=args.get("pad", 128),
                tenant=args.get("tenant", "default"),
                priority=args.get("priority", 0),
                timeout=args.get("timeout"))
            return {"ticket": self._register(ticket),
                    "cache_hit": ticket.cache_hit}
        if op == "poll":
            tid = args["ticket"]
            with self._lock:
                ticket = self._tickets.get(tid)
            if ticket is None:
                raise WireError(f"unknown ticket {tid}")
            if not ticket.done():
                return {"done": False}
            with self._lock:
                self._tickets.pop(tid, None)
            try:
                plan = ticket.result(0)
            except BaseException as e:
                return {"done": True, "ok": False, "error": e}
            return {"done": True, "ok": True, "plan": plan,
                    "cache_hit": ticket.cache_hit}
        if op == "cancel":
            with self._lock:
                ticket = self._tickets.pop(args["ticket"], None)
            return {"cancelled": bool(ticket.cancel()) if ticket is not None
                    else False}
        if op == "fingerprints":
            return svc.plan_cache.fingerprints()
        if op == "gossip_pull":
            return svc.plan_cache.snapshot_payload(args.get("fingerprints"))
        if op == "gossip_push":
            return {"admitted": svc.plan_cache.admit_payload(
                args["payload"], source="gossip frame")}
        if op == "metrics":
            return svc.metrics()
        if op == "stats":
            return svc.stats
        if op == "default_opts":
            return svc.default_opts
        if op == "close":
            svc.close()
            self.shutdown()
            return {"closed": True}
        raise WireError(f"unknown op {op!r}")


# ---------------------------------------------------------------------------
# Client-side ticket + adapter
# ---------------------------------------------------------------------------


class _RemoteTicket:
    """``PlanTicket``-shaped client future, resolved by polling the worker.

    A broken connection resolves the ticket with ``ServiceClosedError`` —
    exactly what a drained local queue raises — so the group driver's
    existing failover path handles a dead worker without a special case.
    A *deadline* miss (stalled worker) leaves the ticket pending: the
    heartbeat machinery, not the ticket, decides that replica is suspect.
    """

    def __init__(self, conn: ReplicaConnection, tid: int,
                 poll_deadline_s: float) -> None:
        self._conn = conn
        self._tid = tid
        self._poll_deadline_s = poll_deadline_s
        self._done = False
        self._value: Any = None
        self._error: Optional[BaseException] = None
        self.cache_hit = False
        self.cancelled = False

    def done(self) -> bool:
        if self._done:
            return True
        try:
            v = self._conn.call("poll", {"ticket": self._tid},
                                deadline_s=self._poll_deadline_s)
        except DeadlineExceeded:
            return False
        except (WireError, ConnectionError, OSError) as e:
            self._error = ServiceClosedError(
                f"replica connection lost polling ticket {self._tid}: {e}")
            self._done = True
            return True
        if v["done"]:
            if v["ok"]:
                self._value = v["plan"]
                self.cache_hit = bool(v.get("cache_hit", self.cache_hit))
            else:
                self._error = v["error"]
            self._done = True
        return self._done

    def cancel(self, buffer=None) -> bool:
        self.cancelled = True
        try:
            v = self._conn.call("cancel", {"ticket": self._tid},
                                deadline_s=self._poll_deadline_s)
            return bool(v.get("cancelled"))
        except (WireError, ConnectionError, OSError, DeadlineExceeded):
            return False

    def result(self, timeout: float | None = None) -> Any:
        deadline = None if timeout is None else time.monotonic() + timeout
        while not self.done():
            if deadline is not None and time.monotonic() >= deadline:
                raise TimeoutError("partition not ready")
            time.sleep(0.002)
        if self._error is not None:
            raise self._error
        return self._value


class _RemoteCacheView:
    """``plan_cache``-shaped peek/put over the gossip RPCs, so the group's
    update path (seed the base plan into whichever replica computes) works
    unchanged against a remote worker."""

    def __init__(self, replica: "RemoteReplica") -> None:
        self._replica = replica

    def peek(self, fingerprint: str):
        plans = self._replica.gossip_pull([fingerprint])
        for fp, _tenant, _pinned, plan in plans:
            if fp == fingerprint:
                return plan
        return None

    def put(self, plan, tenant: str = "default") -> None:
        self._replica.gossip_push([(plan.fingerprint, tenant, False, plan)])


class _RemoteSchedulerStub:
    """Accepts the ``pre_job_hook`` assignment the group makes when a
    FaultInjector is attached.  The hook cannot cross the process boundary
    — worker-side stalls are configured at spawn time
    (``replica_worker --stall``) — so the assignment is kept but unused."""

    def __init__(self) -> None:
        self.pre_job_hook: Optional[Callable[[Any], None]] = None


def _empty_metrics() -> ServiceMetrics:
    return ServiceMetrics(
        queue_depth=0, workers=0, busy_workers=0, utilization=0.0,
        executor="remote", jobs_completed=0, jobs_failed=0,
        cancelled_queued=0, cancelled_inflight=0, coalesced=0,
        latency_s=_latency_summary([]), queue_wait_s=_latency_summary([]),
        tenants={})


_UNSET = object()


class RemoteReplica:
    """Client adapter: one socket-backed replica worker process.

    Duck-types the surface ``ReplicaGroup._Replica`` bookkeeping touches on
    a local ``PartitionService`` — hand a list of these to
    ``ReplicaGroup(replicas=[...])`` and failover, hedging, health, and
    stale-serve run unchanged over the wire.  ``metrics()``/``stats`` on an
    unreachable worker degrade to empty snapshots rather than raising, so
    group aggregation survives a dead member.
    """

    def __init__(
        self,
        address: tuple[str, int],
        *,
        process=None,
        pid: Optional[int] = None,
        rpc_deadline_s: float = 10.0,
        poll_deadline_s: float = 1.0,
        heartbeat_deadline_s: float = 0.25,
        heartbeat_interval_s: float = 0.05,
        connect_timeout_s: float = 5.0,
        reconnect_base_s: float = 0.05,
        reconnect_cap_s: float = 2.0,
    ) -> None:
        self._conn = ReplicaConnection(
            address, connect_timeout_s=connect_timeout_s,
            default_deadline_s=rpc_deadline_s,
            reconnect_base_s=reconnect_base_s, reconnect_cap_s=reconnect_cap_s)
        self.process = process
        self._pid = pid
        self.rpc_deadline_s = rpc_deadline_s
        self.poll_deadline_s = poll_deadline_s
        self.heartbeat_deadline_s = heartbeat_deadline_s
        self.heartbeat_interval_s = heartbeat_interval_s
        self._closed = False
        self._default_opts: Any = _UNSET
        self._hb_lock = threading.Lock()
        self._hb_t = -1e18
        self._hb_ok = False
        self.scheduler = _RemoteSchedulerStub()
        self.plan_cache = _RemoteCacheView(self)

    # -- identity -----------------------------------------------------------

    @property
    def address(self) -> tuple[str, int]:
        return self._conn.address

    @property
    def pid(self) -> Optional[int]:
        if self._pid is not None:
            return self._pid
        if self.process is not None:
            return self.process.pid
        return self._conn.server_pid

    @property
    def closed(self) -> bool:
        return self._closed

    @property
    def default_opts(self):
        if self._default_opts is _UNSET:
            try:
                self._default_opts = self._conn.call(
                    "default_opts", deadline_s=self.rpc_deadline_s)
            except (WireError, ConnectionError, OSError, DeadlineExceeded):
                return None
        return self._default_opts

    # -- service surface ----------------------------------------------------

    def submit(self, edges, k, method="ep", opts=None, seed=0, pad=128,
               coo=None, buffer=None, tenant="default", priority=0,
               timeout=None) -> _RemoteTicket:
        v = self._conn.call("submit", {
            "edges": edges, "k": k, "method": method, "opts": opts,
            "seed": seed, "pad": pad, "coo": coo, "tenant": tenant,
            "priority": priority, "timeout": timeout},
            deadline_s=self.rpc_deadline_s)
        ticket = _RemoteTicket(self._conn, v["ticket"], self.poll_deadline_s)
        ticket.cache_hit = bool(v["cache_hit"])
        return ticket

    def update_async(self, base_fingerprint, k, insert_u=None, insert_v=None,
                     delete_ids=None, method="ep", opts=None, seed=0, pad=128,
                     buffer=None, tenant="default", priority=0,
                     timeout=None) -> _RemoteTicket:
        v = self._conn.call("update", {
            "base_fingerprint": base_fingerprint, "k": k,
            "insert_u": insert_u, "insert_v": insert_v,
            "delete_ids": delete_ids, "method": method, "opts": opts,
            "seed": seed, "pad": pad, "tenant": tenant,
            "priority": priority, "timeout": timeout},
            deadline_s=self.rpc_deadline_s)
        ticket = _RemoteTicket(self._conn, v["ticket"], self.poll_deadline_s)
        ticket.cache_hit = bool(v["cache_hit"])
        return ticket

    def metrics(self) -> ServiceMetrics:
        try:
            return self._conn.call("metrics", deadline_s=self.rpc_deadline_s)
        except (WireError, ConnectionError, OSError, DeadlineExceeded):
            return _empty_metrics()

    @property
    def stats(self) -> ServiceStats:
        try:
            return self._conn.call("stats", deadline_s=self.rpc_deadline_s)
        except (WireError, ConnectionError, OSError, DeadlineExceeded):
            return ServiceStats()

    # -- wire-only surface --------------------------------------------------

    def heartbeat(self) -> bool:
        """Rate-limited liveness ping; True iff the worker answered.

        The group credits a beat only on True, so heartbeats genuinely
        travel over the wire: a ``SIGKILL``-ed worker fails the ping
        (connect refused), a ``SIGSTOP``-ed one times out the short
        deadline.  Between pings the last outcome is returned, bounding how
        long the group lock can be held on a stalled worker.
        """
        with self._hb_lock:
            now = time.monotonic()
            if now - self._hb_t < self.heartbeat_interval_s:
                return self._hb_ok
            self._hb_t = now
            try:
                self._conn.call("ping", deadline_s=self.heartbeat_deadline_s)
                self._hb_ok = True
            except (WireError, ConnectionError, OSError, DeadlineExceeded):
                self._hb_ok = False
            return self._hb_ok

    def gossip_fingerprints(self) -> list[str]:
        return list(self._conn.call("fingerprints",
                                    deadline_s=self.rpc_deadline_s))

    def gossip_pull(self, fingerprints: list[str]) -> list[tuple]:
        """Pull the named plans as persistence-format entries."""
        if not fingerprints:
            return []
        payload = self._conn.call("gossip_pull",
                                  {"fingerprints": list(fingerprints)},
                                  deadline_s=self.rpc_deadline_s)
        if (not isinstance(payload, dict)
                or payload.get("magic") != PERSIST_MAGIC
                or payload.get("version") != PERSIST_VERSION):
            raise ProtocolError("gossip frame is not a plan-cache payload")
        return list(payload["entries"])

    def gossip_push(self, entries: list[tuple]) -> int:
        """Push persistence-format ``(fp, tenant, pinned, plan)`` entries."""
        if not entries:
            return 0
        payload = {"magic": PERSIST_MAGIC, "version": PERSIST_VERSION,
                   "entries": list(entries)}
        v = self._conn.call("gossip_push", {"payload": payload},
                            deadline_s=self.rpc_deadline_s)
        return int(v.get("admitted", 0))

    # -- fault probes -------------------------------------------------------

    def sigkill(self) -> None:
        """Process probe: ``kill -9`` the worker (no cleanup, no goodbye)."""
        pid = self.pid
        if pid is not None:
            os.kill(pid, signal.SIGKILL)

    def sigstop(self) -> None:
        """Process probe: pause the worker; it holds sockets but answers
        nothing, so only the per-RPC deadlines reveal it."""
        pid = self.pid
        if pid is not None:
            os.kill(pid, signal.SIGSTOP)

    def sigcont(self) -> None:
        pid = self.pid
        if pid is not None:
            os.kill(pid, signal.SIGCONT)

    def sever_connection(self, mid_frame: bool = True) -> None:
        """Network probe: cut this client's socket, by default mid-frame so
        the server side exercises truncated-read recovery too."""
        self._conn.sever(mid_frame=mid_frame)

    # -- lifecycle ----------------------------------------------------------

    def close(self, timeout_s: float = 5.0) -> None:
        """Graceful remote close, then reap the worker process (SIGKILL
        fallback covers workers that are stopped or already gone)."""
        if self._closed:
            return
        self._closed = True
        try:
            self._conn.call("close", deadline_s=2.0)
        except (WireError, ConnectionError, OSError, DeadlineExceeded):
            pass
        self._conn.close()
        proc = self.process
        if proc is not None and proc.poll() is None:
            try:
                proc.wait(timeout=timeout_s)
            except Exception:
                try:
                    os.kill(proc.pid, signal.SIGKILL)
                    proc.wait(timeout=timeout_s)
                except Exception:
                    pass

    def __enter__(self) -> "RemoteReplica":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

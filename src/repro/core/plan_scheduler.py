"""Priority worker pool for partitioning jobs — the scheduling half of §4.2.

The paper's argument is that the *runtime*, not just the preprocessing,
decides whether a good partition turns into cache efficiency: optimization
work must run off the request path, never block compute, and be cheap to
re-trigger.  PR 1's single ``_worker`` thread implemented the minimal form.
This module grows it into a multi-tenant scheduling subsystem:

  * **N-worker pool** — ``PlanScheduler(workers=N)`` drains one priority
    queue with N dispatchers.  ``executor="thread"`` runs jobs in-process
    (zero setup, shares memory; fine for a single worker or I/O-light
    loads).  ``executor="process"`` runs each job in a spawned worker
    process — partitioning is CPU-bound numpy and the GIL serializes
    threads, so real cold-plan parallelism needs processes.  Jobs must then
    be (module-level function, picklable args) pairs.
  * **Priorities** — ``submit(..., priority=p)``: higher runs first, FIFO
    within a class.  Re-submitting a queued key at a higher priority bumps
    it (re-queued at the tail of the new class).
  * **Cancellation** — ``cancel(ticket)`` drops queued work (the ticket
    fails with :class:`PlanCancelledError`); an in-flight job cannot be
    interrupted, so cancel *marks* the ticket (``ticket.cancelled``) and
    the result still lands in the cache — the work is salvaged, the caller
    stops waiting.
  * **Coalescing** — concurrent submits of one key share a single
    computation and one ticket (each extra submit is counted; cancellation
    of a shared ticket only detaches the canceller).
  * **Metrics** — :meth:`metrics_snapshot` exports a :class:`ServiceMetrics`:
    queue depth, worker utilization, completion/cancellation/coalesce
    counters, and latency histograms (queue wait + total submit→done).

The scheduler is deliberately ignorant of *what* a job computes: the
``PartitionService`` facade owns fingerprints, the plan cache, and stats,
and passes an ``on_done`` callback that runs (on the dispatcher thread)
before the ticket resolves — so cache population happens-before any waiter
wakes, exactly like the old single-worker loop.
"""
from __future__ import annotations

import dataclasses
import heapq
import threading
import time
from collections import deque
from typing import Any, Callable, Optional

from .admission import (
    AdmissionController,
    AdmissionRejectedError,
    DeadlineShedError,
)

__all__ = [
    "AdmissionRejectedError",
    "DeadlineShedError",
    "PlanCancelledError",
    "PlanScheduler",
    "PlanTicket",
    "ServiceClosedError",
    "ServiceMetrics",
]


class ServiceClosedError(RuntimeError):
    """The service/scheduler is closed: queued work is drained, new work
    is refused.  Subclasses RuntimeError so pre-existing callers matching
    ``RuntimeError("... closed")`` keep working."""


class PlanCancelledError(RuntimeError):
    """The request was cancelled before a worker picked it up."""


def _pin_worker_blas_env() -> None:
    """Pin numeric libraries to one thread each in ``os.environ`` BEFORE
    spawning pool workers: children inherit the environment, and BLAS
    libraries size their thread pools at load time — the env must be set in
    the parent, since anything executed *in* the child (even a pool
    initializer) runs after the child has already imported numpy while
    unpickling it.  The pool itself is the parallelism; P workers x N BLAS
    threads oversubscribes the cores and measurably slows every job.
    ``setdefault`` keeps an operator's explicit setting."""
    import os

    for var in ("OMP_NUM_THREADS", "OPENBLAS_NUM_THREADS",
                "MKL_NUM_THREADS", "NUMEXPR_NUM_THREADS"):
        os.environ.setdefault(var, "1")


# Log-2 latency buckets for the exported histograms (seconds).
_BUCKET_EDGES_S = tuple(2.0**e for e in range(-10, 5))  # ~1 ms .. 16 s


def _latency_summary(samples: list[float]) -> dict:
    """{count, mean, p50, p90, p99, max, histogram} over latency seconds."""
    if not samples:
        return {"count": 0, "mean": 0.0, "p50": 0.0, "p90": 0.0, "p99": 0.0,
                "max": 0.0, "histogram": {}}
    xs = sorted(samples)
    n = len(xs)

    def pct(p: float) -> float:
        return xs[min(n - 1, int(p * n))]

    hist: dict[str, int] = {}
    for x in xs:
        for edge in _BUCKET_EDGES_S:
            if x < edge:
                label = f"<{edge * 1e3:g}ms" if edge < 1 else f"<{edge:g}s"
                break
        else:
            label = f">={_BUCKET_EDGES_S[-1]:g}s"
        hist[label] = hist.get(label, 0) + 1
    return {
        "count": n,
        "mean": sum(xs) / n,
        "p50": pct(0.50),
        "p90": pct(0.90),
        "p99": pct(0.99),
        "max": xs[-1],
        "histogram": hist,
    }


@dataclasses.dataclass
class ServiceMetrics:
    """Point-in-time snapshot of the scheduling subsystem.

    ``tenants`` maps tenant -> flat counter dict (hits/misses/evictions/
    bytes/entries from the plan cache, submitted/completed from the
    scheduler); latency dicts come from :func:`_latency_summary`.
    """

    queue_depth: int
    workers: int
    busy_workers: int
    utilization: float  # busy-seconds / (workers * uptime) since start
    executor: str
    jobs_completed: int
    jobs_failed: int
    cancelled_queued: int
    cancelled_inflight: int
    coalesced: int
    latency_s: dict  # submit -> done
    queue_wait_s: dict  # submit -> worker pickup
    tenants: dict = dataclasses.field(default_factory=dict)
    # Kernel compile-cache counters (hits/misses/evictions/entries/
    # size_elems per bucket), merged in by the serve layer's GraphServer —
    # empty when no compile cache reports into this snapshot.
    compile_cache: dict = dataclasses.field(default_factory=dict)
    # Overload-protection counters (defaulted so pre-admission snapshots
    # and the transport's empty-metrics constructor keep working):
    # high-water queue depth, admission rejections, deadline sheds, and
    # the admission controller's view (bound / per-tenant occupancy /
    # drain rate) — empty dict when the scheduler runs unbounded.
    queue_depth_max: int = 0
    rejected: int = 0
    shed_deadline: int = 0
    admission: dict = dataclasses.field(default_factory=dict)


class PlanTicket:
    """Future handed back by async submission; resolves to a ServicePlan.

    ``cache_hit`` is True when the request was answered from the plan cache
    without any partitioning work (set before the ticket is returned, so it
    is race-free even with concurrent requests on other graphs).
    ``cancelled`` is True once :meth:`cancel` took effect: a queued request
    fails with :class:`PlanCancelledError`; an in-flight one is only
    *marked* — the computation finishes and ``result()`` still returns it.
    """

    def __init__(self, tenant: str = "default", priority: int = 0) -> None:
        self._event = threading.Event()
        self._value: Any = None
        self._error: Optional[BaseException] = None
        self.cache_hit = False
        self.cancelled = False
        self.tenant = tenant
        self.priority = priority
        # Lifecycle timestamps (perf_counter): set by the scheduler.
        self.t_submit: float = 0.0
        self.t_start: float = 0.0
        self.t_done: float = 0.0
        # Buffers to publish to on completion.  Coalescing can hand one
        # ticket to several callers, each with its own DoubleBuffer — all of
        # them must see the swap (guarded by the scheduler lock).
        self._buffers: list = []
        self._cancel_cb: Optional[Callable[["PlanTicket"], bool]] = None
        self._waiters = 1

    def _resolve(self, value) -> None:
        self._value = value
        self._event.set()

    def _fail(self, err: BaseException) -> None:
        self._error = err
        self._event.set()

    def done(self) -> bool:
        return self._event.is_set()

    def cancel(self, buffer=None) -> bool:
        """Try to cancel; True iff the computation itself was prevented.

        Pass the ``DoubleBuffer`` you gave ``submit`` to detach it as well:
        a coalesced computation keeps running for the other waiters, and
        without detaching, its eventual publish would overwrite whatever
        your buffer is serving by then.
        """
        # Single read: the worker nulls the callback concurrently on
        # completion, and a cancel that loses that race is a benign False.
        cb = self._cancel_cb
        return cb(self, buffer) if cb is not None else False

    def result(self, timeout: float | None = None):
        if not self._event.wait(timeout):
            raise TimeoutError("partition not ready")
        if self._error is not None:
            raise self._error
        return self._value


class _Job:
    """One queued/running computation: heap entries point at this."""

    __slots__ = ("key", "fn", "args", "ticket", "on_done", "priority", "seq",
                 "state", "t_submit", "t_start", "deadline")
    QUEUED, RUNNING, DONE = 0, 1, 2

    def __init__(self, key, fn, args, ticket, on_done, priority, seq,
                 deadline=None):
        self.key = key
        self.fn = fn
        self.args = args
        self.ticket = ticket
        self.on_done = on_done
        self.priority = priority
        self.seq = seq
        self.state = _Job.QUEUED
        self.t_submit = time.perf_counter()
        self.t_start = 0.0
        self.deadline = deadline  # absolute perf_counter(); None = unbounded


class PlanScheduler:
    """Priority-ordered N-worker pool with coalescing and cancellation."""

    def __init__(
        self,
        workers: int = 1,
        executor: str = "thread",
        name: str = "plan-sched",
        max_queue_depth: int | None = None,
        tenant_weights: dict[str, float] | None = None,
        admission: AdmissionController | None = None,
    ) -> None:
        if workers < 1:
            raise ValueError("workers must be >= 1")
        if executor not in ("thread", "process"):
            raise ValueError(f"executor must be 'thread' or 'process', got {executor!r}")
        self.workers = workers
        self.executor = executor
        self._name = name
        # Admission is opt-in: with no bound the scheduler keeps its
        # historical unbounded-queue behavior.  The controller's methods are
        # only ever called under _cv's lock.
        if admission is not None:
            self._admission: Optional[AdmissionController] = admission
        elif max_queue_depth is not None:
            self._admission = AdmissionController(
                max_queue_depth, tenant_weights=tenant_weights)
        else:
            if tenant_weights:
                raise ValueError("tenant_weights requires max_queue_depth")
            self._admission = None
        self._cv = threading.Condition()
        self._heap: list[tuple[int, int, _Job]] = []  # (-priority, seq, job)
        self._jobs: dict[Any, _Job] = {}  # key -> queued/running job (coalescing)
        self._seq = 0
        self._threads: list[threading.Thread] = []
        self._pool = None  # multiprocessing pool when executor == "process"
        self._stop = False
        self._closed = False
        # Metrics (all guarded by _cv's lock).
        self._t0 = time.perf_counter()
        self._busy_s = 0.0
        self._busy_workers = 0
        self._jobs_completed = 0
        self._jobs_failed = 0
        self._cancelled_queued = 0
        self._cancelled_inflight = 0
        self._coalesced = 0
        self._rejected = 0
        self._shed_deadline = 0
        self._queued = 0  # live queue depth (QUEUED jobs)
        self._queue_depth_max = 0
        self._tenant_counts: dict[str, dict[str, int]] = {}
        self._lat_total: deque[float] = deque(maxlen=2048)
        self._lat_wait: deque[float] = deque(maxlen=2048)
        # Pure service time (worker pickup -> done): the deadline-shedding
        # predictor.  Total latency would double-count queue wait.
        self._lat_run: deque[float] = deque(maxlen=2048)
        # Test/bench seam: called with the job key on the dispatcher thread
        # just before the job executes (thread executor only — a process
        # pool's children cannot see it).  ``ReplicaGroup``'s FaultInjector
        # uses it to stall a replica deterministically; an exception raised
        # here fails the job like a job error.
        self.pre_job_hook: Optional[Callable[[Any], None]] = None

    # -- lifecycle ---------------------------------------------------------

    def start(self) -> None:
        """Spin up the worker pool; idempotent while the scheduler is open,
        and reopens a closed scheduler (the drained queue stays failed, new
        submits are accepted again — matching the pre-pool single-worker
        service, whose start() after close() revived it)."""
        with self._cv:
            self._closed = False
            self._stop = False
            if self.executor == "process" and self._pool is None:
                import multiprocessing as mp

                # "spawn", not "fork": the parent may hold jax/BLAS threads
                # whose locks a forked child would inherit mid-flight.
                _pin_worker_blas_env()
                self._pool = mp.get_context("spawn").Pool(self.workers)
            missing = self.workers - len([t for t in self._threads if t.is_alive()])
            for i in range(missing):
                t = threading.Thread(
                    target=self._worker_loop, name=f"{self._name}-{i}", daemon=True
                )
                self._threads.append(t)
                t.start()

    def close(self) -> None:
        """Drain-safe, idempotent shutdown: queued tickets fail with
        :class:`ServiceClosedError`; in-flight jobs finish (close blocks on
        them — their waiters must see a resolved ticket, never a ticket
        orphaned by a killed worker); a second call is a no-op."""
        with self._cv:
            if self._closed:
                return
            self._closed = True
            self._stop = True
            drained: list[_Job] = []
            while self._heap:
                _, seq, job = heapq.heappop(self._heap)
                if job.state == _Job.QUEUED and job.seq == seq:
                    job.state = _Job.DONE
                    self._jobs.pop(job.key, None)
                    self._queued -= 1
                    if self._admission is not None:
                        self._admission.release(job.ticket.tenant)
                    drained.append(job)
            self._cv.notify_all()
        for job in drained:
            job.ticket._fail(ServiceClosedError(
                "PartitionService closed before this request was scheduled"))
        # No join timeout: dispatchers exit as soon as their current job
        # completes, and cutting them off early (then terminating the
        # process pool) would kill an in-flight job and hang its waiters.
        for t in self._threads:
            t.join()
        self._threads = []
        if self._pool is not None:
            # Dispatchers are gone, so no apply() is outstanding: a
            # graceful close/join, not terminate(), reaps the workers.
            self._pool.close()
            self._pool.join()
            self._pool = None

    @property
    def closed(self) -> bool:
        return self._closed

    # -- submission --------------------------------------------------------

    def _p50_run_locked(self) -> float:
        """Median observed service time (pickup -> done); 0 with no history,
        so a cold scheduler never sheds on an unfounded prediction."""
        if not self._lat_run:
            return 0.0
        ys = sorted(self._lat_run)
        return ys[min(len(ys) - 1, len(ys) // 2)]

    def submit(
        self,
        key,
        fn: Callable,
        args: tuple,
        *,
        priority: int = 0,
        tenant: str = "default",
        buffer=None,
        on_done: Optional[Callable] = None,
        deadline: float | None = None,
        block: bool = False,
    ) -> tuple[PlanTicket, bool]:
        """Enqueue ``fn(*args)`` under ``key``; returns ``(ticket, created)``.

        ``created`` is False when an identical key was already queued or
        in-flight — the existing ticket is shared (coalescing) and, if the
        new priority is higher and the job is still queued, the job is
        bumped.  With ``executor="process"``, ``fn`` must be a module-level
        function and ``args`` picklable.

        ``deadline`` is an absolute ``time.perf_counter()`` instant: a job
        whose p50-predicted service time no longer fits its remaining
        budget is shed (its ticket fails with :class:`DeadlineShedError`)
        instead of wasting a worker — at the door here, and again at worker
        pickup for jobs that aged out while queued.

        When the scheduler has a queue bound, an over-share submit either
        raises :class:`AdmissionRejectedError` (with a drain-rate-derived
        ``retry_after_s`` hint) or, with ``block=True``, waits under
        backpressure for a slot.  Coalesced submits bypass admission: they
        consume no new queue slot.
        """
        with self._cv:
            while True:
                # Closed is checked first on every pass — including every
                # block=True wakeup — so a submit racing close() gets
                # ServiceClosedError deterministically, never a retryable
                # admission hint that would steer clients back into a dead
                # service.
                if self._closed:
                    ticket = PlanTicket(tenant=tenant, priority=priority)
                    ticket._fail(ServiceClosedError("PartitionService closed"))
                    return ticket, False
                job = self._jobs.get(key)
                if job is not None and job.state != _Job.DONE:
                    self._coalesced += 1
                    t = job.ticket
                    t._waiters += 1
                    if buffer is not None:
                        t._buffers.append(buffer)
                    if priority > job.priority and job.state == _Job.QUEUED:
                        job.priority = priority
                        self._seq += 1
                        job.seq = self._seq
                        heapq.heappush(self._heap, (-priority, self._seq, job))
                    # A new waiter may bring a laxer deadline: keep the job
                    # alive as long as anyone still has budget for it.
                    if job.deadline is not None and (
                            deadline is None or deadline > job.deadline):
                        job.deadline = deadline
                    return t, False
                now = time.perf_counter()
                if deadline is not None and now + self._p50_run_locked() > deadline:
                    self._shed_deadline += 1
                    ticket = PlanTicket(tenant=tenant, priority=priority)
                    ticket._fail(DeadlineShedError(
                        f"deadline budget ({deadline - now:.3g}s left) below "
                        "p50-predicted service time; shed at admission"))
                    return ticket, False
                if self._admission is None:
                    break
                err = self._admission.try_acquire(tenant)
                if err is None:
                    break
                if not block:
                    self._rejected += 1
                    tc = self._tenant_counts.setdefault(
                        tenant, {"submitted": 0, "completed": 0})
                    tc["rejected"] = tc.get("rejected", 0) + 1
                    raise err
                # Backpressure: wait for a queue slot (workers notify on
                # every pickup) or for close/deadline to resolve the wait.
                self._cv.wait(timeout=None if deadline is None
                              else max(deadline - now, 0.0) or 0.001)
            ticket = PlanTicket(tenant=tenant, priority=priority)
            ticket.t_submit = time.perf_counter()
            ticket._cancel_cb = self._cancel
            if buffer is not None:
                ticket._buffers.append(buffer)
            self._seq += 1
            job = _Job(key, fn, args, ticket, on_done, priority, self._seq,
                       deadline=deadline)
            self._jobs[key] = job
            tc = self._tenant_counts.setdefault(tenant, {"submitted": 0, "completed": 0})
            tc["submitted"] += 1
            heapq.heappush(self._heap, (-priority, self._seq, job))
            self._queued += 1
            self._queue_depth_max = max(self._queue_depth_max, self._queued)
            self._cv.notify()
            return ticket, True

    def _cancel(self, ticket: PlanTicket, buffer=None) -> bool:
        with self._cv:
            if buffer is not None and buffer in ticket._buffers:
                # The canceller's serving loop must not receive the plan it
                # just walked away from (the job may finish for others).
                ticket._buffers.remove(buffer)
            job = None
            for j in self._jobs.values():
                if j.ticket is ticket:
                    job = j
                    break
            if job is None or job.state == _Job.DONE:
                return False
            if ticket._waiters > 1:
                # Coalesced: detach this caller, keep computing for the rest.
                ticket._waiters -= 1
                return False
            if job.state == _Job.RUNNING:
                ticket.cancelled = True
                self._cancelled_inflight += 1
                return False
            # Queued and solely owned: drop it (heap entry goes stale).
            job.state = _Job.DONE
            self._jobs.pop(job.key, None)
            ticket.cancelled = True
            self._cancelled_queued += 1
            self._queued -= 1
            if self._admission is not None:
                self._admission.release(ticket.tenant)
                self._cv.notify_all()  # a blocked submit may now have a slot
        ticket._fail(PlanCancelledError("request cancelled while queued"))
        return True

    # -- workers -----------------------------------------------------------

    def _worker_loop(self) -> None:
        while True:
            shed: list[_Job] = []
            with self._cv:
                job = None
                while job is None:
                    while self._heap:
                        _, seq, cand = heapq.heappop(self._heap)
                        # Stale entries: cancelled jobs and superseded
                        # priority-bump duplicates point at a job whose
                        # state/seq moved on.
                        if cand.state != _Job.QUEUED or cand.seq != seq:
                            continue
                        if cand.deadline is not None and (
                                time.perf_counter() + self._p50_run_locked()
                                > cand.deadline):
                            # Aged out while queued: running it now would
                            # waste a worker on a result nobody can use.
                            cand.state = _Job.DONE
                            self._jobs.pop(cand.key, None)
                            self._shed_deadline += 1
                            self._queued -= 1
                            if self._admission is not None:
                                self._admission.release(cand.ticket.tenant)
                                self._cv.notify_all()
                            shed.append(cand)
                            continue
                        job = cand
                        break
                    if job is not None or shed:
                        # Shed tickets must be failed outside the lock
                        # promptly, not after an unbounded wait().
                        break
                    if self._stop:
                        return
                    self._cv.wait()
                if job is not None:
                    job.state = _Job.RUNNING
                    job.t_start = time.perf_counter()
                    job.ticket.t_start = job.t_start
                    self._busy_workers += 1
                    self._queued -= 1
                    if self._admission is not None:
                        # The bound covers *queued* work: pickup frees the
                        # slot and wakes any backpressured submitter.
                        self._admission.release(job.ticket.tenant)
                        self._cv.notify_all()
                pool = self._pool
            for s in shed:
                s.ticket._cancel_cb = None
                s.ticket._fail(DeadlineShedError(
                    "deadline budget exhausted while queued; shed at pickup"))
            if job is None:
                continue
            try:
                hook = self.pre_job_hook
                if hook is not None:
                    hook(job.key)
                if pool is not None:
                    value = pool.apply(job.fn, job.args)
                else:
                    value = job.fn(*job.args)
                if job.on_done is not None:
                    # Runs before the ticket resolves: cache population
                    # happens-before any waiter wakes.
                    value = job.on_done(value, job.ticket)
                err = None
            except BaseException as e:  # propagate to waiters, keep serving
                err = e
            t_done = time.perf_counter()
            with self._cv:
                job.state = _Job.DONE
                if self._jobs.get(job.key) is job:
                    del self._jobs[job.key]
                self._busy_workers -= 1
                self._busy_s += t_done - job.t_start
                if err is None:
                    self._jobs_completed += 1
                    tc = self._tenant_counts.setdefault(
                        job.ticket.tenant, {"submitted": 0, "completed": 0})
                    tc["completed"] += 1
                    self._lat_total.append(t_done - job.t_submit)
                    self._lat_wait.append(job.t_start - job.t_submit)
                    self._lat_run.append(t_done - job.t_start)
                else:
                    self._jobs_failed += 1
                if self._admission is not None:
                    # Completion is the drain signal the retry_after_s
                    # estimator converts into seconds-until-slot-free.
                    self._admission.note_drained(t_done)
                buffers = list(job.ticket._buffers)
            job.ticket.t_done = t_done
            job.ticket._cancel_cb = None
            if err is not None:
                job.ticket._fail(err)
            else:
                for buf in buffers:
                    buf.publish(value)
                job.ticket._resolve(value)

    # -- metrics -----------------------------------------------------------

    def metrics_snapshot(self) -> ServiceMetrics:
        with self._cv:
            uptime = max(time.perf_counter() - self._t0, 1e-9)
            busy = self._busy_s
            # Credit the running jobs' elapsed time too, so a snapshot taken
            # mid-computation doesn't read as an idle pool.
            for job in self._jobs.values():
                if job.state == _Job.RUNNING:
                    busy += time.perf_counter() - job.t_start
            tenants = {t: dict(c) for t, c in self._tenant_counts.items()}
            if self._admission is not None:
                for t, n in self._admission.occupancy().items():
                    tenants.setdefault(
                        t, {"submitted": 0, "completed": 0})["queued"] = n
            return ServiceMetrics(
                queue_depth=sum(
                    1 for j in self._jobs.values() if j.state == _Job.QUEUED),
                workers=self.workers,
                busy_workers=self._busy_workers,
                utilization=min(busy / (self.workers * uptime), 1.0),
                executor=self.executor,
                jobs_completed=self._jobs_completed,
                jobs_failed=self._jobs_failed,
                cancelled_queued=self._cancelled_queued,
                cancelled_inflight=self._cancelled_inflight,
                coalesced=self._coalesced,
                latency_s=_latency_summary(list(self._lat_total)),
                queue_wait_s=_latency_summary(list(self._lat_wait)),
                tenants=tenants,
                queue_depth_max=self._queue_depth_max,
                rejected=self._rejected,
                shed_deadline=self._shed_deadline,
                admission=(self._admission.snapshot()
                           if self._admission is not None else {}),
            )

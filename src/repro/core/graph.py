"""Data-affinity graph model (paper §3.1, Definition 1).

A data-affinity graph D = (V, E): vertices are *data objects*, edges are
*computation tasks* touching exactly two data objects.  The graph is stored
two ways:

  * ``EdgeList`` — the canonical (m, 2) task list; the unit of partitioning.
  * ``CSRGraph`` — compressed adjacency used by the multilevel vertex
    partitioner and by the clone-and-connect transformation.

Everything here is NumPy (host-side): the partitioner runs on the host CPU
asynchronously with accelerator compute, exactly like the paper's separate
CPU optimization thread (§4.2).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import numpy as np

__all__ = [
    "EdgeList",
    "CSRGraph",
    "csr_from_edges",
    "affinity_graph_from_coo",
    "synthetic_mesh_graph",
    "synthetic_powerlaw_graph",
    "synthetic_banded_graph",
    "synthetic_random_graph",
    "synthetic_bipartite_graph",
]


@dataclasses.dataclass(frozen=True)
class EdgeList:
    """Task list: edge i = (u[i], v[i]) is one computation task.

    ``n`` is the number of data objects (vertices).  Self-loops are allowed
    (a task that touches a single data object twice); parallel edges are
    allowed (two tasks over the same data-object pair).
    """

    n: int
    u: np.ndarray  # (m,) int32/int64 endpoint 0
    v: np.ndarray  # (m,) endpoint 1

    def __post_init__(self):
        if self.u.shape != self.v.shape:
            raise ValueError("endpoint arrays must have the same shape")
        if self.m and (int(self.u.max()) >= self.n or int(self.v.max()) >= self.n):
            raise ValueError("endpoint id out of range")
        if self.m and (int(self.u.min()) < 0 or int(self.v.min()) < 0):
            raise ValueError("negative endpoint id")

    @property
    def m(self) -> int:
        return int(self.u.shape[0])

    def degrees(self) -> np.ndarray:
        """Degree of every data object = number of incident tasks."""
        deg = np.bincount(self.u, minlength=self.n)
        deg += np.bincount(self.v, minlength=self.n)
        return deg

    def max_degree(self) -> int:
        return int(self.degrees().max(initial=0))

    def degree_histogram(self) -> dict[int, int]:
        deg = self.degrees()
        vals, counts = np.unique(deg, return_counts=True)
        return {int(d): int(c) for d, c in zip(vals, counts)}


@dataclasses.dataclass(frozen=True)
class CSRGraph:
    """Undirected weighted graph in CSR form (both directions stored).

    ``vweights`` are vertex weights used for balance (coarse vertices carry
    the weight of everything they absorbed).
    """

    indptr: np.ndarray  # (n+1,) int64
    indices: np.ndarray  # (nnz,) int32 neighbour ids
    eweights: np.ndarray  # (nnz,) float64 edge weights
    vweights: np.ndarray  # (n,) int64 vertex weights

    @property
    def n(self) -> int:
        return int(self.indptr.shape[0] - 1)

    @property
    def nnz(self) -> int:
        return int(self.indices.shape[0])

    def degree(self) -> np.ndarray:
        return np.diff(self.indptr)

    # Cached COO view.  The multilevel partitioner (coarsening, contraction,
    # connectivity tables, edgecut) repeatedly needs the row index of every
    # stored edge; materializing it once per graph instead of re-running
    # ``np.repeat(arange, diff(indptr))`` at every call site takes the
    # expansion off the hot path.  ``functools.cached_property`` writes to
    # the instance ``__dict__`` directly, so it composes with frozen.
    # Both arrays are frozen (``setflags(write=False)``): they are shared by
    # every stage of every partitioning run on this graph, so a call site
    # mutating them in place would silently corrupt all later coarsening /
    # contraction rounds — writing through the view fails loudly instead.

    @functools.cached_property
    def coo_src(self) -> np.ndarray:
        """(nnz,) int64 source vertex of every stored (directed) edge."""
        arr = np.repeat(np.arange(self.n, dtype=np.int64), np.diff(self.indptr))
        arr.setflags(write=False)
        return arr

    @functools.cached_property
    def coo_dst(self) -> np.ndarray:
        """(nnz,) int64 view of ``indices`` (widened once, reused everywhere)."""
        arr = self.indices.astype(np.int64)
        arr.setflags(write=False)
        return arr


def csr_from_edges(
    n: int,
    eu: np.ndarray,
    ev: np.ndarray,
    ew: Optional[np.ndarray] = None,
    vweights: Optional[np.ndarray] = None,
    dedupe: bool = True,
) -> CSRGraph:
    """Build an undirected CSR graph from an edge list, summing duplicates.

    Self loops are dropped (they contribute nothing to a cut objective).
    """
    eu = np.asarray(eu, dtype=np.int64)
    ev = np.asarray(ev, dtype=np.int64)
    if ew is None:
        ew = np.ones(eu.shape[0], dtype=np.float64)
    else:
        ew = np.asarray(ew, dtype=np.float64)
    keep = eu != ev
    eu, ev, ew = eu[keep], ev[keep], ew[keep]
    # Symmetrize.
    src = np.concatenate([eu, ev])
    dst = np.concatenate([ev, eu])
    w = np.concatenate([ew, ew])
    if dedupe and src.size:
        key = src * n + dst
        order = np.argsort(key, kind="stable")
        key, src, dst, w = key[order], src[order], dst[order], w[order]
        uniq_mask = np.empty(key.shape[0], dtype=bool)
        uniq_mask[0] = True
        np.not_equal(key[1:], key[:-1], out=uniq_mask[1:])
        seg_ids = np.cumsum(uniq_mask) - 1
        w = np.bincount(seg_ids, weights=w)
        src = src[uniq_mask]
        dst = dst[uniq_mask]
    else:
        order = np.argsort(src, kind="stable")
        src, dst, w = src[order], dst[order], w[order]
    indptr = np.zeros(n + 1, dtype=np.int64)
    np.add.at(indptr, src + 1, 1)
    np.cumsum(indptr, out=indptr)
    if vweights is None:
        vweights = np.ones(n, dtype=np.int64)
    return CSRGraph(
        indptr=indptr,
        indices=dst.astype(np.int32),
        eweights=w.astype(np.float64),
        vweights=np.asarray(vweights, dtype=np.int64),
    )


def affinity_graph_from_coo(
    n_rows: int, n_cols: int, rows: np.ndarray, cols: np.ndarray
) -> EdgeList:
    """Data-affinity graph of SpMV ``y = A @ x`` (paper §5.2).

    One vertex per input-vector element x_j (ids ``0..n_cols``) and per
    output element y_i (ids ``n_cols..n_cols+n_rows``); one edge (task) per
    non-zero A[i, j] connecting x_j with y_i.  Naturally bipartite.
    """
    rows = np.asarray(rows, dtype=np.int64)
    cols = np.asarray(cols, dtype=np.int64)
    return EdgeList(n=n_cols + n_rows, u=cols.copy(), v=n_cols + rows)


# ---------------------------------------------------------------------------
# Synthetic graph generators matching the degree-distribution families of the
# paper's evaluation matrices (Figure 4/5): mesh-like (mc2depi), banded FEM
# (cant), power-law (in-2004, scircuit), random (circuit5M).
# ---------------------------------------------------------------------------


def synthetic_mesh_graph(side: int, seed: int = 0) -> EdgeList:
    """2D grid mesh: nearly all vertices have degree 4 (mc2depi analogue)."""
    n = side * side
    ids = np.arange(n).reshape(side, side)
    right = np.stack([ids[:, :-1].ravel(), ids[:, 1:].ravel()], axis=1)
    down = np.stack([ids[:-1, :].ravel(), ids[1:, :].ravel()], axis=1)
    e = np.concatenate([right, down], axis=0)
    return EdgeList(n=n, u=e[:, 0].copy(), v=e[:, 1].copy())


def _fix_self_loops(u: np.ndarray, v: np.ndarray, n: int) -> np.ndarray:
    """Return ``v`` with self-loops redirected to the next vertex, loop-free.

    Wherever ``u == v``, the new endpoint is ``(v + 1) % n`` — distinct from
    ``u`` for every ``n >= 2`` in a single vectorized shot (no retry loop
    needed: the collision ``u == (v + 1) % n`` would require ``u == v`` and
    ``n == 1`` simultaneously).  ``n < 2`` cannot host a loop-free edge at
    all, so it is rejected up front rather than silently returning loops.
    """
    fix = u == v
    if not fix.any():
        return v
    if n < 2:
        raise ValueError("need n >= 2 to redirect self-loops")
    v = v.copy()
    v[fix] = (v[fix] + 1) % n
    assert not (u == v).any()
    return v


def synthetic_powerlaw_graph(n: int, m: int, alpha: float = 2.2, seed: int = 0) -> EdgeList:
    """Power-law degree graph via weighted endpoint sampling (in-2004-like)."""
    rng = np.random.default_rng(seed)
    w = (np.arange(1, n + 1, dtype=np.float64)) ** (-1.0 / (alpha - 1.0))
    w /= w.sum()
    u = rng.choice(n, size=m, p=w)
    v = _fix_self_loops(u, rng.choice(n, size=m, p=w), n)
    perm = rng.permutation(n)  # decorrelate id from degree
    return EdgeList(n=n, u=perm[u], v=perm[v])


def synthetic_banded_graph(n: int, band: int = 12, seed: int = 0) -> EdgeList:
    """Banded FEM-style matrix graph (cant analogue): degree ~ 2*band."""
    rng = np.random.default_rng(seed)
    offs = np.arange(1, band + 1)
    u = np.repeat(np.arange(n), band)
    v = u + np.tile(offs, n)
    keep = v < n
    u, v = u[keep], v[keep]
    drop = rng.random(u.shape[0]) < 0.15  # irregular holes in the band
    return EdgeList(n=n, u=u[~drop], v=v[~drop])


def synthetic_random_graph(n: int, m: int, seed: int = 0) -> EdgeList:
    """Uniform random graph (circuit5M analogue)."""
    rng = np.random.default_rng(seed)
    u = rng.integers(0, n, size=m)
    v = _fix_self_loops(u, rng.integers(0, n, size=m), n)
    return EdgeList(n=n, u=u, v=v)


def synthetic_bipartite_graph(
    n_rows: int, n_cols: int, nnz_per_row: int, seed: int = 0, clustered: bool = True
) -> tuple[EdgeList, np.ndarray, np.ndarray]:
    """Sparse-matrix bipartite affinity graph + its COO (rows, cols).

    ``clustered=True`` draws column indices near the diagonal so that real
    locality exists for the partitioner to find (like FEM/circuit matrices).
    """
    rng = np.random.default_rng(seed)
    rows = np.repeat(np.arange(n_rows), nnz_per_row)
    if clustered:
        center = (np.repeat(np.arange(n_rows), nnz_per_row) * n_cols) // max(n_rows, 1)
        jitter = rng.integers(-max(4, n_cols // 64), max(4, n_cols // 64) + 1, size=rows.shape[0])
        cols = np.clip(center + jitter, 0, n_cols - 1)
    else:
        cols = rng.integers(0, n_cols, size=rows.shape[0])
    # Dedupe (row, col) pairs.
    key = rows * n_cols + cols
    _, uniq_idx = np.unique(key, return_index=True)
    rows, cols = rows[uniq_idx], cols[uniq_idx]
    return affinity_graph_from_coo(n_rows, n_cols, rows, cols), rows, cols

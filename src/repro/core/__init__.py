"""Edge-centric graph model for cache-locality-aware task scheduling.

Implements Li et al., "A Graph-based Model for GPU Caching Problems" (2016):
data-affinity graphs, balanced edge partitioning via clone-and-connect +
multilevel vertex partitioning, baselines, cpack layout transformation, and
adaptive overhead control — adapted to the TPU memory hierarchy (HBM->VMEM).
"""
from .baselines import (
    default_schedule,
    greedy_powergraph,
    hypergraph_partition,
    random_partition,
)
from .coarsen import ClusterCoarsener, LevelStats, contract_clusters
from .edge_partition import EdgePartitionResult, edge_partition
from .hierarchy import HierarchicalPartition, hierarchical_edge_partition
from .moe_schedule import (
    MoEDispatchPlan,
    dispatch_traffic,
    plan_moe_dispatch,
    routing_affinity_graph,
)
from .graph import (
    CSRGraph,
    EdgeList,
    affinity_graph_from_coo,
    csr_from_edges,
    synthetic_banded_graph,
    synthetic_bipartite_graph,
    synthetic_mesh_graph,
    synthetic_powerlaw_graph,
    synthetic_random_graph,
)
from .metrics import (
    PartitionQuality,
    edge_balance_factor,
    evaluate_edge_partition,
    parts_per_vertex,
    redundant_load_fraction,
    replication_factor,
    vertex_cut_cost,
)
from .overhead import AdaptiveScheduler
from .partition import MultilevelOptions, PartitionStats, partition_vertices
from .partition_service import (
    AdmissionRejectedError,
    DeadlineShedError,
    DoubleBuffer,
    IncrementalStats,
    PartitionService,
    PlanCache,
    PlanCancelledError,
    PlanPadding,
    PlanScheduler,
    PlanTicket,
    ServiceClosedError,
    ServiceMetrics,
    ServicePlan,
    ServiceStats,
    TenantCacheStats,
    graph_fingerprint,
    incremental_repartition,
    incremental_repartition_reference,
)
from .replica import (
    FaultInjector,
    ReplicaExhaustedError,
    ReplicaGroup,
    ReplicaMetrics,
    ReplicaStats,
    ReplicaTicket,
)
from .reorder import PackPlan, build_pack_plan, build_pack_plan_reference, cpack_order
from .transport import (
    DeadlineExceeded,
    PlanServer,
    ProtocolError,
    RemoteReplica,
    ReplicaConnection,
    WireError,
)
from .transform import (
    ClonedGraph,
    clone_and_connect,
    contracted_clone_graph,
    reconstruct_edge_partition,
)

__all__ = [
    "AdaptiveScheduler",
    "AdmissionRejectedError",
    "CSRGraph",
    "ClonedGraph",
    "ClusterCoarsener",
    "DeadlineExceeded",
    "DeadlineShedError",
    "DoubleBuffer",
    "EdgeList",
    "EdgePartitionResult",
    "FaultInjector",
    "HierarchicalPartition",
    "IncrementalStats",
    "LevelStats",
    "MoEDispatchPlan",
    "MultilevelOptions",
    "PackPlan",
    "PartitionQuality",
    "PartitionService",
    "PartitionStats",
    "PlanCache",
    "PlanCancelledError",
    "PlanPadding",
    "PlanScheduler",
    "PlanServer",
    "PlanTicket",
    "ProtocolError",
    "RemoteReplica",
    "ReplicaConnection",
    "ReplicaExhaustedError",
    "ReplicaGroup",
    "ReplicaMetrics",
    "ReplicaStats",
    "ReplicaTicket",
    "ServiceClosedError",
    "WireError",
    "ServiceMetrics",
    "ServicePlan",
    "ServiceStats",
    "TenantCacheStats",
    "affinity_graph_from_coo",
    "build_pack_plan",
    "build_pack_plan_reference",
    "clone_and_connect",
    "contract_clusters",
    "contracted_clone_graph",
    "cpack_order",
    "csr_from_edges",
    "default_schedule",
    "dispatch_traffic",
    "edge_balance_factor",
    "edge_partition",
    "hierarchical_edge_partition",
    "plan_moe_dispatch",
    "routing_affinity_graph",
    "evaluate_edge_partition",
    "graph_fingerprint",
    "greedy_powergraph",
    "hypergraph_partition",
    "incremental_repartition",
    "incremental_repartition_reference",
    "parts_per_vertex",
    "partition_vertices",
    "random_partition",
    "reconstruct_edge_partition",
    "redundant_load_fraction",
    "replication_factor",
    "synthetic_banded_graph",
    "synthetic_bipartite_graph",
    "synthetic_mesh_graph",
    "synthetic_powerlaw_graph",
    "synthetic_random_graph",
    "vertex_cut_cost",
]

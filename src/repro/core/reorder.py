"""Data layout transformation (paper §4.1) — cpack + kernel pack plan.

After edge partitioning, the paper reorganizes tasks among thread blocks and
reorders the data layout with the cpack algorithm (consecutive packing: data
objects are laid out in first-touch order of the scheduled tasks), so each
thread block loads a *contiguous* segment into its software cache.

The TPU analogue: each Pallas grid cell p owns

  * a packed task tile   (vals, local x index, local y index)  — E_max slots
  * a packed input tile  x[x_gidx[p]]                          — X_max slots
  * a packed output tile scattered back via y_gidx[p]          — Y_max slots

Cut vertices are *replicated* across the segments that use them; the number
of replicas is exactly p_v, so total packed input size = n_touched + C —
the vertex-cut cost C is the physical redundancy of the layout, which is
what makes the model's cost function the real memory-traffic count.

``build_pack_plan`` is fully vectorized — no per-partition Python loop, no
dict-based id remapping.  One stable argsort groups tasks by partition;
one global sort over ``(partition, object)`` keys finds each partition's
distinct objects together with their *first-touch position*, and a second
sort by ``(partition, first_touch)`` turns those groups into cpack ranks.
Every task's local slot is then a single gather through the group-id array,
and all per-partition tiles are filled with flat fancy-index scatters into
the padded (k, ·) planes.  ``build_pack_plan_reference`` retains the
original per-partition formulation as an executable specification — the
property suite asserts the two are slot-for-slot identical.
"""
from __future__ import annotations

import dataclasses

import numpy as np

__all__ = ["PackPlan", "build_pack_plan", "build_pack_plan_reference", "cpack_order"]


def _pad_to(x: int, mult: int) -> int:
    return max(mult, ((x + mult - 1) // mult) * mult)


@dataclasses.dataclass(frozen=True)
class PackPlan:
    """Padded, rectangular schedule for k cache-tiles (host-side numpy)."""

    k: int
    n_rows: int
    n_cols: int
    e_max: int
    x_max: int
    y_max: int
    # Per-partition packed indices.
    x_lidx: np.ndarray  # (k, E_max) i32: task -> local slot in x tile
    y_lidx: np.ndarray  # (k, E_max) i32: task -> local slot in y tile
    x_gidx: np.ndarray  # (k, X_max) i32: local x slot -> global column id
    y_gidx: np.ndarray  # (k, Y_max) i32: local y slot -> global row id (n_rows = sentinel)
    e_count: np.ndarray  # (k,)
    x_count: np.ndarray  # (k,)
    y_count: np.ndarray  # (k,)
    # Permutation from original edge order into the packed layout.
    edge_perm: np.ndarray  # (m,) original edge id for packed slot (p * E_max + s)
    edge_valid: np.ndarray  # (k, E_max) bool

    @property
    def m(self) -> int:
        return int(self.edge_perm.shape[0])

    def pack_values(self, vals: np.ndarray) -> np.ndarray:
        """Arrange per-edge values (e.g. A's non-zeros) as (k, E_max)."""
        out = np.zeros((self.k, self.e_max), dtype=vals.dtype)
        flat = out.reshape(-1)
        slots = np.where(self.edge_valid.reshape(-1))[0]
        flat[slots] = vals[self.edge_perm]
        return out

    def modeled_loads(self) -> int:
        """Memory-traffic model: distinct objects fetched per tile, summed."""
        return int(self.x_count.sum() + self.y_count.sum())

    def vmem_bytes(self, val_bytes: int = 4, idx_bytes: int = 4) -> int:
        """Working set of ONE grid cell (the VMEM footprint the kernel claims)."""
        return (
            self.e_max * (val_bytes + 2 * idx_bytes)
            + self.x_max * val_bytes
            + self.y_max * val_bytes
        )

    def nbytes(self) -> int:
        """Host-side bytes this plan pins (used for cache byte-budget eviction)."""
        return sum(
            a.nbytes
            for a in (
                self.x_lidx,
                self.y_lidx,
                self.x_gidx,
                self.y_gidx,
                self.e_count,
                self.x_count,
                self.y_count,
                self.edge_perm,
                self.edge_valid,
            )
        )


def cpack_order(ids_in_task_order: np.ndarray) -> np.ndarray:
    """cpack (Ding & Kennedy): unique ids in first-touch order."""
    vals, first_idx = np.unique(ids_in_task_order, return_index=True)
    return vals[np.argsort(first_idx, kind="stable")]


def _cpack_ranks(
    part_sorted_labels: np.ndarray, part_sorted_ids: np.ndarray, n_ids: int, k: int
) -> tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray, np.ndarray]:
    """Segmented first-touch unique over ``(partition, object)`` pairs.

    Inputs are task-parallel arrays already grouped by partition with the
    original task order preserved inside each group (= cpack's first-touch
    order).  Returns per-task local slots plus per-group scatter data:

      ``local``   (m,) cpack rank of every task's object within its partition
      ``g_part``  (#groups,) owning partition of each distinct object
      ``g_rank``  (#groups,) cpack rank of that object in its partition
      ``g_id``    (#groups,) the global object id
      ``counts``  (k,) distinct objects per partition
    """
    m = part_sorted_ids.shape[0]
    key = part_sorted_labels * n_ids + part_sorted_ids
    order = np.argsort(key, kind="stable")
    key_s = key[order]
    first = np.empty(m, dtype=bool)
    first[0] = True
    np.not_equal(key_s[1:], key_s[:-1], out=first[1:])
    group_of = np.empty(m, dtype=np.int64)
    group_of[order] = np.cumsum(first) - 1  # task -> group id
    first_pos = order[first]  # first-touch position of each group
    g_key = key_s[first]
    g_part = g_key // n_ids
    g_id = g_key % n_ids
    counts = np.bincount(g_part, minlength=k)
    # cpack rank: groups ordered by (partition, first touch).
    by_touch = np.lexsort((first_pos, g_part))
    offsets = np.zeros(k, dtype=np.int64)
    np.cumsum(counts[:-1], out=offsets[1:])
    g_rank = np.empty(g_part.shape[0], dtype=np.int64)
    g_rank[by_touch] = np.arange(g_part.shape[0], dtype=np.int64) - np.repeat(
        offsets, counts
    )
    return g_rank[group_of], g_part, g_rank, g_id, counts


def build_pack_plan(
    n_rows: int,
    n_cols: int,
    rows: np.ndarray,
    cols: np.ndarray,
    labels: np.ndarray,
    k: int,
    pad: int = 128,
) -> PackPlan:
    """Build the packed tile schedule for SpMV from an edge partition.

    ``labels[e]`` is the cluster of non-zero e = (rows[e], cols[e]).
    Within each cluster, tasks are ordered by local row then column (so the
    per-tile scatter is segment-friendly) and data objects are packed in
    first-touch (cpack) order.  Fully vectorized: one global lexsort plus a
    segmented first-touch unique per side, no per-partition loop.
    """
    m = rows.shape[0]
    labels = np.asarray(labels, dtype=np.int64)
    rows = np.asarray(rows, dtype=np.int64)
    cols = np.asarray(cols, dtype=np.int64)

    # Group tasks by partition (stable keeps original task order = cpack's
    # first-touch order within the cluster).
    part_order = np.argsort(labels, kind="stable")
    sorted_labels = labels[part_order]
    e_count = np.bincount(labels, minlength=k)
    e_max = _pad_to(int(e_count.max(initial=1)), pad)

    x_gidx_shape_known = m > 0
    if not x_gidx_shape_known:
        x_max = y_max = _pad_to(1, pad)
        return PackPlan(
            k=k,
            n_rows=n_rows,
            n_cols=n_cols,
            e_max=e_max,
            x_max=x_max,
            y_max=y_max,
            x_lidx=np.zeros((k, e_max), dtype=np.int32),
            y_lidx=np.zeros((k, e_max), dtype=np.int32),
            x_gidx=np.zeros((k, x_max), dtype=np.int32),
            y_gidx=np.full((k, y_max), n_rows, dtype=np.int32),
            e_count=e_count.astype(np.int64),
            x_count=np.zeros(k, dtype=np.int64),
            y_count=np.zeros(k, dtype=np.int64),
            edge_perm=np.empty(0, dtype=np.int64),
            edge_valid=np.zeros((k, e_max), dtype=bool),
        )

    # Per-side cpack: local slot per task + (partition, rank) -> object id.
    lx, gx_part, gx_rank, gx_id, x_counts = _cpack_ranks(
        sorted_labels, cols[part_order], n_cols, k
    )
    ly, gy_part, gy_rank, gy_id, y_counts = _cpack_ranks(
        sorted_labels, rows[part_order], n_rows, k
    )
    x_max = _pad_to(int(x_counts.max(initial=1)), pad)
    y_max = _pad_to(int(y_counts.max(initial=1)), pad)

    x_gidx = np.zeros((k, x_max), dtype=np.int32)
    y_gidx = np.full((k, y_max), n_rows, dtype=np.int32)  # sentinel row
    x_gidx.reshape(-1)[gx_part * x_max + gx_rank] = gx_id
    y_gidx.reshape(-1)[gy_part * y_max + gy_rank] = gy_id

    # Order tasks by (partition, local y, local x): scatter-friendly.  The
    # primary key keeps partitions contiguous, so the packed slot of a task
    # is its position minus its partition's start offset.
    torder = np.lexsort((lx, ly, sorted_labels))
    lx, ly = lx[torder], ly[torder]
    edge_perm = part_order[torder]
    starts = np.zeros(k + 1, dtype=np.int64)
    np.cumsum(e_count, out=starts[1:])
    slot = np.arange(m, dtype=np.int64) - np.repeat(starts[:-1], e_count)
    flat_slot = sorted_labels * e_max + slot  # sorted_labels is unchanged by torder

    x_lidx = np.zeros((k, e_max), dtype=np.int32)
    y_lidx = np.zeros((k, e_max), dtype=np.int32)
    edge_valid = np.zeros((k, e_max), dtype=bool)
    x_lidx.reshape(-1)[flat_slot] = lx
    y_lidx.reshape(-1)[flat_slot] = ly
    edge_valid.reshape(-1)[flat_slot] = True

    return PackPlan(
        k=k,
        n_rows=n_rows,
        n_cols=n_cols,
        e_max=e_max,
        x_max=x_max,
        y_max=y_max,
        x_lidx=x_lidx,
        y_lidx=y_lidx,
        x_gidx=x_gidx,
        y_gidx=y_gidx,
        e_count=e_count.astype(np.int64),
        x_count=x_counts.astype(np.int64),
        y_count=y_counts.astype(np.int64),
        edge_perm=edge_perm,
        edge_valid=edge_valid,
    )


def build_pack_plan_reference(
    n_rows: int,
    n_cols: int,
    rows: np.ndarray,
    cols: np.ndarray,
    labels: np.ndarray,
    k: int,
    pad: int = 128,
) -> PackPlan:
    """Naive per-partition reference for :func:`build_pack_plan`.

    Kept as an executable specification: the property suite asserts the
    vectorized builder is slot-for-slot identical to this loop on random
    COO inputs.  Not a hot path — do not call from serving code.
    """
    m = rows.shape[0]
    labels = np.asarray(labels, dtype=np.int64)
    rows = np.asarray(rows, dtype=np.int64)
    cols = np.asarray(cols, dtype=np.int64)

    part_order = np.argsort(labels, kind="stable")
    sorted_labels = labels[part_order]
    e_count = np.bincount(labels, minlength=k)
    e_max = _pad_to(int(e_count.max(initial=1)), pad)

    xkey = np.unique(sorted_labels * n_cols + cols[part_order])
    x_counts = np.bincount((xkey // n_cols).astype(np.int64), minlength=k)
    ykey = np.unique(sorted_labels * n_rows + rows[part_order])
    y_counts = np.bincount((ykey // n_rows).astype(np.int64), minlength=k)
    x_max = _pad_to(int(x_counts.max(initial=1)), pad)
    y_max = _pad_to(int(y_counts.max(initial=1)), pad)

    x_lidx = np.zeros((k, e_max), dtype=np.int32)
    y_lidx = np.zeros((k, e_max), dtype=np.int32)
    x_gidx = np.zeros((k, x_max), dtype=np.int32)
    y_gidx = np.full((k, y_max), n_rows, dtype=np.int32)  # sentinel row
    edge_valid = np.zeros((k, e_max), dtype=bool)
    edge_perm = np.empty(m, dtype=np.int64)

    starts = np.zeros(k + 1, dtype=np.int64)
    np.cumsum(e_count, out=starts[1:])
    slot_base = 0
    for p in range(k):
        seg = part_order[starts[p] : starts[p + 1]]
        if seg.size == 0:
            continue
        c = cols[seg]
        r = rows[seg]
        cx = cpack_order(c)
        cy = cpack_order(r)
        x_gidx[p, : cx.size] = cx
        y_gidx[p, : cy.size] = cy
        cmap = {int(g): i for i, g in enumerate(cx)}
        rmap = {int(g): i for i, g in enumerate(cy)}
        lx = np.fromiter((cmap[int(g)] for g in c), dtype=np.int32, count=seg.size)
        ly = np.fromiter((rmap[int(g)] for g in r), dtype=np.int32, count=seg.size)
        torder = np.lexsort((lx, ly))
        seg, lx, ly = seg[torder], lx[torder], ly[torder]
        ne = seg.size
        x_lidx[p, :ne] = lx
        y_lidx[p, :ne] = ly
        edge_valid[p, :ne] = True
        edge_perm[slot_base : slot_base + ne] = seg
        slot_base += ne

    return PackPlan(
        k=k,
        n_rows=n_rows,
        n_cols=n_cols,
        e_max=e_max,
        x_max=x_max,
        y_max=y_max,
        x_lidx=x_lidx,
        y_lidx=y_lidx,
        x_gidx=x_gidx,
        y_gidx=y_gidx,
        e_count=e_count.astype(np.int64),
        x_count=x_counts.astype(np.int64),
        y_count=y_counts.astype(np.int64),
        edge_perm=edge_perm,
        edge_valid=edge_valid,
    )

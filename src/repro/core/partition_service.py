"""Async partition service — the paper's CPU optimization thread (§4.2),
grown into a multi-tenant scheduling subsystem.

The paper's key systems design is that graph partitioning and data relayout
never block GPU compute: they run on *separate CPU optimization threads*,
and the kernel keeps executing under the old schedule until the new one is
ready, at which point the runtime atomically swaps it in.  This module is
the thin facade over that subsystem; the two halves live in their own
modules and are independently testable:

  * **Scheduling** (`plan_scheduler.PlanScheduler`) — an N-worker pool
    (thread or spawned-process executors) draining one priority queue, with
    request coalescing, cancellation, and a `ServiceMetrics` snapshot
    (queue depth, worker utilization, latency histograms).  Results are
    published with an atomic front/back `DoubleBuffer` swap so readers
    never observe a half-built plan — §4.2's async optimization thread.
  * **Caching** (`plan_cache.PlanCache` keyed by `graph_fingerprint`) —
    §4.2 amortizes one partitioning over many kernel launches on the same
    graph; in a serving system the same graph arrives from many requests
    *and tenants*, so plans are memoized under a cheap content hash with
    per-tenant byte budgets, cost-aware eviction
    (`compute_time_s / nbytes`: cheap-to-recompute plans go first),
    incremental-lineage pinning, and save/load persistence.
  * **Incremental repartition** (`incremental_repartition`) — §4.2's
    overhead-control argument only holds if re-optimization is cheap when
    the graph drifts.  For a small batch of edge insertions/deletions we
    keep the cached labeling, place new tasks in batched rounds by
    vertex-cut delta, and run *batched* boundary refinement over the dirty
    region only — driving the same shared engine (`refine.py`: gain-sorted
    candidates, per-destination prefix-sum admission, rank-packed repair)
    as the full multilevel refiner (`partition._refine`), over a dense
    ``(n_relevant, k)`` incidence table instead of the whole graph.  The
    pre-vectorization dict/set implementation survives as
    `incremental_repartition_reference`, the property-test oracle.  Between
    this single-level gear and a full rebuild sits `local_repartition`: a
    **local V-cycle** that freezes labels outside the churn-dirty region
    (plus a bounded halo), contracts the frozen region to per-part anchor
    super-vertices, and re-coarsens/refines only the dirty subgraph.  A
    drift-gated `GearPolicy` picks among the three gears per update from
    the accumulated churn fraction and each gear's own quality signal (the
    paper's adaptive overhead control, cf. `overhead.AdaptiveScheduler`).

Every plan carries the full `EdgePartitionResult` (labels + quality) and,
for SpMV-shaped requests, the `PackPlan` (§4.1 cpack layout), so kernels
can bind a service-supplied plan directly (`kernels.ops.make_ep_spmv_fn`).
"""
from __future__ import annotations

import collections
import dataclasses
import hashlib
import os
import threading
import time
from typing import Optional

import numpy as np

from .edge_partition import EdgePartitionResult, edge_partition
from .graph import EdgeList, affinity_graph_from_coo, csr_from_edges
from .metrics import evaluate_edge_partition
from .partition import MultilevelOptions, _local_vcycle
from .plan_cache import PlanCache, TenantCacheStats
from .plan_scheduler import (
    AdmissionRejectedError,
    DeadlineShedError,
    PlanCancelledError,
    PlanScheduler,
    PlanTicket,
    ServiceClosedError,
    ServiceMetrics,
)
from .refine import (
    admit_batched_moves,
    apply_task_moves,
    build_task_connectivity,
    run_first_mask,
    segmented_cumsum,
)
from .reorder import PackPlan, build_pack_plan

__all__ = [
    "AdmissionRejectedError",
    "DeadlineShedError",
    "DoubleBuffer",
    "GearPolicy",
    "IncrementalStats",
    "PartitionService",
    "PlanCache",
    "PlanCancelledError",
    "PlanPadding",
    "PlanScheduler",
    "PlanTicket",
    "ServiceClosedError",
    "ServiceMetrics",
    "ServicePlan",
    "ServiceStats",
    "TenantCacheStats",
    "graph_fingerprint",
    "incremental_repartition",
    "incremental_repartition_reference",
    "local_repartition",
]


# ---------------------------------------------------------------------------
# Fingerprinting
# ---------------------------------------------------------------------------


def graph_fingerprint(
    edges: EdgeList,
    k: int,
    pad: int = 0,
    opts: MultilevelOptions | None = None,
    method: str = "ep",
    seed: int = 0,
    extra: tuple = (),
) -> str:
    """Cheap content hash identifying a partition request.

    Hashes (n, m, k, pad, method, seed, option fields, endpoint arrays) —
    O(m) bytes through blake2b, microseconds to milliseconds even for
    million-edge graphs, versus seconds for a multilevel run.  ``extra``
    lets SpMV requests mix in (n_rows, n_cols) so a bipartite affinity
    graph and a plain graph with identical arrays never collide.
    """
    h = hashlib.blake2b(digest_size=16)
    meta = (edges.n, edges.m, k, pad, method, seed) + tuple(extra)
    if opts is not None:
        meta = meta + dataclasses.astuple(opts)
    h.update(repr(meta).encode())
    h.update(np.ascontiguousarray(edges.u, dtype=np.int64).tobytes())
    h.update(np.ascontiguousarray(edges.v, dtype=np.int64).tobytes())
    return h.hexdigest()


def _multilevel_stage_times(stats) -> dict:
    """Flat ``{stage: seconds}`` entries derived from a PartitionStats.

    Strictly wall times — the V-cycle *shape* (level count, per-level
    records) travels separately via :func:`_vcycle_shape` into
    ``ServicePlan.vcycle``, so consumers summing or formatting
    ``stage_times_s`` values never meet a count or a list.
    """
    return {
        "coarsen": stats.coarsen_s,
        "init": stats.init_s,
        "refine": stats.refine_s,
    }


def _vcycle_shape(stats) -> dict:
    """ServicePlan.vcycle payload: the multilevel V-cycle's shape — level
    count, coarsest size, coarsen mode, and the per-level (n, nnz,
    contraction ratio, wall time) records — so serving dashboards see where
    the dominant cold stage spends its time without re-running anything."""
    return {
        "levels": stats.levels,
        "coarsest_n": stats.coarsest_n,
        "coarsen_mode": stats.coarsen_mode,
        "coarsen_levels": [dataclasses.asdict(ls) for ls in stats.level_stats],
    }


# ---------------------------------------------------------------------------
# Incremental repartition
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class IncrementalStats:
    m_old: int
    m_new: int
    n_inserted: int
    n_deleted: int
    n_dirty: int
    moves: int
    passes_run: int
    dirty_fraction: float
    balance: float
    balance_ok: bool
    time_s: float = 0.0
    # Per-stage wall times: dirty-region + table build / insertion placement
    # / dirty-region refinement (the pack stage is timed by the service).
    dirty_s: float = 0.0
    place_s: float = 0.0
    refine_s: float = 0.0
    # Which update gear produced this record ("incremental" | "local" —
    # the service overwrites it with the final policy decision, so an
    # escalated attempt reads as the gear that actually shipped), and the
    # drift estimate the policy gated on (base plan drift + churn fraction).
    gear: str = "incremental"
    drift: float = 0.0
    # Local V-cycle extras: frozen-region contraction + re-coarsening time
    # and the number of local levels (both 0 for the incremental gear).
    coarsen_s: float = 0.0
    levels: int = 0


def _count_key(v: int, p: int, k: int) -> int:
    return v * k + p


@dataclasses.dataclass
class _ChurnSetup:
    """Shared front half of both incremental implementations.

    The churned task list (kept order + insertions appended), the dirty task
    set, and the relevant-vertex mask — computed once, identically, so the
    batched pipeline and the scalar reference agree on every input.
    """

    m_old: int
    m_new: int
    n: int
    n_kept: int
    n_ins: int
    n_deleted: int
    u_all: np.ndarray
    v_all: np.ndarray
    lab_kept: np.ndarray
    insert_u: np.ndarray
    insert_v: np.ndarray
    dirty_idx: np.ndarray
    relevant: np.ndarray


def _churn_setup(
    edges: EdgeList,
    labels: np.ndarray,
    insert_u: np.ndarray | None,
    insert_v: np.ndarray | None,
    delete_ids: np.ndarray | None,
    dirty_degree_cap: int | None,
    need_relevant: bool = True,
) -> _ChurnSetup:
    insert_u = (
        np.asarray(insert_u, dtype=np.int64)
        if insert_u is not None
        else np.empty(0, dtype=np.int64)
    )
    insert_v = (
        np.asarray(insert_v, dtype=np.int64)
        if insert_v is not None
        else np.empty(0, dtype=np.int64)
    )
    if insert_u.shape != insert_v.shape:
        raise ValueError("insert_u/insert_v must have the same shape")
    n_ins = int(insert_u.shape[0])
    if n_ins and (int(insert_u.min()) < 0 or int(insert_v.min()) < 0):
        raise ValueError("insert endpoints must be non-negative vertex ids")
    labels = np.asarray(labels, dtype=np.int64)
    m_old = edges.m
    keep = np.ones(m_old, dtype=bool)
    n_deleted = 0
    touched = [insert_u, insert_v]
    if delete_ids is not None and len(delete_ids) > 0:
        delete_ids = np.asarray(delete_ids, dtype=np.int64)
        bad = (delete_ids < 0) | (delete_ids >= m_old)
        if bad.any():
            raise ValueError(
                f"delete_ids must be task indices in [0, {m_old}); got "
                f"{np.unique(delete_ids[bad])[:8].tolist()} — negative ids "
                "would silently wrap around, past-the-end ids are not tasks"
            )
        delete_ids = np.unique(delete_ids)
        keep[delete_ids] = False
        n_deleted = int(delete_ids.shape[0])
        touched += [
            edges.u[delete_ids].astype(np.int64),
            edges.v[delete_ids].astype(np.int64),
        ]
    u_all = np.concatenate([edges.u[keep].astype(np.int64), insert_u])
    v_all = np.concatenate([edges.v[keep].astype(np.int64), insert_v])
    n_kept = int(keep.sum())
    m_new = n_kept + n_ins
    n = max(edges.n, int(u_all.max(initial=-1)) + 1, int(v_all.max(initial=-1)) + 1)

    # Dirty region — it defines which vertices ever get queried, so the
    # incidence tables can be restricted to them (keeps the update cost
    # O(dirty-neighbourhood), not O(m)).  A churned *hub* vertex would mark
    # all of its (possibly thousands of) incident tasks dirty, making
    # "localized" refinement cost like a full pass — yet hubs are replicated
    # across most parts, so local moves around them almost never pay; tasks
    # are only marked dirty through touched vertices of degree <= cap.
    if not need_relevant:
        # The local gear derives its own (ring-based) dirty region and
        # incidence tables; skip the vertex-incident machinery entirely.
        return _ChurnSetup(
            m_old=m_old,
            m_new=m_new,
            n=n,
            n_kept=n_kept,
            n_ins=n_ins,
            n_deleted=n_deleted,
            u_all=u_all,
            v_all=v_all,
            lab_kept=labels[keep],
            insert_u=insert_u,
            insert_v=insert_v,
            dirty_idx=np.empty(0, dtype=np.int64),
            relevant=np.zeros(0, dtype=bool),
        )
    if dirty_degree_cap is None:
        avg_deg = 2.0 * m_new / max(n, 1)
        dirty_degree_cap = max(16, int(4 * avg_deg))
    t_arr = np.unique(np.concatenate(touched))
    if t_arr.size:
        deg = np.bincount(np.concatenate([u_all, v_all]), minlength=max(n, 1))
        t_capped = t_arr[deg[t_arr] <= dirty_degree_cap]
        is_touched = np.zeros(max(n, 1), dtype=bool)
        is_touched[t_capped] = True
        dirty_mask = is_touched[u_all] | is_touched[v_all]
    else:
        dirty_mask = np.zeros(m_new, dtype=bool)
    dirty_mask[n_kept:] = True  # inserted tasks always refine
    dirty_idx = np.flatnonzero(dirty_mask)

    relevant = np.zeros(max(n, 1), dtype=bool)
    relevant[u_all[dirty_mask]] = True
    relevant[v_all[dirty_mask]] = True
    relevant[t_arr] = True

    return _ChurnSetup(
        m_old=m_old,
        m_new=m_new,
        n=n,
        n_kept=n_kept,
        n_ins=n_ins,
        n_deleted=n_deleted,
        u_all=u_all,
        v_all=v_all,
        lab_kept=labels[keep],
        insert_u=insert_u,
        insert_v=insert_v,
        dirty_idx=dirty_idx,
        relevant=relevant,
    )


def _incremental_stats(
    cs: _ChurnSetup,
    k: int,
    sizes: np.ndarray,
    cap: float,
    moves: int,
    passes_run: int,
    t0: float,
    t1: float,
    t2: float,
    t3: float,
) -> IncrementalStats:
    avg = cs.m_new / k if k else 1.0
    return IncrementalStats(
        m_old=cs.m_old,
        m_new=cs.m_new,
        n_inserted=cs.n_ins,
        n_deleted=cs.n_deleted,
        n_dirty=int(cs.dirty_idx.shape[0]),
        moves=moves,
        passes_run=passes_run,
        dirty_fraction=(cs.n_ins + cs.n_deleted) / max(cs.m_new, 1),
        balance=float(sizes.max() / avg) if avg > 0 else 1.0,
        balance_ok=bool(sizes.max() <= cap),
        time_s=t3 - t0,
        dirty_s=t1 - t0,
        place_s=t2 - t1,
        refine_s=t3 - t2,
    )


def _place_insertions_batched(
    insert_u: np.ndarray,
    insert_v: np.ndarray,
    rel_of: np.ndarray,
    table: np.ndarray,
    sizes: np.ndarray,
    cap: float,
    k: int,
    m_new: int,
) -> np.ndarray:
    """Place all pending insertions in batched rounds; returns their labels.

    Each round scores every still-pending task against every part at once
    from the round-start snapshot of (table, sizes): vertex-cut delta via the
    dense incidence table, ties to the lightest part, then the lowest part
    id.  Claims are admitted per part in pending order with a prefix-count
    against the balance cap (exactly `_initial_partition`'s region-growing
    admission); unadmitted tasks retry next round against the updated state.
    The scalar reference mirrors these rounds item by item, which is what
    makes placement-only (``refine_passes=0``) runs byte-identical.
    """
    n_ins = int(insert_u.shape[0])
    new_labels = np.empty(n_ins, dtype=np.int64)
    if n_ins == 0:
        return new_labels
    pend = np.arange(n_ins, dtype=np.int64)
    # Composite lexicographic score (delta, sizes[p], p) packed into int64.
    w1 = np.int64((m_new + 1) * k)
    huge = np.int64(3) * w1
    part_ids = np.arange(k, dtype=np.int64)
    while pend.size:
        iu, iv = insert_u[pend], insert_v[pend]
        tu, tv = table[rel_of[iu]], table[rel_of[iv]]
        loop = iu == iv
        delta = (tu == 0).astype(np.int64) + ((~loop)[:, None] & (tv == 0))
        score = delta * w1 + sizes * np.int64(k) + part_ids
        score[:, sizes + 1 > cap] = huge
        claimed = np.argmin(score, axis=1)
        forced = score[np.arange(pend.size), claimed] >= huge
        if forced.any():  # no part under the cap — unreachable by the cap
            claimed[forced] = np.argmin(sizes)  # construction; kept as a valve
        order = np.argsort(claimed, kind="stable")  # pending order within part
        p_s = claimed[order]
        rank = segmented_cumsum(np.ones(p_s.size), run_first_mask(p_s))
        ok = forced[order] | (sizes[p_s] + rank <= cap)
        adm = order[ok]
        if adm.size == 0:  # safety valve, same shape as the scalar reference
            new_labels[pend[0]] = int(np.argmin(sizes))
            adm_p = new_labels[pend[:1]]
            ids = pend[:1]
        else:
            adm_p = claimed[adm]
            ids = pend[adm]
            new_labels[ids] = adm_p
        # Apply the round at its end — scores were against the snapshot.
        uu, vv = insert_u[ids], insert_v[ids]
        lp = uu == vv
        rows = np.concatenate([rel_of[uu], rel_of[vv][~lp]])
        parts = np.concatenate([adm_p, adm_p[~lp]])
        np.add.at(table.reshape(-1), rows * k + parts, 1)
        sizes += np.bincount(adm_p, minlength=k)
        sel = np.zeros(pend.size, dtype=bool)
        if adm.size == 0:
            sel[0] = True
        else:
            sel[adm] = True
        pend = pend[~sel]
    return new_labels


def _refine_dirty_batched(
    u_all: np.ndarray,
    v_all: np.ndarray,
    labels_all: np.ndarray,
    dirty_idx: np.ndarray,
    rel_of: np.ndarray,
    table: np.ndarray,
    sizes: np.ndarray,
    cap: float,
    k: int,
    passes: int,
) -> tuple[int, int]:
    """Whole-pass batched refinement of the dirty task set, in place.

    The task-side mirror of `partition._refine`: per pass, every dirty task
    scores all k destinations from the dense incidence table (replicas freed
    at the source minus replicas added at the destination), candidates are
    ordered overweight-escapes-first then by gain, and the shared engine
    admits the batch under the cap.  The table and sizes update
    incrementally — only moved tasks' endpoint rows change per pass.
    """
    moves = 0
    passes_run = 0
    de = dirty_idx
    if de.size == 0 or passes <= 0:
        return 0, 0
    du, dv = u_all[de], v_all[de]
    ru, rv = rel_of[du], rel_of[dv]
    loop = du == dv
    notloop_col = (~loop)[:, None]
    rows = np.arange(de.size)
    neg = np.int64(-100)  # sentinel far below any real gain (range [-2, 2])
    for _ in range(passes):
        passes_run += 1
        a = labels_all[de]
        tu, tv = table[ru], table[rv]
        freed = (tu[rows, a] == 1).astype(np.int64)
        freed += (~loop) & (tv[rows, a] == 1)
        gain = freed[:, None] - ((tu == 0).astype(np.int64) + (notloop_col & (tv == 0)))
        gain[rows, a] = neg
        full = sizes + 1 > cap
        if full.any():
            gain[:, full] = neg
        best_b = np.argmax(gain, axis=1)
        best_gain = gain[rows, best_b]
        over_row = (sizes > cap)[a]
        cand = np.flatnonzero((best_gain > 0) | (over_row & (best_gain > neg // 2)))
        if cand.size == 0:
            break
        cand = cand[np.lexsort((-best_gain[cand], ~over_row[cand]))]
        mv, dst = admit_batched_moves(
            de[cand],
            best_gain[cand].astype(np.float64),
            best_b[cand],
            a[cand],
            np.ones(cand.size),
            sizes.astype(np.float64),
            cap,
            over_row[cand],
        )
        if mv.size == 0:
            break
        old = labels_all[mv]
        labels_all[mv] = dst
        sizes += np.bincount(dst, minlength=k) - np.bincount(old, minlength=k)
        apply_task_moves(table, rel_of, u_all[mv], v_all[mv], old, dst)
        moves += int(mv.size)
    return moves, passes_run


def incremental_repartition(
    edges: EdgeList,
    labels: np.ndarray,
    k: int,
    insert_u: np.ndarray | None = None,
    insert_v: np.ndarray | None = None,
    delete_ids: np.ndarray | None = None,
    eps: float = 0.03,
    refine_passes: int = 3,
    slack: int = 1,
    dirty_degree_cap: int | None = None,
) -> tuple[EdgeList, np.ndarray, IncrementalStats]:
    """Repartition after a small edge-churn batch, touching only the dirty region.

    Returns ``(new_edges, new_labels, stats)`` where ``new_edges`` is the old
    task list minus ``delete_ids`` (order preserved) with insertions appended.
    Deleted tasks release their replicas; inserted tasks are placed in
    batched rounds in the part minimizing the vertex-cut delta (ties to the
    lightest part) under the cap ``(1+eps)*ceil(m_new/k) + slack``; then
    batched boundary refinement sweeps tasks incident to any churned vertex,
    admitting whole passes of positive-gain moves through the shared engine
    (`refine.admit_batched_moves`) — the same machinery the full multilevel
    refiner runs, restricted to the dirty task set.

    The pipeline is fully array-based: a dense ``(n_relevant, k)`` incidence
    table over a compacted index of relevant vertices (one bincount over
    packed keys) replaces the per-edge dict/set bookkeeping of
    :func:`incremental_repartition_reference`, which is retained as the
    scalar oracle — placement-only runs (``refine_passes=0``) produce
    byte-identical labels.

    ``delete_ids`` must be valid task indices in ``[0, edges.m)``; anything
    negative or past the end raises ``ValueError``.  ``dirty_degree_cap``
    bounds dirty-set expansion on skewed graphs (default:
    ``max(16, 4 * average_degree)``); inserted tasks are always refined.

    ``stats.balance_ok`` is False when the surviving distribution violates
    the cap (e.g. concentrated deletions shrank the target) — callers should
    fall back to a full run in that case, as `PartitionService.update` does.
    """
    t0 = time.perf_counter()
    cs = _churn_setup(edges, labels, insert_u, insert_v, delete_ids, dirty_degree_cap)
    cap = (1.0 + eps) * np.ceil(cs.m_new / k) + slack

    # Compacted relevant-vertex index + dense (n_rel, k) incidence table over
    # the kept labeling (one bincount over packed keys).
    rel_ids = np.flatnonzero(cs.relevant)
    rel_of = np.full(cs.relevant.shape[0], -1, dtype=np.int64)
    rel_of[rel_ids] = np.arange(rel_ids.size, dtype=np.int64)
    u_kept, v_kept = cs.u_all[: cs.n_kept], cs.v_all[: cs.n_kept]
    table = build_task_connectivity(rel_of, u_kept, v_kept, cs.lab_kept, k, rel_ids.size)
    sizes = np.bincount(cs.lab_kept, minlength=k).astype(np.int64)
    t1 = time.perf_counter()

    new_labels = _place_insertions_batched(
        cs.insert_u, cs.insert_v, rel_of, table, sizes, cap, k, cs.m_new
    )
    labels_all = np.concatenate([cs.lab_kept, new_labels])
    t2 = time.perf_counter()

    moves, passes_run = _refine_dirty_batched(
        cs.u_all, cs.v_all, labels_all, cs.dirty_idx, rel_of, table, sizes, cap, k, refine_passes
    )
    t3 = time.perf_counter()

    new_edges = EdgeList(n=cs.n, u=cs.u_all, v=cs.v_all)
    stats = _incremental_stats(cs, k, sizes, cap, moves, passes_run, t0, t1, t2, t3)
    return new_edges, labels_all.astype(np.int32), stats


def incremental_repartition_reference(
    edges: EdgeList,
    labels: np.ndarray,
    k: int,
    insert_u: np.ndarray | None = None,
    insert_v: np.ndarray | None = None,
    delete_ids: np.ndarray | None = None,
    eps: float = 0.03,
    refine_passes: int = 3,
    slack: int = 1,
    dirty_degree_cap: int | None = None,
) -> tuple[EdgeList, np.ndarray, IncrementalStats]:
    """Scalar oracle for :func:`incremental_repartition` (dict/set loops).

    Same contract and invariants as the batched pipeline: identical churned
    task list, balance cap respected, placement rounds item-for-item
    equivalent (so ``refine_passes=0`` labels are byte-identical).  The
    refinement loop applies moves one task at a time with immediate table
    updates — the pre-vectorization behaviour, kept as the property-test
    baseline for quality and balance.
    """
    t0 = time.perf_counter()
    cs = _churn_setup(edges, labels, insert_u, insert_v, delete_ids, dirty_degree_cap)
    cap = (1.0 + eps) * np.ceil(cs.m_new / k) + slack
    u_all, v_all, lab_kept = cs.u_all, cs.v_all, cs.lab_kept
    relevant, dirty_idx, n_ins = cs.relevant, cs.dirty_idx, cs.n_ins

    # Incidence tables over the kept labeling, for relevant vertices only:
    # cnt[v*k+p] = #incident tasks of v in part p (self-loops count once),
    # vparts[v] = parts with cnt>0.
    u_kept, v_kept = u_all[: cs.n_kept], v_all[: cs.n_kept]
    loop = u_kept == v_kept
    keys = np.concatenate(
        [
            (u_kept * k + lab_kept)[relevant[u_kept]],
            (v_kept * k + lab_kept)[relevant[v_kept] & ~loop],
        ]
    )
    uk, uc = np.unique(keys, return_counts=True)
    cnt: dict[int, int] = dict(zip(uk.tolist(), uc.tolist()))
    vparts: dict[int, set] = collections.defaultdict(set)
    for key in uk.tolist():
        vparts[key // k].add(key % k)
    sizes = np.bincount(lab_kept, minlength=k).astype(np.int64)

    def _add(uu: int, vv: int, p: int) -> None:
        for w in (uu,) if uu == vv else (uu, vv):
            key = _count_key(w, p, k)
            c = cnt.get(key, 0)
            cnt[key] = c + 1
            if c == 0:
                vparts[w].add(p)

    def _remove(uu: int, vv: int, p: int) -> None:
        for w in (uu,) if uu == vv else (uu, vv):
            key = _count_key(w, p, k)
            c = cnt[key] - 1
            if c == 0:
                del cnt[key]
                vparts[w].discard(p)
            else:
                cnt[key] = c

    t1 = time.perf_counter()

    # --- placement: the scalar mirror of `_place_insertions_batched`'s
    # rounds (min vertex-cut delta, tie lightest then lowest part; per-part
    # prefix-count admission against the round-start snapshot) ---
    insert_u, insert_v = cs.insert_u, cs.insert_v
    new_labels = np.empty(n_ins, dtype=np.int64)
    pending = list(range(n_ins))
    while pending:
        snap = sizes.copy()
        claim_count = [0] * k
        admitted: list[tuple[int, int]] = []
        for i in pending:
            uu, vv = int(insert_u[i]), int(insert_v[i])
            best_key, best_p = None, -1
            for p in range(k):
                if snap[p] + 1 > cap:
                    continue
                delta = (cnt.get(uu * k + p, 0) == 0) + (
                    0 if uu == vv else (cnt.get(vv * k + p, 0) == 0)
                )
                score = (delta, int(snap[p]), p)
                if best_key is None or score < best_key:
                    best_key, best_p = score, p
            forced = best_p < 0
            if forced:  # no part under the cap — unreachable, kept as a valve
                best_p = int(np.argmin(snap))
            claim_count[best_p] += 1
            if forced or snap[best_p] + claim_count[best_p] <= cap:
                admitted.append((i, best_p))
        if not admitted:  # safety valve, same shape as the batched engine
            admitted.append((pending[0], int(np.argmin(snap))))
        for i, p in admitted:
            uu, vv = int(insert_u[i]), int(insert_v[i])
            new_labels[i] = p
            _add(uu, vv, p)
            sizes[p] += 1
        done = {i for i, _ in admitted}
        pending = [i for i in pending if i not in done]

    labels_all = np.concatenate([lab_kept, new_labels])
    t2 = time.perf_counter()

    # --- localized boundary refinement over the dirty region only ---
    moves = 0
    passes_run = 0
    cnt_get = cnt.get
    cand_cap = 16  # a hub present in >cap parts contributes no candidates:
    # moving a task into one of the hub's many parts barely changes the
    # hub's replica count — the gain lives in the low-degree endpoint.
    for _ in range(refine_passes):
        passes_run += 1
        pass_moves = 0
        for e in dirty_idx:
            a = int(labels_all[e])
            uu, vv = int(u_all[e]), int(v_all[e])
            is_loop = uu == vv
            pu, pv = vparts[uu], vparts[vv]
            if len(pu) > cand_cap:
                cand = pv if len(pv) <= cand_cap else ()
            elif len(pv) > cand_cap:
                cand = pu
            else:
                cand = pu | pv
            over_a = sizes[a] > cap
            # Replicas freed by leaving part a — invariant over candidates.
            ua, va = uu * k + a, vv * k + a
            freed = (cnt_get(ua, 0) == 1) + (0 if is_loop else cnt_get(va, 0) == 1)
            best_b, best_gain = -1, 0
            for b in cand:
                if b == a or sizes[b] + 1 > cap:
                    continue
                added = (cnt_get(uu * k + b, 0) == 0) + (
                    0 if is_loop else cnt_get(vv * k + b, 0) == 0
                )
                gain = freed - added
                if gain > best_gain or (over_a and best_b < 0 and gain >= best_gain):
                    best_b, best_gain = b, gain
            if over_a and best_b < 0:
                b = int(np.argmin(sizes))
                if b != a and sizes[b] + 1 <= cap:
                    best_b = b
            if best_b >= 0 and (best_gain > 0 or over_a):
                _remove(uu, vv, a)
                _add(uu, vv, best_b)
                sizes[a] -= 1
                sizes[best_b] += 1
                labels_all[e] = best_b
                pass_moves += 1
        moves += pass_moves
        if pass_moves == 0:
            break
    t3 = time.perf_counter()

    new_edges = EdgeList(n=cs.n, u=u_all, v=v_all)
    stats = _incremental_stats(cs, k, sizes, cap, moves, passes_run, t0, t1, t2, t3)
    return new_edges, labels_all.astype(np.int32), stats


def local_repartition(
    edges: EdgeList,
    labels: np.ndarray,
    k: int,
    insert_u: np.ndarray | None = None,
    insert_v: np.ndarray | None = None,
    delete_ids: np.ndarray | None = None,
    eps: float = 0.03,
    opts: MultilevelOptions | None = None,
    seed: int = 0,
    halo_hops: int = 0,
    slack: int = 1,
    dirty_degree_cap: int | None = None,
    polish_passes: int | None = None,
) -> tuple[EdgeList, np.ndarray, IncrementalStats]:
    """Repartition after a churn batch by re-coarsening only the dirty region.

    The mid-churn gear between :func:`incremental_repartition` (single-level
    refinement, quality decays past ~1-2% churn) and a full rebuild (6-12x
    the work when most of the graph is untouched).  The churn front half is
    shared with the incremental path (`_churn_setup` + batched insertion
    placement); then a **local V-cycle** (:func:`partition._local_vcycle`)
    re-coarsens the dirty region of the method-"ep" task graph — one node
    per task, so task labels project back directly.  Labels outside the
    dirty region are frozen as per-part anchor super-vertices that pin the
    global balance cap; the dirty subgraph is re-coarsened with the anchors
    pinned, seeded from the current labels, and refined through the batched
    engine at every level.  A short vertex-cut polish
    (:func:`_refine_dirty_batched`, the incremental gear's sweep) runs last:
    the V-cycle optimizes the clone-graph edge cut, which only *bounds* the
    §3.1 vertex cut, and the direct sweep reliably claws back 5-15% of it.

    The dirty region is seeded from the churned tasks themselves — inserted
    tasks plus each deletion's ring scars (the former incidence-ring
    neighbours a deletion leaves newly adjacent) — then grown ``halo_hops``
    rings over the task graph, whose degree is ~4 (two ring neighbours per
    endpoint), so the region stays proportional to the churn batch.  The
    churn-setup's vertex-incident dirty set (right for the single-level
    sweep) is *not* used: every touched vertex would mark all of its
    incident tasks, so at 5% churn on a degree-20 graph it covers most of
    the task list and the "local" V-cycle degenerates into a full one.

    The local graph is assembled directly from the incidence-ring pair list
    (one stable argsort over the churned endpoints — the same ordering
    ``transform.contracted_clone_graph`` uses), never materializing the full
    task graph: ring-consecutive pairs with at least one dirty endpoint
    become local edges (frozen endpoints collapse to their part's anchor),
    frozen-frozen pairs are a constant of the optimization and are dropped.

    Returns ``(new_edges, new_labels, stats)`` with ``stats.gear ==
    "local"``.  ``stats.balance_ok`` False means the frozen weight alone
    breaks the cap — escalate to a full rebuild, as the service's gear
    policy does.
    """
    t0 = time.perf_counter()
    cs = _churn_setup(
        edges, labels, insert_u, insert_v, delete_ids, dirty_degree_cap,
        need_relevant=False,
    )
    cap = (1.0 + eps) * np.ceil(cs.m_new / k) + slack

    # Placement only queries the inserted endpoints' incidence rows, so the
    # table is restricted to them (not the churn-setup's full relevant set —
    # the polish sweep builds its own table over the final dirty region).
    rel_mask = np.zeros(max(cs.n, 1), dtype=bool)
    rel_mask[cs.insert_u] = True
    rel_mask[cs.insert_v] = True
    rel_ids = np.flatnonzero(rel_mask)
    rel_of = np.full(rel_mask.shape[0], -1, dtype=np.int64)
    rel_of[rel_ids] = np.arange(rel_ids.size, dtype=np.int64)
    u_kept, v_kept = cs.u_all[: cs.n_kept], cs.v_all[: cs.n_kept]
    table = build_task_connectivity(rel_of, u_kept, v_kept, cs.lab_kept, k, rel_ids.size)
    sizes = np.bincount(cs.lab_kept, minlength=k).astype(np.int64)
    t1 = time.perf_counter()

    new_labels = _place_insertions_batched(
        cs.insert_u, cs.insert_v, rel_of, table, sizes, cap, k, cs.m_new
    )
    labels_all = np.concatenate([cs.lab_kept, new_labels])
    t2 = time.perf_counter()

    # --- dirty region + new-ring pairs, from ONE old-ring argsort ---
    # The old clone list's stable argsort gives every vertex's incidence
    # ring.  Deleting a task deletes ring slots; kept clones stay in sorted
    # order (``old_to_new`` is monotone, parity is preserved), so the
    # churned ring is the kept slots MERGED with the (tiny, sorted) inserted
    # clone list via one searchsorted — no second full-size argsort.
    dirty_mask = np.zeros(cs.m_new, dtype=bool)
    dirty_mask[cs.n_kept:] = True
    clone_vertex = np.empty(2 * cs.m_old, dtype=np.int32)
    clone_vertex[0::2] = edges.u
    clone_vertex[1::2] = edges.v
    ring = np.argsort(clone_vertex, kind="stable")
    ring_vertex = clone_vertex[ring]
    ring_task = ring >> 1
    deleted = np.zeros(cs.m_old, dtype=bool)
    if cs.n_deleted:
        deleted[np.unique(np.asarray(delete_ids, dtype=np.int64))] = True
    old_to_new = np.cumsum(~deleted) - 1  # kept tasks keep their order
    if cs.n_deleted:
        # Ring scars: the surviving neighbours a deleted slot leaves newly
        # adjacent (consecutive deletions chain — both survivors still flank
        # some deleted slot, so both are caught here).
        del_slots = np.flatnonzero(deleted[ring_task])
        for off in (-1, 1):
            nb = del_slots + off
            ok = (nb >= 0) & (nb < ring.size)
            nb, slots = nb[ok], del_slots[ok]
            same = ring_vertex[nb] == ring_vertex[slots]
            scar = ring_task[nb[same]]
            scar = scar[~deleted[scar]]
            dirty_mask[old_to_new[scar]] = True

    kept_slot = ~deleted[ring_task]
    kept_vert = ring_vertex[kept_slot]
    kept_clone = (old_to_new[ring_task[kept_slot]] << 1) | (ring[kept_slot] & 1)
    if cs.n_ins:
        ins_vert = np.empty(2 * cs.n_ins, dtype=np.int32)
        ins_vert[0::2] = cs.insert_u
        ins_vert[1::2] = cs.insert_v
        io_ = np.argsort(ins_vert, kind="stable")
        # Inserted clone j is new clone 2*n_kept + j; inserted tasks sort
        # after every kept task of the same vertex (their ids are larger),
        # so side="right" keeps the merge stable.
        pos = np.searchsorted(kept_vert, ins_vert[io_], side="right")
        total = kept_vert.size + io_.size
        ins_at = pos + np.arange(io_.size, dtype=np.int64)
        kept_at = np.ones(total, dtype=bool)
        kept_at[ins_at] = False
        merged_vert = np.empty(total, dtype=np.int32)
        merged_clone = np.empty(total, dtype=np.int64)
        merged_vert[kept_at] = kept_vert
        merged_vert[ins_at] = ins_vert[io_]
        merged_clone[kept_at] = kept_clone
        merged_clone[ins_at] = 2 * cs.n_kept + io_
    else:
        merged_vert, merged_clone = kept_vert, kept_clone

    # Ring-consecutive pairs of the churned task list == task-graph edges.
    same_new = merged_vert[:-1] == merged_vert[1:]
    pa = merged_clone[:-1][same_new] >> 1
    pb = merged_clone[1:][same_new] >> 1
    for _ in range(max(0, halo_hops)):
        touch = dirty_mask[pa] | dirty_mask[pb]
        dirty_mask[pa[touch]] = True
        dirty_mask[pb[touch]] = True

    # --- assemble the local graph: dirty tasks + per-part anchors ---
    dirty_ids = np.flatnonzero(dirty_mask)
    nd = int(dirty_ids.size)
    frozen_count = np.bincount(labels_all[~dirty_mask], minlength=k)
    anchor_parts = np.flatnonzero(frozen_count > 0)
    n_anchor = int(anchor_parts.size)
    n_local = nd + n_anchor
    anchor_of = np.full(k, -1, dtype=np.int64)
    anchor_of[anchor_parts] = nd + np.arange(n_anchor, dtype=np.int64)
    task_local = np.empty(cs.m_new, dtype=np.int64)
    task_local[dirty_ids] = np.arange(nd, dtype=np.int64)
    task_local[~dirty_mask] = anchor_of[labels_all[~dirty_mask]]
    keep_pair = dirty_mask[pa] | dirty_mask[pb]
    vw = np.ones(n_local, dtype=np.int64)
    vw[nd:] = frozen_count[anchor_parts]
    # Parallel local edges are left as-is (dedupe=False): the refinement
    # tables and contraction histograms sum them exactly like a merged edge,
    # and the next coarsening level dedupes anyway.
    local_g = csr_from_edges(
        n_local, task_local[pa[keep_pair]], task_local[pb[keep_pair]],
        vweights=vw, dedupe=False,
    )
    pinned = np.zeros(n_local, dtype=bool)
    pinned[nd:] = True
    lab_local = np.empty(n_local, dtype=np.int64)
    lab_local[task_local] = labels_all  # anchors are per-part: scatter is exact
    t3 = time.perf_counter()

    # --- local V-cycle + vertex-cut polish over the dirty tasks ---
    # Lighter default pass counts than a cold build: the V-cycle starts from
    # an already-good seed and the vertex-cut polish below catches residue.
    vopts = (
        opts
        if opts is not None
        else MultilevelOptions(
            seed=seed, refine_passes=3, coarsest_refine_passes=5, cluster_rounds=1
        )
    )
    rng = np.random.default_rng(vopts.seed)
    before = labels_all[dirty_ids].copy()
    lab, levels, _level_stats, coarsen_s, _ref_s = _local_vcycle(
        local_g, lab_local, pinned, k, cap, vopts, rng
    )
    labels_all[dirty_ids] = lab[:nd]
    t4 = time.perf_counter()

    rel2 = np.zeros(max(cs.n, 1), dtype=bool)
    rel2[cs.u_all[dirty_mask]] = True
    rel2[cs.v_all[dirty_mask]] = True
    rel2_ids = np.flatnonzero(rel2)
    rel2_of = np.full(rel2.shape[0], -1, dtype=np.int64)
    rel2_of[rel2_ids] = np.arange(rel2_ids.size, dtype=np.int64)
    table2 = build_task_connectivity(
        rel2_of, cs.u_all, cs.v_all, labels_all, k, rel2_ids.size
    )
    sizes2 = np.bincount(labels_all, minlength=k).astype(np.int64)
    if polish_passes is None:
        # Small batches leave a near-optimal V-cycle seed — one sweep
        # converges; past ~6% churn the extra residue makes a second pass
        # pay for its candidate scan (measured on the bench graph family).
        churn_frac = (cs.n_ins + cs.n_deleted) / max(cs.m_new, 1)
        polish_passes = 1 if churn_frac <= 0.06 else 2
    pol_moves, pol_passes = _refine_dirty_batched(
        cs.u_all, cs.v_all, labels_all, dirty_ids, rel2_of, table2, sizes2,
        cap, k, polish_passes,
    )
    t5 = time.perf_counter()

    new_edges = EdgeList(n=cs.n, u=cs.u_all, v=cs.v_all)
    avg = cs.m_new / k if k else 1.0
    moved = int((labels_all[dirty_ids] != before).sum())
    stats = IncrementalStats(
        m_old=cs.m_old,
        m_new=cs.m_new,
        n_inserted=cs.n_ins,
        n_deleted=cs.n_deleted,
        n_dirty=nd,
        moves=moved,
        passes_run=int(pol_passes),
        dirty_fraction=(cs.n_ins + cs.n_deleted) / max(cs.m_new, 1),
        balance=float(sizes2.max() / avg) if avg > 0 else 1.0,
        balance_ok=bool(sizes2.max() <= cap),
        time_s=t5 - t0,
        dirty_s=(t1 - t0) + (t3 - t2),
        place_s=t2 - t1,
        refine_s=(t4 - t3 - coarsen_s) + (t5 - t4),
        gear="local",
        coarsen_s=coarsen_s,
        levels=levels,
    )
    return new_edges, labels_all.astype(np.int32), stats


# ---------------------------------------------------------------------------
# Gear policy: drift-gated choice of incremental / local / full
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class GearPolicy:
    """Drift-gated selection among the three update gears.

    The drift estimate for an update is the base plan's accumulated drift
    (carried on ``ServicePlan.drift``: incremental updates inherit and grow
    it, local/full rebuilds reset it to 0) plus the batch's churn fraction —
    so a stream of small batches escalates exactly like one large batch.

    Thresholds are measured, not principled: on the bench graph families the
    incremental gear's cut tracks a rebuild to ~2% cumulative churn, and
    past ~15% the local gear's drift against a same-run rebuild climbs
    toward the quality ceiling while its speedup decays toward ~2x — the
    dirty region stops being "local" — so the top of the churn band goes to
    a full rebuild (see docs/serving.md, "Churn & repartition policy").
    Note the drift estimate for a pure-churn batch of rate r lands at
    ~r/(1 + r/2), not r (deletions do not grow ``m``), so the threshold is
    calibrated against the estimate, not the nominal rate.

    Quality escalation is independent of the thresholds: an incremental
    result whose cut grew past ``cut_growth_limit`` x the base plan's
    recorded cut (or broke balance) escalates to local; a local result that
    cannot restore balance (frozen weight alone over the cap) escalates to
    full.
    """

    incremental_max_drift: float = 0.02
    local_max_drift: float = 0.15
    cut_growth_limit: float = 1.10
    # Task-graph halo rings around the churn seed.  0 (seed only: inserted
    # tasks + deletion scars) measures fastest and the vertex-cut polish
    # recovers what a wider region would; raise it when churn is spatially
    # clustered and the repair needs room to move the surrounding boundary.
    halo_hops: int = 0

    def __post_init__(self) -> None:
        if not 0.0 <= self.incremental_max_drift <= self.local_max_drift:
            raise ValueError(
                "need 0 <= incremental_max_drift <= local_max_drift, got "
                f"{self.incremental_max_drift} / {self.local_max_drift}"
            )
        if self.cut_growth_limit < 1.0:
            raise ValueError(
                f"cut_growth_limit must be >= 1.0, got {self.cut_growth_limit}"
            )
        if self.halo_hops < 0:
            raise ValueError(f"halo_hops must be >= 0, got {self.halo_hops}")

    def pick(self, drift: float) -> str:
        if drift <= self.incremental_max_drift:
            return "incremental"
        if drift <= self.local_max_drift:
            return "local"
        return "full"


# ---------------------------------------------------------------------------
# Service plumbing: plans, double buffer, stats
# ---------------------------------------------------------------------------


def _payload_nbytes(obj) -> int:
    """Deterministic size estimate of a JSON-shaped stats payload.

    The plan cache's byte budgets must account for *everything* a cached
    plan pins, including the ``vcycle``/``stage_times_s`` dict payloads —
    a deep V-cycle's per-level records are real memory.  CPython object
    headers vary across builds, so this uses fixed per-node costs (close to
    64-bit CPython's) rather than ``sys.getsizeof``: the estimate must be
    stable for the eviction tests and the committed bench baselines.
    """
    if obj is None:
        return 0
    if isinstance(obj, (bool, int, float)):
        return 8
    if isinstance(obj, str):
        return 49 + len(obj)
    if isinstance(obj, np.ndarray):
        return int(obj.nbytes)
    if isinstance(obj, (list, tuple)):
        return 56 + sum(_payload_nbytes(x) for x in obj)
    if isinstance(obj, dict):
        return 64 + sum(
            _payload_nbytes(k) + _payload_nbytes(v) for k, v in obj.items()
        )
    return 48


@dataclasses.dataclass(frozen=True)
class PlanPadding:
    """Padded-shape metadata of a plan's cpack tiles (§4.1 layout).

    Everything the serve path's shape-bucketing needs to pick a compile
    bucket *without* touching the PackPlan arrays: the logical matrix dims,
    the true nnz, and the 128-aligned per-cluster tile ceilings.  Carried on
    :class:`ServicePlan` so bucket selection is O(1) per request.
    """

    pad: int
    k: int
    n_rows: int
    n_cols: int
    nnz: int
    e_max: int
    x_max: int
    y_max: int

    @classmethod
    def from_plan(cls, plan: PackPlan, pad: int) -> "PlanPadding":
        return cls(
            pad=pad,
            k=plan.k,
            n_rows=plan.n_rows,
            n_cols=plan.n_cols,
            nnz=int(plan.e_count.sum()),
            e_max=plan.e_max,
            x_max=plan.x_max,
            y_max=plan.y_max,
        )


@dataclasses.dataclass(frozen=True)
class ServicePlan:
    """One cached unit of partitioning work: labels (+ optional PackPlan)."""

    fingerprint: str
    result: EdgePartitionResult
    plan: Optional[PackPlan]
    edges: EdgeList
    source: str  # "full" | "incremental" | "local" — the gear that built it
    compute_time_s: float
    coo: Optional[tuple] = None  # (n_rows, n_cols, rows, cols) for SpMV plans
    # Padded-shape metadata of the PackPlan tiles (set iff plan is set) —
    # what the serve path's bucketed compilation keys on.
    padding: Optional[PlanPadding] = None
    # Per-stage wall times of the cold path (coarsen/init/refine/partition/
    # pack for full runs; incremental/pack for churn updates), so serving
    # dashboards see where compute_time_s goes.  Values are seconds, always.
    stage_times_s: Optional[dict] = None
    # V-cycle shape of a full multilevel run (levels, coarsest_n,
    # coarsen_mode, per-level records) — kept apart from stage_times_s so
    # that mapping stays a flat {stage: seconds}.
    vcycle: Optional[dict] = None
    # Base-plan fingerprint for incrementally-derived plans: the plan cache
    # refcounts these so a churn stream's base survives eviction.
    lineage: Optional[str] = None
    # Accumulated drift since the last multilevel pass over this graph:
    # incremental updates inherit the base's drift plus their churn
    # fraction; local and full rebuilds reset it to 0.  The gear policy
    # gates on it (see GearPolicy).
    drift: float = 0.0

    def nbytes(self) -> int:
        """Host-side bytes this plan pins — the unit of cache budgeting.

        Counts the labels, the task list, the PackPlan tiles, the COO
        arrays retained for SpMV re-pack, and the stats payloads
        (``stage_times_s``/``vcycle`` — the per-level V-cycle records grew
        real weight in PR 4 and budget accounting must see them).
        """
        b = self.result.labels.nbytes + self.edges.u.nbytes + self.edges.v.nbytes
        if self.plan is not None:
            b += self.plan.nbytes()
        if self.coo is not None:
            _, _, rows, cols = self.coo
            b += getattr(rows, "nbytes", 8) + getattr(cols, "nbytes", 8)
        b += _payload_nbytes(self.stage_times_s) + _payload_nbytes(self.vcycle)
        return b


class DoubleBuffer:
    """Two-slot atomic handoff: the compute path reads ``current()`` while the
    optimization thread builds into the back slot and ``publish``es with a
    front/back swap — the §4.2 schedule-swap, no torn reads, no locks held
    during compute."""

    def __init__(self) -> None:
        self._slots: list[Optional[ServicePlan]] = [None, None]
        self._front = 0
        self._generation = 0
        self._lock = threading.Lock()

    def publish(self, value: ServicePlan) -> int:
        with self._lock:
            back = 1 - self._front
            self._slots[back] = value
            self._front = back
            self._generation += 1
            return self._generation

    def current(self) -> tuple[Optional[ServicePlan], int]:
        with self._lock:
            return self._slots[self._front], self._generation


@dataclasses.dataclass
class ServiceStats:
    hits: int = 0
    misses: int = 0
    full_runs: int = 0
    incremental_runs: int = 0
    local_runs: int = 0
    # Updates whose chosen gear escalated on its own quality signal
    # (incremental -> local on cut growth / balance, local -> full on
    # unrecoverable balance).
    incremental_fallbacks: int = 0
    evictions: int = 0
    lookup_time_s: float = 0.0
    compute_time_s: float = 0.0


# ---------------------------------------------------------------------------
# Worker jobs — module-level pure functions over picklable request records,
# so the scheduler's process executor can ship them to spawned workers (the
# GIL serializes CPU-bound numpy across threads; real cold-plan parallelism
# needs processes).  All service-state side effects (stats, cache, memo)
# happen in the facade's on_done callbacks, never here.
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class _FullRequest:
    fingerprint: str
    edges: EdgeList
    k: int
    method: str
    opts: MultilevelOptions | None
    seed: int
    pad: int
    coo: Optional[tuple]


def _full_plan_job(req: _FullRequest) -> tuple[ServicePlan, dict]:
    t0 = time.perf_counter()
    result = edge_partition(req.edges, req.k, method=req.method, opts=req.opts, seed=req.seed)
    t_part = time.perf_counter() - t0
    plan = None
    padding = None
    if req.coo is not None:
        n_rows, n_cols, rows, cols = req.coo
        plan = build_pack_plan(n_rows, n_cols, rows, cols, result.labels, req.k, pad=req.pad)
        padding = PlanPadding.from_plan(plan, req.pad)
    dt = time.perf_counter() - t0
    stage_times = {"partition": t_part, "pack": dt - t_part}
    vcycle = None
    if result.stats is not None:
        stage_times.update(_multilevel_stage_times(result.stats))
        vcycle = _vcycle_shape(result.stats)
    sp = ServicePlan(
        fingerprint=req.fingerprint,
        result=result,
        plan=plan,
        edges=req.edges,
        source="full",
        compute_time_s=dt,
        coo=req.coo,
        padding=padding,
        stage_times_s=stage_times,
        vcycle=vcycle,
    )
    return sp, {"kind": "full"}


@dataclasses.dataclass
class _UpdateRequest:
    churn_key: str
    base: ServicePlan
    k: int
    insert_u: np.ndarray
    insert_v: np.ndarray
    delete_ids: np.ndarray
    pad: int
    method: str
    opts: MultilevelOptions | None
    seed: int
    eps: float
    policy: GearPolicy
    refine_passes: int


def _update_plan_job(req: _UpdateRequest) -> tuple[ServicePlan, dict]:
    t0 = time.perf_counter()
    base = req.base
    policy = req.policy
    insert_u, insert_v, delete_ids = req.insert_u, req.insert_v, req.delete_ids
    n_churn = len(insert_u) + len(delete_ids)
    m_new_est = max(base.edges.m + n_churn, 1)
    # Drift estimate: the base plan's accumulated drift (0.0 on plans from
    # before the field existed, via getattr) plus this batch's churn
    # fraction — a stream of small batches escalates like one big batch.
    drift_est = float(getattr(base, "drift", 0.0)) + n_churn / m_new_est
    gear = policy.pick(drift_est)
    if gear == "local" and req.method != "ep":
        # The local V-cycle runs on the method-"ep" task graph (node == task);
        # other methods have no such identification, so they skip the gear.
        gear = "full"
    new_edges, labels, inc = None, None, None
    result = None
    escalated = False
    gear_times: dict = {}
    stage_times: dict = {}
    vcycle = None
    base_cut = float(base.result.quality.vertex_cut)

    if gear == "incremental":
        tg = time.perf_counter()
        new_edges, labels, inc = incremental_repartition(
            base.edges,
            base.result.labels,
            req.k,
            insert_u=insert_u,
            insert_v=insert_v,
            delete_ids=delete_ids,
            eps=req.eps,
            refine_passes=req.refine_passes,
        )
        quality = evaluate_edge_partition(new_edges, labels, req.k)
        gear_times["incremental"] = time.perf_counter() - tg
        # The incremental path's own quality signal: cut delta vs. the base
        # plan's recorded cut, and the balance invariant.
        cut_ok = quality.vertex_cut <= policy.cut_growth_limit * max(base_cut, 1.0)
        if inc.balance_ok and cut_ok:
            result = EdgePartitionResult(
                labels=labels,
                k=req.k,
                method=f"{req.method}+incremental",
                quality=quality,
                partition_time_s=inc.time_s,
            )
            stage_times["incremental"] = inc.time_s
            stage_times.update(
                inc_dirty=inc.dirty_s,
                inc_place=inc.place_s,
                inc_refine=inc.refine_s,
            )
        else:
            gear = "local" if req.method == "ep" else "full"
            escalated = True

    if result is None and gear == "local":
        tg = time.perf_counter()
        new_edges, labels, inc = local_repartition(
            base.edges,
            base.result.labels,
            req.k,
            insert_u=insert_u,
            insert_v=insert_v,
            delete_ids=delete_ids,
            eps=req.eps,
            opts=req.opts,
            seed=req.seed,
            halo_hops=policy.halo_hops,
        )
        gear_times["local"] = time.perf_counter() - tg
        if inc.balance_ok:
            quality = evaluate_edge_partition(new_edges, labels, req.k)
            result = EdgePartitionResult(
                labels=labels,
                k=req.k,
                method=f"{req.method}+local",
                quality=quality,
                partition_time_s=inc.time_s,
            )
            stage_times["local"] = inc.time_s
            stage_times.update(
                loc_dirty=inc.dirty_s,
                loc_place=inc.place_s,
                loc_coarsen=inc.coarsen_s,
                loc_refine=inc.refine_s,
            )
        else:
            gear = "full"
            escalated = True

    if result is None:
        gear = "full"
        tg = time.perf_counter()
        if new_edges is None:
            new_edges, labels, _ = incremental_repartition(
                base.edges,
                base.result.labels,
                req.k,
                insert_u=insert_u,
                insert_v=insert_v,
                delete_ids=delete_ids,
                eps=req.eps,
                refine_passes=0,
            )
        result = edge_partition(new_edges, req.k, method=req.method, opts=req.opts, seed=req.seed)
        labels = result.labels
        gear_times["full"] = time.perf_counter() - tg
        stage_times["partition"] = result.partition_time_s
        if result.stats is not None:
            stage_times.update(_multilevel_stage_times(result.stats))
            vcycle = _vcycle_shape(result.stats)

    source = gear
    # Per-gear wall times of every gear *attempted* this update (an
    # escalated attempt's cost is real and shows up here), plus the final
    # decision on the stats record.
    for gname, gt in gear_times.items():
        stage_times[f"gear_{gname}"] = gt
    if inc is not None:
        inc.gear = source
        inc.drift = drift_est
    plan = None
    coo = None
    padding = None
    t_pack0 = time.perf_counter()
    if base.coo is not None:
        n_rows, n_cols, _, _ = base.coo
        # Affinity convention: u = column vertex, v = n_cols + row.
        rows = (new_edges.v - n_cols).astype(np.int64)
        cols = new_edges.u.astype(np.int64)
        coo = (n_rows, n_cols, rows, cols)
        plan = build_pack_plan(n_rows, n_cols, rows, cols, labels, req.k, pad=req.pad)
        padding = PlanPadding.from_plan(plan, req.pad)
    stage_times["pack"] = time.perf_counter() - t_pack0
    # Content fingerprint of the post-churn graph — hashed here on the
    # worker so the request path stays O(churn), not O(m).
    extra = (base.coo[0], base.coo[1]) if base.coo is not None else ()
    fingerprint = graph_fingerprint(
        new_edges, req.k, req.pad, req.opts, req.method, req.seed, extra
    )
    dt = time.perf_counter() - t0
    sp = ServicePlan(
        fingerprint=fingerprint,
        result=result,
        plan=plan,
        edges=new_edges,
        source=source,
        compute_time_s=dt,
        coo=coo,
        padding=padding,
        stage_times_s=stage_times,
        vcycle=vcycle,
        lineage=base.fingerprint if source in ("incremental", "local") else None,
        # Incremental updates accumulate drift; local and full rebuilds ran
        # a (local) V-cycle over everything that drifted, so they reset it.
        drift=drift_est if source == "incremental" else 0.0,
    )
    return sp, {
        "kind": "update",
        "source": source,
        "fallback": escalated,
        "churn_key": req.churn_key,
    }


# ---------------------------------------------------------------------------
# The service facade
# ---------------------------------------------------------------------------


class PartitionService:
    """Thin facade: `PlanScheduler` (workers) + `PlanCache` (tenant budgets).

    Synchronous fast path: ``get``/``get_spmv_plan`` return a cached plan in
    O(fingerprint) time on a warm hit; on a miss the request is computed on
    the worker pool (callers block on the ticket — use ``submit`` /
    ``update_async`` to overlap with compute, per §4.2).  Every request may
    carry ``tenant=`` (cache accounting + budget isolation) and
    ``priority=`` (queue ordering; higher first).

    ``workers``/``executor`` size the pool: the default single thread
    matches PR 1's behavior; ``executor="process"`` buys real cold-plan
    parallelism for multi-worker pools (partitioning is CPU-bound and the
    GIL serializes threads).  ``persist_path`` warms the cache from a prior
    snapshot at construction and saves it on ``close()``.
    """

    def __init__(
        self,
        max_entries: int = 64,
        max_bytes: int | None = None,
        eps: float = 0.03,
        churn_threshold: float = 0.15,
        refine_passes: int = 3,
        gear_policy: GearPolicy | None = None,
        default_opts: MultilevelOptions | None = None,
        start: bool = True,
        workers: int = 1,
        executor: str = "thread",
        tenant_budgets: dict[str, int] | None = None,
        default_tenant_budget: int | None = None,
        persist_path: str | None = None,
        max_pinned_bases: int = 16,
        max_queue_depth: int | None = None,
        tenant_weights: dict[str, float] | None = None,
    ) -> None:
        self.max_entries = max_entries
        self.max_bytes = max_bytes
        self.eps = eps
        self.churn_threshold = churn_threshold
        self.refine_passes = refine_passes
        # Gear selection for plan updates.  ``churn_threshold`` survives as
        # the shorthand knob: it bounds the *cheap* gears from above (drift
        # past it -> full rebuild), exactly its historical meaning, with the
        # incremental/local split handled by the policy's inner threshold.
        self.gear_policy = gear_policy or GearPolicy(
            incremental_max_drift=min(
                GearPolicy.incremental_max_drift, churn_threshold
            ),
            local_max_drift=churn_threshold,
        )
        self.default_opts = default_opts
        self.persist_path = persist_path
        self.stats = ServiceStats()
        self._cache = PlanCache(
            max_entries=max_entries,
            max_bytes=max_bytes,
            tenant_budgets=tenant_budgets,
            default_tenant_budget=default_tenant_budget,
        )
        self._sched = PlanScheduler(
            workers=workers, executor=executor, name="partition-service",
            max_queue_depth=max_queue_depth, tenant_weights=tenant_weights,
        )
        # churn-request key -> content fingerprint of the resulting plan, so
        # a repeated identical update is a cache hit without re-applying the
        # churn (the request key is O(churn) to compute, see update_async).
        self._churn_memo: collections.OrderedDict[str, str] = collections.OrderedDict()
        # LRU of churn-stream anchors currently pinned in the cache (see
        # update_async): bounds pin accumulation at max_pinned_bases — an
        # active stream refreshes its anchor every update, a dead stream's
        # anchor expires once enough newer anchors appear.
        self.max_pinned_bases = max_pinned_bases
        self._pinned_bases: collections.OrderedDict[str, None] = collections.OrderedDict()
        self._lock = threading.RLock()
        self._closed = False
        if persist_path and os.path.exists(persist_path):
            self._cache.load(persist_path)
            self._adopt_restored_pins()
        if start:
            self.start()

    def _adopt_restored_pins(self) -> None:
        """Fold pins restored from a snapshot into the bounded anchor LRU,
        so a dead stream's pin ages out after a restart exactly as it would
        have in the original process (instead of becoming immortal)."""
        with self._lock:
            for fp in self._cache.pinned_fingerprints():
                self._pinned_bases[fp] = None
                self._pinned_bases.move_to_end(fp)
            while len(self._pinned_bases) > self.max_pinned_bases:
                expired, _ = self._pinned_bases.popitem(last=False)
                self._cache.unpin(expired)

    # -- lifecycle ---------------------------------------------------------

    def start(self) -> None:
        """Start (or, after ``close()``, reopen) the worker pool."""
        with self._lock:
            self._closed = False
        self._sched.start()

    def close(self) -> None:
        """Idempotent, drain-safe shutdown: queued tickets fail with
        :class:`ServiceClosedError`, in-flight work completes, the cache is
        snapshotted to ``persist_path`` (when set), and a second ``close()``
        is a no-op."""
        with self._lock:
            if self._closed:
                return
            self._closed = True
        self._sched.close()
        if self.persist_path:
            self._cache.save(self.persist_path)

    @property
    def closed(self) -> bool:
        return self._closed

    @property
    def plan_cache(self) -> PlanCache:
        """The underlying plan cache — read/write access for replication:
        ``ReplicaGroup``'s anti-entropy pump copies shared-store entries in
        through it so a warm hit on any replica is a warm hit on all."""
        return self._cache

    @property
    def scheduler(self) -> PlanScheduler:
        """The underlying scheduler — exposed for fault injection seams
        (``pre_job_hook``) and replica-level metrics."""
        return self._sched

    def __enter__(self) -> "PartitionService":
        self.start()
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    # -- cache surface -----------------------------------------------------

    def lookup(self, fingerprint: str, tenant: str = "default") -> Optional[ServicePlan]:
        """Warm-path cache probe: O(1) dict hit, no partitioning."""
        t0 = time.perf_counter()
        plan = self._cache.get(fingerprint, tenant)
        with self._lock:
            if plan is not None:
                self.stats.hits += 1
            self.stats.lookup_time_s += time.perf_counter() - t0
        return plan

    def __len__(self) -> int:
        return len(self._cache)

    def unpin_plan(self, fingerprint: str) -> bool:
        """Release a churn stream's base-plan pin (see ``update_async``).
        Call when a stream ends and its base graph will not be updated
        again; the entry then competes for cache space normally."""
        with self._lock:
            self._pinned_bases.pop(fingerprint, None)
            return self._cache.unpin(fingerprint)

    def save_cache(self, path: str | None = None) -> int:
        """Snapshot the plan cache (defaults to ``persist_path``); returns
        the number of entries written."""
        path = path or self.persist_path
        if not path:
            raise ValueError("no path given and no persist_path configured")
        return self._cache.save(path)

    def load_cache(self, path: str | None = None) -> int:
        """Restore a cache snapshot (defaults to ``persist_path``); returns
        the number of entries admitted under the configured budgets."""
        path = path or self.persist_path
        if not path:
            raise ValueError("no path given and no persist_path configured")
        n = self._cache.load(path)
        self._adopt_restored_pins()
        return n

    def metrics(self) -> ServiceMetrics:
        """One ServiceMetrics snapshot: scheduler state (queue depth, worker
        utilization, latency histograms) merged with the cache's per-tenant
        hit/miss/eviction/bytes counters."""
        snap = self._sched.metrics_snapshot()
        for tenant, st in self._cache.tenant_stats().items():
            d = snap.tenants.setdefault(tenant, {})
            d.update(
                hits=st.hits,
                misses=st.misses,
                evictions=st.evictions,
                entries=st.entries,
                bytes=st.bytes,
                budget_bytes=st.budget_bytes,
            )
        return snap

    # -- completion callbacks (dispatcher thread, before ticket resolve) ----

    def _on_full_done(self, value: tuple, ticket: PlanTicket) -> ServicePlan:
        plan, _ = value
        with self._lock:
            self.stats.full_runs += 1
            self.stats.compute_time_s += plan.compute_time_s
            self.stats.evictions += self._cache.put(plan, tenant=ticket.tenant)
        return plan

    def _on_update_done(self, value: tuple, ticket: PlanTicket) -> ServicePlan:
        plan, info = value
        with self._lock:
            if info["source"] == "incremental":
                self.stats.incremental_runs += 1
            elif info["source"] == "local":
                self.stats.local_runs += 1
            else:
                self.stats.full_runs += 1
            if info["fallback"]:
                self.stats.incremental_fallbacks += 1
            self.stats.compute_time_s += plan.compute_time_s
            self._churn_memo[info["churn_key"]] = plan.fingerprint
            while len(self._churn_memo) > 4 * self.max_entries:
                self._churn_memo.popitem(last=False)
            self.stats.evictions += self._cache.put(plan, tenant=ticket.tenant)
        return plan

    # -- full partition requests -------------------------------------------

    def submit(
        self,
        edges: EdgeList,
        k: int,
        method: str = "ep",
        opts: MultilevelOptions | None = None,
        seed: int = 0,
        pad: int = 128,
        coo: Optional[tuple] = None,
        buffer: DoubleBuffer | None = None,
        tenant: str = "default",
        priority: int = 0,
        timeout: float | None = None,
    ) -> PlanTicket:
        """Async request: returns a ticket immediately; cache hits resolve at
        once (and publish to ``buffer``); misses are queued by ``priority``
        and computed on the worker pool (identical concurrent requests
        coalesce onto one computation).

        ``timeout`` is the end-to-end deadline budget: it bounds the
        caller's ``ticket.result(timeout)`` wait *and* rides into the
        scheduler as an absolute deadline, so a queued job whose
        p50-predicted service time no longer fits its remaining budget is
        shed (:class:`DeadlineShedError`) instead of occupying a worker.
        With a bounded scheduler (``max_queue_depth``), an over-share
        submit raises :class:`AdmissionRejectedError` carrying a
        ``retry_after_s`` hint."""
        opts = opts if opts is not None else self.default_opts
        extra = (coo[0], coo[1]) if coo is not None else ()
        fingerprint = graph_fingerprint(edges, k, pad, opts, method, seed, extra)
        deadline = time.perf_counter() + timeout if timeout is not None else None
        with self._lock:
            # Hit/miss decided under the lock: a dispatcher finishing the
            # same fingerprint blocks on this lock in on_done, so its job
            # stays visible to the scheduler for coalescing until the plan
            # is in the cache — no rerun race.
            cached = self._cache.get(fingerprint, tenant)
            if cached is not None:
                self.stats.hits += 1
                ticket = PlanTicket(tenant=tenant, priority=priority)
                ticket.cache_hit = True
            else:
                req = _FullRequest(fingerprint, edges, k, method, opts, seed, pad, coo)
                ticket, created = self._sched.submit(
                    fingerprint,
                    _full_plan_job,
                    (req,),
                    priority=priority,
                    tenant=tenant,
                    buffer=buffer,
                    on_done=self._on_full_done,
                    deadline=deadline,
                )
                if created:
                    self._cache.record_miss(tenant)
                    self.stats.misses += 1
                return ticket
        if buffer is not None:
            buffer.publish(cached)
        ticket._resolve(cached)
        return ticket

    def get(
        self,
        edges: EdgeList,
        k: int,
        method: str = "ep",
        opts: MultilevelOptions | None = None,
        seed: int = 0,
        pad: int = 128,
        coo: Optional[tuple] = None,
        timeout: float | None = None,
        tenant: str = "default",
        priority: int = 0,
    ) -> ServicePlan:
        """Sync request: warm hit returns the cached plan object; cold blocks
        until a worker finishes."""
        return self.submit(
            edges, k, method=method, opts=opts, seed=seed, pad=pad, coo=coo,
            tenant=tenant, priority=priority, timeout=timeout,
        ).result(timeout)

    def get_spmv_plan(
        self,
        n_rows: int,
        n_cols: int,
        rows: np.ndarray,
        cols: np.ndarray,
        k: int,
        method: str = "ep",
        opts: MultilevelOptions | None = None,
        seed: int = 0,
        pad: int = 128,
        timeout: float | None = None,
        tenant: str = "default",
        priority: int = 0,
    ) -> ServicePlan:
        """SpMV request path: affinity graph from COO + a PackPlan (§4.1)."""
        rows = np.asarray(rows, dtype=np.int64)
        cols = np.asarray(cols, dtype=np.int64)
        edges = affinity_graph_from_coo(n_rows, n_cols, rows, cols)
        return self.get(
            edges,
            k,
            method=method,
            opts=opts,
            seed=seed,
            pad=pad,
            coo=(n_rows, n_cols, rows, cols),
            timeout=timeout,
            tenant=tenant,
            priority=priority,
        )

    # -- incremental updates -----------------------------------------------

    def update_async(
        self,
        base_fingerprint: str,
        k: int,
        insert_u: np.ndarray | None = None,
        insert_v: np.ndarray | None = None,
        delete_ids: np.ndarray | None = None,
        method: str = "ep",
        opts: MultilevelOptions | None = None,
        seed: int = 0,
        pad: int = 128,
        buffer: DoubleBuffer | None = None,
        tenant: str = "default",
        priority: int = 0,
        timeout: float | None = None,
    ) -> PlanTicket:
        """Apply an edge-churn batch to a cached plan, off the request path.

        The serving loop keeps using the old plan (e.g. via ``buffer``) until
        the updated plan is published — the paper's overlap of optimization
        with compute.  The update gear is drift-gated (``gear_policy``):
        small accumulated drift runs single-level incremental refinement,
        the mid-range re-coarsens only the dirty region through a local
        V-cycle (:func:`local_repartition`), heavy drift — or a cheap gear's
        own quality signal (cut growth vs. the base plan's recorded cut,
        balance breakage) — escalates to a full multilevel rebuild.  The
        decision ships on ``ServicePlan.source``/``drift`` and the per-gear
        ``gear_*`` entries of ``stage_times_s``.

        The request path is O(churn): the request is identified by
        ``(base fingerprint, churn batch)``; applying the churn and hashing
        the resulting graph happen on a worker.  A repeated identical
        update hits the cache through the churn memo.  The base plan is
        *pinned* in the cache while it is used as an update base: a churn
        stream's anchor must survive eviction even when every derived plan
        is cheap to recompute.  Pins are bounded by an LRU of
        ``max_pinned_bases`` anchors (each update refreshes its base's
        slot, so active streams never expire; dead streams' pins age out),
        and ``unpin_plan`` releases an anchor explicitly when a stream
        ends.

        Raises ``KeyError`` when the base plan has been evicted — the
        churn alone cannot reconstruct the graph, so callers that retain
        only a fingerprint must treat this as "cache cold" and resubmit the
        full graph via ``submit``/``get`` (sizing the budgets to the
        working set, plus the pinning above, avoids it).
        """
        base = self._cache.peek(base_fingerprint)
        if base is None:
            raise KeyError(
                f"no cached plan for fingerprint {base_fingerprint!r} "
                "(evicted or never computed); resubmit the full graph"
            )
        self._cache.touch(base_fingerprint)
        with self._lock:
            # Pin the stream's anchor, bounded: the pinned-anchor set is an
            # LRU of at most max_pinned_bases fingerprints, so dead streams
            # cannot leak immortal pins that starve the owner's budget,
            # while every actively-updated base stays protected (each
            # update refreshes its anchor's recency here).
            self._cache.pin(base_fingerprint)
            self._pinned_bases[base_fingerprint] = None
            self._pinned_bases.move_to_end(base_fingerprint)
            while len(self._pinned_bases) > self.max_pinned_bases:
                expired, _ = self._pinned_bases.popitem(last=False)
                self._cache.unpin(expired)
        opts = opts if opts is not None else self.default_opts
        iu = np.asarray(insert_u, dtype=np.int64) if insert_u is not None else np.empty(0, np.int64)
        iv = np.asarray(insert_v, dtype=np.int64) if insert_v is not None else np.empty(0, np.int64)
        dele = (
            np.unique(np.asarray(delete_ids, dtype=np.int64))
            if delete_ids is not None and len(delete_ids) > 0
            else np.empty(0, np.int64)
        )
        h = hashlib.blake2b(digest_size=16)
        meta = (base_fingerprint, k, pad, method, seed)
        if opts is not None:
            meta = meta + dataclasses.astuple(opts)
        h.update(repr(meta).encode())
        h.update(iu.tobytes())
        h.update(iv.tobytes())
        h.update(dele.tobytes())
        churn_key = "churn-" + h.hexdigest()
        deadline = time.perf_counter() + timeout if timeout is not None else None
        with self._lock:
            known_fp = self._churn_memo.get(churn_key)
            cached = self._cache.get(known_fp, tenant) if known_fp is not None else None
            if cached is not None:
                self.stats.hits += 1
                ticket = PlanTicket(tenant=tenant, priority=priority)
                ticket.cache_hit = True
            else:
                req = _UpdateRequest(
                    churn_key, base, k, iu, iv, dele, pad, method, opts, seed,
                    self.eps, self.gear_policy, self.refine_passes,
                )
                ticket, created = self._sched.submit(
                    churn_key,
                    _update_plan_job,
                    (req,),
                    priority=priority,
                    tenant=tenant,
                    buffer=buffer,
                    on_done=self._on_update_done,
                    deadline=deadline,
                )
                if created:
                    self._cache.record_miss(tenant)
                    self.stats.misses += 1
                return ticket
        if buffer is not None:
            buffer.publish(cached)
        ticket._resolve(cached)
        return ticket

    def update(
        self,
        base_fingerprint: str,
        k: int,
        insert_u: np.ndarray | None = None,
        insert_v: np.ndarray | None = None,
        delete_ids: np.ndarray | None = None,
        method: str = "ep",
        opts: MultilevelOptions | None = None,
        seed: int = 0,
        pad: int = 128,
        timeout: float | None = None,
        tenant: str = "default",
        priority: int = 0,
    ) -> ServicePlan:
        """Sync wrapper over ``update_async``."""
        return self.update_async(
            base_fingerprint,
            k,
            insert_u=insert_u,
            insert_v=insert_v,
            delete_ids=delete_ids,
            method=method,
            opts=opts,
            seed=seed,
            pad=pad,
            tenant=tenant,
            priority=priority,
            timeout=timeout,
        ).result(timeout)

"""Async partition service — the paper's CPU optimization thread (§4.2).

The paper's key systems design is that graph partitioning and data relayout
never block GPU compute: they run on a *separate CPU optimization thread*,
and the kernel keeps executing under the old schedule until the new one is
ready, at which point the runtime atomically swaps it in.  This module is
that subsystem, grown into a serving-path component:

  * **Worker thread + double buffer** (`PartitionService._worker`,
    `DoubleBuffer`) — mirrors §4.2's async optimization thread: requests are
    queued, partitioned off the request path, and published with an atomic
    front/back swap so readers never observe a half-built plan.
  * **Fingerprint plan cache** (`graph_fingerprint`, the LRU in
    `PartitionService`) — §4.2 amortizes one partitioning over many kernel
    launches on the same graph; in a serving system the same graph arrives
    from many requests, so plans are memoized under a cheap content hash
    (n, m, k, pad, method, options, digest of the endpoint arrays).
  * **Incremental repartition** (`incremental_repartition`) — §4.2's
    overhead-control argument only holds if re-optimization is cheap when
    the graph drifts.  For a small batch of edge insertions/deletions we
    keep the cached labeling, place new tasks in batched rounds by
    vertex-cut delta, and run *batched* boundary refinement over the dirty
    region only — driving the same shared engine (`refine.py`: gain-sorted
    candidates, per-destination prefix-sum admission, rank-packed repair)
    as the full multilevel refiner (`partition._refine`), over a dense
    ``(n_relevant, k)`` incidence table instead of the whole graph.  The
    pre-vectorization dict/set implementation survives as
    `incremental_repartition_reference`, the property-test oracle.  When
    the dirty fraction or the balance drift exceeds a threshold the
    service falls back to a full multilevel run (the paper's adaptive
    overhead control, cf. `overhead.AdaptiveScheduler`).

Every plan carries the full `EdgePartitionResult` (labels + quality) and,
for SpMV-shaped requests, the `PackPlan` (§4.1 cpack layout), so kernels
can bind a service-supplied plan directly (`kernels.ops.make_ep_spmv_fn`).
"""
from __future__ import annotations

import collections
import dataclasses
import hashlib
import queue
import threading
import time
from typing import Callable, Optional

import numpy as np

from .edge_partition import EdgePartitionResult, edge_partition
from .graph import EdgeList, affinity_graph_from_coo
from .metrics import evaluate_edge_partition
from .partition import MultilevelOptions
from .refine import (
    admit_batched_moves,
    apply_task_moves,
    build_task_connectivity,
    run_first_mask,
    segmented_cumsum,
)
from .reorder import PackPlan, build_pack_plan

__all__ = [
    "DoubleBuffer",
    "IncrementalStats",
    "PartitionService",
    "PlanTicket",
    "ServicePlan",
    "ServiceStats",
    "graph_fingerprint",
    "incremental_repartition",
    "incremental_repartition_reference",
]


# ---------------------------------------------------------------------------
# Fingerprinting
# ---------------------------------------------------------------------------


def graph_fingerprint(
    edges: EdgeList,
    k: int,
    pad: int = 0,
    opts: MultilevelOptions | None = None,
    method: str = "ep",
    seed: int = 0,
    extra: tuple = (),
) -> str:
    """Cheap content hash identifying a partition request.

    Hashes (n, m, k, pad, method, seed, option fields, endpoint arrays) —
    O(m) bytes through blake2b, microseconds to milliseconds even for
    million-edge graphs, versus seconds for a multilevel run.  ``extra``
    lets SpMV requests mix in (n_rows, n_cols) so a bipartite affinity
    graph and a plain graph with identical arrays never collide.
    """
    h = hashlib.blake2b(digest_size=16)
    meta = (edges.n, edges.m, k, pad, method, seed) + tuple(extra)
    if opts is not None:
        meta = meta + dataclasses.astuple(opts)
    h.update(repr(meta).encode())
    h.update(np.ascontiguousarray(edges.u, dtype=np.int64).tobytes())
    h.update(np.ascontiguousarray(edges.v, dtype=np.int64).tobytes())
    return h.hexdigest()


def _multilevel_stage_times(stats) -> dict:
    """Flat ``{stage: seconds}`` entries derived from a PartitionStats.

    Strictly wall times — the V-cycle *shape* (level count, per-level
    records) travels separately via :func:`_vcycle_shape` into
    ``ServicePlan.vcycle``, so consumers summing or formatting
    ``stage_times_s`` values never meet a count or a list.
    """
    return {
        "coarsen": stats.coarsen_s,
        "init": stats.init_s,
        "refine": stats.refine_s,
    }


def _vcycle_shape(stats) -> dict:
    """ServicePlan.vcycle payload: the multilevel V-cycle's shape — level
    count, coarsest size, coarsen mode, and the per-level (n, nnz,
    contraction ratio, wall time) records — so serving dashboards see where
    the dominant cold stage spends its time without re-running anything."""
    return {
        "levels": stats.levels,
        "coarsest_n": stats.coarsest_n,
        "coarsen_mode": stats.coarsen_mode,
        "coarsen_levels": [dataclasses.asdict(ls) for ls in stats.level_stats],
    }


# ---------------------------------------------------------------------------
# Incremental repartition
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class IncrementalStats:
    m_old: int
    m_new: int
    n_inserted: int
    n_deleted: int
    n_dirty: int
    moves: int
    passes_run: int
    dirty_fraction: float
    balance: float
    balance_ok: bool
    time_s: float = 0.0
    # Per-stage wall times: dirty-region + table build / insertion placement
    # / dirty-region refinement (the pack stage is timed by the service).
    dirty_s: float = 0.0
    place_s: float = 0.0
    refine_s: float = 0.0


def _count_key(v: int, p: int, k: int) -> int:
    return v * k + p


@dataclasses.dataclass
class _ChurnSetup:
    """Shared front half of both incremental implementations.

    The churned task list (kept order + insertions appended), the dirty task
    set, and the relevant-vertex mask — computed once, identically, so the
    batched pipeline and the scalar reference agree on every input.
    """

    m_old: int
    m_new: int
    n: int
    n_kept: int
    n_ins: int
    n_deleted: int
    u_all: np.ndarray
    v_all: np.ndarray
    lab_kept: np.ndarray
    insert_u: np.ndarray
    insert_v: np.ndarray
    dirty_idx: np.ndarray
    relevant: np.ndarray


def _churn_setup(
    edges: EdgeList,
    labels: np.ndarray,
    insert_u: np.ndarray | None,
    insert_v: np.ndarray | None,
    delete_ids: np.ndarray | None,
    dirty_degree_cap: int | None,
) -> _ChurnSetup:
    insert_u = (
        np.asarray(insert_u, dtype=np.int64)
        if insert_u is not None
        else np.empty(0, dtype=np.int64)
    )
    insert_v = (
        np.asarray(insert_v, dtype=np.int64)
        if insert_v is not None
        else np.empty(0, dtype=np.int64)
    )
    if insert_u.shape != insert_v.shape:
        raise ValueError("insert_u/insert_v must have the same shape")
    n_ins = int(insert_u.shape[0])
    if n_ins and (int(insert_u.min()) < 0 or int(insert_v.min()) < 0):
        raise ValueError("insert endpoints must be non-negative vertex ids")
    labels = np.asarray(labels, dtype=np.int64)
    m_old = edges.m
    keep = np.ones(m_old, dtype=bool)
    n_deleted = 0
    touched = [insert_u, insert_v]
    if delete_ids is not None and len(delete_ids) > 0:
        delete_ids = np.asarray(delete_ids, dtype=np.int64)
        bad = (delete_ids < 0) | (delete_ids >= m_old)
        if bad.any():
            raise ValueError(
                f"delete_ids must be task indices in [0, {m_old}); got "
                f"{np.unique(delete_ids[bad])[:8].tolist()} — negative ids "
                "would silently wrap around, past-the-end ids are not tasks"
            )
        delete_ids = np.unique(delete_ids)
        keep[delete_ids] = False
        n_deleted = int(delete_ids.shape[0])
        touched += [
            edges.u[delete_ids].astype(np.int64),
            edges.v[delete_ids].astype(np.int64),
        ]
    u_all = np.concatenate([edges.u[keep].astype(np.int64), insert_u])
    v_all = np.concatenate([edges.v[keep].astype(np.int64), insert_v])
    n_kept = int(keep.sum())
    m_new = n_kept + n_ins
    n = max(edges.n, int(u_all.max(initial=-1)) + 1, int(v_all.max(initial=-1)) + 1)

    # Dirty region — it defines which vertices ever get queried, so the
    # incidence tables can be restricted to them (keeps the update cost
    # O(dirty-neighbourhood), not O(m)).  A churned *hub* vertex would mark
    # all of its (possibly thousands of) incident tasks dirty, making
    # "localized" refinement cost like a full pass — yet hubs are replicated
    # across most parts, so local moves around them almost never pay; tasks
    # are only marked dirty through touched vertices of degree <= cap.
    if dirty_degree_cap is None:
        avg_deg = 2.0 * m_new / max(n, 1)
        dirty_degree_cap = max(16, int(4 * avg_deg))
    t_arr = np.unique(np.concatenate(touched))
    if t_arr.size:
        deg = np.bincount(np.concatenate([u_all, v_all]), minlength=max(n, 1))
        t_capped = t_arr[deg[t_arr] <= dirty_degree_cap]
        is_touched = np.zeros(max(n, 1), dtype=bool)
        is_touched[t_capped] = True
        dirty_mask = is_touched[u_all] | is_touched[v_all]
    else:
        dirty_mask = np.zeros(m_new, dtype=bool)
    dirty_mask[n_kept:] = True  # inserted tasks always refine
    dirty_idx = np.flatnonzero(dirty_mask)

    relevant = np.zeros(max(n, 1), dtype=bool)
    relevant[u_all[dirty_mask]] = True
    relevant[v_all[dirty_mask]] = True
    relevant[t_arr] = True

    return _ChurnSetup(
        m_old=m_old,
        m_new=m_new,
        n=n,
        n_kept=n_kept,
        n_ins=n_ins,
        n_deleted=n_deleted,
        u_all=u_all,
        v_all=v_all,
        lab_kept=labels[keep],
        insert_u=insert_u,
        insert_v=insert_v,
        dirty_idx=dirty_idx,
        relevant=relevant,
    )


def _incremental_stats(
    cs: _ChurnSetup,
    k: int,
    sizes: np.ndarray,
    cap: float,
    moves: int,
    passes_run: int,
    t0: float,
    t1: float,
    t2: float,
    t3: float,
) -> IncrementalStats:
    avg = cs.m_new / k if k else 1.0
    return IncrementalStats(
        m_old=cs.m_old,
        m_new=cs.m_new,
        n_inserted=cs.n_ins,
        n_deleted=cs.n_deleted,
        n_dirty=int(cs.dirty_idx.shape[0]),
        moves=moves,
        passes_run=passes_run,
        dirty_fraction=(cs.n_ins + cs.n_deleted) / max(cs.m_new, 1),
        balance=float(sizes.max() / avg) if avg > 0 else 1.0,
        balance_ok=bool(sizes.max() <= cap),
        time_s=t3 - t0,
        dirty_s=t1 - t0,
        place_s=t2 - t1,
        refine_s=t3 - t2,
    )


def _place_insertions_batched(
    insert_u: np.ndarray,
    insert_v: np.ndarray,
    rel_of: np.ndarray,
    table: np.ndarray,
    sizes: np.ndarray,
    cap: float,
    k: int,
    m_new: int,
) -> np.ndarray:
    """Place all pending insertions in batched rounds; returns their labels.

    Each round scores every still-pending task against every part at once
    from the round-start snapshot of (table, sizes): vertex-cut delta via the
    dense incidence table, ties to the lightest part, then the lowest part
    id.  Claims are admitted per part in pending order with a prefix-count
    against the balance cap (exactly `_initial_partition`'s region-growing
    admission); unadmitted tasks retry next round against the updated state.
    The scalar reference mirrors these rounds item by item, which is what
    makes placement-only (``refine_passes=0``) runs byte-identical.
    """
    n_ins = int(insert_u.shape[0])
    new_labels = np.empty(n_ins, dtype=np.int64)
    if n_ins == 0:
        return new_labels
    pend = np.arange(n_ins, dtype=np.int64)
    # Composite lexicographic score (delta, sizes[p], p) packed into int64.
    w1 = np.int64((m_new + 1) * k)
    huge = np.int64(3) * w1
    part_ids = np.arange(k, dtype=np.int64)
    while pend.size:
        iu, iv = insert_u[pend], insert_v[pend]
        tu, tv = table[rel_of[iu]], table[rel_of[iv]]
        loop = iu == iv
        delta = (tu == 0).astype(np.int64) + ((~loop)[:, None] & (tv == 0))
        score = delta * w1 + sizes * np.int64(k) + part_ids
        score[:, sizes + 1 > cap] = huge
        claimed = np.argmin(score, axis=1)
        forced = score[np.arange(pend.size), claimed] >= huge
        if forced.any():  # no part under the cap — unreachable by the cap
            claimed[forced] = np.argmin(sizes)  # construction; kept as a valve
        order = np.argsort(claimed, kind="stable")  # pending order within part
        p_s = claimed[order]
        rank = segmented_cumsum(np.ones(p_s.size), run_first_mask(p_s))
        ok = forced[order] | (sizes[p_s] + rank <= cap)
        adm = order[ok]
        if adm.size == 0:  # safety valve, same shape as the scalar reference
            new_labels[pend[0]] = int(np.argmin(sizes))
            adm_p = new_labels[pend[:1]]
            ids = pend[:1]
        else:
            adm_p = claimed[adm]
            ids = pend[adm]
            new_labels[ids] = adm_p
        # Apply the round at its end — scores were against the snapshot.
        uu, vv = insert_u[ids], insert_v[ids]
        lp = uu == vv
        rows = np.concatenate([rel_of[uu], rel_of[vv][~lp]])
        parts = np.concatenate([adm_p, adm_p[~lp]])
        np.add.at(table.reshape(-1), rows * k + parts, 1)
        sizes += np.bincount(adm_p, minlength=k)
        sel = np.zeros(pend.size, dtype=bool)
        if adm.size == 0:
            sel[0] = True
        else:
            sel[adm] = True
        pend = pend[~sel]
    return new_labels


def _refine_dirty_batched(
    u_all: np.ndarray,
    v_all: np.ndarray,
    labels_all: np.ndarray,
    dirty_idx: np.ndarray,
    rel_of: np.ndarray,
    table: np.ndarray,
    sizes: np.ndarray,
    cap: float,
    k: int,
    passes: int,
) -> tuple[int, int]:
    """Whole-pass batched refinement of the dirty task set, in place.

    The task-side mirror of `partition._refine`: per pass, every dirty task
    scores all k destinations from the dense incidence table (replicas freed
    at the source minus replicas added at the destination), candidates are
    ordered overweight-escapes-first then by gain, and the shared engine
    admits the batch under the cap.  The table and sizes update
    incrementally — only moved tasks' endpoint rows change per pass.
    """
    moves = 0
    passes_run = 0
    de = dirty_idx
    if de.size == 0 or passes <= 0:
        return 0, 0
    du, dv = u_all[de], v_all[de]
    ru, rv = rel_of[du], rel_of[dv]
    loop = du == dv
    notloop_col = (~loop)[:, None]
    rows = np.arange(de.size)
    neg = np.int64(-100)  # sentinel far below any real gain (range [-2, 2])
    for _ in range(passes):
        passes_run += 1
        a = labels_all[de]
        tu, tv = table[ru], table[rv]
        freed = (tu[rows, a] == 1).astype(np.int64)
        freed += (~loop) & (tv[rows, a] == 1)
        gain = freed[:, None] - ((tu == 0).astype(np.int64) + (notloop_col & (tv == 0)))
        gain[rows, a] = neg
        full = sizes + 1 > cap
        if full.any():
            gain[:, full] = neg
        best_b = np.argmax(gain, axis=1)
        best_gain = gain[rows, best_b]
        over_row = (sizes > cap)[a]
        cand = np.flatnonzero((best_gain > 0) | (over_row & (best_gain > neg // 2)))
        if cand.size == 0:
            break
        cand = cand[np.lexsort((-best_gain[cand], ~over_row[cand]))]
        mv, dst = admit_batched_moves(
            de[cand],
            best_gain[cand].astype(np.float64),
            best_b[cand],
            a[cand],
            np.ones(cand.size),
            sizes.astype(np.float64),
            cap,
            over_row[cand],
        )
        if mv.size == 0:
            break
        old = labels_all[mv]
        labels_all[mv] = dst
        sizes += np.bincount(dst, minlength=k) - np.bincount(old, minlength=k)
        apply_task_moves(table, rel_of, u_all[mv], v_all[mv], old, dst)
        moves += int(mv.size)
    return moves, passes_run


def incremental_repartition(
    edges: EdgeList,
    labels: np.ndarray,
    k: int,
    insert_u: np.ndarray | None = None,
    insert_v: np.ndarray | None = None,
    delete_ids: np.ndarray | None = None,
    eps: float = 0.03,
    refine_passes: int = 3,
    slack: int = 1,
    dirty_degree_cap: int | None = None,
) -> tuple[EdgeList, np.ndarray, IncrementalStats]:
    """Repartition after a small edge-churn batch, touching only the dirty region.

    Returns ``(new_edges, new_labels, stats)`` where ``new_edges`` is the old
    task list minus ``delete_ids`` (order preserved) with insertions appended.
    Deleted tasks release their replicas; inserted tasks are placed in
    batched rounds in the part minimizing the vertex-cut delta (ties to the
    lightest part) under the cap ``(1+eps)*ceil(m_new/k) + slack``; then
    batched boundary refinement sweeps tasks incident to any churned vertex,
    admitting whole passes of positive-gain moves through the shared engine
    (`refine.admit_batched_moves`) — the same machinery the full multilevel
    refiner runs, restricted to the dirty task set.

    The pipeline is fully array-based: a dense ``(n_relevant, k)`` incidence
    table over a compacted index of relevant vertices (one bincount over
    packed keys) replaces the per-edge dict/set bookkeeping of
    :func:`incremental_repartition_reference`, which is retained as the
    scalar oracle — placement-only runs (``refine_passes=0``) produce
    byte-identical labels.

    ``delete_ids`` must be valid task indices in ``[0, edges.m)``; anything
    negative or past the end raises ``ValueError``.  ``dirty_degree_cap``
    bounds dirty-set expansion on skewed graphs (default:
    ``max(16, 4 * average_degree)``); inserted tasks are always refined.

    ``stats.balance_ok`` is False when the surviving distribution violates
    the cap (e.g. concentrated deletions shrank the target) — callers should
    fall back to a full run in that case, as `PartitionService.update` does.
    """
    t0 = time.perf_counter()
    cs = _churn_setup(edges, labels, insert_u, insert_v, delete_ids, dirty_degree_cap)
    cap = (1.0 + eps) * np.ceil(cs.m_new / k) + slack

    # Compacted relevant-vertex index + dense (n_rel, k) incidence table over
    # the kept labeling (one bincount over packed keys).
    rel_ids = np.flatnonzero(cs.relevant)
    rel_of = np.full(cs.relevant.shape[0], -1, dtype=np.int64)
    rel_of[rel_ids] = np.arange(rel_ids.size, dtype=np.int64)
    u_kept, v_kept = cs.u_all[: cs.n_kept], cs.v_all[: cs.n_kept]
    table = build_task_connectivity(rel_of, u_kept, v_kept, cs.lab_kept, k, rel_ids.size)
    sizes = np.bincount(cs.lab_kept, minlength=k).astype(np.int64)
    t1 = time.perf_counter()

    new_labels = _place_insertions_batched(
        cs.insert_u, cs.insert_v, rel_of, table, sizes, cap, k, cs.m_new
    )
    labels_all = np.concatenate([cs.lab_kept, new_labels])
    t2 = time.perf_counter()

    moves, passes_run = _refine_dirty_batched(
        cs.u_all, cs.v_all, labels_all, cs.dirty_idx, rel_of, table, sizes, cap, k, refine_passes
    )
    t3 = time.perf_counter()

    new_edges = EdgeList(n=cs.n, u=cs.u_all, v=cs.v_all)
    stats = _incremental_stats(cs, k, sizes, cap, moves, passes_run, t0, t1, t2, t3)
    return new_edges, labels_all.astype(np.int32), stats


def incremental_repartition_reference(
    edges: EdgeList,
    labels: np.ndarray,
    k: int,
    insert_u: np.ndarray | None = None,
    insert_v: np.ndarray | None = None,
    delete_ids: np.ndarray | None = None,
    eps: float = 0.03,
    refine_passes: int = 3,
    slack: int = 1,
    dirty_degree_cap: int | None = None,
) -> tuple[EdgeList, np.ndarray, IncrementalStats]:
    """Scalar oracle for :func:`incremental_repartition` (dict/set loops).

    Same contract and invariants as the batched pipeline: identical churned
    task list, balance cap respected, placement rounds item-for-item
    equivalent (so ``refine_passes=0`` labels are byte-identical).  The
    refinement loop applies moves one task at a time with immediate table
    updates — the pre-vectorization behaviour, kept as the property-test
    baseline for quality and balance.
    """
    t0 = time.perf_counter()
    cs = _churn_setup(edges, labels, insert_u, insert_v, delete_ids, dirty_degree_cap)
    cap = (1.0 + eps) * np.ceil(cs.m_new / k) + slack
    u_all, v_all, lab_kept = cs.u_all, cs.v_all, cs.lab_kept
    relevant, dirty_idx, n_ins = cs.relevant, cs.dirty_idx, cs.n_ins

    # Incidence tables over the kept labeling, for relevant vertices only:
    # cnt[v*k+p] = #incident tasks of v in part p (self-loops count once),
    # vparts[v] = parts with cnt>0.
    u_kept, v_kept = u_all[: cs.n_kept], v_all[: cs.n_kept]
    loop = u_kept == v_kept
    keys = np.concatenate(
        [
            (u_kept * k + lab_kept)[relevant[u_kept]],
            (v_kept * k + lab_kept)[relevant[v_kept] & ~loop],
        ]
    )
    uk, uc = np.unique(keys, return_counts=True)
    cnt: dict[int, int] = dict(zip(uk.tolist(), uc.tolist()))
    vparts: dict[int, set] = collections.defaultdict(set)
    for key in uk.tolist():
        vparts[key // k].add(key % k)
    sizes = np.bincount(lab_kept, minlength=k).astype(np.int64)

    def _add(uu: int, vv: int, p: int) -> None:
        for w in (uu,) if uu == vv else (uu, vv):
            key = _count_key(w, p, k)
            c = cnt.get(key, 0)
            cnt[key] = c + 1
            if c == 0:
                vparts[w].add(p)

    def _remove(uu: int, vv: int, p: int) -> None:
        for w in (uu,) if uu == vv else (uu, vv):
            key = _count_key(w, p, k)
            c = cnt[key] - 1
            if c == 0:
                del cnt[key]
                vparts[w].discard(p)
            else:
                cnt[key] = c

    t1 = time.perf_counter()

    # --- placement: the scalar mirror of `_place_insertions_batched`'s
    # rounds (min vertex-cut delta, tie lightest then lowest part; per-part
    # prefix-count admission against the round-start snapshot) ---
    insert_u, insert_v = cs.insert_u, cs.insert_v
    new_labels = np.empty(n_ins, dtype=np.int64)
    pending = list(range(n_ins))
    while pending:
        snap = sizes.copy()
        claim_count = [0] * k
        admitted: list[tuple[int, int]] = []
        for i in pending:
            uu, vv = int(insert_u[i]), int(insert_v[i])
            best_key, best_p = None, -1
            for p in range(k):
                if snap[p] + 1 > cap:
                    continue
                delta = (cnt.get(uu * k + p, 0) == 0) + (
                    0 if uu == vv else (cnt.get(vv * k + p, 0) == 0)
                )
                score = (delta, int(snap[p]), p)
                if best_key is None or score < best_key:
                    best_key, best_p = score, p
            forced = best_p < 0
            if forced:  # no part under the cap — unreachable, kept as a valve
                best_p = int(np.argmin(snap))
            claim_count[best_p] += 1
            if forced or snap[best_p] + claim_count[best_p] <= cap:
                admitted.append((i, best_p))
        if not admitted:  # safety valve, same shape as the batched engine
            admitted.append((pending[0], int(np.argmin(snap))))
        for i, p in admitted:
            uu, vv = int(insert_u[i]), int(insert_v[i])
            new_labels[i] = p
            _add(uu, vv, p)
            sizes[p] += 1
        done = {i for i, _ in admitted}
        pending = [i for i in pending if i not in done]

    labels_all = np.concatenate([lab_kept, new_labels])
    t2 = time.perf_counter()

    # --- localized boundary refinement over the dirty region only ---
    moves = 0
    passes_run = 0
    cnt_get = cnt.get
    cand_cap = 16  # a hub present in >cap parts contributes no candidates:
    # moving a task into one of the hub's many parts barely changes the
    # hub's replica count — the gain lives in the low-degree endpoint.
    for _ in range(refine_passes):
        passes_run += 1
        pass_moves = 0
        for e in dirty_idx:
            a = int(labels_all[e])
            uu, vv = int(u_all[e]), int(v_all[e])
            is_loop = uu == vv
            pu, pv = vparts[uu], vparts[vv]
            if len(pu) > cand_cap:
                cand = pv if len(pv) <= cand_cap else ()
            elif len(pv) > cand_cap:
                cand = pu
            else:
                cand = pu | pv
            over_a = sizes[a] > cap
            # Replicas freed by leaving part a — invariant over candidates.
            ua, va = uu * k + a, vv * k + a
            freed = (cnt_get(ua, 0) == 1) + (0 if is_loop else cnt_get(va, 0) == 1)
            best_b, best_gain = -1, 0
            for b in cand:
                if b == a or sizes[b] + 1 > cap:
                    continue
                added = (cnt_get(uu * k + b, 0) == 0) + (
                    0 if is_loop else cnt_get(vv * k + b, 0) == 0
                )
                gain = freed - added
                if gain > best_gain or (over_a and best_b < 0 and gain >= best_gain):
                    best_b, best_gain = b, gain
            if over_a and best_b < 0:
                b = int(np.argmin(sizes))
                if b != a and sizes[b] + 1 <= cap:
                    best_b = b
            if best_b >= 0 and (best_gain > 0 or over_a):
                _remove(uu, vv, a)
                _add(uu, vv, best_b)
                sizes[a] -= 1
                sizes[best_b] += 1
                labels_all[e] = best_b
                pass_moves += 1
        moves += pass_moves
        if pass_moves == 0:
            break
    t3 = time.perf_counter()

    new_edges = EdgeList(n=cs.n, u=u_all, v=v_all)
    stats = _incremental_stats(cs, k, sizes, cap, moves, passes_run, t0, t1, t2, t3)
    return new_edges, labels_all.astype(np.int32), stats


# ---------------------------------------------------------------------------
# Service plumbing: tickets, double buffer, stats
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ServicePlan:
    """One cached unit of partitioning work: labels (+ optional PackPlan)."""

    fingerprint: str
    result: EdgePartitionResult
    plan: Optional[PackPlan]
    edges: EdgeList
    source: str  # "full" | "incremental"
    compute_time_s: float
    coo: Optional[tuple] = None  # (n_rows, n_cols, rows, cols) for SpMV plans
    # Per-stage wall times of the cold path (coarsen/init/refine/partition/
    # pack for full runs; incremental/pack for churn updates), so serving
    # dashboards see where compute_time_s goes.  Values are seconds, always.
    stage_times_s: Optional[dict] = None
    # V-cycle shape of a full multilevel run (levels, coarsest_n,
    # coarsen_mode, per-level records) — kept apart from stage_times_s so
    # that mapping stays a flat {stage: seconds}.
    vcycle: Optional[dict] = None

    def nbytes(self) -> int:
        b = self.result.labels.nbytes + self.edges.u.nbytes + self.edges.v.nbytes
        if self.plan is not None:
            b += self.plan.nbytes()
        return b


class PlanTicket:
    """Future handed back by async submission; resolves to a ServicePlan.

    ``cache_hit`` is True when the request was answered from the plan cache
    without any partitioning work (set before the ticket is returned, so it
    is race-free even with concurrent requests on other graphs).
    """

    def __init__(self) -> None:
        self._event = threading.Event()
        self._value: Optional[ServicePlan] = None
        self._error: Optional[BaseException] = None
        self.cache_hit = False
        # Buffers to publish to on completion.  In-flight dedup can hand one
        # ticket to several callers, each with its own DoubleBuffer — all of
        # them must see the swap (guarded by the service lock).
        self._buffers: list["DoubleBuffer"] = []

    def _resolve(self, value: ServicePlan) -> None:
        self._value = value
        self._event.set()

    def _fail(self, err: BaseException) -> None:
        self._error = err
        self._event.set()

    def done(self) -> bool:
        return self._event.is_set()

    def result(self, timeout: float | None = None) -> ServicePlan:
        if not self._event.wait(timeout):
            raise TimeoutError("partition not ready")
        if self._error is not None:
            raise self._error
        assert self._value is not None
        return self._value


class DoubleBuffer:
    """Two-slot atomic handoff: the compute path reads ``current()`` while the
    optimization thread builds into the back slot and ``publish``es with a
    front/back swap — the §4.2 schedule-swap, no torn reads, no locks held
    during compute."""

    def __init__(self) -> None:
        self._slots: list[Optional[ServicePlan]] = [None, None]
        self._front = 0
        self._generation = 0
        self._lock = threading.Lock()

    def publish(self, value: ServicePlan) -> int:
        with self._lock:
            back = 1 - self._front
            self._slots[back] = value
            self._front = back
            self._generation += 1
            return self._generation

    def current(self) -> tuple[Optional[ServicePlan], int]:
        with self._lock:
            return self._slots[self._front], self._generation


@dataclasses.dataclass
class ServiceStats:
    hits: int = 0
    misses: int = 0
    full_runs: int = 0
    incremental_runs: int = 0
    incremental_fallbacks: int = 0
    evictions: int = 0
    lookup_time_s: float = 0.0
    compute_time_s: float = 0.0


# ---------------------------------------------------------------------------
# The service
# ---------------------------------------------------------------------------


class PartitionService:
    """Background partitioning + plan cache, the serving-path subsystem.

    Synchronous fast path: ``get``/``get_spmv_plan`` return a cached plan in
    O(fingerprint) time on a warm hit; on a miss the request is computed on
    the worker thread (callers block on the ticket — use ``submit`` /
    ``update_async`` to overlap with compute, per §4.2).
    """

    def __init__(
        self,
        max_entries: int = 64,
        max_bytes: int | None = None,
        eps: float = 0.03,
        churn_threshold: float = 0.10,
        refine_passes: int = 3,
        default_opts: MultilevelOptions | None = None,
        start: bool = True,
    ) -> None:
        self.max_entries = max_entries
        self.max_bytes = max_bytes
        self.eps = eps
        self.churn_threshold = churn_threshold
        self.refine_passes = refine_passes
        self.default_opts = default_opts
        self.stats = ServiceStats()
        self._cache: collections.OrderedDict[str, ServicePlan] = collections.OrderedDict()
        # churn-request key -> content fingerprint of the resulting plan, so
        # a repeated identical update is a cache hit without re-applying the
        # churn (the request key is O(churn) to compute, see update_async).
        self._churn_memo: collections.OrderedDict[str, str] = collections.OrderedDict()
        self._pending: dict[str, PlanTicket] = {}
        self._lock = threading.RLock()
        self._queue: queue.Queue = queue.Queue()
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None
        if start:
            self.start()

    # -- lifecycle ---------------------------------------------------------

    def start(self) -> None:
        if self._thread is None or not self._thread.is_alive():
            self._stop.clear()
            self._thread = threading.Thread(
                target=self._worker, name="partition-service", daemon=True
            )
            self._thread.start()

    def close(self) -> None:
        self._stop.set()
        # Fail tickets still sitting in the queue — a blocked waiter must see
        # an error, not hang forever (the worker fails anything it picks up
        # after the stop flag too, closing the takeover race).
        while True:
            try:
                item = self._queue.get_nowait()
            except queue.Empty:
                break
            if item is None:
                continue
            _, key, ticket = item
            with self._lock:
                self._pending.pop(key, None)
            ticket._fail(RuntimeError("PartitionService closed"))
        self._queue.put(None)
        if self._thread is not None:
            self._thread.join(timeout=5.0)
            self._thread = None

    def __enter__(self) -> "PartitionService":
        self.start()
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    # -- worker ------------------------------------------------------------

    def _worker(self) -> None:
        while True:
            item = self._queue.get()
            if item is None:
                break
            fn, key, ticket = item
            if self._stop.is_set():
                with self._lock:
                    self._pending.pop(key, None)
                ticket._fail(RuntimeError("PartitionService closed"))
                continue
            try:
                plan = fn()
            except BaseException as err:  # propagate to the waiter, keep serving
                with self._lock:
                    self._pending.pop(key, None)
                ticket._fail(err)
                continue
            with self._lock:
                self._store(plan)
                self._pending.pop(key, None)
                buffers = list(ticket._buffers)
            for buf in buffers:
                buf.publish(plan)
            ticket._resolve(plan)

    # -- cache internals ---------------------------------------------------

    def _store(self, plan: ServicePlan) -> None:
        self._cache[plan.fingerprint] = plan
        self._cache.move_to_end(plan.fingerprint)
        while len(self._cache) > self.max_entries:
            self._cache.popitem(last=False)
            self.stats.evictions += 1
        if self.max_bytes is not None:
            total = sum(p.nbytes() for p in self._cache.values())
            while total > self.max_bytes and len(self._cache) > 1:
                _, evicted = self._cache.popitem(last=False)
                total -= evicted.nbytes()
                self.stats.evictions += 1

    def lookup(self, fingerprint: str) -> Optional[ServicePlan]:
        """Warm-path cache probe: O(1) dict hit, no partitioning."""
        t0 = time.perf_counter()
        with self._lock:
            plan = self._cache.get(fingerprint)
            if plan is not None:
                self._cache.move_to_end(fingerprint)
                self.stats.hits += 1
            self.stats.lookup_time_s += time.perf_counter() - t0
            return plan

    def __len__(self) -> int:
        with self._lock:
            return len(self._cache)

    # -- full partition requests -------------------------------------------

    def _compute_full(
        self,
        fingerprint: str,
        edges: EdgeList,
        k: int,
        method: str,
        opts: MultilevelOptions | None,
        seed: int,
        pad: int,
        coo: Optional[tuple],
    ) -> Callable[[], ServicePlan]:
        def run() -> ServicePlan:
            t0 = time.perf_counter()
            result = edge_partition(edges, k, method=method, opts=opts, seed=seed)
            t_part = time.perf_counter() - t0
            plan = None
            if coo is not None:
                n_rows, n_cols, rows, cols = coo
                plan = build_pack_plan(n_rows, n_cols, rows, cols, result.labels, k, pad=pad)
            dt = time.perf_counter() - t0
            stage_times = {"partition": t_part, "pack": dt - t_part}
            vcycle = None
            if result.stats is not None:
                stage_times.update(_multilevel_stage_times(result.stats))
                vcycle = _vcycle_shape(result.stats)
            self.stats.full_runs += 1
            self.stats.compute_time_s += dt
            return ServicePlan(
                fingerprint=fingerprint,
                result=result,
                plan=plan,
                edges=edges,
                source="full",
                compute_time_s=dt,
                coo=coo,
                stage_times_s=stage_times,
                vcycle=vcycle,
            )

        return run

    def submit(
        self,
        edges: EdgeList,
        k: int,
        method: str = "ep",
        opts: MultilevelOptions | None = None,
        seed: int = 0,
        pad: int = 128,
        coo: Optional[tuple] = None,
        buffer: DoubleBuffer | None = None,
    ) -> PlanTicket:
        """Async request: returns a ticket immediately; cache hits resolve at
        once (and publish to ``buffer``); misses are computed on the worker."""
        opts = opts if opts is not None else self.default_opts
        extra = (coo[0], coo[1]) if coo is not None else ()
        fingerprint = graph_fingerprint(edges, k, pad, opts, method, seed, extra)
        ticket = PlanTicket()
        with self._lock:
            # Hit/miss decided under the lock so a worker finishing the same
            # fingerprint between probe and registration can't cause a rerun.
            cached = self._cache.get(fingerprint)
            if cached is not None:
                self._cache.move_to_end(fingerprint)
                self.stats.hits += 1
                ticket.cache_hit = True
            else:
                inflight = self._pending.get(fingerprint)
                if inflight is not None:
                    # Dedupe identical in-flight requests — but every
                    # caller's buffer must still see the publish.
                    if buffer is not None:
                        inflight._buffers.append(buffer)
                    return inflight
                self.stats.misses += 1
                self._pending[fingerprint] = ticket
                if buffer is not None:
                    ticket._buffers.append(buffer)
        if cached is not None:
            if buffer is not None:
                buffer.publish(cached)
            ticket._resolve(cached)
            return ticket
        if self._stop.is_set():
            with self._lock:
                self._pending.pop(fingerprint, None)
            ticket._fail(RuntimeError("PartitionService closed"))
            return ticket
        fn = self._compute_full(fingerprint, edges, k, method, opts, seed, pad, coo)
        self._queue.put((fn, fingerprint, ticket))
        return ticket

    def get(
        self,
        edges: EdgeList,
        k: int,
        method: str = "ep",
        opts: MultilevelOptions | None = None,
        seed: int = 0,
        pad: int = 128,
        coo: Optional[tuple] = None,
        timeout: float | None = None,
    ) -> ServicePlan:
        """Sync request: warm hit returns the cached plan object; cold blocks
        until the worker finishes."""
        return self.submit(edges, k, method=method, opts=opts, seed=seed, pad=pad, coo=coo).result(
            timeout
        )

    def get_spmv_plan(
        self,
        n_rows: int,
        n_cols: int,
        rows: np.ndarray,
        cols: np.ndarray,
        k: int,
        method: str = "ep",
        opts: MultilevelOptions | None = None,
        seed: int = 0,
        pad: int = 128,
        timeout: float | None = None,
    ) -> ServicePlan:
        """SpMV request path: affinity graph from COO + a PackPlan (§4.1)."""
        rows = np.asarray(rows, dtype=np.int64)
        cols = np.asarray(cols, dtype=np.int64)
        edges = affinity_graph_from_coo(n_rows, n_cols, rows, cols)
        return self.get(
            edges,
            k,
            method=method,
            opts=opts,
            seed=seed,
            pad=pad,
            coo=(n_rows, n_cols, rows, cols),
            timeout=timeout,
        )

    # -- incremental updates -----------------------------------------------

    def _compute_update(
        self,
        churn_key: str,
        base: ServicePlan,
        k: int,
        insert_u: np.ndarray | None,
        insert_v: np.ndarray | None,
        delete_ids: np.ndarray | None,
        pad: int,
        method: str,
        opts: MultilevelOptions | None,
        seed: int,
    ) -> Callable[[], ServicePlan]:
        def run() -> ServicePlan:
            t0 = time.perf_counter()
            n_churn = (0 if insert_u is None else len(insert_u)) + (
                0 if delete_ids is None else len(delete_ids)
            )
            m_new_est = max(base.edges.m + n_churn, 1)
            new_edges, labels, inc = None, None, None
            use_full = n_churn / m_new_est > self.churn_threshold
            if not use_full:
                new_edges, labels, inc = incremental_repartition(
                    base.edges,
                    base.result.labels,
                    k,
                    insert_u=insert_u,
                    insert_v=insert_v,
                    delete_ids=delete_ids,
                    eps=self.eps,
                    refine_passes=self.refine_passes,
                )
                if not inc.balance_ok:
                    use_full = True
                    self.stats.incremental_fallbacks += 1
            stage_times: dict = {}
            vcycle = None
            if use_full:
                if new_edges is None:
                    new_edges, labels, _ = incremental_repartition(
                        base.edges,
                        base.result.labels,
                        k,
                        insert_u=insert_u,
                        insert_v=insert_v,
                        delete_ids=delete_ids,
                        eps=self.eps,
                        refine_passes=0,
                    )
                result = edge_partition(new_edges, k, method=method, opts=opts, seed=seed)
                labels = result.labels
                source = "full"
                self.stats.full_runs += 1
                stage_times["partition"] = result.partition_time_s
                if result.stats is not None:
                    stage_times.update(_multilevel_stage_times(result.stats))
                    vcycle = _vcycle_shape(result.stats)
            else:
                quality = evaluate_edge_partition(new_edges, labels, k)
                result = EdgePartitionResult(
                    labels=labels,
                    k=k,
                    method=f"{method}+incremental",
                    quality=quality,
                    partition_time_s=inc.time_s,
                )
                source = "incremental"
                self.stats.incremental_runs += 1
                stage_times["incremental"] = inc.time_s
                stage_times.update(
                    inc_dirty=inc.dirty_s,
                    inc_place=inc.place_s,
                    inc_refine=inc.refine_s,
                )
            plan = None
            coo = None
            t_pack0 = time.perf_counter()
            if base.coo is not None:
                n_rows, n_cols, _, _ = base.coo
                # Affinity convention: u = column vertex, v = n_cols + row.
                rows = (new_edges.v - n_cols).astype(np.int64)
                cols = new_edges.u.astype(np.int64)
                coo = (n_rows, n_cols, rows, cols)
                plan = build_pack_plan(n_rows, n_cols, rows, cols, labels, k, pad=pad)
            stage_times["pack"] = time.perf_counter() - t_pack0
            # Content fingerprint of the post-churn graph — hashed here on
            # the worker so the request path stays O(churn), not O(m).
            extra = (base.coo[0], base.coo[1]) if base.coo is not None else ()
            fingerprint = graph_fingerprint(new_edges, k, pad, opts, method, seed, extra)
            with self._lock:
                self._churn_memo[churn_key] = fingerprint
                while len(self._churn_memo) > 4 * self.max_entries:
                    self._churn_memo.popitem(last=False)
            dt = time.perf_counter() - t0
            self.stats.compute_time_s += dt
            return ServicePlan(
                fingerprint=fingerprint,
                result=result,
                plan=plan,
                edges=new_edges,
                source=source,
                compute_time_s=dt,
                coo=coo,
                stage_times_s=stage_times,
                vcycle=vcycle,
            )

        return run

    def update_async(
        self,
        base_fingerprint: str,
        k: int,
        insert_u: np.ndarray | None = None,
        insert_v: np.ndarray | None = None,
        delete_ids: np.ndarray | None = None,
        method: str = "ep",
        opts: MultilevelOptions | None = None,
        seed: int = 0,
        pad: int = 128,
        buffer: DoubleBuffer | None = None,
    ) -> PlanTicket:
        """Apply an edge-churn batch to a cached plan, off the request path.

        The serving loop keeps using the old plan (e.g. via ``buffer``) until
        the updated plan is published — the paper's overlap of optimization
        with compute.  Falls back to a full multilevel run when the dirty
        fraction exceeds ``churn_threshold`` or balance drifts past the cap.

        The request path is O(churn): the request is identified by
        ``(base fingerprint, churn batch)``; applying the churn and hashing
        the resulting graph happen on the worker.  A repeated identical
        update hits the cache through the churn memo.

        Raises ``KeyError`` when the base plan has been LRU-evicted — the
        churn alone cannot reconstruct the graph, so callers that retain
        only a fingerprint must treat this as "cache cold" and resubmit the
        full graph via ``submit``/``get`` (sizing ``max_entries`` to the
        working set avoids it).
        """
        with self._lock:
            base = self._cache.get(base_fingerprint)
            if base is not None:
                self._cache.move_to_end(base_fingerprint)
        if base is None:
            raise KeyError(
                f"no cached plan for fingerprint {base_fingerprint!r} "
                "(evicted or never computed); resubmit the full graph"
            )
        opts = opts if opts is not None else self.default_opts
        iu = np.asarray(insert_u, dtype=np.int64) if insert_u is not None else np.empty(0, np.int64)
        iv = np.asarray(insert_v, dtype=np.int64) if insert_v is not None else np.empty(0, np.int64)
        dele = (
            np.unique(np.asarray(delete_ids, dtype=np.int64))
            if delete_ids is not None and len(delete_ids) > 0
            else np.empty(0, np.int64)
        )
        h = hashlib.blake2b(digest_size=16)
        meta = (base_fingerprint, k, pad, method, seed)
        if opts is not None:
            meta = meta + dataclasses.astuple(opts)
        h.update(repr(meta).encode())
        h.update(iu.tobytes())
        h.update(iv.tobytes())
        h.update(dele.tobytes())
        churn_key = "churn-" + h.hexdigest()
        ticket = PlanTicket()
        with self._lock:
            known_fp = self._churn_memo.get(churn_key)
            cached = self._cache.get(known_fp) if known_fp is not None else None
            if cached is not None:
                self._cache.move_to_end(known_fp)
                self.stats.hits += 1
                ticket.cache_hit = True
            else:
                inflight = self._pending.get(churn_key)
                if inflight is not None:
                    if buffer is not None:
                        inflight._buffers.append(buffer)
                    return inflight
                self.stats.misses += 1
                self._pending[churn_key] = ticket
                if buffer is not None:
                    ticket._buffers.append(buffer)
        if cached is not None:
            if buffer is not None:
                buffer.publish(cached)
            ticket._resolve(cached)
            return ticket
        if self._stop.is_set():
            with self._lock:
                self._pending.pop(churn_key, None)
            ticket._fail(RuntimeError("PartitionService closed"))
            return ticket
        fn = self._compute_update(
            churn_key, base, k, iu, iv, dele, pad, method, opts, seed
        )
        self._queue.put((fn, churn_key, ticket))
        return ticket

    def update(
        self,
        base_fingerprint: str,
        k: int,
        insert_u: np.ndarray | None = None,
        insert_v: np.ndarray | None = None,
        delete_ids: np.ndarray | None = None,
        method: str = "ep",
        opts: MultilevelOptions | None = None,
        seed: int = 0,
        pad: int = 128,
        timeout: float | None = None,
    ) -> ServicePlan:
        """Sync wrapper over ``update_async``."""
        return self.update_async(
            base_fingerprint,
            k,
            insert_u=insert_u,
            insert_v=insert_v,
            delete_ids=delete_ids,
            method=method,
            opts=opts,
            seed=seed,
            pad=pad,
        ).result(timeout)

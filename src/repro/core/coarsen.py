"""Size-constrained cluster-coarsening engine for the multilevel V-cycle.

Pairwise heavy-edge matching halves the graph *at best* per level (and far
less on power-law degree distributions, where hubs exhaust their neighbours
after one match), so the V-cycle needs 10+ levels on banded graphs and
stalls thousands of vertices above the target on random/power-law ones.
Modern multilevel partitioners replaced matching with *cluster* coarsening:
every vertex proposes to join a neighbouring cluster, whole stars and chains
collapse at once, and one level contracts 3-8x.

This module is that engine, fully array-native:

  * :meth:`ClusterCoarsener.cluster_level` — one level of size-constrained
    clustering.  Each round, every still-singleton vertex proposes to join
    the cluster of its heaviest-affinity neighbour (jittered heavy-edge
    affinity; see the in-line note on why cluster-weight normalization was
    measured and rejected);
    a random-rank direction rule makes the proposal pointer graph acyclic,
    **pointer-jumping** flattens chains to cluster roots in O(log n) array
    steps, and admission into each cluster is a score-ordered prefix-sum of
    joiner weights against the cluster-size cap (derived from the balance
    slack, so refinement can still rebalance the projected partition).
  * :meth:`ClusterCoarsener.contract_clusters` — contraction by an
    *arbitrary* fine->coarse root map (the generalization of the old
    matched-pair ``_contract``): dense-scatter renumbering, parallel-edge
    dedupe via a packed-key bincount histogram when the coarse graph is
    small (skipping the per-level full-nnz ``argsort``), stable-argsort
    grouping otherwise — both paths produce byte-identical coarse graphs.

The engine owns its scratch buffers (:meth:`_buf`), so the n- and nnz-sized
work arrays are allocated once at the finest level and reused as the levels
shrink.  Pairwise matching survives in ``partition._heavy_edge_matching`` as
the property-test reference, selectable via
``MultilevelOptions(coarsen_mode="matching")``.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from .graph import CSRGraph
from .refine import run_first_mask, segmented_cumsum, segmented_max

__all__ = ["ClusterCoarsener", "LevelStats", "contract_clusters"]


@dataclasses.dataclass
class LevelStats:
    """Per-level coarsening record (one entry per V-cycle contraction)."""

    n: int  # fine vertex count entering the level
    nnz: int  # fine stored (directed) edge count
    coarse_n: int  # vertex count after contraction
    ratio: float  # n / coarse_n — the level's contraction factor
    time_s: float  # wall time of clustering + contraction


#: Max nc*nc for the dense packed-key dedupe histograms (at the limit: a 4M
#: int64 count histogram + a 4M float64 weight histogram = 64 MB transient).
_DENSE_DEDUPE_LIMIT = 1 << 22


def _use_dense_dedupe(nc: int, nnz: int) -> bool:
    """Whether contraction dedupes via the dense packed-key histogram.

    The histogram costs O(nc^2) regardless of nnz, so it only beats the
    O(nnz log nnz) stable argsort when the key space is dense relative to
    the edge count.  Measured crossover (numpy 2.x, one core): dense wins
    2-15x at ``nc^2/nnz <= ~3`` and loses from ~10 up — ``4 * nnz`` sits on
    the boundary.  Default V-cycle levels stop at 500+ vertices with sparse
    coarse graphs (ratio 10-700: always argsort); the dense path engages
    when callers coarsen far down (small ``coarsen_until`` / small k), where
    tiny-nc contractions dominate the level count.  Both paths group
    identically (keys ascending, weights summed in original edge order), so
    switching between them is invisible to the result — property- and
    unit-tested byte-identical.
    """
    return nc * nc <= min(_DENSE_DEDUPE_LIMIT, 4 * nnz)


class ClusterCoarsener:
    """Reusable cluster-coarsening engine with level-spanning scratch buffers."""

    def __init__(self) -> None:
        self._scratch: dict[str, np.ndarray] = {}

    def _buf(self, name: str, size: int, dtype) -> np.ndarray:
        """Uninitialized scratch array of at least ``size``, reused across
        levels (the finest level allocates the high-water mark)."""
        arr = self._scratch.get(name)
        if arr is None or arr.shape[0] < size or arr.dtype != np.dtype(dtype):
            arr = np.empty(size, dtype=dtype)
            self._scratch[name] = arr
        return arr[:size]

    # -- clustering --------------------------------------------------------

    def cluster_level(
        self,
        g: CSRGraph,
        rng: np.random.Generator,
        cluster_cap: float,
        rounds: int = 2,
        pinned: np.ndarray | None = None,
    ) -> np.ndarray:
        """One level of size-constrained clustering; returns root[v].

        ``root[v]`` is the vertex id of v's cluster root (``root[r] == r``
        for roots), ready for :meth:`contract_clusters`.  No cluster's total
        vertex weight exceeds ``cluster_cap`` beyond what a single fine
        vertex already weighs.

        ``pinned`` marks vertices that must survive contraction untouched
        (the local V-cycle's frozen-label anchor super-vertices): a pinned
        vertex never proposes and never accepts joiners, so it stays a
        singleton cluster rooted at itself through every round.
        """
        n = g.n
        if n == 0 or g.nnz == 0:
            return np.arange(n, dtype=np.int64)
        src, dst = g.coo_src, g.coo_dst
        row_first = run_first_mask(src)  # src nonempty: nnz == 0 returned above
        root = self._buf("root", n, np.int64)
        root[:] = np.arange(n, dtype=np.int64)
        cw = self._buf("cw", n, np.float64)
        cw[:] = g.vweights
        # Random rank: proposals only point to lower-rank targets, so the
        # pointer graph is a forest and pointer jumping terminates.
        rank = rng.permutation(n)
        # Multiplicative jitter decorrelates ties at any weight magnitude
        # (the ep-cloned path carries 1e9 original-edge weights).
        score_w = g.eweights * (1.0 + 1e-9 * rng.random(g.nnz))
        neg_inf = -np.inf
        for _ in range(max(1, rounds)):
            csize = np.bincount(root, minlength=n)
            singleton = csize == 1  # indexed by root id == the vertex itself
            tgt = root[dst]
            # Eligible proposal edges: singleton source, foreign target
            # cluster, joined weight under the cap.
            eligible = (
                singleton[src]
                & (tgt != src)
                & (cw[src] + cw[tgt] <= cluster_cap)
            )
            if pinned is not None:
                # Pinned vertices are always their own root, so pinned[tgt]
                # exactly marks proposals into a pinned cluster.
                eligible &= ~pinned[src] & ~pinned[tgt]
            if not eligible.any():
                break
            # Affinity: the jittered edge weight (classic heavy-edge).
            # Normalizing by target cluster weight (w / cw[tgt], KaMinPar
            # style) was measured and rejected: it buys ~2% cut on the mesh
            # family but costs 3-5% on banded/random/power-law graphs and
            # 20%+ on path-structured routing-affinity graphs, where it
            # pulls vertices off their natural cluster toward whatever is
            # lightest.  The size cap alone keeps growth spread out.
            score = np.where(eligible, score_w, neg_inf)
            row_best = segmented_max(score, row_first)
            is_best = eligible & (score == row_best)
            prop = self._buf("prop", n, np.int64)
            prop[:] = np.arange(n, dtype=np.int64)
            prop[src[is_best]] = tgt[is_best]  # one winner per row (last write)
            sc = self._buf("sc", n, np.float64)
            sc[:] = 0.0
            sc[src[is_best]] = score[is_best]
            # Direction rule: a proposal may target a non-proposing root
            # (stable cluster) freely, but a proposer->proposer pointer must
            # descend in rank — that breaks every potential cycle.
            proposing = prop != np.arange(n, dtype=np.int64)
            bad = proposing & proposing[prop] & (rank[prop] >= rank)
            prop[bad] = np.flatnonzero(bad)
            # Pointer-jump chains flat: root-assignment in O(log n) rounds
            # of whole-array gathers, no Python-scale loops.
            while True:
                nxt = prop[prop]
                if np.array_equal(nxt, prop):
                    break
                prop = nxt
            joiner = np.flatnonzero(prop != np.arange(n, dtype=np.int64))
            if joiner.size == 0:
                break
            jt = prop[joiner]
            # Cap admission: strongest joiners first per target cluster,
            # cumulative joiner weight against the round-start base weight.
            order = np.lexsort((-sc[joiner], jt))
            joiner, jt = joiner[order], jt[order]
            local = segmented_cumsum(cw[joiner], run_first_mask(jt))
            admit = cw[jt] + local <= cluster_cap
            joiner, jt = joiner[admit], jt[admit]
            if joiner.size == 0:
                break
            root[joiner] = jt
            np.add.at(cw, jt, cw[joiner])
        return root.copy()

    # -- contraction -------------------------------------------------------

    def contract_clusters(self, g: CSRGraph, root: np.ndarray) -> tuple[CSRGraph, np.ndarray]:
        """Contract an arbitrary fine->coarse root map; returns (coarse, cmap).

        ``root[v]`` may be any idempotent representative map
        (``root[root[v]] == root[v]``): matched pairs, multi-vertex clusters,
        or identity.  Coarse ids are the dense renumbering of the
        representatives in ascending order; ``cmap[v]`` is v's coarse id.
        Parallel coarse edges are deduped with summed weights; self-edges
        (intra-cluster) are dropped.
        """
        n = g.n
        present = self._buf("present", n, bool)
        present.fill(False)
        present[root] = True
        uniq = np.flatnonzero(present)
        nc = uniq.shape[0]
        lookup = self._buf("lookup", n, np.int64)
        lookup[uniq] = np.arange(nc, dtype=np.int64)
        cmap = lookup[root]
        src = cmap[g.coo_src]
        dst = cmap[g.coo_dst]
        w = g.eweights
        keep = src != dst
        src, dst, w = src[keep], dst[keep], w[keep]
        if src.size:
            key = src * nc + dst
            if _use_dense_dedupe(nc, src.size):
                # Dense histogram dedupe: one bincount over packed keys
                # replaces the full-nnz argsort.  Nonzero bins come out in
                # ascending key order with weights summed in original edge
                # order — byte-identical to the argsort path below.
                cnt = np.bincount(key, minlength=nc * nc)
                key_u = np.flatnonzero(cnt)  # presence by count, so a
                # zero-weight edge group survives exactly like it does below
                w = np.bincount(key, weights=w, minlength=nc * nc)[key_u]
                src = key_u // nc
                dst = key_u % nc
            else:
                order = np.argsort(key, kind="stable")
                key, src, dst, w = key[order], src[order], dst[order], w[order]
                uniq_mask = run_first_mask(key)
                seg = np.cumsum(uniq_mask) - 1
                w = np.bincount(seg, weights=w)
                src, dst = src[uniq_mask], dst[uniq_mask]
        indptr = np.zeros(nc + 1, dtype=np.int64)
        np.add.at(indptr, src + 1, 1)
        np.cumsum(indptr, out=indptr)
        vw = np.bincount(cmap, weights=g.vweights.astype(np.float64), minlength=nc)
        coarse = CSRGraph(
            indptr=indptr,
            indices=dst.astype(np.int32),
            eweights=w.astype(np.float64),
            vweights=vw.astype(np.int64),
        )
        return coarse, cmap


def contract_clusters(g: CSRGraph, root: np.ndarray) -> tuple[CSRGraph, np.ndarray]:
    """One-shot :meth:`ClusterCoarsener.contract_clusters` (no buffer reuse)."""
    return ClusterCoarsener().contract_clusters(g, root)

"""Clone-and-connect transformation (paper §3.2, Definitions 3-4).

Balanced edge partitioning of the data-affinity graph D = (V, E) is reduced
to balanced vertex partitioning of a transformed graph D' = (V', E'):

  * every vertex v of degree d is replaced by d *cloned vertices*, one per
    incident edge;
  * every original edge (u, v) becomes an edge between the matching clones
    (weight ``original_weight``, chosen huge so the vertex partitioner never
    cuts it);
  * the d clones of each vertex are connected into a *path* with d - 1
    auxiliary edges of weight 1 (connected in index order, the paper's
    practical choice).

D' has exactly 2m vertices.  A balanced vertex partition of D' that cuts no
original edge maps back (Definition 4) to a balanced edge partition of D
whose vertex-cut cost is bounded by the number of cut auxiliary edges
(Theorem 1), giving the (d_max - 1)·O(sqrt(log m log k)) approximation
(Theorem 2).

Two constructions are provided:

``clone_and_connect``  — literal Definition 3 (used for the theorem tests
    and for fidelity).

``contracted_clone_graph`` — the same graph after contracting every
    original edge (each infinite-weight pair of clones becomes one node of
    weight 1).  This is *exactly* what a multilevel partitioner would do
    with the infinite-weight edges in its first coarsening step, so
    partitioning the contracted graph is equivalent — but ~2x smaller and
    guarantees no original edge is ever cut.  Nodes of the contracted graph
    are the original edges themselves; auxiliary path edges connect edges
    that are consecutive in some vertex's incidence list.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from .graph import CSRGraph, EdgeList, csr_from_edges

__all__ = [
    "ClonedGraph",
    "clone_and_connect",
    "contracted_clone_graph",
    "reconstruct_edge_partition",
]

#: Weight given to original edges so the partitioner treats them as uncuttable.
ORIGINAL_EDGE_WEIGHT = 1e9


@dataclasses.dataclass(frozen=True)
class ClonedGraph:
    """D' = (V', E') plus the bookkeeping to map a partition of V' back.

    Clone ids: edge e of D contributes clones ``2e`` (for endpoint u) and
    ``2e + 1`` (for endpoint v); hence ``clone_owner[c] = e = c >> 1`` and
    the original vertex of clone c is recorded in ``clone_vertex``.
    """

    graph: CSRGraph  # 2m vertices
    clone_vertex: np.ndarray  # (2m,) original vertex id of each clone
    n_original_edges: int
    aux_src: np.ndarray  # auxiliary path edges (for analysis)
    aux_dst: np.ndarray


def _incidence_order(edges: EdgeList) -> tuple[np.ndarray, np.ndarray]:
    """Per-vertex incidence lists as (sorted clone ids, vertex indptr).

    Clone c belongs to vertex ``clone_vertex[c]``; sorting clones by vertex
    (stable, so clones keep edge-index order — the paper connects clones in
    index order) gives each vertex's incidence list contiguously.
    """
    m = edges.m
    clone_vertex = np.empty(2 * m, dtype=np.int64)
    clone_vertex[0::2] = edges.u
    clone_vertex[1::2] = edges.v
    order = np.argsort(clone_vertex, kind="stable")
    counts = np.bincount(clone_vertex, minlength=edges.n)
    indptr = np.zeros(edges.n + 1, dtype=np.int64)
    np.cumsum(counts, out=indptr[1:])
    return order, indptr


def clone_and_connect(edges: EdgeList) -> ClonedGraph:
    """Literal Definition 3: build D' with 2m clones, original + aux edges."""
    m = edges.m
    clone_vertex = np.empty(2 * m, dtype=np.int64)
    clone_vertex[0::2] = edges.u
    clone_vertex[1::2] = edges.v

    # Original edges between the two clones of each task.
    orig_src = np.arange(0, 2 * m, 2, dtype=np.int64)
    orig_dst = orig_src + 1

    # Auxiliary path edges: consecutive clones in each vertex's incidence
    # list (index order).
    order, indptr = _incidence_order(edges)
    aux_src_list = []
    aux_dst_list = []
    starts = indptr[:-1]
    ends = indptr[1:]
    # Consecutive pairs within each vertex segment, vectorized: a pair
    # (order[i], order[i+1]) is an aux edge iff i and i+1 fall in the same
    # vertex segment.
    if order.size >= 2:
        same_seg = clone_vertex[order[:-1]] == clone_vertex[order[1:]]
        aux_src_list.append(order[:-1][same_seg])
        aux_dst_list.append(order[1:][same_seg])
    aux_src = (
        np.concatenate(aux_src_list) if aux_src_list else np.empty(0, dtype=np.int64)
    )
    aux_dst = (
        np.concatenate(aux_dst_list) if aux_dst_list else np.empty(0, dtype=np.int64)
    )

    src = np.concatenate([orig_src, aux_src])
    dst = np.concatenate([orig_dst, aux_dst])
    w = np.concatenate(
        [
            np.full(m, ORIGINAL_EDGE_WEIGHT, dtype=np.float64),
            np.ones(aux_src.shape[0], dtype=np.float64),
        ]
    )
    g = csr_from_edges(2 * m, src, dst, w)
    return ClonedGraph(
        graph=g,
        clone_vertex=clone_vertex,
        n_original_edges=m,
        aux_src=aux_src,
        aux_dst=aux_dst,
    )


def contracted_clone_graph(edges: EdgeList) -> CSRGraph:
    """D' with every original edge contracted: m nodes (= tasks), aux edges.

    Node i of the result IS task/edge i of D (vertex weight 1).  For every
    original vertex v of degree d, its d incident tasks are chained into a
    path (in index order) with d - 1 auxiliary edges of weight 1.  Parallel
    aux edges (two tasks sharing both endpoints) are merged with summed
    weight, which only helps the partitioner keep them together.
    """
    m = edges.m
    clone_vertex = np.empty(2 * m, dtype=np.int64)
    clone_vertex[0::2] = edges.u
    clone_vertex[1::2] = edges.v
    order, _ = _incidence_order(edges)
    if order.size >= 2:
        same_seg = clone_vertex[order[:-1]] == clone_vertex[order[1:]]
        a = order[:-1][same_seg] >> 1  # clone id -> task id
        b = order[1:][same_seg] >> 1
    else:
        a = np.empty(0, dtype=np.int64)
        b = np.empty(0, dtype=np.int64)
    return csr_from_edges(m, a, b, np.ones(a.shape[0], dtype=np.float64))


def reconstruct_edge_partition(
    cloned: ClonedGraph, clone_labels: np.ndarray
) -> np.ndarray:
    """Definition 4: map a vertex partition of D' to an edge partition of D.

    If the partitioner cut an original edge despite its huge weight (it
    should not), the edge is assigned to the partition of its first clone.
    """
    lab0 = clone_labels[0::2]
    return np.asarray(lab0, dtype=np.int32)

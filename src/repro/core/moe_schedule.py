"""EP-scheduled MoE dispatch (DESIGN.md §3.2) — the paper's model applied to
Mixture-of-Experts routing.

A MoE layer's token→expert routing is a data-affinity problem in exactly the
paper's sense: the *expert weights* are the shared data objects (vertices)
and each routed token is a task touching its top-k experts.  Grouping tokens
so that tokens sharing experts land on the same expert-parallel shard
minimizes the number of (expert, shard) pairs — i.e. the all-to-all /
weight-replication volume — which is the vertex-cut cost `C = Σ_e (p_e − 1)`
with a device's HBM playing the cache role that SM shared memory plays in
the paper.

top-2 routing (jamba) maps to the model literally: one edge per token.  For
top-k > 2 (qwen3-moe top-8, qwen2-moe top-4) a token is a *hyperedge*; we
use the same path decomposition the clone-and-connect transform uses for
vertex incidence lists: the k experts of a token are chained into k−1
pairwise edges.  (This is the standard clique-sparsifier; it preserves the
connectivity objective while keeping m = T·(k−1) linear in tokens.)

Outputs:
  * ``token_shard``  — which expert-parallel shard each token's computation
    is scheduled on (the edge partition).
  * ``expert_shard`` — expert placement: each expert lands on the shard that
    owns the plurality of its tokens (majority vote over incident edges).
  * traffic model    — cross-shard expert fetches under the EP schedule vs
    the default contiguous schedule with round-robin expert placement
    (the analogue of paper Fig. 11's transaction comparison).
"""
from __future__ import annotations

import dataclasses

import numpy as np

from .edge_partition import edge_partition
from .graph import EdgeList
from .metrics import evaluate_edge_partition

__all__ = [
    "routing_affinity_graph",
    "MoEDispatchPlan",
    "plan_moe_dispatch",
    "dispatch_traffic",
]


def routing_affinity_graph(expert_ids: np.ndarray, n_experts: int) -> tuple[EdgeList, np.ndarray]:
    """Build the expert-affinity graph from routed ids.

    ``expert_ids`` is (T, k): the top-k expert of each token.  Returns the
    EdgeList (one path of k−1 edges per token; vertices are experts) and the
    (m,) map from edge id back to token id.
    """
    expert_ids = np.asarray(expert_ids, dtype=np.int64)
    if expert_ids.ndim == 1:
        expert_ids = expert_ids[:, None]
    t, k = expert_ids.shape
    if k < 2:
        # top-1: no sharing structure between experts via single tokens; the
        # graph is edgeless — one degenerate self-edge per token keeps the
        # "edge = token" bookkeeping intact (self loops never cost cut).
        u = expert_ids[:, 0]
        return EdgeList(n=n_experts, u=u.copy(), v=u.copy()), np.arange(t)
    u = expert_ids[:, :-1].reshape(-1)
    v = expert_ids[:, 1:].reshape(-1)
    edge_token = np.repeat(np.arange(t), k - 1)
    return EdgeList(n=n_experts, u=u.copy(), v=v.copy()), edge_token


@dataclasses.dataclass(frozen=True)
class MoEDispatchPlan:
    n_experts: int
    n_shards: int
    token_shard: np.ndarray   # (T,) int32 expert-parallel shard per token
    expert_shard: np.ndarray  # (E,) int32 home shard per expert
    ep_cross_fetches: int     # (token, remote-expert) pairs under this plan
    default_cross_fetches: int  # same under contiguous tokens + round-robin experts
    vertex_cut: int           # C of the edge partition (model objective)
    balance: float

    @property
    def traffic_ratio(self) -> float:
        """EP cross-shard fetches / default cross-shard fetches (lower=better)."""
        if self.default_cross_fetches == 0:
            return 1.0 if self.ep_cross_fetches == 0 else float("inf")
        return self.ep_cross_fetches / self.default_cross_fetches


def dispatch_traffic(
    expert_ids: np.ndarray, token_shard: np.ndarray, expert_shard: np.ndarray
) -> int:
    """Cross-shard fetches: routed (token, expert) pairs whose expert does
    not live on the token's shard — each is one all-to-all transfer of a
    token activation (the redundant load of the paper's model)."""
    expert_ids = np.asarray(expert_ids, dtype=np.int64)
    if expert_ids.ndim == 1:
        expert_ids = expert_ids[:, None]
    home = expert_shard[expert_ids]              # (T, k) shard of each routed expert
    return int((home != token_shard[:, None]).sum())


def _majority_expert_placement(
    expert_ids: np.ndarray, token_shard: np.ndarray, n_experts: int, n_shards: int
) -> np.ndarray:
    """expert -> shard owning the plurality of its routed tokens.

    Ties and unrouted experts fall back to balanced round-robin over the
    least-loaded shards (keeps expert counts per shard even, which the
    expert-parallel layout requires: n_experts/n_shards slots per shard).
    """
    expert_ids = np.asarray(expert_ids, dtype=np.int64)
    if expert_ids.ndim == 1:
        expert_ids = expert_ids[:, None]
    t, k = expert_ids.shape
    votes = np.zeros((n_experts, n_shards), dtype=np.int64)
    flat_e = expert_ids.reshape(-1)
    flat_s = np.repeat(token_shard, k)
    np.add.at(votes, (flat_e, flat_s), 1)

    per_shard = n_experts // n_shards
    extra = n_experts % n_shards
    cap = np.full(n_shards, per_shard, dtype=np.int64)
    cap[:extra] += 1

    # Greedy assignment by decreasing vote strength, respecting slot caps —
    # the balance constraint of Definition 2 applied to expert placement.
    expert_shard = np.full(n_experts, -1, dtype=np.int32)
    load = np.zeros(n_shards, dtype=np.int64)
    order = np.argsort(-votes.max(axis=1), kind="stable")
    for e in order:
        pref = np.argsort(-votes[e], kind="stable")
        placed = False
        for s in pref:
            if load[s] < cap[s]:
                expert_shard[e] = s
                load[s] += 1
                placed = True
                break
        if not placed:  # pragma: no cover - caps always sum to n_experts
            s = int(np.argmin(load))
            expert_shard[e] = s
            load[s] += 1
    return expert_shard


def plan_moe_dispatch(
    expert_ids: np.ndarray,
    n_experts: int,
    n_shards: int,
    method: str = "ep",
    seed: int = 0,
) -> MoEDispatchPlan:
    """Schedule tokens + place experts across expert-parallel shards.

    The edge partition groups tokens (tasks) into shards minimizing expert
    replication; expert placement then follows the token majority.  The
    default comparison point is what a framework does with no model:
    contiguous token chunks + round-robin expert placement.
    """
    expert_ids = np.asarray(expert_ids, dtype=np.int64)
    if expert_ids.ndim == 1:
        expert_ids = expert_ids[:, None]
    t, k = expert_ids.shape

    graph, edge_token = routing_affinity_graph(expert_ids, n_experts)
    res = edge_partition(graph, n_shards, method=method, seed=seed)

    # Token shard = shard of its first path edge (all of a token's edges are
    # chained, so the partitioner already pulls them together; using the
    # first is the Definition-4 reconstruction applied per token).
    token_shard = np.empty(t, dtype=np.int32)
    first_edge = np.searchsorted(edge_token, np.arange(t), side="left")
    token_shard[:] = res.labels[first_edge]

    expert_shard = _majority_expert_placement(expert_ids, token_shard, n_experts, n_shards)
    ep_fetches = dispatch_traffic(expert_ids, token_shard, expert_shard)

    # Default: contiguous equal chunks of tokens, round-robin experts.
    chunk = -(-t // n_shards)
    default_token_shard = (np.arange(t) // chunk).astype(np.int32)
    default_expert_shard = (np.arange(n_experts) % n_shards).astype(np.int32)
    default_fetches = dispatch_traffic(expert_ids, default_token_shard, default_expert_shard)

    quality = evaluate_edge_partition(graph, res.labels, n_shards)
    return MoEDispatchPlan(
        n_experts=n_experts,
        n_shards=n_shards,
        token_shard=token_shard,
        expert_shard=expert_shard,
        ep_cross_fetches=ep_fetches,
        default_cross_fetches=default_fetches,
        vertex_cut=quality.vertex_cut,
        balance=quality.balance,
    )

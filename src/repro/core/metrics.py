"""Partition quality metrics (paper Definition 2 and §3.1).

The central quantity is the total vertex-cut cost

    C = sum_v (p_v - 1)

where p_v is the number of distinct edge clusters that vertex v's incident
edges fall into.  C equals the number of *redundant data accesses*: every
extra cluster a data object appears in is one extra fetch from off-chip
memory (HBM on TPU).
"""
from __future__ import annotations

import dataclasses

import numpy as np

from .graph import EdgeList

__all__ = [
    "vertex_cut_cost",
    "parts_per_vertex",
    "edge_balance_factor",
    "replication_factor",
    "redundant_load_fraction",
    "PartitionQuality",
    "evaluate_edge_partition",
]


def parts_per_vertex(edges: EdgeList, labels: np.ndarray, k: int) -> np.ndarray:
    """p_v = number of distinct clusters among v's incident edges (0 for
    isolated vertices)."""
    labels = np.asarray(labels, dtype=np.int64)
    v_ids = np.concatenate([edges.u, edges.v])
    l_ids = np.concatenate([labels, labels])
    key = v_ids.astype(np.int64) * k + l_ids
    uniq = np.unique(key)
    pv = np.bincount((uniq // k).astype(np.int64), minlength=edges.n)
    return pv


def vertex_cut_cost(edges: EdgeList, labels: np.ndarray, k: int) -> int:
    """C = sum_v (p_v - 1), the data-reuse cost / redundant access count."""
    pv = parts_per_vertex(edges, labels, k)
    touched = pv > 0
    return int((pv[touched] - 1).sum())


def edge_balance_factor(labels: np.ndarray, k: int) -> float:
    """max cluster size / average cluster size (paper: <1.03 in practice)."""
    counts = np.bincount(np.asarray(labels, dtype=np.int64), minlength=k)
    avg = labels.shape[0] / k
    return float(counts.max() / avg) if avg > 0 else 1.0


def replication_factor(edges: EdgeList, labels: np.ndarray, k: int) -> float:
    """Average number of clusters each touched data object appears in."""
    pv = parts_per_vertex(edges, labels, k)
    touched = pv > 0
    return float(pv[touched].mean()) if touched.any() else 0.0


def redundant_load_fraction(edges: EdgeList, labels: np.ndarray, k: int) -> float:
    """Fraction of loads that are redundant: C / (n_touched + C).

    Each touched object needs 1 compulsory load + (p_v - 1) redundant ones.
    The paper reports 73.4% redundancy for cfd under default scheduling.
    """
    pv = parts_per_vertex(edges, labels, k)
    touched = pv > 0
    total = int(pv[touched].sum())
    compulsory = int(touched.sum())
    return (total - compulsory) / total if total else 0.0


@dataclasses.dataclass(frozen=True)
class PartitionQuality:
    k: int
    vertex_cut: int
    balance: float
    replication: float
    redundant_fraction: float
    loads_total: int  # sum_v p_v = memory fetches under this schedule

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)


def evaluate_edge_partition(edges: EdgeList, labels: np.ndarray, k: int) -> PartitionQuality:
    pv = parts_per_vertex(edges, labels, k)
    touched = pv > 0
    total = int(pv[touched].sum())
    compulsory = int(touched.sum())
    return PartitionQuality(
        k=k,
        vertex_cut=total - compulsory,
        balance=edge_balance_factor(labels, k),
        replication=float(pv[touched].mean()) if compulsory else 0.0,
        redundant_fraction=(total - compulsory) / total if total else 0.0,
        loads_total=total,
    )

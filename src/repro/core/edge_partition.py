"""Balanced edge partitioning — the paper's core contribution (§3).

``edge_partition(edges, k)`` assigns every task (edge) to one of k clusters
(thread blocks on a GPU; Pallas grid cells / mesh shards on TPU), minimizing
the total vertex-cut cost under balance.

Methods:
  * ``"ep"``            — the paper's model: clone-and-connect + multilevel
                          vertex partitioning, via the contracted form
                          (exact, 2x smaller; see transform.py).
  * ``"ep-cloned"``     — literal Definition 3 on the 2m-clone graph with
                          huge weights on original edges (kept for fidelity
                          and for the theorem tests).
  * ``"default" | "random" | "greedy" | "hypergraph"`` — baselines (§3.3).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Literal

import numpy as np

from .baselines import (
    default_schedule,
    greedy_powergraph,
    hypergraph_partition,
    random_partition,
)
from .graph import EdgeList
from .metrics import PartitionQuality, evaluate_edge_partition
from .partition import MultilevelOptions, PartitionStats, partition_vertices
from .transform import (
    clone_and_connect,
    contracted_clone_graph,
    reconstruct_edge_partition,
)

__all__ = ["EdgePartitionResult", "edge_partition", "Method"]

Method = Literal["ep", "ep-cloned", "default", "random", "greedy", "hypergraph"]


@dataclasses.dataclass(frozen=True)
class EdgePartitionResult:
    labels: np.ndarray  # (m,) int32 cluster per task
    k: int
    method: str
    quality: PartitionQuality
    partition_time_s: float
    # Multilevel per-stage timings (coarsen/init/refine) when the method
    # ran the vertex partitioner; None for baselines.
    stats: PartitionStats | None = None

    @property
    def vertex_cut(self) -> int:
        return self.quality.vertex_cut


def edge_partition(
    edges: EdgeList,
    k: int,
    method: Method = "ep",
    opts: MultilevelOptions | None = None,
    seed: int = 0,
    service=None,
    tenant: str = "default",
    priority: int = 0,
) -> EdgePartitionResult:
    if k < 1:
        raise ValueError("k must be >= 1")
    if service is not None:
        # Serving path: consult the async partition service's fingerprint
        # cache (repeated graphs skip partitioning entirely, paper §4.2).
        # ``tenant`` charges the request to that tenant's cache budget;
        # ``priority`` orders it in the service's worker queue.
        return service.get(
            edges, k, method=method, opts=opts, seed=seed,
            tenant=tenant, priority=priority,
        ).result
    t0 = time.perf_counter()
    pstats: PartitionStats | None = None
    if method == "ep":
        g = contracted_clone_graph(edges)
        mo = opts or MultilevelOptions(seed=seed)
        labels, pstats = partition_vertices(g, k, mo)
    elif method == "ep-cloned":
        cg = clone_and_connect(edges)
        mo = opts or MultilevelOptions(seed=seed)
        clone_labels, pstats = partition_vertices(cg.graph, k, mo)
        labels = reconstruct_edge_partition(cg, clone_labels)
    elif method == "default":
        labels = default_schedule(edges, k)
    elif method == "random":
        labels = random_partition(edges, k, seed=seed)
    elif method == "greedy":
        labels = greedy_powergraph(edges, k, seed=seed)
    elif method == "hypergraph":
        labels = hypergraph_partition(edges, k, opts)
    else:
        raise ValueError(f"unknown method {method!r}")
    dt = time.perf_counter() - t0
    quality = evaluate_edge_partition(edges, labels, k)
    return EdgePartitionResult(
        labels=np.asarray(labels, dtype=np.int32),
        k=k,
        method=method,
        quality=quality,
        partition_time_s=dt,
        stats=pstats,
    )

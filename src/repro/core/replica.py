"""Replicated plan service: N ``PartitionService`` replicas, one facade.

The paper's bet is that scheduling work pays only if the partition plan is
*always there* on the hot path; a single service process makes every tenant
one crash away from cold-start latency.  :class:`ReplicaGroup` runs N
replicas behind the same submit/get surface ``GraphServer`` already speaks:

* **Health** — built on :class:`~repro.runtime.fault.HeartbeatRegistry`.
  A replica beats once per group-observed job completion (idle replicas are
  beaten on the pump so silence means *stuck*, not *unused*); a missed
  deadline marks it suspect and drains its routing weight to zero until it
  beats again.
* **Failover** — a ticket in flight on a crashed or suspect replica is
  resubmitted to a healthy one.  Resubmission is idempotent by plan
  fingerprint (the same request re-keys to the same plan, so the target's
  cache/coalescing absorbs duplicates), paced by exponential backoff with
  seeded jitter, and bounded by a per-ticket retry budget — exhaustion
  raises the typed :class:`ReplicaExhaustedError`.
* **Hedging** — when the primary lane is slower than a p99-derived hedge
  delay, a secondary submit fires on a different replica; first complete
  wins and the loser is cancelled through the existing ``PlanScheduler``
  cancellation path (queued → dropped, in-flight → marked, coalesced →
  detached).
* **Shared plan store** — completed plans are published into a group-owned
  :class:`~repro.core.plan_cache.PlanCache`; the anti-entropy pump copies
  fingerprints each replica is missing back into its local cache on a sync
  interval, so a warm hit on any replica is a warm hit on all.  Replicas
  behind a process boundary (``core/transport.py``'s ``RemoteReplica``)
  sync by pairwise gossip instead: fingerprint-digest exchange, then
  pull/push only the missing entries over the wire.
* **Graceful degradation** — when every replica is suspect/crashed, the
  group serves the freshest cached plan with ``ticket.stale = True``
  (surfaced as ``ServeInfo.stale`` by the request layer) instead of
  erroring; only with nothing cached does it raise.

Every group request is driven by a small state machine on a dedicated
daemon thread (submit → poll → hedge → failover → resolve), so callers keep
the plain future surface (``ticket.result(timeout)``) and identical
concurrent requests coalesce onto one driver.  :class:`FaultInjector`
provides deterministic, seeded crash/stall/heartbeat-drop schedules (with an
injectable clock) for the tests and ``benchmarks/svc_chaos.py``.
"""
from __future__ import annotations

import dataclasses
import hashlib
import threading
import time
from collections import deque
from typing import Any, Callable, Optional, Sequence

import numpy as np

from ..runtime.fault import CircuitBreaker, HeartbeatRegistry, OverloadSchedule
from .graph import EdgeList, affinity_graph_from_coo
from .partition import MultilevelOptions
from .partition_service import (
    DoubleBuffer,
    PartitionService,
    ServicePlan,
    ServiceStats,
    graph_fingerprint,
)
from .plan_cache import PlanCache
from .plan_scheduler import (
    AdmissionRejectedError,
    PlanTicket,
    ServiceClosedError,
    ServiceMetrics,
    _latency_summary,
)

__all__ = [
    "FaultInjector",
    "ReplicaExhaustedError",
    "ReplicaGroup",
    "ReplicaMetrics",
    "ReplicaStats",
    "ReplicaTicket",
]


class ReplicaExhaustedError(RuntimeError):
    """No replica could complete the request within the retry budget, and no
    cached plan was available to serve stale."""


def _pct(xs: list[float], p: float) -> float:
    if not xs:
        return 0.0
    ys = sorted(xs)
    return ys[min(len(ys) - 1, int(p * len(ys)))]


# ---------------------------------------------------------------------------
# Deterministic fault injection
# ---------------------------------------------------------------------------


class FaultInjector:
    """Seeded, deterministic fault schedules for a :class:`ReplicaGroup`.

    Three fault kinds, all scheduled up front so a chaos run replays
    identically:

    * ``crash_after_jobs(rid, n)`` / ``crash_at(rid, t_s)`` — the group
      kills the replica once it has completed ``n`` group-observed jobs /
      once ``t_s`` seconds (injected clock) have passed since :meth:`arm`.
    * ``stall_jobs(rid, delay_s, first, last)`` — jobs ``first..last``
      (0-based, per-replica dispatch order) sleep ``delay_s`` before
      executing, via ``PlanScheduler.pre_job_hook`` — a straggler, not a
      corpse: the work still completes.
    * ``drop_heartbeats(rid, count)`` — the next ``count`` beats for the
      replica are swallowed, so a live replica goes suspect exactly when
      the schedule says.

    Process-level probes (meaningful for socket-backed replicas, see
    ``core/transport.py``) schedule real OS faults by completed-job count:

    * ``sigkill_after_jobs(rid, n)`` — ``kill -9`` the worker process: no
      drain, no goodbye; only wire errors and missed heartbeats reveal it.
    * ``sigstop_after_jobs(rid, n)`` — pause the worker: it holds its
      sockets but answers nothing, so per-RPC deadlines are the only
      detection signal.
    * ``sever_after_jobs(rid, n)`` — cut the replica's client socket
      mid-frame; the connection supervisor must reconnect and no ticket
      may be lost.

    The group fires these from the pump (``process_fault_due``) against
    replicas that expose the matching probe surface; for in-process
    replicas ``sigkill`` degrades to a plain :meth:`ReplicaGroup.kill`
    and the other two are no-ops.

    The injector records every fired event in ``events`` (kind, replica,
    t_rel) for assertions and bench reporting.
    """

    def __init__(self, seed: int = 0, clock: Callable[[], float] = time.monotonic) -> None:
        self.seed = seed
        self.rng = np.random.default_rng(seed)
        self.clock = clock
        self._t0: Optional[float] = None
        self._crash_jobs: dict[str, int] = {}
        self._crash_at: dict[str, float] = {}
        self._stalls: dict[str, list[tuple[int, int, float]]] = {}
        self._drops: dict[str, int] = {}
        self._dispatched: dict[str, int] = {}
        self._process_faults: dict[str, list[tuple[str, int]]] = {}
        self._overload: Optional[OverloadSchedule] = None
        self._flood_logged: set[str] = set()
        self._lock = threading.Lock()
        self.events: list[tuple[str, str, float]] = []

    # -- schedule builders (chainable) --------------------------------------

    def crash_after_jobs(self, replica: str, jobs: int) -> "FaultInjector":
        self._crash_jobs[replica] = int(jobs)
        return self

    def crash_at(self, replica: str, t_s: float) -> "FaultInjector":
        self._crash_at[replica] = float(t_s)
        return self

    def stall_jobs(self, replica: str, delay_s: float, first: int = 0,
                   last: Optional[int] = None) -> "FaultInjector":
        hi = (1 << 30) if last is None else int(last)
        self._stalls.setdefault(replica, []).append((int(first), hi, float(delay_s)))
        return self

    def drop_heartbeats(self, replica: str, count: int) -> "FaultInjector":
        self._drops[replica] = self._drops.get(replica, 0) + int(count)
        return self

    def sigkill_after_jobs(self, replica: str, jobs: int) -> "FaultInjector":
        self._process_faults.setdefault(replica, []).append(
            ("sigkill", int(jobs)))
        return self

    def sigstop_after_jobs(self, replica: str, jobs: int) -> "FaultInjector":
        self._process_faults.setdefault(replica, []).append(
            ("sigstop", int(jobs)))
        return self

    def sever_after_jobs(self, replica: str, jobs: int) -> "FaultInjector":
        self._process_faults.setdefault(replica, []).append(
            ("sever", int(jobs)))
        return self

    def flood(self, tenant: str, factor: float, start_s: float = 0.0,
              duration_s: float = 1.0) -> "FaultInjector":
        """Arm a per-tenant overload window: during ``[start_s, start_s +
        duration_s)`` of injected time, :meth:`flood_factor` reports
        ``factor`` — the rate multiplier a bench's load generator applies to
        that tenant.  Windows compose via :class:`OverloadSchedule`, so a
        chaos run's flood phase replays identically."""
        if self._overload is None:
            self._overload = OverloadSchedule(clock=self.now)
        self._overload.add(tenant, start_s, duration_s, factor)
        return self

    def flood_factor(self, tenant: str) -> float:
        """Current load multiplier for ``tenant`` (1.0 outside windows).
        The first in-window probe per tenant logs a ``flood`` event."""
        if self._overload is None:
            return 1.0
        f = self._overload.factor_at(tenant)
        if f != 1.0:
            with self._lock:
                first = tenant not in self._flood_logged
                self._flood_logged.add(tenant)
            if first:
                self._log("flood", tenant)
        return f

    # -- group-facing probes ------------------------------------------------

    def arm(self) -> None:
        """Start the injected clock; called by the group at construction."""
        if self._t0 is None:
            self._t0 = self.clock()

    def now(self) -> float:
        return 0.0 if self._t0 is None else self.clock() - self._t0

    def _log(self, kind: str, replica: str) -> None:
        self.events.append((kind, replica, self.now()))

    def job_dispatched(self, replica: str) -> float:
        """Per-replica dispatch tick; returns the stall delay for this job."""
        with self._lock:
            i = self._dispatched.get(replica, 0)
            self._dispatched[replica] = i + 1
            for first, last, delay in self._stalls.get(replica, ()):
                if first <= i <= last:
                    self._log("stall", replica)
                    return delay
        return 0.0

    def crash_due(self, replica: str, jobs_completed: int) -> bool:
        """True once the replica's scheduled crash point has been reached."""
        with self._lock:
            jobs = self._crash_jobs.get(replica)
            if jobs is not None and jobs_completed >= jobs:
                del self._crash_jobs[replica]
                self._log("crash", replica)
                return True
            t = self._crash_at.get(replica)
            if t is not None and self.now() >= t:
                del self._crash_at[replica]
                self._log("crash", replica)
                return True
        return False

    def process_fault_due(self, replica: str, jobs_completed: int) -> Optional[str]:
        """The next due process-level fault kind for the replica, or None.
        Each scheduled fault fires exactly once (and is logged)."""
        with self._lock:
            for i, (kind, jobs) in enumerate(self._process_faults.get(replica, ())):
                if jobs_completed >= jobs:
                    del self._process_faults[replica][i]
                    self._log(kind, replica)
                    return kind
        return None

    def take_heartbeat(self, replica: str) -> bool:
        """False when this beat is scheduled to be dropped."""
        with self._lock:
            left = self._drops.get(replica, 0)
            if left > 0:
                self._drops[replica] = left - 1
                self._log("drop_beat", replica)
                return False
        return True


# ---------------------------------------------------------------------------
# Tickets and metrics
# ---------------------------------------------------------------------------


class ReplicaTicket:
    """Group-level future; same waiting surface as ``PlanTicket``.

    Extra fields over a plain ticket: ``stale`` (resolved from the shared
    store because no replica was healthy), ``retries`` (failover
    resubmissions consumed), ``hedged`` (a secondary lane fired), and
    ``replica`` (the id that served it; None for store hits).  Group tickets
    are not cancellable — the group itself owns lane lifecycle — so
    :meth:`cancel` only detaches a caller's buffer.
    """

    def __init__(self, tenant: str = "default", priority: int = 0) -> None:
        self._event = threading.Event()
        self._value: Optional[ServicePlan] = None
        self._error: Optional[BaseException] = None
        self._buffers: list = []
        self._lock = threading.Lock()
        self.cache_hit = False
        self.stale = False
        self.cancelled = False
        self.tenant = tenant
        self.priority = priority
        self.retries = 0
        self.hedged = False
        self.replica: Optional[str] = None

    def _resolve(self, value: ServicePlan) -> None:
        with self._lock:
            buffers = list(self._buffers)
        for buf in buffers:
            buf.publish(value)
        self._value = value
        self._event.set()

    def _fail(self, err: BaseException) -> None:
        self._error = err
        self._event.set()

    def done(self) -> bool:
        return self._event.is_set()

    def cancel(self, buffer: DoubleBuffer | None = None) -> bool:
        if buffer is not None:
            with self._lock:
                if buffer in self._buffers:
                    self._buffers.remove(buffer)
        return False

    def result(self, timeout: float | None = None) -> ServicePlan:
        if not self._event.wait(timeout):
            raise TimeoutError("partition not ready")
        if self._error is not None:
            raise self._error
        return self._value  # type: ignore[return-value]


@dataclasses.dataclass
class ReplicaStats:
    """Point-in-time view of one replica inside the group."""

    replica: str
    state: str  # "healthy" | "suspect" | "crashed"
    weight: float
    beats: int
    jobs_completed: int
    failovers_from: int
    hedges_to: int
    p50_ms: float
    p99_ms: float
    rejections: int = 0  # admission rejections this replica answered
    breakers_open: int = 0  # per-tenant breakers currently not closed
    breaker_trips: int = 0  # total open transitions across tenants

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)


@dataclasses.dataclass
class ReplicaMetrics:
    """Group-level snapshot: per-replica rows + failover/hedge counters.

    ``lost`` is the invariant the chaos bench gates on: group tickets that
    will never resolve (submitted minus resolved minus failed minus still
    pending) — it must be zero through any crash schedule.
    """

    replicas: list[ReplicaStats]
    submitted: int
    resolved: int
    failed: int
    pending: int
    coalesced: int
    failovers: int
    retries: int
    hedges_fired: int
    hedges_won: int
    hedges_lost: int
    stale_serves: int
    store_entries: int
    store_publishes: int

    @property
    def lost(self) -> int:
        return max(0, self.submitted - self.resolved - self.failed - self.pending)

    def as_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d["replicas"] = [r.as_dict() if isinstance(r, ReplicaStats) else r
                         for r in self.replicas]
        d["lost"] = self.lost
        return d


# ---------------------------------------------------------------------------
# Internal request/lane records
# ---------------------------------------------------------------------------


class _Lane:
    """One attempt of a group request on one replica."""

    __slots__ = ("rid", "ticket", "kind", "t_start")

    def __init__(self, rid: str, ticket: PlanTicket, kind: str, t_start: float) -> None:
        self.rid = rid
        self.ticket = ticket
        self.kind = kind  # "primary" | "failover" | "hedge"
        self.t_start = t_start


class _GroupRequest:
    """One coalesced group-level request, driven by a dedicated thread."""

    __slots__ = ("key", "fingerprint", "base_plan", "submit_fn", "match_fn",
                 "tenant", "priority", "ticket", "waiters", "t_submit",
                 "deadline", "timeout_s", "last_rejection")

    def __init__(self, key, fingerprint, base_plan, submit_fn, match_fn,
                 tenant, priority, t_submit, deadline=None,
                 timeout_s=None) -> None:
        self.key = key
        self.fingerprint = fingerprint  # known up front for full submits
        self.base_plan = base_plan  # stale-serve fallback for updates
        self.submit_fn = submit_fn  # svc -> PlanTicket
        self.match_fn = match_fn  # plan -> bool: usable as a stale stand-in?
        self.tenant = tenant
        self.priority = priority
        self.ticket = ReplicaTicket(tenant=tenant, priority=priority)
        self.waiters = 1
        self.t_submit = t_submit
        self.deadline = deadline  # absolute (group clock); None = unbounded
        self.timeout_s = timeout_s  # the caller's timeout, for the error text
        # Freshest AdmissionRejectedError any replica answered: when the
        # retry budget dies on overload, the caller gets the typed rejection
        # (with its retry_after_s hint) instead of a generic exhaustion.
        self.last_rejection: Optional[AdmissionRejectedError] = None


class _Replica:
    """Book-keeping for one member service."""

    __slots__ = ("rid", "svc", "crashed", "inflight", "jobs_completed",
                 "beats", "failovers_from", "hedges_to", "latencies",
                 "rejections", "breakers")

    def __init__(self, rid: str, svc: PartitionService) -> None:
        self.rid = rid
        self.svc = svc
        self.crashed = False
        self.inflight = 0
        self.jobs_completed = 0
        self.beats = 0
        self.failovers_from = 0
        self.hedges_to = 0
        self.latencies: deque[float] = deque(maxlen=512)
        self.rejections = 0  # admission rejections answered by this replica
        # (tenant -> CircuitBreaker): per-tenant so a flooding tenant's
        # rejections open *its* breaker without blacklisting the replica
        # for well-behaved tenants.
        self.breakers: dict[str, CircuitBreaker] = {}


# ---------------------------------------------------------------------------
# ReplicaGroup
# ---------------------------------------------------------------------------


class ReplicaGroup:
    """N ``PartitionService`` replicas behind one submit/get facade.

    Duck-type compatible with ``PartitionService`` where ``GraphServer`` and
    the launch demos touch it: ``submit`` / ``get`` / ``get_spmv_plan`` /
    ``update_async`` / ``update`` / ``metrics()`` / ``stats`` / ``close()``
    / context manager.  Replicas must be identically configured — the group
    fingerprints requests against replica 0's defaults and treats the
    fingerprint as the idempotency key across all members.

    ``replicas`` is either a count (members built via ``factory`` or as
    plain ``PartitionService(**service_kwargs)``) or an explicit sequence of
    services — including ``core.transport.RemoteReplica`` adapters for
    workers in separate OS processes (``launch.replica_worker``), which
    slot in behind the same driver loop: heartbeats become wire pings,
    store sync becomes gossip, and a dead worker is just a replica whose
    lanes fail.  Health checking and anti-entropy run on the *pump*, which is
    called opportunistically by every submit and every driver poll tick —
    no background thread, so tests with an injected ``clock`` stay
    deterministic by calling :meth:`pump` themselves.
    """

    def __init__(
        self,
        replicas: int | Sequence[PartitionService] = 2,
        *,
        factory: Optional[Callable[[int], PartitionService]] = None,
        heartbeat_deadline_s: float = 2.0,
        sync_interval_s: float = 0.05,
        hedge: bool = True,
        hedge_delay_s: Optional[float] = None,
        hedge_p99_factor: float = 1.5,
        hedge_min_delay_s: float = 0.05,
        retry_budget: int = 3,
        backoff_base_s: float = 0.01,
        backoff_cap_s: float = 0.25,
        backoff_jitter: float = 0.5,
        breaker_failures: int = 3,
        breaker_cooldown_s: float = 1.0,
        store: Optional[PlanCache] = None,
        store_entries: int = 256,
        allow_stale: bool = True,
        injector: Optional[FaultInjector] = None,
        seed: int = 0,
        clock: Callable[[], float] = time.monotonic,
        sleep: Callable[[float], None] = time.sleep,
        poll_interval_s: float = 0.002,
        **service_kwargs,
    ) -> None:
        if isinstance(replicas, int):
            if replicas < 1:
                raise ValueError("need at least one replica")
            make = factory or (lambda i: PartitionService(**service_kwargs))
            services = [make(i) for i in range(replicas)]
        else:
            services = list(replicas)
            if not services:
                raise ValueError("need at least one replica")
        self._replicas = [_Replica(f"r{i}", svc) for i, svc in enumerate(services)]
        self._by_rid = {rep.rid: rep for rep in self._replicas}
        self.hedge = hedge
        self.hedge_delay_s = hedge_delay_s
        self.hedge_p99_factor = hedge_p99_factor
        self.hedge_min_delay_s = hedge_min_delay_s
        self.retry_budget = int(retry_budget)
        self.backoff_base_s = backoff_base_s
        self.backoff_cap_s = backoff_cap_s
        self.backoff_jitter = backoff_jitter
        self.breaker_failures = int(breaker_failures)
        self.breaker_cooldown_s = breaker_cooldown_s
        self.allow_stale = allow_stale
        self.sync_interval_s = sync_interval_s
        self.poll_interval_s = poll_interval_s
        self._clock = clock
        self._sleep = sleep
        self._rng = np.random.default_rng(seed)
        self._injector = injector
        self._store = store if store is not None else PlanCache(max_entries=store_entries)
        self._store_tenant: dict[str, str] = {}
        self._registry = HeartbeatRegistry(deadline_s=heartbeat_deadline_s, clock=clock)
        self._lock = threading.RLock()
        self._inflight: dict[Any, _GroupRequest] = {}
        self._rr = 0
        self._driver_seq = 0
        self._last_sync = clock()
        self._closed = False
        # Counters (guarded by _lock).
        self._m_submitted = 0
        self._m_resolved = 0
        self._m_failed = 0
        self._m_coalesced = 0
        self._m_failovers = 0
        self._m_retries = 0
        self._m_hedges_fired = 0
        self._m_hedges_won = 0
        self._m_hedges_lost = 0
        self._m_stale = 0
        self._m_publishes = 0
        self._latencies: deque[float] = deque(maxlen=2048)
        for rep in self._replicas:
            # register(), not beat(): the deadline clock starts at
            # construction without crediting a heartbeat the replica never
            # sent — the fix that makes silent-from-birth replicas visible.
            self._registry.register(rep.rid)
            if injector is not None:
                rep.svc.scheduler.pre_job_hook = self._make_stall_hook(rep.rid)
        if injector is not None:
            injector.arm()

    def _make_stall_hook(self, rid: str) -> Callable[[Any], None]:
        def hook(_key) -> None:
            delay = self._injector.job_dispatched(rid)
            if delay > 0:
                self._sleep(delay)
        return hook

    # -- lifecycle ----------------------------------------------------------

    @property
    def closed(self) -> bool:
        return self._closed

    def close(self) -> None:
        """Close every replica (graceful drain each); idempotent.  Requests
        still in flight fail over normally until their replicas drain."""
        with self._lock:
            if self._closed:
                return
            self._closed = True
        for rep in self._replicas:
            rep.svc.close()

    def __enter__(self) -> "ReplicaGroup":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    def kill(self, rid: str) -> None:
        """Crash a replica *now*: it stops receiving work immediately, its
        in-flight group lanes fail over, and the orphaned service is drained
        in the background (queued local tickets fail with
        ``ServiceClosedError``, which drivers also treat as failover)."""
        rep = self._by_rid[rid]
        with self._lock:
            if rep.crashed:
                return
            rep.crashed = True
        threading.Thread(target=rep.svc.close, name=f"replica-reaper-{rid}",
                         daemon=True).start()

    # -- health + anti-entropy pump -----------------------------------------

    def _weight(self, rep: _Replica) -> float:
        """Routing weight: suspect and crashed replicas are fully drained."""
        if rep.crashed or not self._registry.alive(rep.rid):
            return 0.0
        return 1.0

    def _beat(self, rep: _Replica) -> None:
        if self._injector is not None and not self._injector.take_heartbeat(rep.rid):
            return
        probe = getattr(rep.svc, "heartbeat", None)
        if probe is not None and not probe():
            # Socket-backed replica: the beat is credited only when the
            # worker actually answered a ping over the wire — a SIGKILLed
            # or SIGSTOPped worker stays silent and goes suspect on the
            # registry deadline like any stuck replica.
            return
        self._registry.beat(rep.rid)
        rep.beats += 1

    def _apply_process_fault(self, rep: _Replica, kind: str) -> None:
        """Fire a scheduled process-level fault against ``rep``.  Remote
        replicas take the real OS fault; in-process ones degrade: sigkill
        becomes a plain crash, sigstop/sever have no process to act on."""
        probe = getattr(rep.svc, kind if kind != "sever" else "sever_connection",
                        None)
        if probe is not None:
            try:
                probe()
            except OSError:
                pass  # already-dead worker: the fault is moot
        elif kind == "sigkill":
            self.kill(rep.rid)

    def pump(self) -> None:
        """One maintenance tick: fire due time-based crashes, beat idle
        replicas, run the heartbeat deadline check, and (rate-limited by
        ``sync_interval_s``) anti-entropy-sync the shared store into each
        healthy replica's local cache.  Drivers and submits call this
        continuously; deterministic tests call it manually."""
        with self._lock:
            for rep in self._replicas:
                if rep.crashed:
                    continue
                if self._injector is not None and self._injector.crash_due(
                        rep.rid, rep.jobs_completed):
                    self.kill(rep.rid)
                    continue
                if self._injector is not None:
                    fault = self._injector.process_fault_due(
                        rep.rid, rep.jobs_completed)
                    if fault is not None:
                        self._apply_process_fault(rep, fault)
                        if fault == "sigkill":
                            continue
                if rep.inflight == 0:
                    # Idle is not dead: beat on its behalf so only replicas
                    # sitting on stuck work go suspect.
                    self._beat(rep)
            self._registry.check()
            now = self._clock()
            do_sync = now - self._last_sync >= self.sync_interval_s
            if do_sync:
                self._last_sync = now
        if do_sync:
            self._sync_store()

    def _sync_store(self) -> None:
        """Anti-entropy round between the shared store and each replica.

        In-process replicas get the direct copy (store entries they are
        missing land in their local cache).  Socket-backed replicas
        (anything exposing ``gossip_fingerprints``) run pairwise gossip
        instead: exchange fingerprint digests, *pull* entries the store has
        never seen, *push* only what the worker is missing — entries travel
        in the ``plan_cache`` persistence envelope, and a plan pulled from
        one worker propagates to the others on the following rounds.  An
        unreachable worker just skips its round; the next sync retries."""
        store_fps = set(self._store.fingerprints())
        for rep in self._replicas:
            if rep.crashed or rep.svc.closed:
                continue
            if hasattr(rep.svc, "gossip_fingerprints"):
                try:
                    have = set(rep.svc.gossip_fingerprints())
                    pulled = rep.svc.gossip_pull(
                        [fp for fp in have if fp not in store_fps])
                    for fp, tenant, _pinned, plan in pulled:
                        self._publish(plan, tenant)
                        store_fps.add(fp)
                    push = []
                    for fp in store_fps - have:
                        plan = self._store.peek(fp)
                        if plan is not None:
                            push.append((fp, self._store_tenant.get(fp, "default"),
                                         False, plan))
                    rep.svc.gossip_push(push)
                except Exception:
                    continue
            else:
                for fp in store_fps:
                    plan = self._store.peek(fp)
                    if plan is None:
                        continue
                    if rep.svc.plan_cache.peek(fp) is None:
                        rep.svc.plan_cache.put(
                            plan, tenant=self._store_tenant.get(fp, "default"))

    def _publish(self, plan: ServicePlan, tenant: str) -> None:
        if self._store.peek(plan.fingerprint) is None:
            self._store.put(plan, tenant=tenant)
            self._store_tenant[plan.fingerprint] = tenant
            with self._lock:
                self._m_publishes += 1

    # -- routing ------------------------------------------------------------

    def _pick(self, exclude: set[str] = frozenset()) -> Optional[_Replica]:
        """Round-robin over healthy replicas, preferring ones not in
        ``exclude``; falls back to any healthy one; None when none are."""
        with self._lock:
            healthy = [r for r in self._replicas if self._weight(r) > 0.0]
            preferred = [r for r in healthy if r.rid not in exclude] or healthy
            if not preferred:
                return None
            self._rr += 1
            return preferred[self._rr % len(preferred)]

    def _hedge_delay(self) -> float:
        if self.hedge_delay_s is not None:
            return self.hedge_delay_s
        with self._lock:
            xs = list(self._latencies)
        if not xs:
            return self.hedge_min_delay_s
        return max(self.hedge_min_delay_s, self.hedge_p99_factor * _pct(xs, 0.99))

    def _backoff(self, attempt: int) -> float:
        base = min(self.backoff_cap_s, self.backoff_base_s * (2.0 ** attempt))
        with self._lock:
            jitter = float(self._rng.random())
        return base * (1.0 + self.backoff_jitter * jitter)

    def _clamp_delay(self, delay: float, req: _GroupRequest) -> float:
        """Never sleep past the request deadline: the expiry check at the
        top of the driver loop should fire on time, not a backoff later."""
        if req.deadline is None:
            return delay
        return max(0.0, min(delay, req.deadline - self._clock()))

    def _hedge_budget_ok(self, req: _GroupRequest, now: float) -> bool:
        """Hedge only while the request has at least ``hedge_min_delay_s``
        of deadline budget left: a secondary lane opened closer to expiry
        than the smallest useful hedge window cannot win — it only burns a
        replica slot that failover (or another request) could use."""
        return (req.deadline is None
                or req.deadline - now >= self.hedge_min_delay_s)

    # -- request driving ----------------------------------------------------

    def _stale_candidate(self, req: _GroupRequest) -> Optional[tuple[ServicePlan, bool]]:
        """(plan, stale) fallback when no replica is healthy: the exact
        fingerprint if the store has it (a plain warm hit), else the base
        plan for updates / the freshest *shape-compatible* store entry —
        genuinely stale.  ``match_fn`` gates compatibility: a plan for a
        structurally different graph would feed wrong-shaped operands to the
        kernel layer, so "freshest cached plan" means freshest plan the
        caller could actually use (same dims, same k)."""
        if req.fingerprint is not None:
            plan = self._store.peek(req.fingerprint)
            if plan is not None:
                return plan, False
        if not self.allow_stale:
            return None
        if req.base_plan is not None:
            return req.base_plan, True
        if req.match_fn is not None:
            for fp in reversed(self._store.fingerprints()):  # freshest first
                plan = self._store.peek(fp)
                if plan is not None and req.match_fn(plan):
                    return plan, True
        return None

    def _breaker(self, rep: _Replica, tenant: str) -> CircuitBreaker:
        with self._lock:
            br = rep.breakers.get(tenant)
            if br is None:
                br = rep.breakers[tenant] = CircuitBreaker(
                    failures_to_trip=self.breaker_failures,
                    cooldown_s=self.breaker_cooldown_s,
                    clock=self._clock)
            return br

    def breaker_states(self, tenant: str = "default") -> dict[str, str]:
        """Per-replica breaker state for ``tenant`` ("closed" when the pair
        has never seen pressure)."""
        out = {}
        for rep in self._replicas:
            br = rep.breakers.get(tenant)
            out[rep.rid] = br.state if br is not None else CircuitBreaker.CLOSED
        return out

    def _rejection_pressure(self, req: _GroupRequest) -> Optional[AdmissionRejectedError]:
        """Fail-fast signal: when every healthy replica's breaker for this
        tenant refuses calls, dispatching (or backing off and redispatching)
        is guaranteed wasted work — answer the typed rejection immediately
        with the soonest cooldown as the retry hint."""
        with self._lock:
            healthy = [r for r in self._replicas if self._weight(r) > 0.0]
        if not healthy:
            return None  # health machinery owns this case, not the breaker
        waits = []
        for rep in healthy:
            br = rep.breakers.get(req.tenant)
            if br is None or not br.blocked():
                return None
            waits.append(br.retry_in())
        hint = max(min(waits), 0.001) if waits else 0.001
        if req.last_rejection is not None:
            hint = max(hint, req.last_rejection.retry_after_s)
        return AdmissionRejectedError(
            f"tenant {req.tenant!r} circuit open on every healthy replica; "
            f"retry in {hint:.3g}s", retry_after_s=hint, tenant=req.tenant,
            reason="breaker_open")

    def _open_lane(self, req: _GroupRequest, rep: _Replica, kind: str) -> Optional[_Lane]:
        breaker = self._breaker(rep, req.tenant)
        if not breaker.allow():
            return None
        try:
            ticket = req.submit_fn(rep.svc)
        except AdmissionRejectedError as e:
            # The replica's queue refused this tenant: count the pressure
            # (trips the breaker at breaker_failures consecutive rejections)
            # and remember the hint for the caller's eventual error.
            with self._lock:
                rep.rejections += 1
            breaker.record_failure()
            req.last_rejection = e
            return None
        except BaseException:
            breaker.record_failure()
            return None
        breaker.record_success()
        with self._lock:
            rep.inflight += 1
        return _Lane(rep.rid, ticket, kind, self._clock())

    def _close_lane(self, lane: _Lane) -> None:
        rep = self._by_rid[lane.rid]
        with self._lock:
            rep.inflight = max(0, rep.inflight - 1)

    def _lane_won(self, req: _GroupRequest, lane: _Lane, plan: ServicePlan) -> None:
        rep = self._by_rid[lane.rid]
        dt = self._clock() - lane.t_start
        with self._lock:
            rep.jobs_completed += 1
            rep.latencies.append(dt)
            self._latencies.append(dt)
            self._beat(rep)
            if self._injector is not None and not rep.crashed and \
                    self._injector.crash_due(rep.rid, rep.jobs_completed):
                self.kill(rep.rid)
        self._publish(plan, req.tenant)

    def _drive(self, req: _GroupRequest) -> None:
        try:
            plan, lane, losers, stale = self._run(req)
        except BaseException as e:
            with self._lock:
                self._inflight.pop(req.key, None)
                self._m_failed += req.waiters
            req.ticket._fail(e)
            return
        for loser in losers:
            loser.ticket.cancel()
            self._close_lane(loser)
        if lane is not None:
            self._close_lane(lane)
            self._lane_won(req, lane, plan)
        with self._lock:
            self._inflight.pop(req.key, None)
            self._m_resolved += req.waiters
            if stale:
                self._m_stale += 1
            if lane is not None:
                req.ticket.replica = lane.rid
                req.ticket.cache_hit = lane.ticket.cache_hit
                if lane.kind == "hedge":
                    self._m_hedges_won += 1
                elif req.ticket.hedged:
                    self._m_hedges_lost += 1
        req.ticket.stale = stale
        req.ticket._resolve(plan)

    def _run(self, req: _GroupRequest):
        """The per-request state machine; returns (plan, winning lane,
        loser lanes, stale)."""
        lanes: list[_Lane] = []
        tried: set[str] = set()
        retries = 0
        hedge_deadline: Optional[float] = None
        while True:
            self.pump()
            # Reap finished lanes: first success wins.
            for lane in list(lanes):
                if not lane.ticket.done():
                    continue
                try:
                    plan = lane.ticket.result(0)
                except BaseException:
                    # Job error / drained queue (ServiceClosedError) /
                    # local cancel: this lane is dead, the others race on.
                    lanes.remove(lane)
                    tried.add(lane.rid)
                    self._close_lane(lane)
                else:
                    lanes.remove(lane)
                    return plan, lane, lanes, False
            # End-to-end deadline: checked after reaping so a result that
            # made it under the wire still wins, but no further waiting or
            # retrying happens once the caller's deadline has passed.
            if req.deadline is not None and self._clock() >= req.deadline:
                for lane in lanes:
                    lane.ticket.cancel()
                    self._close_lane(lane)
                raise ReplicaExhaustedError(
                    f"request deadline ({req.timeout_s:g}s) expired after "
                    f"{retries} retries; replicas tried: {sorted(tried)}")
            # Abandon lanes sitting on crashed or suspect replicas.
            for lane in list(lanes):
                rep = self._by_rid[lane.rid]
                if self._weight(rep) > 0.0:
                    continue
                lanes.remove(lane)
                tried.add(lane.rid)
                lane.ticket.cancel()
                self._close_lane(lane)
                with self._lock:
                    rep.failovers_from += 1
                    self._m_failovers += 1
            if not lanes:
                pressure = self._rejection_pressure(req)
                if pressure is not None:
                    # Every healthy replica's breaker refuses this tenant:
                    # fail fast with the typed rejection instead of burning
                    # the retry budget against queues known to be full.
                    raise pressure
                rep = self._pick(exclude=tried)
                if rep is None:
                    # Nobody healthy: degrade to the store, or back off and
                    # wait for a replica to beat its way back.
                    cand = self._stale_candidate(req)
                    if cand is not None:
                        return cand[0], None, [], cand[1]
                    if retries >= self.retry_budget:
                        if req.last_rejection is not None:
                            raise req.last_rejection
                        raise ReplicaExhaustedError(
                            f"no healthy replica after {retries} retries "
                            f"(budget {self.retry_budget}) and nothing cached "
                            "to serve stale")
                    self._sleep(self._clamp_delay(self._backoff(retries), req))
                    retries += 1
                    with self._lock:
                        self._m_retries += 1
                    req.ticket.retries = retries
                    continue
                kind = "primary" if not tried else "failover"
                if kind == "failover":
                    if retries >= self.retry_budget:
                        if req.last_rejection is not None:
                            # Overload, not failure: surface the retryable
                            # rejection with its backoff hint intact.
                            raise req.last_rejection
                        raise ReplicaExhaustedError(
                            f"retry budget ({self.retry_budget}) exhausted; "
                            f"replicas tried: {sorted(tried)}")
                    retries += 1
                    with self._lock:
                        self._m_retries += 1
                    req.ticket.retries = retries
                    self._sleep(self._clamp_delay(self._backoff(retries - 1), req))
                lane = self._open_lane(req, rep, kind)
                if lane is None:
                    tried.add(rep.rid)
                    continue
                lanes.append(lane)
                if hedge_deadline is None:
                    hedge_deadline = self._clock() + self._hedge_delay()
                continue
            # Hedge: one secondary lane once the primary overstays p99 —
            # but never with less than a useful window of deadline left.
            now = self._clock()
            if (self.hedge and len(lanes) == 1 and not req.ticket.hedged
                    and hedge_deadline is not None and now >= hedge_deadline
                    and self._hedge_budget_ok(req, now)):
                rep = self._pick(exclude=tried | {lanes[0].rid})
                if rep is not None and rep.rid != lanes[0].rid:
                    lane = self._open_lane(req, rep, "hedge")
                    if lane is not None:
                        lanes.append(lane)
                        req.ticket.hedged = True
                        with self._lock:
                            self._m_hedges_fired += 1
                            rep.hedges_to += 1
            self._sleep(self.poll_interval_s)

    # -- submission surface (PartitionService-compatible) -------------------

    def _submit_request(self, key, fingerprint, base_plan, submit_fn, match_fn,
                        tenant: str, priority: int,
                        buffer: DoubleBuffer | None,
                        timeout: float | None = None) -> ReplicaTicket:
        self.pump()
        with self._lock:
            if self._closed:
                ticket = ReplicaTicket(tenant=tenant, priority=priority)
                ticket._fail(ServiceClosedError("ReplicaGroup closed"))
                return ticket
            self._m_submitted += 1
            existing = self._inflight.get(key)
            if existing is not None:
                self._m_coalesced += 1
                existing.waiters += 1
                if buffer is not None:
                    existing.ticket._buffers.append(buffer)
                return existing.ticket
            if fingerprint is not None:
                plan = self._store.get(fingerprint, tenant)
                if plan is not None:
                    ticket = ReplicaTicket(tenant=tenant, priority=priority)
                    ticket.cache_hit = True
                    if buffer is not None:
                        ticket._buffers.append(buffer)
                    self._m_resolved += 1
                    ticket._resolve(plan)
                    return ticket
            now = self._clock()
            req = _GroupRequest(key, fingerprint, base_plan, submit_fn,
                                match_fn, tenant, priority, now,
                                deadline=(now + timeout
                                          if timeout is not None else None),
                                timeout_s=timeout)
            if buffer is not None:
                req.ticket._buffers.append(buffer)
            self._inflight[key] = req
            self._driver_seq += 1
            name = f"replica-driver-{self._driver_seq}"
        threading.Thread(target=self._drive, args=(req,), name=name,
                         daemon=True).start()
        return req.ticket

    def submit(
        self,
        edges: EdgeList,
        k: int,
        method: str = "ep",
        opts: MultilevelOptions | None = None,
        seed: int = 0,
        pad: int = 128,
        coo: Optional[tuple] = None,
        buffer: DoubleBuffer | None = None,
        tenant: str = "default",
        priority: int = 0,
        timeout: float | None = None,
    ) -> ReplicaTicket:
        """Async full-partition request; same signature and ticket semantics
        as ``PartitionService.submit``, plus group behavior (store warm
        hits, failover, hedging, stale degradation).  ``timeout`` is an
        *end-to-end* deadline: once it expires the driver stops retrying —
        even with budget left — and fails the ticket with
        :class:`ReplicaExhaustedError` noting the deadline."""
        opts = opts if opts is not None else self._replicas[0].svc.default_opts
        extra = (coo[0], coo[1]) if coo is not None else ()
        fp = graph_fingerprint(edges, k, pad, opts, method, seed, extra)

        def submit_fn(svc: PartitionService) -> PlanTicket:
            return svc.submit(edges, k, method=method, opts=opts, seed=seed,
                              pad=pad, coo=coo, tenant=tenant, priority=priority)

        if coo is not None:
            n_rows, n_cols, rows = coo[0], coo[1], coo[2]
            nnz = len(rows)

            def match_fn(plan: ServicePlan) -> bool:
                return (plan.coo is not None and plan.plan is not None
                        and plan.coo[0] == n_rows and plan.coo[1] == n_cols
                        and len(plan.coo[2]) == nnz
                        and plan.result.k == k)
        else:
            n, m = edges.n, edges.m

            def match_fn(plan: ServicePlan) -> bool:
                return (plan.edges.n == n and plan.edges.m == m
                        and plan.result.k == k)

        return self._submit_request(("full", fp), fp, None, submit_fn,
                                    match_fn, tenant, priority, buffer,
                                    timeout=timeout)

    def get(self, edges: EdgeList, k: int, method: str = "ep",
            opts: MultilevelOptions | None = None, seed: int = 0,
            pad: int = 128, coo: Optional[tuple] = None,
            timeout: float | None = None, tenant: str = "default",
            priority: int = 0) -> ServicePlan:
        return self.submit(edges, k, method=method, opts=opts, seed=seed,
                           pad=pad, coo=coo, tenant=tenant,
                           priority=priority, timeout=timeout).result(timeout)

    def get_spmv_plan(self, n_rows: int, n_cols: int, rows: np.ndarray,
                      cols: np.ndarray, k: int, method: str = "ep",
                      opts: MultilevelOptions | None = None, seed: int = 0,
                      pad: int = 128, timeout: float | None = None,
                      tenant: str = "default", priority: int = 0) -> ServicePlan:
        rows = np.asarray(rows, dtype=np.int64)
        cols = np.asarray(cols, dtype=np.int64)
        edges = affinity_graph_from_coo(n_rows, n_cols, rows, cols)
        return self.get(edges, k, method=method, opts=opts, seed=seed, pad=pad,
                        coo=(n_rows, n_cols, rows, cols), timeout=timeout,
                        tenant=tenant, priority=priority)

    def _base_plan(self, base_fingerprint: str) -> Optional[ServicePlan]:
        plan = self._store.peek(base_fingerprint)
        if plan is not None:
            return plan
        for rep in self._replicas:
            if rep.crashed:
                continue
            plan = rep.svc.plan_cache.peek(base_fingerprint)
            if plan is not None:
                # Pull it into the store so failover targets can seed it.
                self._store.put(plan, tenant=self._store_tenant.get(
                    base_fingerprint, "default"))
                return plan
        return None

    def update_async(
        self,
        base_fingerprint: str,
        k: int,
        insert_u: np.ndarray | None = None,
        insert_v: np.ndarray | None = None,
        delete_ids: np.ndarray | None = None,
        method: str = "ep",
        opts: MultilevelOptions | None = None,
        seed: int = 0,
        pad: int = 128,
        buffer: DoubleBuffer | None = None,
        tenant: str = "default",
        priority: int = 0,
        timeout: float | None = None,
    ) -> ReplicaTicket:
        """Edge-churn update against a cached base plan, group-wide.

        The base plan is located in the shared store or any live replica
        (and seeded into whichever replica ends up computing, including
        failover targets), so an update survives the death of the replica
        that computed its base.  With every replica down, the *base* plan is
        served with ``stale=True`` — the freshest known state of that graph.
        Raises ``KeyError`` when no copy of the base exists anywhere."""
        opts = opts if opts is not None else self._replicas[0].svc.default_opts
        iu = np.asarray(insert_u, dtype=np.int64) if insert_u is not None \
            else np.empty(0, np.int64)
        iv = np.asarray(insert_v, dtype=np.int64) if insert_v is not None \
            else np.empty(0, np.int64)
        dele = (np.unique(np.asarray(delete_ids, dtype=np.int64))
                if delete_ids is not None and len(delete_ids) > 0
                else np.empty(0, np.int64))
        base = self._base_plan(base_fingerprint)
        if base is None:
            raise KeyError(
                f"no cached plan for fingerprint {base_fingerprint!r} in the "
                "shared store or any live replica; resubmit the full graph")
        h = hashlib.blake2b(digest_size=16)
        meta = (base_fingerprint, k, pad, method, seed)
        if opts is not None:
            meta = meta + dataclasses.astuple(opts)
        h.update(repr(meta).encode())
        h.update(iu.tobytes())
        h.update(iv.tobytes())
        h.update(dele.tobytes())
        key = ("update", h.hexdigest())

        def submit_fn(svc: PartitionService) -> PlanTicket:
            if svc.plan_cache.peek(base_fingerprint) is None:
                svc.plan_cache.put(base, tenant=tenant)
            return svc.update_async(
                base_fingerprint, k, insert_u=iu, insert_v=iv, delete_ids=dele,
                method=method, opts=opts, seed=seed, pad=pad, tenant=tenant,
                priority=priority)

        return self._submit_request(key, None, base, submit_fn, None, tenant,
                                    priority, buffer, timeout=timeout)

    def update(self, base_fingerprint: str, k: int, timeout: float | None = None,
               **kwargs) -> ServicePlan:
        return self.update_async(base_fingerprint, k, timeout=timeout,
                                 **kwargs).result(timeout)

    # -- metrics ------------------------------------------------------------

    @property
    def stats(self) -> ServiceStats:
        """Summed ``ServiceStats`` across replicas (facade compatibility)."""
        agg = ServiceStats()
        for rep in self._replicas:
            s = rep.svc.stats
            for f in dataclasses.fields(ServiceStats):
                setattr(agg, f.name, getattr(agg, f.name) + getattr(s, f.name))
        return agg

    @property
    def store(self) -> PlanCache:
        return self._store

    @property
    def default_opts(self) -> MultilevelOptions | None:
        """Replica 0's default options — the group's fingerprinting basis
        (members are identically configured by contract)."""
        return self._replicas[0].svc.default_opts

    def lookup(self, fingerprint: str, tenant: str = "default") -> Optional[ServicePlan]:
        """Cache-only probe: the shared store, then any live replica's
        local cache — no partitioning work, no queueing.  The brownout path
        uses this to answer low-priority tenants from cache alone while the
        group sheds load."""
        plan = self._store.get(fingerprint, tenant)
        if plan is not None:
            return plan
        for rep in self._replicas:
            if rep.crashed:
                continue
            try:
                plan = rep.svc.plan_cache.peek(fingerprint)
            except Exception:
                continue  # unreachable remote: probe the next replica
            if plan is not None:
                return plan
        return None

    @property
    def registry(self) -> HeartbeatRegistry:
        return self._registry

    def replica_ids(self) -> list[str]:
        return [rep.rid for rep in self._replicas]

    def replica_metrics(self) -> ReplicaMetrics:
        """The replication-level snapshot (per-replica health + counters)."""
        with self._lock:
            rows = []
            for rep in self._replicas:
                if rep.crashed:
                    state = "crashed"
                elif rep.rid in self._registry.dead:
                    state = "suspect"
                else:
                    state = "healthy"
                xs = [x * 1e3 for x in rep.latencies]
                rows.append(ReplicaStats(
                    replica=rep.rid,
                    state=state,
                    weight=self._weight(rep),
                    beats=rep.beats,
                    jobs_completed=rep.jobs_completed,
                    failovers_from=rep.failovers_from,
                    hedges_to=rep.hedges_to,
                    p50_ms=_pct(xs, 0.50),
                    p99_ms=_pct(xs, 0.99),
                    rejections=rep.rejections,
                    breakers_open=sum(
                        1 for br in rep.breakers.values()
                        if br.state != CircuitBreaker.CLOSED),
                    breaker_trips=sum(
                        br.trips for br in rep.breakers.values()),
                ))
            return ReplicaMetrics(
                replicas=rows,
                submitted=self._m_submitted,
                resolved=self._m_resolved,
                failed=self._m_failed,
                pending=len(self._inflight),
                coalesced=self._m_coalesced,
                failovers=self._m_failovers,
                retries=self._m_retries,
                hedges_fired=self._m_hedges_fired,
                hedges_won=self._m_hedges_won,
                hedges_lost=self._m_hedges_lost,
                stale_serves=self._m_stale,
                store_entries=len(self._store),
                store_publishes=self._m_publishes,
            )

    def metrics(self) -> ServiceMetrics:
        """Aggregated ``ServiceMetrics`` across replicas — the shape
        ``GraphServer.metrics()`` expects.  Counters sum; utilization
        averages over members; latency summaries are recomputed from the
        group's own completion samples (per-replica summaries don't merge).
        Per-replica detail lives in :meth:`replica_metrics`."""
        snaps = [rep.svc.metrics() for rep in self._replicas]
        with self._lock:
            lat = list(self._latencies)
        tenants: dict[str, dict] = {}
        for snap in snaps:
            for tenant, d in snap.tenants.items():
                agg = tenants.setdefault(tenant, {})
                for k, v in d.items():
                    cur = agg.get(k)
                    if isinstance(v, (int, float)) and isinstance(cur, (int, float)):
                        agg[k] = cur + v
                    elif cur is None:
                        # budget_bytes and friends: None means "no budget";
                        # keep any concrete value a member reports.
                        agg[k] = v
        return ServiceMetrics(
            queue_depth=sum(s.queue_depth for s in snaps),
            workers=sum(s.workers for s in snaps),
            busy_workers=sum(s.busy_workers for s in snaps),
            utilization=sum(s.utilization for s in snaps) / max(len(snaps), 1),
            executor=snaps[0].executor if snaps else "thread",
            jobs_completed=sum(s.jobs_completed for s in snaps),
            jobs_failed=sum(s.jobs_failed for s in snaps),
            cancelled_queued=sum(s.cancelled_queued for s in snaps),
            cancelled_inflight=sum(s.cancelled_inflight for s in snaps),
            coalesced=sum(s.coalesced for s in snaps),
            latency_s=_latency_summary(lat),
            queue_wait_s=_latency_summary([]),
            tenants=tenants,
            queue_depth_max=max((s.queue_depth_max for s in snaps), default=0),
            rejected=sum(s.rejected for s in snaps),
            shed_deadline=sum(s.shed_deadline for s in snaps),
        )

"""Two-level (hierarchical) edge partitioning — beyond-paper (DESIGN.md §3.4).

The TPU memory hierarchy has two cache-like levels the paper's single-level
model can exploit *recursively*:

  level 1  edges → devices      cut cost = inter-chip ICI traffic
  level 2  per-device edges → VMEM tiles   cut cost = per-chip HBM traffic

The objective function is identical at both levels (Definition 2); only the
"cache domain" changes.  Because vertex-cut is sub-additive under refinement,
solving level 1 first and then level 2 *within* each device is never worse
for ICI traffic than a flat k_outer·k_inner partition, and it is empirically
better for the combined cost because the outer partitioner spends its entire
budget on the expensive (slow-link) level.

``hierarchical_edge_partition`` returns labels at both levels plus the flat
composite label, and the per-level cut costs so benchmarks can compare
against the flat single-level schedule.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from .edge_partition import EdgePartitionResult, edge_partition
from .graph import EdgeList
from .metrics import edge_balance_factor, vertex_cut_cost

__all__ = ["HierarchicalPartition", "hierarchical_edge_partition"]


@dataclasses.dataclass(frozen=True)
class HierarchicalPartition:
    k_outer: int
    k_inner: int
    outer_labels: np.ndarray  # (m,) device id per task
    inner_labels: np.ndarray  # (m,) LOCAL tile id per task (within its device)
    flat_labels: np.ndarray   # (m,) device * k_inner + tile
    outer_cut: int            # ICI-traffic objective (redundant inter-device loads)
    inner_cut: int            # HBM-traffic objective, summed over devices
    flat_cut: int             # vertex-cut of the composite k_outer*k_inner partition
    outer_balance: float
    flat_balance: float

    @property
    def total_k(self) -> int:
        return self.k_outer * self.k_inner


def hierarchical_edge_partition(
    edges: EdgeList,
    k_outer: int,
    k_inner: int,
    method: str = "ep",
    seed: int = 0,
) -> HierarchicalPartition:
    """Partition tasks devices-first, then VMEM-tiles within each device."""
    outer: EdgePartitionResult = edge_partition(edges, k_outer, method=method, seed=seed)
    outer_labels = outer.labels

    inner_labels = np.zeros(edges.m, dtype=np.int32)
    inner_cut = 0
    for d in range(k_outer):
        mask = outer_labels == d
        if not mask.any():
            continue
        # Re-index the device's sub-problem to its local vertex universe so
        # the inner partitioner sees only data the device actually touches.
        sub_u = edges.u[mask]
        sub_v = edges.v[mask]
        verts = np.unique(np.concatenate([sub_u, sub_v]))
        remap = np.empty(edges.n, dtype=np.int64)
        remap[verts] = np.arange(verts.shape[0])
        sub = EdgeList(n=verts.shape[0], u=remap[sub_u], v=remap[sub_v])
        res = edge_partition(sub, k_inner, method=method, seed=seed + 1 + d)
        inner_labels[mask] = res.labels
        inner_cut += res.vertex_cut

    flat_labels = (outer_labels.astype(np.int64) * k_inner + inner_labels).astype(np.int32)
    k_flat = k_outer * k_inner
    return HierarchicalPartition(
        k_outer=k_outer,
        k_inner=k_inner,
        outer_labels=outer_labels,
        inner_labels=inner_labels,
        flat_labels=flat_labels,
        outer_cut=outer.vertex_cut,
        inner_cut=inner_cut,
        flat_cut=vertex_cut_cost(edges, flat_labels, k_flat),
        outer_balance=outer.quality.balance,
        flat_balance=edge_balance_factor(flat_labels, k_flat),
    )

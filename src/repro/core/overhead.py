"""Adaptive overhead control (paper §4.2).

The partitioner runs on a separate host (CPU) thread while the accelerator
executes the unoptimized kernel; once the optimized schedule is ready, the
program switches over.  The first optimized invocation is timed against the
rolling baseline average, and if it is slower the scheduler *falls back*
permanently — guaranteeing no slowdown (paper Figure 14 shows gains or
parity everywhere thanks to this control).
"""
from __future__ import annotations

import threading
import time
from typing import Any, Callable, Optional

__all__ = ["AdaptiveScheduler"]


class AdaptiveScheduler:
    """Asynchronous optimize-then-switch execution controller.

    Parameters
    ----------
    baseline_fn:
        The unoptimized step, called as ``baseline_fn(*args, **kw)``.
    optimize_fn:
        Host-side optimization job (e.g. edge partitioning + pack-plan
        construction).  Runs once on a background thread; its return value
        is handed to ``build_optimized_fn``.
    build_optimized_fn:
        ``plan -> step_fn``; e.g. closes a Pallas kernel over the pack plan.
    min_baseline_samples:
        Baseline timings to collect before an optimized run may be judged.
    """

    def __init__(
        self,
        baseline_fn: Callable[..., Any],
        optimize_fn: Callable[[], Any],
        build_optimized_fn: Callable[[Any], Callable[..., Any]],
        min_baseline_samples: int = 2,
    ):
        self._baseline_fn = baseline_fn
        self._build = build_optimized_fn
        self._min_samples = min_baseline_samples
        self._plan: Any = None
        self._optimized_fn: Optional[Callable[..., Any]] = None
        self._error: Optional[BaseException] = None
        self.state = "baseline"  # baseline -> optimized | fallback
        self.baseline_times: list[float] = []
        self.optimized_times: list[float] = []
        self.calls = 0
        self.optimized_calls = 0

        def _job():
            try:
                self._plan = optimize_fn()
            except BaseException as e:  # surfaced on next step
                self._error = e

        self._thread = threading.Thread(target=_job, daemon=True)
        self._t_opt_start = time.perf_counter()
        self._thread.start()
        self.optimize_time_s: Optional[float] = None

    # -- public ----------------------------------------------------------

    def ready(self) -> bool:
        return self._plan is not None and not self._thread.is_alive()

    def wait(self, timeout: Optional[float] = None) -> bool:
        self._thread.join(timeout)
        return self.ready()

    @property
    def plan(self) -> Any:
        return self._plan

    def __call__(self, *args, **kw):
        self.calls += 1
        if self._error is not None:
            err, self._error = self._error, None
            self.state = "fallback"
            raise err
        if self.state == "baseline" and self.ready():
            if self.optimize_time_s is None:
                self.optimize_time_s = time.perf_counter() - self._t_opt_start
            self._optimized_fn = self._build(self._plan)
            self.state = "optimized"
        if self.state == "optimized":
            t0 = time.perf_counter()
            out = self._optimized_fn(*args, **kw)
            dt = time.perf_counter() - t0
            self.optimized_times.append(dt)
            self.optimized_calls += 1
            # Judge the FIRST optimized run against the baseline average
            # (skipping it would hide a permanently-slower kernel).
            if (
                self.optimized_calls == 2  # first timed run after warmup/compile
                and len(self.baseline_times) >= self._min_samples
            ):
                base_avg = sum(self.baseline_times) / len(self.baseline_times)
                if dt > base_avg:
                    self.state = "fallback"
            return out
        t0 = time.perf_counter()
        out = self._baseline_fn(*args, **kw)
        self.baseline_times.append(time.perf_counter() - t0)
        return out

    def summary(self) -> dict:
        return {
            "state": self.state,
            "calls": self.calls,
            "optimized_calls": self.optimized_calls,
            "optimize_time_s": self.optimize_time_s,
            "baseline_avg_s": (
                sum(self.baseline_times) / len(self.baseline_times)
                if self.baseline_times
                else None
            ),
            "optimized_avg_s": (
                sum(self.optimized_times) / len(self.optimized_times)
                if self.optimized_times
                else None
            ),
        }

"""AdamW from scratch (no optax offline), ZeRO-friendly.

Optimizer state mirrors the parameter pytree (m, v) so GSPMD shards it with
the same specs as the params — with FSDP rules this is ZeRO-3: params,
grads, and both moments all sharded over ('data', 'model').

``state_dtype`` lets memory-critical configs (jamba 398B) hold the moments
in bf16: 398e9 × (2 param + 2 m + 2 v) / 256 chips = 9.3 GB/chip, vs 18.7GB
at fp32 moments (does not fit v5e).  Global-norm clipping runs in fp32.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

__all__ = ["AdamWConfig", "adamw_init", "adamw_update", "global_norm"]


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: Callable[[jax.Array], jax.Array] | float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: Optional[float] = 1.0
    state_dtype: Optional[str] = None  # None = param dtype; 'bfloat16'|'float32'

    def lr_at(self, step: jax.Array) -> jax.Array:
        if callable(self.lr):
            return jnp.asarray(self.lr(step), jnp.float32)
        return jnp.asarray(self.lr, jnp.float32)


def _state_dtype(cfg: AdamWConfig, p: jax.Array):
    if cfg.state_dtype is None:
        return p.dtype
    return jnp.bfloat16 if cfg.state_dtype == "bfloat16" else jnp.float32


def adamw_init(cfg: AdamWConfig, params: Any) -> dict:
    zeros = lambda p: jnp.zeros(p.shape, _state_dtype(cfg, p))
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "count": jnp.zeros((), jnp.int32),
    }


def global_norm(tree: Any) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in leaves)
    )


def adamw_update(
    cfg: AdamWConfig, grads: Any, state: dict, params: Any
) -> tuple[Any, dict, dict]:
    """Returns (new_params, new_state, stats)."""
    count = state["count"] + 1
    lr = cfg.lr_at(count)
    gnorm = global_norm(grads)
    if cfg.clip_norm is not None:
        scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))
    else:
        scale = jnp.ones((), jnp.float32)
    bc1 = 1.0 - cfg.b1 ** count.astype(jnp.float32)
    bc2 = 1.0 - cfg.b2 ** count.astype(jnp.float32)

    def upd(p, g, m, v):
        gf = g.astype(jnp.float32) * scale
        mf = cfg.b1 * m.astype(jnp.float32) + (1 - cfg.b1) * gf
        vf = cfg.b2 * v.astype(jnp.float32) + (1 - cfg.b2) * gf * gf
        mh = mf / bc1
        vh = vf / bc2
        step = mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
        new_p = p.astype(jnp.float32) - lr * step
        return new_p.astype(p.dtype), mf.astype(m.dtype), vf.astype(v.dtype)

    out = jax.tree.map(upd, params, grads, state["m"], state["v"])
    # Unzip the 3-tuples.
    new_params = jax.tree.map(lambda t: t[0], out, is_leaf=lambda t: isinstance(t, tuple))
    new_m = jax.tree.map(lambda t: t[1], out, is_leaf=lambda t: isinstance(t, tuple))
    new_v = jax.tree.map(lambda t: t[2], out, is_leaf=lambda t: isinstance(t, tuple))
    new_state = {"m": new_m, "v": new_v, "count": count}
    return new_params, new_state, {"grad_norm": gnorm, "lr": lr}

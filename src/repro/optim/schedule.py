"""Learning-rate schedules (warmup + cosine, the large-model default)."""
from __future__ import annotations

import jax.numpy as jnp

__all__ = ["warmup_cosine", "constant"]


def warmup_cosine(peak: float, warmup_steps: int, total_steps: int, floor: float = 0.1):
    """Linear warmup to ``peak`` then cosine decay to ``floor * peak``."""

    def sched(step):
        step = jnp.asarray(step, jnp.float32)
        warm = peak * step / jnp.maximum(warmup_steps, 1)
        frac = (step - warmup_steps) / jnp.maximum(total_steps - warmup_steps, 1)
        frac = jnp.clip(frac, 0.0, 1.0)
        cos = floor * peak + (1 - floor) * peak * 0.5 * (1 + jnp.cos(jnp.pi * frac))
        return jnp.where(step < warmup_steps, warm, cos)

    return sched


def constant(value: float):
    return lambda step: jnp.full((), value, jnp.float32)

"""Optimizer substrate: AdamW (ZeRO-sharded), schedules, grad compression."""
from .adamw import AdamWConfig, adamw_init, adamw_update, global_norm
from .compress import compress_grads, compressed_psum, dequantize_int8, quantize_int8
from .schedule import constant, warmup_cosine

__all__ = [
    "AdamWConfig",
    "adamw_init",
    "adamw_update",
    "compress_grads",
    "compressed_psum",
    "constant",
    "dequantize_int8",
    "global_norm",
    "quantize_int8",
    "warmup_cosine",
]

"""Gradient compression for the slow inter-pod links (beyond-paper).

int8 quantization with per-tensor scales and *error feedback* (the residual
of each quantization is carried to the next step, so compression error does
not accumulate into the optimizer trajectory — Seide et al. 2014 / Karimireddy
et al. 2019 semantics).

Use: the cross-pod gradient all-reduce is the one collective on the slow
links (DESIGN.md §6).  Quantizing it 4x (bf16 -> int8 payload, fp32 scale
per tensor) cuts the multi-pod collective roofline term of train steps by
the same factor; the EXPERIMENTS.md §Perf log measures this on the jamba
train cell.  ``compressed_psum`` is written for ``shard_map`` manual
collectives over the 'pod' axis; the quantize/dequantize pair is also
usable standalone (tested against exactness bounds + error feedback).
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

__all__ = ["quantize_int8", "dequantize_int8", "compress_grads", "compressed_psum"]


def quantize_int8(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Symmetric per-tensor int8 quantization; returns (q, scale)."""
    xf = x.astype(jnp.float32)
    amax = jnp.max(jnp.abs(xf))
    scale = jnp.maximum(amax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array, dtype=jnp.float32) -> jax.Array:
    return (q.astype(jnp.float32) * scale).astype(dtype)


def compress_grads(grads: Any, error_buf: Any) -> tuple[Any, Any]:
    """Quantize grads+error with feedback; returns (dequantized, new_error).

    new_error = (g + e) - dequant(quant(g + e)); applying the returned
    dequantized gradients plus carrying new_error is equivalent to an
    unbiased-in-the-limit compressed update.
    """

    def one(g, e):
        target = g.astype(jnp.float32) + e
        q, s = quantize_int8(target)
        deq = dequantize_int8(q, s)
        return deq.astype(g.dtype), target - deq

    out = jax.tree.map(one, grads, error_buf)
    deq = jax.tree.map(lambda t: t[0], out, is_leaf=lambda t: isinstance(t, tuple))
    err = jax.tree.map(lambda t: t[1], out, is_leaf=lambda t: isinstance(t, tuple))
    return deq, err


def compressed_psum(x: jax.Array, axis_name: str) -> jax.Array:
    """int8-payload all-reduce for shard_map bodies (e.g. over 'pod').

    Quantizes locally, sums the int8 payloads in int32 (no overflow for
    <= 2^23 participants), and rescales by the max of the per-shard scales
    (all shards must agree on one scale: we psum-max it first — that max is
    a scalar, negligible traffic).  Payload on the slow link: 1 byte/grad
    element + 8 bytes of scalars, vs 2 (bf16) or 4 (fp32).
    """
    xf = x.astype(jnp.float32)
    amax_local = jnp.max(jnp.abs(xf))
    amax = jax.lax.pmax(amax_local, axis_name)
    scale = jnp.maximum(amax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
    total = jax.lax.psum(q.astype(jnp.int32), axis_name)
    return (total.astype(jnp.float32) * scale).astype(x.dtype)

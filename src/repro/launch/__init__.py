"""Launchers: production mesh, multi-pod dry-run, train/serve drivers.

NOTE: ``repro.launch.dryrun`` sets xla_force_host_platform_device_count=512
at import (before jax init) — import it only in dry-run processes.
"""
from .mesh import make_host_mesh, make_production_mesh

__all__ = ["make_host_mesh", "make_production_mesh"]

"""Loop-aware static cost analysis of compiled (SPMD-partitioned) HLO.

WHY: ``compiled.cost_analysis()`` visits every computation ONCE — a scanned
transformer (layers x microbatches x kv-chunks as nested `while` loops) is
undercounted by orders of magnitude (measured: granite train_4k reported
156x fewer FLOPs than 6·N·D, i.e. an MFU "of 7.0").  XLA however annotates
every while with ``backend_config={"known_trip_count":{"n":...}}``; this
module rebuilds the call graph (while/fusion/call/conditional/to_apply),
propagates trip-count multipliers from ENTRY, and accumulates:

  * FLOPs        — 2·prod(result)·prod(contracting) per dot (matmuls are
                   >99% of model FLOPs; elementwise ignored like 6·N·D does);
  * HBM bytes    — a fusion-boundary traffic model: each executed kernel-ish
                   op (fusion, dot, copy, reduce, collectives, (dynamic-)
                   slice/update-slice, gather/scatter, ...) reads its
                   operands and writes its result once.  DUS is special-
                   cased (in-place slice write, not a full-buffer rewrite).
  * collective bytes — the hlo.py per-op link-traffic model x multipliers.

All shapes in the SPMD module are PER-DEVICE, so totals are per-chip.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Optional

import numpy as np

from .hlo import _DTYPE_BYTES, _GROUPS_ARR_RE, _GROUPS_RE, _SHAPE_RE

__all__ = ["HloCostModel", "analyze_module"]

_COMP_HEAD_RE = re.compile(r"^(ENTRY\s+)?%?([\w\.\-]+)\s*(\([^)]*\))?.*\{")
_OP_RE = re.compile(r"^\s+(?:ROOT\s+)?%([\w\.\-]+)\s*=\s*(.*)$")
_OPCODE_RE = re.compile(r"([a-z][a-z0-9\-]*)\(")
_OPERAND_RE = re.compile(r"%([\w\.\-]+)")
_PARAM_RE = re.compile(r"%?([\w\.\-]+)\s*:\s*([a-z0-9]+\[[0-9,]*\])")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_CALLS_RE = re.compile(r"(?:calls|to_apply|body|condition)=%?([\w\.\-]+)")
_BRANCHES_RE = re.compile(r"branch_computations=\{([^}]*)\}")

# Ops whose operands/results move through HBM on a TPU-style backend.
# Standalone elementwise (add/mul/select/convert/broadcast/...) is NOT
# counted: TPU XLA fuses elementwise chains into their producers/consumers,
# so charging each CPU-HLO standalone op would bill the same tensor many
# times (measured 4x overcount on granite train_4k).  Bookkeeping
# (bitcast/tuple/get-tuple-element/parameter/constant) is free.
_MEM_OPS = {
    "fusion", "dot", "copy", "reduce", "transpose",
    "concatenate", "pad", "reduce-window", "scatter", "gather",
    "slice", "dynamic-slice", "dynamic-update-slice", "sort",
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute",
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")


def _shape_elems_bytes(shape_str: str) -> tuple[int, int, list[int]]:
    m = _SHAPE_RE.match(shape_str)
    if not m:
        return 0, 0, []
    dt, dims = m.group(1), m.group(2)
    b = _DTYPE_BYTES.get(dt, 4)
    dd = [int(x) for x in dims.split(",") if x] if dims else []
    n = int(np.prod(dd)) if dd else 1
    return n, n * b, dd


@dataclasses.dataclass
class _Op:
    name: str
    opcode: str
    result_shapes: list[str]      # shape strings of the result (tuple-flattened)
    operands: list[str]
    line: str
    is_root: bool = False


@dataclasses.dataclass
class _Computation:
    name: str
    ops: list[_Op]
    param_shapes: dict            # name -> shape string
    param_order: list = dataclasses.field(default_factory=list)  # [(name, shape)]


def _parse_module(text: str) -> tuple[dict, Optional[str]]:
    comps: dict[str, _Computation] = {}
    entry = None
    cur: Optional[_Computation] = None
    for raw in text.splitlines():
        if cur is None:
            m = _COMP_HEAD_RE.match(raw)
            if m and "{" in raw:
                name = m.group(2)
                order = _PARAM_RE.findall(m.group(3) or "")
                cur = _Computation(
                    name=name, ops=[], param_shapes=dict(order), param_order=order
                )
                if m.group(1):
                    entry = name
            continue
        if raw.startswith("}"):
            comps[cur.name] = cur
            cur = None
            continue
        m = _OP_RE.match(raw)
        if not m:
            continue
        name, rhs = m.group(1), m.group(2)
        is_root = raw.lstrip().startswith("ROOT")
        oc = _OPCODE_RE.search(rhs)
        opcode = oc.group(1) if oc else ""
        # Result shapes: shape literals before the opcode occurrence.
        cut = rhs.find(f" {opcode}(") if opcode else -1
        region = rhs[: cut if cut > 0 else None]
        shapes = [s.group(0) for s in _SHAPE_RE.finditer(region)]
        # Operands: inside the first (...) after opcode.
        operands = []
        if oc:
            depth = 0
            start = rhs.find("(", oc.start())
            end = start
            for i in range(start, len(rhs)):
                if rhs[i] == "(":
                    depth += 1
                elif rhs[i] == ")":
                    depth -= 1
                    if depth == 0:
                        end = i
                        break
            operands = _OPERAND_RE.findall(rhs[start:end])
        cur.ops.append(_Op(name, opcode, shapes, operands, rhs, is_root))
    return comps, entry


def _multipliers(comps: dict, entry: str) -> tuple[dict, list[str]]:
    """Execution-count multiplier per computation from the call graph."""
    mult = {name: 0.0 for name in comps}
    if entry not in comps:
        return {name: 1.0 for name in comps}, ["entry not found"]
    mult[entry] = 1.0
    warnings: list[str] = []
    # Edges: (caller, callee, factor)
    edges: dict[str, list[tuple[str, float]]] = {name: [] for name in comps}
    for comp in comps.values():
        for op in comp.ops:
            if op.opcode == "while":
                trip = 1.0
                tm = _TRIP_RE.search(op.line)
                if tm:
                    trip = float(tm.group(1))
                else:
                    warnings.append(f"no trip count for {op.name} in {comp.name}")
                for callee in _CALLS_RE.findall(op.line):
                    if callee in comps:
                        edges[comp.name].append((callee, trip))
            else:
                for callee in _CALLS_RE.findall(op.line):
                    if callee in comps:
                        edges[comp.name].append((callee, 1.0))
                bm = _BRANCHES_RE.search(op.line)
                if bm:
                    for b in _OPERAND_RE.findall(bm.group(1)):
                        if b in comps:
                            edges[comp.name].append((b, 1.0))
    # Fixed-point propagation (the call graph is a DAG, so this converges
    # in <= depth iterations; the cap guards malformed input).
    for _ in range(1000):
        new = {name: (1.0 if name == entry else 0.0) for name in comps}
        for caller, outs in edges.items():
            for callee, factor in outs:
                new[callee] += mult[caller] * factor
        new[entry] = 1.0
        if all(abs(new[k] - mult[k]) <= 1e-9 * max(1.0, mult[k]) for k in comps):
            break
        mult = new
    return mult, warnings


def _dot_flops(op: _Op, comp: _Computation, symbols: dict) -> float:
    if not op.result_shapes:
        return 0.0
    out_n, _, _ = _shape_elems_bytes(op.result_shapes[0])
    # Contracting sizes from lhs shape + lhs_contracting_dims.
    m = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", op.line)
    if not m or not op.operands:
        return 2.0 * out_n  # degenerate
    lhs_shape = symbols.get(op.operands[0])
    if lhs_shape is None:
        return 2.0 * out_n
    _, _, dims = _shape_elems_bytes(lhs_shape)
    k = 1
    for d in (int(x) for x in m.group(1).split(",") if x):
        if d < len(dims):
            k *= dims[d]
    return 2.0 * out_n * k


@dataclasses.dataclass
class HloCostModel:
    flops: float
    hbm_bytes: float
    collective_bytes: float          # per-chip link traffic (ring model)
    collective_op_bytes: dict
    collective_op_counts: dict
    dot_flops_unrolled: float        # without loop multipliers (sanity)
    warnings: list

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)


def _fusion_bytes(op: _Op, callee: _Computation) -> float:
    """HBM traffic of one fusion launch — slice-aware at both boundaries.

    A fusion parameter consumed ONLY via dynamic-slice/gather reads just the
    slices, not the buffer (the scan-over-layers pattern: the stacked
    (L, ...) weights/activations buffer is indexed one layer per iteration —
    charging the whole buffer per iteration overcounted the granite cell by
    ~10x).  A root dynamic-update-slice writes just the updated slice (the
    output buffer is aliased through the loop).
    """
    # CPU bf16-emulation normalization: the CPU backend upcasts bf16 ops to
    # f32, wrapping slice/update-slice fusions in whole-buffer converts
    # (observed: convert(dus(convert(buf), convert(upd))) — charges the full
    # 1.3 GB buffer per layer step where a TPU does a native in-place bf16
    # DUS).  If the body reduces to a single (dynamic-)(update-)slice after
    # dropping parameter/constant/convert/bitcast/broadcast ops, charge the
    # slice semantics, not the convert wrappers.
    core = [
        bop for bop in callee.ops
        if bop.opcode not in ("parameter", "constant", "convert", "bitcast", "broadcast", "copy")
    ]
    body_syms = dict(callee.param_shapes)
    for bop in callee.ops:
        if bop.result_shapes:
            body_syms[bop.name] = bop.result_shapes[0]
    if len(core) == 1 and core[0].opcode == "dynamic-update-slice":
        upd = body_syms.get(core[0].operands[1]) if len(core[0].operands) > 1 else None
        return 2.0 * (_shape_elems_bytes(upd)[1] if upd else 0)
    if len(core) == 1 and core[0].opcode in ("dynamic-slice", "slice", "gather"):
        out_b = sum(_shape_elems_bytes(s)[1] for s in core[0].result_shapes)
        return 2.0 * out_b

    total = 0.0
    # --- inputs ---
    consumers: dict[str, list[_Op]] = {}
    for bop in callee.ops:
        for o in bop.operands:
            consumers.setdefault(o, []).append(bop)
    for i, (pname, pshape) in enumerate(callee.param_order):
        cons = consumers.get(pname, [])
        if cons and all(c.opcode in ("dynamic-slice", "gather") for c in cons):
            total += sum(
                sum(_shape_elems_bytes(s)[1] for s in c.result_shapes) for c in cons
            )
        else:
            total += _shape_elems_bytes(pshape)[1]
    # --- output ---
    body_symbols = dict(callee.param_shapes)
    for bop in callee.ops:
        if bop.result_shapes:
            body_symbols[bop.name] = bop.result_shapes[0]
    roots = [bop for bop in callee.ops if bop.is_root]
    root_dus = []
    if roots:
        r = roots[0]
        if r.opcode == "dynamic-update-slice":
            root_dus = [r]
        elif r.opcode == "tuple":
            root_dus = [
                bop for bop in callee.ops
                if bop.name in r.operands and bop.opcode == "dynamic-update-slice"
            ]
            if len(root_dus) != len(r.operands):
                root_dus = []
    if root_dus:
        for r in root_dus:
            upd = body_symbols.get(r.operands[1]) if len(r.operands) > 1 else None
            total += _shape_elems_bytes(upd)[1] if upd else 0
    else:
        total += sum(_shape_elems_bytes(s)[1] for s in op.result_shapes)
    return total


def analyze_module(text: str, total_devices: int) -> HloCostModel:
    comps, entry = _parse_module(text)
    mult, warnings = _multipliers(comps, entry or "")

    # Fusion bodies are accounted at their caller's boundary, never inline.
    fusion_bodies: set[str] = set()
    for comp in comps.values():
        for op in comp.ops:
            if op.opcode == "fusion":
                for callee in _CALLS_RE.findall(op.line):
                    fusion_bodies.add(callee)

    flops = 0.0
    flops_once = 0.0
    hbm = 0.0
    coll_bytes = 0.0
    coll_op_bytes: dict[str, float] = {}
    coll_op_counts: dict[str, float] = {}

    for comp in comps.values():
        m = mult.get(comp.name, 0.0)
        if m <= 0:
            continue
        in_fusion = comp.name in fusion_bodies
        # Symbol table: result shape per op + params.
        symbols = dict(comp.param_shapes)
        for op in comp.ops:
            if op.result_shapes:
                symbols[op.name] = op.result_shapes[0]
        for op in comp.ops:
            if op.opcode == "dot":
                f = _dot_flops(op, comp, symbols)
                flops += m * f
                flops_once += f
            if op.opcode in _MEM_OPS and not in_fusion:
                out_b = sum(_shape_elems_bytes(s)[1] for s in op.result_shapes)
                if op.opcode == "fusion":
                    callees = _CALLS_RE.findall(op.line)
                    if callees and callees[0] in comps:
                        hbm += m * _fusion_bytes(op, comps[callees[0]])
                    else:
                        hbm += m * out_b
                elif op.opcode == "dynamic-update-slice":
                    # In-place slice write: read+write the update, not the buffer.
                    upd = symbols.get(op.operands[1]) if len(op.operands) > 1 else None
                    ub = _shape_elems_bytes(upd)[1] if upd else 0
                    hbm += m * (2.0 * ub)
                elif op.opcode in ("dynamic-slice", "slice", "gather"):
                    hbm += m * (2.0 * out_b)  # read slice + write result
                else:
                    in_b = sum(
                        _shape_elems_bytes(symbols.get(o, ""))[1] for o in op.operands
                    )
                    hbm += m * (in_b + out_b)
            if op.opcode in _COLLECTIVES or any(
                f" {c}-start(" in op.line for c in _COLLECTIVES
            ):
                opname = op.opcode if op.opcode in _COLLECTIVES else next(
                    c for c in _COLLECTIVES if f" {c}-start(" in op.line
                )
                size = sum(_shape_elems_bytes(s)[1] for s in op.result_shapes)
                # CPU bf16-emulation normalization: the CPU backend upcasts
                # bf16 dots to f32, so their TP all-reduce runs on the f32
                # form and converts straight back (convert producer and/or
                # consumer).  A TPU reduces native bf16 — count that.
                if size and _bf16_emulated(op, comp, symbols):
                    size *= 0.5
                n = _group_size_line(op.line, total_devices)
                if n <= 1:
                    continue
                if opname == "all-reduce":
                    traffic = 2.0 * size * (n - 1) / n
                elif opname == "all-gather":
                    traffic = size * (n - 1) / n
                elif opname == "reduce-scatter":
                    traffic = size * (n - 1)
                elif opname == "all-to-all":
                    traffic = size * (n - 1) / n
                else:
                    traffic = float(size)
                coll_bytes += m * traffic
                coll_op_bytes[opname] = coll_op_bytes.get(opname, 0.0) + m * traffic
                coll_op_counts[opname] = coll_op_counts.get(opname, 0.0) + m

    return HloCostModel(
        flops=flops,
        hbm_bytes=hbm,
        collective_bytes=coll_bytes,
        collective_op_bytes=coll_op_bytes,
        collective_op_counts=coll_op_counts,
        dot_flops_unrolled=flops_once,
        warnings=warnings,
    )


def _bf16_emulated(op: _Op, comp: _Computation, symbols: dict) -> bool:
    """True if this f32 collective is a bf16 value in f32-emulation clothing:
    its operand converts up from a 2-byte dtype, or a consumer converts the
    result back down.  Conservative: requires an explicit convert adjacency.
    """
    if not op.result_shapes:
        return False
    m = _SHAPE_RE.match(op.result_shapes[0])
    if not m or _DTYPE_BYTES.get(m.group(1), 4) != 4:
        return False
    # Producer side: operand defined by a convert from a 2-byte dtype.
    producer_names = set(op.operands)
    for bop in comp.ops:
        if bop.name in producer_names and bop.opcode == "convert" and bop.operands:
            src = symbols.get(bop.operands[0], "")
            sm = _SHAPE_RE.match(src)
            if sm and _DTYPE_BYTES.get(sm.group(1), 4) == 2:
                return True
    # Consumer side: some op converts this result down to 2 bytes.
    for bop in comp.ops:
        if op.name in bop.operands and bop.opcode == "convert" and bop.result_shapes:
            rm = _SHAPE_RE.match(bop.result_shapes[0])
            if rm and _DTYPE_BYTES.get(rm.group(1), 4) == 2:
                return True
    return False


def _group_size_line(line: str, total_devices: int) -> int:
    m = _GROUPS_ARR_RE.search(line)
    if m:
        return int(m.group(2))
    m = _GROUPS_RE.search(line)
    if m:
        first = m.group(1).split("}")[0].strip("{ ")
        n = len([x for x in first.split(",") if x.strip() != ""])
        return max(n, 1)
    return total_devices

"""Production mesh construction.

``make_production_mesh`` is a FUNCTION (not a module constant) so importing
this module never touches jax device state — the dry-run sets
``xla_force_host_platform_device_count=512`` before first jax init, and
tests/benches must keep seeing 1 device.

Axes: 16x16 = 256 chips/pod ('data', 'model'); multi-pod adds a leading
'pod' axis (2x16x16 = 512).  'pod' carries pure data parallelism: exactly
one gradient all-reduce per train step crosses the slow inter-pod links
(DESIGN.md §6).  The same function generalizes past 2 pods — the axes are
what matter, not the constant.
"""
from __future__ import annotations

import numpy as np

import jax
from jax.sharding import Mesh

__all__ = ["make_production_mesh", "make_host_mesh"]


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = int(np.prod(shape))
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(
            f"need {n} devices for the production mesh, have {len(devices)}; "
            "the dry-run must set xla_force_host_platform_device_count=512 "
            "before any jax import"
        )
    # jax.make_mesh requires len(devices) == prod(shape); slice explicitly so
    # the single-pod mesh also works in a 512-device process.
    return Mesh(np.asarray(devices[:n]).reshape(shape), axes)


def make_host_mesh() -> Mesh:
    """1x1 mesh over the real local device (tests / CPU examples)."""
    return Mesh(np.asarray(jax.devices()[:1]).reshape(1, 1), ("data", "model"))

"""HLO-text analysis: collective traffic + roofline terms.

``cost_analysis()`` gives HLO_FLOPs and HLO_bytes but NOT collective bytes;
those are extracted here by parsing the SPMD-partitioned module text
(``compiled.as_text()``), where every shape is a PER-DEVICE shape.  Per-op
link-traffic model (ring algorithms, N = replica-group size):

    all-reduce         2·S·(N−1)/N      (reduce-scatter + all-gather phases)
    all-gather         S·(N−1)/N        (S = output bytes, already gathered)
    reduce-scatter     S·(N−1)          (S = output bytes; input = N·S)
    all-to-all         S·(N−1)/N
    collective-permute S                (point-to-point)

Roofline terms (task spec; v5e constants):
    compute    = HLO_FLOPs / (chips · 197e12 FLOP/s)
    memory     = HLO_bytes / (chips · 819e9 B/s)
    collective = per-chip collective bytes / 50e9 B/s
                 (algebraically equal to total/(chips·link_bw) since SPMD
                  shapes are per-device)
"""
from __future__ import annotations

import dataclasses
import re

import numpy as np

__all__ = [
    "HW",
    "CollectiveStats",
    "parse_collectives",
    "RooflineTerms",
    "roofline_terms",
    "shape_bytes",
]


@dataclasses.dataclass(frozen=True)
class HW:
    """TPU v5e-class hardware constants (task spec)."""

    peak_flops: float = 197e12       # bf16 FLOP/s per chip
    hbm_bw: float = 819e9            # B/s per chip
    link_bw: float = 50e9            # B/s per ICI link
    hbm_bytes: float = 16e9


_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "s32": 4, "u32": 4,
    "s64": 8, "u64": 8, "f8e4m3fn": 1, "f8e5m2": 1, "bf16": 2, "f16": 2,
    "f32": 4, "f64": 8, "c64": 8, "c128": 16, "token": 0, "s4": 1, "u4": 1,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=\{(.*?)\}")
_GROUPS_ARR_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_COLLECTIVES = (
    "all-reduce",
    "all-gather",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)


def shape_bytes(dtype: str, dims: str) -> int:
    b = _DTYPE_BYTES.get(dtype)
    if b is None:
        return 0
    if not dims:
        return b
    return b * int(np.prod([int(d) for d in dims.split(",") if d]))


def _result_bytes(line: str, op: str) -> int:
    """Sum the shape literals in the result type (LHS of the op name)."""
    eq = line.find(" = ")
    if eq < 0:
        return 0
    opi = line.find(op, eq)
    region = line[eq + 3 : opi if opi > 0 else None]
    return sum(shape_bytes(d, s) for d, s in _SHAPE_RE.findall(region))


def _group_size(line: str, total_devices: int) -> int:
    m = _GROUPS_ARR_RE.search(line)
    if m:  # replica_groups=[G,N] iota form: G groups of N
        return int(m.group(2))
    m = _GROUPS_RE.search(line)
    if m:
        first = m.group(1).split("}")[0].strip("{ ")
        n = len([x for x in first.split(",") if x.strip() != ""])
        return max(n, 1)
    return total_devices


@dataclasses.dataclass
class CollectiveStats:
    per_chip_bytes: float = 0.0
    op_bytes: dict = dataclasses.field(default_factory=dict)
    op_counts: dict = dataclasses.field(default_factory=dict)

    def add(self, op: str, traffic: float):
        self.per_chip_bytes += traffic
        self.op_bytes[op] = self.op_bytes.get(op, 0.0) + traffic
        self.op_counts[op] = self.op_counts.get(op, 0) + 1


def parse_collectives(hlo_text: str, total_devices: int) -> CollectiveStats:
    stats = CollectiveStats()
    for line in hlo_text.splitlines():
        ls = line.strip()
        if ls.startswith("//") or " = " not in ls:
            continue
        for op in _COLLECTIVES:
            # Match the op invocation (e.g. "all-reduce(" or "all-reduce-start(").
            if f" {op}(" in ls or f" {op}-start(" in ls:
                size = _result_bytes(ls, op)
                n = _group_size(ls, total_devices)
                if n <= 1:
                    continue
                if op == "all-reduce":
                    traffic = 2.0 * size * (n - 1) / n
                elif op == "all-gather":
                    traffic = size * (n - 1) / n
                elif op == "reduce-scatter":
                    traffic = size * (n - 1)
                elif op == "all-to-all":
                    traffic = size * (n - 1) / n
                else:  # collective-permute
                    traffic = float(size)
                stats.add(op, traffic)
                break
    return stats


@dataclasses.dataclass(frozen=True)
class RooflineTerms:
    compute_s: float
    memory_s: float
    collective_s: float
    flops: float
    bytes_accessed: float
    collective_bytes: float  # per chip

    @property
    def dominant(self) -> str:
        terms = {
            "compute": self.compute_s,
            "memory": self.memory_s,
            "collective": self.collective_s,
        }
        return max(terms, key=terms.get)

    @property
    def step_time_s(self) -> float:
        """Roofline step time: max of the three overlappable terms."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    def roofline_fraction(self, model_flops: float, chips: int, hw: HW = HW()) -> float:
        """Useful-FLOPs throughput / peak, at the roofline step time."""
        if self.step_time_s == 0:
            return 0.0
        return model_flops / chips / self.step_time_s / hw.peak_flops


def roofline_terms(
    flops: float,
    bytes_accessed: float,
    collective_per_chip_bytes: float,
    chips: int,
    hw: HW = HW(),
) -> RooflineTerms:
    """flops/bytes are whole-program HLO totals; collectives are per-chip.

    On an SPMD program ``cost_analysis`` already reports per-device work, so
    callers pass chips=1 scaling there — see dryrun.py for the convention
    actually used (documented where the numbers are produced).
    """
    return RooflineTerms(
        compute_s=flops / (chips * hw.peak_flops),
        memory_s=bytes_accessed / (chips * hw.hbm_bw),
        collective_s=collective_per_chip_bytes / hw.link_bw,
        flops=flops,
        bytes_accessed=bytes_accessed,
        collective_bytes=collective_per_chip_bytes,
    )

import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

The two lines above MUST stay first: jax locks the device count at first
init, and the production meshes need 512 host-platform placeholder devices.
(Only the dry-run does this — smoke tests and benches see 1 device.)

Per cell this driver:
  1. builds the production mesh (16x16 single-pod / 2x16x16 multi-pod);
  2. lowers the cell's step with ShapeDtypeStruct inputs + NamedShardings
     (train_4k -> train_step with grad-accumulation; prefill_32k ->
     prefill; decode_32k / long_500k -> one-token serve_step);
  3. ``.compile()``s it — sharding mismatches, compile-time OOM and
     unsupported collectives fail HERE, which is the point;
  4. records ``memory_analysis()`` (fits-on-chip proof),
     ``cost_analysis()`` (FLOPs/bytes) and the collective traffic parsed
     from the SPMD module text into a JSON blob for EXPERIMENTS.md.

CLI:
  python -m repro.launch.dryrun --arch qwen3-32b --shape train_4k [--multi-pod]
  python -m repro.launch.dryrun --all [--jobs 4]      # every runnable cell
"""
import argparse
import json
import sys
import time
import traceback
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from ..configs import SHAPES, ArchConfig, ShapeConfig, cells, get_config, param_count
from ..models import Model
from ..optim import AdamWConfig
from ..runtime import (
    TrainState,
    batch_specs,
    cache_spec_tree,
    init_train_state,
    make_decode_step,
    make_prefill_step,
    make_sharding_rules,
    make_train_step,
    param_specs,
    tree_named,
)
from ..runtime.axes import ActivationSharding, set_activation_sharding
from .hlo import HW, roofline_terms
from .hlo_analysis import analyze_module
from .mesh import make_production_mesh
from .specs import decode_input_specs, prefill_input_specs, train_input_specs

DEFAULT_OUT = "experiments/dryrun"


def _dp_size(mesh) -> int:
    n = mesh.shape.get("data", 1)
    n *= mesh.shape.get("pod", 1)
    return n


def _opt_config(cfg: ArchConfig) -> AdamWConfig:
    return AdamWConfig(lr=1e-4, state_dtype=None)  # moments in param dtype


def _install_profile(mesh, rules) -> None:
    """Activation-sharding hints (runtime/axes.py) for this mesh/mode."""
    set_activation_sharding(
        ActivationSharding(
            mesh=mesh,
            logical={"batch": tuple(rules.dp), "model": ("model",)},
        )
    )


def build_train(cfg: ArchConfig, shape: ShapeConfig, mesh, num_microbatches=None):
    rules = make_sharding_rules(mesh, "train")
    _install_profile(mesh, rules)
    model = Model(cfg)
    nmb = num_microbatches or max(1, shape.global_batch // _dp_size(mesh))
    opt_cfg = _opt_config(cfg)
    accum = jnp.bfloat16 if cfg.param_dtype == "bfloat16" else jnp.float32
    step_fn = make_train_step(model, opt_cfg, num_microbatches=nmb, accum_dtype=accum)

    state_abs = jax.eval_shape(
        lambda k: init_train_state(model, opt_cfg, k), jax.random.PRNGKey(0)
    )
    pspecs = param_specs(state_abs.params, rules)
    state_specs = TrainState(
        params=pspecs,
        opt_state={"m": pspecs, "v": pspecs, "count": P()},
        step=P(),
    )
    batch_abs = train_input_specs(cfg, shape)
    bspecs = batch_specs(batch_abs, rules)
    in_shardings = (tree_named(rules, state_specs), tree_named(rules, bspecs))
    jitted = jax.jit(step_fn, in_shardings=in_shardings, donate_argnums=0)
    return jitted, (state_abs, batch_abs), {"num_microbatches": nmb, "mode": "train"}


def _serving_params_abs(model, cfg):
    """Serving holds weights in the compute dtype (bf16) — an f32 master
    copy is a training artifact; serving loads bf16 checkpoints.  Halves
    weight HBM (and fixed qwen3-32b decode_32k: 18.8 GB -> fits)."""
    from ..models.transformer import cast_params_for_compute

    return jax.eval_shape(
        lambda k: cast_params_for_compute(model.init(k), cfg), jax.random.PRNGKey(0)
    )


def build_prefill(cfg: ArchConfig, shape: ShapeConfig, mesh):
    rules = make_sharding_rules(mesh, "serve")
    _install_profile(mesh, rules)
    model = Model(cfg)
    step_fn = make_prefill_step(model, max_len=shape.seq_len)
    params_abs = _serving_params_abs(model, cfg)
    pspecs = param_specs(params_abs, rules)
    batch_abs = prefill_input_specs(cfg, shape)
    bspecs = batch_specs(batch_abs, rules)
    # Pin the output cache layout to the decode-compatible sharding.
    enc_len = shape.seq_len if cfg.family == "encdec" else 0
    cache_abs = jax.eval_shape(
        lambda: model.init_cache(shape.global_batch, shape.seq_len, enc_len)
    )
    cspecs = cache_spec_tree(cache_abs, rules)
    out_shardings = (
        NamedSharding(mesh, P()),          # next_token (tiny)
        tree_named(rules, cspecs),
    )
    jitted = jax.jit(
        step_fn,
        in_shardings=(tree_named(rules, pspecs), tree_named(rules, bspecs)),
        out_shardings=out_shardings,
    )
    return jitted, (params_abs, batch_abs), {"mode": "prefill"}


def build_decode(cfg: ArchConfig, shape: ShapeConfig, mesh):
    rules = make_sharding_rules(mesh, "serve")
    _install_profile(mesh, rules)
    model = Model(cfg)
    step_fn = make_decode_step(model)
    params_abs = _serving_params_abs(model, cfg)
    pspecs = param_specs(params_abs, rules)
    ins = decode_input_specs(cfg, shape)
    cspecs = cache_spec_tree(ins["cache"], rules)
    in_shardings = (
        tree_named(rules, pspecs),
        tree_named(rules, cspecs),
        NamedSharding(mesh, P(None, None)),  # tokens (B, 1): tiny, replicated
        NamedSharding(mesh, P()),            # pos scalar
    )
    out_shardings = (NamedSharding(mesh, P(None, None)), tree_named(rules, cspecs))
    jitted = jax.jit(
        step_fn, in_shardings=in_shardings, out_shardings=out_shardings,
        donate_argnums=1,
    )
    args = (params_abs, ins["cache"], ins["tokens"], ins["pos"])
    return jitted, args, {"mode": "decode"}


def _memory_dict(compiled) -> dict:
    out = {}
    try:
        ma = compiled.memory_analysis()
    except Exception as e:  # pragma: no cover
        return {"error": str(e)}
    if ma is None:
        return {"unavailable": True}
    for f in (
        "argument_size_in_bytes",
        "output_size_in_bytes",
        "temp_size_in_bytes",
        "alias_size_in_bytes",
        "generated_code_size_in_bytes",
    ):
        v = getattr(ma, f, None)
        if v is not None:
            out[f] = int(v)
    if out:
        out["per_device_total_bytes"] = (
            out.get("argument_size_in_bytes", 0)
            + out.get("output_size_in_bytes", 0)
            + out.get("temp_size_in_bytes", 0)
            - out.get("alias_size_in_bytes", 0)
        )
    return out


def _cost_dict(compiled) -> dict:
    try:
        ca = compiled.cost_analysis()
    except Exception as e:  # pragma: no cover
        return {"error": str(e)}
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    keep = {}
    for k in ("flops", "bytes accessed", "transcendentals", "utilization"):
        if k in ca:
            keep[k] = float(ca[k])
    return keep


def run_cell(
    arch: str,
    shape_name: str,
    multi_pod: bool = False,
    out_dir: Optional[str] = DEFAULT_OUT,
    verbose: bool = True,
) -> dict:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.size
    mesh_name = "2x16x16" if multi_pod else "16x16"
    rec: dict[str, Any] = {
        "arch": arch, "shape": shape_name, "mesh": mesh_name, "chips": chips,
        "kind": shape.kind,
    }
    t0 = time.perf_counter()
    try:
        with mesh:
            if shape.kind == "train":
                jitted, (state_abs, batch_abs), meta = build_train(cfg, shape, mesh)
                lowered = jitted.lower(state_abs, batch_abs)
            elif shape.kind == "prefill":
                jitted, (params_abs, batch_abs), meta = build_prefill(cfg, shape, mesh)
                lowered = jitted.lower(params_abs, batch_abs)
            else:
                jitted, args, meta = build_decode(cfg, shape, mesh)
                lowered = jitted.lower(*args)
            rec.update(meta)
            rec["lower_s"] = time.perf_counter() - t0
            t1 = time.perf_counter()
            compiled = lowered.compile()
            rec["compile_s"] = time.perf_counter() - t1
    except Exception as e:
        rec["status"] = "error"
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-4000:]
        if verbose:
            print(json.dumps({k: rec[k] for k in ("arch", "shape", "mesh", "status", "error")}))
        _write(rec, out_dir, arch, shape_name, mesh_name)
        return rec

    rec["status"] = "ok"
    rec["memory"] = _memory_dict(compiled)
    rec["cost_raw"] = _cost_dict(compiled)  # XLA's loop-unaware numbers (reference)

    hlo = compiled.as_text()
    rec["hlo_bytes"] = len(hlo)
    # Loop-aware static cost model: while-trip multipliers applied to dot
    # FLOPs, fusion-boundary HBM traffic and collective link traffic (all
    # PER-DEVICE — the SPMD module's shapes are per-device).
    cm = analyze_module(hlo, chips)
    rec["cost_model"] = {
        "flops_per_chip": cm.flops,
        "hbm_bytes_per_chip": cm.hbm_bytes,
        "collective_bytes_per_chip": cm.collective_bytes,
        "collective_op_bytes": cm.collective_op_bytes,
        "collective_op_counts": cm.collective_op_counts,
        "dot_flops_visited_once": cm.dot_flops_unrolled,
        "warnings": cm.warnings[:10],
    }

    terms = roofline_terms(cm.flops, cm.hbm_bytes, cm.collective_bytes, chips=1)
    pc = param_count(cfg)
    tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode" else 1)
    factor = 6 if shape.kind == "train" else 2
    model_flops = factor * pc["active"] * tokens
    hlo_total = cm.flops * chips
    rec["roofline"] = {
        "compute_s": terms.compute_s,
        "memory_s": terms.memory_s,
        "collective_s": terms.collective_s,
        "dominant": terms.dominant,
        "step_time_s": terms.step_time_s,
        "model_flops": model_flops,
        "hlo_flops_total": hlo_total,
        "useful_flops_ratio": model_flops / hlo_total if hlo_total else 0.0,
        "roofline_fraction": (
            model_flops / chips / terms.step_time_s / HW().peak_flops
            if terms.step_time_s else 0.0
        ),
    }
    if verbose:
        r = rec["roofline"]
        print(json.dumps({
            "arch": arch, "shape": shape_name, "mesh": mesh_name, "status": "ok",
            "mem_GB": rec["memory"].get("per_device_total_bytes", 0) / 1e9,
            "compute_s": round(r["compute_s"], 6), "memory_s": round(r["memory_s"], 6),
            "collective_s": round(r["collective_s"], 6), "dominant": r["dominant"],
            "mfu": round(r["roofline_fraction"], 4),
            "lower_s": round(rec["lower_s"], 1), "compile_s": round(rec["compile_s"], 1),
        }))
    _write(rec, out_dir, arch, shape_name, mesh_name)
    return rec


def _write(rec, out_dir, arch, shape_name, mesh_name):
    if out_dir is None:
        return
    os.makedirs(out_dir, exist_ok=True)
    path = os.path.join(out_dir, f"{arch}__{shape_name}__{mesh_name}.json")
    with open(path, "w") as f:
        json.dump(rec, f, indent=1)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape", choices=sorted(SHAPES))
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true", help="list runnable cells")
    ap.add_argument("--out", default=DEFAULT_OUT)
    args = ap.parse_args(argv)

    if args.all:
        # Print the work list (driven by scripts/run_dryruns.sh in parallel
        # subprocesses — each compile is a fresh process for isolation).
        for arch, shape, status in cells(include_skips=True):
            for mp in ("", "--multi-pod"):
                if status == "run":
                    print(f"--arch {arch} --shape {shape} {mp}".strip())
                else:
                    print(f"# SKIP {arch} {shape}: {status}")
        return 0

    if not args.arch or not args.shape:
        ap.error("--arch and --shape required (or --all)")
    rec = run_cell(args.arch, args.shape, args.multi_pod, args.out)
    return 0 if rec.get("status") == "ok" else 1


if __name__ == "__main__":
    sys.exit(main())

"""End-to-end training driver.

Runs a REAL training loop (CPU-sized via --reduced, or the full config on a
TPU slice): synthetic pipeline -> jit'd train step (grad-accumulation +
remat + AdamW) -> fault-tolerant loop with async checkpointing.  This is
deliverable (b)'s end-to-end example driver; examples/train_lm.py wraps it.

    PYTHONPATH=src python -m repro.launch.train --arch qwen3-moe-30b-a3b \
        --reduced --steps 50 --batch 8 --seq 64 --ckpt-dir /tmp/ckpt
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from ..ckpt import CheckpointManager
from ..configs import get_config
from ..data import PipelineConfig, SyntheticPipeline
from ..models import Model
from ..optim import AdamWConfig, warmup_cosine
from ..runtime import (
    FaultTolerantLoop,
    StragglerMonitor,
    init_train_state,
    make_train_step,
)

__all__ = ["run_training", "main"]


def run_training(
    arch: str,
    steps: int = 20,
    batch: int = 8,
    seq: int = 64,
    reduced: bool = True,
    ckpt_dir: str | None = None,
    ckpt_every: int = 10,
    num_microbatches: int = 2,
    seed: int = 0,
    log_every: int = 5,
    fail_at: int | None = None,
):
    """Train; returns (final_state, history).  ``fail_at`` injects one step
    failure to exercise the checkpoint/restart path (tests use it)."""
    cfg = get_config(arch, reduced=reduced)
    model = Model(cfg)
    opt_cfg = AdamWConfig(lr=warmup_cosine(3e-4, max(2, steps // 10), steps))
    step_fn = jax.jit(
        make_train_step(model, opt_cfg, num_microbatches=num_microbatches)
    )
    state = init_train_state(model, opt_cfg, jax.random.PRNGKey(seed))

    pipe = SyntheticPipeline(
        PipelineConfig(
            vocab_size=cfg.vocab_size, seq_len=seq, global_batch=batch,
            seed=seed, frontend=cfg.frontend, d_model=cfg.d_model,
        )
    )

    failed = {"done": False}

    def batch_fn(step: int) -> dict:
        if fail_at is not None and step == fail_at and not failed["done"]:
            failed["done"] = True
            raise RuntimeError(f"injected failure at step {step}")
        b = pipe.enc_dec_batch(step) if cfg.family == "encdec" else pipe.batch(step)
        return {k: jnp.asarray(v) for k, v in b.items()}

    history = []
    if ckpt_dir is not None:
        loop = FaultTolerantLoop(
            step_fn=step_fn,
            batch_fn=batch_fn,
            ckpt=CheckpointManager(ckpt_dir, keep=2),
            ckpt_every=ckpt_every,
            straggler=StragglerMonitor(),
        )
        state, history = loop.run(state, 0, steps)
    else:
        for step in range(steps):
            t0 = time.perf_counter()
            state, metrics = step_fn(state, batch_fn(step))
            if step % log_every == 0:
                print(
                    f"step {step:5d} loss={float(metrics['loss']):.4f} "
                    f"gnorm={float(metrics['grad_norm']):.3f} "
                    f"dt={time.perf_counter() - t0:.3f}s"
                )
            history.append({"step": step, "loss": float(metrics["loss"])})
    return state, history


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=10)
    ap.add_argument("--microbatches", type=int, default=2)
    args = ap.parse_args(argv)
    _, history = run_training(
        args.arch, steps=args.steps, batch=args.batch, seq=args.seq,
        reduced=args.reduced, ckpt_dir=args.ckpt_dir,
        ckpt_every=args.ckpt_every, num_microbatches=args.microbatches,
    )
    losses = [h["loss"] for h in history if "loss" in h]
    print(f"trained {len(history)} steps; loss {losses[0]:.4f} -> {losses[-1]:.4f}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())

"""Serving driver: batched prefill + greedy decode loop.

    PYTHONPATH=src python -m repro.launch.serve --arch granite-3-8b \
        --reduced --batch 4 --prompt-len 32 --gen 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import get_config
from ..models import Model
from ..runtime import make_decode_step, make_prefill_step

__all__ = ["run_serving", "main"]


def run_serving(
    arch: str,
    batch: int = 4,
    prompt_len: int = 32,
    gen: int = 16,
    reduced: bool = True,
    seed: int = 0,
):
    """Prefill a batch of prompts, then greedy-decode ``gen`` tokens.

    Returns (tokens (B, gen), timing dict)."""
    cfg = get_config(arch, reduced=reduced)
    model = Model(cfg)
    rng = jax.random.PRNGKey(seed)
    params = model.init(rng)
    max_len = prompt_len + gen

    batch_in: dict = {}
    if cfg.frontend:
        batch_in["embeds"] = jax.random.normal(
            rng, (batch, prompt_len, cfg.d_model), jnp.float32
        )
        if cfg.mrope:
            batch_in["positions3"] = jnp.broadcast_to(
                jnp.arange(prompt_len, dtype=jnp.int32), (3, batch, prompt_len)
            )
    else:
        batch_in["tokens"] = jax.random.randint(
            rng, (batch, prompt_len), 2, cfg.vocab_size
        )
    if cfg.family == "encdec":
        batch_in["enc_embeds"] = jax.random.normal(
            rng, (batch, prompt_len, cfg.d_model), jnp.float32
        )
        batch_in["tokens"] = jax.random.randint(
            rng, (batch, prompt_len), 2, cfg.vocab_size
        )

    prefill = jax.jit(make_prefill_step(model, max_len=max_len))
    decode = jax.jit(make_decode_step(model))

    t0 = time.perf_counter()
    tok, cache = prefill(params, batch_in)
    tok = tok[:, None]
    t_prefill = time.perf_counter() - t0

    out = [tok]
    t1 = time.perf_counter()
    for i in range(gen - 1):
        tok, cache = decode(params, cache, tok, jnp.asarray(prompt_len + i, jnp.int32))
        out.append(tok)
    jax.block_until_ready(tok)
    t_decode = time.perf_counter() - t1
    tokens = jnp.concatenate(out, axis=1)
    return tokens, {
        "prefill_s": t_prefill,
        "decode_s": t_decode,
        "tok_per_s": batch * (gen - 1) / max(t_decode, 1e-9),
    }


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--reduced", action="store_true")
    args = ap.parse_args(argv)
    tokens, stats = run_serving(
        args.arch, batch=args.batch, prompt_len=args.prompt_len,
        gen=args.gen, reduced=args.reduced,
    )
    print(f"generated {tokens.shape} tokens; {stats}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())

"""Serving drivers: LM prefill/decode loop + service-backed EP-SpMV serving.

    PYTHONPATH=src python -m repro.launch.serve --arch granite-3-8b \
        --reduced --batch 4 --prompt-len 32 --gen 16

    PYTHONPATH=src python -m repro.launch.serve --graph --requests 16 --churn 0.01

    PYTHONPATH=src python -m repro.launch.serve --graph --tenants 3 \
        --cache-budget-mb 1.0 --workers 2

    PYTHONPATH=src python -m repro.launch.serve --graph --batched \
        --clients 4 --graphs 48 --max-batch 8 --max-wait-ms 2

The ``--graph`` mode demonstrates the paper-§4.2 serving architecture: a
stream of SpMV requests over a (mostly) repeated matrix hits the
PartitionService's fingerprint cache; a churn batch triggers an *async*
incremental repartition on the optimization thread while requests keep
being served under the old plan from a double buffer, which swaps when the
new plan lands.

With ``--tenants N`` (N > 1) the demo drives the multi-tenant scheduling
subsystem instead: N tenants share one PartitionService with per-tenant
cache byte budgets (``--cache-budget-mb``) and a ``--workers``-wide pool;
tenant 0 floods the cache with one-shot matrices while the others keep
re-requesting their hot matrix, and the final report shows the per-tenant
hit/miss/eviction isolation plus the scheduler's ServiceMetrics snapshot.

With ``--batched`` the demo drives the bucketed-compilation micro-batcher:
``--clients`` threads push ``--graphs`` distinct small matrices through
``GraphServer.submit``; same-bucket requests coalesce within the
``--max-batch``/``--max-wait-ms`` window onto a handful of compiled bucket
kernels, and the report shows compile counts, the batch-size histogram,
and steady-state request rate.

With ``--replicas N`` (N > 1) the demo serves through a ``ReplicaGroup`` —
N PartitionService replicas behind one facade — and ``--kill-after R``
crashes one replica after R requests mid-stream.  The stream keeps being
served (in-flight work fails over, the shared plan store keeps warm hits
warm), and the final report shows the per-replica health/failover table:

    PYTHONPATH=src python -m repro.launch.serve --graph --replicas 2 \
        --kill-after 4

``--transport=process`` moves each replica into its own OS process behind
the loopback TCP transport (``launch.replica_worker``); the mid-stream
kill then is a real ``SIGKILL`` of a worker process, survived on wire
errors and missed heartbeats alone:

    PYTHONPATH=src python -m repro.launch.serve --graph --replicas 2 \
        --kill-after 4 --transport process
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import get_config
from ..models import Model
from ..runtime import (
    GraphRequest,
    GraphServer,
    make_decode_step,
    make_prefill_step,
)

__all__ = [
    "run_serving",
    "run_graph_serving",
    "run_multitenant_graph_serving",
    "run_batched_graph_serving",
    "run_replicated_graph_serving",
    "run_overload_graph_serving",
    "main",
]


def run_serving(
    arch: str,
    batch: int = 4,
    prompt_len: int = 32,
    gen: int = 16,
    reduced: bool = True,
    seed: int = 0,
):
    """Prefill a batch of prompts, then greedy-decode ``gen`` tokens.

    Returns (tokens (B, gen), timing dict)."""
    cfg = get_config(arch, reduced=reduced)
    model = Model(cfg)
    rng = jax.random.PRNGKey(seed)
    params = model.init(rng)
    max_len = prompt_len + gen

    batch_in: dict = {}
    if cfg.frontend:
        batch_in["embeds"] = jax.random.normal(
            rng, (batch, prompt_len, cfg.d_model), jnp.float32
        )
        if cfg.mrope:
            batch_in["positions3"] = jnp.broadcast_to(
                jnp.arange(prompt_len, dtype=jnp.int32), (3, batch, prompt_len)
            )
    else:
        batch_in["tokens"] = jax.random.randint(
            rng, (batch, prompt_len), 2, cfg.vocab_size
        )
    if cfg.family == "encdec":
        batch_in["enc_embeds"] = jax.random.normal(
            rng, (batch, prompt_len, cfg.d_model), jnp.float32
        )
        batch_in["tokens"] = jax.random.randint(
            rng, (batch, prompt_len), 2, cfg.vocab_size
        )

    prefill = jax.jit(make_prefill_step(model, max_len=max_len))
    decode = jax.jit(make_decode_step(model))

    t0 = time.perf_counter()
    tok, cache = prefill(params, batch_in)
    tok = tok[:, None]
    t_prefill = time.perf_counter() - t0

    out = [tok]
    t1 = time.perf_counter()
    for i in range(gen - 1):
        tok, cache = decode(params, cache, tok, jnp.asarray(prompt_len + i, jnp.int32))
        out.append(tok)
    jax.block_until_ready(tok)
    t_decode = time.perf_counter() - t1
    tokens = jnp.concatenate(out, axis=1)
    return tokens, {
        "prefill_s": t_prefill,
        "decode_s": t_decode,
        "tok_per_s": batch * (gen - 1) / max(t_decode, 1e-9),
    }


def run_graph_serving(
    n_rows: int = 1024,
    n_cols: int = 1024,
    nnz_per_row: int = 6,
    k: int = 32,
    requests: int = 16,
    churn: float = 0.01,
    pad: int = 128,
    seed: int = 0,
):
    """Serve a stream of EP-SpMV requests through the PartitionService.

    Phases: (1) cold request — full partition + pack + jit; (2) warm
    requests — fingerprint cache hits, steady-state kernel only; (3) churn —
    ``churn`` fraction of the nnz is deleted and replaced, the incremental
    repartition runs on the optimization thread behind a DoubleBuffer while
    warm requests continue against the old plan; (4) post-swap requests use
    the refreshed plan.  Returns a timing/stats dict.
    """
    from ..core import DoubleBuffer, PartitionService
    from ..core.graph import synthetic_bipartite_graph
    from ..kernels import make_ep_spmv_fn, spmv_hbm_traffic_model

    _, rows, cols = synthetic_bipartite_graph(n_rows, n_cols, nnz_per_row, seed=seed)
    rng = np.random.default_rng(seed + 1)
    vals = rng.standard_normal(rows.shape[0]).astype(np.float32)

    with PartitionService() as svc:
        server = GraphServer(svc, k=k, pad=pad, interpret=True, start_batcher=False)

        def serve_once(x):
            return server.serve(GraphRequest(n_rows, n_cols, rows, cols, vals, x))

        t0 = time.perf_counter()
        info0 = serve_once(rng.standard_normal(n_cols)).info
        cold_s = time.perf_counter() - t0

        t0 = time.perf_counter()
        n_warm = max(requests - 1, 1)
        for _ in range(n_warm):
            res = serve_once(rng.standard_normal(n_cols))
            assert res.info.cache_hit
        warm_s = (time.perf_counter() - t0) / n_warm

        # Churn batch: delete + insert churn*m edges, repartition ASYNC while
        # the old plan keeps serving from the double buffer.
        m = rows.shape[0]
        n_churn = max(int(churn * m), 1)
        delete_ids = rng.choice(m, size=n_churn, replace=False)
        ins_rows = rng.integers(0, n_rows, n_churn)
        ins_cols = rng.integers(0, n_cols, n_churn)
        buffer = DoubleBuffer()
        base_fp = info0.fingerprint
        t0 = time.perf_counter()
        ticket = svc.update_async(
            base_fp,
            k,
            insert_u=ins_cols.astype(np.int64),
            insert_v=(n_cols + ins_rows).astype(np.int64),
            delete_ids=delete_ids,
            pad=pad,
            buffer=buffer,
        )
        overlapped = 0
        while not ticket.done():  # old plan keeps serving — §4.2 overlap
            serve_once(rng.standard_normal(n_cols))
            overlapped += 1
        new_plan = ticket.result()
        incr_s = time.perf_counter() - t0
        swapped, gen = buffer.current()
        assert swapped is new_plan and gen == 1

        # Values follow the churn: surviving nnz keep theirs, insertions get new.
        vals_new = np.concatenate(
            [np.delete(vals, delete_ids), rng.standard_normal(n_churn).astype(np.float32)]
        )
        fn = make_ep_spmv_fn(new_plan.plan, vals_new, interpret=True)
        t0 = time.perf_counter()
        fn(jnp.asarray(rng.standard_normal(n_cols)))
        post_swap_s = time.perf_counter() - t0

        stats = {
            "cold_s": cold_s,
            "warm_s": warm_s,
            "warm_speedup": cold_s / max(warm_s, 1e-9),
            "incremental_s": incr_s,
            "incremental_source": new_plan.source,
            "requests_overlapped_with_repartition": overlapped,
            "post_swap_s": post_swap_s,
            "traffic": spmv_hbm_traffic_model(new_plan.plan),
            "service": dataclasses.asdict(svc.stats),
            "compile_cache": server.stats(),
        }
    return stats


def run_multitenant_graph_serving(
    tenants: int = 3,
    cache_budget_mb: float = 1.0,
    workers: int = 2,
    rounds: int = 4,
    n_rows: int = 256,
    n_cols: int = 256,
    nnz_per_row: int = 4,
    k: int = 16,
    pad: int = 128,
    seed: int = 0,
):
    """Drive K tenants through one PartitionService under cache contention.

    Tenant 0 is the *flooder*: every round it serves a brand-new one-shot
    matrix (cache pollution).  Tenants 1..K-1 are *victims*: each owns one
    hot matrix and re-requests it every round.  With per-tenant byte
    budgets the flood can only evict the flooder's own entries, so every
    victim round after the first is a warm hit.  Returns a dict with
    per-tenant serving stats and the ServiceMetrics snapshot.
    """
    import dataclasses as _dc

    from ..core import PartitionService
    from ..core.graph import synthetic_bipartite_graph

    budget = int(cache_budget_mb * 1e6)
    rng = np.random.default_rng(seed)
    with PartitionService(workers=workers, default_tenant_budget=budget) as svc:
        server = GraphServer(svc, k=k, pad=pad, interpret=True, start_batcher=False)

        def serve(n_rows, n_cols, rows, cols, vals, x, tenant):
            res = server.serve(
                GraphRequest(n_rows, n_cols, rows, cols, vals, x, tenant=tenant)
            )
            return res.y, res.info

        hot = {}
        for t in range(1, tenants):
            _, rows, cols = synthetic_bipartite_graph(
                n_rows, n_cols, nnz_per_row, seed=100 + t)
            vals = rng.standard_normal(rows.shape[0]).astype(np.float32)
            hot[f"tenant{t}"] = (rows, cols, vals)
        per_round: dict[str, list] = {f"tenant{t}": [] for t in range(tenants)}
        flood_seed = 0
        for _ in range(rounds):
            flood_seed += 1
            _, rows, cols = synthetic_bipartite_graph(
                n_rows, n_cols, nnz_per_row, seed=1000 + flood_seed)
            vals = rng.standard_normal(rows.shape[0]).astype(np.float32)
            t0 = time.perf_counter()
            _, info = serve(n_rows, n_cols, rows, cols, vals,
                            rng.standard_normal(n_cols), tenant="tenant0")
            per_round["tenant0"].append((time.perf_counter() - t0, info.cache_hit))
            for name, (rows, cols, vals) in hot.items():
                t0 = time.perf_counter()
                _, info = serve(n_rows, n_cols, rows, cols, vals,
                                rng.standard_normal(n_cols), tenant=name)
                per_round[name].append((time.perf_counter() - t0, info.cache_hit))
        snap = svc.metrics()
        report = {"tenants": {}, "metrics": _dc.asdict(snap)}
        for name, rts in per_round.items():
            warm = [dt for dt, hit in rts[1:] if hit]
            report["tenants"][name] = {
                "requests": len(rts),
                "warm_hits_after_round1": sum(hit for _, hit in rts[1:]),
                "warm_hit_rate_after_round1": (
                    sum(hit for _, hit in rts[1:]) / max(len(rts) - 1, 1)),
                "median_warm_ms": float(np.median(warm)) * 1e3 if warm else None,
                "evictions": snap.tenants.get(name, {}).get("evictions", 0),
            }
    return report


def run_batched_graph_serving(
    clients: int = 4,
    graphs: int = 48,
    requests_per_client: int = 24,
    max_batch: int = 8,
    max_wait_ms: float = 2.0,
    n_rows: int = 192,
    n_cols: int = 192,
    nnz_per_row: int = 4,
    k: int = 8,
    pad: int = 128,
    seed: int = 0,
):
    """Concurrent clients through the bucketed micro-batched serve path.

    ``clients`` threads each fire ``requests_per_client`` requests drawn
    from a pool of ``graphs`` distinct small matrices (all landing in a
    handful of shape buckets).  Requests go through ``GraphServer.submit``,
    so same-bucket arrivals inside the ``max_wait_ms`` window share one
    stacked kernel launch.  Reports total/steady req/s, distinct kernel
    compiles, and the batch-size histogram — on this workload the compile
    count stays at the bucket count, not the graph count.
    """
    import threading

    from ..core import PartitionService
    from ..core.graph import synthetic_bipartite_graph

    rng = np.random.default_rng(seed)
    pool = []
    for g in range(graphs):
        _, rows, cols = synthetic_bipartite_graph(n_rows, n_cols, nnz_per_row, seed=seed + g)
        vals = rng.standard_normal(rows.shape[0]).astype(np.float32)
        pool.append((rows, cols, vals))

    with PartitionService(max_entries=graphs + 8) as svc:
        with GraphServer(
            svc, k=k, pad=pad, interpret=True,
            max_batch=max_batch, max_wait_ms=max_wait_ms,
        ) as server:
            # Warm the plan cache so the measured phase is serving, not
            # partitioning (the §4.2 split: optimization off the hot path).
            for rows, cols, vals in pool:
                server.serve(GraphRequest(n_rows, n_cols, rows, cols, vals,
                                          np.zeros(n_cols, np.float32)))
            latencies: list[float] = []
            lat_lock = threading.Lock()

            def client(cid: int) -> None:
                crng = np.random.default_rng(1000 + cid)
                for _ in range(requests_per_client):
                    rows, cols, vals = pool[crng.integers(0, len(pool))]
                    x = crng.standard_normal(n_cols).astype(np.float32)
                    t0 = time.perf_counter()
                    server.submit(
                        GraphRequest(n_rows, n_cols, rows, cols, vals, x,
                                     tenant=f"client{cid}")
                    ).wait(60.0)
                    with lat_lock:
                        latencies.append(time.perf_counter() - t0)

            threads = [threading.Thread(target=client, args=(c,)) for c in range(clients)]
            t0 = time.perf_counter()
            for t in threads:
                t.start()
            for t in threads:
                t.join()
            elapsed = time.perf_counter() - t0
            stats = server.stats()
    n_req = clients * requests_per_client
    lat = np.asarray(sorted(latencies))
    return {
        "requests": n_req,
        "elapsed_s": elapsed,
        "req_per_s": n_req / max(elapsed, 1e-9),
        "p50_ms": float(lat[int(0.50 * (len(lat) - 1))]) * 1e3,
        "p99_ms": float(lat[int(0.99 * (len(lat) - 1))]) * 1e3,
        "kernel_compiles": stats["misses"],
        "kernel_cache_hits": stats["hits"],
        "buckets": list(stats["buckets"]),
        "batch_hist": stats["batch_hist"],
    }


def run_replicated_graph_serving(
    replicas: int = 2,
    kill_after: int | None = 4,
    requests: int = 12,
    matrices: int = 4,
    n_rows: int = 256,
    n_cols: int = 256,
    nnz_per_row: int = 4,
    k: int = 16,
    pad: int = 128,
    seed: int = 0,
    transport: str = "thread",
):
    """Serve an EP-SpMV stream through a ReplicaGroup, crashing one replica
    mid-stream.

    The stream cycles through ``matrices`` distinct matrices; after
    ``kill_after`` requests one replica is killed.  Requests keep being
    served — in-flight plans fail over, warm requests hit the shared plan
    store — and the report carries per-request outcomes plus the group's
    per-replica health/failover table.

    ``transport="thread"`` (default) runs the replicas in-process; the
    mid-stream kill is a graceful-drain crash.  ``transport="process"``
    spawns one worker OS process per replica behind the TCP transport
    (``launch.replica_worker``) and the kill is a real ``SIGKILL`` — the
    stream must survive on wire errors and missed heartbeats alone.
    """
    from ..core import ReplicaGroup
    from ..core.graph import synthetic_bipartite_graph

    if transport not in ("thread", "process"):
        raise ValueError(f"unknown transport {transport!r}")

    rng = np.random.default_rng(seed)
    pool = []
    for g in range(matrices):
        _, rows, cols = synthetic_bipartite_graph(n_rows, n_cols, nnz_per_row,
                                                  seed=seed + g)
        vals = rng.standard_normal(rows.shape[0]).astype(np.float32)
        pool.append((rows, cols, vals))

    if transport == "process":
        from .replica_worker import spawn_process_group
        group_cm = spawn_process_group(replicas, heartbeat_deadline_s=1.0)
    else:
        group_cm = ReplicaGroup(replicas)
    with group_cm as group:
        server = GraphServer(group, k=k, pad=pad, interpret=True,
                             start_batcher=False)
        killed = None
        per_request = []
        t_all = time.perf_counter()
        for i in range(requests):
            if kill_after is not None and i == kill_after and killed is None:
                killed = group.replica_ids()[0]
                if transport == "process":
                    # kill -9 the worker process: no drain, no goodbye.
                    group._by_rid[killed].svc.sigkill()
                else:
                    group.kill(killed)
            rows, cols, vals = pool[i % len(pool)]
            x = rng.standard_normal(n_cols).astype(np.float32)
            t0 = time.perf_counter()
            res = server.serve(GraphRequest(n_rows, n_cols, rows, cols, vals, x))
            per_request.append({
                "latency_ms": (time.perf_counter() - t0) * 1e3,
                "cache_hit": res.info.cache_hit,
                "stale": res.info.stale,
            })
        elapsed = time.perf_counter() - t_all
        rm = group.replica_metrics()
    return {
        "replicas": replicas,
        "transport": transport,
        "killed_replica": killed,
        "requests": requests,
        "elapsed_s": elapsed,
        "served_after_kill": sum(1 for r in per_request[kill_after or 0:]),
        "stale_serves": rm.stale_serves,
        "lost_tickets": rm.lost,
        "failovers": rm.failovers,
        "hedges_fired": rm.hedges_fired,
        "per_request": per_request,
        "replica_table": [r.as_dict() for r in rm.replicas],
    }


def run_overload_graph_serving(
    queue_bound: int = 4,
    flood_clients: int = 6,
    flood_requests_each: int = 4,
    victim_rounds: int = 10,
    stall_s: float = 0.03,
    n_rows: int = 192,
    n_cols: int = 192,
    nnz_per_row: int = 4,
    k: int = 8,
    pad: int = 128,
    seed: int = 0,
):
    """Overload-protection demo: a flooding tenant against bounded admission.

    One low-priority tenant fires cold one-shot matrices from
    ``flood_clients`` threads at a service whose scheduler queue is bounded
    at ``queue_bound`` (drain artificially slowed by ``stall_s`` per job so
    the flood actually queues).  A high-priority victim keeps re-requesting
    its warm matrix throughout.  The report shows the ladder working:
    victims stay on warm-hit latency with zero rejections; the flooder
    absorbs ``AdmissionRejectedError`` with ``retry_after_s`` hints; under
    sustained rejection pressure the :class:`GraphServer` browns out —
    hedging off first, then the low-priority tenant goes cache-only.
    """
    import threading

    from ..core import AdmissionRejectedError, ReplicaGroup
    from ..core.graph import synthetic_bipartite_graph

    rng = np.random.default_rng(seed)
    _, vrows, vcols = synthetic_bipartite_graph(n_rows, n_cols, nnz_per_row,
                                                seed=seed)
    vvals = rng.standard_normal(vrows.shape[0]).astype(np.float32)

    with ReplicaGroup(
        1, hedge=False, workers=1, retry_budget=1,
        backoff_base_s=0.002, backoff_cap_s=0.005,
        breaker_cooldown_s=0.2,
        max_queue_depth=queue_bound,
    ) as group:
        server = GraphServer(group, k=k, pad=pad, interpret=True,
                             start_batcher=False,
                             brownout_hedge_off=2, brownout_stale_only=4,
                             brownout_window_s=2.0)

        def victim_req(x):
            return server.serve(GraphRequest(n_rows, n_cols, vrows, vcols,
                                             vvals, x, tenant="victim",
                                             priority=1))

        victim_req(rng.standard_normal(n_cols))  # warm the hot matrix
        # Slow the drain so the flood queues instead of racing through.
        group._replicas[0].svc.scheduler.pre_job_hook = (
            lambda _key: time.sleep(stall_s))

        admitted = [0]
        rejections: list[float] = []
        brownout_rejects = [0]
        out_lock = threading.Lock()

        def flooder(cid: int) -> None:
            crng = np.random.default_rng(5000 + cid)
            for j in range(flood_requests_each):
                _, rows, cols = synthetic_bipartite_graph(
                    n_rows, n_cols, nnz_per_row,
                    seed=9000 + cid * 100 + j)
                vals = crng.standard_normal(rows.shape[0]).astype(np.float32)
                x = crng.standard_normal(n_cols).astype(np.float32)
                try:
                    server.serve(GraphRequest(n_rows, n_cols, rows, cols,
                                              vals, x, tenant="flooder",
                                              priority=0))
                    with out_lock:
                        admitted[0] += 1
                except AdmissionRejectedError as e:
                    with out_lock:
                        if e.reason == "brownout":
                            brownout_rejects[0] += 1
                        else:
                            rejections.append(e.retry_after_s)

        threads = [threading.Thread(target=flooder, args=(c,))
                   for c in range(flood_clients)]
        for t in threads:
            t.start()
        victim_lat = []
        for _ in range(victim_rounds):
            t0 = time.perf_counter()
            res = victim_req(rng.standard_normal(n_cols))
            victim_lat.append(time.perf_counter() - t0)
            assert res.info.cache_hit and not res.info.degraded
            time.sleep(0.01)
        for t in threads:
            t.join()
        lat = np.asarray(sorted(victim_lat))
        snap = group.metrics()
        stats = server.stats()
        report = {
            "queue_bound": queue_bound,
            "victim_p50_ms": float(lat[int(0.50 * (len(lat) - 1))]) * 1e3,
            "victim_p99_ms": float(lat[int(0.99 * (len(lat) - 1))]) * 1e3,
            "victim_rejections": 0,  # any rejection would have raised above
            "flooder_admitted": admitted[0],
            "flooder_rejections": len(rejections),
            "flooder_brownout_rejects": brownout_rejects[0],
            "min_retry_after_s": min(rejections) if rejections else None,
            "queue_depth_max": snap.queue_depth_max,
            "rejected": snap.rejected,
            "shed_deadline": snap.shed_deadline,
            "brownout_level_final": stats["brownout_level"],
            "degraded_serves": stats["degraded_serves"],
            "breakers": group.breaker_states("flooder"),
        }
    return report


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--graph", action="store_true",
                    help="serve EP-SpMV requests through the PartitionService")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--churn", type=float, default=0.01)
    ap.add_argument("--k", type=int, default=32)
    ap.add_argument("--tenants", type=int, default=1,
                    help="with --graph: drive N tenants under cache "
                         "contention through one service (N > 1)")
    ap.add_argument("--cache-budget-mb", type=float, default=1.0,
                    help="per-tenant plan-cache byte budget (MB)")
    ap.add_argument("--workers", type=int, default=2,
                    help="partition worker pool size for the tenant demo")
    ap.add_argument("--batched", action="store_true",
                    help="with --graph: drive the bucketed micro-batched "
                         "serve path with concurrent clients")
    ap.add_argument("--clients", type=int, default=4,
                    help="concurrent client threads for --batched")
    ap.add_argument("--graphs", type=int, default=48,
                    help="distinct matrices in the --batched request pool")
    ap.add_argument("--max-batch", type=int, default=8,
                    help="micro-batch width for --batched")
    ap.add_argument("--max-wait-ms", type=float, default=2.0,
                    help="micro-batch coalescing window for --batched")
    ap.add_argument("--replicas", type=int, default=1,
                    help="with --graph: serve through a ReplicaGroup of N "
                         "PartitionService replicas (N > 1)")
    ap.add_argument("--kill-after", type=int, default=4,
                    help="with --replicas: crash one replica after this "
                         "many requests (negative disables)")
    ap.add_argument("--overload", action="store_true",
                    help="with --graph: flood a bounded-admission service "
                         "with one tenant and show victims staying fast "
                         "while the flooder absorbs typed rejections")
    ap.add_argument("--queue-bound", type=int, default=4,
                    help="scheduler queue bound for --overload")
    ap.add_argument("--transport", choices=["thread", "process"],
                    default="thread",
                    help="with --replicas: 'thread' keeps replicas "
                         "in-process; 'process' spawns one worker OS "
                         "process per replica behind the TCP transport "
                         "and the mid-stream kill becomes a real SIGKILL")
    args = ap.parse_args(argv)
    if args.graph and args.overload:
        report = run_overload_graph_serving(queue_bound=args.queue_bound,
                                            k=args.k)
        for key, val in report.items():
            print(f"  {key}: {val}")
        return 0
    if args.graph and args.replicas > 1:
        stats = run_replicated_graph_serving(
            replicas=args.replicas,
            kill_after=args.kill_after if args.kill_after >= 0 else None,
            requests=args.requests, k=args.k,
            transport=args.transport,
        )
        for row in stats.pop("replica_table"):
            print(f"  replica {row['replica']}: state={row['state']} "
                  f"beats={row['beats']} jobs={row['jobs_completed']} "
                  f"failovers_from={row['failovers_from']} "
                  f"p99_ms={row['p99_ms']:.1f}")
        for r in stats.pop("per_request"):
            print(f"  req: {r['latency_ms']:8.2f}ms cache_hit={r['cache_hit']} "
                  f"stale={r['stale']}")
        for key, val in stats.items():
            print(f"  {key}: {val}")
        return 0
    if args.graph and args.batched:
        stats = run_batched_graph_serving(
            clients=args.clients, graphs=args.graphs,
            max_batch=args.max_batch, max_wait_ms=args.max_wait_ms,
        )
        for key, val in stats.items():
            print(f"  {key}: {val}")
        return 0
    if args.graph and args.tenants > 1:
        report = run_multitenant_graph_serving(
            tenants=args.tenants, cache_budget_mb=args.cache_budget_mb,
            workers=args.workers, k=args.k,
        )
        for name, row in report["tenants"].items():
            print(f"  {name}: {row}")
        m = report["metrics"]
        print(f"  scheduler: workers={m['workers']} "
              f"utilization={m['utilization']:.2f} "
              f"completed={m['jobs_completed']} coalesced={m['coalesced']} "
              f"p99_latency_s={m['latency_s'].get('p99', 0.0):.4f}")
        return 0
    if args.graph:
        stats = run_graph_serving(requests=args.requests, churn=args.churn, k=args.k)
        for key, val in stats.items():
            print(f"  {key}: {val}")
        return 0
    if not args.arch:
        ap.error("--arch is required unless --graph is given")
    tokens, stats = run_serving(
        args.arch, batch=args.batch, prompt_len=args.prompt_len,
        gen=args.gen, reduced=args.reduced,
    )
    print(f"generated {tokens.shape} tokens; {stats}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())

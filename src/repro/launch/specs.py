"""Abstract input specs for every (arch x shape) cell.

``input_specs`` returns jax.ShapeDtypeStruct stand-ins for every model
input — weak-type-correct, shardable, zero allocation — the dry-run lowers
against these.  The same functions drive the real launchers (which replace
the structs with pipeline arrays of identical shape/dtype).
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from ..configs import ArchConfig, ShapeConfig
from ..models import Model

__all__ = ["train_input_specs", "prefill_input_specs", "decode_input_specs", "activation_dtype"]


def activation_dtype(cfg: ArchConfig):
    return jnp.bfloat16 if cfg.param_dtype == "bfloat16" else jnp.float32


def train_input_specs(cfg: ArchConfig, shape: ShapeConfig) -> dict:
    b, s = shape.global_batch, shape.seq_len
    sds = jax.ShapeDtypeStruct
    batch: dict[str, Any] = {"labels": sds((b, s), jnp.int32)}
    if cfg.frontend:
        batch["embeds"] = sds((b, s, cfg.d_model), activation_dtype(cfg))
        if cfg.mrope:
            batch["positions3"] = sds((3, b, s), jnp.int32)
    else:
        batch["tokens"] = sds((b, s), jnp.int32)
    if cfg.family == "encdec":
        batch["enc_embeds"] = sds((b, s, cfg.d_model), activation_dtype(cfg))
        batch["tokens"] = sds((b, s), jnp.int32)
    return batch


def prefill_input_specs(cfg: ArchConfig, shape: ShapeConfig) -> dict:
    batch = train_input_specs(cfg, shape)
    batch.pop("labels")
    return batch


def decode_input_specs(cfg: ArchConfig, shape: ShapeConfig) -> dict:
    """Decode = ONE new token against a cache of seq_len."""
    b, s = shape.global_batch, shape.seq_len
    sds = jax.ShapeDtypeStruct
    model = Model(cfg)
    enc_len = s if cfg.family == "encdec" else 0
    cache = jax.eval_shape(lambda: model.init_cache(b, s, enc_len))
    return {
        "cache": cache,
        "tokens": sds((b, 1), jnp.int32),
        "pos": sds((), jnp.int32),
    }

"""Replica worker: subprocess entrypoint hosting one ``PartitionService``.

``python -m repro.launch.replica_worker --port 0`` starts a service behind
``core.transport.PlanServer`` on a loopback TCP port and announces itself
on stdout as::

    REPLICA_WORKER_READY port=<port> pid=<pid>

so a parent can bind ``port 0`` without races.  The worker exits when it
receives the ``close`` RPC, or — with ``--parent-watch`` (default) — when
its stdin reaches EOF, which is how an abruptly dead parent reaps its
children without a supervisor.

Deterministic chaos needs stragglers *inside* the worker process (the
group's ``pre_job_hook`` cannot cross the process boundary), so
``--stall DELAY:FIRST:LAST`` installs a dispatch-order stall schedule
matching ``FaultInjector.stall_jobs`` semantics: jobs ``FIRST..LAST``
(0-based) sleep ``DELAY`` seconds before executing.

:func:`spawn_worker` / :func:`spawn_process_group` are the parent-side
helpers: spawn N workers, wrap each in a ``RemoteReplica``, and hand the
set to ``ReplicaGroup`` — the ``--transport=process`` path of
``launch.serve`` and the kill -9 scenario in ``benchmarks/svc_chaos.py``.
"""
from __future__ import annotations

import argparse
import dataclasses
import os
import select
import subprocess
import sys
import threading
import time
from typing import Optional, Sequence

_READY_TAG = "REPLICA_WORKER_READY"


def _parse_stall(spec: str) -> tuple[float, int, int]:
    """``DELAY:FIRST:LAST`` -> (delay_s, first, last); LAST may be ``inf``."""
    parts = spec.split(":")
    if len(parts) != 3:
        raise argparse.ArgumentTypeError(
            f"--stall wants DELAY:FIRST:LAST, got {spec!r}")
    delay = float(parts[0])
    first = int(parts[1])
    last = (1 << 30) if parts[2] in ("inf", "") else int(parts[2])
    return delay, first, last


def _make_stall_hook(stalls: Sequence[tuple[float, int, int]]):
    lock = threading.Lock()
    counter = [0]

    def hook(_key) -> None:
        with lock:
            i = counter[0]
            counter[0] = i + 1
        for delay, first, last in stalls:
            if first <= i <= last:
                time.sleep(delay)
                return
    return hook


def main(argv: Optional[Sequence[str]] = None) -> int:
    p = argparse.ArgumentParser(
        description="Socket-backed PartitionService replica worker")
    p.add_argument("--host", default="127.0.0.1")
    p.add_argument("--port", type=int, default=0,
                   help="0 binds an ephemeral port (announced on stdout)")
    p.add_argument("--workers", type=int, default=1)
    p.add_argument("--executor", choices=["thread", "process"], default="thread")
    p.add_argument("--max-entries", type=int, default=64)
    p.add_argument("--queue-bound", type=int, default=None,
                   help="bounded admission: max scheduler queue depth "
                        "(default unbounded); over-share submits answer "
                        "AdmissionRejectedError frames over the wire")
    p.add_argument("--persist-path", default=None)
    p.add_argument("--stall", action="append", type=_parse_stall, default=[],
                   metavar="DELAY:FIRST:LAST",
                   help="straggler schedule for this worker's jobs "
                        "(repeatable; FaultInjector.stall_jobs semantics)")
    p.add_argument("--no-parent-watch", dest="parent_watch",
                   action="store_false", default=True,
                   help="do not exit when stdin reaches EOF")
    args = p.parse_args(argv)

    # Deferred: the parent only pays the jax import inside the child.
    from repro.core.partition_service import PartitionService
    from repro.core.transport import PlanServer

    svc = PartitionService(workers=args.workers, executor=args.executor,
                           max_entries=args.max_entries,
                           persist_path=args.persist_path,
                           max_queue_depth=args.queue_bound)
    if args.stall:
        svc.scheduler.pre_job_hook = _make_stall_hook(args.stall)
    server = PlanServer(svc, host=args.host, port=args.port)
    print(f"{_READY_TAG} port={server.port} pid={os.getpid()}", flush=True)

    if args.parent_watch:
        def watch() -> None:
            try:
                sys.stdin.buffer.read()
            except Exception:
                pass
            os._exit(0)
        threading.Thread(target=watch, name="parent-watch",
                         daemon=True).start()

    server.serve_forever()
    svc.close()
    if args.parent_watch:
        # The watch thread is blocked inside stdin's buffered read and
        # would deadlock interpreter finalization; a drained worker has
        # nothing left to flush, so leave without the shutdown dance.
        sys.stdout.flush()
        sys.stderr.flush()
        os._exit(0)
    return 0


# ---------------------------------------------------------------------------
# Parent-side spawn helpers
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class WorkerHandle:
    """A spawned replica worker: the process plus its announced endpoint."""

    proc: subprocess.Popen
    address: tuple[str, int]
    pid: int


def _src_root() -> str:
    import repro
    # repro is a namespace package (no __init__.py): locate it via __path__.
    return os.path.dirname(os.path.abspath(list(repro.__path__)[0]))


def spawn_worker(
    *,
    stalls: Sequence[tuple[float, int, int]] = (),
    workers: int = 1,
    executor: str = "thread",
    max_entries: int = 64,
    queue_bound: Optional[int] = None,
    persist_path: Optional[str] = None,
    host: str = "127.0.0.1",
    startup_timeout_s: float = 120.0,
    python: Optional[str] = None,
) -> WorkerHandle:
    """Start one worker subprocess and wait for its ready announcement."""
    cmd = [python or sys.executable, "-m", "repro.launch.replica_worker",
           "--host", host, "--port", "0",
           "--workers", str(workers), "--executor", executor,
           "--max-entries", str(max_entries)]
    if queue_bound is not None:
        cmd += ["--queue-bound", str(queue_bound)]
    if persist_path:
        cmd += ["--persist-path", persist_path]
    for delay, first, last in stalls:
        cmd += ["--stall", f"{delay}:{first}:{last}"]
    env = dict(os.environ)
    src = _src_root()
    env["PYTHONPATH"] = (src + os.pathsep + env["PYTHONPATH"]
                         if env.get("PYTHONPATH") else src)
    proc = subprocess.Popen(cmd, stdin=subprocess.PIPE,
                            stdout=subprocess.PIPE, env=env)
    deadline = time.monotonic() + startup_timeout_s
    line = ""
    try:
        while True:
            remaining = deadline - time.monotonic()
            if remaining <= 0:
                raise TimeoutError(
                    f"replica worker did not announce within "
                    f"{startup_timeout_s}s")
            if proc.poll() is not None:
                raise RuntimeError(
                    f"replica worker exited rc={proc.returncode} "
                    "before announcing")
            ready, _, _ = select.select([proc.stdout], [], [], min(remaining, 0.5))
            if not ready:
                continue
            line = proc.stdout.readline().decode("utf-8", "replace").strip()
            if line.startswith(_READY_TAG):
                break
            if not line:  # EOF without announcement
                raise RuntimeError("replica worker closed stdout "
                                   "before announcing")
    except BaseException:
        proc.kill()
        raise
    fields = dict(kv.split("=", 1) for kv in line.split()[1:])
    return WorkerHandle(proc=proc, address=(host, int(fields["port"])),
                        pid=int(fields["pid"]))


def spawn_process_group(
    n: int,
    *,
    stalls_per_replica: Optional[Sequence[Sequence[tuple[float, int, int]]]] = None,
    worker_kwargs: Optional[dict] = None,
    replica_kwargs: Optional[dict] = None,
    **group_kwargs,
):
    """Spawn ``n`` worker processes and wrap them in a ``ReplicaGroup``.

    Replica ``r{i}`` maps to worker ``i`` (the same ids the group assigns),
    so ``FaultInjector`` process-probe schedules address workers by the
    familiar ``r0``/``r1`` names.  Closing the group closes the remote
    services and reaps the worker processes."""
    from repro.core.replica import ReplicaGroup
    from repro.core.transport import RemoteReplica

    handles = []
    try:
        for i in range(n):
            stalls = (stalls_per_replica[i]
                      if stalls_per_replica is not None else ())
            handles.append(spawn_worker(stalls=stalls, **(worker_kwargs or {})))
    except BaseException:
        for h in handles:
            h.proc.kill()
        raise
    remotes = [RemoteReplica(h.address, process=h.proc, pid=h.pid,
                             **(replica_kwargs or {}))
               for h in handles]
    return ReplicaGroup(remotes, **group_kwargs)


if __name__ == "__main__":
    sys.exit(main())

"""Architecture + shape configuration system.

Every assigned architecture is a frozen ``ArchConfig``; ``reduced()`` yields
the CPU-smoke-test variant of the same family (small widths/few layers/tiny
vocab — the family-defining structure is preserved: GQA ratios, MoE top-k,
SSD grouping, hybrid interleave, enc-dec split).

``REGISTRY`` maps ``--arch <id>`` names to configs; ``SHAPES`` maps shape
names to ``ShapeConfig``.  ``cells()`` enumerates the assigned (arch × shape)
grid, honouring the spec'd skips (long_500k only for sub-quadratic archs).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

__all__ = [
    "MoESettings",
    "SSMSettings",
    "ArchConfig",
    "ShapeConfig",
    "SHAPES",
    "REGISTRY",
    "register",
    "get_config",
    "cells",
    "param_count",
]


@dataclasses.dataclass(frozen=True)
class MoESettings:
    n_experts: int
    top_k: int
    d_ff_expert: int
    n_shared_experts: int = 0
    every: int = 1          # MoE FFN at layers where (layer_idx % every == every - 1)
    capacity_factor: float = 1.25
    aux_loss_weight: float = 0.01


@dataclasses.dataclass(frozen=True)
class SSMSettings:
    d_state: int = 128
    expand: int = 2
    d_conv: int = 4
    head_dim: int = 64
    n_groups: int = 1
    chunk: int = 256


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                 # dense | moe | ssm | hybrid | encdec
    n_layers: int               # decoder layers
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_head: int
    d_ff: int                   # dense-MLP width (0 for pure-MoE / pure-SSM archs)
    vocab_size: int
    qk_norm: bool = False
    rope_theta: float = 1_000_000.0
    mrope: bool = False                       # qwen2-vl 3-section M-RoPE
    mrope_sections: tuple[int, ...] = (16, 24, 24)
    frontend: Optional[str] = None            # 'audio' | 'vision' -> embeds-in stub
    moe: Optional[MoESettings] = None
    ssm: Optional[SSMSettings] = None
    attn_every: int = 0         # hybrid: 1 attn layer per this many layers (0 = all attn)
    attn_offset: int = 4        # position of the attn layer inside the hybrid period
    n_encoder_layers: int = 0   # encdec only
    tie_embeddings: bool = False
    norm_eps: float = 1e-6
    param_dtype: str = "float32"      # master weights ('bfloat16' for the 398B config)
    compute_dtype: str = "bfloat16"   # activations/matmul dtype (mixed precision)
    # runtime knobs (shape-independent defaults; launchers may override)
    remat: bool = True
    attn_chunk_q: int = 512
    attn_chunk_kv: int = 1024
    loss_chunk: int = 512
    notes: str = ""

    @property
    def uses_attention(self) -> bool:
        return self.family != "ssm"

    @property
    def is_subquadratic(self) -> bool:
        """Eligible for long_500k (SSM / hybrid — decode state is O(1) or
        attention layers are 1-in-8)."""
        return self.family in ("ssm", "hybrid")

    def layer_kinds(self) -> list[str]:
        """Mixer kind per decoder layer: 'attn' or 'mamba'."""
        if self.family == "ssm":
            return ["mamba"] * self.n_layers
        if self.attn_every:
            return [
                "attn" if (i % self.attn_every) == self.attn_offset else "mamba"
                for i in range(self.n_layers)
            ]
        return ["attn"] * self.n_layers

    def ffn_kinds(self) -> list[str]:
        """FFN kind per decoder layer: 'moe', 'mlp' or 'none'."""
        if self.family == "ssm":
            return ["none"] * self.n_layers
        out = []
        for i in range(self.n_layers):
            if self.moe is not None and (i % self.moe.every) == (self.moe.every - 1):
                out.append("moe")
            else:
                out.append("mlp" if self.d_ff else "none")
        return out


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    kind: str          # 'train' | 'prefill' | 'decode'
    seq_len: int
    global_batch: int

    @property
    def tokens(self) -> int:
        return self.seq_len * self.global_batch


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeConfig("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeConfig("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeConfig("long_500k", "decode", 524288, 1),
}


REGISTRY: dict[str, Callable[[], ArchConfig]] = {}
_REDUCED: dict[str, Callable[[], ArchConfig]] = {}


def register(name: str, full: Callable[[], ArchConfig], reduced: Callable[[], ArchConfig]):
    REGISTRY[name] = full
    _REDUCED[name] = reduced


def get_config(name: str, reduced: bool = False) -> ArchConfig:
    # Import side-effect registration of all arch modules.
    from . import _register_all  # noqa: F401

    table = _REDUCED if reduced else REGISTRY
    if name not in table:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(table)}")
    cfg = table[name]()
    if reduced:
        # Smoke tests assert exact numerics: full-precision compute on CPU.
        cfg = dataclasses.replace(cfg, compute_dtype="float32")
    return cfg


def list_archs() -> list[str]:
    from . import _register_all  # noqa: F401

    return sorted(REGISTRY)


def cells(include_skips: bool = False) -> list[tuple[str, str, str]]:
    """The assigned (arch, shape, status) grid.

    status: 'run' or 'skip:<reason>'.  long_500k is skipped for pure
    full-attention archs per spec (recorded in DESIGN.md); no encoder-only
    archs are assigned, so decode shapes run everywhere.
    """
    from . import _register_all  # noqa: F401

    out = []
    for arch in sorted(REGISTRY):
        cfg = REGISTRY[arch]()
        for shape in SHAPES.values():
            if shape.name == "long_500k" and not cfg.is_subquadratic:
                if include_skips:
                    out.append((arch, shape.name, "skip:full-attention at 524k"))
                continue
            out.append((arch, shape.name, "run"))
    return out


# ---------------------------------------------------------------------------
# Parameter counting (used for MODEL_FLOPS = 6·N·D in the roofline)
# ---------------------------------------------------------------------------


def param_count(cfg: ArchConfig) -> dict:
    """Analytic parameter counts: total and active-per-token (MoE-aware)."""
    d, dh = cfg.d_model, cfg.d_head
    attn = d * (cfg.n_heads * dh) + 2 * d * (cfg.n_kv_heads * dh) + (cfg.n_heads * dh) * d
    if cfg.qk_norm:
        attn += 2 * dh
    mlp = 3 * d * cfg.d_ff if cfg.d_ff else 0

    moe_total = moe_active = router = shared = 0
    if cfg.moe:
        e = cfg.moe
        per_expert = 3 * d * e.d_ff_expert
        moe_total = e.n_experts * per_expert
        moe_active = e.top_k * per_expert
        router = d * e.n_experts
        if e.n_shared_experts:
            shared = 3 * d * (e.n_shared_experts * e.d_ff_expert) + d
        moe_total += router + shared
        moe_active += router + shared

    mamba = 0
    if cfg.ssm:
        s = cfg.ssm
        d_inner = s.expand * d
        h = d_inner // s.head_dim
        conv_dim = d_inner + 2 * s.n_groups * s.d_state
        d_in_proj = 2 * d_inner + 2 * s.n_groups * s.d_state + h
        mamba = (
            d * d_in_proj + s.d_conv * conv_dim + conv_dim
            + 3 * h + d_inner + d_inner * d
        )

    layer_kinds = cfg.layer_kinds()
    ffn_kinds = cfg.ffn_kinds()
    total = active = 0
    for lk, fk in zip(layer_kinds, ffn_kinds):
        mixer = attn if lk == "attn" else mamba
        norms = 2 * d
        if fk == "moe":
            total += mixer + moe_total + norms
            active += mixer + moe_active + norms
        elif fk == "mlp":
            total += mixer + mlp + norms
            active += mixer + mlp + norms
        else:
            total += mixer + d
            active += mixer + d

    # Encoder stack (dense attn + MLP, bidirectional) + decoder cross-attn.
    if cfg.n_encoder_layers:
        enc_layer = attn + mlp + 2 * d
        cross = attn + d
        total += cfg.n_encoder_layers * enc_layer + cfg.n_layers * cross
        active += cfg.n_encoder_layers * enc_layer + cfg.n_layers * cross

    embed = cfg.vocab_size * d
    head = 0 if cfg.tie_embeddings else cfg.vocab_size * d
    total += embed + head + d
    active += embed + head + d
    return {"total": total, "active": active}

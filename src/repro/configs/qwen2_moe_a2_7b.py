"""qwen2-moe-a2.7b [moe] — 4 shared + 60 routed top-4.
[hf:Qwen/Qwen1.5-MoE-A2.7B; hf]

24L d_model=2048 16H (GQA kv=16 = MHA) vocab=151936, MoE 60e top-4 with
expert d_ff=1408 plus 4 shared experts (implemented as one fused dense
SwiGLU of width 4x1408 with a sigmoid gate — mathematically identical to
the sum of 4 independent experts).
"""
from .base import ArchConfig, MoESettings, register


def full() -> ArchConfig:
    return ArchConfig(
        name="qwen2-moe-a2.7b",
        family="moe",
        n_layers=24,
        d_model=2048,
        n_heads=16,
        n_kv_heads=16,
        d_head=128,
        d_ff=0,
        vocab_size=151936,
        moe=MoESettings(
            n_experts=60, top_k=4, d_ff_expert=1408, n_shared_experts=4, every=1
        ),
        rope_theta=1_000_000.0,
    )


def reduced() -> ArchConfig:
    return ArchConfig(
        name="qwen2-moe-a2.7b",
        family="moe",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=4,
        d_head=16,
        d_ff=0,
        vocab_size=512,
        moe=MoESettings(n_experts=6, top_k=2, d_ff_expert=64, n_shared_experts=2, every=1),
        attn_chunk_q=16,
        attn_chunk_kv=16,
        loss_chunk=16,
    )


register("qwen2-moe-a2.7b", full, reduced)

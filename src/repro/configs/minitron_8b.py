"""minitron-8b [dense] — pruned Nemotron.  [arXiv:2407.14679; hf]

32L d_model=4096 32H (GQA kv=8) d_ff=16384 vocab=256000.
"""
from .base import ArchConfig, register


def full() -> ArchConfig:
    return ArchConfig(
        name="minitron-8b",
        family="dense",
        n_layers=32,
        d_model=4096,
        n_heads=32,
        n_kv_heads=8,
        d_head=128,
        d_ff=16384,
        vocab_size=256000,
        rope_theta=10_000.0,
    )


def reduced() -> ArchConfig:
    return ArchConfig(
        name="minitron-8b",
        family="dense",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        d_head=16,
        d_ff=128,
        vocab_size=512,
        rope_theta=10_000.0,
        attn_chunk_q=16,
        attn_chunk_kv=16,
        loss_chunk=16,
    )


register("minitron-8b", full, reduced)

"""Architecture & shape configs for the assigned (arch x shape) grid."""
from .base import (
    REGISTRY,
    SHAPES,
    ArchConfig,
    MoESettings,
    ShapeConfig,
    SSMSettings,
    cells,
    get_config,
    list_archs,
    param_count,
)

__all__ = [
    "REGISTRY",
    "SHAPES",
    "ArchConfig",
    "MoESettings",
    "ShapeConfig",
    "SSMSettings",
    "cells",
    "get_config",
    "list_archs",
    "param_count",
]

"""phi4-mini-3.8b [dense] — RoPE SwiGLU GQA.  [arXiv:2412.08905; hf]

32L d_model=3072 24H (GQA kv=8) d_ff=8192 vocab=200064.
"""
from .base import ArchConfig, register


def full() -> ArchConfig:
    return ArchConfig(
        name="phi4-mini-3.8b",
        family="dense",
        n_layers=32,
        d_model=3072,
        n_heads=24,
        n_kv_heads=8,
        d_head=128,
        d_ff=8192,
        vocab_size=200064,
        rope_theta=10_000.0,
        tie_embeddings=True,
    )


def reduced() -> ArchConfig:
    return ArchConfig(
        name="phi4-mini-3.8b",
        family="dense",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        d_head=16,
        d_ff=128,
        vocab_size=512,
        rope_theta=10_000.0,
        tie_embeddings=True,
        attn_chunk_q=16,
        attn_chunk_kv=16,
        loss_chunk=16,
    )


register("phi4-mini-3.8b", full, reduced)

"""jamba-1.5-large-398b [hybrid] — Mamba+attention 1:7 interleave, MoE 16e top-2.

72L d_model=8192 64H (GQA kv=8) d_ff=24576 vocab=65536  [arXiv:2403.19887; hf]
Period of 8 layers: 1 attention (position 4) + 7 Mamba2; MoE FFN every other
layer.  bf16 params (398B at fp32 master + fp32 Adam states would not fit
256 chips; see DESIGN.md §6).
"""
from .base import ArchConfig, MoESettings, SSMSettings, register


def full() -> ArchConfig:
    return ArchConfig(
        name="jamba-1.5-large-398b",
        family="hybrid",
        n_layers=72,
        d_model=8192,
        n_heads=64,
        n_kv_heads=8,
        d_head=128,
        d_ff=24576,
        vocab_size=65536,
        moe=MoESettings(n_experts=16, top_k=2, d_ff_expert=24576, every=2),
        ssm=SSMSettings(d_state=128, expand=2, d_conv=4, head_dim=64, n_groups=1, chunk=256),
        attn_every=8,
        attn_offset=4,
        param_dtype="bfloat16",
        notes="hybrid 1:7 attn:mamba interleave; MoE every other layer",
    )


def reduced() -> ArchConfig:
    return ArchConfig(
        name="jamba-1.5-large-398b",
        family="hybrid",
        n_layers=8,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        d_head=16,
        d_ff=128,
        vocab_size=512,
        moe=MoESettings(n_experts=4, top_k=2, d_ff_expert=128, every=2),
        ssm=SSMSettings(d_state=16, expand=2, d_conv=4, head_dim=32, n_groups=1, chunk=16),
        attn_every=4,
        attn_offset=2,
        attn_chunk_q=16,
        attn_chunk_kv=16,
        loss_chunk=16,
    )


register("jamba-1.5-large-398b", full, reduced)

"""Importing this module registers every assigned architecture."""
from . import (  # noqa: F401
    granite_3_8b,
    jamba_1_5_large_398b,
    mamba2_2_7b,
    minitron_8b,
    phi4_mini_3_8b,
    qwen2_moe_a2_7b,
    qwen2_vl_2b,
    qwen3_32b,
    qwen3_moe_30b_a3b,
    seamless_m4t_medium,
)

"""mamba2-2.7b [ssm] — SSD (state-space duality).  [arXiv:2405.21060; unverified]

64L d_model=2560 (attention-free) vocab=50280, ssm_state=128.
d_inner = 2*2560 = 5120, head_dim 64 -> 80 SSD heads, 1 state group.
Attention-free: no KV cache; decode carries (conv_state, ssm_state) only,
which is why this arch runs the long_500k cell.
"""
from .base import ArchConfig, SSMSettings, register


def full() -> ArchConfig:
    return ArchConfig(
        name="mamba2-2.7b",
        family="ssm",
        n_layers=64,
        d_model=2560,
        n_heads=0,
        n_kv_heads=0,
        d_head=0,
        d_ff=0,
        vocab_size=50280,
        ssm=SSMSettings(d_state=128, expand=2, d_conv=4, head_dim=64, n_groups=1, chunk=256),
        tie_embeddings=True,
    )


def reduced() -> ArchConfig:
    return ArchConfig(
        name="mamba2-2.7b",
        family="ssm",
        n_layers=2,
        d_model=64,
        n_heads=0,
        n_kv_heads=0,
        d_head=0,
        d_ff=0,
        vocab_size=512,
        ssm=SSMSettings(d_state=16, expand=2, d_conv=4, head_dim=32, n_groups=1, chunk=16),
        tie_embeddings=True,
        loss_chunk=16,
    )


register("mamba2-2.7b", full, reduced)

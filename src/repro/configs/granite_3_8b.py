"""granite-3-8b [dense] — GQA.  [hf:ibm-granite/granite-3.0 family; hf]

40L d_model=4096 32H (GQA kv=8) d_ff=12800 vocab=49155.
"""
from .base import ArchConfig, register


def full() -> ArchConfig:
    return ArchConfig(
        name="granite-3-8b",
        family="dense",
        n_layers=40,
        d_model=4096,
        n_heads=32,
        n_kv_heads=8,
        d_head=128,
        d_ff=12800,
        vocab_size=49155,
        rope_theta=10_000.0,
        tie_embeddings=True,
    )


def reduced() -> ArchConfig:
    return ArchConfig(
        name="granite-3-8b",
        family="dense",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        d_head=16,
        d_ff=128,
        vocab_size=512,
        rope_theta=10_000.0,
        tie_embeddings=True,
        attn_chunk_q=16,
        attn_chunk_kv=16,
        loss_chunk=16,
    )


register("granite-3-8b", full, reduced)

"""seamless-m4t-medium [audio] — encoder-decoder multimodal backbone.

12L d_model=1024 16H (GQA kv=16 = MHA) d_ff=4096 vocab=256206
[arXiv:2308.11596; hf].  12 encoder + 12 decoder layers (the spec's "12L"
names the per-stack depth of the medium text model).  The audio frontend is
a STUB per the task spec: input_specs() supplies precomputed frame
embeddings (B, S, D) to the encoder; the decoder consumes token ids.
"""
from .base import ArchConfig, register


def full() -> ArchConfig:
    return ArchConfig(
        name="seamless-m4t-medium",
        family="encdec",
        n_layers=12,
        n_encoder_layers=12,
        d_model=1024,
        n_heads=16,
        n_kv_heads=16,
        d_head=64,
        d_ff=4096,
        vocab_size=256206,
        frontend="audio",
        rope_theta=10_000.0,
        notes="enc-dec; audio frontend stubbed with precomputed frame embeddings",
    )


def reduced() -> ArchConfig:
    return ArchConfig(
        name="seamless-m4t-medium",
        family="encdec",
        n_layers=2,
        n_encoder_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=4,
        d_head=16,
        d_ff=128,
        vocab_size=512,
        frontend="audio",
        rope_theta=10_000.0,
        attn_chunk_q=16,
        attn_chunk_kv=16,
        loss_chunk=16,
    )


register("seamless-m4t-medium", full, reduced)

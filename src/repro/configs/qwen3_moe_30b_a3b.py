"""qwen3-moe-30b-a3b [moe] — 128 experts top-8.  [hf:Qwen/Qwen3-30B-A3B; hf]

48L d_model=2048 32H (GQA kv=4) vocab=151936, MoE 128e top-8 with expert
d_ff=768; every layer is MoE (no dense FFN).  head_dim=128, qk_norm (qwen3).
This is the PRIMARY attachment point of the paper's technique: EP-scheduled
expert placement + dispatch (core/moe_schedule.py) minimizes the biggest
all-to-all in the fleet.
"""
from .base import ArchConfig, MoESettings, register


def full() -> ArchConfig:
    return ArchConfig(
        name="qwen3-moe-30b-a3b",
        family="moe",
        n_layers=48,
        d_model=2048,
        n_heads=32,
        n_kv_heads=4,
        d_head=128,
        d_ff=0,  # pure MoE FFN
        vocab_size=151936,
        qk_norm=True,
        moe=MoESettings(n_experts=128, top_k=8, d_ff_expert=768, every=1),
        rope_theta=1_000_000.0,
    )


def reduced() -> ArchConfig:
    return ArchConfig(
        name="qwen3-moe-30b-a3b",
        family="moe",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        d_head=16,
        d_ff=0,
        vocab_size=512,
        qk_norm=True,
        moe=MoESettings(n_experts=8, top_k=2, d_ff_expert=64, every=1),
        attn_chunk_q=16,
        attn_chunk_kv=16,
        loss_chunk=16,
    )


register("qwen3-moe-30b-a3b", full, reduced)

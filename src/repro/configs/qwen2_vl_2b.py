"""qwen2-vl-2b [vlm] — M-RoPE, dynamic resolution.  [arXiv:2409.12191; hf]

28L d_model=1536 12H (GQA kv=2) d_ff=8960 vocab=151936.  The vision
frontend is a STUB per the task spec: input_specs() supplies precomputed
patch embeddings (B, S, D) plus the 3-stream (t, h, w) M-RoPE position ids.
"""
from .base import ArchConfig, register


def full() -> ArchConfig:
    return ArchConfig(
        name="qwen2-vl-2b",
        family="dense",
        n_layers=28,
        d_model=1536,
        n_heads=12,
        n_kv_heads=2,
        d_head=128,
        d_ff=8960,
        vocab_size=151936,
        mrope=True,
        mrope_sections=(16, 24, 24),
        frontend="vision",
        rope_theta=1_000_000.0,
        tie_embeddings=True,
    )


def reduced() -> ArchConfig:
    return ArchConfig(
        name="qwen2-vl-2b",
        family="dense",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        d_head=16,
        d_ff=128,
        vocab_size=512,
        mrope=True,
        mrope_sections=(2, 3, 3),
        frontend="vision",
        tie_embeddings=True,
        attn_chunk_q=16,
        attn_chunk_kv=16,
        loss_chunk=16,
    )


register("qwen2-vl-2b", full, reduced)

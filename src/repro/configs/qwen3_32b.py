"""qwen3-32b [dense] — qk_norm, GQA.  [hf:Qwen/Qwen3-8B family; hf]

64L d_model=5120 64H (GQA kv=8) d_ff=25600 vocab=151936; head_dim=128
(attention width 8192 > d_model, faithful to the HF config), per-head
RMSNorm on q/k.
"""
from .base import ArchConfig, register


def full() -> ArchConfig:
    return ArchConfig(
        name="qwen3-32b",
        family="dense",
        n_layers=64,
        d_model=5120,
        n_heads=64,
        n_kv_heads=8,
        d_head=128,
        d_ff=25600,
        vocab_size=151936,
        qk_norm=True,
        rope_theta=1_000_000.0,
    )


def reduced() -> ArchConfig:
    return ArchConfig(
        name="qwen3-32b",
        family="dense",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        d_head=32,  # attention width 128 > d_model 64, like the full config
        d_ff=128,
        vocab_size=512,
        qk_norm=True,
        attn_chunk_q=16,
        attn_chunk_kv=16,
        loss_chunk=16,
    )


register("qwen3-32b", full, reduced)

"""Pallas TPU kernels for the paper's compute hot-spots.

The paper's optimized kernel is SpMV with cluster-local caching (§5.2);
``ep_spmv`` is its TPU-native form.  ``moe_mlp`` is the grouped expert FFN
fed by EP-scheduled MoE dispatch (the technique's application to the
assigned MoE architectures).  Pure-jnp oracles live in ``ref.py``; kernels
are validated in interpret mode on CPU and target TPU via Mosaic.

The model zoo / dry-run path stays pure JAX: Mosaic custom calls neither
compile on the CPU backend nor contribute FLOPs to ``cost_analysis()``,
so kernels are an opt-in fast path, not a lowering dependency.
"""
from .ep_spmv import spmv_software_cache, spmv_streaming, spmv_streaming_batched
from .flash_attention import flash_attention
from .ops import (
    BucketSpec,
    ep_spmv,
    make_bucketed_spmv_fn,
    make_ep_spmv_fn,
    moe_mlp,
    pad_plan_operands,
    resolve_plan,
    spmv_hbm_traffic_model,
)

__all__ = [
    "BucketSpec",
    "ep_spmv",
    "flash_attention",
    "make_bucketed_spmv_fn",
    "make_ep_spmv_fn",
    "moe_mlp",
    "pad_plan_operands",
    "resolve_plan",
    "spmv_hbm_traffic_model",
    "spmv_software_cache",
    "spmv_streaming",
    "spmv_streaming_batched",
]

"""Pure-jnp oracles for every kernel in this package.

Tests sweep shapes/dtypes and ``assert_allclose`` the Pallas kernels
(interpret mode on CPU) against these references.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["spmv_coo_ref", "moe_mlp_ref", "flash_attention_ref"]


def spmv_coo_ref(
    n_rows: int, rows: jax.Array, cols: jax.Array, vals: jax.Array, x: jax.Array
) -> jax.Array:
    """y = A @ x for COO A, the semantics every SpMV variant must match."""
    return jnp.zeros(n_rows, dtype=vals.dtype).at[rows].add(vals * x[cols])


def flash_attention_ref(
    q: jax.Array, k: jax.Array, v: jax.Array, causal: bool = True
) -> jax.Array:
    """Naive softmax attention over (B, H, S|T, D); the flash oracle."""
    dh = q.shape[-1]
    s = jnp.einsum("bhsd,bhtd->bhst", q, k).astype(jnp.float32) / jnp.sqrt(dh)
    if causal:
        ii = jnp.arange(q.shape[2])[:, None]
        jj = jnp.arange(k.shape[2])[None, :]
        s = jnp.where(ii >= jj, s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhst,bhtd->bhsd", p.astype(v.dtype), v).astype(q.dtype)


def moe_mlp_ref(
    x_packed: jax.Array, w_gate: jax.Array, w_up: jax.Array, w_down: jax.Array
) -> jax.Array:
    """Per-expert SwiGLU FFN over packed slabs (batched einsum)."""
    gate = jnp.einsum("ecd,edf->ecf", x_packed, w_gate).astype(jnp.float32)
    up = jnp.einsum("ecd,edf->ecf", x_packed, w_up).astype(jnp.float32)
    h = jax.nn.silu(gate) * up
    out = jnp.einsum("ecf,efd->ecd", h, w_down.astype(jnp.float32))
    return out.astype(x_packed.dtype)

"""Flash attention Pallas TPU kernel (prefill/training building block).

Tiling: grid = (B·H, S/q_block); each cell owns one q tile in VMEM and
streams the K/V tiles for its (batch, head) through an in-kernel fori_loop
with the classic online-softmax recurrence.  Causal cells stop the loop at
the diagonal (≈2x fewer K/V tiles touched than a masked full sweep — the
same waste the pure-JAX layer pays; this kernel is the TPU fix).

VMEM budget per cell: q_block·D + 2·T·D floats (+ (q_block, kv_chunk)
scores).  At D=128, T=8192, q_block=256, kv_chunk=512: ~8.5 MB — inside a
v5e's ~16 MB VMEM.  Longer T wants a kv-grid axis with accumulator
scratch; documented as the scale-out variant, not needed for validation.

MXU alignment: q_block multiple of 8, D and kv_chunk multiples of 128
(enforced), f32 accumulation via preferred_element_type.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

__all__ = ["flash_attention"]


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, *, causal, kv_chunk, q_block):
    qi = pl.program_id(1)
    q = q_ref[0]  # (qb, D) VMEM tile
    t = k_ref.shape[1]
    dh = q.shape[-1]
    scale = 1.0 / np.sqrt(dh)
    nk_total = t // kv_chunk
    if causal:
        # Only tiles up to the diagonal contribute.
        last = (qi + 1) * q_block  # exclusive q end
        nk = (last + kv_chunk - 1) // kv_chunk
        nk = jnp.minimum(nk, nk_total)
    else:
        nk = nk_total

    def body(i, carry):
        m_prev, l_prev, acc = carry
        k = k_ref[0, pl.ds(i * kv_chunk, kv_chunk), :]  # (kc, D)
        v = v_ref[0, pl.ds(i * kv_chunk, kv_chunk), :]
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) * scale  # (qb, kc)
        if causal:
            qpos = qi * q_block + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
            kpos = i * kv_chunk + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
            s = jnp.where(qpos >= kpos, s, -jnp.inf)
        m_cur = jnp.max(s, axis=-1)
        m_new = jnp.maximum(m_prev, m_cur)
        safe_m = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
        p = jnp.exp(s - safe_m[:, None])
        p = jnp.where(jnp.isfinite(s), p, 0.0)
        alpha = jnp.where(jnp.isfinite(m_prev), jnp.exp(m_prev - safe_m), 0.0)
        l_new = l_prev * alpha + p.sum(axis=-1)
        pv = jax.lax.dot_general(
            p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        acc_new = acc * alpha[:, None] + pv
        return m_new, l_new, acc_new

    m0 = jnp.full((q_block,), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((q_block,), jnp.float32)
    a0 = jnp.zeros((q_block, dh), jnp.float32)
    m, l, acc = jax.lax.fori_loop(0, nk, body, (m0, l0, a0))
    l = jnp.maximum(l, 1e-20)
    o_ref[0] = (acc / l[:, None]).astype(o_ref.dtype)


def flash_attention(
    q: jax.Array,  # (B, H, S, D)
    k: jax.Array,  # (B, H, T, D) — already head-repeated for GQA
    v: jax.Array,  # (B, H, T, D)
    *,
    causal: bool = True,
    q_block: int = 128,
    kv_chunk: int = 128,
    interpret: bool = True,
) -> jax.Array:
    b, h, s, dh = q.shape
    t = k.shape[2]
    q_block = min(q_block, s)
    kv_chunk = min(kv_chunk, t)
    assert s % q_block == 0 and t % kv_chunk == 0, (s, q_block, t, kv_chunk)
    bh = b * h
    qf = q.reshape(bh, s, dh)
    kf = k.reshape(bh, t, dh)
    vf = v.reshape(bh, t, dh)
    grid = (bh, s // q_block)
    kernel = functools.partial(
        _flash_kernel, causal=causal, kv_chunk=kv_chunk, q_block=q_block
    )
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, q_block, dh), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, t, dh), lambda i, j: (i, 0, 0)),
            pl.BlockSpec((1, t, dh), lambda i, j: (i, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, q_block, dh), lambda i, j: (i, j, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, s, dh), q.dtype),
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(b, h, s, dh)

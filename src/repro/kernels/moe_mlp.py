"""Grouped expert-MLP Pallas kernel for EP-scheduled MoE dispatch.

The EP model's MoE application (DESIGN.md §3.2): routed (token, expert)
pairs are tasks; the EP scheduler packs each expert's tokens into a padded
capacity slab.  This kernel consumes the packed slabs: grid cell (e, t)
computes the SwiGLU expert FFN for token tile t of expert e, with the
expert's weights staged in VMEM for the duration of its row of tiles —
VMEM reuse of weights across a tile row is the cache-domain structure the
paper builds for x in SpMV, applied to the expert weights (the hot shared
data object of a MoE layer).

Blocking: token tiles of ``tm`` rows (multiple of 8); d_model and d_ff kept
whole per block (MoE expert d_ff in the assigned archs is small: 768/1408),
rounded up to 128 by the caller.  MXU dims (tm × d_model × d_ff) are
hardware-aligned multiples of (8, 128, 128).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["moe_mlp"]


def _moe_mlp_kernel(x_ref, wg_ref, wu_ref, wd_ref, out_ref):
    x = x_ref[0]      # (tm, d_model) token tile of expert e
    wg = wg_ref[0]    # (d_model, d_ff) gate weights, staged in VMEM
    wu = wu_ref[0]    # (d_model, d_ff)
    wd = wd_ref[0]    # (d_ff, d_model)
    gate = jnp.dot(x, wg, preferred_element_type=jnp.float32)
    up = jnp.dot(x, wu, preferred_element_type=jnp.float32)
    h = jax.nn.silu(gate) * up
    out_ref[0] = jnp.dot(h, wd, preferred_element_type=jnp.float32).astype(out_ref.dtype)


def moe_mlp(
    x_packed: jax.Array,  # (n_experts, capacity, d_model) packed token slabs
    w_gate: jax.Array,    # (n_experts, d_model, d_ff)
    w_up: jax.Array,      # (n_experts, d_model, d_ff)
    w_down: jax.Array,    # (n_experts, d_ff, d_model)
    *,
    tm: int = 128,
    interpret: bool = True,
) -> jax.Array:
    """SwiGLU expert FFN over packed per-expert token tiles."""
    n_experts, capacity, d_model = x_packed.shape
    d_ff = w_gate.shape[-1]
    if capacity % tm:
        raise ValueError(f"capacity {capacity} must be a multiple of tm {tm}")
    grid = (n_experts, capacity // tm)
    return pl.pallas_call(
        _moe_mlp_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, tm, d_model), lambda e, t: (e, t, 0)),
            # Expert weights: same block for every t -> stays resident in
            # VMEM across the expert's whole tile row (weight reuse).
            pl.BlockSpec((1, d_model, d_ff), lambda e, t: (e, 0, 0)),
            pl.BlockSpec((1, d_model, d_ff), lambda e, t: (e, 0, 0)),
            pl.BlockSpec((1, d_ff, d_model), lambda e, t: (e, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, tm, d_model), lambda e, t: (e, t, 0)),
        out_shape=jax.ShapeDtypeStruct(x_packed.shape, x_packed.dtype),
        interpret=interpret,
    )(x_packed, w_gate, w_up, w_down)

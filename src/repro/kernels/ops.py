"""Jit'd public wrappers around the Pallas kernels.

``ep_spmv`` is the end-to-end EP-scheduled SpMV: it closes over a host-side
``PackPlan`` (static) and runs pack → kernel → combine:

  1. *pack*    — gather ``x`` into per-cluster contiguous tiles (the cpack
                 ``opt_arrayA`` rewrite; this gather's size is exactly
                 ``n_touched + C(x)``, the model's traffic count);
  2. *kernel*  — per-cluster partial products in VMEM;
  3. *combine* — scatter-add partial y tiles into the global y (cut rows
                 are summed here).

``mode="software"`` stages x tiles in VMEM (shared-memory analogue);
``mode="streaming"`` gathers from the full x inside the kernel (texture
analogue, skips step 1's relayout).
"""
from __future__ import annotations

import functools
from typing import Literal

import jax
import jax.numpy as jnp
import numpy as np

from ..core.reorder import PackPlan
from . import ep_spmv as _spmv
from . import moe_mlp as _moe

__all__ = ["ep_spmv", "make_ep_spmv_fn", "moe_mlp", "resolve_plan", "spmv_hbm_traffic_model"]


def resolve_plan(plan, timeout: float | None = None) -> PackPlan:
    """Accept a PackPlan, a ServicePlan, or a PlanTicket (async service).

    Tickets block until a pool worker publishes (paper §4.2's handoff) —
    ``timeout`` bounds that wait, and a ticket cancelled while queued
    raises ``PlanCancelledError`` here; ServicePlans must have been
    requested with COO metadata so a PackPlan was built alongside the
    labels.
    """
    if hasattr(plan, "result") and callable(plan.result):  # PlanTicket
        plan = plan.result(timeout)
    inner = getattr(plan, "plan", None)  # ServicePlan
    if inner is not None:
        plan = inner
    if not isinstance(plan, PackPlan):
        raise TypeError(
            "expected a PackPlan, a ServicePlan with a PackPlan (request via "
            "get_spmv_plan/coo=...), or a PlanTicket resolving to one; got "
            f"{type(plan).__name__}"
        )
    return plan


def make_ep_spmv_fn(
    plan: PackPlan,
    vals: np.ndarray,
    mode: Literal["software", "streaming"] = "software",
    interpret: bool = True,
    timeout: float | None = None,
):
    """Bind a PackPlan + matrix values; return jit'd ``x -> y``.

    ``plan`` may be a host-side PackPlan or a service-supplied handle
    (ServicePlan / PlanTicket from ``core.PartitionService``) — the async
    ticket is resolved here (``timeout`` bounds the wait on a still-queued
    ticket), so callers can submit partitioning early, at whatever tenant/
    priority the service request carried, and bind the kernel when the
    plan lands.

    The plan and packed indices are host-side constants (they change only
    when the matrix/partition changes — per paper §4 the relayout happens
    once, asynchronously); the returned function is the steady-state kernel
    the accelerator runs every iteration.
    """
    plan = resolve_plan(plan, timeout)
    vals_packed = jnp.asarray(plan.pack_values(np.asarray(vals)))
    x_lidx = jnp.asarray(plan.x_lidx)
    y_lidx = jnp.asarray(plan.y_lidx)
    x_gidx = jnp.asarray(plan.x_gidx)          # (k, X_max)
    y_gidx = jnp.asarray(plan.y_gidx)          # (k, Y_max), n_rows = sentinel
    n_rows, y_max = plan.n_rows, plan.y_max

    if mode == "software":

        @jax.jit
        def run(x):
            x_packed = jnp.take(x, x_gidx, axis=0)  # pack: n_touched + C loads
            partials = _spmv.spmv_software_cache(
                vals_packed, x_lidx, y_lidx, x_packed, y_max, interpret=interpret
            )
            y = jnp.zeros(n_rows + 1, dtype=partials.dtype)
            return y.at[y_gidx.reshape(-1)].add(partials.reshape(-1))[:n_rows]

    elif mode == "streaming":
        # Global x index per task = x_gidx[p, x_lidx[p, e]].
        xg_task = jnp.take_along_axis(x_gidx, x_lidx, axis=1)

        @jax.jit
        def run(x):
            partials = _spmv.spmv_streaming(
                vals_packed, xg_task, y_lidx, x, y_max, interpret=interpret
            )
            y = jnp.zeros(n_rows + 1, dtype=partials.dtype)
            return y.at[y_gidx.reshape(-1)].add(partials.reshape(-1))[:n_rows]

    else:
        raise ValueError(f"unknown mode {mode!r}")

    return run


def ep_spmv(
    x: jax.Array,
    plan: PackPlan,
    vals: np.ndarray,
    mode: Literal["software", "streaming"] = "software",
    interpret: bool = True,
) -> jax.Array:
    """One-shot convenience wrapper (rebinds the plan every call)."""
    return make_ep_spmv_fn(plan, vals, mode, interpret)(x)


def spmv_hbm_traffic_model(plan: PackPlan, mode: str = "software") -> dict:
    """Modeled off-chip loads (paper Fig. 11's transaction count).

    software: unique x + unique y entries per cluster (C is the redundancy);
    streaming: every task load goes through the implicit cache — best case
    equals software, worst case one load per task (cache thrashing).
    """
    unique_loads = int(plan.x_count.sum() + plan.y_count.sum())
    task_loads = int(plan.e_count.sum() * 2)
    return {
        "mode": mode,
        "unique_loads": unique_loads,
        "worst_case_loads": unique_loads if mode == "software" else task_loads,
    }


moe_mlp = functools.partial(_moe.moe_mlp)

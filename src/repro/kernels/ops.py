"""Jit'd public wrappers around the Pallas kernels.

``ep_spmv`` is the end-to-end EP-scheduled SpMV: it closes over a host-side
``PackPlan`` (static) and runs pack → kernel → combine:

  1. *pack*    — gather ``x`` into per-cluster contiguous tiles (the cpack
                 ``opt_arrayA`` rewrite; this gather's size is exactly
                 ``n_touched + C(x)``, the model's traffic count);
  2. *kernel*  — per-cluster partial products in VMEM;
  3. *combine* — scatter-add partial y tiles into the global y (cut rows
                 are summed here).

``mode="software"`` stages x tiles in VMEM (shared-memory analogue);
``mode="streaming"`` gathers from the full x inside the kernel (texture
analogue, skips step 1's relayout).

Two compilation contracts coexist:

* **Per-plan** (``make_ep_spmv_fn``) — the plan's padded indices are baked
  into the trace as constants; one compile per (structure, values).  Right
  for a few long-lived matrices, fatal for thousands of small ones.
* **Bucketed** (``BucketSpec`` + ``pad_plan_operands`` +
  ``make_bucketed_spmv_fn``) — the plan arrays are *arguments* of a kernel
  compiled once per shape bucket, so every request whose plan fits the
  bucket's padded ceilings reuses the same executable, micro-batched
  ``spec.batch`` requests at a time.  Tail slots are zero-filled
  (``vals == 0`` contributes nothing) and out-of-range rows land on the
  bucket's sentinel row, de-padded by the caller.

This module takes only host-side ``PackPlan``s (+ the padding spec);
scheduler handles (ServicePlan / PlanTicket) are resolved by the request
layer (``repro.runtime.request``) — the pass-through acceptance here is a
deprecated shim.
"""
from __future__ import annotations

import dataclasses
import functools
import warnings
from typing import Literal

import jax
import jax.numpy as jnp
import numpy as np

from ..core.reorder import PackPlan
from . import ep_spmv as _spmv
from . import moe_mlp as _moe

__all__ = [
    "BucketSpec",
    "ep_spmv",
    "make_bucketed_spmv_fn",
    "make_ep_spmv_fn",
    "moe_mlp",
    "pad_plan_operands",
    "resolve_plan",
    "spmv_hbm_traffic_model",
]


# ---------------------------------------------------------------------------
# Bucketed compilation: padded-shape contract
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class BucketSpec:
    """Padded-shape contract of one compiled bucket kernel.

    Every request served through a bucket arrives widened to these
    rectangular ceilings: the plan tiles to ``(k, e_max/x_max/y_max)``, the
    input vector to ``n_cols`` slots, the output to ``n_rows`` rows, and
    the micro-batch to exactly ``batch`` requests (unused slots are
    all-zero and provably contribute nothing).  Two requests with the same
    spec share one compiled executable — the spec IS the compile-cache key.
    """

    k: int
    n_rows: int  # row ceiling: y is produced at this length, de-padded by the caller
    n_cols: int  # column ceiling: x must arrive zero-padded to this length
    e_max: int
    x_max: int
    y_max: int
    batch: int  # fixed micro-batch width; short batches are zero-padded
    mode: str = "software"

    def fits(self, plan: PackPlan) -> bool:
        """True when ``plan``'s padded tiles fit inside this bucket."""
        return (
            plan.k == self.k
            and plan.n_rows <= self.n_rows
            and plan.n_cols <= self.n_cols
            and plan.e_max <= self.e_max
            and plan.x_max <= self.x_max
            and plan.y_max <= self.y_max
        )

    def operand_elems(self) -> int:
        """Total padded operand elements of one launch — the compile-cache
        size coordinate for (size, recency) eviction."""
        return self.batch * (
            self.k * (3 * self.e_max + self.x_max + self.y_max) + self.n_cols
        )


def pad_plan_operands(
    plan: PackPlan, vals: np.ndarray, spec: BucketSpec
) -> tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray, np.ndarray]:
    """Widen one plan + its matrix values into ``spec``'s rectangular tiles.

    Returns host-side ``(vals_packed, x_lidx, y_lidx, x_gidx, y_gidx)`` of
    shapes ``(k, E)``/``(k, E)``/``(k, E)``/``(k, X)``/``(k, Y)``.  The tail
    contract that makes one compiled kernel safe for every plan in the
    bucket:

    * task tail slots carry ``vals == 0`` with local indices 0, so they add
      exactly ``0.0`` to slot 0 of their tiles;
    * ``x_gidx`` tail slots gather ``x[0]`` into x-tile slots no task reads;
    * ``y_gidx`` tail slots — and the plan's own ``n_rows`` sentinel —
      are remapped to the *bucket* sentinel ``spec.n_rows``, the row the
      caller slices off, so zero-sum padding scatters never touch a real
      row of a smaller matrix.
    """
    if not spec.fits(plan):
        raise ValueError(
            f"plan (k={plan.k}, rows={plan.n_rows}, cols={plan.n_cols}, "
            f"tiles=({plan.e_max},{plan.x_max},{plan.y_max})) does not fit "
            f"bucket {spec}"
        )
    vals = np.asarray(vals)
    vp = np.zeros((spec.k, spec.e_max), dtype=vals.dtype)
    vp[:, : plan.e_max] = plan.pack_values(vals)
    xl = np.zeros((spec.k, spec.e_max), dtype=np.int32)
    xl[:, : plan.e_max] = plan.x_lidx
    yl = np.zeros((spec.k, spec.e_max), dtype=np.int32)
    yl[:, : plan.e_max] = plan.y_lidx
    xg = np.zeros((spec.k, spec.x_max), dtype=np.int32)
    xg[:, : plan.x_max] = plan.x_gidx
    yg = np.full((spec.k, spec.y_max), spec.n_rows, dtype=np.int32)
    yg[:, : plan.y_max] = np.where(plan.y_gidx == plan.n_rows, spec.n_rows, plan.y_gidx)
    return vp, xl, yl, xg, yg


def make_bucketed_spmv_fn(spec: BucketSpec, interpret: bool = True):
    """Compile-once kernel for a shape bucket: ``(plan arrays, x) -> y``.

    Unlike :func:`make_ep_spmv_fn`, nothing about the matrix is baked into
    the trace — the packed values and indices are *arguments*, so one
    compiled executable serves every (plan, values, x) whose shapes were
    widened to ``spec`` by :func:`pad_plan_operands`.  The returned jit'd
    function maps batch-leading operands

        ``vals (B,k,E) · x_lidx (B,k,E) · y_lidx (B,k,E) ·
        x_gidx (B,k,X) · y_gidx (B,k,Y) · x (B, n_cols)``

    to ``y (B, n_rows)`` — ``B == spec.batch`` always; callers zero-pad
    short micro-batches and de-pad each row to its request's true
    ``n_rows`` on the way out.
    """
    b, k = spec.batch, spec.k
    e_max, x_max, y_max = spec.e_max, spec.x_max, spec.y_max
    n_rows = spec.n_rows

    if spec.mode == "software":

        @jax.jit
        def run(vals, x_lidx, y_lidx, x_gidx, y_gidx, x):
            # pack: each request gathers its unique x entries (n_touched + C loads)
            x_packed = jax.vmap(lambda xg, xv: jnp.take(xv, xg, axis=0))(x_gidx, x)
            partials = _spmv.spmv_software_cache(
                vals.reshape(b * k, e_max),
                x_lidx.reshape(b * k, e_max),
                y_lidx.reshape(b * k, e_max),
                x_packed.reshape(b * k, x_max),
                y_max,
                interpret=interpret,
            ).reshape(b, k, y_max)
            return _combine(partials, y_gidx)

    elif spec.mode == "streaming":

        @jax.jit
        def run(vals, x_lidx, y_lidx, x_gidx, y_gidx, x):
            # Global x index per task = x_gidx[b, p, x_lidx[b, p, e]].
            xg_task = jnp.take_along_axis(x_gidx, x_lidx, axis=2)
            partials = _spmv.spmv_streaming_batched(
                vals, xg_task, y_lidx, x, y_max, interpret=interpret
            )
            return _combine(partials, y_gidx)

    else:
        raise ValueError(f"unknown mode {spec.mode!r}")

    def _combine(partials, y_gidx):
        # One flat scatter-add over the whole batch: request b's rows live
        # at offset b * (n_rows + 1); the sentinel row is sliced off.
        offs = (jnp.arange(b, dtype=y_gidx.dtype) * (n_rows + 1))[:, None, None]
        y = jnp.zeros(b * (n_rows + 1), dtype=partials.dtype)
        y = y.at[(y_gidx + offs).reshape(-1)].add(partials.reshape(-1))
        return y.reshape(b, n_rows + 1)[:, :n_rows]

    return run


# ---------------------------------------------------------------------------
# Per-plan compilation (+ deprecated scheduler-handle shims)
# ---------------------------------------------------------------------------


def resolve_plan(plan, timeout: float | None = None) -> PackPlan:
    """Deprecated alias: plan-kind resolution moved to the request layer.

    Use :func:`repro.runtime.request.resolve_plan` — the kernel layer takes
    only host-side ``PackPlan``s now, and unwrapping scheduler handles
    (ServicePlan / PlanTicket, with their timeout semantics) is a serving
    concern, not a kernel one.
    """
    warnings.warn(
        "repro.kernels.resolve_plan is deprecated; use "
        "repro.runtime.request.resolve_plan",
        DeprecationWarning,
        stacklevel=2,
    )
    from ..runtime.request import resolve_plan as _resolve  # lazy: layering

    return _resolve(plan, timeout)


def make_ep_spmv_fn(
    plan: PackPlan,
    vals: np.ndarray,
    mode: Literal["software", "streaming"] = "software",
    interpret: bool = True,
    timeout: float | None = None,
):
    """Bind a PackPlan + matrix values; return jit'd ``x -> y``.

    ``plan`` must be a host-side ``PackPlan``.  Passing a service-supplied
    handle (ServicePlan / PlanTicket) is deprecated: resolution lives in
    the request layer (``repro.runtime.request.resolve_plan`` /
    ``GraphServer``), which owns tenants, timeouts, and the compile cache —
    the shim below unwraps handles with a ``DeprecationWarning`` so old
    callers keep working.  The ``timeout`` kwarg only ever applied to that
    deprecated ticket wait and is deprecated with it.

    The plan and packed indices are host-side constants (they change only
    when the matrix/partition changes — per paper §4 the relayout happens
    once, asynchronously); the returned function is the steady-state kernel
    the accelerator runs every iteration.  For many small matrices, prefer
    the bucketed contract (:func:`make_bucketed_spmv_fn`): this per-plan
    form pays one fresh trace/compile per structure.
    """
    if timeout is not None:
        warnings.warn(
            "make_ep_spmv_fn(timeout=...) is deprecated: pass timeouts to "
            "the request layer (GraphRequest.timeout / resolve_plan)",
            DeprecationWarning,
            stacklevel=2,
        )
    if not isinstance(plan, PackPlan):
        warnings.warn(
            "passing a ServicePlan/PlanTicket to make_ep_spmv_fn is "
            "deprecated; resolve it first via "
            "repro.runtime.request.resolve_plan",
            DeprecationWarning,
            stacklevel=2,
        )
        from ..runtime.request import resolve_plan as _resolve  # lazy: layering

        plan = _resolve(plan, timeout)
    vals_packed = jnp.asarray(plan.pack_values(np.asarray(vals)))
    x_lidx = jnp.asarray(plan.x_lidx)
    y_lidx = jnp.asarray(plan.y_lidx)
    x_gidx = jnp.asarray(plan.x_gidx)          # (k, X_max)
    y_gidx = jnp.asarray(plan.y_gidx)          # (k, Y_max), n_rows = sentinel
    n_rows, y_max = plan.n_rows, plan.y_max

    if mode == "software":

        @jax.jit
        def run(x):
            x_packed = jnp.take(x, x_gidx, axis=0)  # pack: n_touched + C loads
            partials = _spmv.spmv_software_cache(
                vals_packed, x_lidx, y_lidx, x_packed, y_max, interpret=interpret
            )
            y = jnp.zeros(n_rows + 1, dtype=partials.dtype)
            return y.at[y_gidx.reshape(-1)].add(partials.reshape(-1))[:n_rows]

    elif mode == "streaming":
        # Global x index per task = x_gidx[p, x_lidx[p, e]].
        xg_task = jnp.take_along_axis(x_gidx, x_lidx, axis=1)

        @jax.jit
        def run(x):
            partials = _spmv.spmv_streaming(
                vals_packed, xg_task, y_lidx, x, y_max, interpret=interpret
            )
            y = jnp.zeros(n_rows + 1, dtype=partials.dtype)
            return y.at[y_gidx.reshape(-1)].add(partials.reshape(-1))[:n_rows]

    else:
        raise ValueError(f"unknown mode {mode!r}")

    return run


def ep_spmv(
    x: jax.Array,
    plan: PackPlan,
    vals: np.ndarray,
    mode: Literal["software", "streaming"] = "software",
    interpret: bool = True,
) -> jax.Array:
    """One-shot convenience wrapper (rebinds the plan every call)."""
    return make_ep_spmv_fn(plan, vals, mode, interpret)(x)


def spmv_hbm_traffic_model(plan: PackPlan, mode: str = "software") -> dict:
    """Modeled off-chip loads (paper Fig. 11's transaction count).

    software: unique x + unique y entries per cluster (C is the redundancy);
    streaming: every task load goes through the implicit cache — best case
    equals software, worst case one load per task (cache thrashing).
    """
    unique_loads = int(plan.x_count.sum() + plan.y_count.sum())
    task_loads = int(plan.e_count.sum() * 2)
    return {
        "mode": mode,
        "unique_loads": unique_loads,
        "worst_case_loads": unique_loads if mode == "software" else task_loads,
    }


moe_mlp = functools.partial(_moe.moe_mlp)

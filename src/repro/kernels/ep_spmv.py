"""EP-scheduled SpMV Pallas TPU kernel (paper §5.2, TPU-native).

The host-side edge partitioner (core.edge_partition) assigns every non-zero
(task) to one of k clusters; ``core.reorder.build_pack_plan`` packs each
cluster's tasks and the *unique* x/y entries it touches into padded,
128-aligned tiles (the cpack layout transformation of paper §4.1 — the
``opt_arrayA`` rewrite).  Each Pallas grid cell then plays the role of one
GPU thread block:

* **software-cache variant** (paper: shared memory / ``__shared__``):
  the cell's packed x tile is staged into VMEM *once*; every task reads x
  through a cheap VMEM-local index.  Off-chip traffic per cell = its unique
  x entries + unique y entries, so total HBM traffic = ``n_touched + C`` —
  the partition objective *is* the traffic count.

* **streaming variant** (paper: texture cache / ``tex1Dfetch``):
  no staging; every task gathers straight from the full x vector, relying
  on the implicit HBM→VMEM pipeline.  Same programmability/perf trade-off
  the paper studies in Fig. 12.

Both kernels emit per-cluster *partial* y tiles; the ops.py wrapper
scatter-adds them into the global y (cut output rows are combined there —
the analogue of the paper's per-block write-back; y is write-shared, which
is exactly why the paper cannot keep it in texture cache).

Grid cells map to TensorCores; tiles are padded to multiples of 128 so
gathers/scatters stay vector-lane aligned (the TPU substitute for GPU
memory coalescing).  VMEM working set per cell is
``PackPlan.vmem_bytes()``; the pack plan's ``pad`` parameter is the tile
knob swept by benchmarks/table3_block_size.py (the paper's thread-block
size study).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["spmv_software_cache", "spmv_streaming", "spmv_streaming_batched"]


def _smem_kernel(vals_ref, xl_ref, yl_ref, xt_ref, out_ref):
    """One grid cell = one task cluster with an explicit VMEM x tile."""
    vals = vals_ref[0, :]          # (E,) packed non-zeros of this cluster
    xl = xl_ref[0, :]              # (E,) local x slot per task
    yl = yl_ref[0, :]              # (E,) local y slot per task
    x_tile = xt_ref[0, :]          # (X,) staged unique x entries (the "software cache")
    contrib = vals * x_tile[xl]    # VMEM-local gather
    acc = jnp.zeros(out_ref.shape[1], dtype=vals.dtype)
    acc = acc.at[yl].add(contrib)  # VMEM-local scatter into the y tile
    out_ref[0, :] = acc


def _stream_kernel(vals_ref, xg_ref, yl_ref, x_ref, out_ref):
    """Streaming variant: tasks gather from the full x (implicit cache)."""
    vals = vals_ref[0, :]
    xg = xg_ref[0, :]              # (E,) GLOBAL x index per task
    yl = yl_ref[0, :]
    contrib = vals * x_ref[xg]     # gather from the un-staged vector
    acc = jnp.zeros(out_ref.shape[1], dtype=vals.dtype)
    acc = acc.at[yl].add(contrib)
    out_ref[0, :] = acc


def spmv_software_cache(
    vals: jax.Array,      # (k, E_max) packed non-zeros (0 in padding slots)
    x_lidx: jax.Array,    # (k, E_max) int32 local x slot per task
    y_lidx: jax.Array,    # (k, E_max) int32 local y slot per task
    x_packed: jax.Array,  # (k, X_max) packed unique x entries per cluster
    y_max: int,
    *,
    interpret: bool = True,
) -> jax.Array:
    """Per-cluster partial y tiles, shape (k, y_max)."""
    k, e_max = vals.shape
    x_max = x_packed.shape[1]
    spec_e = pl.BlockSpec((1, e_max), lambda p: (p, 0))
    spec_x = pl.BlockSpec((1, x_max), lambda p: (p, 0))
    spec_y = pl.BlockSpec((1, y_max), lambda p: (p, 0))
    return pl.pallas_call(
        _smem_kernel,
        grid=(k,),
        in_specs=[spec_e, spec_e, spec_e, spec_x],
        out_specs=spec_y,
        out_shape=jax.ShapeDtypeStruct((k, y_max), vals.dtype),
        interpret=interpret,
    )(vals, x_lidx, y_lidx, x_packed)


def spmv_streaming(
    vals: jax.Array,         # (k, E_max)
    x_gidx_task: jax.Array,  # (k, E_max) int32 GLOBAL x index per task
    y_lidx: jax.Array,       # (k, E_max)
    x: jax.Array,            # (n_cols,) full input vector, NOT staged
    y_max: int,
    *,
    interpret: bool = True,
) -> jax.Array:
    """Per-cluster partial y tiles, shape (k, y_max)."""
    k, e_max = vals.shape
    n_cols = x.shape[0]
    spec_e = pl.BlockSpec((1, e_max), lambda p: (p, 0))
    spec_full_x = pl.BlockSpec((n_cols,), lambda p: (0,))
    spec_y = pl.BlockSpec((1, y_max), lambda p: (p, 0))
    return pl.pallas_call(
        _stream_kernel,
        grid=(k,),
        in_specs=[spec_e, spec_e, spec_e, spec_full_x],
        out_specs=spec_y,
        out_shape=jax.ShapeDtypeStruct((k, y_max), vals.dtype),
        interpret=interpret,
    )(vals, x_gidx_task, y_lidx, x)


def _stream_kernel_batched(vals_ref, xg_ref, yl_ref, x_ref, out_ref):
    """One grid cell = (request b, cluster p); gathers from request b's x."""
    vals = vals_ref[0, 0, :]
    xg = xg_ref[0, 0, :]           # (E,) GLOBAL x index per task
    yl = yl_ref[0, 0, :]
    x_row = x_ref[0, :]            # request b's full (padded) x vector
    contrib = vals * x_row[xg]
    acc = jnp.zeros(out_ref.shape[2], dtype=vals.dtype)
    acc = acc.at[yl].add(contrib)
    out_ref[0, 0, :] = acc


def spmv_streaming_batched(
    vals: jax.Array,         # (B, k, E_max) packed non-zeros, 0 in padding
    x_gidx_task: jax.Array,  # (B, k, E_max) int32 GLOBAL x index per task
    y_lidx: jax.Array,       # (B, k, E_max)
    x: jax.Array,            # (B, n_cols) one full input vector per request
    y_max: int,
    *,
    interpret: bool = True,
) -> jax.Array:
    """Micro-batched streaming variant: B same-bucket requests, one launch.

    The grid is (B, k) — each cell still plays one GPU thread block, but a
    whole micro-batch of same-shape requests shares a single compiled
    kernel (the bucketed-compilation serve path).  Padding slots carry
    ``vals == 0`` so they contribute nothing; unused batch slots are
    all-zero rows.  Returns per-(request, cluster) partial y tiles,
    shape (B, k, y_max).
    """
    b, k, e_max = vals.shape
    n_cols = x.shape[1]
    spec_e = pl.BlockSpec((1, 1, e_max), lambda i, p: (i, p, 0))
    spec_x = pl.BlockSpec((1, n_cols), lambda i, p: (i, 0))
    spec_y = pl.BlockSpec((1, 1, y_max), lambda i, p: (i, p, 0))
    return pl.pallas_call(
        _stream_kernel_batched,
        grid=(b, k),
        in_specs=[spec_e, spec_e, spec_e, spec_x],
        out_specs=spec_y,
        out_shape=jax.ShapeDtypeStruct((b, k, y_max), vals.dtype),
        interpret=interpret,
    )(vals, x_gidx_task, y_lidx, x)

"""Sharded, async, atomic checkpointing with elastic re-mesh restore.

Layout (one directory per step):

    <root>/step_00001230.tmp.<nonce>/   — staged write
        manifest.json                   — pytree structure, shapes, dtypes
        leaf_00000.bin ...              — raw little-endian buffers
    <root>/step_00001230/               — atomic rename on completion

Protocol properties the tests assert:
  * **atomic commit** — a checkpoint is visible iff the final rename
    happened; a crash mid-write leaves only a ``.tmp.*`` dir that restore
    ignores and save garbage-collects;
  * **async** — ``save`` snapshots to host memory synchronously (cheap) and
    writes on a background thread; ``wait()`` joins, errors re-raise;
  * **retention** — keep the newest ``keep`` complete checkpoints;
  * **elastic re-mesh** — buffers are stored device-layout-free (single
    logical array), so ``restore`` can re-shard onto ANY mesh: pass
    ``shardings`` built for the new topology and each leaf is device_put
    with the new layout.  This is the restart path after a pod-count change.

bf16 leaves are stored as raw uint16 payloads with the logical dtype in the
manifest (NumPy has no native bfloat16; ml_dtypes handles the view back).
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import uuid
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

try:  # ships with jax
    import ml_dtypes

    _BF16 = np.dtype(ml_dtypes.bfloat16)
except Exception:  # pragma: no cover
    _BF16 = None

__all__ = ["CheckpointManager", "save_pytree", "restore_pytree", "latest_step"]


def _leaf_paths(tree: Any):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    paths = ["/".join(str(getattr(p, "key", p)) for p in path) for path, _ in flat]
    leaves = [leaf for _, leaf in flat]
    return paths, leaves, treedef


def save_pytree(tree: Any, directory: str) -> None:
    """Synchronous staged+atomic write of one pytree."""
    parent = os.path.dirname(directory) or "."
    os.makedirs(parent, exist_ok=True)
    tmp = f"{directory}.tmp.{uuid.uuid4().hex[:8]}"
    os.makedirs(tmp)
    paths, leaves, _ = _leaf_paths(tree)
    manifest = {"leaves": []}
    for i, (path, leaf) in enumerate(zip(paths, leaves)):
        arr = np.asarray(jax.device_get(leaf))
        dtype_name = str(leaf.dtype)
        if dtype_name == "bfloat16":
            arr = arr.view(np.uint16)
        fname = f"leaf_{i:05d}.bin"
        with open(os.path.join(tmp, fname), "wb") as f:
            f.write(np.ascontiguousarray(arr).tobytes())
        manifest["leaves"].append(
            {"path": path, "file": fname, "shape": list(arr.shape), "dtype": dtype_name}
        )
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    os.replace(tmp, directory) if not os.path.exists(directory) else shutil.rmtree(tmp)


def restore_pytree(directory: str, like: Any, shardings: Optional[Any] = None) -> Any:
    """Restore into the structure of ``like`` (an abstract or real pytree).

    ``shardings`` — optional matching pytree of NamedSharding for elastic
    re-mesh: leaves are device_put with the *new* layout.
    """
    with open(os.path.join(directory, "manifest.json")) as f:
        manifest = json.load(f)
    by_path = {e["path"]: e for e in manifest["leaves"]}
    paths, leaves, treedef = _leaf_paths(like)
    shard_leaves = None
    if shardings is not None:
        _, shard_leaves, _ = _leaf_paths(shardings)
    out = []
    for i, (path, leaf) in enumerate(zip(paths, leaves)):
        e = by_path[path]
        raw_dtype = np.uint16 if e["dtype"] == "bfloat16" else np.dtype(e["dtype"])
        with open(os.path.join(directory, e["file"]), "rb") as f:
            arr = np.frombuffer(f.read(), dtype=raw_dtype).reshape(e["shape"])
        if e["dtype"] == "bfloat16":
            arr = arr.view(_BF16)
        if shard_leaves is not None:
            out.append(jax.device_put(arr, shard_leaves[i]))
        else:
            out.append(jnp.asarray(arr))
    return jax.tree_util.tree_unflatten(treedef, out)


def _step_dirs(root: str) -> list[tuple[int, str]]:
    if not os.path.isdir(root):
        return []
    out = []
    for name in os.listdir(root):
        if name.startswith("step_") and ".tmp." not in name:
            try:
                out.append((int(name[5:]), os.path.join(root, name)))
            except ValueError:
                continue
    return sorted(out)


def latest_step(root: str) -> Optional[int]:
    dirs = _step_dirs(root)
    return dirs[-1][0] if dirs else None


class CheckpointManager:
    """Async save + retention + restart discovery."""

    def __init__(self, root: str, keep: int = 3):
        self.root = root
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None
        os.makedirs(root, exist_ok=True)
        self._gc_tmp()

    def _gc_tmp(self):
        for name in os.listdir(self.root):
            if ".tmp." in name:
                shutil.rmtree(os.path.join(self.root, name), ignore_errors=True)

    def directory(self, step: int) -> str:
        return os.path.join(self.root, f"step_{step:08d}")

    def save(self, step: int, tree: Any, blocking: bool = False) -> None:
        self.wait()  # one in-flight save at a time
        # Snapshot to host now (device buffers may be donated/mutated next step).
        host_tree = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)
        dtypes = jax.tree.map(lambda x: str(x.dtype), tree)

        def _job():
            try:
                # Re-wrap so save_pytree sees logical dtypes (bf16 via jnp).
                t = jax.tree.map(
                    lambda a, d: a if str(a.dtype) == d else a, host_tree, dtypes
                )
                save_pytree(t, self.directory(step))
                self._retain()
            except BaseException as e:  # pragma: no cover
                self._error = e

        self._thread = threading.Thread(target=_job, daemon=True)
        self._thread.start()
        if blocking:
            self.wait()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def _retain(self):
        dirs = _step_dirs(self.root)
        for _, d in dirs[: -self.keep]:
            shutil.rmtree(d, ignore_errors=True)

    def latest(self) -> Optional[int]:
        return latest_step(self.root)

    def restore(self, like: Any, step: Optional[int] = None, shardings: Optional[Any] = None) -> tuple[int, Any]:
        self.wait()
        if step is None:
            step = self.latest()
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {self.root}")
        return step, restore_pytree(self.directory(step), like, shardings)

"""Async sharded checkpointing with atomic commit + elastic re-mesh restore."""
from .checkpoint import CheckpointManager, latest_step, restore_pytree, save_pytree

__all__ = ["CheckpointManager", "latest_step", "restore_pytree", "save_pytree"]

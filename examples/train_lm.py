"""End-to-end training driver (deliverable b): train a reduced LM for a few
hundred steps with the full substrate — synthetic pipeline, AdamW +
warmup-cosine, grad accumulation, async checkpointing, fault-tolerant loop.

    PYTHONPATH=src python examples/train_lm.py [--arch qwen3-moe-30b-a3b] [--steps 300]

Any assigned architecture id works (reduced family config on CPU); the same
driver lowers the FULL config on a TPU slice via repro.launch.train.
"""
import argparse
import tempfile

from repro.launch.train import run_training


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-moe-30b-a3b")
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    args = ap.parse_args()

    with tempfile.TemporaryDirectory() as ckpt_dir:
        state, history = run_training(
            args.arch, steps=args.steps, batch=args.batch, seq=args.seq,
            reduced=True, ckpt_dir=ckpt_dir, ckpt_every=50, num_microbatches=2,
        )
    losses = [h["loss"] for h in history]
    print(f"{args.arch}: {len(history)} steps, "
          f"loss {losses[0]:.3f} -> {losses[-1]:.3f} "
          f"(min {min(losses):.3f})")
    assert losses[-1] < losses[0], "training must reduce loss"
    print("train_lm OK")


if __name__ == "__main__":
    main()

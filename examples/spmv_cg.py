"""Conjugate-gradient solver with EP-scheduled SpMV + adaptive overhead
control — the paper's §5.2 pipeline end to end.

    PYTHONPATH=src python examples/spmv_cg.py

CG calls SpMV every iteration; the EP partitioner runs asynchronously on a
host thread (paper §4.2) while iterations proceed with the baseline kernel.
Once the optimized schedule is ready the solver switches over — and the
first optimized run is timed against the baseline average with automatic
fallback, so the solver can never lose.
"""
import time

import jax.numpy as jnp
import numpy as np

from repro.core import (
    AdaptiveScheduler,
    build_pack_plan,
    edge_partition,
    synthetic_bipartite_graph,
)
from repro.kernels import make_ep_spmv_fn
from repro.kernels.ref import spmv_coo_ref


def make_spd_problem(n=1024, seed=0):
    """Sparse SPD system A = L L^T + n*I from a random sparse L."""
    edges, rows, cols = synthetic_bipartite_graph(n, n, nnz_per_row=6, seed=seed)
    rng = np.random.default_rng(seed)
    vals = rng.standard_normal(rows.shape[0]).astype(np.float32) * 0.1
    # Symmetrize: A = (B + B^T)/2 + diag boost (diagonally dominant -> SPD).
    r2 = np.concatenate([rows, cols, np.arange(n)])
    c2 = np.concatenate([cols, rows, np.arange(n)])
    v2 = np.concatenate([vals / 2, vals / 2, np.full(n, 4.0, np.float32)])
    key = r2.astype(np.int64) * n + c2
    order = np.argsort(key)
    key, r2, c2, v2 = key[order], r2[order], c2[order], v2[order]
    uniq = np.concatenate([[True], key[1:] != key[:-1]])
    seg = np.cumsum(uniq) - 1
    v2 = np.bincount(seg, weights=v2).astype(np.float32)
    r2, c2 = r2[uniq], c2[uniq]
    return n, r2, c2, v2


def main():
    n, rows, cols, vals = make_spd_problem()
    b = np.ones(n, np.float32)
    k = 16

    # Baseline SpMV: jnp scatter-add over the raw COO (CUSP-like).
    rj, cj, vj = jnp.asarray(rows), jnp.asarray(cols), jnp.asarray(vals)
    baseline = lambda x: spmv_coo_ref(n, rj, cj, vj, x)

    # Async optimization job: EP partition + pack plan + kernel bind.
    from repro.core.graph import affinity_graph_from_coo

    def optimize():
        edges = affinity_graph_from_coo(n, n, rows, cols)
        ep = edge_partition(edges, k, method="ep")
        plan = build_pack_plan(n, n, rows, cols, ep.labels, k, pad=128)
        return plan

    sched = AdaptiveScheduler(
        baseline_fn=baseline,
        optimize_fn=optimize,
        build_optimized_fn=lambda plan: make_ep_spmv_fn(plan, vals, mode="software"),
    )

    # CG iterations (spmv via the adaptive scheduler).
    x = jnp.zeros(n)
    r = jnp.asarray(b) - sched(x)
    p = r
    rs = jnp.vdot(r, r)
    t0 = time.perf_counter()
    for it in range(60):
        ap = sched(p)
        alpha = rs / jnp.vdot(p, ap)
        x = x + alpha * p
        r = r - alpha * ap
        rs_new = jnp.vdot(r, r)
        if float(jnp.sqrt(rs_new)) < 1e-5:
            break
        p = r + (rs_new / rs) * p
        rs = rs_new
    dt = time.perf_counter() - t0
    resid = float(jnp.linalg.norm(jnp.asarray(b) - baseline(x)))
    s = sched.summary()
    print(f"CG converged in {it + 1} iters, residual {resid:.2e}, {dt:.2f}s")
    print(f"adaptive control: state={s['state']} "
          f"optimize_time={s['optimize_time_s'] and round(s['optimize_time_s'], 3)}s "
          f"baseline_calls={len(sched.baseline_times)} optimized_calls={s['optimized_calls']}")
    assert resid < 1e-3
    print("spmv_cg OK")


if __name__ == "__main__":
    main()

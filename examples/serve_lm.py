"""Serving example (deliverable b): batched prefill + greedy decode with a
KV cache, for any assigned architecture.

    PYTHONPATH=src python examples/serve_lm.py [--arch granite-3-8b]
"""
import argparse

from repro.launch.serve import run_serving


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-3-8b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    args = ap.parse_args()

    tokens, stats = run_serving(
        args.arch, batch=args.batch, prompt_len=args.prompt_len,
        gen=args.gen, reduced=True,
    )
    assert tokens.shape == (args.batch, args.gen)
    print(f"{args.arch}: generated {tokens.shape[1]} tokens x {tokens.shape[0]} seqs")
    print(f"prefill {stats['prefill_s']:.2f}s, decode {stats['decode_s']:.2f}s "
          f"({stats['tok_per_s']:.1f} tok/s on CPU-interpret)")
    print("serve_lm OK")


if __name__ == "__main__":
    main()

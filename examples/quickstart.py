"""Quickstart: the paper's EP model in five steps.

    PYTHONPATH=src python examples/quickstart.py

1. build a data-affinity graph (tasks = edges, data objects = vertices);
2. partition tasks into cache domains with the EP model (clone-and-connect
   + multilevel vertex partitioning);
3. compare the vertex-cut (= redundant off-chip loads) against baselines;
4. build the cpack layout (PackPlan) and run the EP-scheduled SpMV Pallas
   kernel (software-cache mode, interpret on CPU);
5. verify against the pure-jnp oracle.
"""
import jax.numpy as jnp
import numpy as np

from repro.core import (
    build_pack_plan,
    edge_partition,
    synthetic_bipartite_graph,
)
from repro.kernels import make_ep_spmv_fn, spmv_hbm_traffic_model
from repro.kernels.ref import spmv_coo_ref


def main():
    # 1. A sparse matrix's data-affinity graph: one task per non-zero,
    #    touching one input-vector and one output-vector element.
    n = 2048
    # Clustered structure + SCRAMBLED task order: the matrix has locality,
    # but it is invisible to the default contiguous schedule (the paper's
    # irregular-application setting).  EP rediscovers it from the graph.
    edges, rows, cols = synthetic_bipartite_graph(n, n, nnz_per_row=8, seed=0)
    perm = np.random.default_rng(1).permutation(edges.m)
    rows, cols = rows[perm], cols[perm]
    from repro.core.graph import affinity_graph_from_coo

    edges = affinity_graph_from_coo(n, n, rows, cols)
    print(f"affinity graph: {edges.n} data objects, {edges.m} tasks, "
          f"d_max={edges.max_degree()}")

    # 2/3. Partition into k cache domains; compare methods.
    k = 16
    for method in ("default", "random", "greedy", "ep"):
        r = edge_partition(edges, k, method=method)
        print(f"  {method:8s} vertex-cut={r.vertex_cut:7d} "
              f"balance={r.quality.balance:.3f} "
              f"redundant={r.quality.redundant_fraction:.1%} "
              f"({r.partition_time_s * 1e3:.0f} ms)")

    ep = edge_partition(edges, k, method="ep")

    # 4. cpack layout + kernel.
    plan = build_pack_plan(n, n, rows, cols, ep.labels, k, pad=128)
    print(f"pack plan: E_max={plan.e_max} X_max={plan.x_max} Y_max={plan.y_max} "
          f"VMEM/cell={plan.vmem_bytes() / 1024:.0f} KiB")
    print(f"modeled HBM loads: {plan.modeled_loads()} "
          f"({spmv_hbm_traffic_model(plan)})")

    rng = np.random.default_rng(0)
    vals = rng.standard_normal(edges.m).astype(np.float32)
    x = rng.standard_normal(n).astype(np.float32)
    spmv = make_ep_spmv_fn(plan, vals, mode="software")
    y = spmv(jnp.asarray(x))

    # 5. Oracle check.
    ref = spmv_coo_ref(n, jnp.asarray(rows), jnp.asarray(cols),
                       jnp.asarray(vals), jnp.asarray(x))
    err = float(jnp.abs(y - ref).max())
    print(f"max |EP-SpMV - oracle| = {err:.2e}")
    assert err < 1e-4
    print("quickstart OK")


if __name__ == "__main__":
    main()

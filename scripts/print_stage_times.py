#!/usr/bin/env python
"""Print a compact per-stage timing table from a benchmark JSON.

    python scripts/print_stage_times.py bench.json

Reads the ``perf`` section written by ``benchmarks.run --json`` and renders
the coarsen/init/refine/pack breakdown per graph — the one table to scan in
a CI job log to see where the cold partition->pack pipeline spends time and
how the trajectory moves PR over PR.
"""
from __future__ import annotations

import argparse
import json
import sys

COLS = ("coarsen_s", "init_s", "refine_s", "ep_total_s", "pack_s")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("bench_json")
    args = ap.parse_args(argv)
    with open(args.bench_json) as f:
        doc = json.load(f)
    rows = doc.get("sections", {}).get("perf") or []
    if not rows:
        print("no perf section in", args.bench_json)
        return 1
    print(f"stage timings (scale {doc.get('scale', '?')}):")
    print(f"{'graph':28s} {'m':>9s} "
          + " ".join(f"{c[:-2]:>9s}" for c in COLS))
    for r in rows:
        print(f"{r['graph']:28s} {r['m']:9d} "
              + " ".join(f"{float(r[c]):9.3f}" for c in COLS))
    totals = {c: sum(float(r[c]) for r in rows) for c in COLS}
    print(f"{'TOTAL':28s} {'':9s} "
          + " ".join(f"{totals[c]:9.3f}" for c in COLS))
    return 0


if __name__ == "__main__":
    sys.exit(main())

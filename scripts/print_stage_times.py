#!/usr/bin/env python
"""Print compact per-stage timing tables from a benchmark JSON.

    python scripts/print_stage_times.py bench.json

Reads the ``perf`` section written by ``benchmarks.run --json`` and renders
the coarsen/init/refine/pack breakdown per graph, the per-level coarsening
table (level, n, nnz, contraction ratio, ms — where the V-cycle's dominant
stage spends its time), then the ``svc`` section's incremental breakdown
(dirty-build / placement / refine / pack per churn rate) — the tables to
scan in a CI job log to see where the cold partition->pack pipeline and the
serving-path update spend time, and how the trajectory moves PR over PR.
"""
from __future__ import annotations

import argparse
import json
import sys

COLS = ("coarsen_s", "init_s", "refine_s", "ep_total_s", "pack_s")
INC_COLS = ("inc_dirty_s", "inc_place_s", "inc_refine_s", "incr_s", "pack_s")


def _table(rows: list[dict], cols: tuple[str, ...], label_w: int = 28) -> None:
    print(f"{'graph':{label_w}s} {'m':>9s} "
          + " ".join(f"{c[:-2]:>10s}" for c in cols))
    for r in rows:
        print(f"{r['graph']:{label_w}s} {r['m']:9d} "
              + " ".join(f"{float(r[c]):10.4f}" for c in cols))
    totals = {c: sum(float(r[c]) for r in rows) for c in cols}
    print(f"{'TOTAL':{label_w}s} {'':9s} "
          + " ".join(f"{totals[c]:10.4f}" for c in cols))


def _level_table(rows: list[dict]) -> None:
    """Per-level coarsening breakdown: one block per graph, one line per
    V-cycle contraction (level, fine n, fine nnz, contraction ratio, ms)."""
    print(f"{'graph':28s} {'lvl':>3s} {'n':>8s} {'nnz':>9s} "
          f"{'coarse_n':>8s} {'ratio':>6s} {'ms':>7s}")
    for r in rows:
        levels = r.get("level_stats") or []
        if not levels:
            continue
        for i, ls in enumerate(levels):
            label = r["graph"] if i == 0 else ""
            print(f"{label:28s} {i:3d} {int(ls['n']):8d} {int(ls['nnz']):9d} "
                  f"{int(ls['coarse_n']):8d} {float(ls['ratio']):6.2f} "
                  f"{float(ls['time_s']) * 1e3:7.2f}")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("bench_json")
    args = ap.parse_args(argv)
    with open(args.bench_json) as f:
        doc = json.load(f)
    rows = doc.get("sections", {}).get("perf") or []
    if not rows:
        print("no perf section in", args.bench_json)
        return 1
    print(f"cold-path stage timings (scale {doc.get('scale', '?')}):")
    _table(rows, COLS)
    if any(r.get("level_stats") for r in rows):
        print("\nper-level coarsening (V-cycle shape):")
        _level_table(rows)

    # Incremental breakdown: svc rows that carry the batched pipeline's
    # stage split (full-fallback rows and pre-sweep JSONs just lack them).
    svc_rows = [r for r in (doc.get("sections", {}).get("svc") or [])
                if all(c in r for c in INC_COLS)]
    if svc_rows:
        print("\nincremental stage timings (dirty-build/placement/refine/pack):")
        _table(svc_rows, INC_COLS, label_w=40)
    else:
        print("\nno incremental stage timings in the svc section")
    return 0


if __name__ == "__main__":
    sys.exit(main())

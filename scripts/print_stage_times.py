#!/usr/bin/env python
"""Print compact per-stage timing tables from a benchmark JSON.

    python scripts/print_stage_times.py bench.json

Reads the ``perf`` section written by ``benchmarks.run --json`` and renders
the coarsen/init/refine/pack breakdown per graph, the per-level coarsening
table (level, n, nnz, contraction ratio, ms — where the V-cycle's dominant
stage spends its time), then the ``svc`` section's per-gear breakdowns
(incremental: dirty-build / placement / refine; local: dirty-build /
placement / coarsen / refine+polish — one table per gear, keyed by the
row's ``incr_source``), then the ``svc_streaming`` section's per-tenant
churn-stream table (gear mix, p50/p99 update latency, drift, mid-band
local-vs-full speedup), then the
``svc_multitenant`` section: per-tenant isolation rows (warm-hit rate,
p50/p99 latency, hit/miss/eviction counters), the worker-pool throughput
row, and the scheduler's ServiceMetrics snapshot (queue depth, utilization,
latency histogram), then the ``svc_batched`` section: the per-bucket
compile table (bucket label, batch width, tile ceilings, compiles, hits)
and the batch-size histogram, then the ``svc_chaos`` section: the
per-replica health table (state, heartbeats, jobs, failovers, p99) next to
the failover/hedging outcome lines — the tables to scan in a CI job log to
see where the cold pipeline, the serving-path update, the multi-tenant
scheduler, the bucketed serve path, and the replica group spend time, and
how the trajectory moves PR over PR.
"""
from __future__ import annotations

import argparse
import json
import sys

COLS = ("coarsen_s", "init_s", "refine_s", "ep_total_s", "pack_s")
INC_COLS = ("inc_dirty_s", "inc_place_s", "inc_refine_s", "incr_s", "pack_s")
LOC_COLS = ("loc_dirty_s", "loc_place_s", "loc_coarsen_s", "loc_refine_s",
            "incr_s", "pack_s")


def _table(rows: list[dict], cols: tuple[str, ...], label_w: int = 28) -> None:
    print(f"{'graph':{label_w}s} {'m':>9s} "
          + " ".join(f"{c[:-2]:>10s}" for c in cols))
    for r in rows:
        print(f"{r['graph']:{label_w}s} {r['m']:9d} "
              + " ".join(f"{float(r[c]):10.4f}" for c in cols))
    totals = {c: sum(float(r[c]) for r in rows) for c in cols}
    print(f"{'TOTAL':{label_w}s} {'':9s} "
          + " ".join(f"{totals[c]:10.4f}" for c in cols))


def _level_table(rows: list[dict]) -> None:
    """Per-level coarsening breakdown: one block per graph, one line per
    V-cycle contraction (level, fine n, fine nnz, contraction ratio, ms)."""
    print(f"{'graph':28s} {'lvl':>3s} {'n':>8s} {'nnz':>9s} "
          f"{'coarse_n':>8s} {'ratio':>6s} {'ms':>7s}")
    for r in rows:
        levels = r.get("level_stats") or []
        if not levels:
            continue
        for i, ls in enumerate(levels):
            label = r["graph"] if i == 0 else ""
            print(f"{label:28s} {i:3d} {int(ls['n']):8d} {int(ls['nnz']):9d} "
                  f"{int(ls['coarse_n']):8d} {float(ls['ratio']):6.2f} "
                  f"{float(ls['time_s']) * 1e3:7.2f}")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("bench_json")
    args = ap.parse_args(argv)
    with open(args.bench_json) as f:
        doc = json.load(f)
    rows = doc.get("sections", {}).get("perf") or []
    if not rows:
        print("no perf section in", args.bench_json)
        return 1
    print(f"cold-path stage timings (scale {doc.get('scale', '?')}):")
    _table(rows, COLS)
    if any(r.get("level_stats") for r in rows):
        print("\nper-level coarsening (V-cycle shape):")
        _level_table(rows)

    # Per-gear breakdowns: svc rows that carry each gear's stage split
    # (full-fallback rows and pre-sweep JSONs just lack them).  The gear a
    # row took is ``incr_source``; every gear gets its own table because
    # their stages differ (single-level sweep vs dirty V-cycle).
    all_svc = doc.get("sections", {}).get("svc") or []
    svc_rows = [r for r in all_svc if all(c in r for c in INC_COLS)]
    if svc_rows:
        print("\nincremental-gear stage timings "
              "(dirty-build/placement/refine/pack):")
        _table(svc_rows, INC_COLS, label_w=40)
    else:
        print("\nno incremental stage timings in the svc section")
    loc_rows = [r for r in all_svc if all(c in r for c in LOC_COLS)]
    if loc_rows:
        print("\nlocal-gear stage timings "
              "(dirty-build/placement/coarsen/refine+polish/pack):")
        _table(loc_rows, LOC_COLS, label_w=40)

    _streaming_tables(doc.get("sections", {}).get("svc_streaming") or [])
    _multitenant_tables(doc.get("sections", {}).get("svc_multitenant") or [])
    _batched_tables(doc.get("sections", {}).get("svc_batched") or [])
    _chaos_tables(doc.get("sections", {}).get("svc_chaos") or [])
    return 0


def _streaming_tables(rows: list[dict]) -> None:
    """Per-tenant churn-stream rows: gear mix, update latency, drift."""
    tenant_rows = [r for r in rows if "p99_update_s" in r]
    summary = next((r for r in rows if r.get("graph") == "stream"), None)
    if not tenant_rows and summary is None:
        return
    print("\nchurn streams (svc_streaming, per-gear mix + update latency):")
    print(f"{'tenant':34s} {'events':>6s} {'inc':>4s} {'loc':>4s} "
          f"{'full':>4s} {'p50_ms':>7s} {'p99_ms':>7s} {'max_drift':>9s} "
          f"{'local_x':>8s}")
    for r in tenant_rows:
        lx = float(r.get("local_speedup", 0.0))
        print(f"{r['graph']:34s} {int(r['n_events']):6d} "
              f"{int(r['n_incremental']):4d} {int(r['n_local']):4d} "
              f"{int(r['n_full']):4d} "
              f"{float(r['p50_update_s']) * 1e3:7.1f} "
              f"{float(r['p99_update_s']) * 1e3:7.1f} "
              f"{float(r['max_drift']):9.3f} "
              + (f"{lx:7.2f}x" if lx else f"{'-':>8s}"))
    if summary is not None:
        print(f"  stream summary: gears inc/loc/full = "
              f"{int(summary['n_incremental'])}/{int(summary['n_local'])}/"
              f"{int(summary['n_full'])} over {int(summary['n_events'])} "
              f"events, full_frac {float(summary['full_frac']):.2f}; "
              f"mid-band local speedup "
              f"{float(summary.get('local_speedup_mid', 0.0)):.2f}x "
              f"({int(summary.get('n_local_mid', 0))} events <= 6% churn, "
              f"all-band {float(summary.get('local_speedup', 0.0)):.2f}x); "
              f"max drift {float(summary['max_drift']):.3f}")


def _multitenant_tables(rows: list[dict]) -> None:
    """Per-tenant isolation rows + pool throughput + metrics snapshot."""
    tenant_rows = [r for r in rows if "tenant" in r]
    if tenant_rows:
        print("\nmulti-tenant isolation (per-tenant serving stats):")
        print(f"{'tenant':22s} {'mode':>9s} {'warm_hit':>9s} {'p50_ms':>8s} "
              f"{'p99_ms':>8s} {'hits':>6s} {'miss':>6s} {'evict':>6s}")
        for r in tenant_rows:
            whr = (f"{float(r['warm_hit_rate']):.2f}"
                   if "warm_hit_rate" in r else "-")
            print(f"{r['tenant']:22s} {r['mode']:>9s} {whr:>9s} "
                  f"{float(r['p50_ms']):8.2f} {float(r['p99_ms']):8.2f} "
                  f"{int(r['hits']):6d} {int(r['misses']):6d} "
                  f"{int(r['evictions']):6d}")
    thr = next((r for r in rows if r.get("graph") == "cold_throughput"), None)
    if thr is not None:
        print(f"\nworker-pool cold throughput: "
              f"{float(thr['plans_per_s_1w']):.2f} plans/s @1w -> "
              f"{float(thr['plans_per_s_nw']):.2f} plans/s "
              f"@{int(thr['workers'])}w "
              f"({float(thr['workers_speedup']):.2f}x, utilization "
              f"{float(thr['pool_utilization']):.2f})")
    met = next((r for r in rows if r.get("graph") == "metrics"), None)
    if met is not None:
        print("\nservice metrics snapshot (budgeted contention run):")
        print(f"  queue_depth={int(met['queue_depth'])} "
              f"(max {int(met.get('queue_depth_max', 0))}) "
              f"rejected={int(met.get('rejected', 0))} "
              f"shed_deadline={int(met.get('shed_deadline', 0))} "
              f"utilization={float(met['utilization']):.2f} "
              f"jobs_completed={int(met['jobs_completed'])} "
              f"coalesced={int(met['coalesced'])} "
              f"latency p50={float(met['latency_p50_s']) * 1e3:.2f}ms "
              f"p99={float(met['latency_p99_s']) * 1e3:.2f}ms")
        hist = met.get("latency_histogram") or {}
        if hist:
            print("  latency histogram: "
                  + "  ".join(f"{k}:{v}" for k, v in hist.items()))
        tenants = met.get("tenants") or {}
        occ = {t: s for t, s in tenants.items()
               if s.get("queued", 0) or s.get("rejected", 0)}
        if occ:
            print("  per-tenant queue occupancy: "
                  + "  ".join(f"{t}:queued={s.get('queued', 0)}"
                              f",rejected={s.get('rejected', 0)}"
                              for t, s in sorted(occ.items())))


def _batched_tables(rows: list[dict]) -> None:
    """Bucketed-compilation serve path: summary, per-bucket compile table,
    and the micro-batch size histogram."""
    summary = next((r for r in rows if r.get("graph") == "batched"), None)
    if summary is None:
        return
    print("\nbucketed serving (svc_batched):")
    print(f"  {int(summary['n_graphs'])} graphs, "
          f"{int(summary['n_tenants'])} tenants: "
          f"{float(summary['req_per_s_unbatched']):.1f} req/s unbatched -> "
          f"{float(summary['req_per_s_batched']):.1f} req/s batched "
          f"({float(summary['speedup']):.1f}x); p99 "
          f"{float(summary['p99_ms_unbatched']):.1f}ms -> "
          f"{float(summary['p99_ms_batched']):.1f}ms; "
          f"byte_identical={summary.get('byte_identical')}")
    bucket_rows = [r for r in rows if "label" in r]
    if bucket_rows:
        print(f"{'bucket':32s} {'batch':>5s} {'e_max':>7s} {'rows':>6s} "
              f"{'op_elems':>10s} {'hits':>6s} {'compiled':>8s}")
        for r in bucket_rows:
            print(f"{r['label']:32s} {int(r['batch']):5d} {int(r['e_max']):7d} "
                  f"{int(r['n_rows']):6d} {int(r['operand_elems']):10d} "
                  f"{int(r['hits']):6d} {str(bool(r.get('compiled'))):>8s}")
    hist_row = next((r for r in rows if r.get("graph") == "batch_hist"), None)
    if hist_row and hist_row.get("hist"):
        print("  batch-size histogram: "
              + "  ".join(f"{k}:{v}" for k, v in
                          sorted(hist_row["hist"].items(), key=lambda kv: int(kv[0]))))


def _chaos_tables(rows: list[dict]) -> None:
    """Replica group under fault injection: failover + hedging outcomes and
    the per-replica health/failover table."""
    fo = next((r for r in rows if r.get("graph") == "chaos_failover"), None)
    hg = next((r for r in rows if r.get("graph") == "chaos_hedge"), None)
    k9 = next((r for r in rows if r.get("graph") == "chaos_kill9"), None)
    fl = next((r for r in rows if r.get("graph") == "chaos_flood"), None)
    reps = next((r for r in rows if r.get("graph") == "replicas"), None)
    if fo is None and hg is None and k9 is None and fl is None and reps is None:
        return
    print("\nreplica chaos (svc_chaos):")
    if fo is not None:
        print(f"  failover: killed {fo.get('killed_replica')} after "
              f"{int(fo['kill_after_jobs'])} jobs -> "
              f"lost={int(fo['lost_tickets'])} "
              f"byte_identical={fo.get('byte_identical')} "
              f"recovery={float(fo['recovery_latency_s']) * 1e3:.0f}ms "
              f"(failovers={int(fo['failovers'])}, "
              f"retries={int(fo['retries'])})")
    if hg is not None:
        print(f"  hedging vs {float(hg['straggler_delay_s']) * 1e3:.0f}ms "
              f"straggler: p99 {float(hg['p99_nohedge_ms']):.0f}ms -> "
              f"{float(hg['p99_hedge_ms']):.0f}ms "
              f"({float(hg['p99_speedup']):.1f}x), win rate "
              f"{float(hg['hedge_win_rate']):.2f} "
              f"({int(hg['hedges_won'])}/{int(hg['hedges_fired'])})")
    if k9 is not None:
        print(f"  kill -9 ({k9.get('transport')} transport): SIGKILLed "
              f"{k9.get('killed_replica')} after "
              f"{int(k9['kill_after_jobs'])} jobs -> "
              f"lost={int(k9['lost_tickets'])} "
              f"byte_identical={k9.get('byte_identical')} "
              f"recovery={float(k9['recovery_latency_s']) * 1e3:.0f}ms "
              f"(retries={int(k9['retries'])})")
    if fl is not None:
        print(f"  flood: {float(fl['flood_factor']):.0f}x flooder vs queue "
              f"bound {int(fl['queue_bound'])}: victim p99 "
              f"{float(fl['victim_p99_noflood_ms']):.1f}ms -> "
              f"{float(fl['victim_p99_flood_ms']):.1f}ms "
              f"({float(fl['victim_p99_ratio']):.2f}x), victim rejections "
              f"{int(fl['victim_rejections'])}, flooder rejected "
              f"{int(fl['flooder_rejections'])}/{int(fl['flooder_submits'])} "
              f"(min retry_after {float(fl['min_retry_after_s']):.3f}s), "
              f"breaker trips={int(fl['breaker_trips'])} "
              f"recovered={fl.get('breaker_recovered')} "
              f"wire_identical={fl.get('rejection_wire_identical')}")
    if reps is not None and reps.get("replicas"):
        print(f"{'replica':>10s} {'state':>8s} {'weight':>6s} {'beats':>6s} "
              f"{'jobs':>5s} {'failovers':>9s} {'hedges_to':>9s} "
              f"{'p50_ms':>8s} {'p99_ms':>8s}")
        for r in reps["replicas"]:
            print(f"{r['replica']:>10s} {r['state']:>8s} "
                  f"{float(r['weight']):6.1f} {int(r['beats']):6d} "
                  f"{int(r['jobs_completed']):5d} "
                  f"{int(r['failovers_from']):9d} {int(r['hedges_to']):9d} "
                  f"{float(r['p50_ms']):8.1f} {float(r['p99_ms']):8.1f}")


if __name__ == "__main__":
    sys.exit(main())

#!/usr/bin/env python
"""Print compact per-stage timing tables from a benchmark JSON.

    python scripts/print_stage_times.py bench.json

Reads the ``perf`` section written by ``benchmarks.run --json`` and renders
the coarsen/init/refine/pack breakdown per graph, then the ``svc`` section's
incremental breakdown (dirty-build / placement / refine / pack per churn
rate) — the two tables to scan in a CI job log to see where the cold
partition->pack pipeline and the serving-path update spend time, and how
the trajectory moves PR over PR.
"""
from __future__ import annotations

import argparse
import json
import sys

COLS = ("coarsen_s", "init_s", "refine_s", "ep_total_s", "pack_s")
INC_COLS = ("inc_dirty_s", "inc_place_s", "inc_refine_s", "incr_s", "pack_s")


def _table(rows: list[dict], cols: tuple[str, ...], label_w: int = 28) -> None:
    print(f"{'graph':{label_w}s} {'m':>9s} "
          + " ".join(f"{c[:-2]:>10s}" for c in cols))
    for r in rows:
        print(f"{r['graph']:{label_w}s} {r['m']:9d} "
              + " ".join(f"{float(r[c]):10.4f}" for c in cols))
    totals = {c: sum(float(r[c]) for r in rows) for c in cols}
    print(f"{'TOTAL':{label_w}s} {'':9s} "
          + " ".join(f"{totals[c]:10.4f}" for c in cols))


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("bench_json")
    args = ap.parse_args(argv)
    with open(args.bench_json) as f:
        doc = json.load(f)
    rows = doc.get("sections", {}).get("perf") or []
    if not rows:
        print("no perf section in", args.bench_json)
        return 1
    print(f"cold-path stage timings (scale {doc.get('scale', '?')}):")
    _table(rows, COLS)

    # Incremental breakdown: svc rows that carry the batched pipeline's
    # stage split (full-fallback rows and pre-sweep JSONs just lack them).
    svc_rows = [r for r in (doc.get("sections", {}).get("svc") or [])
                if all(c in r for c in INC_COLS)]
    if svc_rows:
        print("\nincremental stage timings (dirty-build/placement/refine/pack):")
        _table(svc_rows, INC_COLS, label_w=40)
    else:
        print("\nno incremental stage timings in the svc section")
    return 0


if __name__ == "__main__":
    sys.exit(main())

#!/usr/bin/env python
"""CI gate: diff a fresh benchmark JSON against the committed baseline.

    python scripts/check_bench_regression.py NEW.json BASELINE.json \
        [--threshold 0.25] [--abs-floor 0.25] [--svc-threshold 4.0]

Compares the fig6 EP partition times per graph (the paper's headline cost)
and fails (exit 1) when any graph regresses by more than ``threshold``
(relative) AND ``abs-floor`` seconds (absolute — absorbs scheduler noise on
small smoke-scale runs), or when the total EP time regresses by more than
``threshold``.  Quality (vertex cut) is checked too: EP cut must not grow
by more than 10% on any graph — a partition-quality regression is a bug
even if it happens to run faster.

When the baseline carries an ``svc`` section, the serving-path latencies
are gated as well: warm-cache hits and incremental repartitions — the
primary per-graph rows and the ``<graph>|churn=<rate>`` sweep rows alike —
must not regress beyond ``svc-threshold`` (2x by default; started at 5x
until runner variance was characterized, tightened once two PRs of runner
data showed the jitter stays well under that).

When the baseline carries an ``svc_streaming`` section, the drift-gated
gear policy's stream claims are gated on the ``stream`` summary row:
``local_speedup_mid`` (geomean of same-run full-rebuild time over
local-gear time, restricted to mid-band events <= 6% churn — where the
acceptance criterion's ">= 3x at 5% churn" lives; a same-run ratio, so
runner speed divides out) must stay >= ``stream-local-speedup-min``,
``max_drift`` (worst event's updated-cut / same-run-rebuild-cut across
every tenant stream) must stay <= ``stream-drift-ceiling``, and
``full_frac`` must stay < 0.5 with at least one local event — in the
1-20% band full rebuilds must be the minority, or the mid-range gear has
stopped engaging and "streaming updates" silently became "rebuild every
batch".  Per-tenant ``p99_update_s`` is gated against the baseline like
the other serving-path latencies (relative ``svc-threshold`` above an
absolute ``stream-p99-floor`` — stream p99 at smoke scale is one 15-80ms
update on a loaded runner).

When the baseline carries an ``svc_multitenant`` section, the multi-tenant
serving guarantees are gated: every *budgeted* tenant row's warm-hit rate
must stay within ``mt-hit-slack`` of the baseline (the isolation scenario
is deterministic — per-tenant budgets hold the victims at 1.0, so any drop
means the budget isolation broke), its p99 request latency must stay
within the svc allowance above an absolute floor, and the
``cold_throughput`` row's multi-worker speedup must keep the
baseline's pool executor (identity check, deterministic: a pool that
silently became a thread pool hides inside run jitter on few-core
runners, so it is caught structurally; the worker *count* is machine-
derived and deliberately not identity-checked across runners) and must not
fall below ``mt-speedup-frac`` of the committed baseline's speedup (the
absolute value is machine-dependent — bounded by real cores — and jitters
with runner load, so the ratio floor only guards a catastrophic collapse).

When the baseline carries an ``svc_batched`` section, the bucketed-
compilation serve path is gated on its structural claims, which are
deterministic and machine-independent: distinct kernel compiles must stay
<= n_buckets + 1 (one executable per shape bucket is the whole point — a
compile count tracking the graph count means bucketing silently broke),
batched results must remain byte-identical to dedicated per-request
serving, the batched/unbatched speedup must stay >= ``batched-speedup-min``
(an absolute floor, not a baseline ratio: the measured margin is ~10-30x
and wall-clock ratios jitter with runner load, so the gate sits at the
acceptance criterion's 3x), and the bucket-cache hit rate must stay within
``batched-hit-slack`` of the committed baseline (request mix is seeded and
deterministic; only coalescing jitter moves it).

When the baseline carries an ``svc_chaos`` section, the replicated-service
robustness claims are gated.  Correctness claims are hard and noise-free:
``lost_tickets`` must be exactly 0 in every chaos scenario (a lost ticket
under a replica kill is a dropped request, never jitter), failover
responses must stay ``byte_identical`` to the fault-free run — including
the ``chaos_kill9`` scenario, where the stream runs against socket-backed
*worker processes* and the target worker is SIGKILLed mid-V-cycle, so
byte-identity also proves the transport adds no bytes — and the
hedge win rate against the injected straggler must stay positive (the
straggler delay is 5x the hedge delay — a hedge that stops winning means
the secondary lane stopped firing or stopped being counted).  Latency
claims get noise allowances: recovery latency (kill -> last orphaned
ticket resolved on a healthy replica) must not regress beyond
``chaos-recovery-threshold`` above a ``chaos-recovery-floor`` absolute
delta, and the hedged p99 must stay under ``chaos-p99-frac`` of the
no-hedge p99 measured in the same run (a same-run ratio, so runner speed
divides out; the injected straggler pins the no-hedge p99 at ~250ms while
the hedged path sits at ~60ms, so 0.8 only trips when hedging stops
cutting the tail).  The ``chaos_flood`` row gates the overload-protection
claims: zero victim rejections, flooder rejections present and carrying
positive ``retry_after_s`` hints, the flooder's breaker tripping and then
re-closing after the flood, byte-identical rejection frames across the
process transport (all hard, noise-free), and the victim p99 staying
within ``overload-threshold`` of the same run's no-flood baseline above an
``overload-floor-ms`` absolute floor.

When the baseline carries a ``perf`` section, the V-cycle's dominant stage
is gated too: the *section-total* ``coarsen_s`` must not regress beyond
``coarsen-threshold`` above a ``coarsen-floor`` absolute delta (per-graph
stage timings at smoke scale are 6-30ms and jitter up to ~4x on a loaded
runner — five back-to-back runs showed per-graph noise that would flake
any per-graph gate, while the total stayed within 2.3x and the
matching-era coarsening it must catch sits at 3.3x), and per-graph
``levels`` must not exceed the baseline level count by more than 2 (the
level count is deterministic given the seed, so this structural gate has
no noise: a blowup means cluster coarsening degenerated back to
pairwise-matching behaviour even if the wall time hides it).
"""
from __future__ import annotations

import argparse
import json
import sys


def _rows(doc: dict, section: str) -> dict[str, dict]:
    rows = doc.get("sections", {}).get(section) or []
    return {r["graph"]: r for r in rows}


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("new_json")
    ap.add_argument("baseline_json")
    ap.add_argument("--threshold", type=float, default=0.25,
                    help="max tolerated relative regression (default 25%%)")
    ap.add_argument("--abs-floor", type=float, default=0.25,
                    help="ignore absolute deltas below this many seconds")
    ap.add_argument("--cut-threshold", type=float, default=0.10,
                    help="max tolerated relative vertex-cut growth")
    ap.add_argument("--svc-threshold", type=float, default=1.0,
                    help="max tolerated relative regression of svc warm-hit "
                         "and incremental latencies (tightened from the "
                         "initial 4.0 after two PRs of runner data: observed "
                         "jitter on these timings stays well under 2x, and "
                         "the batched incremental path the gate now guards "
                         "is a 5-14x margin that a Python-loop regression "
                         "would erase outright)")
    ap.add_argument("--svc-warm-floor", type=float, default=0.01,
                    help="ignore warm-hit deltas below this many seconds "
                         "(baseline warm_s is 0.1-0.5ms — a dict probe plus "
                         "an O(m) fingerprint hash — so the floor must sit "
                         "well above one GC pause on a shared runner while "
                         "still catching a structural hit-path regression)")
    ap.add_argument("--svc-incr-floor", type=float, default=0.01,
                    help="ignore incremental deltas below this many seconds "
                         "(baseline incr_s at smoke scale is 0.002-0.03s "
                         "after vectorization, so the floor must sit below "
                         "the values it gates)")
    ap.add_argument("--stream-local-speedup-min", type=float, default=3.0,
                    help="absolute floor for svc_streaming's mid-band "
                         "local-gear speedup vs same-run full rebuilds "
                         "(the acceptance criterion; measured margin is "
                         "~3.5-4x and the ratio is same-run, so runner "
                         "speed divides out)")
    ap.add_argument("--stream-drift-ceiling", type=float, default=1.15,
                    help="max tolerated worst-event quality drift across "
                         "the churn streams (updated cut / same-run full "
                         "rebuild cut; measured worst is ~1.09 — an "
                         "incremental-only policy at 15-20% churn lands "
                         "well above this)")
    ap.add_argument("--stream-p99-floor", type=float, default=0.03,
                    help="ignore svc_streaming per-tenant p99 update-"
                         "latency deltas below this many seconds (stream "
                         "p99 at smoke scale is one 15-80ms update and "
                         "jitters with runner load)")
    ap.add_argument("--mt-hit-slack", type=float, default=0.02,
                    help="max tolerated drop of a budgeted tenant's "
                         "warm-hit rate vs baseline (the isolation run is "
                         "deterministic: budgeted victims sit at 1.0)")
    ap.add_argument("--mt-p99-floor", type=float, default=0.03,
                    help="ignore svc_multitenant p99 latency deltas below "
                         "this many seconds (a victim's p99 is one queued-"
                         "behind-the-flood request; observed spread on a "
                         "loaded 2-vCPU runner is 14-51ms around a ~24ms "
                         "baseline, so the floor must clear that band "
                         "while still catching a structural latency "
                         "regression, which lands in the 100s of ms)")
    ap.add_argument("--mt-speedup-frac", type=float, default=0.5,
                    help="multi-worker cold-plan speedup must stay above "
                         "this fraction of the committed baseline's. "
                         "Absolute speedup is core-count-bound and machine-"
                         "dependent, and on 2-vCPU containers the measured "
                         "ratio jitters ~1.5x run to run, overlapping the "
                         "thread-pool regime — so silent serialization is "
                         "caught by the executor/workers identity check, "
                         "and this ratio floor only guards against a "
                         "catastrophic (~0.2x) collapse")
    ap.add_argument("--batched-speedup-min", type=float, default=3.0,
                    help="absolute floor for svc_batched's batched/unbatched "
                         "req/s ratio (the acceptance criterion; measured "
                         "margin is ~10-30x, so 3x only trips on a "
                         "structural collapse, not runner jitter)")
    ap.add_argument("--batched-hit-slack", type=float, default=0.02,
                    help="max tolerated drop of svc_batched's bucket-cache "
                         "hit rate vs baseline (the request mix is seeded; "
                         "only batch-coalescing jitter moves the rate)")
    ap.add_argument("--chaos-recovery-threshold", type=float, default=1.0,
                    help="max tolerated relative regression of svc_chaos "
                         "recovery latency (kill -> last orphaned ticket "
                         "resolved; the smoke-scale baseline is ~0.3s, "
                         "dominated by the injected stall plus one backoff, "
                         "so 2x only trips when failover itself slows down)")
    ap.add_argument("--chaos-recovery-floor", type=float, default=0.25,
                    help="ignore svc_chaos recovery-latency deltas below "
                         "this many seconds (absorbs scheduler noise around "
                         "the injected 150ms stalls)")
    ap.add_argument("--overload-threshold", type=float, default=2.0,
                    help="max tolerated ratio of the flood scenario's victim "
                         "p99 over its same-run no-flood baseline (same-run "
                         "ratio: runner speed divides out; bounded admission "
                         "plus priority pickup holds the measured ratio near "
                         "1.3x, so 2x only trips when overload isolation "
                         "stops working)")
    ap.add_argument("--overload-floor-ms", type=float, default=75.0,
                    help="ignore flood-scenario victim p99 values below this "
                         "many milliseconds (the no-flood baseline is one "
                         "~7ms cold partition, so tiny absolute wobble can "
                         "blow past any ratio; below the floor the victims "
                         "are unhurt by definition)")
    ap.add_argument("--chaos-p99-frac", type=float, default=0.8,
                    help="hedged p99 must stay below this fraction of the "
                         "same run's no-hedge p99 (same-run ratio: runner "
                         "speed divides out; measured margin is ~4x)")
    ap.add_argument("--coarsen-threshold", type=float, default=1.5,
                    help="max tolerated relative regression of the perf "
                         "section's TOTAL coarsen_s (1.5 = 2.5x; observed "
                         "loaded-runner jitter reaches 2.3x, the matching-"
                         "era coarsening this must catch sits at 3.3x)")
    ap.add_argument("--coarsen-floor", type=float, default=0.05,
                    help="ignore total coarsen_s deltas below this many "
                         "seconds (the smoke-scale total is ~90ms)")
    ap.add_argument("--levels-slack", type=int, default=2,
                    help="max tolerated growth of the perf section's "
                         "V-cycle level count over the baseline")
    args = ap.parse_args(argv)

    with open(args.new_json) as f:
        new = json.load(f)
    with open(args.baseline_json) as f:
        base = json.load(f)

    new_rows, base_rows = _rows(new, "fig6"), _rows(base, "fig6")
    if not new_rows:
        print("ERROR: no fig6 section in the new results")
        return 1
    if not base_rows:
        print("ERROR: no fig6 section in the baseline")
        return 1

    failures = []
    new_total = base_total = 0.0
    for graph, b in base_rows.items():
        n = new_rows.get(graph)
        if n is None:
            failures.append(f"{graph}: missing from new results")
            continue
        nt, bt = float(n["ep_t"]), float(b["ep_t"])
        new_total += nt
        base_total += bt
        if nt - bt > args.abs_floor and nt > bt * (1 + args.threshold):
            failures.append(
                f"{graph}: EP partition time {bt:.3f}s -> {nt:.3f}s "
                f"(+{(nt / max(bt, 1e-9) - 1) * 100:.0f}%)"
            )
        nq, bq = float(n["ep_q"]), float(b["ep_q"])
        if nq > bq * (1 + args.cut_threshold) and nq - bq > 2:
            failures.append(
                f"{graph}: EP vertex cut {bq:.0f} -> {nq:.0f} "
                f"(+{(nq / max(bq, 1.0) - 1) * 100:.0f}%)"
            )
    if (
        base_total > 0
        and new_total - base_total > args.abs_floor
        and new_total > base_total * (1 + args.threshold)
    ):
        failures.append(
            f"total: EP partition time {base_total:.3f}s -> {new_total:.3f}s"
        )

    print(f"fig6 EP time: baseline {base_total:.3f}s, new {new_total:.3f}s "
          f"({len(base_rows)} graphs, threshold {args.threshold:.0%}, "
          f"floor {args.abs_floor}s)")

    # --- svc section: serving-path latency gate (warm hit + incremental) ---
    base_svc = _rows(base, "svc")
    if base_svc:
        new_svc = _rows(new, "svc")
        if not new_svc:
            failures.append("svc: baseline has an svc section but the new "
                            "results do not — serving-path bench was skipped")
        checks = (("warm_s", args.svc_warm_floor), ("incr_s", args.svc_incr_floor))
        for graph, b in base_svc.items():
            n = new_svc.get(graph)
            if n is None:
                if new_svc:
                    failures.append(f"svc/{graph}: missing from new results")
                continue
            for field, floor in checks:
                # Churn-sweep rows carry incr_s but no warm_s (the warm path
                # is measured once per graph) — gate what the baseline has.
                # A field the baseline gates must not vanish from the new
                # results: that's a measurement silently lost, not a pass.
                if field not in b:
                    continue
                if field not in n:
                    failures.append(f"svc/{graph}: {field} missing from new results")
                    continue
                nt, bt = float(n[field]), float(b[field])
                if nt - bt > floor and nt > bt * (1 + args.svc_threshold):
                    failures.append(
                        f"svc/{graph}: {field} {bt:.4f}s -> {nt:.4f}s "
                        f"(+{(nt / max(bt, 1e-9) - 1) * 100:.0f}%)"
                    )
        print(f"svc latencies: {len(base_svc)} graphs gated "
              f"(threshold {args.svc_threshold:.0%}, floors "
              f"{args.svc_warm_floor}s warm / {args.svc_incr_floor}s incr)")
    else:
        print("svc latencies: no svc section in baseline, skipped")

    # --- svc_streaming section: gear-policy stream gates ---
    base_st = _rows(base, "svc_streaming")
    if base_st:
        new_st = _rows(new, "svc_streaming")
        if not new_st:
            failures.append("svc_streaming: baseline has the section but "
                            "the new results do not — streaming bench was "
                            "skipped")
        b_sum = base_st.get("stream")
        n_sum = new_st.get("stream")
        if b_sum is not None and n_sum is None and new_st:
            failures.append("svc_streaming/stream: summary row missing "
                            "from new results")
        if n_sum is not None:
            sp = float(n_sum.get("local_speedup_mid", 0.0))
            n_mid = int(n_sum.get("n_local_mid", 0))
            if n_mid <= 0:
                failures.append(
                    "svc_streaming/stream: no mid-band local-gear events — "
                    "the local gear stopped engaging in the 1-6% churn range")
            elif sp < args.stream_local_speedup_min:
                failures.append(
                    f"svc_streaming/stream: mid-band local-gear speedup "
                    f"{sp:.2f}x below the "
                    f"{args.stream_local_speedup_min:.1f}x floor "
                    f"({n_mid} events)")
            md = float(n_sum.get("max_drift", 1e9))
            if md > args.stream_drift_ceiling:
                failures.append(
                    f"svc_streaming/stream: worst stream drift {md:.3f} "
                    f"over the {args.stream_drift_ceiling:.2f} ceiling — "
                    "the gear policy is shipping decayed partitions")
            ff = float(n_sum.get("full_frac", 1.0))
            n_local = int(n_sum.get("n_local", 0))
            if ff >= 0.5 or n_local == 0:
                failures.append(
                    f"svc_streaming/stream: gear mix broke — full_frac "
                    f"{ff:.2f} (gate < 0.5), {n_local} local events; the "
                    "mid-range gear is not carrying the 1-20% band")
            print(f"svc_streaming: mid-band local speedup {sp:.2f}x "
                  f"(floor {args.stream_local_speedup_min:.1f}x, "
                  f"{n_mid} events), max drift {md:.3f} "
                  f"(ceiling {args.stream_drift_ceiling:.2f}), full_frac "
                  f"{ff:.2f}, gears inc/loc/full = "
                  f"{int(n_sum.get('n_incremental', 0))}/"
                  f"{n_local}/{int(n_sum.get('n_full', 0))}")
        for key, b in base_st.items():
            if key == "stream" or "p99_update_s" not in b:
                continue
            n = new_st.get(key)
            if n is None:
                if new_st:
                    failures.append(f"svc_streaming/{key}: missing from "
                                    "new results")
                continue
            if "p99_update_s" not in n:
                failures.append(f"svc_streaming/{key}: p99_update_s "
                                "missing from new results")
                continue
            nt, bt = float(n["p99_update_s"]), float(b["p99_update_s"])
            if nt - bt > args.stream_p99_floor and nt > bt * (1 + args.svc_threshold):
                failures.append(
                    f"svc_streaming/{key}: p99 update latency "
                    f"{bt:.4f}s -> {nt:.4f}s "
                    f"(+{(nt / max(bt, 1e-9) - 1) * 100:.0f}%)")
    else:
        print("svc_streaming: no section in baseline, skipped")

    # --- svc_multitenant section: isolation + pool-throughput gates ---
    base_mt = _rows(base, "svc_multitenant")
    if base_mt:
        new_mt = _rows(new, "svc_multitenant")
        if not new_mt:
            failures.append("svc_multitenant: baseline has the section but "
                            "the new results do not — multi-tenant bench "
                            "was skipped")
        for key, b in base_mt.items():
            n = new_mt.get(key)
            if n is None:
                if new_mt:
                    failures.append(f"svc_multitenant/{key}: missing from "
                                    "new results")
                continue
            # Budgeted tenants only: blind-mode rows are the diagnostic
            # contrast and legitimately noisy; the budgeted rows are the
            # deterministic isolation guarantee.
            if b.get("mode") == "budgeted" and "warm_hit_rate" in b:
                nr, br = float(n.get("warm_hit_rate", 0.0)), float(b["warm_hit_rate"])
                if nr < br - args.mt_hit_slack:
                    failures.append(
                        f"svc_multitenant/{key}: warm-hit rate "
                        f"{br:.2f} -> {nr:.2f} — tenant budget isolation broke"
                    )
                np99, bp99 = float(n.get("p99_ms", 0.0)), float(b.get("p99_ms", 0.0))
                if (np99 - bp99 > args.mt_p99_floor * 1e3
                        and np99 > bp99 * (1 + args.svc_threshold)):
                    failures.append(
                        f"svc_multitenant/{key}: p99 latency "
                        f"{bp99:.2f}ms -> {np99:.2f}ms"
                    )
            if key == "cold_throughput" and "workers_speedup" in b:
                # Structural identity first: on few-core runners the
                # thread-vs-process performance delta hides inside run
                # jitter, so "the pool silently became a thread pool" is
                # caught deterministically by configuration, not by the
                # noisy ratio.  Only the executor is identity-checked —
                # the worker count is machine-derived (min(4, cores)), so
                # comparing it across the baseline machine and the CI
                # runner would hard-fail on a core-count difference alone.
                if "executor" in b and n.get("executor") != b["executor"]:
                    failures.append(
                        f"svc_multitenant/cold_throughput: executor "
                        f"{b['executor']!r} -> {n.get('executor')!r} — the "
                        "pool configuration changed under the bench"
                    )
                ns, bs = float(n.get("workers_speedup", 0.0)), float(b["workers_speedup"])
                if ns < bs * args.mt_speedup_frac:
                    failures.append(
                        f"svc_multitenant/cold_throughput: workers speedup "
                        f"{bs:.2f}x -> {ns:.2f}x (floor "
                        f"{args.mt_speedup_frac:.0%} of baseline)"
                    )
        print(f"svc_multitenant: {len(base_mt)} rows gated (hit slack "
              f"{args.mt_hit_slack}, p99 floor {args.mt_p99_floor}s, "
              f"speedup frac {args.mt_speedup_frac})")
    else:
        print("svc_multitenant: no section in baseline, skipped")

    # --- svc_batched section: bucketed-compilation structural gates ---
    base_sb = _rows(base, "svc_batched")
    if base_sb:
        new_sb = _rows(new, "svc_batched")
        if not new_sb:
            failures.append("svc_batched: baseline has the section but the "
                            "new results do not — batched bench was skipped")
        b = base_sb.get("batched")
        n = new_sb.get("batched")
        if b is not None and n is None and new_sb:
            failures.append("svc_batched/batched: summary row missing from "
                            "new results")
        if b is not None and n is not None:
            n_buckets = int(n.get("n_buckets", 0))
            compiles = int(n.get("kernel_compiles_batched", 1 << 30))
            if n_buckets == 0:
                failures.append("svc_batched/batched: n_buckets is 0 — "
                                "bucketing stopped engaging")
            elif compiles > n_buckets + 1:
                failures.append(
                    f"svc_batched/batched: {compiles} kernel compiles for "
                    f"{n_buckets} buckets (gate <= n_buckets + 1) — "
                    "bucket sharing broke"
                )
            if not n.get("byte_identical", False):
                failures.append("svc_batched/batched: batched results are "
                                "not byte-identical to per-request serving")
            ns = float(n.get("speedup", 0.0))
            if ns < args.batched_speedup_min:
                failures.append(
                    f"svc_batched/batched: batched/unbatched speedup "
                    f"{ns:.2f}x below the {args.batched_speedup_min:.1f}x floor"
                )
            nh = float(n.get("hit_rate_batched", 0.0))
            bh = float(b.get("hit_rate_batched", 0.0))
            if nh < bh - args.batched_hit_slack:
                failures.append(
                    f"svc_batched/batched: bucket-cache hit rate "
                    f"{bh:.3f} -> {nh:.3f} (slack {args.batched_hit_slack})"
                )
            print(f"svc_batched: speedup {ns:.2f}x (floor "
                  f"{args.batched_speedup_min:.1f}x), {compiles} compiles / "
                  f"{n_buckets} buckets, hit rate {nh:.3f} "
                  f"(baseline {bh:.3f})")
    else:
        print("svc_batched: no section in baseline, skipped")

    # --- svc_chaos section: replication robustness gates ---
    base_ch = _rows(base, "svc_chaos")
    if base_ch:
        new_ch = _rows(new, "svc_chaos")
        if not new_ch:
            failures.append("svc_chaos: baseline has the section but the "
                            "new results do not — chaos bench was skipped")
        b_fo, n_fo = base_ch.get("chaos_failover"), new_ch.get("chaos_failover")
        if b_fo is not None and n_fo is None and new_ch:
            failures.append("svc_chaos/chaos_failover: row missing from "
                            "new results")
        if n_fo is not None:
            lost = int(n_fo.get("lost_tickets", 1 << 30))
            if lost != 0:
                failures.append(
                    f"svc_chaos/chaos_failover: {lost} lost tickets under "
                    "replica kill — failover dropped requests")
            if not n_fo.get("byte_identical", False):
                failures.append(
                    "svc_chaos/chaos_failover: failover responses are not "
                    "byte-identical to the fault-free run")
            nr = float(n_fo.get("recovery_latency_s", 0.0))
            br = float(b_fo.get("recovery_latency_s", 0.0)) if b_fo else 0.0
            if (nr - br > args.chaos_recovery_floor
                    and nr > br * (1 + args.chaos_recovery_threshold)):
                failures.append(
                    f"svc_chaos/chaos_failover: recovery latency "
                    f"{br:.3f}s -> {nr:.3f}s "
                    f"(+{(nr / max(br, 1e-9) - 1) * 100:.0f}%)")
        b_hg, n_hg = base_ch.get("chaos_hedge"), new_ch.get("chaos_hedge")
        if b_hg is not None and n_hg is None and new_ch:
            failures.append("svc_chaos/chaos_hedge: row missing from "
                            "new results")
        if n_hg is not None:
            if "hedge_win_rate" not in n_hg:
                failures.append("svc_chaos/chaos_hedge: hedge_win_rate "
                                "missing from new results")
            elif float(n_hg["hedge_win_rate"]) <= 0.0:
                failures.append(
                    "svc_chaos/chaos_hedge: hedge win rate is 0 against the "
                    "injected straggler — hedging stopped firing or winning")
            if int(n_hg.get("lost_tickets", 1 << 30)) != 0:
                failures.append("svc_chaos/chaos_hedge: lost tickets in the "
                                "hedging scenario")
            np99 = float(n_hg.get("p99_hedge_ms", 0.0))
            bp99 = float(n_hg.get("p99_nohedge_ms", 0.0))
            if bp99 > 0 and np99 > bp99 * args.chaos_p99_frac:
                failures.append(
                    f"svc_chaos/chaos_hedge: hedged p99 {np99:.0f}ms is not "
                    f"under {args.chaos_p99_frac:.0%} of the no-hedge p99 "
                    f"{bp99:.0f}ms — hedging stopped cutting the tail")
        b_k9, n_k9 = base_ch.get("chaos_kill9"), new_ch.get("chaos_kill9")
        if b_k9 is not None and n_k9 is None and new_ch:
            failures.append("svc_chaos/chaos_kill9: row missing from "
                            "new results")
        if n_k9 is not None:
            lost = int(n_k9.get("lost_tickets", 1 << 30))
            if lost != 0:
                failures.append(
                    f"svc_chaos/chaos_kill9: {lost} lost tickets under a "
                    "SIGKILLed worker process — cross-process failover "
                    "dropped requests")
            if not n_k9.get("byte_identical", False):
                failures.append(
                    "svc_chaos/chaos_kill9: process-transport responses are "
                    "not byte-identical to the in-process fault-free run")
            nr = float(n_k9.get("recovery_latency_s", 0.0))
            br = float(b_k9.get("recovery_latency_s", 0.0)) if b_k9 else 0.0
            if (nr - br > args.chaos_recovery_floor
                    and nr > br * (1 + args.chaos_recovery_threshold)):
                failures.append(
                    f"svc_chaos/chaos_kill9: recovery latency "
                    f"{br:.3f}s -> {nr:.3f}s "
                    f"(+{(nr / max(br, 1e-9) - 1) * 100:.0f}%)")
            print(f"svc_chaos kill9: lost={int(n_k9.get('lost_tickets', -1))}, "
                  f"byte_identical={bool(n_k9.get('byte_identical'))}, "
                  f"recovery {float(n_k9.get('recovery_latency_s', 0.0)):.3f}s "
                  f"(killed {n_k9.get('killed_replica')!r} after "
                  f"{int(n_k9.get('kill_after_jobs', 0))} jobs)")
        b_fl, n_fl = base_ch.get("chaos_flood"), new_ch.get("chaos_flood")
        if b_fl is not None and n_fl is None and new_ch:
            failures.append("svc_chaos/chaos_flood: row missing from "
                            "new results")
        if n_fl is not None:
            # Hard structural claims first — none of these carry timing
            # noise, so they gate exactly.
            vr = int(n_fl.get("victim_rejections", 1 << 30))
            if vr != 0:
                failures.append(
                    f"svc_chaos/chaos_flood: {vr} victim rejections — "
                    "bounded admission shed a well-behaved tenant")
            if int(n_fl.get("flooder_rejections", 0)) <= 0:
                failures.append(
                    "svc_chaos/chaos_flood: the flooder was never rejected "
                    "— the queue bound stopped engaging")
            elif not n_fl.get("retry_after_valid", False):
                failures.append(
                    "svc_chaos/chaos_flood: a flooder rejection carried "
                    "retry_after_s <= 0 — the backpressure hint broke")
            if int(n_fl.get("breaker_trips", 0)) <= 0:
                failures.append(
                    "svc_chaos/chaos_flood: the flooder's circuit breaker "
                    "never tripped under sustained rejection")
            if not n_fl.get("breaker_recovered", False):
                failures.append(
                    "svc_chaos/chaos_flood: the breaker did not re-close "
                    "after the flood stopped — half-open probing broke")
            if not n_fl.get("rejection_wire_identical", False):
                failures.append(
                    "svc_chaos/chaos_flood: an AdmissionRejectedError "
                    "crossed the process transport with different args than "
                    "in-process — the typed error frame broke")
            # Victim-latency claim, with an absolute floor under the ratio.
            np99 = float(n_fl.get("victim_p99_flood_ms", 0.0))
            bp99 = float(n_fl.get("victim_p99_noflood_ms", 0.0))
            if (np99 > args.overload_floor_ms
                    and bp99 > 0
                    and np99 > bp99 * args.overload_threshold):
                failures.append(
                    f"svc_chaos/chaos_flood: victim p99 {bp99:.1f}ms -> "
                    f"{np99:.1f}ms under flood "
                    f"({np99 / max(bp99, 1e-9):.2f}x, gate "
                    f"{args.overload_threshold:.1f}x above "
                    f"{args.overload_floor_ms:.0f}ms) — overload isolation "
                    "stopped protecting well-behaved tenants")
            print(f"svc_chaos flood: victim p99 {bp99:.1f}ms -> {np99:.1f}ms "
                  f"(gate {args.overload_threshold:.1f}x / "
                  f"{args.overload_floor_ms:.0f}ms floor), "
                  f"victim_rejections={int(n_fl.get('victim_rejections', -1))}, "
                  f"flooder rejected "
                  f"{int(n_fl.get('flooder_rejections', 0))}/"
                  f"{int(n_fl.get('flooder_submits', 0))}, "
                  f"breaker trips={int(n_fl.get('breaker_trips', 0))} "
                  f"recovered={bool(n_fl.get('breaker_recovered'))}, "
                  f"wire_identical={bool(n_fl.get('rejection_wire_identical'))}")
        if n_fo is not None and n_hg is not None:
            print(f"svc_chaos: lost={int(n_fo.get('lost_tickets', -1))}, "
                  f"byte_identical={bool(n_fo.get('byte_identical'))}, "
                  f"recovery {float(n_fo.get('recovery_latency_s', 0.0)):.3f}s "
                  f"(threshold {args.chaos_recovery_threshold:.0%}, floor "
                  f"{args.chaos_recovery_floor}s); hedge win rate "
                  f"{float(n_hg.get('hedge_win_rate', 0.0)):.2f}, p99 "
                  f"{float(n_hg.get('p99_nohedge_ms', 0.0)):.0f}ms -> "
                  f"{float(n_hg.get('p99_hedge_ms', 0.0)):.0f}ms "
                  f"(frac {args.chaos_p99_frac})")
    else:
        print("svc_chaos: no section in baseline, skipped")

    # --- perf section: coarsening-stage gate (coarsen_s + level count) ---
    base_perf = _rows(base, "perf")
    if base_perf:
        new_perf = _rows(new, "perf")
        if not new_perf:
            failures.append("perf: baseline has a perf section but the new "
                            "results do not — stage bench was skipped")
        new_coarsen = base_coarsen = 0.0
        for graph, b in base_perf.items():
            n = new_perf.get(graph)
            if n is None:
                if new_perf:
                    failures.append(f"perf/{graph}: missing from new results")
                continue
            if "coarsen_s" in b and "coarsen_s" not in n:
                # Mirror of the levels==0 guard below: a gated field
                # vanishing from the new rows is broken stage reporting
                # (and would otherwise read as a free improvement).
                failures.append(f"perf/{graph}: coarsen_s missing from "
                                "new results — stage reporting broke")
            new_coarsen += float(n.get("coarsen_s", 0.0))
            base_coarsen += float(b.get("coarsen_s", 0.0))
            if "levels" in b:
                nl, bl = int(n.get("levels", 0)), int(b["levels"])
                if bl > 0 and nl == 0:
                    # levels is never 0 when PartitionStats flow (a run
                    # without coarsening still reports 1) — 0 means the
                    # stage stats stopped flowing, which must not pass as
                    # an "improvement".
                    failures.append(
                        f"perf/{graph}: V-cycle stats missing (levels 0, "
                        f"baseline {bl}) — stage reporting broke"
                    )
                elif nl > bl + args.levels_slack:
                    failures.append(
                        f"perf/{graph}: V-cycle levels {bl} -> {nl} "
                        f"(slack {args.levels_slack})"
                    )
        if (
            new_coarsen - base_coarsen > args.coarsen_floor
            and new_coarsen > base_coarsen * (1 + args.coarsen_threshold)
        ):
            failures.append(
                f"perf/total: coarsen_s {base_coarsen:.4f}s -> "
                f"{new_coarsen:.4f}s "
                f"(+{(new_coarsen / max(base_coarsen, 1e-9) - 1) * 100:.0f}%)"
            )
        print(f"perf stages: {len(base_perf)} graphs gated (total coarsen_s "
              f"{base_coarsen:.3f}s -> {new_coarsen:.3f}s, threshold "
              f"{args.coarsen_threshold:.0%}, floor {args.coarsen_floor}s, "
              f"levels slack {args.levels_slack})")
    else:
        print("perf stages: no perf section in baseline, skipped")

    if failures:
        print("BENCH REGRESSION:")
        for f_ in failures:
            print(f"  - {f_}")
        return 1
    print("no regression")
    return 0


if __name__ == "__main__":
    sys.exit(main())

#!/usr/bin/env python
"""CI gate: diff a fresh benchmark JSON against the committed baseline.

    python scripts/check_bench_regression.py NEW.json BASELINE.json \
        [--threshold 0.25] [--abs-floor 0.25]

Compares the fig6 EP partition times per graph (the paper's headline cost)
and fails (exit 1) when any graph regresses by more than ``threshold``
(relative) AND ``abs-floor`` seconds (absolute — absorbs scheduler noise on
small smoke-scale runs), or when the total EP time regresses by more than
``threshold``.  Quality (vertex cut) is checked too: EP cut must not grow
by more than 10% on any graph — a partition-quality regression is a bug
even if it happens to run faster.
"""
from __future__ import annotations

import argparse
import json
import sys


def _fig6_rows(doc: dict) -> dict[str, dict]:
    rows = doc.get("sections", {}).get("fig6") or []
    return {r["graph"]: r for r in rows}


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("new_json")
    ap.add_argument("baseline_json")
    ap.add_argument("--threshold", type=float, default=0.25,
                    help="max tolerated relative regression (default 25%%)")
    ap.add_argument("--abs-floor", type=float, default=0.25,
                    help="ignore absolute deltas below this many seconds")
    ap.add_argument("--cut-threshold", type=float, default=0.10,
                    help="max tolerated relative vertex-cut growth")
    args = ap.parse_args(argv)

    with open(args.new_json) as f:
        new = json.load(f)
    with open(args.baseline_json) as f:
        base = json.load(f)

    new_rows, base_rows = _fig6_rows(new), _fig6_rows(base)
    if not new_rows:
        print("ERROR: no fig6 section in the new results")
        return 1
    if not base_rows:
        print("ERROR: no fig6 section in the baseline")
        return 1

    failures = []
    new_total = base_total = 0.0
    for graph, b in base_rows.items():
        n = new_rows.get(graph)
        if n is None:
            failures.append(f"{graph}: missing from new results")
            continue
        nt, bt = float(n["ep_t"]), float(b["ep_t"])
        new_total += nt
        base_total += bt
        if nt - bt > args.abs_floor and nt > bt * (1 + args.threshold):
            failures.append(
                f"{graph}: EP partition time {bt:.3f}s -> {nt:.3f}s "
                f"(+{(nt / max(bt, 1e-9) - 1) * 100:.0f}%)"
            )
        nq, bq = float(n["ep_q"]), float(b["ep_q"])
        if nq > bq * (1 + args.cut_threshold) and nq - bq > 2:
            failures.append(
                f"{graph}: EP vertex cut {bq:.0f} -> {nq:.0f} "
                f"(+{(nq / max(bq, 1.0) - 1) * 100:.0f}%)"
            )
    if (
        base_total > 0
        and new_total - base_total > args.abs_floor
        and new_total > base_total * (1 + args.threshold)
    ):
        failures.append(
            f"total: EP partition time {base_total:.3f}s -> {new_total:.3f}s"
        )

    print(f"fig6 EP time: baseline {base_total:.3f}s, new {new_total:.3f}s "
          f"({len(base_rows)} graphs, threshold {args.threshold:.0%}, "
          f"floor {args.abs_floor}s)")
    if failures:
        print("BENCH REGRESSION:")
        for f_ in failures:
            print(f"  - {f_}")
        return 1
    print("no regression")
    return 0


if __name__ == "__main__":
    sys.exit(main())

"""svc_batched: bucketed compilation + micro-batching vs per-shape compiles.

The many-small-graphs serving scenario the ROADMAP targets ("millions of
users, thousands of small graphs"): a pool of >100 distinct matrix
structures drawn from 4 shape families, served by 3 tenants.  Two phases
over the *same* warm plan cache (partitioning is off the measured path in
both — this bench isolates the kernel-compilation axis):

  * **unbatched** — the pre-PR design: a dedicated jit per structure
    through a bounded compile cache (capacity 32 « pool size), served
    sequentially.  The pool thrashes the cache, so steady state recompiles
    on every request — first-request p99 everywhere, forever.
  * **batched** — the bucketed path: every structure falls into one of
    <= 4 geometric shape buckets; 3 client threads push requests through
    ``GraphServer.submit`` and same-bucket arrivals coalesce into stacked
    kernel launches.  The same 32-entry compile cache now holds the entire
    working set (one executable per bucket), so steady state never
    compiles.

Claims gated by CI (``scripts/check_bench_regression.py``):

  * distinct kernel compiles in the batched phase <= n_buckets + 1;
  * steady-state requests/sec >= 3x the unbatched baseline;
  * batched results byte-identical (after de-padding) to per-request
    dedicated serving, for every structure in the pool;
  * bucket-cache hit rate does not regress vs the committed baseline.

Row keys (CI baseline stable): ``batched`` for the summary claims,
``bucket=<label>`` per compile bucket (compiles/hits/operand elems),
``batch_hist`` for the batch-size histogram rendered by
``scripts/print_stage_times.py``.
"""
from __future__ import annotations

import threading
import time

import numpy as np

from repro.core import PartitionService
from repro.core.graph import synthetic_bipartite_graph
from repro.runtime import BucketPolicy, GraphRequest, GraphServer

#: 4 shape families x GRAPHS_PER_FAMILY distinct structures; with the
#: default BucketPolicy (floors 256/1024, growth 2) the families land in
#: exactly 4 buckets: (256,256,e1024), (256,256,e2048), (512,512,e2048),
#: (512,512,e1024).
FAMILIES = [
    # (n_rows, n_cols, nnz_per_row) — nnz below is post-dedup, what the
    # generator actually emits.
    (150, 150, 4),    # ~500 nnz   -> r256 c256 e1024
    (150, 150, 16),   # ~1130 nnz  -> r256 c256 e2048
    (300, 300, 5),    # ~1170 nnz  -> r512 c512 e2048
    (300, 300, 3),    # ~800 nnz   -> r512 c512 e1024
]
GRAPHS_PER_FAMILY = 26  # 104 distinct structures >= the 100-graph floor
N_TENANTS = 3
K = 8
COMPILE_CACHE_ENTRIES = 32  # both phases; << pool size, >= bucket count
MAX_BATCH = 8
MAX_WAIT_MS = 4.0
PASSES_BATCHED = 2  # pass 1 doubles as the byte-identity check


def _pcts(samples_s: list[float]) -> tuple[float, float]:
    xs = sorted(samples_s)
    if not xs:
        return 0.0, 0.0
    return (
        xs[min(len(xs) - 1, int(0.50 * len(xs)))] * 1e3,
        xs[min(len(xs) - 1, int(0.99 * len(xs)))] * 1e3,
    )


def _build_pool(seed: int = 0) -> list[dict]:
    """The request pool: (structure, vals, deterministic x) per graph."""
    rng = np.random.default_rng(seed)
    pool = []
    for fam, (n_rows, n_cols, nnz_per_row) in enumerate(FAMILIES):
        for g in range(GRAPHS_PER_FAMILY):
            _, rows, cols = synthetic_bipartite_graph(
                n_rows, n_cols, nnz_per_row, seed=1000 * fam + g
            )
            pool.append({
                "n_rows": n_rows,
                "n_cols": n_cols,
                "rows": rows,
                "cols": cols,
                "vals": rng.standard_normal(rows.shape[0]).astype(np.float32),
                "x": rng.standard_normal(n_cols).astype(np.float32),
                "tenant": f"tenant{(fam * GRAPHS_PER_FAMILY + g) % N_TENANTS}",
            })
    return pool


def _request(entry: dict) -> GraphRequest:
    return GraphRequest(
        entry["n_rows"], entry["n_cols"], entry["rows"], entry["cols"],
        entry["vals"], entry["x"], tenant=entry["tenant"],
    )


def _unbatched_phase(svc: PartitionService, pool: list[dict]):
    """Sequential pass, dedicated compile per structure (bucketing off)."""
    server = GraphServer(
        svc, k=K, interpret=True, bucketing=None,
        compile_cache_entries=COMPILE_CACHE_ENTRIES, start_batcher=False,
    )
    lat: list[float] = []
    y_ref: list[np.ndarray] = []
    t_all = time.perf_counter()
    for entry in pool:
        t0 = time.perf_counter()
        res = server.serve(_request(entry))
        lat.append(time.perf_counter() - t0)
        y_ref.append(np.asarray(res.y))
    elapsed = time.perf_counter() - t_all
    return elapsed, lat, y_ref, server.stats()


def _batched_phase(svc: PartitionService, pool: list[dict], y_ref: list[np.ndarray]):
    """Concurrent clients through submit(); pass 1 checks byte identity."""
    identical = [True]
    lat: list[float] = []
    lock = threading.Lock()
    with GraphServer(
        svc, k=K, interpret=True, bucketing=BucketPolicy(),
        compile_cache_entries=COMPILE_CACHE_ENTRIES,
        max_batch=MAX_BATCH, max_wait_ms=MAX_WAIT_MS,
    ) as server:

        def client(cid: int) -> None:
            mine = [i for i, e in enumerate(pool) if e["tenant"] == f"tenant{cid}"]
            for pass_no in range(PASSES_BATCHED):
                for i in mine:
                    entry = pool[i]
                    t0 = time.perf_counter()
                    res = server.submit(_request(entry)).wait(120.0)
                    dt = time.perf_counter() - t0
                    ok = (
                        pass_no != 0
                        or np.array_equal(np.asarray(res.y), y_ref[i])
                    )
                    with lock:
                        lat.append(dt)
                        if not ok:
                            identical[0] = False

        threads = [threading.Thread(target=client, args=(c,)) for c in range(N_TENANTS)]
        t_all = time.perf_counter()
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        elapsed = time.perf_counter() - t_all
        stats = server.stats()
    return elapsed, lat, identical[0], stats


def main(scale: float = 0.3) -> list[dict]:
    # The pool is intentionally scale-independent: the scenario is *many
    # small* graphs — shrinking them further would leave nothing to bucket,
    # growing them changes the story to few-large (covered by svc).
    del scale
    pool = _build_pool()
    n_graphs = len(pool)
    print(f"\n== svc_batched: bucketed compiles + micro-batching "
          f"({n_graphs} graphs / {len(FAMILIES)} families, {N_TENANTS} tenants, "
          f"compile cache {COMPILE_CACHE_ENTRIES}) ==")

    with PartitionService(max_entries=n_graphs + 16) as svc:
        # Warm the plan cache outside both measured phases: this bench is
        # about kernel compilation, and §4.2 already keeps partitioning off
        # the request path.
        for entry in pool:
            svc.get_spmv_plan(
                entry["n_rows"], entry["n_cols"], entry["rows"], entry["cols"],
                K, tenant=entry["tenant"],
            )

        un_elapsed, un_lat, y_ref, un_stats = _unbatched_phase(svc, pool)
        b_elapsed, b_lat, identical, b_stats = _batched_phase(svc, pool, y_ref)

    un_rps = n_graphs / max(un_elapsed, 1e-9)
    n_req_b = n_graphs * PASSES_BATCHED
    b_rps = n_req_b / max(b_elapsed, 1e-9)
    un_p50, un_p99 = _pcts(un_lat)
    b_p50, b_p99 = _pcts(b_lat)
    n_buckets = len(b_stats["buckets"])
    compiles = b_stats["misses"]
    hit_rate = b_stats["hits"] / max(b_stats["hits"] + b_stats["misses"], 1)

    rows: list[dict] = [{
        "graph": "batched",
        "n_graphs": n_graphs,
        "n_tenants": N_TENANTS,
        "requests_unbatched": n_graphs,
        "requests_batched": n_req_b,
        "req_per_s_unbatched": un_rps,
        "req_per_s_batched": b_rps,
        "speedup": b_rps / max(un_rps, 1e-9),
        "p50_ms_unbatched": un_p50,
        "p99_ms_unbatched": un_p99,
        "p50_ms_batched": b_p50,
        "p99_ms_batched": b_p99,
        "n_buckets": n_buckets,
        "kernel_compiles_batched": compiles,
        "kernel_compiles_unbatched": un_stats["misses"],
        "kernel_evictions_unbatched": un_stats["evictions"],
        "compiles_ok": compiles <= n_buckets + 1,
        "hit_rate_batched": hit_rate,
        "byte_identical": bool(identical),
    }]
    for label, b in sorted(b_stats["buckets"].items()):
        rows.append({
            "graph": f"bucket={label}",
            "label": label,
            "batch": b["batch"],
            "e_max": b["e_max"],
            "n_rows": b["n_rows"],
            "operand_elems": b["operand_elems"],
            "hits": b["hits"],
            "compiled": b["compiled"],
        })
    rows.append({
        "graph": "batch_hist",
        "hist": {str(k): v for k, v in b_stats["batch_hist"].items()},
    })

    r = rows[0]
    print(f"{'phase':12s} {'req/s':>9s} {'p50_ms':>8s} {'p99_ms':>8s} "
          f"{'compiles':>9s} {'evict':>6s}")
    print(f"{'unbatched':12s} {un_rps:9.1f} {un_p50:8.2f} {un_p99:8.2f} "
          f"{un_stats['misses']:9d} {un_stats['evictions']:6d}")
    print(f"{'batched':12s} {b_rps:9.1f} {b_p50:8.2f} {b_p99:8.2f} "
          f"{compiles:9d} {b_stats['evictions']:6d}")
    print(f"claims: {r['speedup']:.2f}x req/s (gate >= 3x); "
          f"{compiles} compiles for {n_buckets} buckets "
          f"(gate <= {n_buckets + 1}); byte-identical: {identical}; "
          f"bucket hit rate {hit_rate:.3f}")
    return rows


if __name__ == "__main__":
    main()

"""Paper Table 3: sensitivity to thread-block size (here: cluster size).

GPU thread-block size b <-> tasks per cache domain; k = m / b clusters.
Smaller blocks give better locality (fewer distinct objects per domain) but
more cut (more domains) and longer partition time — the paper's trade-off,
reproduced via modeled loads + partition time across b in {256, 512, 1024}.
"""
from __future__ import annotations

import time

from repro.core import build_pack_plan, edge_partition

from .graphs import spmv_matrices


def main(scale: float = 0.35) -> list[dict]:
    sizes = (256, 512, 1024)
    print("\n== table3: block-size sensitivity ==")
    print(f"{'matrix':16s} " + " | ".join(f"b={b}: loads, part_s" for b in sizes))
    rows = []
    for name, (edges, r, c, nr, nc) in spmv_matrices(scale).items():
        row = {"matrix": name}
        cells = []
        for b in sizes:
            k = max(2, edges.m // b)
            t0 = time.perf_counter()
            ep = edge_partition(edges, k, method="ep")
            dt = time.perf_counter() - t0
            plan = build_pack_plan(nr, nc, r, c, ep.labels, k, pad=8)
            row[f"loads_b{b}"] = plan.modeled_loads()
            row[f"part_s_b{b}"] = dt
            row[f"vmem_b{b}"] = plan.vmem_bytes()
            cells.append(f"{plan.modeled_loads():8d}, {dt:6.2f}")
        rows.append(row)
        print(f"{name:16s} " + " | ".join(cells))
    print("smaller blocks -> fewer loads but longer partition time "
          "(paper: net effect roughly balanced; 1024 chosen as default)")
    return rows


if __name__ == "__main__":
    main()

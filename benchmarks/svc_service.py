"""svc: PartitionService latency — cold vs warm-cache vs incremental.

Measures the serving-path numbers the roadmap cares about (paper §4.2's
amortization argument, quantified):

  * cold_s    — full multilevel partition + evaluation through the service;
  * warm_s    — fingerprint-cache hit for the SAME graph (the repeated-
                request serving case); warm_speedup = cold/warm, target
                >= 100x at scale 0.3;
  * incr_s    — incremental repartition after a 1% edge-churn batch
                (0.5% deletions + 0.5% insertions); incr_speedup =
                full-repartition-on-churned-graph / incr, target >= 1.5x
                (the vectorized cold path compressed this gap: full
                multilevel is ~3.6x faster than it was, while the
                localized Python refinement is unchanged — see the
                ROADMAP item on vectorizing the incremental path);
  * drift     — incremental vertex-cut / full-from-scratch vertex-cut on
                the churned graph (quality drift; ~1.0 means the localized
                refinement holds the line), plus the balance factor.
"""
from __future__ import annotations

import time

import numpy as np

from repro.core import PartitionService, edge_partition

from .graphs import paper_graphs


def main(scale: float = 0.3, k: int = 64, churn: float = 0.01) -> list[dict]:
    print(f"\n== svc: partition service cold/warm/incremental (k={k}, churn={churn:.1%}) ==")
    hdr = (f"{'graph':28s} {'m':>9s} | {'cold_s':>8s} {'warm_s':>9s} {'warm_x':>9s} | "
           f"{'incr_s':>7s} {'full_s':>7s} {'incr_x':>7s} | {'drift':>6s} {'bal':>6s}")
    print(hdr)
    rows = []
    for name, g in paper_graphs(scale).items():
        with PartitionService() as svc:
            t0 = time.perf_counter()
            plan = svc.get(g, k)
            cold_s = time.perf_counter() - t0

            # Warm lookups: median of a few, the steady-state request path.
            warm_times = []
            for _ in range(5):
                t0 = time.perf_counter()
                again = svc.get(g, k)
                warm_times.append(time.perf_counter() - t0)
            assert again is plan
            warm_s = float(np.median(warm_times))

            # 1% churn: half deletions, half random insertions.
            rng = np.random.default_rng(7)
            n_half = max(int(churn * g.m / 2), 1)
            delete_ids = rng.choice(g.m, size=n_half, replace=False)
            ins_u = rng.integers(0, g.n, n_half).astype(np.int64)
            ins_v = rng.integers(0, g.n, n_half).astype(np.int64)
            t0 = time.perf_counter()
            upd = svc.update(
                plan.fingerprint, k, insert_u=ins_u, insert_v=ins_v, delete_ids=delete_ids
            )
            incr_s = time.perf_counter() - t0

            t0 = time.perf_counter()
            full = edge_partition(upd.edges, k, method="ep")
            full_s = time.perf_counter() - t0

            row = {
                "graph": name,
                "m": g.m,
                "cold_s": cold_s,
                "warm_s": warm_s,
                "warm_speedup": cold_s / max(warm_s, 1e-9),
                "incr_s": incr_s,
                "full_s": full_s,
                "incr_speedup": full_s / max(incr_s, 1e-9),
                "incr_source": upd.source,
                "incr_cut": upd.result.quality.vertex_cut,
                "full_cut": full.quality.vertex_cut,
                "cut_drift": upd.result.quality.vertex_cut / max(full.quality.vertex_cut, 1),
                "incr_balance": upd.result.quality.balance,
            }
            rows.append(row)
            print(
                f"{name:28s} {g.m:9d} | {cold_s:8.3f} {warm_s:9.6f} "
                f"{row['warm_speedup']:8.0f}x | {incr_s:7.3f} {full_s:7.3f} "
                f"{row['incr_speedup']:6.1f}x | {row['cut_drift']:6.3f} "
                f"{row['incr_balance']:6.3f}"
            )
    ok_warm = all(r["warm_speedup"] >= 100 for r in rows)
    incr_rows = [r for r in rows if r["incr_source"] == "incremental"]
    # Guard against a vacuous claim: if every graph fell back to a full
    # rerun there is nothing to measure and the claim must read False.
    ok_incr = bool(incr_rows) and all(r["incr_speedup"] >= 1.5 for r in incr_rows)
    print(f"claims: warm-cache >=100x on all graphs: {ok_warm}; "
          f"incremental >=1.5x vs full repartition: {ok_incr} "
          f"({len(incr_rows)}/{len(rows)} graphs took the incremental path); "
          f"max cut drift {max(r['cut_drift'] for r in rows):.3f}; "
          f"max balance {max(r['incr_balance'] for r in rows):.3f}")
    return rows


if __name__ == "__main__":
    main()

"""svc: PartitionService latency — cold vs warm-cache vs incremental.

Measures the serving-path numbers the roadmap cares about (paper §4.2's
amortization argument, quantified):

  * cold_s    — full multilevel partition + evaluation through the service;
  * warm_s    — fingerprint-cache hit for the SAME graph (the repeated-
                request serving case); warm_speedup = cold/warm, target
                >= 100x at scale 0.3;
  * incr_s    — incremental repartition after an edge-churn batch (half
                deletions + half insertions), swept over churn rates
                (0.1% / 1% / 5%); incr_speedup = full-repartition-on-
                churned-graph / incr, target >= 5x at 1% churn now that
                the dirty-region sweep is batched end to end (it was
                1.5-2x with the Python dict/set loops);
  * stage timings — the batched pipeline's dirty-build / placement /
                refine split plus pack, from ``ServicePlan.stage_times_s``
                (rendered by ``scripts/print_stage_times.py``);
  * drift     — incremental vertex-cut / full-from-scratch vertex-cut on
                the churned graph (quality drift; ~1.0 means the localized
                refinement holds the line), plus the balance factor.

The primary row per graph (at ``churn``, default 1%) keeps the plain graph
name so the CI regression baseline keys stay stable; the sweep rows are
keyed ``<graph>|churn=<rate>`` and are gated the same way once they appear
in the baseline.
"""
from __future__ import annotations

import time

import numpy as np

from repro.core import PartitionService, edge_partition

from .graphs import paper_graphs

#: Churn rates swept per graph (the primary ``churn`` rate is measured even
#: if it is not in this tuple).
CHURN_SWEEP = (0.001, 0.01, 0.05)


def _churn_batch(g, rate: float, seed: int = 7):
    """Half deletions, half random insertions totalling ``rate * m`` tasks."""
    rng = np.random.default_rng(seed)
    n_half = max(int(rate * g.m / 2), 1)
    delete_ids = rng.choice(g.m, size=n_half, replace=False)
    ins_u = rng.integers(0, g.n, n_half).astype(np.int64)
    ins_v = rng.integers(0, g.n, n_half).astype(np.int64)
    return ins_u, ins_v, delete_ids


def main(scale: float = 0.3, k: int = 64, churn: float = 0.01) -> list[dict]:
    print(f"\n== svc: partition service cold/warm/incremental (k={k}, "
          f"churn sweep {', '.join(f'{c:.1%}' for c in CHURN_SWEEP)}) ==")
    hdr = (f"{'graph':40s} {'m':>9s} | {'cold_s':>8s} {'warm_s':>9s} {'warm_x':>8s} | "
           f"{'incr_s':>7s} {'full_s':>7s} {'incr_x':>7s} | {'drift':>6s} {'bal':>6s}")
    print(hdr)
    rows = []
    sweep = tuple(sorted(set(CHURN_SWEEP) | {churn}))
    for name, g in paper_graphs(scale).items():
        with PartitionService() as svc:
            t0 = time.perf_counter()
            plan = svc.get(g, k)
            cold_s = time.perf_counter() - t0

            # Warm lookups: median of a few, the steady-state request path.
            warm_times = []
            for _ in range(5):
                t0 = time.perf_counter()
                again = svc.get(g, k)
                warm_times.append(time.perf_counter() - t0)
            assert again is plan
            warm_s = float(np.median(warm_times))

            for rate in sweep:
                ins_u, ins_v, delete_ids = _churn_batch(g, rate)
                t0 = time.perf_counter()
                upd = svc.update(
                    plan.fingerprint, k,
                    insert_u=ins_u, insert_v=ins_v, delete_ids=delete_ids,
                )
                incr_s = time.perf_counter() - t0

                t0 = time.perf_counter()
                full = edge_partition(upd.edges, k, method="ep")
                full_s = time.perf_counter() - t0

                primary = rate == churn
                st = upd.stage_times_s or {}
                row = {
                    "graph": name if primary else f"{name}|churn={rate:.1%}",
                    "m": g.m,
                    "churn": rate,
                    "incr_s": incr_s,
                    "full_s": full_s,
                    "incr_speedup": full_s / max(incr_s, 1e-9),
                    "incr_source": upd.source,
                    "drift_est": float(getattr(upd, "drift", 0.0)),
                    "incr_cut": upd.result.quality.vertex_cut,
                    "full_cut": full.quality.vertex_cut,
                    "cut_drift": upd.result.quality.vertex_cut
                    / max(full.quality.vertex_cut, 1),
                    "incr_balance": upd.result.quality.balance,
                    "pack_s": st.get("pack", 0.0),
                }
                if upd.source == "incremental":
                    # Full-fallback rows get no inc_* keys: zeros here would
                    # render a full rerun as an impossibly fast incremental
                    # update in the stage table.
                    row.update(
                        inc_dirty_s=st.get("inc_dirty", 0.0),
                        inc_place_s=st.get("inc_place", 0.0),
                        inc_refine_s=st.get("inc_refine", 0.0),
                    )
                elif upd.source == "local":
                    # Local-gear rows carry the V-cycle's stage split instead
                    # (dirty-region build / placement / coarsen / refine+polish).
                    row.update(
                        loc_dirty_s=st.get("loc_dirty", 0.0),
                        loc_place_s=st.get("loc_place", 0.0),
                        loc_coarsen_s=st.get("loc_coarsen", 0.0),
                        loc_refine_s=st.get("loc_refine", 0.0),
                    )
                if primary:
                    row.update(
                        cold_s=cold_s,
                        warm_s=warm_s,
                        warm_speedup=cold_s / max(warm_s, 1e-9),
                    )
                rows.append(row)
                cw = (f"{cold_s:8.3f} {warm_s:9.6f} {row['warm_speedup']:7.0f}x"
                      if primary else f"{'':8s} {'':9s} {'':8s}")
                print(
                    f"{row['graph']:40s} {g.m:9d} | {cw} | {incr_s:7.3f} "
                    f"{full_s:7.3f} {row['incr_speedup']:6.1f}x | "
                    f"{row['cut_drift']:6.3f} {row['incr_balance']:6.3f}"
                )
    primary_rows = [r for r in rows if "warm_s" in r]
    ok_warm = all(r["warm_speedup"] >= 100 for r in primary_rows)
    incr_rows = [r for r in primary_rows if r["incr_source"] == "incremental"]
    # Guard against a vacuous claim: if every graph fell back to a full
    # rerun there is nothing to measure and the claim must read False.
    ok_incr = bool(incr_rows) and all(r["incr_speedup"] >= 5 for r in incr_rows)
    print(f"claims: warm-cache >=100x on all graphs: {ok_warm}; "
          f"incremental >=5x vs full repartition at {churn:.1%} churn: {ok_incr} "
          f"({len(incr_rows)}/{len(primary_rows)} graphs took the incremental path); "
          f"max cut drift {max(r['cut_drift'] for r in rows):.3f}; "
          f"max balance {max(r['incr_balance'] for r in rows):.3f}")
    return rows


if __name__ == "__main__":
    main()

"""svc_multitenant: tenant-budget isolation + worker-pool cold throughput.

Drives the multi-tenant scheduling subsystem under the contention pattern
that motivated it (ROADMAP: "multi-tenant cache eviction policy"):

  * **Isolation** — three victim tenants each own one hot graph and keep
    re-requesting it while a fourth tenant bursts ``N_FLOOD`` one-shot
    graphs through the shared cache between every pair of victim rounds —
    a burst wider than the whole cache, the classic scan-thrash pattern.
    Per-tenant byte budgets (2.5x one hot plan) mean the flood can only
    evict the flooder's own entries: every victim request after warm-up
    must stay a cache hit.  The same scenario is replayed *tenant-blind*
    (one global byte cap with the same total memory, no per-tenant
    budgets) as the contrast rows — there each burst flushes the victims'
    plans before they return, and their warm-hit rate collapses.
    Measured per tenant: warm-hit rate after warm-up, p50/p99 request
    latency (submit -> result, hits included), hit/miss/eviction counters.
  * **Throughput** — N distinct cold graphs through a single-worker service
    (PR 1's architecture) vs a 4-worker process-executor pool.  Partition
    compute is CPU-bound numpy, so thread pools cannot parallelize it (the
    GIL); the process pool's speedup is bounded by the machine's real core
    count — the committed baseline records what this runner delivers, and
    the CI gate holds the ratio (see ``check_bench_regression.py``).

Row keys (CI baseline stable): ``tenant=<name>|mode=<budgeted|blind>`` for
the isolation rows, ``cold_throughput`` for the pool comparison, and
``metrics`` for the ServiceMetrics snapshot (queue depth, utilization,
latency histogram) rendered by ``scripts/print_stage_times.py``.
"""
from __future__ import annotations

import dataclasses
import os
import time

from repro.core import PartitionService, synthetic_powerlaw_graph

#: Isolation scenario shape: each round the flooder bursts N_FLOOD one-shot
#: graphs (wider than the blind cache's ~10-plan cap, so a tenant-blind
#: eviction policy must flush the victims every round), then every victim
#: re-requests its hot graph.
N_VICTIMS = 3
N_FLOOD = 12
ROUNDS = 5
#: Throughput scenario shape.  The pool is sized to the machine: process
#: workers beyond the real core count just thrash each other's caches (on
#: a >= 4-core host this is the issue's 4-worker configuration).
N_COLD = 8
POOL_WORKERS = max(2, min(4, os.cpu_count() or 1))


def _victim_graph(scale: float, i: int):
    s = max(scale, 0.01)
    return synthetic_powerlaw_graph(
        int(20_000 * s), int(80_000 * s), alpha=2.1 + 0.1 * i, seed=100 + i
    )


def _flood_graph(scale: float, i: int):
    s = max(scale, 0.01)
    return synthetic_powerlaw_graph(int(20_000 * s), int(80_000 * s), seed=200 + i)


def _cold_graph(scale: float, i: int):
    # Floor the size: the pool comparison needs per-plan compute that
    # dwarfs dispatch + pickling, or it measures overhead, not workers.
    s = max(scale, 0.2)
    return synthetic_powerlaw_graph(int(16_000 * s), int(64_000 * s), seed=300 + i)


def _pcts(samples_s: list[float]) -> tuple[float, float]:
    """(p50_ms, p99_ms) of per-request latencies."""
    xs = sorted(samples_s)
    if not xs:
        return 0.0, 0.0
    return (
        xs[min(len(xs) - 1, int(0.50 * len(xs)))] * 1e3,
        xs[min(len(xs) - 1, int(0.99 * len(xs)))] * 1e3,
    )


def _contention_run(scale: float, k: int, budget: int, mode: str) -> tuple[list[dict], dict]:
    """One isolation scenario; returns (per-tenant rows, metrics snapshot)."""
    victims = [(f"victim{i}", _victim_graph(scale, i)) for i in range(N_VICTIMS)]
    kwargs = (
        dict(default_tenant_budget=budget)
        if mode == "budgeted"
        # Blind contrast: same total memory, no per-tenant isolation.
        else dict(max_bytes=budget * (N_VICTIMS + 1))
    )
    lat: dict[str, list[float]] = {name: [] for name, _ in victims}
    lat["flooder"] = []
    with PartitionService(workers=2, **kwargs) as svc:
        hits_warm: dict[str, int] = {name: 0 for name, _ in victims}
        reqs_warm: dict[str, int] = {name: 0 for name, _ in victims}
        # Warm-up round: every victim's hot plan goes cold -> cached.
        for name, g in victims:
            t0 = time.perf_counter()
            svc.get(g, k, tenant=name)
            lat[name].append(time.perf_counter() - t0)
        flood = [_flood_graph(scale, i) for i in range(N_FLOOD)]
        for _ in range(ROUNDS):
            # Flood burst: wider than the blind cache, below the victims'
            # interactive priority.
            for g in flood:
                t0 = time.perf_counter()
                svc.get(g, k, tenant="flooder", priority=-1)
                lat["flooder"].append(time.perf_counter() - t0)
            for name, g in victims:
                t0 = time.perf_counter()
                ticket = svc.submit(g, k, tenant=name, priority=1)
                ticket.result(timeout=600)
                lat[name].append(time.perf_counter() - t0)
                reqs_warm[name] += 1
                hits_warm[name] += bool(ticket.cache_hit)
        snap = svc.metrics()
    rows = []
    for name in [v for v, _ in victims] + ["flooder"]:
        tstats = snap.tenants.get(name, {})
        p50, p99 = _pcts(lat[name])
        row = {
            "graph": f"tenant={name}|mode={mode}",
            "m": victims[0][1].m,
            "mode": mode,
            "tenant": name,
            "p50_ms": p50,
            "p99_ms": p99,
            "hits": tstats.get("hits", 0),
            "misses": tstats.get("misses", 0),
            "evictions": tstats.get("evictions", 0),
        }
        if name in reqs_warm:  # victims: post-warm-up hit rate is the claim
            row["warm_hit_rate"] = hits_warm[name] / max(reqs_warm[name], 1)
        rows.append(row)
    return rows, dataclasses.asdict(snap)


def _throughput_run(scale: float, k: int) -> dict:
    """Cold-plan throughput: 1 worker (thread) vs POOL_WORKERS (process)."""
    graphs = [_cold_graph(scale, i) for i in range(N_COLD)]
    with PartitionService(workers=1) as svc:
        t0 = time.perf_counter()
        tickets = [svc.submit(g, k) for g in graphs]
        for t in tickets:
            t.result(timeout=600)
        t_1w = time.perf_counter() - t0
    with PartitionService(workers=POOL_WORKERS, executor="process") as svc:
        # Warm the spawned workers (module import + numpy init) outside the
        # measured window, one tiny dummy plan per worker.
        warm = [
            svc.submit(synthetic_powerlaw_graph(200, 800, seed=1000 + i), 4)
            for i in range(POOL_WORKERS)
        ]
        for t in warm:
            t.result(timeout=600)
        t0 = time.perf_counter()
        tickets = [svc.submit(g, k) for g in graphs]
        for t in tickets:
            t.result(timeout=600)
        t_nw = time.perf_counter() - t0
        util = svc.metrics().utilization
    return {
        "graph": "cold_throughput",
        "m": graphs[0].m,
        "n_plans": N_COLD,
        "workers": POOL_WORKERS,
        "executor": "process",
        "wall_1w_s": t_1w,
        "wall_nw_s": t_nw,
        "plans_per_s_1w": N_COLD / max(t_1w, 1e-9),
        "plans_per_s_nw": N_COLD / max(t_nw, 1e-9),
        "workers_speedup": t_1w / max(t_nw, 1e-9),
        "pool_utilization": util,
    }


def main(scale: float = 0.3, k: int = 64) -> list[dict]:
    print(f"\n== svc_multitenant: tenant isolation + worker pool (k={k}, "
          f"{N_VICTIMS} victims + flooder, {ROUNDS} rounds) ==")
    # Budget: 2.5x one victim hot plan — room for the hot plan plus churn,
    # not for a flood.
    with PartitionService() as probe:
        plan_bytes = probe.get(_victim_graph(scale, 0), k).nbytes()
    budget = int(plan_bytes * 2.5)

    rows: list[dict] = []
    metrics = None
    for mode in ("budgeted", "blind"):
        mode_rows, snap = _contention_run(scale, k, budget, mode)
        rows.extend(mode_rows)
        if mode == "budgeted":
            metrics = snap
    print(f"{'tenant':26s} {'mode':>9s} {'warm_hit':>9s} {'p50_ms':>8s} "
          f"{'p99_ms':>8s} {'evict':>6s}")
    for r in rows:
        whr = f"{r['warm_hit_rate']:.2f}" if "warm_hit_rate" in r else "-"
        print(f"{r['tenant']:26s} {r['mode']:>9s} {whr:>9s} "
              f"{r['p50_ms']:8.2f} {r['p99_ms']:8.2f} {r['evictions']:6d}")

    thr = _throughput_run(scale, k)
    rows.append(thr)
    print(f"cold throughput: {thr['plans_per_s_1w']:.2f} plans/s @1 worker, "
          f"{thr['plans_per_s_nw']:.2f} plans/s @{POOL_WORKERS} process workers "
          f"({thr['workers_speedup']:.2f}x, pool utilization "
          f"{thr['pool_utilization']:.2f})")

    if metrics is not None:
        lat = metrics["latency_s"]
        mrow = {
            "graph": "metrics",
            "queue_depth": metrics["queue_depth"],
            "queue_depth_max": metrics.get("queue_depth_max", 0),
            "rejected": metrics.get("rejected", 0),
            "shed_deadline": metrics.get("shed_deadline", 0),
            "utilization": metrics["utilization"],
            "jobs_completed": metrics["jobs_completed"],
            "coalesced": metrics["coalesced"],
            "latency_p50_s": lat["p50"],
            "latency_p99_s": lat["p99"],
            "latency_histogram": lat["histogram"],
            "tenants": metrics["tenants"],
        }
        rows.append(mrow)

    budgeted = [r for r in rows if r.get("mode") == "budgeted" and "warm_hit_rate" in r]
    blind = [r for r in rows if r.get("mode") == "blind" and "warm_hit_rate" in r]
    iso_ok = bool(budgeted) and all(r["warm_hit_rate"] >= 0.99 for r in budgeted)
    blind_rate = min((r["warm_hit_rate"] for r in blind), default=1.0)
    print(f"claims: per-tenant budgets hold every victim at warm-hit rate 1.0 "
          f"under flood: {iso_ok} (blind-LRU contrast min rate {blind_rate:.2f}); "
          f"{POOL_WORKERS}-worker cold throughput {thr['workers_speedup']:.2f}x "
          f"single worker")
    return rows


if __name__ == "__main__":
    main()

"""Benchmark driver: one section per paper table/figure + framework extras.

    PYTHONPATH=src python -m benchmarks.run [--scale 0.3]

Sections:
  fig4   degree distributions of the evaluation graphs
  fig6   partition methods: time + quality (the paper's headline table)
  table2 EP-SpMV vs default: modeled loads + partition overhead + allclose
  fig11  normalized transaction counts
  fig12  software vs streaming (texture) cache
  table3 block-size sensitivity
  fig13  general workloads + MoE dispatch + adaptive control (fig14)
  hier   beyond-paper two-level EP (ICI + HBM)
  roofline  dry-run roofline table (if artifacts exist)
"""
from __future__ import annotations

import argparse
import time


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", type=float, default=0.3,
                    help="graph size multiplier for the partitioning benches")
    ap.add_argument("--only", default=None, help="run a single section")
    args = ap.parse_args(argv)

    from . import (
        fig4_degree_dist,
        fig6_partition_methods,
        fig11_transactions,
        fig12_cache_types,
        fig13_apps,
        hierarchy_bench,
        roofline,
        table2_spmv,
        table3_block_size,
    )

    sections = {
        "fig4": lambda: fig4_degree_dist.main(scale=args.scale),
        "fig6": lambda: fig6_partition_methods.main(scale=args.scale),
        "table2": lambda: table2_spmv.main(scale=min(args.scale * 1.5, 1.0)),
        "fig11": lambda: fig11_transactions.main(scale=min(args.scale * 1.5, 1.0)),
        "fig12": lambda: fig12_cache_types.main(),
        "table3": lambda: table3_block_size.main(),
        "fig13": lambda: fig13_apps.main(),
        "hier": lambda: hierarchy_bench.main(),
        "roofline": lambda: roofline.main(),
    }
    t_all = time.perf_counter()
    for name, fn in sections.items():
        if args.only and name != args.only:
            continue
        t0 = time.perf_counter()
        fn()
        print(f"[{name} done in {time.perf_counter() - t0:.1f}s]")
    print(f"\nall benchmarks done in {time.perf_counter() - t_all:.1f}s")


if __name__ == "__main__":
    main()

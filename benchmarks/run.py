"""Benchmark driver: one section per paper table/figure + framework extras.

    PYTHONPATH=src python -m benchmarks.run [--scale 0.3] [--json out.json]

Sections:
  fig4   degree distributions of the evaluation graphs
  fig6   partition methods: time + quality (the paper's headline table)
  table2 EP-SpMV vs default: modeled loads + partition overhead + allclose
  fig11  normalized transaction counts
  fig12  software vs streaming (texture) cache
  table3 block-size sensitivity
  fig13  general workloads + MoE dispatch + adaptive control (fig14)
  hier   beyond-paper two-level EP (ICI + HBM)
  svc    PartitionService: cold vs warm-cache vs incremental repartition
  svc_streaming  long-lived per-tenant churn streams sweeping the 1-20%
         band: drift-gated gear mix (incremental/local/full), p50/p99
         update latency, quality drift vs same-run full rebuilds
  svc_multitenant  tenant-budget isolation under cache flood + worker-pool
         cold-plan throughput (1 worker vs machine-sized process pool)
  svc_batched  bucketed kernel compilation + micro-batched serving vs
         per-shape dedicated compiles (many-small-graphs scenario)
  svc_chaos  replicated plan service under fault injection: kill-a-replica
         failover (zero lost tickets, byte-identical responses) + hedging
         vs an injected straggler
  perf   per-stage partition->pack timings (coarsen/init/refine/pack)
  roofline  dry-run roofline table (if artifacts exist)

``--only`` accepts a comma-separated list (e.g. ``--only fig6,svc,perf``).

``--json PATH`` writes every section's structured rows (plus timings and the
scale) so CI can track the BENCH_* perf trajectory per PR and
``scripts/check_bench_regression.py`` can diff against the baseline.

``--profile`` wraps the selected sections in cProfile and prints the top 20
functions by cumulative time — so when a stage table shows a new dominant
cost, finding the function behind it is one flag away, no editing required.
"""
from __future__ import annotations

import argparse
import json
import time


def _jsonable(obj):
    """Best-effort conversion of section results (numpy scalars etc.)."""
    import numpy as np

    if isinstance(obj, (np.integer,)):
        return int(obj)
    if isinstance(obj, (np.floating,)):
        return float(obj)
    if isinstance(obj, np.ndarray):
        return obj.tolist()
    return str(obj)


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", type=float, default=0.3,
                    help="graph size multiplier for the partitioning benches")
    ap.add_argument("--only", default=None,
                    help="run selected sections (comma-separated)")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="write section results + timings as JSON")
    ap.add_argument("--profile", action="store_true",
                    help="run the selected sections under cProfile and "
                         "print the top 20 functions by cumulative time")
    args = ap.parse_args(argv)

    from . import (
        fig4_degree_dist,
        fig6_partition_methods,
        fig11_transactions,
        fig12_cache_types,
        fig13_apps,
        hierarchy_bench,
        perf_stages,
        roofline,
        svc_batched,
        svc_chaos,
        svc_multitenant,
        svc_service,
        svc_streaming,
        table2_spmv,
        table3_block_size,
    )

    sections = {
        "fig4": lambda: fig4_degree_dist.main(scale=args.scale),
        "fig6": lambda: fig6_partition_methods.main(scale=args.scale),
        "table2": lambda: table2_spmv.main(scale=min(args.scale * 1.5, 1.0)),
        "fig11": lambda: fig11_transactions.main(scale=min(args.scale * 1.5, 1.0)),
        "fig12": lambda: fig12_cache_types.main(),
        "table3": lambda: table3_block_size.main(),
        "fig13": lambda: fig13_apps.main(),
        "hier": lambda: hierarchy_bench.main(),
        "svc": lambda: svc_service.main(scale=args.scale),
        "svc_streaming": lambda: svc_streaming.main(scale=args.scale),
        "svc_multitenant": lambda: svc_multitenant.main(scale=args.scale),
        "svc_batched": lambda: svc_batched.main(scale=args.scale),
        "svc_chaos": lambda: svc_chaos.main(scale=args.scale),
        "perf": lambda: perf_stages.main(scale=args.scale),
        "roofline": lambda: roofline.main(),
    }
    only = set(args.only.split(",")) if args.only else None
    if only and not only <= sections.keys():
        raise SystemExit(f"unknown section(s): {sorted(only - sections.keys())}")
    results: dict = {"scale": args.scale, "sections": {}, "section_time_s": {}}
    profiler = None
    if args.profile:
        import cProfile

        profiler = cProfile.Profile()
    t_all = time.perf_counter()
    for name, fn in sections.items():
        if only and name not in only:
            continue
        t0 = time.perf_counter()
        if profiler is not None:
            profiler.enable()
        try:
            out = fn()
        finally:
            if profiler is not None:
                profiler.disable()
        dt = time.perf_counter() - t0
        results["sections"][name] = out
        results["section_time_s"][name] = dt
        print(f"[{name} done in {dt:.1f}s]")
    results["total_time_s"] = time.perf_counter() - t_all
    if profiler is not None:
        import pstats

        print("\n== cProfile: top 20 by cumulative time ==")
        pstats.Stats(profiler).sort_stats("cumulative").print_stats(20)
    print(f"\nall benchmarks done in {results['total_time_s']:.1f}s")
    if args.json:
        with open(args.json, "w") as f:
            json.dump(results, f, indent=2, default=_jsonable)
        print(f"wrote {args.json}")


if __name__ == "__main__":
    main()

"""Paper Table 2 / Fig. 10: EP-scheduled SpMV vs default scheduling.

On this CPU container the meaningful metrics are the *modeled HBM loads*
(paper Fig. 11's transaction count — exactly what the EP objective is) and
the partition-time : kernel-time ratio (paper: EP partitioning is 22.7% of
total CUSPARSE time vs 205% for hypergraph).  Wall-times of the
interpret-mode Pallas kernels are functional checks, not TPU predictions.
"""
from __future__ import annotations

import time

import jax.numpy as jnp
import numpy as np

from repro.core import build_pack_plan, default_schedule, edge_partition
from repro.kernels import make_ep_spmv_fn
from repro.kernels.ref import spmv_coo_ref

from .graphs import spmv_matrices


def main(scale: float = 0.5, k: int = 32) -> list[dict]:
    print(f"\n== table2/fig10: EP-SpMV vs default (k={k}) ==")
    print(f"{'matrix':16s} {'nnz':>7s} | {'def_loads':>9s} {'ep_loads':>9s} {'ratio':>6s} | "
          f"{'EP_part_s':>9s} {'hg_part_s':>9s} | {'allclose':>8s}")
    rows = []
    rng = np.random.default_rng(0)
    for name, (edges, r, c, nr, nc) in spmv_matrices(scale).items():
        t0 = time.perf_counter()
        ep = edge_partition(edges, k, method="ep")
        ep_t = time.perf_counter() - t0
        t0 = time.perf_counter()
        edge_partition(edges, k, method="hypergraph")
        hg_t = time.perf_counter() - t0

        plan_ep = build_pack_plan(nr, nc, r, c, ep.labels, k, pad=128)
        plan_def = build_pack_plan(nr, nc, r, c, default_schedule(edges, k), k, pad=128)
        ep_loads = plan_ep.modeled_loads()
        def_loads = plan_def.modeled_loads()

        vals = rng.standard_normal(r.shape[0]).astype(np.float32)
        x = rng.standard_normal(nc).astype(np.float32)
        fn = make_ep_spmv_fn(plan_ep, vals, mode="software")
        y = fn(jnp.asarray(x))
        ref = spmv_coo_ref(nr, jnp.asarray(r), jnp.asarray(c), jnp.asarray(vals), jnp.asarray(x))
        close = bool(jnp.allclose(y, ref, rtol=1e-4, atol=1e-4))

        row = {
            "matrix": name, "nnz": edges.m,
            "default_loads": def_loads, "ep_loads": ep_loads,
            "load_ratio": ep_loads / def_loads,
            "ep_partition_s": ep_t, "hypergraph_partition_s": hg_t,
            "allclose": close,
        }
        rows.append(row)
        print(f"{name:16s} {edges.m:7d} | {def_loads:9d} {ep_loads:9d} "
              f"{row['load_ratio']:6.3f} | {ep_t:9.3f} {hg_t:9.3f} | {str(close):>8s}")
    avg = float(np.mean([r["load_ratio"] for r in rows]))
    ok_faster = all(r["ep_partition_s"] < r["hypergraph_partition_s"] for r in rows)
    print(f"mean EP/default modeled-load ratio: {avg:.3f}; "
          f"EP partition faster than hypergraph stand-in on all: {ok_faster} "
          f"(paper Tab. 2: EP overhead 22.7% vs hypergraph 205% of kernel time)")
    return rows


if __name__ == "__main__":
    main()

"""Paper Fig. 12: software cache vs texture cache (streaming) trade-off.

software (shared-memory analogue): each cluster stages its UNIQUE x entries
into VMEM once -> loads = unique objects per cluster (the EP objective).
streaming (texture analogue): tasks gather through the implicit cache; the
modeled bounds are [unique, per-task] depending on hit rate — we report the
pessimistic per-task bound plus an LRU-modeled estimate, mirroring the
paper's finding that software beats texture except on low-reuse graphs.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core import build_pack_plan, edge_partition
from repro.kernels import make_ep_spmv_fn
from repro.kernels.ref import spmv_coo_ref

from .graphs import spmv_matrices


def main(scale: float = 0.35, k: int = 32) -> list[dict]:
    print(f"\n== fig12: software vs streaming cache (k={k}) ==")
    print(f"{'matrix':16s} {'smem_loads':>10s} {'tex_worst':>10s} {'tex/smem':>8s} "
          f"{'reuse':>6s} {'both_allclose':>13s}")
    rows = []
    rng = np.random.default_rng(0)
    for name, (edges, r, c, nr, nc) in spmv_matrices(scale).items():
        ep = edge_partition(edges, k, method="ep")
        plan = build_pack_plan(nr, nc, r, c, ep.labels, k, pad=128)
        smem = plan.modeled_loads()
        tex_worst = int(plan.e_count.sum() * 2)  # one gather per endpoint
        reuse = tex_worst / max(smem, 1)

        vals = rng.standard_normal(r.shape[0]).astype(np.float32)
        x = rng.standard_normal(nc).astype(np.float32)
        ref = spmv_coo_ref(nr, jnp.asarray(r), jnp.asarray(c), jnp.asarray(vals), jnp.asarray(x))
        ys = make_ep_spmv_fn(plan, vals, mode="software")(jnp.asarray(x))
        yt = make_ep_spmv_fn(plan, vals, mode="streaming")(jnp.asarray(x))
        close = bool(jnp.allclose(ys, ref, rtol=1e-4, atol=1e-4)) and bool(
            jnp.allclose(yt, ref, rtol=1e-4, atol=1e-4)
        )
        row = {
            "matrix": name, "software_loads": smem, "streaming_worst": tex_worst,
            "ratio": tex_worst / smem, "avg_reuse": reuse, "allclose": close,
        }
        rows.append(row)
        print(f"{name:16s} {smem:10d} {tex_worst:10d} {row['ratio']:8.2f} "
              f"{reuse:6.2f} {str(close):>13s}")
    print("software <= streaming everywhere; margin = data reuse available "
          "(paper: software wins except on low-reuse in-2004)")
    return rows


if __name__ == "__main__":
    main()

"""svc_streaming: long-lived per-tenant churn streams across the 1-20% band.

The single-shot ``svc`` section measures one churn batch per rate; this
section measures the thing the gear policy actually serves: a *stream* of
churn batches per tenant, with jittered arrival rates sweeping the 1-20%
band, applied to a plan chain (every update's base is the previous update's
plan, so the policy's accumulated-drift bookkeeping is exercised, not just
its per-batch threshold).

Per event the bench records which gear the policy picked, the end-to-end
update latency through the service, and the quality drift against a
same-run full rebuild of the post-churn graph.  Local-gear events also get
an A/B: gear compute time (``stage_times_s["local"]`` — the V-cycle itself,
excluding the evaluation/pack overhead both gears share) vs. that same-run
full rebuild, which is the acceptance criterion's "local >= 3x a full
rebuild" measured where it matters, inside the stream.

Per-tenant rows are keyed ``<graph>|stream`` (p50/p99 update latency, gear
mix, drift stats); one ``stream`` summary row aggregates the gated claims:

  * ``local_speedup_mid`` — geometric mean of full-rebuild-time /
                         local-gear-time over the *mid-band* local events
                         (churn fraction <= 6%, where the acceptance
                         criterion's ">= 3x at 5% churn" lives; high-band
                         local events legitimately decay toward ~2x as the
                         dirty region stops being local);
  * ``local_speedup``  — the same geomean over every local-gear event
                         (informational);
  * ``full_frac``      — fraction of stream events that escalated to a full
                         rebuild (the gear-mix sanity claim: in the 1-20%
                         band, full rebuilds must stay the minority);
  * ``max_drift``      — worst event drift (updated cut / same-run full
                         rebuild cut) across every stream.

``scripts/check_bench_regression.py`` gates all three plus per-tenant p99
against the committed baseline.
"""
from __future__ import annotations

import time

import numpy as np

from repro.core import PartitionService, edge_partition

from .graphs import paper_graphs
from .svc_service import _churn_batch

#: Base churn rates cycled per stream — two sweeps of the 1-20% band per
#: tenant at the default event count.
STREAM_RATES = (0.01, 0.03, 0.05, 0.08, 0.12, 0.20)

#: Events per tenant stream (two full sweeps of STREAM_RATES).
DEFAULT_EVENTS = 12


def main(scale: float = 0.3, k: int = 64, events: int = DEFAULT_EVENTS,
         seed: int = 11) -> list[dict]:
    print(f"\n== svc_streaming: per-tenant churn streams (k={k}, "
          f"{events} events/tenant, band "
          f"{STREAM_RATES[0]:.0%}-{STREAM_RATES[-1]:.0%} with jitter) ==")
    print(f"{'tenant':28s} {'events':>6s} {'inc/loc/full':>12s} "
          f"{'p50_ms':>7s} {'p99_ms':>7s} {'max_drift':>9s} "
          f"{'local_x':>8s}")
    rows: list[dict] = []
    all_speedups: list[float] = []
    mid_speedups: list[float] = []
    all_drifts: list[float] = []
    total_events = 0
    total_gears = {"incremental": 0, "local": 0, "full": 0}
    rng = np.random.default_rng(seed)
    for name, g in paper_graphs(scale).items():
        with PartitionService() as svc:
            plan = svc.get(g, k, tenant=name)
            cur = plan
            update_s: list[float] = []
            drifts: list[float] = []
            gears: list[str] = []
            speedups: list[float] = []
            for i in range(events):
                # Arrival jitter: the band is swept deterministically, the
                # per-event rate wobbles +-20% around it.
                rate = STREAM_RATES[i % len(STREAM_RATES)] * rng.uniform(0.8, 1.25)
                ins_u, ins_v, delete_ids = _churn_batch(
                    cur.edges, rate, seed=seed + 100 * i
                )
                t0 = time.perf_counter()
                upd = svc.update(
                    cur.fingerprint, k,
                    insert_u=ins_u, insert_v=ins_v, delete_ids=delete_ids,
                    tenant=name,
                )
                dt = time.perf_counter() - t0
                t0 = time.perf_counter()
                full = edge_partition(upd.edges, k, method="ep")
                full_s = time.perf_counter() - t0
                drift = upd.result.quality.vertex_cut / max(
                    full.quality.vertex_cut, 1
                )
                update_s.append(dt)
                drifts.append(drift)
                gears.append(upd.source)
                if upd.source == "local":
                    gear_s = (upd.stage_times_s or {}).get("local", dt)
                    sp = full_s / max(gear_s, 1e-9)
                    speedups.append(sp)
                    churn_frac = (2 * len(ins_u)) / max(upd.edges.m, 1)
                    if churn_frac <= 0.06:
                        mid_speedups.append(sp)
                cur = upd
            mix = {s: gears.count(s) for s in ("incremental", "local", "full")}
            for s, c in mix.items():
                total_gears[s] += c
            total_events += events
            all_speedups.extend(speedups)
            all_drifts.extend(drifts)
            loc_x = (float(np.exp(np.mean(np.log(speedups))))
                     if speedups else 0.0)
            row = {
                "graph": f"{name}|stream",
                "m": g.m,
                "n_events": events,
                "n_incremental": mix["incremental"],
                "n_local": mix["local"],
                "n_full": mix["full"],
                "p50_update_s": float(np.percentile(update_s, 50)),
                "p99_update_s": float(np.percentile(update_s, 99)),
                "max_drift": float(max(drifts)),
                "final_drift": float(drifts[-1]),
                "local_speedup": loc_x,
            }
            rows.append(row)
            print(f"{name:28s} {events:6d} "
                  f"{mix['incremental']:4d}/{mix['local']:3d}/{mix['full']:3d} "
                  f"{row['p50_update_s'] * 1e3:7.1f} "
                  f"{row['p99_update_s'] * 1e3:7.1f} "
                  f"{row['max_drift']:9.3f} "
                  + (f"{loc_x:7.2f}x" if speedups else f"{'-':>8s}"))
    summary = {
        "graph": "stream",
        "n_events": total_events,
        "n_incremental": total_gears["incremental"],
        "n_local": total_gears["local"],
        "n_full": total_gears["full"],
        "full_frac": total_gears["full"] / max(total_events, 1),
        "local_speedup": (float(np.exp(np.mean(np.log(all_speedups))))
                          if all_speedups else 0.0),
        "local_speedup_mid": (float(np.exp(np.mean(np.log(mid_speedups))))
                              if mid_speedups else 0.0),
        "n_local_mid": len(mid_speedups),
        "max_drift": float(max(all_drifts)) if all_drifts else 0.0,
    }
    rows.append(summary)
    ok_speed = summary["local_speedup_mid"] >= 3.0 and mid_speedups
    ok_mix = summary["full_frac"] < 0.5
    ok_drift = summary["max_drift"] <= 1.15
    print(f"claims: mid-band local gear >= 3x same-run full rebuild: "
          f"{bool(ok_speed)} (geomean {summary['local_speedup_mid']:.2f}x "
          f"over {len(mid_speedups)} events <= 6% churn; all-band "
          f"{summary['local_speedup']:.2f}x over {total_gears['local']}); "
          f"full rebuilds a minority in the 1-20% band: {ok_mix} "
          f"({total_gears['full']}/{total_events} events); stream drift "
          f"ceiling 1.15: {ok_drift} (max {summary['max_drift']:.3f})")
    return rows


if __name__ == "__main__":
    main(scale=0.05)

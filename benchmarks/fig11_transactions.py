"""Paper Fig. 11: normalized memory-transaction counts.

Transaction model: one load per distinct data object per cache domain
(vertex-cut + compulsory) — the quantity the EP objective minimizes, and
what the paper measured with the CUDA profiler.
"""
from __future__ import annotations


from repro.core import (
    build_pack_plan,
    default_schedule,
    edge_partition,
    greedy_powergraph,
    random_partition,
)

from .graphs import spmv_matrices


def main(scale: float = 0.5, k: int = 32) -> list[dict]:
    print(f"\n== fig11: normalized transactions (k={k}; default = 1.0) ==")
    print(f"{'matrix':16s} {'default':>8s} {'random':>8s} {'greedy':>8s} {'EP':>8s}")
    rows = []
    for name, (edges, r, c, nr, nc) in spmv_matrices(scale).items():
        loads = {}
        for method, labels in (
            ("default", default_schedule(edges, k)),
            ("random", random_partition(edges, k)),
            ("greedy", greedy_powergraph(edges, k)),
            ("ep", edge_partition(edges, k, method="ep").labels),
        ):
            plan = build_pack_plan(nr, nc, r, c, labels, k, pad=8)
            loads[method] = plan.modeled_loads()
        base = loads["default"]
        row = {"matrix": name, **{m: v / base for m, v in loads.items()}}
        rows.append(row)
        print(f"{name:16s} {row['default']:8.3f} {row['random']:8.3f} "
              f"{row['greedy']:8.3f} {row['ep']:8.3f}")
    return rows


if __name__ == "__main__":
    main()

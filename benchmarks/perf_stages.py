"""perf: per-stage timing of the cold partition→pack pipeline.

Breaks fig6's ``ep_t`` into the multilevel stages (coarsen / init / refine,
from ``PartitionStats``) plus the §4.1 cpack pack-plan build, per graph —
the numbers the vectorization work is judged by, tracked in the CI-gated
JSON so a stage-level regression is visible even when the total hides it.
Each row also carries the V-cycle shape (``levels``, ``coarsest_n``, and the
per-level ``level_stats`` records), which the regression gate checks and
``scripts/print_stage_times.py`` renders as the per-level coarsening table.
"""
from __future__ import annotations

import dataclasses
import time

from repro.core import build_pack_plan, edge_partition

from .graphs import paper_graphs


def main(scale: float = 0.3, k: int = 64, pad: int = 128) -> list[dict]:
    print(f"\n== perf: partition->pack stage timings (k={k}) ==")
    hdr = (f"{'graph':28s} {'m':>9s} | {'coarsen':>8s} {'init':>8s} {'refine':>8s} "
           f"{'ep_total':>8s} | {'pack':>8s}")
    print(hdr)
    rows = []
    for name, g in paper_graphs(scale).items():
        t0 = time.perf_counter()
        res = edge_partition(g, k, method="ep")
        ep_total = time.perf_counter() - t0
        st = res.stats
        # Pack stage on the same task list: endpoints index the two tile
        # sides directly (u -> x side, v -> y side), realistic m and k.
        t0 = time.perf_counter()
        build_pack_plan(g.n, g.n, g.v, g.u, res.labels, k, pad=pad)
        pack_s = time.perf_counter() - t0
        row = {
            "graph": name,
            "m": g.m,
            "coarsen_s": st.coarsen_s if st else 0.0,
            "init_s": st.init_s if st else 0.0,
            "refine_s": st.refine_s if st else 0.0,
            "ep_total_s": ep_total,
            "pack_s": pack_s,
            "levels": st.levels if st else 0,
            "coarsest_n": st.coarsest_n if st else 0,
            "coarsen_mode": st.coarsen_mode if st else "",
            "level_stats": (
                [dataclasses.asdict(ls) for ls in st.level_stats] if st else []
            ),
        }
        rows.append(row)
        print(
            f"{name:28s} {g.m:9d} | {row['coarsen_s']:8.3f} {row['init_s']:8.3f} "
            f"{row['refine_s']:8.3f} {ep_total:8.3f} | {pack_s:8.3f}"
        )
    tot = {kk: sum(r[kk] for r in rows)
           for kk in ("coarsen_s", "init_s", "refine_s", "ep_total_s", "pack_s")}
    print(f"{'TOTAL':28s} {'':9s} | {tot['coarsen_s']:8.3f} {tot['init_s']:8.3f} "
          f"{tot['refine_s']:8.3f} {tot['ep_total_s']:8.3f} | {tot['pack_s']:8.3f}")
    return rows


if __name__ == "__main__":
    main()

"""Shared benchmark inputs: synthetic graphs matching the paper's matrix
families (Fig. 4/5 degree distributions), scaled to CPU-benchable sizes.

The Florida collection is not available offline; these generators reproduce
the structural families the paper evaluates — banded FEM (cant), uniform
random (circuit5M), power-law (in-2004 / scircuit), mesh (mc2depi / cfd) —
which is what the partitioners actually respond to.
"""
from __future__ import annotations

import numpy as np

from repro.core import (
    EdgeList,
    synthetic_banded_graph,
    synthetic_bipartite_graph,
    synthetic_mesh_graph,
    synthetic_powerlaw_graph,
    synthetic_random_graph,
)

__all__ = ["PAPER_GRAPHS", "paper_graphs", "spmv_matrices"]


def _shuffle_tasks(g: EdgeList, seed: int) -> EdgeList:
    """Scramble task order: structure keeps its locality, the stored order
    hides it (the paper's irregular setting — default scheduling on a
    pre-sorted mesh would be trivially optimal and the comparison vacuous)."""
    perm = np.random.default_rng(seed).permutation(g.m)
    return EdgeList(n=g.n, u=g.u[perm], v=g.v[perm])


def paper_graphs(scale: float = 1.0) -> dict[str, EdgeList]:
    s = scale
    gs = {
        "cant-like(banded)": synthetic_banded_graph(int(30_000 * s), band=12, seed=0),
        "circuit5M-like(random)": synthetic_random_graph(
            int(90_000 * s), int(300_000 * s), seed=1
        ),
        "in2004-like(powerlaw)": synthetic_powerlaw_graph(
            int(50_000 * s), int(280_000 * s), alpha=2.1, seed=2
        ),
        "mc2depi-like(mesh)": synthetic_mesh_graph(int(220 * np.sqrt(s)), seed=3),
        "scircuit-like(powerlaw)": synthetic_powerlaw_graph(
            int(30_000 * s), int(90_000 * s), alpha=2.4, seed=4
        ),
    }
    return {k: _shuffle_tasks(g, i + 50) for i, (k, g) in enumerate(gs.items())}


PAPER_GRAPHS = paper_graphs


def spmv_matrices(scale: float = 1.0):
    """(name -> (EdgeList, rows, cols, n_rows, n_cols)) for SpMV benches."""
    out = {}
    specs = [
        ("cant-like", 4096, 4096, 16, True, 0),
        ("cop20k-like", 6144, 6144, 8, True, 1),
        ("mc2depi-like", 8192, 8192, 4, True, 2),
        ("scircuit-like", 4096, 4096, 6, False, 3),
        ("mac_econ-like", 6144, 6144, 6, False, 4),
        ("in2004-like", 5120, 5120, 12, False, 5),
    ]
    for name, nr, nc, nnz, clustered, seed in specs:
        nr, nc = int(nr * scale), int(nc * scale)
        edges, rows, cols = synthetic_bipartite_graph(
            nr, nc, nnz, seed=seed, clustered=clustered
        )
        # Scramble the task (nnz) ORDER: the matrix structure keeps its
        # locality but the stored order doesn't expose it — the paper's
        # irregular-application setting (its default schedule shows 73.4%
        # redundant loads on cfd; an already-sorted banded matrix would
        # make `default` trivially optimal and the comparison vacuous).
        perm = np.random.default_rng(seed + 100).permutation(rows.shape[0])
        rows, cols = rows[perm], cols[perm]
        from repro.core.graph import affinity_graph_from_coo

        edges = affinity_graph_from_coo(nr, nc, rows, cols)
        out[name] = (edges, rows, cols, nr, nc)
    return out

"""Paper Fig. 13/14/15: general workloads + adaptive overhead control.

Applications mapped to this framework's context:
  * cfd     — mesh interaction graph (the paper's running example);
  * bfs     — power-law frontier expansion graph (texture-cache app);
  * streamcluster — low-reuse graph (degree <= 2): the paper's worst case,
    adaptive control must keep it at parity;
  * moe-dispatch — the LM-framework application (DESIGN.md §3.2): EP
    schedules qwen3-moe-style token->expert routing across expert-parallel
    shards; metric = cross-shard activation fetches (all-to-all volume).
Fig. 14's guarantee (never slower than baseline) is exercised through
AdaptiveScheduler state transitions.
"""
from __future__ import annotations

import time

import numpy as np

from repro.core import (
    AdaptiveScheduler,
    EdgeList,
    edge_partition,
    plan_moe_dispatch,
    synthetic_mesh_graph,
    synthetic_powerlaw_graph,
)


def _streamcluster_graph(n_points=20_000, n_centers=32, seed=0):
    """Every task connects a unique point to a shared center: degree ~<= 2."""
    rng = np.random.default_rng(seed)
    centers = rng.integers(0, n_centers, size=n_points)
    u = n_centers + np.arange(n_points)
    return EdgeList(n=n_centers + n_points, u=u, v=centers.astype(np.int64))


def _clustered_routing(n_tokens, n_experts, top_k, n_groups, seed=0):
    rng = np.random.default_rng(seed)
    group = rng.integers(0, n_groups, size=n_tokens)
    per = n_experts // n_groups
    offs = np.stack([rng.permutation(per)[:top_k] for _ in range(n_tokens)])
    return (group[:, None] * per + offs) % n_experts


def main(k: int = 64) -> list[dict]:
    print(f"\n== fig13/14/15: general workloads (k={k}) ==")
    rows = []
    apps = {
        "cfd(mesh)": synthetic_mesh_graph(180, seed=0),
        "bfs(powerlaw)": synthetic_powerlaw_graph(30_000, 120_000, seed=1),
        "streamcluster(low-reuse)": _streamcluster_graph(),
    }
    print(f"{'app':26s} {'default_q':>9s} {'EP_q':>9s} {'traffic_ratio':>13s} {'redundancy':>10s}")
    for name, g in apps.items():
        dflt = edge_partition(g, k, method="default")
        ep = edge_partition(g, k, method="ep")
        d_loads = dflt.quality.loads_total
        e_loads = ep.quality.loads_total
        row = {
            "app": name,
            "default_cut": dflt.vertex_cut, "ep_cut": ep.vertex_cut,
            "traffic_ratio": e_loads / d_loads,
            "default_redundancy": dflt.quality.redundant_fraction,
        }
        rows.append(row)
        print(f"{name:26s} {dflt.vertex_cut:9d} {ep.vertex_cut:9d} "
              f"{row['traffic_ratio']:13.3f} {row['default_redundancy']:10.3f}")

    # MoE dispatch (the framework-level application of the model).
    ids = _clustered_routing(16_384, 128, 8, n_groups=16)
    plan = plan_moe_dispatch(ids, n_experts=128, n_shards=16)
    print(f"{'moe-dispatch(qwen3-moe)':26s} {plan.default_cross_fetches:9d} "
          f"{plan.ep_cross_fetches:9d} {plan.traffic_ratio:13.3f} {'—':>10s}")
    rows.append({
        "app": "moe-dispatch", "default_cut": plan.default_cross_fetches,
        "ep_cut": plan.ep_cross_fetches, "traffic_ratio": plan.traffic_ratio,
    })

    # Fig 14: adaptive overhead control never loses.
    print("\n-- fig14: adaptive overhead control --")
    for case, (base_ms, opt_ms) in {
        "optimized-kernel-faster": (2.0, 0.5),
        "optimized-kernel-SLOWER": (0.5, 2.0),
    }.items():
        sched = AdaptiveScheduler(
            baseline_fn=lambda: time.sleep(base_ms / 1e3),
            optimize_fn=lambda: time.sleep(0.005) or "plan",
            build_optimized_fn=lambda plan: (lambda: time.sleep(opt_ms / 1e3)),
        )
        for _ in range(12):
            sched()
        s = sched.summary()
        print(f"{case:26s} final_state={s['state']:9s} calls={s['calls']}")
        rows.append({"app": f"adaptive:{case}", "state": s["state"]})
    return rows


if __name__ == "__main__":
    main()

"""Paper Fig. 4/5: degree distributions of the evaluation graphs."""
from __future__ import annotations


from .graphs import paper_graphs


def main(scale: float = 0.3) -> list[dict]:
    rows = []
    print("\n== fig4: degree distributions (paper Fig. 4/5) ==")
    print(f"{'graph':28s} {'n':>8s} {'m':>9s} {'dmax':>6s} {'davg':>6s}  top degrees (deg:count)")
    for name, g in paper_graphs(scale).items():
        deg = g.degrees()
        hist = g.degree_histogram()
        top = sorted(hist.items(), key=lambda kv: -kv[1])[:4]
        row = {
            "graph": name, "n": g.n, "m": g.m,
            "d_max": int(deg.max()), "d_avg": float(deg.mean()),
            "top": top,
        }
        rows.append(row)
        tops = " ".join(f"{d}:{c}" for d, c in top)
        print(f"{name:28s} {g.n:8d} {g.m:9d} {row['d_max']:6d} {row['d_avg']:6.2f}  {tops}")
    return rows


if __name__ == "__main__":
    main()

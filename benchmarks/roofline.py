"""Roofline table from the dry-run artifacts (EXPERIMENTS.md §Roofline).

Reads experiments/dryrun/*.json (produced by repro.launch.dryrun) and
renders the per-(arch x shape x mesh) three-term table: compute / memory /
collective seconds, dominant term, MODEL_FLOPS/HLO_FLOPs ratio, and the
roofline fraction (the useful-FLOPs throughput at the roofline step time).
"""
from __future__ import annotations

import glob
import json
import os

DRYRUN_DIR = "experiments/dryrun"


def load_records(dryrun_dir: str = DRYRUN_DIR) -> list[dict]:
    """Optimized artifacts, back-filled from the preserved baseline for any
    cell whose optimized re-run hasn't landed yet (marked 'baseline')."""
    recs = {}
    base_dir = dryrun_dir + "_baseline"
    for path in sorted(glob.glob(os.path.join(base_dir, "*.json"))):
        with open(path) as f:
            r = json.load(f)
        r["source"] = "baseline"
        recs[os.path.basename(path)] = r
    for path in sorted(glob.glob(os.path.join(dryrun_dir, "*.json"))):
        with open(path) as f:
            r = json.load(f)
        r["source"] = "optimized"
        recs[os.path.basename(path)] = r
    return [recs[k] for k in sorted(recs)]


def render_table(recs: list[dict], mesh: str | None = "16x16") -> str:
    lines = []
    hdr = (f"{'arch':26s} {'shape':12s} {'mesh':8s} {'mem_GB':>7s} "
           f"{'compute_s':>10s} {'memory_s':>9s} {'collect_s':>9s} "
           f"{'dominant':>10s} {'useful':>7s} {'RL-frac':>8s}")
    lines.append(hdr)
    lines.append("-" * len(hdr))
    for r in recs:
        if mesh and r.get("mesh") != mesh:
            continue
        if r.get("status") != "ok":
            lines.append(f"{r['arch']:26s} {r['shape']:12s} {r.get('mesh','?'):8s} "
                         f"ERROR: {r.get('error','?')[:60]}")
            continue
        rf = r["roofline"]
        mem = r.get("memory", {}).get("per_device_total_bytes", 0) / 1e9
        src = "*" if r.get("source") == "baseline" else " "
        lines.append(
            f"{r['arch']:26s} {r['shape']:12s} {r['mesh']:8s} {mem:7.2f} "
            f"{rf['compute_s']:10.4f} {rf['memory_s']:9.4f} {rf['collective_s']:9.4f} "
            f"{rf['dominant']:>10s} {rf['useful_flops_ratio']:7.3f} "
            f"{rf['roofline_fraction']:8.4f}{src}"
        )
    return "\n".join(lines)


def main() -> list[dict]:
    recs = load_records()
    if not recs:
        print("\n== roofline: no dry-run artifacts under experiments/dryrun "
              "(run python -m repro.launch.dryrun first) ==")
        return []
    print("\n== roofline (single-pod 16x16) ==")
    print(render_table(recs, "16x16"))
    print("\n== roofline (multi-pod 2x16x16) ==")
    print(render_table(recs, "2x16x16"))
    ok = [r for r in recs if r.get("status") == "ok"]
    bad = [r for r in recs if r.get("status") != "ok"]
    print(f"\ncells: {len(ok)} ok, {len(bad)} failed")
    return recs


if __name__ == "__main__":
    main()

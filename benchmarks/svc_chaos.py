"""svc_chaos: replica failover + hedging under deterministic fault injection.

Drives a 2-replica :class:`ReplicaGroup` through two seeded chaos scenarios
(ROADMAP: "replicated plan service with failover"):

  * **Failover** — a multi-tenant request stream (3 tenants, ``N_GRAPHS``
    distinct graphs) runs twice: once fault-free, once with the primary
    replica crashed after ``KILL_AFTER_JOBS`` completed jobs while a job is
    mid-V-cycle (the injector stalls the V-cycle so the crash always lands
    on in-flight work).  The claims the CI gate holds: **zero lost
    tickets**, responses **byte-identical** to the fault-free run (same
    label arrays, digest-compared), and bounded **recovery latency** (kill
    -> last orphaned ticket resolved elsewhere).
  * **Hedging** — one replica stalls every job by ``STRAGGLER_S`` (a
    straggler, not a corpse).  The same cold stream runs with hedging off
    vs on (hedge fires after ``HEDGE_DELAY_S``); the win claims: hedge win
    rate > 0 and hedged p99 well under the straggler's p99.
  * **kill -9** — the same multi-tenant stream against *separate worker
    processes* (``launch.replica_worker`` + ``core.transport``): the
    injector ``SIGKILL``s the primary's OS process after
    ``KILL_AFTER_JOBS`` completions while a stalled job is mid-V-cycle —
    no drain, no goodbye, only wire errors and missed heartbeats.  Gated
    claims: **zero lost tickets** and responses **byte-identical to the
    fault-free in-process run** (the transport adds no bytes and loses
    none), with bounded recovery latency.

  * **Flood** — overload protection: one tenant floods a bounded-admission
    group at ``FLOOD_FACTOR``x while two well-behaved tenants keep serving.
    Gated claims: victim p99 stays within the regression gate's bound of
    the no-flood baseline, **zero victim rejections**, every flooder
    rejection carries ``retry_after_s > 0``, the flooder's circuit breaker
    trips during the flood and **re-closes** once it stops, and a rejection
    raised across the process transport is **byte-identical** (same
    exception args) to one raised in-process.

Row keys (CI baseline stable): ``chaos_failover``, ``chaos_hedge``,
``chaos_kill9``, ``chaos_flood``, and ``replicas`` (per-replica
beats/failovers/p99 table rendered by ``scripts/print_stage_times.py``).
"""
from __future__ import annotations

import hashlib
import threading
import time

from repro.core import (
    AdmissionRejectedError,
    FaultInjector,
    ReplicaExhaustedError,
    ReplicaGroup,
    synthetic_powerlaw_graph,
)
from repro.launch.replica_worker import spawn_process_group, spawn_worker

N_GRAPHS = 10
TENANTS = ("tenant-a", "tenant-b", "tenant-c")
KILL_AFTER_JOBS = 2
STALL_S = 0.15       # failover scenario: keeps work in flight at kill time
STRAGGLER_S = 0.25   # hedging scenario: per-job straggler delay
HEDGE_DELAY_S = 0.05
N_HEDGE = 12
FLOOD_FACTOR = 10.0  # flooding tenant's load multiplier during the window
FLOOD_QUEUE_BOUND = 3
N_FLOOD_VICTIM = 6   # cold graphs per victim tenant per phase


def _graphs(scale: float):
    s = max(scale, 0.01)
    return [
        synthetic_powerlaw_graph(int(4_000 * s), int(16_000 * s), seed=400 + i)
        for i in range(N_GRAPHS)
    ]


def _digest(plans) -> str:
    """Order-independent digest of every response's label array."""
    h = hashlib.blake2b(digest_size=16)
    for sp in sorted(plans, key=lambda p: p.fingerprint):
        h.update(sp.fingerprint.encode())
        h.update(sp.result.labels.tobytes())
    return h.hexdigest()


def _stream_run(graphs, k: int, injector, kill_after, make_group=None,
                crash_kinds=("crash",)) -> dict:
    """One multi-tenant stream; optionally crashes the primary mid-flight.

    ``make_group`` builds the group under test (defaults to 2 in-process
    replicas); ``crash_kinds`` names the injector event kinds that count as
    the kill instant (``crash`` for in-process kills, ``sigkill`` for the
    process-transport scenario)."""
    if make_group is None:
        def make_group(inj):
            return ReplicaGroup(2, injector=inj, hedge=False)
    with make_group(injector) as g:
        t0 = time.perf_counter()
        tickets = [
            g.submit(e, k, tenant=TENANTS[i % len(TENANTS)])
            for i, e in enumerate(graphs)
        ]
        # Poll for per-ticket completion instants; the injector fires the
        # crash from the group's own pump once the victim completes
        # `kill_after` jobs, and recovery latency is measured from the
        # actual kill instant to the last failed-over ticket's completion.
        t_kill = None
        done_t: dict[int, float] = {}
        deadline = time.perf_counter() + 600
        while len(done_t) < len(tickets) and time.perf_counter() < deadline:
            g.pump()
            now = time.perf_counter()
            if t_kill is None and any(e[0] in crash_kinds
                                      for e in injector.events):
                t_kill = now
            for i, t in enumerate(tickets):
                if i not in done_t and t.done():
                    done_t[i] = now
            time.sleep(0.002)
        plans = [t.result(600) for t in tickets]
        wall = time.perf_counter() - t0
        rm = g.replica_metrics()
    recovery = 0.0
    if t_kill is not None:
        recovery = max(
            (done_t[i] - t_kill for i, t in enumerate(tickets)
             if t.retries > 0 and i in done_t),
            default=0.0,
        )
    return {
        "plans": plans,
        "wall_s": wall,
        "recovery_latency_s": recovery,
        "metrics": rm,
        "killed": next((e[1] for e in injector.events
                        if e[0] in crash_kinds), None),
    }


def _failover_scenario(graphs, k: int) -> tuple[dict, list[dict], str]:
    base = _stream_run(graphs, k, FaultInjector(seed=0), kill_after=None)
    # Chaos run: stall early jobs on both replicas so the crash (fired after
    # the victim's KILL_AFTER_JOBS-th completion) always lands mid-V-cycle,
    # then kill whichever replica the round-robin made primary.
    inj = (FaultInjector(seed=0)
           .stall_jobs("r0", STALL_S, first=0, last=KILL_AFTER_JOBS + 1)
           .stall_jobs("r1", STALL_S, first=0, last=KILL_AFTER_JOBS + 1)
           .crash_after_jobs("r1", KILL_AFTER_JOBS))
    chaos = _stream_run(graphs, k, inj, kill_after=KILL_AFTER_JOBS)
    rm = chaos["metrics"]
    row = {
        "graph": "chaos_failover",
        "m": graphs[0].m,
        "n_requests": len(graphs),
        "kill_after_jobs": KILL_AFTER_JOBS,
        "killed_replica": chaos["killed"],
        "lost_tickets": rm.lost,
        "byte_identical": _digest(chaos["plans"]) == _digest(base["plans"]),
        "recovery_latency_s": chaos["recovery_latency_s"],
        "failovers": rm.failovers,
        "retries": rm.retries,
        "wall_nofault_s": base["wall_s"],
        "wall_chaos_s": chaos["wall_s"],
    }
    replica_rows = [r.as_dict() for r in rm.replicas]
    return row, replica_rows, _digest(base["plans"])


def _kill9_scenario(graphs, k: int, base_digest: str) -> dict:
    """kill -9 a replica *worker process* mid-V-cycle, cross-process.

    Two socket-backed workers (one ``PartitionService`` each, separate OS
    processes); the same multi-tenant stream; worker-side stalls keep the
    early jobs mid-V-cycle so the ``SIGKILL`` (fired by the group pump once
    the victim completes ``KILL_AFTER_JOBS`` jobs) always lands on in-flight
    work.  Byte identity is checked against the *in-process fault-free*
    digest: crossing the wire and losing a worker must change nothing."""
    inj = FaultInjector(seed=0).sigkill_after_jobs("r1", KILL_AFTER_JOBS)
    stall = [(STALL_S, 0, KILL_AFTER_JOBS + 1)]

    def make_group(injector):
        return spawn_process_group(
            2, injector=injector, hedge=False, heartbeat_deadline_s=1.0,
            stalls_per_replica=[stall, stall])

    chaos = _stream_run(graphs, k, inj, kill_after=KILL_AFTER_JOBS,
                        make_group=make_group, crash_kinds=("sigkill",))
    rm = chaos["metrics"]
    return {
        "graph": "chaos_kill9",
        "transport": "process",
        "m": graphs[0].m,
        "n_requests": len(graphs),
        "kill_after_jobs": KILL_AFTER_JOBS,
        "killed_replica": chaos["killed"],
        "lost_tickets": rm.lost,
        "byte_identical": _digest(chaos["plans"]) == base_digest,
        "recovery_latency_s": chaos["recovery_latency_s"],
        "failovers": rm.failovers,
        "retries": rm.retries,
        "wall_chaos_s": chaos["wall_s"],
    }


def _pcts_ms(xs):
    ys = sorted(xs)
    if not ys:
        return 0.0, 0.0
    return (ys[min(len(ys) - 1, int(0.50 * len(ys)))] * 1e3,
            ys[min(len(ys) - 1, int(0.99 * len(ys)))] * 1e3)


def _hedge_run(scale: float, k: int, hedge: bool) -> tuple[list[float], object]:
    s = max(scale, 0.01)
    graphs = [
        synthetic_powerlaw_graph(int(3_000 * s), int(12_000 * s), seed=500 + i)
        for i in range(N_HEDGE)
    ]
    inj = FaultInjector(seed=1).stall_jobs("r0", STRAGGLER_S)
    lat = []
    with ReplicaGroup(2, injector=inj, hedge=hedge,
                      hedge_delay_s=HEDGE_DELAY_S) as g:
        for e in graphs:
            t0 = time.perf_counter()
            g.get(e, k, timeout=600)
            lat.append(time.perf_counter() - t0)
        rm = g.replica_metrics()
    return lat, rm


def _hedge_scenario(scale: float, k: int) -> dict:
    lat_off, _ = _hedge_run(scale, k, hedge=False)
    lat_on, rm = _hedge_run(scale, k, hedge=True)
    p50_off, p99_off = _pcts_ms(lat_off)
    p50_on, p99_on = _pcts_ms(lat_on)
    return {
        "graph": "chaos_hedge",
        "n_requests": N_HEDGE,
        "straggler_delay_s": STRAGGLER_S,
        "hedge_delay_s": HEDGE_DELAY_S,
        "p50_nohedge_ms": p50_off,
        "p99_nohedge_ms": p99_off,
        "p50_hedge_ms": p50_on,
        "p99_hedge_ms": p99_on,
        "p99_speedup": p99_off / max(p99_on, 1e-9),
        "hedges_fired": rm.hedges_fired,
        "hedges_won": rm.hedges_won,
        "hedge_win_rate": rm.hedges_won / max(rm.hedges_fired, 1),
        "lost_tickets": rm.lost,
    }


def _flood_victim_pass(g, mk, k: int, seed_base: int) -> tuple[list[float], int]:
    """Closed-loop cold-graph pass for both victim tenants; returns
    (latencies, rejections).  The gate wants rejections == 0 — bounded
    admission must never shed a well-behaved tenant."""
    lat: list[float] = []
    rejections = 0
    for i in range(N_FLOOD_VICTIM):
        for j, tenant in enumerate(("tenant-a", "tenant-b")):
            e = mk(seed_base + 10 * i + j)
            t0 = time.perf_counter()
            try:
                g.get(e, k, tenant=tenant, priority=1, timeout=60)
            except AdmissionRejectedError:
                rejections += 1
                continue
            lat.append(time.perf_counter() - t0)
    return lat, rejections


def _flood_scenario(scale: float, k: int) -> dict:
    """One tenant floods a bounded group; victims must not notice (much)."""
    s = max(scale, 0.01)

    def mk(seed):
        return synthetic_powerlaw_graph(int(2_000 * s), int(8_000 * s),
                                        seed=seed)

    inj = FaultInjector(seed=2).flood("flood", FLOOD_FACTOR,
                                      start_s=0.0, duration_s=60.0)
    with ReplicaGroup(
        2, injector=inj, hedge=False, allow_stale=False,
        retry_budget=2, backoff_base_s=0.002, backoff_cap_s=0.01,
        breaker_failures=3, breaker_cooldown_s=0.15,
        workers=1, max_queue_depth=FLOOD_QUEUE_BOUND,
    ) as g:
        # Phase A: no flooder traffic yet — victim baseline on cold graphs.
        base_lat, base_rej = _flood_victim_pass(g, mk, k, seed_base=700)

        # Phase B: flooder threads push unique cold graphs as fast as the
        # injector's flood factor says, while victims run the same closed
        # loop over fresh cold graphs.
        stop = threading.Event()
        flood_stats = {"submits": 0, "admitted": 0, "rejections": 0,
                       "exhausted": 0, "hints": []}
        flood_lock = threading.Lock()

        def flooder(fid: int) -> None:
            n = 0
            while not stop.is_set():
                if inj.flood_factor("flood") <= 1.0:
                    time.sleep(0.01)
                    continue
                n += 1
                e = mk(9000 + 100 * fid + n)
                with flood_lock:
                    flood_stats["submits"] += 1
                try:
                    g.get(e, k, tenant="flood", priority=0, timeout=60)
                    with flood_lock:
                        flood_stats["admitted"] += 1
                except AdmissionRejectedError as exc:
                    with flood_lock:
                        flood_stats["rejections"] += 1
                        flood_stats["hints"].append(exc.retry_after_s)
                    # The documented client contract: back off for the
                    # hinted interval instead of hammering the group.
                    stop.wait(min(max(exc.retry_after_s, 0.005), 0.1))
                except ReplicaExhaustedError:
                    # Retry budget burned entirely on breaker-gated lanes
                    # (no rejection of this request to carry a hint).
                    with flood_lock:
                        flood_stats["exhausted"] += 1
                    stop.wait(0.01)

        # Closed-loop flooder threads: concurrency IS the overload factor
        # (each thread keeps exactly one request in flight).
        nf = int(FLOOD_FACTOR)
        threads = [threading.Thread(target=flooder, args=(f,))
                   for f in range(nf)]
        for t in threads:
            t.start()
        time.sleep(0.05)  # let the flood build a queue before measuring
        flood_lat, victim_rej = _flood_victim_pass(g, mk, k, seed_base=800)
        stop.set()
        for t in threads:
            t.join()
        trips_during = sum(
            br.trips for rep in g._replicas
            for tn, br in rep.breakers.items() if tn == "flood")

        # Recovery: with the flood gone, a trickle of flooder requests must
        # drain the queue and walk every tripped breaker back to closed.
        recovered = False
        deadline = time.perf_counter() + 10.0
        n = 0
        while time.perf_counter() < deadline:
            n += 1
            try:
                g.get(mk(9900 + n), k, tenant="flood", priority=0,
                      timeout=60)
            except (AdmissionRejectedError, ReplicaExhaustedError):
                pass
            states = g.breaker_states("flood")
            if all(st == "closed" for st in states.values()):
                recovered = True
                break
            time.sleep(0.05)
        snap = g.metrics()

    wire = _rejection_wire_check()
    p50_b, p99_b = _pcts_ms(base_lat)
    p50_f, p99_f = _pcts_ms(flood_lat)
    hints = flood_stats["hints"]
    return {
        "graph": "chaos_flood",
        "m": mk(700).m,
        "queue_bound": FLOOD_QUEUE_BOUND,
        "flood_factor": FLOOD_FACTOR,
        "victim_requests": 2 * N_FLOOD_VICTIM,
        "victim_p50_noflood_ms": p50_b,
        "victim_p99_noflood_ms": p99_b,
        "victim_p50_flood_ms": p50_f,
        "victim_p99_flood_ms": p99_f,
        "victim_p99_ratio": p99_f / max(p99_b, 1e-9),
        "victim_rejections": base_rej + victim_rej,
        "flooder_submits": flood_stats["submits"],
        "flooder_admitted": flood_stats["admitted"],
        "flooder_rejections": flood_stats["rejections"],
        "flooder_exhausted": flood_stats["exhausted"],
        "min_retry_after_s": min(hints) if hints else 0.0,
        "retry_after_valid": bool(hints) and all(h > 0 for h in hints),
        "breaker_trips": trips_during,
        "breaker_recovered": recovered,
        "queue_depth_max": snap.queue_depth_max,
        "rejected": snap.rejected,
        "shed_deadline": snap.shed_deadline,
        "rejection_wire_identical": wire["identical"],
    }


def _rejection_wire_check() -> dict:
    """An AdmissionRejectedError must cross the process transport with the
    exact args it carries in-process: same tenant, same slot accounting in
    the message, same retry hint — compared byte-for-byte on the pickled
    constructor args of both exceptions."""
    import pickle

    from repro.core import PartitionService
    from repro.core.transport import RemoteReplica

    graphs = [synthetic_powerlaw_graph(120, 480, seed=9100 + i)
              for i in range(3)]

    def provoke(submit) -> AdmissionRejectedError:
        # Job 0 is picked up (and stalls, freeing its admission slot); job 1
        # sits queued holding the single slot; job 2 must be rejected with
        # held=1 of share=1 and the no-history retry floor.
        submit(graphs[0])
        time.sleep(0.25)
        submit(graphs[1])
        try:
            submit(graphs[2])
        except AdmissionRejectedError as e:
            return e
        raise AssertionError("third submit was not rejected")

    svc = PartitionService(workers=1, max_queue_depth=1)
    try:
        svc.scheduler.pre_job_hook = lambda _k: time.sleep(1.0)
        local = provoke(lambda e: svc.submit(e, 4))
    finally:
        svc.close()

    handle = spawn_worker(queue_bound=1, stalls=[(1.0, 0, 1 << 30)])
    rr = RemoteReplica(handle.address, process=handle.proc, pid=handle.pid)
    try:
        remote = provoke(lambda e: rr.submit(e, 4))
    finally:
        rr.close()

    la = local.__reduce__()[1]
    ra = remote.__reduce__()[1]
    return {
        "identical": pickle.dumps(la) == pickle.dumps(ra),
        "local_args": la,
        "remote_args": ra,
    }


def main(scale: float = 0.3, k: int = 16) -> list[dict]:
    print(f"\n== svc_chaos: replica failover + hedging + kill -9 + flood "
          f"(k={k}, {N_GRAPHS} graphs x {len(TENANTS)} tenants) ==")
    graphs = _graphs(scale)
    fo, replica_rows, base_digest = _failover_scenario(graphs, k)
    hg = _hedge_scenario(scale, k)
    k9 = _kill9_scenario(graphs, k, base_digest)
    fl = _flood_scenario(scale, k)
    rows = [fo, hg, k9, fl, {"graph": "replicas", "replicas": replica_rows}]

    print(f"failover: killed {fo['killed_replica']} after "
          f"{fo['kill_after_jobs']} jobs -> lost={fo['lost_tickets']} "
          f"byte_identical={fo['byte_identical']} "
          f"recovery={fo['recovery_latency_s'] * 1e3:.0f}ms "
          f"(failovers={fo['failovers']}, retries={fo['retries']})")
    print(f"{'replica':>8s} {'state':>8s} {'beats':>6s} {'jobs':>5s} "
          f"{'failovers':>9s} {'p99_ms':>8s}")
    for r in replica_rows:
        print(f"{r['replica']:>8s} {r['state']:>8s} {r['beats']:6d} "
              f"{r['jobs_completed']:5d} {r['failovers_from']:9d} "
              f"{r['p99_ms']:8.1f}")
    print(f"hedging vs {STRAGGLER_S * 1e3:.0f}ms straggler: "
          f"p99 {hg['p99_nohedge_ms']:.0f}ms -> {hg['p99_hedge_ms']:.0f}ms "
          f"({hg['p99_speedup']:.1f}x), win rate {hg['hedge_win_rate']:.2f}")
    print(f"kill -9 (process transport): SIGKILLed {k9['killed_replica']} "
          f"after {k9['kill_after_jobs']} jobs -> lost={k9['lost_tickets']} "
          f"byte_identical={k9['byte_identical']} "
          f"recovery={k9['recovery_latency_s'] * 1e3:.0f}ms "
          f"(retries={k9['retries']})")
    print(f"flood: {fl['flood_factor']:.0f}x flooder vs queue bound "
          f"{fl['queue_bound']} -> victim p99 "
          f"{fl['victim_p99_noflood_ms']:.0f}ms -> "
          f"{fl['victim_p99_flood_ms']:.0f}ms "
          f"({fl['victim_p99_ratio']:.2f}x), victim_rejections="
          f"{fl['victim_rejections']}, flooder "
          f"{fl['flooder_rejections']}/{fl['flooder_submits']} rejected "
          f"(min retry_after {fl['min_retry_after_s']:.3f}s), "
          f"breaker trips={fl['breaker_trips']} "
          f"recovered={fl['breaker_recovered']} "
          f"wire_identical={fl['rejection_wire_identical']}")
    print(f"claims: zero lost tickets under replica kill: "
          f"{fo['lost_tickets'] == 0}; responses byte-identical to fault-free "
          f"run: {fo['byte_identical']}; hedging cuts straggler p99: "
          f"{hg['p99_hedge_ms'] < hg['p99_nohedge_ms']}; kill -9 of a worker "
          f"process loses nothing: {k9['lost_tickets'] == 0 and k9['byte_identical']}; "
          f"flood sheds only the flooder, with retry hints, and the breaker "
          f"re-closes: {fl['victim_rejections'] == 0 and fl['retry_after_valid'] and fl['breaker_recovered']}")
    return rows


if __name__ == "__main__":
    main()

"""Beyond-paper: two-level EP for the TPU memory hierarchy (DESIGN.md §3.4).

Level 1 partitions tasks across devices (cut = ICI traffic); level 2
partitions each device's tasks across VMEM tiles (cut = HBM traffic).
Compared against a flat k_outer*k_inner partition grouped contiguously onto
devices — hierarchical spends its quality budget on the slow link first.
"""
from __future__ import annotations

import numpy as np

from repro.core import (
    edge_partition,
    hierarchical_edge_partition,
    synthetic_mesh_graph,
    synthetic_powerlaw_graph,
    vertex_cut_cost,
)


def main(k_outer: int = 16, k_inner: int = 8) -> list[dict]:
    print(f"\n== hierarchy: two-level EP (devices={k_outer} x vmem-tiles={k_inner}) ==")
    graphs = {
        "mesh(cfd)": synthetic_mesh_graph(150, seed=0),
        "powerlaw(bfs)": synthetic_powerlaw_graph(20_000, 90_000, seed=1),
    }
    print(f"{'graph':16s} {'flat_ICI':>9s} {'hier_ICI':>9s} {'ICI_ratio':>9s} "
          f"{'flat_total':>10s} {'hier_total':>10s}")
    rows = []
    for name, g in graphs.items():
        h = hierarchical_edge_partition(g, k_outer, k_inner)
        flat = edge_partition(g, k_outer * k_inner, method="ep")
        flat_outer = (flat.labels // k_inner).astype(np.int32)
        flat_ici = vertex_cut_cost(g, flat_outer, k_outer)
        row = {
            "graph": name,
            "flat_ici": flat_ici, "hier_ici": h.outer_cut,
            "ici_ratio": h.outer_cut / max(flat_ici, 1),
            "flat_total": flat.vertex_cut, "hier_total": h.flat_cut,
        }
        rows.append(row)
        print(f"{name:16s} {flat_ici:9d} {h.outer_cut:9d} {row['ici_ratio']:9.3f} "
              f"{flat.vertex_cut:10d} {h.flat_cut:10d}")
    print("hier_ICI <= flat_ICI on all graphs: "
          f"{all(r['hier_ici'] <= r['flat_ici'] for r in rows)} "
          "(slow-link traffic is what the outer level optimizes)")
    return rows


if __name__ == "__main__":
    main()

"""Paper Fig. 6: partition method comparison — time + quality.

Columns mirror the paper's table: default quality, hypergraph (hMETIS/PaToH
stand-in) time+quality, PowerGraph random/greedy quality, our EP model
time+quality.  The paper's claims validated here:
  * EP quality ~ hypergraph quality,
  * EP time << hypergraph time (and the gap grows with graph size),
  * random/greedy quality is far worse — often worse than default.
"""
from __future__ import annotations

import time

from repro.core import edge_partition

from .graphs import paper_graphs


def main(scale: float = 0.3, k: int = 64) -> list[dict]:
    print(f"\n== fig6: partition methods (k={k}) ==")
    hdr = (f"{'graph':28s} {'m':>9s} | {'default':>9s} | {'hgraph_t':>8s} {'hgraph_q':>9s} | "
           f"{'random':>9s} {'greedy':>9s} | {'EP_t':>6s} {'EP_q':>9s} {'EP_bal':>6s}")
    print(hdr)
    rows = []
    for name, g in paper_graphs(scale).items():
        res = {}
        times = {}
        for method in ("default", "hypergraph", "random", "greedy", "ep"):
            t0 = time.perf_counter()
            r = edge_partition(g, k, method=method)
            times[method] = time.perf_counter() - t0
            res[method] = r
        row = {
            "graph": name, "m": g.m,
            "default_q": res["default"].vertex_cut,
            "hypergraph_t": times["hypergraph"],
            "hypergraph_q": res["hypergraph"].vertex_cut,
            "random_q": res["random"].vertex_cut,
            "greedy_q": res["greedy"].vertex_cut,
            "ep_t": times["ep"],
            "ep_q": res["ep"].vertex_cut,
            "ep_balance": res["ep"].quality.balance,
            "speedup_vs_hypergraph": times["hypergraph"] / max(times["ep"], 1e-9),
        }
        rows.append(row)
        print(
            f"{name:28s} {g.m:9d} | {row['default_q']:9d} | "
            f"{row['hypergraph_t']:8.2f} {row['hypergraph_q']:9d} | "
            f"{row['random_q']:9d} {row['greedy_q']:9d} | "
            f"{row['ep_t']:6.2f} {row['ep_q']:9d} {row['ep_balance']:6.3f}"
        )
    # Claim checks (printed so bench_output.txt records them).  NOTE the
    # hypergraph column is a star-expansion stand-in driven by OUR multilevel
    # engine (hMETIS/PaToH are not available offline) — it reproduces the
    # quality comparison; the paper's 10-100x TIME gap is a property of real
    # hypergraph partitioners and shows here only as a 1-4x gap.
    ok_random = all(r["ep_q"] < r["random_q"] for r in rows)
    n_greedy = sum(r["ep_q"] <= r["greedy_q"] for r in rows)
    n_default = sum(r["ep_q"] < r["default_q"] for r in rows)
    n_fast = sum(r["ep_t"] <= r["hypergraph_t"] for r in rows)
    par = all(
        r["ep_q"] <= 1.5 * r["hypergraph_q"] or r["ep_q"] <= r["default_q"]
        for r in rows
    )
    print(f"claims: EP beats random on {len(rows)}/{len(rows)}: {ok_random}; "
          f"EP<=greedy on {n_greedy}/{len(rows)}; EP<default on {n_default}/{len(rows)} "
          f"(paper: default~EP on pre-ordered banded inputs); "
          f"EP quality parity-or-better vs hypergraph stand-in: {par}; "
          f"EP faster than the stand-in on {n_fast}/{len(rows)}")
    return rows


if __name__ == "__main__":
    main()

"""PlanScheduler: priorities, cancellation, coalescing, close, metrics.

These tests drive the scheduler standalone with controllable jobs (events +
sleeps), so queue semantics are observable without any partitioning.
"""
import threading

import pytest

from repro.core import (
    DoubleBuffer,
    PlanCancelledError,
    PlanScheduler,
    ServiceClosedError,
)


def make_job(record=None, gate=None, value="v"):
    """Job fn that optionally blocks on ``gate`` and appends to ``record``."""

    def fn(tag):
        if gate is not None:
            gate.wait(10)
        if record is not None:
            record.append(tag)
        return (tag, value)

    return fn


def pin_worker(sched, record=None):
    """Occupy the (single) worker with a gated job; returns (ticket, gate)
    once the job is observably running, so later submits stay queued."""
    gate = threading.Event()
    started = threading.Event()

    def fn(tag):
        started.set()
        gate.wait(10)
        if record is not None:
            record.append(tag)
        return tag

    ticket = sched.submit("hold", fn, ("hold",))[0]
    assert started.wait(10)
    return ticket, gate


@pytest.fixture()
def sched():
    s = PlanScheduler(workers=1)
    s.start()
    yield s
    s.close()


class TestPriorities:
    def test_priority_order_under_saturated_queue(self, sched):
        """With the single worker pinned, queued requests must drain
        highest-priority-first, FIFO within a class."""
        record: list = []
        blocker, gate = pin_worker(sched, record)
        tickets = {}
        for tag, prio in (("low1", 0), ("high", 5), ("low2", 0), ("mid", 2)):
            tickets[tag] = sched.submit(tag, make_job(record), (tag,), priority=prio)[0]
        gate.set()
        for t in tickets.values():
            t.result(timeout=30)
        blocker.result(timeout=30)
        assert record == ["hold", "high", "mid", "low1", "low2"]

    def test_priority_bump_on_coalesced_resubmit(self, sched):
        record: list = []
        blocker, gate = pin_worker(sched, record)
        ta = sched.submit("a", make_job(record), ("a",), priority=1)[0]
        sched.submit("b", make_job(record), ("b",), priority=0)
        # Re-submit b at a higher priority: it must now beat a.
        t, created = sched.submit("b", make_job(record), ("b",), priority=9)
        assert not created
        gate.set()
        t.result(timeout=30)
        ta.result(timeout=30)
        blocker.result(timeout=30)
        assert record == ["hold", "b", "a"]


class TestCancellation:
    def test_cancel_queued_drops_work(self, sched):
        record: list = []
        blocker, gate = pin_worker(sched, record)
        victim = sched.submit("victim", make_job(record), ("victim",))[0]
        keeper = sched.submit("keeper", make_job(record), ("keeper",))[0]
        assert victim.cancel()
        assert victim.cancelled
        with pytest.raises(PlanCancelledError):
            victim.result(timeout=5)
        gate.set()
        keeper.result(timeout=30)
        blocker.result(timeout=30)
        assert "victim" not in record  # the work never ran
        m = sched.metrics_snapshot()
        assert m.cancelled_queued == 1

    def test_cancel_inflight_marks_but_completes(self, sched):
        gate = threading.Event()
        started = threading.Event()

        def fn(tag):
            started.set()
            gate.wait(10)
            return tag

        ticket = sched.submit("job", fn, ("job",))[0]
        assert started.wait(10)
        assert not ticket.cancel()  # cannot interrupt a running worker
        assert ticket.cancelled is True  # ... but the mark sticks
        gate.set()
        assert ticket.result(timeout=30) == "job"  # work salvaged
        assert sched.metrics_snapshot().cancelled_inflight == 1

    def test_cancel_coalesced_detaches_only(self, sched):
        blocker, gate = pin_worker(sched)
        t1 = sched.submit("shared", make_job(), ("shared",))[0]
        t2, created = sched.submit("shared", make_job(), ("shared",))
        assert t2 is t1 and not created
        assert not t1.cancel()  # two waiters: first cancel only detaches
        assert not t1.cancelled
        gate.set()
        assert t1.result(timeout=30) == ("shared", "v")
        blocker.result(timeout=30)

    def test_cancel_with_buffer_detaches_publication(self, sched):
        """A cancelled caller's DoubleBuffer must not receive the plan the
        shared computation eventually produces for the other waiters."""
        blocker, gate = pin_worker(sched)
        mine, theirs = DoubleBuffer(), DoubleBuffer()
        t1 = sched.submit("shared", make_job(), ("shared",), buffer=mine)[0]
        sched.submit("shared", make_job(), ("shared",), buffer=theirs)
        assert not t1.cancel(buffer=mine)  # coalesced: detach only
        gate.set()
        out = t1.result(timeout=30)
        blocker.result(timeout=30)
        assert theirs.current()[0] == out  # the other waiter sees the swap
        assert mine.current() == (None, 0)  # the canceller's buffer is clean

    def test_cancel_resolved_ticket_is_noop(self, sched):
        t = sched.submit("done", make_job(), ("done",))[0]
        t.result(timeout=30)
        assert not t.cancel()


class TestCoalescing:
    def test_concurrent_submits_share_one_computation(self, sched):
        record: list = []
        blocker, gate = pin_worker(sched, record)
        buf1, buf2 = DoubleBuffer(), DoubleBuffer()
        t1 = sched.submit("x", make_job(record), ("x",), buffer=buf1)[0]
        t2, created = sched.submit("x", make_job(record), ("x",), buffer=buf2)
        assert t2 is t1 and not created
        gate.set()
        out = t1.result(timeout=30)
        blocker.result(timeout=30)
        assert record.count("x") == 1  # one shared computation
        # Every coalesced caller's buffer sees the publish.
        assert buf1.current()[0] == out and buf2.current()[0] == out
        assert sched.metrics_snapshot().coalesced == 1


class TestClose:
    def test_close_idempotent(self):
        s = PlanScheduler(workers=1)
        s.start()
        s.close()
        s.close()  # second close is a no-op
        assert s.closed

    def test_close_fails_queued_tickets(self):
        s = PlanScheduler(workers=1)  # never started: everything stays queued
        t = s.submit("q", make_job(), ("q",))[0]
        s.close()
        with pytest.raises(ServiceClosedError):
            t.result(timeout=5)

    def test_submit_after_close_fails_fast(self):
        s = PlanScheduler(workers=1)
        s.close()
        t, created = s.submit("late", make_job(), ("late",))
        assert not created
        with pytest.raises(ServiceClosedError, match="closed"):
            t.result(timeout=5)

    def test_close_lets_inflight_finish(self):
        s = PlanScheduler(workers=1)
        s.start()
        gate = threading.Event()
        started = threading.Event()

        def fn(tag):
            started.set()
            gate.wait(10)
            return tag

        t = s.submit("run", fn, ("run",))[0]
        assert started.wait(10)
        closer = threading.Thread(target=s.close)
        closer.start()
        gate.set()
        closer.join(timeout=10)
        assert t.result(timeout=5) == "run"

    def test_restart_after_close_serves_again(self):
        """start() reopens a closed scheduler — the pre-pool service
        supported close() -> start() revival and callers rely on it."""
        s = PlanScheduler(workers=1)
        s.start()
        s.close()
        assert s.closed
        s.start()
        try:
            assert not s.closed
            assert s.submit("again", make_job(), ("again",))[0].result(30) == (
                "again", "v")
        finally:
            s.close()


def _boom(tag):
    raise ValueError(f"boom {tag}")


class TestErrorsAndMetrics:
    def test_job_error_propagates_and_worker_survives(self, sched):
        t = sched.submit("bad", _boom, ("bad",))[0]
        with pytest.raises(ValueError, match="boom"):
            t.result(timeout=30)
        ok = sched.submit("good", make_job(), ("good",))[0]
        assert ok.result(timeout=30) == ("good", "v")
        m = sched.metrics_snapshot()
        assert m.jobs_failed == 1 and m.jobs_completed == 1

    def test_metrics_snapshot_shape(self, sched):
        for i in range(4):
            sched.submit(f"j{i}", make_job(), (f"j{i}",), tenant="tA")[0].result(30)
        m = sched.metrics_snapshot()
        assert m.workers == 1 and m.executor == "thread"
        assert m.queue_depth == 0
        assert m.jobs_completed == 4
        assert m.tenants["tA"]["submitted"] == 4
        assert m.tenants["tA"]["completed"] == 4
        lat = m.latency_s
        assert lat["count"] == 4
        assert lat["p50"] <= lat["p99"] <= lat["max"]
        assert sum(lat["histogram"].values()) == 4
        assert 0.0 <= m.utilization <= 1.0

    def test_queue_depth_counts_waiting_jobs(self, sched):
        blocker, gate = pin_worker(sched)
        sched.submit("w1", make_job(), ("w1",))
        sched.submit("w2", make_job(), ("w2",))
        m = sched.metrics_snapshot()
        assert m.queue_depth == 2 and m.busy_workers == 1
        gate.set()
        blocker.result(timeout=30)


class TestMultiWorker:
    def test_n_workers_run_concurrently(self):
        s = PlanScheduler(workers=3)
        s.start()
        try:
            barrier = threading.Barrier(3, timeout=10)

            def fn(tag):
                barrier.wait()  # only passable if 3 jobs run at once
                return tag

            tickets = [s.submit(f"c{i}", fn, (f"c{i}",))[0] for i in range(3)]
            for t in tickets:
                assert t.result(timeout=30).startswith("c")
        finally:
            s.close()

    def test_process_executor_runs_module_level_jobs(self):
        s = PlanScheduler(workers=2, executor="process")
        s.start()
        try:
            # len is a picklable builtin; real services ship module-level
            # partition jobs the same way.
            t1 = s.submit("a", len, ("abcd",))[0]
            t2 = s.submit("b", len, ("xy",))[0]
            assert t1.result(timeout=120) == 4
            assert t2.result(timeout=120) == 2
        finally:
            s.close()

    def test_invalid_config_rejected(self):
        with pytest.raises(ValueError):
            PlanScheduler(workers=0)
        with pytest.raises(ValueError):
            PlanScheduler(executor="fibers")

"""Runtime tests: train step, microbatching, optimizer, schedules."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import Model
from repro.optim import (
    AdamWConfig,
    adamw_init,
    adamw_update,
    constant,
    global_norm,
    warmup_cosine,
)
from repro.runtime import init_train_state, make_train_step, split_microbatches


class TestAdamW:
    def test_matches_reference_adam(self):
        """One fp32 step vs a hand-rolled reference."""
        cfg = AdamWConfig(lr=0.1, b1=0.9, b2=0.99, eps=1e-8, weight_decay=0.0, clip_norm=None)
        params = {"w": jnp.asarray([1.0, -2.0, 3.0])}
        grads = {"w": jnp.asarray([0.5, 0.5, -1.0])}
        state = adamw_init(cfg, params)
        new_params, new_state, stats = adamw_update(cfg, grads, state, params)
        m = 0.1 * np.asarray(grads["w"])
        v = 0.01 * np.asarray(grads["w"]) ** 2
        mh = m / (1 - 0.9)
        vh = v / (1 - 0.99)
        ref = np.asarray(params["w"]) - 0.1 * mh / (np.sqrt(vh) + 1e-8)
        np.testing.assert_allclose(np.asarray(new_params["w"]), ref, rtol=1e-6)
        assert int(new_state["count"]) == 1

    def test_weight_decay_pulls_to_zero(self):
        cfg = AdamWConfig(lr=0.1, weight_decay=0.5, clip_norm=None)
        params = {"w": jnp.asarray([10.0])}
        grads = {"w": jnp.asarray([0.0])}
        state = adamw_init(cfg, params)
        new_params, _, _ = adamw_update(cfg, grads, state, params)
        assert float(new_params["w"][0]) < 10.0

    def test_clipping_bounds_update(self):
        cfg = AdamWConfig(lr=1.0, clip_norm=1.0, weight_decay=0.0)
        params = {"w": jnp.zeros(4)}
        grads = {"w": jnp.full((4,), 100.0)}
        state = adamw_init(cfg, params)
        _, _, stats = adamw_update(cfg, grads, state, params)
        assert float(stats["grad_norm"]) == pytest.approx(200.0)

    def test_bf16_state_dtype(self):
        cfg = AdamWConfig(state_dtype="bfloat16")
        params = {"w": jnp.zeros(4, jnp.float32)}
        state = adamw_init(cfg, params)
        assert state["m"]["w"].dtype == jnp.bfloat16

    def test_global_norm(self):
        t = {"a": jnp.asarray([3.0]), "b": jnp.asarray([4.0])}
        assert float(global_norm(t)) == pytest.approx(5.0)


class TestSchedules:
    def test_warmup_cosine_shape(self):
        s = warmup_cosine(1.0, 10, 100)
        assert float(s(0)) == 0.0
        assert float(s(10)) == pytest.approx(1.0, abs=1e-3)
        assert float(s(100)) == pytest.approx(0.1, abs=1e-3)
        assert float(s(55)) < float(s(20))

    def test_constant(self):
        assert float(constant(0.5)(123)) == 0.5


class TestMicrobatching:
    def test_split_shapes(self):
        batch = {
            "tokens": jnp.zeros((8, 16), jnp.int32),
            "positions3": jnp.zeros((3, 8, 16), jnp.int32),
        }
        mbs = split_microbatches(batch, 4)
        assert mbs["tokens"].shape == (4, 2, 16)
        assert mbs["positions3"].shape == (4, 3, 2, 16)

    def test_grad_accum_equals_full_batch(self):
        """nmb=4 must produce the same step as nmb=1 (linearity of grads)."""
        cfg = get_config("granite-3-8b", reduced=True)
        model = Model(cfg)
        rng = jax.random.PRNGKey(0)
        opt = AdamWConfig(lr=1e-3, clip_norm=None)
        state1 = init_train_state(model, opt, rng)
        state4 = init_train_state(model, opt, rng)
        batch = {
            "tokens": jax.random.randint(rng, (8, 32), 0, cfg.vocab_size),
            "labels": jax.random.randint(rng, (8, 32), 0, cfg.vocab_size),
        }
        s1, m1 = jax.jit(make_train_step(model, opt, num_microbatches=1))(state1, batch)
        s4, m4 = jax.jit(make_train_step(model, opt, num_microbatches=4))(state4, batch)
        assert float(m1["loss"]) == pytest.approx(float(m4["loss"]), rel=1e-5)
        for a, b in zip(jax.tree.leaves(s1.params), jax.tree.leaves(s4.params)):
            np.testing.assert_allclose(
                np.asarray(a, np.float32), np.asarray(b, np.float32), rtol=2e-4, atol=2e-5
            )


class TestTraining:
    @pytest.mark.parametrize("arch", ["granite-3-8b", "qwen3-moe-30b-a3b", "mamba2-2.7b"])
    def test_loss_decreases(self, arch):
        """A few hundred tokens memorized: loss must drop substantially."""
        cfg = get_config(arch, reduced=True)
        model = Model(cfg)
        rng = jax.random.PRNGKey(0)
        opt = AdamWConfig(lr=3e-3)
        state = init_train_state(model, opt, rng)
        step = jax.jit(make_train_step(model, opt, num_microbatches=2))
        batch = {
            "tokens": jax.random.randint(rng, (4, 32), 2, cfg.vocab_size),
            "labels": jax.random.randint(rng, (4, 32), 2, cfg.vocab_size),
        }
        losses = []
        for _ in range(15):
            state, metrics = step(state, batch)
            losses.append(float(metrics["loss"]))
        assert losses[-1] < losses[0] * 0.8, (arch, losses[0], losses[-1])

"""Request API + bucketed compilation + micro-batched serving (GraphServer).

Covers the serve-path guarantees the benchmarks gate on:
  * bucket ceilings are exact at the boundary (a dim exactly at a geometric
    ceiling stays there; one past it doubles) and oversized requests fall
    back to a dedicated compile instead of crashing;
  * zero-padded operand tails are invisible — bucketed results match the
    kernels/ref oracle and are byte-identical to dedicated serving;
  * one compiled kernel serves every structure in a bucket;
  * the compile cache evicts by (size, recency) and surfaces counters
    through ``GraphServer.metrics()``;
  * micro-batched requests keep per-request tenant/batch attribution;
  * the deprecated shims (tuple serve fn, kernels.resolve_plan, ServicePlan
    into make_ep_spmv_fn, the timeout kwarg) warn but keep working.
"""
import numpy as np
import pytest

from repro.core import PartitionService, PlanPadding, synthetic_bipartite_graph
from repro.kernels import make_ep_spmv_fn, pad_plan_operands
from repro.runtime import (
    BucketKey,
    BucketPolicy,
    CompileCache,
    GraphRequest,
    GraphServer,
)


@pytest.fixture()
def service():
    with PartitionService() as svc:
        yield svc


def _entry(n_rows, n_cols, nnz_per_row, seed):
    _, rows, cols = synthetic_bipartite_graph(n_rows, n_cols, nnz_per_row, seed=seed)
    rng = np.random.default_rng(seed + 1000)
    vals = rng.standard_normal(rows.shape[0]).astype(np.float32)
    x = rng.standard_normal(n_cols).astype(np.float32)
    return GraphRequest(n_rows, n_cols, rows, cols, vals, x)


def _ref(req: GraphRequest) -> np.ndarray:
    import jax.numpy as jnp

    from repro.kernels.ref import spmv_coo_ref

    return np.asarray(spmv_coo_ref(
        req.n_rows, jnp.asarray(req.rows), jnp.asarray(req.cols),
        jnp.asarray(req.vals), jnp.asarray(req.x),
    ))


def _padding(n_rows, n_cols, nnz, k=4):
    return PlanPadding(pad=8, k=k, n_rows=n_rows, n_cols=n_cols, nnz=nnz,
                       e_max=0, x_max=0, y_max=0)


class TestBucketPolicy:
    def test_floors_and_growth(self):
        pol = BucketPolicy()
        key = pol.bucket_for(_padding(10, 10, 10), "software")
        assert (key.n_rows, key.n_cols, key.nnz) == (256, 256, 1024)
        key = pol.bucket_for(_padding(300, 600, 3000), "software")
        assert (key.n_rows, key.n_cols, key.nnz) == (512, 1024, 4096)

    def test_exactly_at_ceiling_stays(self):
        pol = BucketPolicy()
        key = pol.bucket_for(_padding(256, 512, 2048), "software")
        assert (key.n_rows, key.n_cols, key.nnz) == (256, 512, 2048)
        # One past any ceiling doubles that dim only.
        key = pol.bucket_for(_padding(257, 512, 2048), "software")
        assert (key.n_rows, key.n_cols, key.nnz) == (512, 512, 2048)
        key = pol.bucket_for(_padding(256, 512, 2049), "software")
        assert (key.n_rows, key.n_cols, key.nnz) == (256, 512, 4096)

    def test_oversized_returns_none(self):
        pol = BucketPolicy(max_rows=64, max_cols=64, max_nnz=128)
        assert pol.bucket_for(_padding(65, 10, 10), "software") is None
        assert pol.bucket_for(_padding(10, 10, 129), "software") is None
        assert pol.bucket_for(_padding(64, 64, 128), "software") is not None

    def test_key_identity_and_label(self):
        pol = BucketPolicy()
        a = pol.bucket_for(_padding(150, 150, 900), "software")
        b = pol.bucket_for(_padding(200, 130, 1000), "software")
        assert a == b and a.label == b.label  # shared compile key
        assert a.label == "r256c256e1024k4-software"
        assert pol.bucket_for(_padding(150, 150, 900), "streaming") != a


class TestBucketSpec:
    def test_fits_and_pad_rejects_too_small(self, service):
        _, rows, cols = synthetic_bipartite_graph(96, 96, 4, seed=0)
        sp = service.get_spmv_plan(96, 96, rows, cols, k=4, pad=8)
        key = BucketPolicy().bucket_for(sp.padding, "software")
        spec = key.spec(batch=2, pad=8)
        assert spec.fits(sp.plan)
        vals = np.ones(rows.shape[0], dtype=np.float32)
        ops = pad_plan_operands(sp.plan, vals, spec)
        assert ops[0].shape == (spec.k, spec.e_max)
        # Tail slots are zero vals / sentinel rows — nothing to contribute.
        e_counts = np.asarray(sp.plan.e_count)
        for c in range(spec.k):
            assert not ops[0][c, e_counts[c]:].any()
        small = BucketKey(8, 8, 8, k=4, mode="software").spec(batch=1, pad=8)
        assert not small.fits(sp.plan)
        with pytest.raises(ValueError):
            pad_plan_operands(sp.plan, vals, small)


class TestGraphRequest:
    def test_normalizes_dtypes(self):
        req = _entry(32, 32, 2, seed=0)
        req2 = GraphRequest(32, 32, req.rows.astype(np.int32),
                            req.cols.astype(np.int32),
                            req.vals.astype(np.float64),
                            req.x.astype(np.float64))
        assert req2.rows.dtype == np.int64 and req2.vals.dtype == np.float32
        assert req2.x.dtype == np.float32

    def test_rejects_bad_shapes(self):
        req = _entry(32, 32, 2, seed=0)
        with pytest.raises(ValueError):
            GraphRequest(32, 32, req.rows, req.cols, req.vals, req.x[:-1])
        with pytest.raises(ValueError):
            GraphRequest(32, 32, req.rows, req.cols, req.vals[:-1], req.x)

    def test_vals_digest_tracks_values(self):
        req = _entry(32, 32, 2, seed=0)
        d1 = req.vals_digest()
        req.vals = req.vals + 1.0
        assert req.vals_digest() != d1


class TestCompileCache:
    def test_hit_miss_counters_and_single_build(self):
        cache = CompileCache(capacity=4)
        built = []
        for _ in range(3):
            fn = cache.get_or_build("k", 10, lambda: built.append(1) or "fn")
        assert fn == "fn" and len(built) == 1
        assert cache.misses == 1 and cache.hits == 2
        assert cache.hits_for("k") == 2

    def test_evicts_largest_of_oldest_quarter(self):
        cache = CompileCache(capacity=4)
        for key, size in [("a", 1), ("b", 10), ("c", 1), ("d", 1)]:
            cache.get_or_build(key, size, lambda: key)
        cache.get_or_build("a", 1, lambda: "a")  # refresh a's recency
        cache.get_or_build("e", 1, lambda: "e")  # overflow -> evict
        # Victim cohort is the oldest quarter {b, c}; b is bigger.
        assert "b" not in cache and "a" in cache and "c" in cache
        assert cache.evictions == 1
        assert len(cache) == 4

    def test_rejects_zero_capacity(self):
        with pytest.raises(ValueError):
            CompileCache(capacity=0)


class TestGraphServerServe:
    def test_bucketed_matches_ref_across_sweep(self, service):
        server = GraphServer(service, k=4, pad=8, start_batcher=False)
        for n_rows, n_cols, npr in [(64, 64, 3), (96, 80, 4), (150, 150, 5)]:
            for seed in range(2):
                req = _entry(n_rows, n_cols, npr, seed=seed)
                res = server.serve(req)
                assert res.info.bucket is not None
                assert res.y.shape == (n_rows,)  # de-padded
                np.testing.assert_allclose(np.asarray(res.y), _ref(req),
                                           rtol=1e-5, atol=1e-5)

    def test_bucketed_byte_identical_to_dedicated(self, service):
        bucketed = GraphServer(service, k=4, pad=8, start_batcher=False)
        dedicated = GraphServer(service, k=4, pad=8, bucketing=None,
                                start_batcher=False)
        for seed in range(3):
            req = _entry(120, 120, 4, seed=seed)
            y_b = np.asarray(bucketed.serve(req).y)
            y_d = np.asarray(dedicated.serve(req).y)
            assert np.array_equal(y_b, y_d)  # byte-identical, not just close

    def test_same_bucket_shares_one_compile(self, service):
        server = GraphServer(service, k=4, pad=8, start_batcher=False)
        r1 = server.serve(_entry(150, 150, 4, seed=0))
        r2 = server.serve(_entry(150, 150, 4, seed=1))  # distinct structure
        assert r1.info.bucket == r2.info.bucket
        assert not r1.info.kernel_cache_hit and r2.info.kernel_cache_hit
        stats = server.stats()
        assert stats["misses"] == 1 and stats["hits"] >= 1
        assert len(stats["buckets"]) == 1

    def test_exactly_at_ceiling_request_serves(self, service):
        server = GraphServer(service, k=4, pad=8, start_batcher=False)
        req = _entry(256, 256, 3, seed=0)  # n_rows/n_cols exactly at floor
        res = server.serve(req)
        assert res.info.bucket.startswith("r256c256")
        np.testing.assert_allclose(np.asarray(res.y), _ref(req),
                                   rtol=1e-5, atol=1e-5)

    def test_oversized_falls_back_to_dedicated(self, service):
        pol = BucketPolicy(max_rows=64, max_cols=64, max_nnz=128)
        server = GraphServer(service, k=4, pad=8, bucketing=pol,
                             start_batcher=False)
        req = _entry(96, 96, 4, seed=0)
        res = server.serve(req)
        assert res.info.bucket is None and res.info.batch_size == 1
        np.testing.assert_allclose(np.asarray(res.y), _ref(req),
                                   rtol=1e-5, atol=1e-5)
        assert server.stats()["buckets"] == {}

    def test_eviction_surfaced_in_metrics(self, service):
        server = GraphServer(service, k=4, pad=8, bucketing=None,
                             compile_cache_entries=1, start_batcher=False)
        server.serve(_entry(64, 64, 3, seed=0))
        server.serve(_entry(64, 64, 3, seed=1))
        cc = server.metrics().compile_cache
        assert cc["misses"] == 2 and cc["evictions"] >= 1
        assert cc["entries"] == 1

    def test_submit_requires_batcher(self, service):
        server = GraphServer(service, k=4, pad=8, start_batcher=False)
        with pytest.raises(RuntimeError):
            server.submit(_entry(64, 64, 3, seed=0))


class TestGraphServerBatching:
    def test_mixed_tenant_batch_attribution(self, service):
        reqs = []
        for i, tenant in enumerate(["acme", "globex", "initech"]):
            req = _entry(100, 100, 4, seed=20 + i)
            req.tenant = tenant
            reqs.append(req)
        with GraphServer(service, k=4, pad=8, max_batch=4,
                         max_wait_ms=300.0) as server:
            # Warm plans + the bucket executable so the submits below land
            # inside one batch window.
            warm = {id(r): np.asarray(server.serve(r).y) for r in reqs}
            handles = [server.submit(r) for r in reqs]
            results = [h.wait(60.0) for h in handles]
            hist = server.stats()["batch_hist"]
        for req, res in zip(reqs, results):
            assert res.info.tenant == req.tenant  # per-request attribution
            assert res.info.bucket is not None
            assert res.info.batch_size == 3
            # Stacked launch, de-padded: byte-identical to the batch-of-1.
            assert np.array_equal(np.asarray(res.y), warm[id(req)])
        assert hist.get(3, 0) >= 1

    def test_submit_after_close_raises(self, service):
        server = GraphServer(service, k=4, pad=8)
        server.close()
        with pytest.raises(RuntimeError):
            server.submit(_entry(64, 64, 3, seed=0))


class TestBrownout:
    def test_ladder_levels_and_window_recovery(self, service):
        import time

        server = GraphServer(service, k=4, pad=8, start_batcher=False,
                             brownout_window_s=0.25, brownout_hedge_off=2,
                             brownout_stale_only=4)
        assert server.brownout_level() == 0
        server._note_rejection()
        assert server.brownout_level() == 0  # below the first rung
        server._note_rejection()
        assert server.brownout_level() == 1  # hedging off
        server._note_rejection()
        server._note_rejection()
        assert server.brownout_level() == 2  # stale-only for low priority
        assert server.stats()["brownout_level"] == 2
        time.sleep(0.3)
        # Rejections aged out of the window: recovery is automatic.
        assert server.brownout_level() == 0

    def test_hedge_rung_disables_and_restores_group_hedging(self):
        import time

        from repro.core import ReplicaGroup

        with ReplicaGroup(2) as g:
            server = GraphServer(service=g, k=4, start_batcher=False,
                                 brownout_window_s=0.25,
                                 brownout_hedge_off=1,
                                 brownout_stale_only=99)
            req = _entry(96, 96, 3, seed=0)
            server.serve(req)
            assert g.hedge  # level 0: hedging untouched
            server._note_rejection()
            server.serve(req)
            assert not g.hedge  # level 1: hedging saved + disabled
            time.sleep(0.3)
            server.serve(req)
            assert g.hedge  # pressure aged out: hedging restored

    def test_stale_only_serves_cached_degraded_and_rejects_cold(self):
        from repro.core import AdmissionRejectedError, ReplicaGroup

        with ReplicaGroup(2, hedge=False) as g:
            server = GraphServer(service=g, k=4, start_batcher=False,
                                 brownout_window_s=5.0,
                                 brownout_hedge_off=1,
                                 brownout_stale_only=2,
                                 brownout_priority_floor=1)
            hot = _entry(96, 96, 3, seed=1)  # default priority 0 < floor
            res = server.serve(hot)
            assert not res.info.degraded
            for _ in range(2):
                server._note_rejection()  # push to the stale-only rung
            # The warmed graph still answers — from cache, flagged.
            res2 = server.serve(hot)
            assert res2.info.degraded
            assert res2.info.as_dict()["degraded"] is True
            np.testing.assert_array_equal(np.asarray(res2.y),
                                          np.asarray(res.y))
            # An uncached graph from a low-priority tenant is refused with
            # the typed brownout rejection (retry ~ the pressure window).
            cold = _entry(96, 96, 3, seed=2)
            with pytest.raises(AdmissionRejectedError) as ei:
                server.serve(cold)
            assert ei.value.reason == "brownout"
            assert ei.value.retry_after_s == 5.0
            # Priority at/above the floor bypasses the rung entirely.
            vip = _entry(96, 96, 3, seed=3)
            vip.priority = 1
            res3 = server.serve(vip)
            assert not res3.info.degraded
            stats = server.stats()
            assert stats["degraded_serves"] >= 1
            assert stats["brownout_rejects"] >= 1
            assert stats["brownout_level"] == 2


class TestDeprecatedShims:
    def test_make_graph_serve_fn_warns_but_serves(self, service):
        from repro.runtime import make_graph_serve_fn

        with pytest.warns(DeprecationWarning):
            serve = make_graph_serve_fn(service, k=4, pad=8)
        req = _entry(64, 64, 3, seed=0)
        y, info = serve(req.n_rows, req.n_cols, req.rows, req.cols,
                        req.vals, req.x)
        assert isinstance(info, dict) and "cache_hit" in info
        np.testing.assert_allclose(np.asarray(y), _ref(req),
                                   rtol=1e-5, atol=1e-5)

    def test_kernels_resolve_plan_forwarder_warns(self, service):
        from repro.kernels import resolve_plan

        _, rows, cols = synthetic_bipartite_graph(64, 64, 3, seed=0)
        sp = service.get_spmv_plan(64, 64, rows, cols, k=4, pad=8)
        with pytest.warns(DeprecationWarning):
            plan = resolve_plan(sp)
        assert plan is sp.plan

    def test_timeout_kwarg_warns(self, service):
        _, rows, cols = synthetic_bipartite_graph(64, 64, 3, seed=0)
        sp = service.get_spmv_plan(64, 64, rows, cols, k=4, pad=8)
        vals = np.ones(rows.shape[0], dtype=np.float32)
        with pytest.warns(DeprecationWarning):
            make_ep_spmv_fn(sp.plan, vals, timeout=1.0)

"""Gradient compression: quantization bounds + error feedback."""
import jax.numpy as jnp
import numpy as np

from repro.optim import compress_grads, dequantize_int8, quantize_int8


class TestQuantize:
    def test_roundtrip_bound(self):
        rng = np.random.default_rng(0)
        x = jnp.asarray(rng.standard_normal(1000), jnp.float32)
        q, s = quantize_int8(x)
        deq = dequantize_int8(q, s)
        # Error bounded by half a quantization step.
        assert float(jnp.abs(deq - x).max()) <= float(s) * 0.5 + 1e-7

    def test_zero_tensor(self):
        q, s = quantize_int8(jnp.zeros(8))
        np.testing.assert_array_equal(np.asarray(q), 0)

    def test_payload_is_int8(self):
        q, _ = quantize_int8(jnp.asarray([1.0, -1.0]))
        assert q.dtype == jnp.int8  # 4x smaller on the wire than f32


class TestErrorFeedback:
    def test_error_carries_residual(self):
        g = {"w": jnp.asarray([0.3, -0.7, 1.2])}
        e = {"w": jnp.zeros(3)}
        deq, err = compress_grads(g, e)
        np.testing.assert_allclose(
            np.asarray(deq["w"] + err["w"]), np.asarray(g["w"]), rtol=1e-6
        )

    def test_accumulated_updates_converge(self):
        """Sum of compressed grads + final error == sum of true grads —
        compression error does not accumulate into the trajectory."""
        rng = np.random.default_rng(1)
        e = {"w": jnp.zeros(64)}
        total_true = np.zeros(64)
        total_deq = np.zeros(64)
        for i in range(50):
            g = {"w": jnp.asarray(rng.standard_normal(64) * 0.01, jnp.float32)}
            deq, e = compress_grads(g, e)
            total_true += np.asarray(g["w"])
            total_deq += np.asarray(deq["w"])
        resid = np.abs(total_true - total_deq)
        np.testing.assert_allclose(resid, np.asarray(jnp.abs(e["w"])), atol=1e-6)
        assert resid.max() < 0.01  # bounded by one quant step, not 50 steps

"""Admission control: bounded queue, weighted-fair shares, deadline sheds.

Controller units run single-threaded with an injectable fake clock so the
drain-rate / retry_after math is exact; scheduler integration pins the
single worker (the ``pin_worker`` idiom from test_plan_scheduler) so queue
occupancy is fully controlled by the test.
"""
import pickle
import threading
import time

import pytest

from repro.core import (
    AdmissionRejectedError,
    DeadlineShedError,
    PlanScheduler,
    ServiceClosedError,
)
from repro.core.admission import AdmissionController


class FakeClock:
    def __init__(self, t: float = 100.0) -> None:
        self.t = t

    def __call__(self) -> float:
        return self.t

    def advance(self, dt: float) -> None:
        self.t += dt


def make_job(record=None, gate=None, value="v"):
    def fn(tag):
        if gate is not None:
            gate.wait(10)
        if record is not None:
            record.append(tag)
        return (tag, value)

    return fn


def pin_worker(sched):
    """Occupy the single worker with a gated job; returns (ticket, gate)
    once the job is observably running (its queue slot already released at
    pickup), so later submits stay queued."""
    gate = threading.Event()
    started = threading.Event()

    def fn(tag):
        started.set()
        gate.wait(10)
        return tag

    ticket = sched.submit("hold", fn, ("hold",))[0]
    assert started.wait(10)
    return ticket, gate


class TestControllerShares:
    def test_lone_tenant_gets_full_bound(self):
        ac = AdmissionController(8)
        assert ac.share("a") == 8
        for _ in range(8):
            assert ac.try_acquire("a") is None
        assert ac.try_acquire("a") is not None

    def test_shares_contract_when_second_tenant_arrives(self):
        """Work-conserving: a lone tenant may fill the queue, but the share
        computation contracts the moment anyone else competes."""
        ac = AdmissionController(8)
        assert ac.try_acquire("a") is None
        # 'b' asking makes the active set {a, b}: equal weights halve it.
        assert ac.share("b") == 4
        # Until 'b' holds a slot it is not active from a's point of view...
        assert ac.share("a") == 8
        # ...but the moment it does, a's share contracts too.
        assert ac.try_acquire("b") is None
        assert ac.share("a") == 4

    def test_share_floor_of_one_prevents_starvation(self):
        ac = AdmissionController(4, tenant_weights={"big": 100.0})
        for _ in range(4):
            ac.try_acquire("big")
        # small's weighted share rounds to 0 but is floored at 1 slot.
        assert ac.share("small") == 1
        assert ac.try_acquire("small") is None

    def test_weighted_shares(self):
        ac = AdmissionController(9, tenant_weights={"a": 2.0, "b": 1.0})
        ac.try_acquire("a")
        ac.try_acquire("b")
        assert ac.share("a") == 6
        assert ac.share("b") == 3

    def test_release_returns_slots(self):
        ac = AdmissionController(2)
        assert ac.try_acquire("a") is None
        assert ac.try_acquire("a") is None
        assert ac.try_acquire("a") is not None
        ac.release("a")
        assert ac.try_acquire("a") is None
        assert ac.occupancy() == {"a": 2}

    def test_occupancy_drops_empty_tenants(self):
        ac = AdmissionController(4)
        ac.try_acquire("a")
        ac.release("a")
        assert ac.occupancy() == {}
        ac.release("a")  # over-release is a no-op
        assert ac.held("a") == 0

    def test_validation(self):
        with pytest.raises(ValueError):
            AdmissionController(0)
        with pytest.raises(ValueError):
            AdmissionController(4, default_weight=0.0)
        with pytest.raises(ValueError):
            AdmissionController(4, tenant_weights={"a": -1.0})


class TestRetryAfter:
    def test_deterministic_floor_without_history(self):
        """No completions observed -> the hint is exactly the floor (the
        transport wire test byte-compares this determinism)."""
        ac = AdmissionController(1, retry_floor_s=0.05)
        assert ac.try_acquire("a") is None
        err = ac.try_acquire("a")
        assert isinstance(err, AdmissionRejectedError)
        assert err.retry_after_s == 0.05
        assert err.tenant == "a"
        assert err.reason == "queue_full"

    def test_drain_rate_math(self):
        clk = FakeClock()
        ac = AdmissionController(4, clock=clk)
        assert ac.drain_rate() == 0.0
        for _ in range(5):
            ac.note_drained()
            clk.advance(0.1)
        # 5 samples over 0.4s span -> (5-1)/0.4 = 10 completions/s.
        assert ac.drain_rate() == pytest.approx(10.0)

    def test_retry_after_scales_with_excess_and_clamps(self):
        clk = FakeClock()
        ac = AdmissionController(2, retry_cap_s=5.0, clock=clk)
        ac.try_acquire("a")
        ac.try_acquire("a")
        for _ in range(3):
            ac.note_drained()
            clk.advance(1.0)  # 1 completion/s
        # held=2, share=2 -> excess floored at 1 -> 1s at 1/s.
        assert ac.retry_after("a") == pytest.approx(1.0)
        ac._held["a"] = 6  # excess 5 -> 5s, at the cap
        assert ac.retry_after("a") == pytest.approx(5.0)
        ac._held["a"] = 60  # est 55s clamps to the cap
        assert ac.retry_after("a") == pytest.approx(5.0)

    def test_snapshot_keys(self):
        ac = AdmissionController(3)
        ac.try_acquire("a")
        snap = ac.snapshot()
        assert snap == {
            "max_queue_depth": 3,
            "occupancy": {"a": 1},
            "drain_rate": 0.0,
        }


class TestRejectionPickling:
    def test_reduce_round_trips_all_fields(self):
        err = AdmissionRejectedError(
            "msg", retry_after_s=1.25, tenant="t1", reason="brownout")
        back = pickle.loads(pickle.dumps(err))
        assert type(back) is AdmissionRejectedError
        assert str(back) == "msg"
        assert back.retry_after_s == 1.25
        assert back.tenant == "t1"
        assert back.reason == "brownout"

    def test_deadline_shed_is_not_retryable(self):
        assert not issubclass(DeadlineShedError, AdmissionRejectedError)


class TestBoundedScheduler:
    @pytest.fixture()
    def sched(self):
        s = PlanScheduler(workers=1, max_queue_depth=2)
        s.start()
        yield s
        s.close()

    def test_over_share_submit_raises(self, sched):
        blocker, gate = pin_worker(sched)
        sched.submit("a", make_job(), ("a",))
        sched.submit("b", make_job(), ("b",))
        with pytest.raises(AdmissionRejectedError) as ei:
            sched.submit("c", make_job(), ("c",))
        assert ei.value.retry_after_s > 0
        assert ei.value.reason == "queue_full"
        gate.set()
        blocker.result(timeout=30)

    def test_coalesced_submit_bypasses_admission(self, sched):
        blocker, gate = pin_worker(sched)
        sched.submit("a", make_job(), ("a",))
        sched.submit("b", make_job(), ("b",))
        # Same key as a queued job: shares the ticket, takes no new slot.
        _, created = sched.submit("a", make_job(), ("a",))
        assert not created
        gate.set()
        blocker.result(timeout=30)

    def test_block_waits_for_slot(self, sched):
        blocker, gate = pin_worker(sched)
        sched.submit("a", make_job(), ("a",))
        tb = sched.submit("b", make_job(), ("b",))[0]
        admitted = threading.Event()
        result: dict = {}

        def blocked_submit():
            t, _ = sched.submit("c", make_job(), ("c",), block=True)
            admitted.set()
            result["ticket"] = t

        th = threading.Thread(target=blocked_submit, daemon=True)
        th.start()
        assert not admitted.wait(0.2)  # genuinely backpressured
        gate.set()  # worker drains the queue, freeing slots
        assert admitted.wait(10)
        th.join(10)
        assert result["ticket"].result(timeout=30) == ("c", "v")
        tb.result(timeout=30)
        blocker.result(timeout=30)

    def test_cancel_releases_slot(self, sched):
        blocker, gate = pin_worker(sched)
        ta = sched.submit("a", make_job(), ("a",))[0]
        sched.submit("b", make_job(), ("b",))
        assert ta.cancel()
        # a's slot came back: a third submit fits again.
        tc = sched.submit("c", make_job(), ("c",))[0]
        gate.set()
        tc.result(timeout=30)
        blocker.result(timeout=30)

    def test_metrics_expose_overload_counters(self, sched):
        blocker, gate = pin_worker(sched)
        sched.submit("a", make_job(), ("a",), tenant="t1")
        sched.submit("b", make_job(), ("b",), tenant="t1")
        with pytest.raises(AdmissionRejectedError):
            sched.submit("c", make_job(), ("c",), tenant="t1")
        m = sched.metrics_snapshot()
        assert m.queue_depth_max >= 2
        assert m.rejected == 1
        assert m.tenants["t1"]["rejected"] == 1
        assert m.tenants["t1"]["queued"] == 2
        assert m.admission["max_queue_depth"] == 2
        assert m.admission["occupancy"] == {"t1": 2}
        gate.set()
        blocker.result(timeout=30)

    def test_tenant_weights_require_bound(self):
        with pytest.raises(ValueError):
            PlanScheduler(workers=1, tenant_weights={"a": 2.0})


class TestWeightedFairness:
    def test_flooder_cannot_starve_weighted_victim(self):
        s = PlanScheduler(workers=1, max_queue_depth=4,
                          tenant_weights={"victim": 2.0, "flood": 1.0})
        s.start()
        try:
            blocker, gate = pin_worker(s)
            # Flooder grabs what it can: sole active tenant at first, but
            # its share contracts as the victim competes.
            flood_ok = 0
            for i in range(6):
                try:
                    s.submit(f"f{i}", make_job(), (f"f{i}",), tenant="flood")
                    flood_ok += 1
                except AdmissionRejectedError:
                    break
            assert flood_ok == 4  # lone tenant: full bound, work-conserving
            # The victim's floor-of-one slot is untouchable.
            tv = s.submit("v", make_job(), ("v",), tenant="victim")[0]
            gate.set()
            assert tv.result(timeout=30) == ("v", "v")
            blocker.result(timeout=30)
        finally:
            s.close()


class TestDeadlineShedding:
    def test_shed_at_door_when_p50_exceeds_budget(self):
        s = PlanScheduler(workers=1)
        s.start()
        try:
            # Build service-time history: p50 ~ 50ms.
            for i in range(3):
                s.submit(f"w{i}", lambda t: time.sleep(0.05) or t,
                         (f"w{i}",))[0].result(timeout=30)
            t = s.submit("late", make_job(), ("late",),
                         deadline=time.perf_counter() + 0.001)[0]
            with pytest.raises(DeadlineShedError, match="shed at admission"):
                t.result(timeout=30)
            assert s.metrics_snapshot().shed_deadline == 1
        finally:
            s.close()

    def test_shed_at_pickup_when_aged_out_in_queue(self):
        s = PlanScheduler(workers=1)
        s.start()
        try:
            blocker, gate = pin_worker(s)
            # Cold scheduler: no p50 history, so the door admits this.
            t = s.submit("aged", make_job(), ("aged",),
                         deadline=time.perf_counter() + 0.05)[0]
            time.sleep(0.2)  # ages out while the worker is pinned
            gate.set()
            with pytest.raises(DeadlineShedError, match="shed at pickup"):
                t.result(timeout=30)
            blocker.result(timeout=30)
            assert s.metrics_snapshot().shed_deadline == 1
        finally:
            s.close()

    def test_coalesced_waiter_extends_deadline(self):
        s = PlanScheduler(workers=1)
        s.start()
        try:
            blocker, gate = pin_worker(s)
            tight = time.perf_counter() + 0.05
            t1 = s.submit("j", make_job(), ("j",), deadline=tight)[0]
            # A laxer waiter keeps the job alive past the first deadline.
            t2, created = s.submit("j", make_job(), ("j",),
                                   deadline=tight + 30.0)
            assert not created and t2 is t1
            time.sleep(0.2)
            gate.set()
            assert t1.result(timeout=30) == ("j", "v")
            blocker.result(timeout=30)
        finally:
            s.close()


class TestCloseRace:
    def test_submit_after_close_gets_closed_error_not_admission(self):
        """Regression: a submit racing close() must observe
        ServiceClosedError deterministically — never a retryable admission
        hint that steers clients back into a dead service."""
        s = PlanScheduler(workers=1, max_queue_depth=1)
        s.start()
        blocker, gate = pin_worker(s)
        s.submit("a", make_job(), ("a",))  # queue (and the bound) is full
        gate.set()
        s.close()
        t, created = s.submit("b", make_job(), ("b",))
        assert not created
        with pytest.raises(ServiceClosedError):
            t.result(timeout=30)

    def test_blocked_submit_woken_by_close_gets_closed_error(self):
        s = PlanScheduler(workers=1, max_queue_depth=1)
        s.start()
        blocker, gate = pin_worker(s)
        s.submit("a", make_job(), ("a",))
        errs: list = []
        entered = threading.Event()

        def blocked_submit():
            entered.set()
            t, _ = s.submit("b", make_job(), ("b",), block=True)
            try:
                t.result(timeout=30)
            except BaseException as e:
                errs.append(e)

        th = threading.Thread(target=blocked_submit, daemon=True)
        th.start()
        assert entered.wait(10)
        time.sleep(0.1)  # let the submit reach its backpressure wait
        gate.set()
        s.close()
        th.join(10)
        assert not th.is_alive()
        for e in errs:
            assert isinstance(e, ServiceClosedError), e

    def test_concurrent_submits_during_close_never_see_admission_error(self):
        """Seeded stress for the close()/AdmissionRejectedError race: many
        threads hammering a full queue while close() lands must only ever
        see ServiceClosedError (or a completed/drained ticket)."""
        s = PlanScheduler(workers=1, max_queue_depth=1)
        s.start()
        blocker, gate = pin_worker(s)
        stop = threading.Event()
        close_done = threading.Event()
        bad: list = []

        def hammer(i):
            n = 0
            while not stop.is_set():
                # Snapshot before submitting: an admission error is only a
                # bug if close() had already fully returned by then.
                was_closed = close_done.is_set()
                try:
                    s.submit(f"h{i}-{n}", make_job(), (f"h{i}-{n}",))
                except AdmissionRejectedError:
                    if was_closed:
                        bad.append("admission error after close")
                        return
                n += 1

        threads = [threading.Thread(target=hammer, args=(i,), daemon=True)
                   for i in range(4)]
        for th in threads:
            th.start()
        time.sleep(0.05)
        gate.set()
        s.close()
        close_done.set()
        time.sleep(0.05)  # let the hammers run against the closed scheduler
        stop.set()
        for th in threads:
            th.join(10)
        assert not bad

"""Data pipeline: determinism, stateless resume, host sharding, packing."""
import numpy as np

from repro.data import PipelineConfig, SyntheticPipeline, pack_documents


def _cfg(**kw):
    base = dict(vocab_size=512, seq_len=64, global_batch=8, seed=7)
    base.update(kw)
    return PipelineConfig(**base)


class TestDeterminism:
    def test_same_step_same_batch(self):
        p1 = SyntheticPipeline(_cfg())
        p2 = SyntheticPipeline(_cfg())
        b1, b2 = p1.batch(13), p2.batch(13)
        for k in b1:
            np.testing.assert_array_equal(b1[k], b2[k])

    def test_different_steps_differ(self):
        p = SyntheticPipeline(_cfg())
        assert not np.array_equal(p.batch(0)["tokens"], p.batch(1)["tokens"])

    def test_stateless_resume(self):
        """Batch at step s is identical whether or not steps 0..s-1 ran."""
        p = SyntheticPipeline(_cfg())
        fresh = SyntheticPipeline(_cfg())
        for s in range(5):
            p.batch(s)
        np.testing.assert_array_equal(p.batch(5)["tokens"], fresh.batch(5)["tokens"])


class TestHostSharding:
    def test_hosts_get_different_slices(self):
        a = SyntheticPipeline(_cfg(host_index=0, host_count=2))
        b = SyntheticPipeline(_cfg(host_index=1, host_count=2))
        assert a.local_batch == 4
        assert not np.array_equal(a.batch(0)["tokens"], b.batch(0)["tokens"])

    def test_labels_are_shifted_tokens(self):
        p = SyntheticPipeline(_cfg())
        b = p.batch(0)
        # labels[t] continues the same stream (next token of the packed row)
        assert b["labels"].shape == b["tokens"].shape
        np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])


class TestFrontendStub:
    def test_vision_positions3(self):
        p = SyntheticPipeline(_cfg(frontend="vision", d_model=32))
        b = p.batch(0)
        assert b["embeds"].shape == (8, 64, 32)
        assert b["positions3"].shape == (3, 8, 64)
        assert "tokens" not in b

    def test_encdec_batch(self):
        p = SyntheticPipeline(_cfg(frontend=None, d_model=32))
        b = p.enc_dec_batch(0)
        assert b["enc_embeds"].shape == (8, 64, 32)
        assert "tokens" in b


class TestPacking:
    def test_pack_documents_first_fit(self):
        rows = pack_documents(np.array([30, 30, 30, 4]), seq_len=64)
        # 30+30 fit one row; 30+4 the next.
        assert rows == [[0, 1, 3], [2]]

    def test_rows_respect_capacity(self):
        rng = np.random.default_rng(0)
        lens = rng.integers(1, 50, size=100)
        rows = pack_documents(lens, seq_len=64)
        for r in rows:
            assert sum(min(int(lens[i]), 64) for i in r) <= 64

"""PlanCache: tenant budgets, cost-aware eviction, lineage pinning, persistence.

The cache is the policy half of the multi-tenant scheduling subsystem; these
tests drive it standalone with synthetic plans whose byte size and recompute
cost are exact, so every eviction decision is deterministic.
"""
import numpy as np
import pytest

from repro.core import (
    EdgeList,
    EdgePartitionResult,
    PartitionQuality,
    PlanCache,
    ServicePlan,
)
from repro.core.partition_service import _payload_nbytes


def make_plan(fp: str, m: int = 50, cost: float = 1.0, lineage=None,
              vcycle=None, stage_times=None, coo=None) -> ServicePlan:
    """Synthetic ServicePlan: ~20 bytes per task (labels i32 + u/v i64)."""
    labels = np.zeros(m, dtype=np.int32)
    edges = EdgeList(n=2, u=np.zeros(m, dtype=np.int64), v=np.ones(m, dtype=np.int64))
    quality = PartitionQuality(k=2, vertex_cut=0, balance=1.0,
                               replication=1.0, redundant_fraction=0.0, loads_total=2)
    result = EdgePartitionResult(labels=labels, k=2, method="ep", quality=quality,
                                 partition_time_s=cost)
    return ServicePlan(
        fingerprint=fp, result=result, plan=None, edges=edges, source="full",
        compute_time_s=cost, coo=coo, stage_times_s=stage_times, vcycle=vcycle,
        lineage=lineage,
    )


class TestBudgets:
    def test_tenant_budget_evicts_own_entries_only(self):
        plan_bytes = make_plan("x").nbytes()
        cache = PlanCache(max_entries=64, default_tenant_budget=3 * plan_bytes)
        for i in range(3):
            cache.put(make_plan(f"a{i}"), tenant="alice")
        victim_owner_bytes = cache.tenant_stats()["alice"].bytes
        assert victim_owner_bytes == 3 * plan_bytes
        # Bob floods: 6 plans through a 3-plan budget.
        for i in range(6):
            cache.put(make_plan(f"b{i}"), tenant="bob")
        st = cache.tenant_stats()
        assert st["alice"].entries == 3 and st["alice"].evictions == 0
        assert st["bob"].entries == 3 and st["bob"].evictions == 3
        for i in range(3):
            assert f"a{i}" in cache

    def test_per_tenant_budget_overrides_default(self):
        plan_bytes = make_plan("x").nbytes()
        cache = PlanCache(
            tenant_budgets={"small": plan_bytes},
            default_tenant_budget=10 * plan_bytes,
        )
        cache.put(make_plan("s0"), tenant="small")
        cache.put(make_plan("s1"), tenant="small")
        st = cache.tenant_stats()["small"]
        assert st.entries == 1 and st.evictions == 1
        assert "s1" in cache and "s0" not in cache

    def test_oversized_plan_not_cached(self):
        plan_bytes = make_plan("x", m=1000).nbytes()
        cache = PlanCache(default_tenant_budget=plan_bytes // 2)
        evicted = cache.put(make_plan("big", m=1000), tenant="t")
        assert evicted == 1
        assert "big" not in cache and len(cache) == 0

    def test_oversized_reput_keeps_existing_entry(self):
        """A recompute whose size jitters over budget must not delete the
        warm (possibly pinned, lineage-anchoring) copy already cached."""
        small = make_plan("p", m=50, cost=1.0)
        cache = PlanCache(default_tenant_budget=small.nbytes() + 100)
        cache.put(small, tenant="t")
        cache.pin("p")
        evicted = cache.put(make_plan("p", m=5000, cost=1.0), tenant="t")
        assert evicted == 0
        assert "p" in cache
        assert cache.peek("p") is small  # the old admissible copy survives
        assert cache._entries["p"].pinned

    def test_no_budget_means_unbounded_bytes(self):
        cache = PlanCache(max_entries=64)
        for i in range(10):
            cache.put(make_plan(f"p{i}", m=500), tenant="t")
        assert len(cache) == 10
        assert cache.tenant_stats()["t"].evictions == 0


class TestCostAwareEviction:
    def test_cheapest_per_byte_goes_first(self):
        plan_bytes = make_plan("x").nbytes()
        cache = PlanCache(default_tenant_budget=3 * plan_bytes)
        cache.put(make_plan("cheap", cost=0.001), tenant="t")
        cache.put(make_plan("mid", cost=0.1), tenant="t")
        cache.put(make_plan("dear", cost=10.0), tenant="t")
        cache.put(make_plan("new", cost=1.0), tenant="t")  # forces one eviction
        assert "cheap" not in cache
        assert "mid" in cache and "dear" in cache and "new" in cache

    def test_equal_scores_fall_back_to_lru(self):
        plan_bytes = make_plan("x").nbytes()
        cache = PlanCache(default_tenant_budget=3 * plan_bytes)
        for fp in ("p0", "p1", "p2"):
            cache.put(make_plan(fp, cost=1.0), tenant="t")
        cache.get("p0", "t")  # refresh p0: p1 becomes the LRU
        cache.put(make_plan("p3", cost=1.0), tenant="t")
        assert "p1" not in cache
        assert "p0" in cache and "p2" in cache and "p3" in cache

    def test_global_max_bytes_scored_across_tenants(self):
        plan_bytes = make_plan("x").nbytes()
        cache = PlanCache(max_bytes=2 * plan_bytes)
        cache.put(make_plan("cheap", cost=0.01), tenant="a")
        cache.put(make_plan("dear", cost=5.0), tenant="b")
        cache.put(make_plan("new", cost=1.0), tenant="a")
        assert "cheap" not in cache and "dear" in cache and "new" in cache


class TestLineagePinning:
    def test_base_of_derived_plan_survives(self):
        plan_bytes = make_plan("x").nbytes()
        cache = PlanCache(default_tenant_budget=3 * plan_bytes)
        # Base is the cheapest per byte — without lineage refs it would be
        # the first victim.
        cache.put(make_plan("base", cost=0.001), tenant="t")
        cache.put(make_plan("derived", cost=5.0, lineage="base"), tenant="t")
        cache.put(make_plan("other", cost=1.0), tenant="t")
        cache.put(make_plan("new", cost=1.0), tenant="t")
        assert "base" in cache  # pinned by the derived plan's lineage ref
        assert "other" not in cache

    def test_explicit_pin_and_unpin(self):
        plan_bytes = make_plan("x").nbytes()
        cache = PlanCache(default_tenant_budget=2 * plan_bytes)
        cache.put(make_plan("keep", cost=0.001), tenant="t")
        assert cache.pin("keep")
        cache.put(make_plan("a", cost=1.0), tenant="t")
        cache.put(make_plan("b", cost=1.0), tenant="t")
        assert "keep" in cache and ("a" not in cache or "b" not in cache)
        cache.unpin("keep")
        cache.put(make_plan("c", cost=1.0), tenant="t")
        assert "keep" not in cache  # unpinned, lowest score -> evicted

    def test_pinned_entries_still_evicted_when_nothing_else(self):
        plan_bytes = make_plan("x").nbytes()
        cache = PlanCache(default_tenant_budget=2 * plan_bytes)
        cache.put(make_plan("p0", cost=1.0), tenant="t")
        cache.put(make_plan("p1", cost=1.0), tenant="t")
        cache.pin("p0")
        cache.pin("p1")
        cache.put(make_plan("p2", cost=1.0), tenant="t")
        # Bounded memory beats the pin: one pinned entry had to go.
        assert len(cache) == 2
        assert "p2" in cache

    def test_pin_missing_fingerprint_returns_false(self):
        cache = PlanCache()
        assert not cache.pin("nope")
        assert not cache.unpin("nope")


class TestPersistence:
    def test_save_load_round_trip(self, tmp_path):
        path = str(tmp_path / "cache.pkl")
        cache = PlanCache()
        cache.put(make_plan("p0", m=20, cost=0.5), tenant="a")
        cache.put(make_plan("p1", m=30, cost=1.5, lineage="p0"), tenant="b")
        cache.pin("p0")
        assert cache.save(path) == 2

        fresh = PlanCache()
        assert fresh.load(path) == 2
        assert "p0" in fresh and "p1" in fresh
        st = fresh.tenant_stats()
        assert st["a"].entries == 1 and st["b"].entries == 1
        # Restores count as neither hits nor misses.
        assert st["a"].hits == 0 and st["a"].misses == 0
        p1 = fresh.peek("p1")
        np.testing.assert_array_equal(
            p1.result.labels, np.zeros(30, dtype=np.int32))
        # Pin state and lineage refs survive: p0 outlives cheap-score eviction.
        plan_bytes = make_plan("x", m=20).nbytes()
        tight = PlanCache(default_tenant_budget=2 * plan_bytes)
        tight.load(path)
        assert "p0" in tight

    def test_load_respects_budgets(self, tmp_path):
        path = str(tmp_path / "cache.pkl")
        cache = PlanCache()
        for i in range(4):
            cache.put(make_plan(f"p{i}"), tenant="t")
        cache.save(path)
        plan_bytes = make_plan("x").nbytes()
        small = PlanCache(default_tenant_budget=2 * plan_bytes)
        assert small.load(path) == 2
        assert len(small) == 2

    def test_load_rejects_foreign_file(self, tmp_path):
        path = tmp_path / "junk.pkl"
        import pickle

        path.write_bytes(pickle.dumps({"not": "a cache"}))
        with pytest.raises(ValueError, match="snapshot"):
            PlanCache().load(str(path))

    def test_truncated_snapshot_is_cold_start(self, tmp_path):
        """A snapshot cut short mid-write (crash, full disk on an old
        non-atomic writer) must read as empty, not raise."""
        path = tmp_path / "cache.pkl"
        cache = PlanCache()
        cache.put(make_plan("p0"))
        cache.put(make_plan("p1"))
        cache.save(str(path))
        blob = path.read_bytes()
        path.write_bytes(blob[: len(blob) // 2])
        fresh = PlanCache()
        assert fresh.load(str(path)) == 0
        assert len(fresh) == 0

    def test_garbage_bytes_are_cold_start(self, tmp_path):
        path = tmp_path / "cache.pkl"
        path.write_bytes(b"\x00\x93 definitely not a pickle stream")
        assert PlanCache().load(str(path)) == 0

    def test_failed_save_leaves_old_snapshot_intact(self, tmp_path, monkeypatch):
        """save() stages into a temp file and os.replace()s it in, so a
        failure mid-pickle neither clobbers the previous snapshot nor
        leaves a temp file behind."""
        import pickle

        path = tmp_path / "cache.pkl"
        cache = PlanCache()
        cache.put(make_plan("p0"))
        assert cache.save(str(path)) == 1

        def explode(*_a, **_k):
            raise RuntimeError("disk full")

        cache.put(make_plan("p1"))
        with monkeypatch.context() as m:
            m.setattr(pickle, "dump", explode)
            with pytest.raises(RuntimeError, match="disk full"):
                cache.save(str(path))
        assert sorted(p.name for p in tmp_path.iterdir()) == ["cache.pkl"]
        fresh = PlanCache()
        assert fresh.load(str(path)) == 1  # the p0-only snapshot survived
        assert "p0" in fresh and "p1" not in fresh


class TestPlanNbytes:
    def test_vcycle_payload_counted(self):
        """PR 4's per-level V-cycle records are real cached memory; budget
        accounting must see them (the satellite fix this test guards)."""
        bare = make_plan("a")
        levels = [{"n": 1000, "nnz": 5000, "coarse_n": 300, "ratio": 3.3,
                   "time_s": 0.01} for _ in range(6)]
        vc = {"levels": 6, "coarsest_n": 300, "coarsen_mode": "cluster",
              "coarsen_levels": levels}
        with_vc = make_plan("a", vcycle=vc)
        assert with_vc.nbytes() > bare.nbytes()
        deeper = make_plan("a", vcycle={**vc, "coarsen_levels": levels * 3})
        assert deeper.nbytes() > with_vc.nbytes()

    def test_stage_times_and_coo_counted(self):
        bare = make_plan("a")
        st = {"coarsen": 0.1, "init": 0.02, "refine": 0.03, "pack": 0.01}
        assert make_plan("a", stage_times=st).nbytes() > bare.nbytes()
        rows = np.zeros(100, dtype=np.int64)
        cols = np.zeros(100, dtype=np.int64)
        with_coo = make_plan("a", coo=(10, 10, rows, cols))
        assert with_coo.nbytes() >= bare.nbytes() + rows.nbytes + cols.nbytes

    def test_payload_nbytes_shapes(self):
        assert _payload_nbytes(None) == 0
        assert _payload_nbytes(1.0) == 8
        assert _payload_nbytes([1.0, 2.0]) == 56 + 16
        assert _payload_nbytes({"a": 1}) > 8
        assert _payload_nbytes(np.zeros(4, dtype=np.int64)) == 32


class TestMisc:
    def test_get_counts_hit_for_requesting_tenant(self):
        cache = PlanCache()
        cache.put(make_plan("p"), tenant="owner")
        assert cache.get("p", "guest") is not None
        st = cache.tenant_stats()
        assert st["guest"].hits == 1
        assert st["owner"].hits == 0

    def test_remove_and_contains(self):
        cache = PlanCache()
        cache.put(make_plan("p"), tenant="t")
        assert "p" in cache
        assert cache.remove("p")
        assert "p" not in cache and not cache.remove("p")
        assert cache.tenant_stats()["t"].evictions == 0  # removal != eviction

    def test_reput_same_fingerprint_keeps_owner_and_pin(self):
        cache = PlanCache()
        cache.put(make_plan("p", cost=1.0), tenant="owner")
        cache.pin("p")
        cache.put(make_plan("p", cost=2.0), tenant="other")
        st = cache.tenant_stats()
        assert st["owner"].entries == 1
        assert st.get("other", None) is None or st["other"].entries == 0
        assert cache.peek("p").compute_time_s == 2.0

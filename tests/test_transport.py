"""Cross-process transport: frame codec, handshake, PlanServer, RemoteReplica.

The cheap tests run the server in-process (``PlanServer.start()`` on a
daemon thread) so protocol behaviour — truncation, deadlines, severed
connections, gossip — is exercised without paying a subprocess spawn.  The
``TestWorkerProcess`` class then crosses a real process boundary via
``spawn_worker`` / ``spawn_process_group`` and checks the property the
whole design rests on: plans that travel the wire are byte-identical to
plans computed locally, and a ``kill -9``-ed worker loses no submitted
work once the group fails over.
"""
import os
import pickle
import socket
import struct
import time

import numpy as np
import pytest

from repro.core import (
    FaultInjector,
    PartitionService,
    ReplicaGroup,
    synthetic_mesh_graph,
    synthetic_random_graph,
)
from repro.core.transport import (
    WIRE_MAGIC,
    DeadlineExceeded,
    PlanServer,
    ProtocolError,
    RemoteReplica,
    ReplicaConnection,
    WireError,
    _check_handshake,
    recv_frame,
    send_frame,
)
from repro.launch.replica_worker import spawn_process_group, spawn_worker

_LEN = struct.Struct(">I")


def _wait(pred, timeout=10.0, dt=0.005):
    t0 = time.monotonic()
    while time.monotonic() - t0 < timeout:
        if pred():
            return True
        time.sleep(dt)
    return pred()


class TestFrameCodec:
    def test_round_trip_preserves_arrays(self):
        a, b = socket.socketpair()
        try:
            payload = {"labels": np.arange(257, dtype=np.int32),
                       "nested": {"k": 4, "fp": "abc" * 40}}
            send_frame(a, payload)
            got = recv_frame(b, deadline_s=5.0)
            np.testing.assert_array_equal(got["labels"], payload["labels"])
            assert got["nested"] == payload["nested"]
        finally:
            a.close()
            b.close()

    def test_truncated_frame_raises_protocol_error(self):
        a, b = socket.socketpair()
        try:
            # Promise 1 MiB, deliver 7 bytes, hang up — the mid-frame sever.
            a.sendall(_LEN.pack(1 << 20) + b"severed")
            a.close()
            with pytest.raises(ProtocolError, match="mid-frame"):
                recv_frame(b, deadline_s=5.0)
        finally:
            b.close()

    def test_oversized_length_prefix_rejected(self):
        a, b = socket.socketpair()
        try:
            a.sendall(_LEN.pack((1 << 30) + 1))
            with pytest.raises(ProtocolError, match="exceeds cap"):
                recv_frame(b, deadline_s=5.0)
        finally:
            a.close()
            b.close()

    def test_undecodable_body_raises_protocol_error(self):
        a, b = socket.socketpair()
        try:
            body = b"\x00\x01not a pickle"
            a.sendall(_LEN.pack(len(body)) + body)
            with pytest.raises(ProtocolError, match="undecodable"):
                recv_frame(b, deadline_s=5.0)
        finally:
            a.close()
            b.close()

    def test_recv_deadline_raises_deadline_exceeded(self):
        a, b = socket.socketpair()
        try:
            with pytest.raises(DeadlineExceeded):
                recv_frame(b, deadline_s=0.05)
        finally:
            a.close()
            b.close()

    def test_handshake_version_and_magic_checked(self):
        with pytest.raises(ProtocolError, match="version"):
            _check_handshake({"magic": WIRE_MAGIC, "version": 99}, "peer")
        with pytest.raises(ProtocolError, match="protocol"):
            _check_handshake({"magic": "something-else", "version": 1}, "peer")
        with pytest.raises(ProtocolError):
            _check_handshake(b"GET / HTTP/1.1", "peer")


@pytest.fixture
def inproc_server():
    svc = PartitionService(workers=1)
    server = PlanServer(svc).start()
    yield svc, server
    server.shutdown()
    svc.close()


class TestPlanServerInProcess:
    def test_submit_over_wire_is_byte_identical(self, inproc_server):
        svc, server = inproc_server
        rep = RemoteReplica(server.address)
        edges = synthetic_mesh_graph(18, seed=7)
        t = rep.submit(edges, 4)
        sp = t.result(60)
        # The wire copy must match the server-resident original bit for bit.
        local = svc.plan_cache.peek(sp.fingerprint)
        assert local is not None and local is not sp
        assert sp.fingerprint == local.fingerprint
        np.testing.assert_array_equal(sp.result.labels, local.result.labels)
        rep.close()

    def test_bad_handshake_dropped_server_keeps_serving(self, inproc_server):
        _svc, server = inproc_server
        raw = socket.create_connection(server.address, timeout=5)
        try:
            send_frame(raw, {"magic": WIRE_MAGIC, "version": 99}, 5.0)
            raw.settimeout(5)
            assert raw.recv(1) == b""  # server hung up without answering
        finally:
            raw.close()
        # A well-behaved client on a fresh connection is unaffected.
        conn = ReplicaConnection(server.address)
        assert conn.call("ping")["pid"] == os.getpid()
        conn.close()

    def test_severed_connection_keeps_tickets(self, inproc_server):
        _svc, server = inproc_server
        rep = RemoteReplica(server.address)
        edges = synthetic_random_graph(150, 500, seed=11)
        t = rep.submit(edges, 4)
        # Cut the socket mid-frame: the server handler must survive the
        # truncated read, and the ticket must outlive the connection.
        rep.sever_connection(mid_frame=True)
        sp = t.result(60)
        assert sp is not None and sp.fingerprint
        assert rep._conn.reconnects >= 1
        rep.close()

    def test_gossip_pull_push_round_trip(self, inproc_server):
        svc, server = inproc_server
        rep = RemoteReplica(server.address)
        sp = rep.submit(synthetic_mesh_graph(16, seed=3), 4).result(60)
        fps = rep.gossip_fingerprints()
        assert sp.fingerprint in fps
        entries = rep.gossip_pull([sp.fingerprint])
        assert [e[0] for e in entries] == [sp.fingerprint]

        svc2 = PartitionService(workers=1)
        server2 = PlanServer(svc2).start()
        rep2 = RemoteReplica(server2.address)
        try:
            assert rep2.gossip_push(entries) == 1
            assert sp.fingerprint in rep2.gossip_fingerprints()
            pulled = rep2.gossip_pull([sp.fingerprint])[0][3]
            np.testing.assert_array_equal(pulled.result.labels,
                                          sp.result.labels)
        finally:
            rep2.close()
            server2.shutdown()
            svc2.close()
        rep.close()

    def test_unknown_op_and_unknown_ticket_raise_wire_error(self, inproc_server):
        _svc, server = inproc_server
        conn = ReplicaConnection(server.address)
        with pytest.raises(WireError, match="unknown op"):
            conn.call("bogus")
        with pytest.raises(WireError, match="unknown ticket"):
            conn.call("poll", {"ticket": 999_999})
        # Transported errors do not cost the connection.
        assert conn.call("ping")["closed"] is False
        conn.close()

    def test_group_gossip_anti_entropy_over_wire(self):
        svc_a = PartitionService(workers=1)
        svc_b = PartitionService(workers=1)
        srv_a = PlanServer(svc_a).start()
        srv_b = PlanServer(svc_b).start()
        reps = [RemoteReplica(srv_a.address), RemoteReplica(srv_b.address)]
        try:
            with ReplicaGroup(reps, backoff_base_s=0.001) as g:
                e = synthetic_random_graph(120, 400, seed=5)
                sp = g.get(e, 4, timeout=60)

                # Pairwise gossip converges both worker caches on the plan.
                # pump() is driven manually: sync rounds piggyback on live
                # request traffic, and this group is now idle.
                def synced():
                    g.pump()
                    return (sp.fingerprint in svc_a.plan_cache.fingerprints()
                            and sp.fingerprint
                            in svc_b.plan_cache.fingerprints())

                assert _wait(synced, 20)
        finally:
            srv_a.shutdown()
            srv_b.shutdown()
            svc_a.close()
            svc_b.close()


class TestOverloadOverWire:
    def test_rejection_is_typed_frame_not_severed_connection(self):
        """An admission rejection answers as a typed error frame: the
        client re-raises :class:`AdmissionRejectedError` with the hint and
        tenant intact, the connection survives (no reconnect backoff), and
        the same socket serves the next request once the queue drains."""
        import threading

        from repro.core import AdmissionRejectedError

        svc = PartitionService(workers=1, max_queue_depth=1)
        gate = threading.Event()
        started = threading.Event()

        def hook(_key):
            started.set()
            gate.wait(10)

        svc.scheduler.pre_job_hook = hook
        server = PlanServer(svc).start()
        rep = RemoteReplica(server.address)
        try:
            graphs = [synthetic_mesh_graph(14 + 2 * i, seed=40 + i)
                      for i in range(3)]
            t0 = rep.submit(graphs[0], 4)  # picked up: stalls in the hook
            assert started.wait(10)
            t1 = rep.submit(graphs[1], 4)  # queued: holds the single slot
            with pytest.raises(AdmissionRejectedError) as ei:
                rep.submit(graphs[2], 4)
            err = ei.value
            assert err.reason == "queue_full"
            assert err.tenant == "default"
            assert err.retry_after_s > 0
            # The typed frame crossed the wire as data, not as a sever:
            # no reconnect happened and no backoff clock is armed.
            assert rep._conn.reconnects == 0
            assert rep._conn._fails == 0
            # Round trip: re-pickling the transported error is lossless.
            back = pickle.loads(pickle.dumps(err))
            assert back.__reduce__()[1] == err.__reduce__()[1]
            # The same connection keeps serving once the queue drains.
            gate.set()
            assert t0.result(60).fingerprint
            assert t1.result(60).fingerprint
            sp = rep.submit(graphs[2], 4).result(60)
            assert sp.fingerprint
            assert rep._conn.reconnects == 0
        finally:
            rep.close()
            server.shutdown()
            svc.close()

    def test_worker_process_surfaces_rejection(self):
        """Across a real process boundary: a worker spawned with a queue
        bound answers the typed rejection through spawn_worker's wire."""
        from repro.core import AdmissionRejectedError

        h = spawn_worker(queue_bound=1, stalls=[(1.0, 0, 1 << 30)])
        rep = RemoteReplica(h.address, process=h.proc, pid=h.pid)
        try:
            assert _wait(rep.heartbeat, 10)
            graphs = [synthetic_mesh_graph(14 + 2 * i, seed=50 + i)
                      for i in range(3)]
            rep.submit(graphs[0], 4)  # picked up: sits in the 1s stall
            time.sleep(0.25)          # let the worker reach the stall
            rep.submit(graphs[1], 4)  # queued: holds the single slot
            with pytest.raises(AdmissionRejectedError) as ei:
                rep.submit(graphs[2], 4)
            assert ei.value.reason == "queue_full"
            assert ei.value.retry_after_s > 0
            assert rep._conn.reconnects == 0
        finally:
            rep.close()
        assert h.proc.poll() is not None


class TestWorkerProcess:
    def test_remote_worker_byte_identical_and_kill(self):
        edges = synthetic_mesh_graph(18, seed=7)
        local = PartitionService(workers=1)
        try:
            ref = local.submit(edges, 4).result(120)
        finally:
            local.close()

        h = spawn_worker()
        rep = RemoteReplica(h.address, process=h.proc, pid=h.pid)
        try:
            assert _wait(rep.heartbeat, 10)
            assert rep.pid != os.getpid()
            sp = rep.submit(edges, 4).result(120)
            assert sp.fingerprint == ref.fingerprint
            np.testing.assert_array_equal(sp.result.labels, ref.result.labels)
            rep.sigkill()
            assert _wait(lambda: not rep.heartbeat(), 10)
            with pytest.raises((WireError, ConnectionError, OSError)):
                rep.submit(edges, 8)
        finally:
            rep.close()
        assert h.proc.poll() is not None

    def test_process_group_sigkill_failover_loses_nothing(self):
        inj = FaultInjector(seed=0).sigkill_after_jobs("r1", 1)
        stalls = [[(0.15, 0, 3)], [(0.15, 0, 3)]]
        with spawn_process_group(
                2, injector=inj, hedge=False, retry_budget=5,
                backoff_base_s=0.01, heartbeat_deadline_s=1.0,
                stalls_per_replica=stalls) as g:
            graphs = [synthetic_random_graph(150 + 10 * i, 500, seed=20 + i)
                      for i in range(6)]
            tickets = [g.submit(e, 4, tenant=f"t{i % 2}")
                       for i, e in enumerate(graphs)]
            plans = [t.result(180) for t in tickets]
            assert all(sp is not None and sp.fingerprint for sp in plans)
            # Six distinct graphs -> six distinct plans, none served stale.
            assert len({sp.fingerprint for sp in plans}) == len(plans)
            assert not any(t.stale for t in tickets)
            assert any(e[0] == "sigkill" for e in inj.events)

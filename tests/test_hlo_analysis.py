"""Loop-aware HLO cost analyzer: trip counts, dot flops, collective model."""
import pytest

from repro.launch.hlo import parse_collectives, roofline_terms, shape_bytes
from repro.launch.hlo_analysis import analyze_module

HLO = """\
HloModule test

%body (p: (s32[], f32[8,16])) -> (s32[], f32[8,16]) {
  %p = (s32[], f32[8,16]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %x = f32[8,16] get-tuple-element(%p), index=1
  %w = f32[16,16] constant({...})
  %dot.1 = f32[8,16] dot(%x, %w), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %ar = f32[8,16] all-reduce(%dot.1), replica_groups=[2,4]<=[8], to_apply=%sum
  %one = s32[] constant(1)
  %ni = s32[] add(%i, %one)
  ROOT %t = (s32[], f32[8,16]) tuple(%ni, %ar)
}

%cond (p2: (s32[], f32[8,16])) -> pred[] {
  %p2 = (s32[], f32[8,16]) parameter(0)
  %i2 = s32[] get-tuple-element(%p2), index=0
  %lim = s32[] constant(10)
  ROOT %cmp = pred[] compare(%i2, %lim), direction=LT
}

%sum (a: f32[], b: f32[]) -> f32[] {
  %a = f32[] parameter(0)
  %b = f32[] parameter(1)
  ROOT %s = f32[] add(%a, %b)
}

ENTRY %main (arg: f32[8,16]) -> (s32[], f32[8,16]) {
  %arg = f32[8,16] parameter(0)
  %zero = s32[] constant(0)
  %init = (s32[], f32[8,16]) tuple(%zero, %arg)
  ROOT %loop = (s32[], f32[8,16]) while(%init), condition=%cond, body=%body, backend_config={"known_trip_count":{"n":"10"},"known_init_step":{"init":"0","step":"1"}}
}
"""


class TestAnalyzer:
    def test_trip_count_multiplies_flops(self):
        m = analyze_module(HLO, 8)
        # dot: 2*8*16*16 = 4096 flops, x10 trips.
        assert m.dot_flops_unrolled == 4096
        assert m.flops == 40960

    def test_collective_trips_and_group_size(self):
        m = analyze_module(HLO, 8)
        # all-reduce of 8*16*4 = 512 B in groups of 4: 2*512*(3/4) = 768 B x10.
        assert m.collective_op_counts["all-reduce"] == 10
        assert m.collective_bytes == pytest.approx(7680.0)

    def test_memory_counts_dot_not_bookkeeping(self):
        m = analyze_module(HLO, 8)
        # Per trip: dot reads x(512)+w(1024), writes 512 -> 2048 B; the
        # all-reduce adds in+out 1024. GTE/tuple/constant are free.
        assert m.hbm_bytes == pytest.approx((2048 + 1024) * 10)


class TestShapeBytes:
    @pytest.mark.parametrize("dtype,dims,expect", [
        ("f32", "2,3", 24),
        ("bf16", "128", 256),
        ("s32", "", 4),
        ("pred", "8", 8),
    ])
    def test_sizes(self, dtype, dims, expect):
        assert shape_bytes(dtype, dims) == expect


class TestRooflineTerms:
    def test_dominant_and_fraction(self):
        t = roofline_terms(197e12, 819e9 * 2, 50e9 * 3, chips=1)
        assert t.compute_s == pytest.approx(1.0)
        assert t.memory_s == pytest.approx(2.0)
        assert t.collective_s == pytest.approx(3.0)
        assert t.dominant == "collective"
        assert t.step_time_s == pytest.approx(3.0)
        # at model_flops == hlo flops and 1 chip: fraction = compute/step.
        assert t.roofline_fraction(197e12, 1) == pytest.approx(1 / 3)


class TestLegacyParser:
    def test_parse_collectives_simple(self):
        text = "  %ag = f32[16,16] all-gather(%x), replica_groups=[4,2]<=[8], dimensions={0}\n"
        st = parse_collectives(text, 8)
        assert st.op_counts["all-gather"] == 1
        assert st.per_chip_bytes == pytest.approx(1024 * (1 / 2))

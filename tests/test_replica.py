"""ReplicaGroup: failover, hedging, shared store, stale serve, fault injection.

These tests drive the group with a deterministic :class:`FaultInjector`
(seeded crash/stall/heartbeat-drop schedules).  Routing is deterministic:
with two healthy replicas the first request's primary lane always lands on
``r1`` (round-robin starts past ``r0``), so schedules can pre-target the
primary.  Where a schedule stalls both replicas symmetrically, the primary
is discovered from the injector's event log instead (the first recorded
stall names the dispatching replica).
"""
import threading
import time

import numpy as np
import pytest

from repro.core import (
    AdmissionRejectedError,
    FaultInjector,
    PartitionService,
    ReplicaExhaustedError,
    ReplicaGroup,
    ServiceClosedError,
    affinity_graph_from_coo,
    synthetic_mesh_graph,
    synthetic_random_graph,
)
from repro.runtime.request import GraphRequest, GraphServer


def _coo(n_rows, n_cols, shift, nnz_per_row=3):
    """Hand-rolled COO with exactly ``n_rows * nnz_per_row`` entries.

    Different ``shift`` values give structurally different graphs with the
    SAME shape and nnz — what the stale-serve compatibility gate needs.
    """
    rows = np.repeat(np.arange(n_rows), nnz_per_row)
    offs = np.tile(np.arange(nnz_per_row) * (shift + 1) + shift, n_rows)
    cols = (rows + offs) % n_cols
    return rows.astype(np.int64), cols.astype(np.int64)


def _wait(pred, timeout=10.0, dt=0.005):
    t0 = time.monotonic()
    while time.monotonic() - t0 < timeout:
        if pred():
            return True
        time.sleep(dt)
    return pred()


def _primary_rid(injector, timeout=10.0):
    """The replica that dispatched the first (stalled) job."""
    assert _wait(lambda: any(e[0] == "stall" for e in injector.events), timeout)
    return next(e[1] for e in injector.events if e[0] == "stall")


def _other(group, rid):
    return next(r for r in group.replica_ids() if r != rid)


class TestBasics:
    def test_cold_then_warm_and_anti_entropy(self):
        with ReplicaGroup(2, sync_interval_s=0.0) as g:
            e = synthetic_random_graph(96, 300, seed=1)
            t1 = g.submit(e, 4)
            sp = t1.result(60)
            assert not t1.cache_hit and not t1.stale
            assert t1.replica in g.replica_ids()
            # Second submit: warm from the shared store, no recompute.
            t2 = g.submit(e, 4)
            assert t2.cache_hit and t2.done()
            assert t2.result(5) is sp
            # Anti-entropy: the pump copies the plan into every replica's
            # local cache, not just the one that computed it.
            g.pump()
            for rid in g.replica_ids():
                assert g._by_rid[rid].svc.plan_cache.peek(sp.fingerprint) is not None
            rm = g.replica_metrics()
            assert rm.lost == 0 and rm.store_publishes == 1
            assert sum(r.jobs_completed for r in rm.replicas) == 1

    def test_coalescing_shares_one_driver(self):
        inj = FaultInjector().stall_jobs("r0", 0.3).stall_jobs("r1", 0.3)
        with ReplicaGroup(2, injector=inj, hedge=False) as g:
            e = synthetic_mesh_graph(24, seed=2)
            results = []
            ts = [threading.Thread(target=lambda: results.append(
                g.get(e, 4, timeout=60))) for _ in range(3)]
            for t in ts:
                t.start()
            for t in ts:
                t.join(60)
            assert len(results) == 3
            assert results[0] is results[1] is results[2]
            rm = g.replica_metrics()
            assert rm.coalesced == 2 and rm.submitted == 3 and rm.resolved == 3
            assert g.stats.full_runs == 1

    def test_submit_after_close_fails_typed(self):
        g = ReplicaGroup(2)
        g.close()
        t = g.submit(synthetic_mesh_graph(12, seed=0), 4)
        with pytest.raises(ServiceClosedError):
            t.result(5)

    def test_explicit_services_and_update_path(self):
        svcs = [PartitionService(max_entries=16) for _ in range(2)]
        with ReplicaGroup(svcs) as g:
            e = synthetic_random_graph(200, 800, seed=3)
            sp = g.get(e, 4, timeout=60)
            up = g.update(sp.fingerprint, 4, insert_u=np.array([0, 1]),
                          insert_v=np.array([5, 6]), timeout=60)
            assert up.fingerprint != sp.fingerprint
            # The updated plan is published to the store too.
            assert g.store.peek(up.fingerprint) is not None

    def test_update_unknown_base_raises_keyerror(self):
        with ReplicaGroup(2) as g:
            with pytest.raises(KeyError):
                g.update_async("no-such-fingerprint", 4,
                               insert_u=np.array([0]), insert_v=np.array([1]))


class TestFailover:
    def test_kill_primary_midflight_fails_over(self):
        # Both replicas stall their first job so the primary lane is
        # reliably still in flight when we kill its replica.
        inj = (FaultInjector().stall_jobs("r0", 0.4, first=0, last=0)
               .stall_jobs("r1", 0.4, first=0, last=0))
        with ReplicaGroup(2, injector=inj, hedge=False) as g:
            e = synthetic_random_graph(128, 500, seed=4)
            t = g.submit(e, 4)
            primary = _primary_rid(inj)
            g.kill(primary)
            sp = t.result(60)
            assert sp.result.k == 4
            assert t.replica == _other(g, primary)
            assert t.retries >= 1
            rm = g.replica_metrics()
            assert rm.failovers >= 1 and rm.lost == 0
            row = next(r for r in rm.replicas if r.replica == primary)
            assert row.state == "crashed" and row.weight == 0.0
            assert row.failovers_from >= 1

    def test_queued_tickets_on_killed_replica_fail_over(self):
        """kill() drains the dead replica's queue (ServiceClosedError),
        which drivers treat as a failover signal — no ticket is lost."""
        inj = (FaultInjector().stall_jobs("r0", 0.3, first=0, last=0)
               .stall_jobs("r1", 0.3, first=0, last=0))
        with ReplicaGroup(2, injector=inj, hedge=False) as g:
            graphs = [synthetic_mesh_graph(14 + 2 * i, seed=i) for i in range(4)]
            tickets = [g.submit(e, 4) for e in graphs]
            primary = _primary_rid(inj)
            g.kill(primary)
            plans = [t.result(60) for t in tickets]
            assert all(p.result.k == 4 for p in plans)
            assert g.replica_metrics().lost == 0

    def test_stalled_primary_goes_suspect_and_drains_routing(self):
        # The primary (deterministically r1) sits on a 0.8s straggler and
        # never beats; with a 0.15s deadline the pump marks it suspect
        # mid-job and the driver resubmits to r0.
        inj = FaultInjector().stall_jobs("r1", 0.8, first=0, last=0)
        with ReplicaGroup(2, injector=inj, hedge=False,
                          heartbeat_deadline_s=0.15) as g:
            e = synthetic_random_graph(128, 500, seed=5)
            t = g.submit(e, 4)
            primary = _primary_rid(inj)
            assert primary == "r1"
            # The driver's pump declares r1 suspect while it sits on the
            # straggler (routing weight 0 — observed via the registry).
            assert _wait(lambda: "r1" in g.registry.dead, timeout=10.0)
            sp = t.result(60)
            assert sp.result.k == 4
            assert t.replica == "r0"
            rm = g.replica_metrics()
            assert rm.failovers >= 1 and rm.lost == 0
            # Suspect is not a death sentence: once the straggler drains and
            # r1 goes idle, the pump's beat resurrects it.
            assert _wait(lambda: (g.pump(), "r1" not in g.registry.dead)[1],
                         timeout=10.0)

    def test_dropped_heartbeats_mark_suspect_then_recover_on_beat(self):
        inj = FaultInjector().drop_heartbeats("r0", 8).drop_heartbeats("r1", 8)
        with ReplicaGroup(2, injector=inj, heartbeat_deadline_s=0.05) as g:
            def states():
                g.pump()
                return {r.replica: r.state for r in g.replica_metrics().replicas}
            # Beats are swallowed: both idle replicas blow the deadline.
            assert _wait(lambda: all(s == "suspect" for s in states().values()),
                         timeout=10.0, dt=0.01)
            # Drop schedule exhausted: idle beats get through again and the
            # registry resurrects both replicas.
            assert _wait(lambda: all(s == "healthy" for s in states().values()),
                         timeout=10.0, dt=0.01)
            assert any(e[0] == "drop_beat" for e in inj.events)

    def test_coalesced_ticket_failover_multiple_waiters(self):
        """Failover of a coalesced ticket: several callers share one group
        request; the crash costs ONE failover, and every waiter gets the
        same recovered plan."""
        inj = FaultInjector().stall_jobs("r0", 0.4).stall_jobs("r1", 0.4)
        with ReplicaGroup(2, injector=inj, hedge=False) as g:
            e = synthetic_random_graph(150, 600, seed=6)
            results = []
            ts = [threading.Thread(target=lambda: results.append(
                g.get(e, 4, timeout=60))) for _ in range(3)]
            for th in ts:
                th.start()
            primary = _primary_rid(inj)
            assert _wait(lambda: g.replica_metrics().coalesced == 2)
            g.kill(primary)
            for th in ts:
                th.join(60)
            assert len(results) == 3
            assert results[0] is results[1] is results[2]
            rm = g.replica_metrics()
            assert rm.failovers == 1  # one shared request, one failover
            assert rm.submitted == 3 and rm.resolved == 3 and rm.lost == 0

    def test_retry_budget_exhaustion_raises_typed_error(self):
        with ReplicaGroup(2, retry_budget=2, backoff_base_s=0.001,
                          hedge=False) as g:
            def boom(*a, **kw):
                raise RuntimeError("injected submit failure")
            for rid in g.replica_ids():
                g._by_rid[rid].svc.submit = boom
            t = g.submit(synthetic_mesh_graph(16, seed=7), 4)
            with pytest.raises(ReplicaExhaustedError, match="budget"):
                t.result(30)
            rm = g.replica_metrics()
            assert rm.failed == 1 and rm.retries >= 2 and rm.lost == 0


class TestHedging:
    def test_hedge_wins_over_straggler(self):
        # Primary (r1) stalls 0.6s; the hedge fires onto clean r0 after
        # 30ms and wins by a wide margin.
        inj = FaultInjector().stall_jobs("r1", 0.6, first=0, last=0)
        with ReplicaGroup(2, injector=inj, hedge_delay_s=0.03) as g:
            e = synthetic_random_graph(128, 500, seed=8)
            t0 = time.monotonic()
            t = g.submit(e, 4)
            sp = t.result(60)
            dt = time.monotonic() - t0
            assert sp.result.k == 4
            assert t.hedged and t.replica == "r0"
            assert dt < 0.55  # beat the 0.6s straggler
            rm = g.replica_metrics()
            assert rm.hedges_fired == 1 and rm.hedges_won == 1
            assert rm.hedges_lost == 0 and rm.lost == 0

    def test_hedge_fires_but_primary_wins(self):
        """Satellite case: both lanes stall 0.3s, but the hedge starts 50ms
        behind the primary — the primary finishes first, the loser is
        cancelled through the PlanScheduler path, and the shared store sees
        exactly one publish (no double-publish)."""
        inj = (FaultInjector().stall_jobs("r0", 0.3, first=0, last=0)
               .stall_jobs("r1", 0.3, first=0, last=0))
        with ReplicaGroup(2, injector=inj, hedge_delay_s=0.05) as g:
            e = synthetic_random_graph(150, 600, seed=9)
            t = g.submit(e, 4)
            sp = t.result(60)
            assert sp.result.k == 4
            assert t.hedged and t.replica == "r1"  # primary won
            rm = g.replica_metrics()
            assert rm.hedges_fired == 1
            assert rm.hedges_won == 0 and rm.hedges_lost == 1
            assert rm.store_publishes == 1 and len(g.store) == 1
            # The losing lane on r0 was cancelled, not left to run blind.
            m = g._by_rid["r0"].svc.metrics()
            assert m.cancelled_queued + m.cancelled_inflight >= 1

    def test_hedge_delay_derives_from_p99(self):
        with ReplicaGroup(2, hedge_min_delay_s=0.02, hedge_p99_factor=2.0) as g:
            assert g._hedge_delay() == pytest.approx(0.02)  # no samples yet
            with g._lock:
                for _ in range(100):
                    g._latencies.append(0.05)
            assert g._hedge_delay() == pytest.approx(0.10)

    def test_hedge_clamped_to_request_deadline(self):
        """Regression: a request whose remaining deadline budget is below
        ``hedge_min_delay_s`` must never hedge — a secondary lane opened
        that close to expiry cannot win, it only burns a replica slot.
        Identical setup to test_hedge_wins_over_straggler (where the hedge
        fires and wins) except the deadline budget is below the floor."""
        inj = FaultInjector().stall_jobs("r1", 0.3, first=0, last=0)
        with ReplicaGroup(2, injector=inj, hedge_delay_s=0.02,
                          hedge_min_delay_s=10.0) as g:
            e = synthetic_random_graph(128, 500, seed=21)
            t = g.submit(e, 4, timeout=5.0)  # budget 5s < 10s floor
            sp = t.result(60)
            assert sp.result.k == 4
            # The primary rode out its 0.3s stall alone.
            assert not t.hedged and t.replica == "r1"
            assert g.replica_metrics().hedges_fired == 0

    def test_no_hedge_when_single_healthy_replica(self):
        inj = FaultInjector().stall_jobs("r0", 0.2, first=0, last=0)
        with ReplicaGroup(2, injector=inj, hedge_delay_s=0.0) as g:
            g.kill("r1")
            sp = g.get(synthetic_mesh_graph(20, seed=10), 4, timeout=60)
            assert sp.result.k == 4
            assert g.replica_metrics().hedges_fired == 0


class TestStaleServe:
    def test_all_down_serves_freshest_compatible_plan_stale(self):
        with ReplicaGroup(2, retry_budget=1, backoff_base_s=0.001) as g:
            n = 96
            rows_a, cols_a = _coo(n, n, shift=0)
            sp_a = g.get_spmv_plan(n, n, rows_a, cols_a, 4, timeout=60)
            for rid in g.replica_ids():
                g.kill(rid)
            # Same shape/nnz, different structure: served stale from store.
            rows_b, cols_b = _coo(n, n, shift=5)
            assert len(rows_b) == len(rows_a)
            tb = g.submit(affinity_graph_from_coo(n, n, rows_b, cols_b), 4,
                          coo=(n, n, rows_b, cols_b))
            sp_b = tb.result(30)
            assert tb.stale and sp_b is sp_a
            assert g.replica_metrics().stale_serves == 1
            # Exact-fingerprint rerequest of A: a warm store hit, NOT stale.
            ta = g.submit(affinity_graph_from_coo(n, n, rows_a, cols_a), 4,
                          coo=(n, n, rows_a, cols_a))
            assert ta.cache_hit and not ta.stale
            assert ta.result(5) is sp_a

    def test_incompatible_shape_is_never_served_stale(self):
        """The degraded path must not hand back a plan whose operands would
        not even fit the request — wrong shape raises instead."""
        with ReplicaGroup(2, retry_budget=1, backoff_base_s=0.001) as g:
            rows, cols = _coo(96, 96, shift=0)
            g.get_spmv_plan(96, 96, rows, cols, 4, timeout=60)
            for rid in g.replica_ids():
                g.kill(rid)
            rows2, cols2 = _coo(64, 64, shift=0)  # different dims + nnz
            t = g.submit(affinity_graph_from_coo(64, 64, rows2, cols2), 4,
                         coo=(64, 64, rows2, cols2))
            with pytest.raises(ReplicaExhaustedError):
                t.result(30)

    def test_all_down_update_serves_base_stale(self):
        with ReplicaGroup(2) as g:
            e = synthetic_random_graph(200, 800, seed=13)
            sp = g.get(e, 4, timeout=60)
            for rid in g.replica_ids():
                g.kill(rid)
            t = g.update_async(sp.fingerprint, 4, insert_u=np.array([0]),
                               insert_v=np.array([3]))
            got = t.result(30)
            assert t.stale and got is sp  # freshest known state of the graph

    def test_all_down_nothing_compatible_raises_exhausted(self):
        with ReplicaGroup(2, retry_budget=1, backoff_base_s=0.001) as g:
            for rid in g.replica_ids():
                g.kill(rid)
            t = g.submit(synthetic_mesh_graph(18, seed=14), 4)
            with pytest.raises(ReplicaExhaustedError):
                t.result(30)

    def test_stale_disabled_raises_even_with_store(self):
        with ReplicaGroup(2, retry_budget=1, backoff_base_s=0.001,
                          allow_stale=False) as g:
            e = synthetic_random_graph(96, 300, seed=15)
            sp = g.get(e, 4, timeout=60)
            for rid in g.replica_ids():
                g.kill(rid)
            t = g.update_async(sp.fingerprint, 4, insert_u=np.array([0]),
                               insert_v=np.array([1]))
            with pytest.raises(ReplicaExhaustedError):
                t.result(30)


class TestRequestDeadline:
    def test_deadline_beats_retry_budget(self):
        """``timeout`` is an end-to-end deadline: with every replica
        stalled past it, the driver gives up when the clock expires — not
        after burning a (here deliberately huge) retry budget — and the
        error names the deadline."""
        inj = (FaultInjector(seed=0)
               .stall_jobs("r0", 0.6).stall_jobs("r1", 0.6))
        with ReplicaGroup(2, injector=inj, hedge=False, retry_budget=100,
                          backoff_base_s=0.001, allow_stale=False) as g:
            t = g.submit(synthetic_mesh_graph(18, seed=3), 4, timeout=0.15)
            t0 = time.monotonic()
            with pytest.raises(ReplicaExhaustedError, match="deadline"):
                t.result(30)
            # It did not wait out the 0.6s stall, let alone 100 retries.
            assert time.monotonic() - t0 < 0.5

    def test_completed_result_wins_over_expired_deadline(self):
        """The deadline is checked after reaping, so a result that landed
        just in time is returned even if the clock has since expired."""
        with ReplicaGroup(2, hedge=False, backoff_base_s=0.001) as g:
            e = synthetic_mesh_graph(16, seed=9)
            sp = g.get(e, 4, timeout=60)
            # Warm store: resolved before the driver ever checks the clock.
            t = g.submit(e, 4, timeout=60)
            assert t.result(30) is sp


class TestOverloadBreakers:
    def test_sustained_rejections_trip_breaker_fail_fast_then_recover(self):
        """A tenant that keeps blowing the replica's queue bound trips the
        per-(replica, tenant) breaker; while it is open the driver answers
        the typed rejection immediately (reason="breaker_open") without
        dispatching; after the cooldown one half-open probe re-closes it."""
        g = ReplicaGroup(1, hedge=False, allow_stale=False, retry_budget=1,
                         backoff_base_s=0.001, backoff_cap_s=0.002,
                         breaker_failures=4, breaker_cooldown_s=0.25,
                         workers=1, max_queue_depth=1)
        try:
            gate = threading.Event()
            started = threading.Event()
            sched = g._replicas[0].svc.scheduler

            def hook(_key):
                started.set()
                gate.wait(10)

            sched.pre_job_hook = hook
            graphs = [synthetic_mesh_graph(14 + 2 * i, seed=30 + i)
                      for i in range(5)]
            t_run = g.submit(graphs[0], 4)  # picked up: stalls in the hook
            assert started.wait(10)
            t_q = g.submit(graphs[1], 4)  # queued: holds the single slot
            assert _wait(lambda: sched.metrics_snapshot()
                         .admission["occupancy"].get("default", 0) == 1)
            # First over-bound request: the replica answers queue_full
            # rejections until the retry budget burns (primary + one
            # failover re-dispatch = two breaker failures, still closed).
            t = g.submit(graphs[2], 4)
            with pytest.raises(AdmissionRejectedError) as ei:
                t.result(30)
            assert ei.value.reason == "queue_full"
            assert ei.value.retry_after_s > 0
            # Second: its own rejected dispatches are the breaker's third
            # and fourth consecutive failures — the breaker trips
            # mid-request and the driver fails fast on the next pass.
            t = g.submit(graphs[3], 4)
            with pytest.raises(AdmissionRejectedError) as ei:
                t.result(30)
            assert ei.value.reason == "breaker_open"
            assert g.breaker_states()["r0"] == "open"
            # Open breaker: rejected without ever touching the replica.
            t = g.submit(graphs[4], 4)
            with pytest.raises(AdmissionRejectedError) as ei:
                t.result(30)
            assert ei.value.reason == "breaker_open"
            assert ei.value.retry_after_s > 0
            row = g.replica_metrics().replicas[0]
            assert row.rejections == 4  # the fail-fast path never dispatched
            assert row.breakers_open == 1 and row.breaker_trips >= 1
            # Drain the queue, ride out the cooldown: the half-open probe
            # dispatch succeeds and re-closes the breaker.
            gate.set()
            t_run.result(30)
            t_q.result(30)
            time.sleep(0.3)
            sp = g.get(graphs[4], 4, timeout=30)
            assert sp.result.k == 4
            assert g.breaker_states()["r0"] == "closed"
        finally:
            g.close()


class TestGraphServerIntegration:
    def test_serve_through_replica_group_and_stale_flag(self):
        n = 96
        rows, cols = _coo(n, n, shift=0)
        rng = np.random.default_rng(0)
        vals = rng.standard_normal(rows.shape[0]).astype(np.float32)
        x = rng.standard_normal(n).astype(np.float32)
        with ReplicaGroup(2, retry_budget=1, backoff_base_s=0.001) as g:
            server = GraphServer(service=g, k=4, start_batcher=False)
            res = server.serve(GraphRequest(n, n, rows, cols, vals, x))
            assert res.info.stale is False
            y_ref = np.zeros(n, np.float32)
            np.add.at(y_ref, rows, vals * x[cols])
            np.testing.assert_allclose(np.asarray(res.y), y_ref, rtol=1e-4,
                                       atol=1e-4)
            # Kill everything; a same-shape different-structure request is
            # served from the stale plan and flagged on ServeInfo.
            for rid in g.replica_ids():
                g.kill(rid)
            rows2, cols2 = _coo(n, n, shift=5)
            res2 = server.serve(GraphRequest(n, n, rows2, cols2, vals, x))
            assert res2.info.stale is True
            # The flag round-trips through the legacy dict view too.
            assert res.info.as_dict()["stale"] is False
            assert res2.info.as_dict()["stale"] is True
            # Metrics still flow through the aggregated group snapshot.
            snap = server.metrics()
            assert snap.workers == 2

    def test_stale_disabled_server_raises_when_all_down(self):
        """``allow_stale=False`` is a correctness contract: with no healthy
        replica, GraphServer.serve surfaces ReplicaExhaustedError rather
        than silently answering from a stale plan."""
        n = 96
        rows, cols = _coo(n, n, shift=0)
        rng = np.random.default_rng(1)
        vals = rng.standard_normal(rows.shape[0]).astype(np.float32)
        x = rng.standard_normal(n).astype(np.float32)
        with ReplicaGroup(2, retry_budget=1, backoff_base_s=0.001,
                          allow_stale=False) as g:
            server = GraphServer(service=g, k=4, start_batcher=False)
            res = server.serve(GraphRequest(n, n, rows, cols, vals, x))
            assert res.info.stale is False
            for rid in g.replica_ids():
                g.kill(rid)
            # Same shape, different structure: exactly what the stale path
            # would have served had it been allowed.
            rows2, cols2 = _coo(n, n, shift=5)
            with pytest.raises(ReplicaExhaustedError):
                server.serve(GraphRequest(n, n, rows2, cols2, vals, x))
